"""AOT compile path: lower the L2 graph to HLO *text* artifacts.

HLO text (NOT ``lowered.compile()`` / serialized HloModuleProto) is the
interchange format: jax ≥ 0.5 emits protos with 64-bit instruction ids which
the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/gen_hlo.py and /opt/xla-example/README.md.

Run once via ``make artifacts``; Rust (`rust/src/runtime/`) loads the text,
compiles it on the PJRT CPU client, and executes it on the request path —
Python never runs at evaluation time.

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import constants as K
from .kernels.cim_energy import energy_latency


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange).

    The text must be printed with ``print_large_constants``: the default
    printer elides big literals as ``...``, which the HLO text parser then
    reads back as *zeros* — the ``sensitivity`` (jax.grad) artifact carries
    one such constant and silently produced all-zero gradients before this
    was forced on (caught by test_aot.py::test_roundtrip_numerics).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    return comp.as_hlo_module().to_string(opts)


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def entry_points(batch: int):
    """(name, fn, example-arg specs) for every artifact we ship."""
    cfg = _spec(batch, K.NCFG)
    tech = _spec(K.NTECH, K.NTECH_PARAMS)
    unit = _spec(K.NC)
    group = _spec(K.NC, K.NCOMP)
    counters = _spec(batch, K.NC)
    perf = _spec(batch, K.NPERF)

    def energy_model(c, t):
        return energy_latency(c, t)

    return [
        ("energy_model", energy_model, (cfg, tech)),
        ("profiler", model.evaluate_system,
         (cfg, cfg, tech, unit, group, counters, counters, perf)),
        ("sensitivity", model.sensitivity,
         (cfg, cfg, tech, unit, group, counters, counters, perf)),
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--batch", type=int, default=K.AOT_BATCH,
                    help="design-point batch size baked into the artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"batch": args.batch, "ncfg": K.NCFG, "nops": K.NOPS,
                "nc": K.NC, "ncomp": K.NCOMP, "nperf": K.NPERF,
                "ntech": K.NTECH, "ntech_params": K.NTECH_PARAMS,
                "counter_names": K.COUNTER_NAMES, "comp_names": K.COMP_NAMES,
                "op_names": K.OP_NAMES, "artifacts": {}}

    for name, fn, specs in entry_points(args.batch):
        # keep_unused pins the full parameter list into the HLO signature so
        # the Rust runtime can pass a uniform argument set to every artifact
        # (jit would otherwise DCE e.g. counters_base out of `sensitivity`).
        lowered = jax.jit(fn, keep_unused=True).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        n_out = len(jax.tree_util.tree_leaves(
            jax.eval_shape(fn, *specs)))
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "num_inputs": len(specs),
            "num_outputs": n_out,
            "input_shapes": [list(s.shape) for s in specs],
        }
        print(f"wrote {path}: {len(text)} chars, "
              f"{len(specs)} inputs -> {n_out} outputs")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out, 'manifest.json')}")


if __name__ == "__main__":
    main()
