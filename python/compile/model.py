"""L2 JAX model: the full Eva-CiM profiling graph (build-time only).

Composes the two L1 Pallas kernels into the system-level evaluation the
paper's modified McPAT performs:

    per-op array energies/latencies  (cim_energy kernel, Table III / Fig 11)
      → per-counter unit energies    (hierarchy assembly, §V-C1)
      → component energies           (profile_agg kernel, Fig 10)
      → totals, energy improvement, constant-CPI speedup (§V-C2),
        processor/cache improvement breakdown (Table VI rows 4–5)

Everything is batched over B design points so the Rust coordinator can
evaluate a whole design-space sweep with a handful of PJRT executions.

`sensitivity` additionally exports the gradient of mean CiM-system energy
w.r.t. the (continuous) cache configuration columns for DSE guidance; it
uses the pure-jnp reference model because pallas_call(interpret=True) is
not differentiable — the math is identical (tested in python/tests/).

NOTE: the counter→component `group` matrix is a *runtime argument*, not a
captured constant — HLO text printing elides constants larger than a few
elements (`constant({...})`), which the text parser reads back as zeros and
would silently break the Rust AOT path (caught by test_aot.py).
"""

import jax
import jax.numpy as jnp

from .kernels import constants as K
from .kernels import ref
from .kernels.cim_energy import energy_latency
from .kernels.profile_agg import profile_agg


def _unit_energy(static_unit, e_l1, e_l2):
    """Assemble the [B, NC] per-counter unit-energy matrix.

    Core events (0..21) and DRAM/leakage come from the calibrated static
    vector; cache and CiM columns come from the array model.  Unit energies
    are *per access to that structure*: hierarchy effects (an L1 miss causing
    an L2 access causing a DRAM access) are carried by the counters, which
    the simulator increments at every level the request touches.
    """
    b = e_l1.shape[0]
    stat = jnp.broadcast_to(static_unit[None, :], (b, K.NC))

    # hierarchy accesses pay the H-tree/bus transport on top of the array
    # access; CiM ops do not (they compute in-array) — constants.XBUS_FACTOR
    rd1 = e_l1[:, K.OP_READ] * K.XBUS_FACTOR
    wr1 = e_l1[:, K.OP_WRITE] * K.XBUS_FACTOR
    rd2 = e_l2[:, K.OP_READ] * K.XBUS_FACTOR
    wr2 = e_l2[:, K.OP_WRITE] * K.XBUS_FACTOR
    fill1 = rd1 + wr1  # miss: tag probe + line refill write
    fill2 = rd2 + wr2

    dyn_cache = jnp.stack(
        [
            rd1, fill1,          # l1i hit / miss
            rd1, fill1,          # l1d read hit / miss
            wr1, fill1,          # l1d write hit / miss
            rd2, fill2,          # l2 read hit / miss
            wr2, fill2,          # l2 write hit / miss
        ],
        axis=1,
    )  # [B, 10]
    dyn_cim = jnp.concatenate(
        [e_l1[:, K.OP_OR:K.OP_ADD + 1], e_l2[:, K.OP_OR:K.OP_ADD + 1]], axis=1
    )  # [B, 8]

    return jnp.concatenate(
        [
            stat[:, :K.C_CACHE_BEGIN],          # core events (22 cols)
            dyn_cache,                          # l1i/l1d/l2 (10 cols)
            stat[:, 32:34],                     # dram read/write
            dyn_cim,                            # CiM ops (8 cols)
            stat[:, K.C_CYCLES:K.C_CYCLES + 1], # leakage per cycle
        ],
        axis=1,
    )


def _evaluate(cfg_l1, cfg_l2, tech_table, static_unit, group,
              counters_base, counters_cim, perf,
              energy_fn, agg_fn):
    e_l1, lat_l1 = energy_fn(cfg_l1, tech_table)
    e_l2, lat_l2 = energy_fn(cfg_l2, tech_table)

    unit = _unit_energy(static_unit, e_l1, e_l2)
    comps_base = agg_fn(counters_base, unit, group)    # [B, NCOMP]
    comps_cim = agg_fn(counters_cim, unit, group)

    # the paper's "total energy including both host CPU and cache" (§VI-B)
    # excludes main memory: DRAM traffic is reported but not part of the
    # improvement ratio.
    total_base = comps_base.sum(axis=1) - comps_base[:, K.COMP_DRAM]
    total_cim = comps_cim.sum(axis=1) - comps_cim[:, K.COMP_DRAM]
    eps = jnp.asarray(1e-9, total_cim.dtype)
    improvement = total_base / jnp.maximum(total_cim, eps)

    # ---- constant-CPI speedup model (§V-C2) -------------------------------
    cycles = perf[:, K.PERF_CYCLES_BASE]
    committed = jnp.maximum(perf[:, K.PERF_COMMITTED_BASE], 1.0)
    removed = perf[:, K.PERF_REMOVED]
    add_l1 = perf[:, K.PERF_CIM_ADD_L1]
    add_l2 = perf[:, K.PERF_CIM_ADD_L2]
    cpi = cycles / committed
    extra_l1 = jnp.maximum(lat_l1[:, K.OP_ADD] - lat_l1[:, K.OP_READ], 0.0)
    extra_l2 = jnp.maximum(lat_l2[:, K.OP_ADD] - lat_l2[:, K.OP_READ], 0.0)
    cycles_cim = cycles - removed * cpi + add_l1 * extra_l1 + add_l2 * extra_l2
    speedup = cycles / jnp.maximum(cycles_cim, 1.0)

    # ---- processor vs cache improvement breakdown (Table VI) --------------
    proc_base = comps_base[:, K.COMP_CORE] + comps_base[:, K.COMP_LEAK]
    proc_cim = comps_cim[:, K.COMP_CORE] + comps_cim[:, K.COMP_LEAK]
    delta_total = total_base - total_cim
    tiny = jnp.abs(delta_total) < eps
    safe = jnp.where(tiny, 1.0, delta_total)
    ratio_proc = jnp.where(tiny, 0.0, (proc_base - proc_cim) / safe)
    ratio_cache = jnp.where(tiny, 0.0, 1.0 - ratio_proc)

    return (comps_base, comps_cim, total_base, total_cim,
            improvement, speedup, ratio_proc, ratio_cache,
            e_l1, lat_l1, e_l2, lat_l2)


def evaluate_system(cfg_l1, cfg_l2, tech_table, static_unit, group,
                    counters_base, counters_cim, perf):
    """Full profiler graph using the Pallas kernels (the AOT'd entry point).

    Args:
      cfg_l1, cfg_l2: f32[B, NCFG]   per-design-point L1/L2 geometries.
      tech_table:     f32[NTECH, 4*NOPS] Table III / Fig 11 anchors.
      static_unit:    f32[NC]        calibrated core/DRAM/leakage unit pJ.
      group:          f32[NC, NCOMP] one-hot counter→component matrix.
      counters_base:  f32[B, NC]     baseline (non-CiM) counters.
      counters_cim:   f32[B, NC]     reshaped (CiM) counters.
      perf:           f32[B, NPERF]  speedup-model inputs.

    Returns the 12-tuple documented in `_evaluate`.
    """
    return _evaluate(cfg_l1, cfg_l2, tech_table, static_unit, group,
                     counters_base, counters_cim, perf,
                     energy_latency, profile_agg)


def evaluate_system_ref(cfg_l1, cfg_l2, tech_table, static_unit, group,
                        counters_base, counters_cim, perf):
    """Same graph on the pure-jnp oracles (test cross-check + grad path)."""
    return _evaluate(cfg_l1, cfg_l2, tech_table, static_unit, group,
                     counters_base, counters_cim, perf,
                     ref.energy_latency_ref, ref.profile_agg_ref)


def sensitivity(cfg_l1, cfg_l2, tech_table, static_unit, group,
                counters_base, counters_cim, perf):
    """d(mean total CiM-system energy)/d(cfg) — DSE guidance vector field.

    Returns (g_l1, g_l2): f32[B, NCFG] gradients.  Discrete columns (tech id,
    level) get gradients too; the Rust side masks them out.
    """
    def total_cim_mean(c1, c2):
        out = evaluate_system_ref(c1, c2, tech_table, static_unit, group,
                                  counters_base, counters_cim, perf)
        return out[3].mean()

    return jax.grad(total_cim_mean, argnums=(0, 1))(cfg_l1, cfg_l2)
