"""L1 Pallas kernel: McPAT-lite counter→component energy aggregation.

Computes ``comp[B, NCOMP] = (counters[B, NC] ⊙ unit[B, NC]) @ group[NC, NCOMP]``
tiled over the design-point batch.  The reduction over the counter axis is a
``[BLOCK_B, NC] × [NC, NCOMP]`` matmul — MXU work on a real TPU (NC=43 and
NCOMP=8 would be padded to the 128-lane tile; at AOT_BATCH=256 the padding
overhead is irrelevant next to the HBM→VMEM streaming of the counter tiles).

VMEM per step (f32): 2 × 128×43 + 43×8 + 128×8 ≈ 48 kB — under the 64 kB
budget of DESIGN §8.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import constants as K

BLOCK_B = 128


def _kernel(counters_ref, unit_ref, group_ref, out_ref):
    weighted = counters_ref[...] * unit_ref[...]        # [BLOCK_B, NC]
    out_ref[...] = weighted @ group_ref[...]            # [BLOCK_B, NCOMP]


@functools.partial(jax.jit, static_argnames=("block_b",))
def profile_agg(counters: jnp.ndarray, unit_energy: jnp.ndarray,
                group: jnp.ndarray, block_b: int = BLOCK_B) -> jnp.ndarray:
    """Pallas entry point matching :func:`ref.profile_agg_ref`."""
    b = counters.shape[0]
    if b % block_b:
        pad = block_b - b % block_b
        counters = jnp.pad(counters, ((0, pad), (0, 0)))
        unit_energy = jnp.pad(unit_energy, ((0, pad), (0, 0)))
    nb = counters.shape[0] // block_b

    out = pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_b, K.NC), lambda i: (i, 0)),
            pl.BlockSpec((block_b, K.NC), lambda i: (i, 0)),
            pl.BlockSpec((K.NC, K.NCOMP), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, K.NCOMP), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((counters.shape[0], K.NCOMP),
                                       counters.dtype),
        interpret=True,
    )(counters, unit_energy, group)
    return out[:b]
