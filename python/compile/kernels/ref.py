"""Pure-jnp reference oracles for the Pallas kernels.

These are the *correctness ground truth*: `cim_energy.py` and
`profile_agg.py` must agree with the functions here to float32 tolerance
(checked by pytest + hypothesis in python/tests/).  The Rust native model
(`rust/src/energy/array.rs`) mirrors the same math.
"""

import jax.numpy as jnp

from . import constants as K


def energy_latency_ref(cfg: jnp.ndarray, tech_table: jnp.ndarray):
    """Analytic DESTINY-lite array model, batched over design points.

    Power-law interpolation anchored at the published Table III points:

        E(cap, assoc) = E_L1 * (cap_eff / 64kB)^b * (assoc / 4)^0.15
        b = (ln(E_L2 / E_L1) - 0.15 * ln 2) / ln 4

    where ``cap_eff = cap * 4 / banks`` normalizes to the anchor's 4 sub-banks
    (a bank twice as big has longer bitlines → more energy) and the
    ``0.15 * ln 2`` term removes the associativity difference between the two
    anchors (4-way L1, 8-way L2).  Latency uses the same law without the
    associativity factor (Fig 11 anchors).

    Args:
      cfg:        f32[B, NCFG] design points (see constants.CFG_*).
      tech_table: f32[NTECH, 4*NOPS] anchor table (constants.DEFAULT_TECH_TABLE).

    Returns:
      (energy, latency): f32[B, NOPS] each — pJ per op, cycles per op.
    """
    cap = cfg[:, K.CFG_CAPACITY]
    assoc = cfg[:, K.CFG_ASSOC]
    banks = cfg[:, K.CFG_BANKS]
    tech = cfg[:, K.CFG_TECH]

    # one-hot select of the per-tech anchor rows (MXU-shaped in the kernel)
    onehot = (tech[:, None] == jnp.arange(K.NTECH, dtype=cfg.dtype)[None, :])
    params = onehot.astype(cfg.dtype) @ tech_table  # [B, 4*NOPS]

    e1 = params[:, K.TP_E_L1:K.TP_E_L1 + K.NOPS]
    e2 = params[:, K.TP_E_L2:K.TP_E_L2 + K.NOPS]
    l1 = params[:, K.TP_LAT_L1:K.TP_LAT_L1 + K.NOPS]
    l2 = params[:, K.TP_LAT_L2:K.TP_LAT_L2 + K.NOPS]

    ln4 = jnp.log(jnp.asarray(4.0, cfg.dtype))
    ln2 = jnp.log(jnp.asarray(2.0, cfg.dtype))

    cap_eff = cap * (K.ANCHOR_BANKS / jnp.maximum(banks, 1.0))
    cap_n = jnp.log(cap_eff / K.ANCHOR_L1_CAP)[:, None]  # [B, 1]

    b_e = (jnp.log(e2 / e1) - K.ASSOC_EXP * ln2) / ln4   # [B, NOPS]
    assoc_f = jnp.exp(
        K.ASSOC_EXP * jnp.log(jnp.maximum(assoc, 1.0) / K.ANCHOR_ASSOC)
    )[:, None]
    energy = e1 * jnp.exp(b_e * cap_n) * assoc_f

    b_l = jnp.log(l2 / l1) / ln4
    latency = l1 * jnp.exp(b_l * cap_n)

    return energy, latency


def profile_agg_ref(counters: jnp.ndarray, unit_energy: jnp.ndarray,
                    group: jnp.ndarray) -> jnp.ndarray:
    """McPAT-lite aggregation: component energy = (counters ⊙ unit) @ group.

    Args:
      counters:    f32[B, NC] performance-counter values.
      unit_energy: f32[B, NC] pJ per counter event.
      group:       f32[NC, NCOMP] one-hot counter→component matrix.

    Returns:
      f32[B, NCOMP] component energies (pJ).
    """
    return (counters * unit_energy) @ group
