"""Canonical constants shared by the L1 Pallas kernels, the L2 model graph,
the pure-jnp reference oracle, and (by mirrored definition) the Rust side
(`rust/src/energy/calib.rs`).

Everything here is a *schema* plus the published Table III / Fig 11 anchors of
the Eva-CiM paper.  Runtime calibration values (core event energies, DRAM
energies, leakage) are passed into the AOT graph as inputs by the Rust
coordinator, so nothing below needs to be retuned when calibrating Table VI.
"""

import numpy as np

# ---------------------------------------------------------------------------
# Operation axis (columns of the per-op energy/latency tables)
# ---------------------------------------------------------------------------
OP_READ = 0   # non-CiM read (regular cache access)
OP_WRITE = 1  # non-CiM write
OP_OR = 2     # CiM-OR
OP_AND = 3    # CiM-AND
OP_XOR = 4    # CiM-XOR
OP_ADD = 5    # CiM-ADDW32 (word add in the sense-amp adder)
NOPS = 6
OP_NAMES = ["read", "write", "cim_or", "cim_and", "cim_xor", "cim_add"]

# ---------------------------------------------------------------------------
# Design-point configuration row (one cache level)
# ---------------------------------------------------------------------------
CFG_CAPACITY = 0  # bytes
CFG_ASSOC = 1     # ways
CFG_LINE = 2      # bytes
CFG_BANKS = 3     # sub-banks (anchor configs use 4)
CFG_TECH = 4      # 0 = SRAM, 1 = FeFET
CFG_LEVEL = 5     # 1 = L1, 2 = L2 (metadata for grouping)
NCFG = 6

TECH_SRAM = 0
TECH_FEFET = 1
NTECH = 2
TECH_NAMES = ["sram", "fefet"]

# Anchor geometry of Table III: L1 = 64 kB / 4-way, L2 = 256 kB / 8-way.
ANCHOR_L1_CAP = 64 * 1024.0
ANCHOR_L2_CAP = 256 * 1024.0
ANCHOR_ASSOC = 4.0
ANCHOR_BANKS = 4.0
ASSOC_EXP = 0.15  # associativity factor exponent: (assoc/4)^0.15
# H-tree/bus transport multiplier for hierarchy accesses (CiM ops compute
# in-array and skip it) — mirrored by rust/src/energy/calib.rs XBUS_FACTOR.
XBUS_FACTOR = 4.0

# ---------------------------------------------------------------------------
# Technology parameter table: [NTECH, 4*NOPS] =
#   [ E_L1(6) | E_L2(6) | LAT_L1(6) | LAT_L2(6) ]
# Energies in pJ straight from Table III (write column interpolated — the
# paper's table omits writes; we use read*1.15 for SRAM and the FeFET write
# numbers consistent with [24]'s low-write-energy claim).
# Latencies in cycles at 1 GHz from Fig 11: SRAM logic ops ≈ read, CiM-ADD
# ≈ read + 4 cycles; FeFET ops are faster across the board.
# ---------------------------------------------------------------------------
TP_E_L1 = 0
TP_E_L2 = NOPS
TP_LAT_L1 = 2 * NOPS
TP_LAT_L2 = 3 * NOPS
NTECH_PARAMS = 4 * NOPS

DEFAULT_TECH_TABLE = np.array(
    [
        # SRAM:      read   write  or     and    xor    add
        [61.0, 70.0, 71.0, 72.0, 79.0, 79.0,          # E_L1 (pJ)
         314.0, 360.0, 341.0, 344.0, 365.0, 365.0,    # E_L2 (pJ)
         2.0, 2.0, 2.0, 2.0, 2.0, 6.0,                # LAT_L1 (cycles)
         8.0, 8.0, 8.0, 8.0, 8.0, 12.0],              # LAT_L2 (cycles)
        # FeFET
        [34.0, 44.0, 35.0, 88.0, 105.0, 105.0,
         70.0, 91.0, 72.0, 146.0, 205.0, 205.0,
         1.0, 1.0, 1.0, 1.0, 1.0, 4.0,
         5.0, 5.0, 5.0, 5.0, 5.0, 9.0],
    ],
    dtype=np.float32,
)

# ---------------------------------------------------------------------------
# Performance-counter axis (rows the McPAT-lite profiler consumes).
# Mirrored by rust/src/profiler/counters.rs — keep the order in sync.
# ---------------------------------------------------------------------------
COUNTER_NAMES = [
    # core events (unit energy = static per-event pJ, index 0..21)
    "fetch_insts", "decode_insts", "rename_ops",
    "iq_reads", "iq_writes", "rob_reads", "rob_writes",
    "int_rf_reads", "int_rf_writes", "fp_rf_reads", "fp_rf_writes",
    "int_alu_ops", "int_mul_ops", "int_div_ops",
    "fp_alu_ops", "fp_mul_ops", "fp_div_ops",
    "branch_ops", "bpred_lookups", "bpred_mispredicts",
    "lsq_reads", "lsq_writes",
    # cache events (unit energy from the array model, index 22..33)
    "l1i_hits", "l1i_misses",
    "l1d_read_hits", "l1d_read_misses",
    "l1d_write_hits", "l1d_write_misses",
    "l2_read_hits", "l2_read_misses",
    "l2_write_hits", "l2_write_misses",
    "dram_reads", "dram_writes",
    # CiM events (unit energy from the array model, index 34..41)
    "cim_l1_or", "cim_l1_and", "cim_l1_xor", "cim_l1_add",
    "cim_l2_or", "cim_l2_and", "cim_l2_xor", "cim_l2_add",
    # time (unit energy = leakage pJ/cycle, index 42)
    "cycles",
]
NC = len(COUNTER_NAMES)  # 43
C_CORE_BEGIN, C_CORE_END = 0, 22          # [0, 22)
C_CACHE_BEGIN, C_CACHE_END = 22, 34       # [22, 34)
C_CIM_BEGIN, C_CIM_END = 34, 42           # [34, 42)
C_CYCLES = 42

# ---------------------------------------------------------------------------
# Component axis (outputs of the aggregation kernel)
# ---------------------------------------------------------------------------
COMP_NAMES = ["core", "l1i", "l1d", "l2", "dram", "cim_l1", "cim_l2", "leak"]
NCOMP = len(COMP_NAMES)
COMP_CORE, COMP_L1I, COMP_L1D, COMP_L2, COMP_DRAM = 0, 1, 2, 3, 4
COMP_CIM_L1, COMP_CIM_L2, COMP_LEAK = 5, 6, 7

# counter index -> component index
_COUNTER_COMP = (
    [COMP_CORE] * 22
    + [COMP_L1I] * 2
    + [COMP_L1D] * 4
    + [COMP_L2] * 4
    + [COMP_DRAM] * 2
    + [COMP_CIM_L1] * 4
    + [COMP_CIM_L2] * 4
    + [COMP_LEAK]
)
assert len(_COUNTER_COMP) == NC

def group_matrix() -> np.ndarray:
    """Static [NC, NCOMP] one-hot grouping matrix for the aggregation matmul."""
    g = np.zeros((NC, NCOMP), dtype=np.float32)
    for i, c in enumerate(_COUNTER_COMP):
        g[i, c] = 1.0
    return g

# ---------------------------------------------------------------------------
# Perf vector (inputs to the constant-CPI speedup model, §V-C2)
# ---------------------------------------------------------------------------
PERF_CYCLES_BASE = 0      # baseline (non-CiM) cycle count
PERF_COMMITTED_BASE = 1   # baseline committed instruction count
PERF_REMOVED = 2          # instructions removed from the CPU stream by offloading
PERF_CIM_ADD_L1 = 3       # CiM-ADD ops executed in L1 (pay extra access cycles)
PERF_CIM_ADD_L2 = 4       # CiM-ADD ops executed in L2
PERF_CLOCK_GHZ = 5
NPERF = 6

# Default per-event core energies (pJ, 45 nm Cortex-A9 class) used by the
# python tests; the Rust coordinator passes its calibrated values at runtime.
DEFAULT_STATIC_UNIT = np.zeros(NC, dtype=np.float32)
DEFAULT_STATIC_UNIT[:22] = np.array(
    [50.0, 19.0, 25.0, 13.0, 15.0, 13.0, 15.0, 8.0, 10.0, 11.0, 14.0,
     63.0, 155.0, 375.0, 113.0, 188.0, 500.0, 25.0, 9.0, 125.0, 19.0,
     23.0],
    dtype=np.float32,
)
DEFAULT_STATIC_UNIT[32] = 6000.0  # dram_reads
DEFAULT_STATIC_UNIT[33] = 6500.0  # dram_writes
DEFAULT_STATIC_UNIT[C_CYCLES] = 25.0  # leakage pJ/cycle (core + caches)

# Batch size baked into the AOT artifacts; the Rust side pads partial batches.
AOT_BATCH = 256
