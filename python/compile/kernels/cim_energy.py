"""L1 Pallas kernel: batched DESTINY-lite per-op energy/latency model.

Grid: design-point batch tiled in blocks of ``BLOCK_B`` rows; each grid step
holds one ``[BLOCK_B, NCFG]`` config tile plus the full ``[NTECH, 4*NOPS]``
anchor table in VMEM and emits ``[BLOCK_B, NOPS]`` energy and latency tiles.

VMEM footprint per step (f32):
    cfg   128 × 6   = 3.0 kB
    tech    2 × 24  = 0.2 kB
    out   2 × 128×6 = 6.0 kB      → ≈ 9.2 kB  (target ≤ 16 kB, see DESIGN §8)

All math is element-wise VPU work except the one-hot tech gather, which is
expressed as a ``[BLOCK_B, NTECH] @ [NTECH, 4*NOPS]`` matmul so a real TPU
would issue it to the MXU.  ``interpret=True`` everywhere: the CPU PJRT
client cannot run Mosaic custom-calls (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import constants as K

BLOCK_B = 128


def _kernel(cfg_ref, tech_ref, energy_ref, lat_ref):
    cfg = cfg_ref[...]          # [BLOCK_B, NCFG]
    tech_table = tech_ref[...]  # [NTECH, 4*NOPS]
    dtype = cfg.dtype

    cap = cfg[:, K.CFG_CAPACITY]
    assoc = cfg[:, K.CFG_ASSOC]
    banks = cfg[:, K.CFG_BANKS]
    tech = cfg[:, K.CFG_TECH]

    # One-hot gather of per-tech anchors as a small matmul (MXU on real TPU).
    iota = jax.lax.broadcasted_iota(dtype, (1, K.NTECH), 1)
    onehot = (tech[:, None] == iota).astype(dtype)      # [B, NTECH]
    params = onehot @ tech_table                        # [B, 4*NOPS]

    e1 = params[:, K.TP_E_L1:K.TP_E_L1 + K.NOPS]
    e2 = params[:, K.TP_E_L2:K.TP_E_L2 + K.NOPS]
    l1 = params[:, K.TP_LAT_L1:K.TP_LAT_L1 + K.NOPS]
    l2 = params[:, K.TP_LAT_L2:K.TP_LAT_L2 + K.NOPS]

    ln4 = jnp.log(jnp.asarray(4.0, dtype))
    ln2 = jnp.log(jnp.asarray(2.0, dtype))

    cap_eff = cap * (K.ANCHOR_BANKS / jnp.maximum(banks, 1.0))
    cap_n = jnp.log(cap_eff / K.ANCHOR_L1_CAP)[:, None]

    b_e = (jnp.log(e2 / e1) - K.ASSOC_EXP * ln2) / ln4
    assoc_f = jnp.exp(
        K.ASSOC_EXP * jnp.log(jnp.maximum(assoc, 1.0) / K.ANCHOR_ASSOC)
    )[:, None]
    energy_ref[...] = e1 * jnp.exp(b_e * cap_n) * assoc_f

    b_l = jnp.log(l2 / l1) / ln4
    lat_ref[...] = l1 * jnp.exp(b_l * cap_n)


@functools.partial(jax.jit, static_argnames=("block_b",))
def energy_latency(cfg: jnp.ndarray, tech_table: jnp.ndarray,
                   block_b: int = BLOCK_B):
    """Pallas entry point matching :func:`ref.energy_latency_ref`.

    ``cfg.shape[0]`` must be a multiple of ``block_b`` (the Rust coordinator
    pads partial batches; tests use exact multiples or pad here).
    """
    b = cfg.shape[0]
    if b % block_b:
        pad = block_b - b % block_b
        # pad rows with a harmless anchor config so log() stays finite
        filler = jnp.broadcast_to(
            jnp.asarray(
                [K.ANCHOR_L1_CAP, K.ANCHOR_ASSOC, 64.0, K.ANCHOR_BANKS, 0.0, 1.0],
                cfg.dtype,
            ),
            (pad, K.NCFG),
        )
        cfg = jnp.concatenate([cfg, filler], axis=0)
    nb = cfg.shape[0] // block_b

    energy, lat = pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_b, K.NCFG), lambda i: (i, 0)),
            pl.BlockSpec((K.NTECH, K.NTECH_PARAMS), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, K.NOPS), lambda i: (i, 0)),
            pl.BlockSpec((block_b, K.NOPS), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((cfg.shape[0], K.NOPS), cfg.dtype),
            jax.ShapeDtypeStruct((cfg.shape[0], K.NOPS), cfg.dtype),
        ],
        interpret=True,
    )(cfg, tech_table)
    return energy[:b], lat[:b]
