# L1: Pallas kernels for Eva-CiM's compute hot-spots (design-space
# evaluation).  See constants.py for the shared schema and ref.py for the
# pure-jnp correctness oracles.
