"""AOT path: every entry point lowers to parseable HLO text, and the text
round-trips through the XLA client with numerics identical to jit execution.
This is exactly the contract the Rust runtime depends on."""

import numpy as np
import pytest
from numpy.testing import assert_allclose

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import aot
from compile.kernels import constants as K
from tests.test_model import make_inputs

BATCH = 8  # small batch keeps the test fast; artifacts use AOT_BATCH


@pytest.fixture(scope="module", params=["energy_model", "profiler",
                                        "sensitivity"])
def entry(request):
    for name, fn, specs in aot.entry_points(BATCH):
        if name == request.param:
            return name, fn, specs
    raise AssertionError(request.param)


def test_lowers_to_hlo_text(entry):
    name, fn, specs = entry
    text = aot.to_hlo_text(jax.jit(fn, keep_unused=True).lower(*specs))
    assert "HloModule" in text
    assert len(text) > 200


def _example_args(name):
    ins = make_inputs(b=BATCH, seed=11)
    if name == "energy_model":
        return (ins[0], ins[2])
    return ins


def _compile_hlo_text(backend, text):
    """Compile parsed HLO text on `backend`, across jaxlib API versions.

    Newer jaxlibs expose ``mlir.hlo_to_stablehlo`` + ``compile_and_load``;
    older ones (e.g. 0.4.x) go HloModuleProto → XlaComputation → MLIR →
    ``compile`` — which is also exactly the Rust runtime's path
    (``XlaComputation::from_proto`` + ``client.compile``).
    """
    module = xc._xla.hlo_module_from_text(text)
    proto = module.as_serialized_hlo_module_proto()
    if hasattr(xc._xla.mlir, "hlo_to_stablehlo"):
        mlir = xc._xla.mlir.hlo_to_stablehlo(proto)
        return backend.compile_and_load(mlir, backend.devices())
    comp = xc._xla.XlaComputation(proto)
    mlir = xc._xla.mlir.xla_computation_to_mlir_module(comp)
    return backend.compile(mlir)


def test_roundtrip_numerics(entry):
    """HLO text → HloModule → compile → execute == jit(fn).

    Mirrors what the Rust runtime does with HloModuleProto::from_text_file:
    the text parser reassigns instruction ids, then the module compiles and
    runs with identical numerics.
    """
    name, fn, specs = entry
    text = aot.to_hlo_text(jax.jit(fn, keep_unused=True).lower(*specs))

    backend = jax.devices()[0].client
    exe = _compile_hlo_text(backend, text)

    args = _example_args(name)
    want = jax.tree_util.tree_leaves(jax.jit(fn)(*args))
    bufs = [backend.buffer_from_pyval(np.asarray(a)) for a in args]
    got = [np.asarray(g) for g in exe.execute(bufs)]
    assert len(got) == len(want)
    for g, w in zip(got, want):
        assert_allclose(g, np.asarray(w), rtol=5e-5, atol=1e-6)
