"""L2 model graph: kernel-path vs ref-path equivalence + invariants."""

import numpy as np
import pytest
from numpy.testing import assert_allclose

import jax.numpy as jnp

from compile import model
from compile.kernels import constants as K


def make_inputs(b=16, seed=0, cim_fraction=0.3):
    """Synthetic but self-consistent profiler inputs.

    counters_cim mimics reshaping: fewer core events and memory accesses,
    some CiM ops added; perf vector consistent with the removal count.
    """
    rng = np.random.default_rng(seed)
    caps = 2.0 ** rng.integers(14, 18, size=b)
    cfg_l1 = np.stack([
        caps, np.full(b, 4.0), np.full(b, 64.0), np.full(b, 4.0),
        rng.integers(0, 2, size=b).astype(float), np.full(b, 1.0)
    ], axis=1).astype(np.float32)
    cfg_l2 = cfg_l1.copy()
    cfg_l2[:, K.CFG_CAPACITY] = caps * 8
    cfg_l2[:, K.CFG_ASSOC] = 8.0
    cfg_l2[:, K.CFG_LEVEL] = 2.0

    counters_base = rng.uniform(1e3, 1e6, size=(b, K.NC)).astype(np.float32)
    counters_base[:, K.C_CIM_BEGIN:K.C_CIM_END] = 0.0
    counters_cim = counters_base.copy()
    counters_cim[:, :K.C_CACHE_BEGIN] *= (1.0 - cim_fraction)
    counters_cim[:, K.C_CACHE_BEGIN:K.C_CIM_BEGIN] *= (1.0 - cim_fraction / 2)
    # each CiM op replaces ~3 offloaded instructions; spread over 8 op kinds
    committed = counters_base[:, 0]
    removed = committed * cim_fraction
    share = rng.dirichlet(np.ones(8), size=b).astype(np.float32)
    counters_cim[:, K.C_CIM_BEGIN:K.C_CIM_END] = (
        share * (removed / 3.0)[:, None])
    perf = np.stack([
        committed * 1.4,                       # cycles (CPI 1.4)
        committed,                             # committed
        removed,                               # removed
        counters_cim[:, 37], counters_cim[:, 41],  # cim add l1/l2
        np.full(b, 1.0),                       # GHz
    ], axis=1).astype(np.float32)

    return (jnp.asarray(cfg_l1), jnp.asarray(cfg_l2),
            jnp.asarray(K.DEFAULT_TECH_TABLE),
            jnp.asarray(K.DEFAULT_STATIC_UNIT),
            jnp.asarray(K.group_matrix()),
            jnp.asarray(counters_base), jnp.asarray(counters_cim),
            jnp.asarray(perf))


@pytest.fixture(scope="module")
def inputs():
    return make_inputs()


def test_kernel_path_matches_ref_path(inputs):
    out_k = model.evaluate_system(*inputs)
    out_r = model.evaluate_system_ref(*inputs)
    assert len(out_k) == 12
    for a, b in zip(out_k, out_r):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5)


def test_improvement_positive_and_sane(inputs):
    out = model.evaluate_system(*inputs)
    improvement, speedup = np.asarray(out[4]), np.asarray(out[5])
    assert (improvement > 0).all()
    assert (improvement > 1.0).all()   # counters_cim strictly cheaper here
    assert (speedup > 0.9).all()


def test_breakdown_ratios_sum_to_one(inputs):
    out = model.evaluate_system(*inputs)
    rp, rc = np.asarray(out[6]), np.asarray(out[7])
    assert_allclose(rp + rc, np.ones_like(rp), rtol=1e-4)


def test_components_nonnegative(inputs):
    out = model.evaluate_system(*inputs)
    assert (np.asarray(out[0]) >= 0).all()
    assert (np.asarray(out[1]) >= 0).all()


def test_total_is_component_sum_excluding_dram(inputs):
    out = model.evaluate_system(*inputs)
    comps = np.asarray(out[0])
    want = comps.sum(axis=1) - comps[:, K.COMP_DRAM]
    assert_allclose(want, np.asarray(out[2]), rtol=1e-5)


def test_identical_counters_give_unity(inputs):
    cfg_l1, cfg_l2, tech, unit, group, cb, _, perf = inputs
    perf0 = np.asarray(perf).copy()
    perf0[:, K.PERF_REMOVED] = 0.0
    perf0[:, K.PERF_CIM_ADD_L1] = 0.0
    perf0[:, K.PERF_CIM_ADD_L2] = 0.0
    out = model.evaluate_system(cfg_l1, cfg_l2, tech, unit, group, cb, cb,
                                jnp.asarray(perf0))
    assert_allclose(np.asarray(out[4]), 1.0, rtol=1e-5)   # improvement
    assert_allclose(np.asarray(out[5]), 1.0, rtol=1e-5)   # speedup


def test_sensitivity_finite_and_capacity_positive(inputs):
    g1, g2 = model.sensitivity(*inputs)
    g1, g2 = np.asarray(g1), np.asarray(g2)
    assert np.isfinite(g1).all() and np.isfinite(g2).all()
    # bigger caches -> more energy per op -> positive capacity gradient
    assert (g1[:, K.CFG_CAPACITY] > 0).all()
    assert (g2[:, K.CFG_CAPACITY] > 0).all()


def test_cim_add_latency_hurts_speedup(inputs):
    cfg_l1, cfg_l2, tech, unit, group, cb, cc, perf = inputs
    hi = np.asarray(perf).copy()
    hi[:, K.PERF_CIM_ADD_L1] *= 100.0
    out_lo = model.evaluate_system(cfg_l1, cfg_l2, tech, unit, group, cb, cc,
                                   perf)
    out_hi = model.evaluate_system(cfg_l1, cfg_l2, tech, unit, group, cb, cc,
                                   jnp.asarray(hi))
    assert (np.asarray(out_hi[5]) <= np.asarray(out_lo[5]) + 1e-6).all()
