"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal.

hypothesis sweeps batch sizes (including non-multiples of the 128-row block),
geometries and dtypes; assert_allclose against ref.py.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis unavailable in the offline image"
)
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

import jax.numpy as jnp

from compile.kernels import constants as K
from compile.kernels import ref
from compile.kernels.cim_energy import energy_latency
from compile.kernels.profile_agg import profile_agg


def make_cfg(rng: np.random.Generator, b: int) -> np.ndarray:
    cap = 2.0 ** rng.integers(12, 22, size=b)          # 4 kB .. 4 MB
    assoc = 2.0 ** rng.integers(0, 5, size=b)          # 1 .. 16 way
    line = np.full(b, 64.0)
    banks = 2.0 ** rng.integers(0, 4, size=b)          # 1 .. 8
    tech = rng.integers(0, K.NTECH, size=b).astype(np.float64)
    level = rng.integers(1, 3, size=b).astype(np.float64)
    return np.stack([cap, assoc, line, banks, tech, level], axis=1).astype(
        np.float32)


@pytest.fixture(scope="module")
def tech_table():
    return jnp.asarray(K.DEFAULT_TECH_TABLE)


class TestEnergyKernel:
    def test_matches_ref_exact_block(self, tech_table):
        rng = np.random.default_rng(0)
        cfg = jnp.asarray(make_cfg(rng, 256))
        e_k, l_k = energy_latency(cfg, tech_table)
        e_r, l_r = ref.energy_latency_ref(cfg, tech_table)
        assert_allclose(np.asarray(e_k), np.asarray(e_r), rtol=1e-5)
        assert_allclose(np.asarray(l_k), np.asarray(l_r), rtol=1e-5)

    @settings(max_examples=25, deadline=None)
    @given(b=st.integers(1, 300), seed=st.integers(0, 2**31 - 1))
    def test_matches_ref_any_batch(self, tech_table, b, seed):
        rng = np.random.default_rng(seed)
        cfg = jnp.asarray(make_cfg(rng, b))
        e_k, l_k = energy_latency(cfg, tech_table)
        e_r, l_r = ref.energy_latency_ref(cfg, tech_table)
        assert e_k.shape == (b, K.NOPS) and l_k.shape == (b, K.NOPS)
        assert_allclose(np.asarray(e_k), np.asarray(e_r), rtol=1e-5)
        assert_allclose(np.asarray(l_k), np.asarray(l_r), rtol=1e-5)

    def test_reproduces_table3_anchors(self, tech_table):
        """At the published geometries the model must return Table III."""
        cfg = jnp.asarray(np.array([
            # cap,            assoc, line, banks, tech, level
            [64 * 1024.0, 4.0, 64.0, 4.0, K.TECH_SRAM, 1.0],
            [256 * 1024.0, 8.0, 64.0, 4.0, K.TECH_SRAM, 2.0],
            [64 * 1024.0, 4.0, 64.0, 4.0, K.TECH_FEFET, 1.0],
            [256 * 1024.0, 8.0, 64.0, 4.0, K.TECH_FEFET, 2.0],
        ], dtype=np.float32))
        e, lat = energy_latency(cfg, tech_table)
        e, lat = np.asarray(e), np.asarray(lat)
        table = np.asarray(K.DEFAULT_TECH_TABLE)
        for i, (t, row) in enumerate([(0, 0), (0, 1), (1, 0), (1, 1)]):
            want_e = table[t, row * K.NOPS:(row + 1) * K.NOPS]
            want_l = table[t, (2 + row) * K.NOPS:(3 + row) * K.NOPS]
            assert_allclose(e[i], want_e, rtol=1e-4)
            assert_allclose(lat[i], want_l, rtol=1e-4)

    def test_energy_monotone_in_capacity(self, tech_table):
        """Bigger arrays must cost more per op (paper finding iii)."""
        caps = [16 * 1024.0, 64 * 1024.0, 256 * 1024.0, 2 * 1024 * 1024.0]
        cfg = jnp.asarray(np.array(
            [[c, 4.0, 64.0, 4.0, K.TECH_SRAM, 1.0] for c in caps],
            dtype=np.float32))
        e, _ = energy_latency(cfg, tech_table)
        e = np.asarray(e)
        assert (np.diff(e, axis=0) > 0).all()

    def test_outputs_finite_and_positive(self, tech_table):
        rng = np.random.default_rng(7)
        cfg = jnp.asarray(make_cfg(rng, 128))
        e, lat = energy_latency(cfg, tech_table)
        assert np.isfinite(np.asarray(e)).all() and (np.asarray(e) > 0).all()
        assert np.isfinite(np.asarray(lat)).all() and (np.asarray(lat) > 0).all()


class TestProfileAggKernel:
    @settings(max_examples=25, deadline=None)
    @given(b=st.integers(1, 300), seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, b, seed):
        rng = np.random.default_rng(seed)
        counters = jnp.asarray(
            rng.uniform(0, 1e6, size=(b, K.NC)).astype(np.float32))
        unit = jnp.asarray(
            rng.uniform(0.1, 500.0, size=(b, K.NC)).astype(np.float32))
        group = jnp.asarray(K.group_matrix())
        out_k = profile_agg(counters, unit, group)
        out_r = ref.profile_agg_ref(counters, unit, group)
        assert out_k.shape == (b, K.NCOMP)
        assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=1e-5)

    def test_group_matrix_partitions_counters(self):
        g = K.group_matrix()
        assert g.shape == (K.NC, K.NCOMP)
        # every counter belongs to exactly one component
        assert_allclose(g.sum(axis=1), np.ones(K.NC))

    def test_total_energy_is_weighted_sum(self):
        rng = np.random.default_rng(3)
        counters = rng.uniform(0, 1e5, size=(8, K.NC)).astype(np.float32)
        unit = rng.uniform(0.1, 100.0, size=(8, K.NC)).astype(np.float32)
        out = np.asarray(profile_agg(
            jnp.asarray(counters), jnp.asarray(unit),
            jnp.asarray(K.group_matrix())))
        assert_allclose(out.sum(axis=1), (counters * unit).sum(axis=1),
                        rtol=1e-4)
