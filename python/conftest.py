"""Pytest bootstrap: make the ``compile`` package and the ``tests``
namespace importable when pytest is invoked from the repository root
(``python -m pytest python/tests``) or from ``python/`` itself."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
