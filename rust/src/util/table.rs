//! Plain-text and CSV table rendering for reports and benches.

/// Column alignment.
#[derive(Clone, Copy, PartialEq, Eq)]
pub enum Align {
    /// Pad on the right (names, labels).
    Left,
    /// Pad on the left (numbers).
    Right,
}

/// A simple text table: headers + rows of strings.
pub struct TextTable {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    align: Vec<Align>,
}

impl TextTable {
    /// An empty table: first column left-aligned, the rest right-aligned.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            align: std::iter::once(Align::Left)
                .chain(std::iter::repeat(Align::Right))
                .take(headers.len())
                .collect(),
        }
    }

    /// Override the per-column alignment (must match the header count).
    pub fn align(mut self, align: &[Align]) -> Self {
        assert_eq!(align.len(), self.headers.len());
        self.align = align.to_vec();
        self
    }

    /// Append one row (must match the header count).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    /// [`TextTable::row`] from string slices.
    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        self.row(cells.iter().map(|s| s.to_string()).collect())
    }

    /// Number of data rows appended so far.
    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render as an aligned monospace table.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            let mut out = String::new();
            for i in 0..ncol {
                let cell = &cells[i];
                let pad = widths[i] - cell.chars().count();
                out.push(' ');
                match self.align[i] {
                    Align::Left => {
                        out.push_str(cell);
                        out.push_str(&" ".repeat(pad));
                    }
                    Align::Right => {
                        out.push_str(&" ".repeat(pad));
                        out.push_str(cell);
                    }
                }
                out.push(' ');
                if i + 1 < ncol {
                    out.push('|');
                }
            }
            out
        };
        let mut s = String::new();
        if !self.title.is_empty() {
            s.push_str(&self.title);
            s.push('\n');
        }
        s.push_str(&fmt_row(&self.headers));
        s.push('\n');
        s.push_str(&sep);
        s.push('\n');
        for row in &self.rows {
            s.push_str(&fmt_row(row));
            s.push('\n');
        }
        s
    }

    /// Render as CSV (RFC-4180-ish; quotes cells containing commas).
    pub fn to_csv(&self) -> String {
        let esc = |c: &str| -> String {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut s = self
            .headers
            .iter()
            .map(|h| esc(h))
            .collect::<Vec<_>>()
            .join(",");
        s.push('\n');
        for row in &self.rows {
            s.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            s.push('\n');
        }
        s
    }
}

/// Format a float with `d` decimals.
pub fn f(x: f64, d: usize) -> String {
    format!("{x:.d$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_and_csv() {
        let mut t = TextTable::new("T", &["name", "val"]);
        t.row_strs(&["a", "1.5"]);
        t.row_strs(&["bb", "22"]);
        let r = t.render();
        assert!(r.contains("name"));
        assert!(r.lines().count() == 5); // title, header, sep, 2 rows
        let c = t.to_csv();
        assert_eq!(c, "name,val\na,1.5\nbb,22\n");
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = TextTable::new("", &["x"]);
        t.row_strs(&["a,b"]);
        assert_eq!(t.to_csv(), "x\n\"a,b\"\n");
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = TextTable::new("", &["a", "b"]);
        t.row_strs(&["only-one"]);
    }
}
