//! Tiny property-based testing harness (proptest is unavailable offline).
//!
//! `check` runs a property over `cases` random inputs produced by `gen`
//! from a seeded [`Rng`]; on failure it retries with progressively simpler
//! sizes (a poor-man's shrink via the `size` hint handed to the generator)
//! and panics with the failing seed so the case can be replayed.

use super::rng::Rng;

/// Run `prop` over `cases` random inputs. `gen(rng, size)` should scale its
/// output with `size` (0..=100) so failures can be re-sought at small sizes.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: u32,
    mut gen: impl FnMut(&mut Rng, u32) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    // deterministic per-property seed so failures are reproducible
    let base_seed = name
        .bytes()
        .fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
            (h ^ b as u64).wrapping_mul(0x1000_0000_01b3)
        });
    let mut failure: Option<(u64, u32, String)> = None;
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let size = 1 + (case * 100 / cases.max(1)).min(99);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng, size);
        if let Err(msg) = prop(&input) {
            failure = Some((seed, size, format!("{msg}; input: {input:?}")));
            break;
        }
    }
    if let Some((seed, size, msg)) = failure {
        // try to find a smaller counterexample before reporting
        for small in 1..=10u32 {
            let mut rng = Rng::new(seed ^ 0xdead_beef ^ small as u64);
            let input = gen(&mut rng, small);
            if let Err(small_msg) = prop(&input) {
                panic!(
                    "property '{name}' failed (shrunk, size={small}): \
                     {small_msg}; input: {input:?}"
                );
            }
        }
        panic!("property '{name}' failed (seed={seed}, size={size}): {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            "sum-commutes",
            100,
            |rng, size| {
                let a = rng.gen_range(size as u64 * 10 + 1) as i64;
                let b = rng.gen_range(size as u64 * 10 + 1) as i64;
                (a, b)
            },
            |(a, b)| {
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("addition not commutative".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_name() {
        check(
            "always-fails",
            10,
            |rng, _| rng.gen_range(100),
            |_| Err("nope".into()),
        );
    }
}
