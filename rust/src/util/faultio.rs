//! Deterministic, injectable I/O fault layer for the on-disk stores.
//!
//! Every fallible filesystem operation the sweep caches perform —
//! open/read/write/fsync/rename — goes through the [`StoreIo`] trait
//! instead of calling `std::fs` directly.  In production the trait is a
//! zero-cost pass-through; under test a process-global injector
//! ([`inject`]) makes the *same* code paths fail on a deterministic
//! schedule (fail the Nth matching operation, short-write, return
//! `EINTR`/`EAGAIN`/`ENOSPC`), so every recovery path is exercised
//! repeatably — the same oracle idea as `replay_reference` /
//! `simulate_reference`, applied to the fault domain.
//!
//! The module also owns the two store-agnostic recovery primitives:
//!
//! - [`with_retries`]: capped exponential backoff with deterministic
//!   jitter for *transient* errors (`EINTR`, `EAGAIN`); every retry is
//!   counted into the process-wide telemetry ([`counters`]) which the
//!   sweep ledger snapshots as `io_retries`.
//! - [`quarantine_bytes`] / [`quarantine_move`]: a store entry that
//!   fails decode is preserved under `<cache-dir>/quarantine/` next to a
//!   `.reason` file instead of being silently skipped, and counted as
//!   `entries_quarantined`.  Quarantine writes use raw `std::fs` (never
//!   injected, never retried): recording a fault must not itself fault
//!   recursively, and a quarantine that cannot be written degrades to
//!   the old skip-with-warning behavior.

use std::collections::hash_map::DefaultHasher;
use std::fs::{File, OpenOptions};
use std::hash::{Hash as _, Hasher as _};
use std::io::{self, Read as _, Write as _};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::lock_unpoisoned;

/// The operation classes the injector can match on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IoOp {
    /// opening a file (append-mode writer, read handle, or create)
    Open,
    /// reading file contents
    Read,
    /// writing bytes (appends, spill chunks, whole-file writes)
    Write,
    /// flushing file contents to stable storage
    Fsync,
    /// atomically publishing a temp file over its final name
    Rename,
    /// creating a store directory
    CreateDir,
    /// removing a file (temp-spill cleanup)
    Remove,
}

/// What an injected fault does to the matched operation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// transient `EINTR`: [`with_retries`] recovers from a burst of these
    Eintr,
    /// transient `EAGAIN`/`EWOULDBLOCK`: also retried
    Eagain,
    /// hard `ENOSPC` (disk full): not transient, surfaces to the caller
    Enospc,
    /// hard `EACCES` (permission denied): the degraded-mode trigger
    Eacces,
    /// write half the buffer for real, then fail — a torn append/spill
    ShortWrite,
}

impl FaultKind {
    fn to_error(self) -> io::Error {
        match self {
            FaultKind::Eintr => {
                io::Error::new(io::ErrorKind::Interrupted, "injected EINTR")
            }
            FaultKind::Eagain => {
                io::Error::new(io::ErrorKind::WouldBlock, "injected EAGAIN")
            }
            FaultKind::Enospc => io::Error::other("injected ENOSPC (disk full)"),
            FaultKind::Eacces => io::Error::new(
                io::ErrorKind::PermissionDenied,
                "injected EACCES",
            ),
            FaultKind::ShortWrite => io::Error::other("injected short write"),
        }
    }
}

/// One injection rule: which operations it matches and what it does.
#[derive(Clone, Debug)]
pub struct FaultSpec {
    /// match only this operation class (`None` = any)
    pub op: Option<IoOp>,
    /// match only paths whose display form contains this substring
    /// (`None` = any path) — confines a test's faults to its own dirs
    pub path_contains: Option<String>,
    /// 1-based index among *matching* operations to fail (`0` = every
    /// matching operation)
    pub nth: u64,
    /// the failure to inject
    pub kind: FaultKind,
}

impl FaultSpec {
    /// A spec that fails every matching operation.
    pub fn every(op: Option<IoOp>, path_contains: &str, kind: FaultKind) -> Self {
        Self {
            op,
            path_contains: Some(path_contains.to_string()),
            nth: 0,
            kind,
        }
    }

    /// A spec that fails only the `nth` matching operation (1-based).
    pub fn nth(op: Option<IoOp>, path_contains: &str, nth: u64, kind: FaultKind) -> Self {
        Self {
            op,
            path_contains: Some(path_contains.to_string()),
            nth,
            kind,
        }
    }
}

/// A deterministic fault schedule: explicit rules plus an optional
/// seeded `EINTR` storm (every operation whose sequence number hashes to
/// `0 mod period` under `seed` fails transiently — same seed, same ops,
/// same faults).
#[derive(Debug, Default)]
pub struct FaultPlan {
    specs: Vec<(FaultSpec, u64)>, // (rule, matched-so-far)
    storm: Option<(u64, u64, u64)>, // (seed, period, ops-seen)
    storm_path: Option<String>,
}

impl FaultPlan {
    /// An empty plan (no faults until rules are added).
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one injection rule.
    pub fn with(mut self, spec: FaultSpec) -> Self {
        self.specs.push((spec, 0));
        self
    }

    /// Add a seeded transient-fault storm: roughly one in `period`
    /// matching operations fails with `EINTR`, chosen by hashing the
    /// operation sequence number with `seed`.
    pub fn with_eintr_storm(mut self, seed: u64, period: u64, path_contains: &str) -> Self {
        self.storm = Some((seed, period.max(1), 0));
        self.storm_path = Some(path_contains.to_string());
        self
    }

    fn decide(&mut self, op: IoOp, path: &Path) -> Option<FaultKind> {
        let shown = path.display().to_string();
        for (spec, matched) in &mut self.specs {
            if let Some(want) = spec.op {
                if want != op {
                    continue;
                }
            }
            if let Some(sub) = &spec.path_contains {
                if !shown.contains(sub.as_str()) {
                    continue;
                }
            }
            *matched += 1;
            if spec.nth == 0 || *matched == spec.nth {
                return Some(spec.kind);
            }
        }
        if let Some((seed, period, seen)) = &mut self.storm {
            let in_scope = self
                .storm_path
                .as_ref()
                .is_none_or(|sub| shown.contains(sub.as_str()));
            if in_scope {
                *seen += 1;
                if mix(*seed, *seen) % *period == 0 {
                    return Some(FaultKind::Eintr);
                }
            }
        }
        None
    }
}

/// Stable 64-bit mix (FNV-1a over the two words) — the storm schedule
/// must be identical across runs and platforms.
fn mix(seed: u64, n: u64) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in seed.to_le_bytes().into_iter().chain(n.to_le_bytes()) {
        h ^= b as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h
}

/// 16-hex content tag for quarantine file names (FNV-1a 64, same family
/// as the store keys so quarantined entries are content-addressed too).
pub fn content_tag(bytes: &[u8]) -> String {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    format!("{h:016x}")
}

static ARMED: AtomicBool = AtomicBool::new(false);
static INJECTOR: Mutex<Option<FaultPlan>> = Mutex::new(None);

/// Arm the process-global fault injector with a schedule.  Test-only by
/// convention: production code never calls this, and the fast path costs
/// one relaxed atomic load while disarmed.
pub fn inject(plan: FaultPlan) {
    *lock_unpoisoned(&INJECTOR) = Some(plan);
    ARMED.store(true, Ordering::SeqCst);
}

/// Disarm the injector (idempotent).  Tests pair every [`inject`] with a
/// `clear`, typically via a drop guard.
pub fn clear() {
    ARMED.store(false, Ordering::SeqCst);
    *lock_unpoisoned(&INJECTOR) = None;
}

fn fault_for(op: IoOp, path: &Path) -> Option<FaultKind> {
    if !ARMED.load(Ordering::Relaxed) {
        return None;
    }
    lock_unpoisoned(&INJECTOR).as_mut().and_then(|p| p.decide(op, path))
}

fn gate(op: IoOp, path: &Path) -> io::Result<()> {
    match fault_for(op, path) {
        Some(k) => Err(k.to_error()),
        None => Ok(()),
    }
}

// ---------------------------------------------------------------------
// process-wide fault telemetry, snapshotted into the sweep ledger
// ---------------------------------------------------------------------

static IO_RETRIES: AtomicU64 = AtomicU64::new(0);
static QUARANTINED: AtomicU64 = AtomicU64::new(0);

/// A snapshot of the process-wide fault telemetry.  Sweeps take a
/// snapshot at entry and report the delta as `io_retries` /
/// `entries_quarantined` in their ledger.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct IoCounters {
    /// transient I/O operations retried (and eventually resolved)
    pub retries: u64,
    /// store entries moved/copied into `<cache-dir>/quarantine/`
    pub quarantined: u64,
}

impl IoCounters {
    /// Counter-wise difference since an earlier snapshot (saturating:
    /// concurrent sweeps in one process share the counters).
    pub fn since(&self, earlier: &IoCounters) -> IoCounters {
        IoCounters {
            retries: self.retries.saturating_sub(earlier.retries),
            quarantined: self.quarantined.saturating_sub(earlier.quarantined),
        }
    }
}

/// Current process-wide fault telemetry.
pub fn counters() -> IoCounters {
    IoCounters {
        retries: IO_RETRIES.load(Ordering::Relaxed),
        quarantined: QUARANTINED.load(Ordering::Relaxed),
    }
}

// ---------------------------------------------------------------------
// retry with capped exponential backoff + deterministic jitter
// ---------------------------------------------------------------------

/// True for errors worth retrying: interrupted syscalls and
/// would-block/lock-contention conditions.  Hard faults (`ENOSPC`,
/// `EACCES`, corruption) are *not* transient and surface immediately.
pub fn is_transient(e: &io::Error) -> bool {
    matches!(
        e.kind(),
        io::ErrorKind::Interrupted | io::ErrorKind::WouldBlock
    )
}

/// Run `f`, retrying transient failures with capped exponential backoff
/// plus deterministic jitter (hashed from `what` and the attempt number,
/// so two contending writers don't thundering-herd in lockstep).  At most
/// 5 attempts; every retry bumps the `io_retries` telemetry.
pub fn with_retries<T>(
    what: &str,
    mut f: impl FnMut() -> io::Result<T>,
) -> io::Result<T> {
    const MAX_ATTEMPTS: u32 = 5;
    let mut attempt = 0u32;
    loop {
        match f() {
            Err(e) if attempt + 1 < MAX_ATTEMPTS && is_transient(&e) => {
                attempt += 1;
                IO_RETRIES.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(backoff(what, attempt));
            }
            other => return other,
        }
    }
}

fn backoff(what: &str, attempt: u32) -> Duration {
    // 1, 2, 4, 8 ms base, capped — transient faults clear in microseconds,
    // this only has to break lockstep, not pace a congestion controller
    let base_ms = 1u64 << (attempt - 1).min(3);
    let mut h = DefaultHasher::new();
    what.hash(&mut h);
    attempt.hash(&mut h);
    let jitter_ms = h.finish() % (base_ms + 1);
    Duration::from_millis(base_ms + jitter_ms)
}

// ---------------------------------------------------------------------
// the StoreIo trait: every store filesystem call goes through here
// ---------------------------------------------------------------------

/// Thin trait over the filesystem operations the stores perform.  The
/// production implementation ([`fs`]) consults the fault injector first,
/// then delegates to `std::fs` — so injected schedules exercise exactly
/// the code paths real faults would take.
pub trait StoreIo: Sync {
    /// Check the injector without performing any I/O — for call sites
    /// that buffer writes internally (the spill writer's chunk path).
    fn probe(&self, op: IoOp, path: &Path) -> io::Result<()>;
    /// `std::fs::create_dir_all`.
    fn create_dir_all(&self, dir: &Path) -> io::Result<()>;
    /// Open `path` append-mode, creating it if missing.
    fn open_append(&self, path: &Path) -> io::Result<File>;
    /// Create/truncate `path` for writing.
    fn create(&self, path: &Path) -> io::Result<File>;
    /// Open `path` read-only.
    fn open_read(&self, path: &Path) -> io::Result<File>;
    /// Read `path` to a string.
    fn read_to_string(&self, path: &Path) -> io::Result<String>;
    /// Write a whole file (`std::fs::write`).
    fn write(&self, path: &Path, contents: &[u8]) -> io::Result<()>;
    /// Write `buf` to an already-open `file` (`path` is for fault
    /// matching and error context only).
    fn write_all(&self, path: &Path, file: &mut File, buf: &[u8]) -> io::Result<()>;
    /// Flush `file` to stable storage (`File::sync_data`).
    fn fsync(&self, path: &Path, file: &File) -> io::Result<()>;
    /// `std::fs::rename`.
    fn rename(&self, from: &Path, to: &Path) -> io::Result<()>;
    /// `std::fs::remove_file`.
    fn remove_file(&self, path: &Path) -> io::Result<()>;
}

struct InjectedIo;

impl StoreIo for InjectedIo {
    fn probe(&self, op: IoOp, path: &Path) -> io::Result<()> {
        gate(op, path)
    }

    fn create_dir_all(&self, dir: &Path) -> io::Result<()> {
        gate(IoOp::CreateDir, dir)?;
        std::fs::create_dir_all(dir)
    }

    fn open_append(&self, path: &Path) -> io::Result<File> {
        gate(IoOp::Open, path)?;
        OpenOptions::new().create(true).append(true).open(path)
    }

    fn create(&self, path: &Path) -> io::Result<File> {
        gate(IoOp::Open, path)?;
        File::create(path)
    }

    fn open_read(&self, path: &Path) -> io::Result<File> {
        gate(IoOp::Open, path)?;
        File::open(path)
    }

    fn read_to_string(&self, path: &Path) -> io::Result<String> {
        gate(IoOp::Read, path)?;
        let mut f = File::open(path)?;
        let mut s = String::new();
        f.read_to_string(&mut s)?;
        Ok(s)
    }

    fn write(&self, path: &Path, contents: &[u8]) -> io::Result<()> {
        match fault_for(IoOp::Write, path) {
            Some(FaultKind::ShortWrite) => {
                // a torn whole-file write: half the bytes land, then fail
                let mut f = File::create(path)?;
                f.write_all(&contents[..contents.len() / 2])?;
                Err(FaultKind::ShortWrite.to_error())
            }
            Some(k) => Err(k.to_error()),
            None => std::fs::write(path, contents),
        }
    }

    fn write_all(&self, path: &Path, file: &mut File, buf: &[u8]) -> io::Result<()> {
        match fault_for(IoOp::Write, path) {
            Some(FaultKind::ShortWrite) => {
                file.write_all(&buf[..buf.len() / 2])?;
                Err(FaultKind::ShortWrite.to_error())
            }
            Some(k) => Err(k.to_error()),
            None => file.write_all(buf),
        }
    }

    fn fsync(&self, path: &Path, file: &File) -> io::Result<()> {
        gate(IoOp::Fsync, path)?;
        file.sync_data()
    }

    fn rename(&self, from: &Path, to: &Path) -> io::Result<()> {
        gate(IoOp::Rename, from)?;
        std::fs::rename(from, to)
    }

    fn remove_file(&self, path: &Path) -> io::Result<()> {
        gate(IoOp::Remove, path)?;
        std::fs::remove_file(path)
    }
}

static FS: InjectedIo = InjectedIo;

/// The process-wide [`StoreIo`] the stores use.  Disarmed, it is a
/// pass-through to `std::fs` behind one relaxed atomic load.
pub fn fs() -> &'static dyn StoreIo {
    &FS
}

// ---------------------------------------------------------------------
// quarantine: preserve entries that fail decode instead of hiding them
// ---------------------------------------------------------------------

/// Preserve a store entry (one JSONL line, typically) that failed decode:
/// write the payload to `<qdir>/<name>` and the human-readable cause to
/// `<qdir>/<name>.reason`, then count it.  Content-addressed names make
/// this idempotent — an already-quarantined entry is **not** re-counted
/// on the next load, so a bad line warns once, not once per sweep.
/// Returns `true` when the entry was newly quarantined.  Best-effort by
/// design: if the quarantine dir itself is unwritable this degrades to
/// the old skip-with-warning behavior and returns `false`.
pub fn quarantine_bytes(qdir: &Path, name: &str, payload: &[u8], reason: &str) -> bool {
    if std::fs::create_dir_all(qdir).is_err() {
        return false;
    }
    let path = qdir.join(name);
    // create_new atomically claims the name: concurrent loaders (and
    // later re-loads) of the same bad entry collapse to one record
    let mut f = match OpenOptions::new().write(true).create_new(true).open(&path) {
        Ok(f) => f,
        Err(_) => return false,
    };
    let _ = f.write_all(payload);
    let _ = std::fs::write(qdir.join(format!("{name}.reason")), reason.as_bytes());
    QUARANTINED.fetch_add(1, Ordering::Relaxed);
    eprintln!("warning: quarantined store entry to {path:?} ({reason})");
    true
}

/// Move a whole corrupt store file (a trace spill, typically) into the
/// quarantine dir with a `.reason` file.  The move is a rename, so the
/// corrupt file stops satisfying existence probes immediately — a
/// quarantined entry can never re-poison a warm resume.  Best-effort:
/// on failure the file is left in place (callers already treat it as a
/// miss) and `false` is returned.
pub fn quarantine_move(qdir: &Path, src: &Path, reason: &str) -> Option<PathBuf> {
    let name = src.file_name()?.to_string_lossy().into_owned();
    if std::fs::create_dir_all(qdir).is_err() {
        return None;
    }
    let dst = qdir.join(&name);
    if std::fs::rename(src, &dst).is_err() {
        return None;
    }
    let _ = std::fs::write(qdir.join(format!("{name}.reason")), reason.as_bytes());
    QUARANTINED.fetch_add(1, Ordering::Relaxed);
    eprintln!("warning: quarantined corrupt store file to {dst:?} ({reason})");
    Some(dst)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The injector is process-global; unit tests here and the chaos
    /// suite each serialize around their own lock, and every test clears
    /// on exit.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    struct Armed;
    impl Drop for Armed {
        fn drop(&mut self) {
            clear();
        }
    }

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("eva-cim-faultio-{tag}-{}", std::process::id()))
    }

    #[test]
    fn disarmed_io_is_a_passthrough() {
        let _g = lock_unpoisoned(&TEST_LOCK);
        let dir = tmp("pass");
        std::fs::remove_dir_all(&dir).ok();
        fs().create_dir_all(&dir).unwrap();
        let p = dir.join("x.txt");
        fs().write(&p, b"hello").unwrap();
        assert_eq!(fs().read_to_string(&p).unwrap(), "hello");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn nth_spec_fails_exactly_the_nth_matching_op() {
        let _g = lock_unpoisoned(&TEST_LOCK);
        let dir = tmp("nth");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let guard = Armed;
        inject(FaultPlan::new().with(FaultSpec::nth(
            Some(IoOp::Write),
            "eva-cim-faultio-nth",
            2,
            FaultKind::Enospc,
        )));
        let p = dir.join("x.txt");
        assert!(fs().write(&p, b"one").is_ok());
        let err = fs().write(&p, b"two").unwrap_err();
        assert!(err.to_string().contains("ENOSPC"));
        assert!(fs().write(&p, b"three").is_ok());
        drop(guard);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn short_write_tears_the_payload() {
        let _g = lock_unpoisoned(&TEST_LOCK);
        let dir = tmp("short");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let guard = Armed;
        inject(FaultPlan::new().with(FaultSpec::nth(
            Some(IoOp::Write),
            "eva-cim-faultio-short",
            1,
            FaultKind::ShortWrite,
        )));
        let p = dir.join("x.txt");
        assert!(fs().write(&p, b"0123456789").is_err());
        drop(guard);
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "01234");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn retries_recover_transient_faults_and_count_them() {
        let _g = lock_unpoisoned(&TEST_LOCK);
        let dir = tmp("retry");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let guard = Armed;
        inject(
            FaultPlan::new()
                .with(FaultSpec::nth(
                    Some(IoOp::Write),
                    "eva-cim-faultio-retry",
                    1,
                    FaultKind::Eintr,
                ))
                .with(FaultSpec::nth(
                    Some(IoOp::Write),
                    "eva-cim-faultio-retry",
                    2,
                    FaultKind::Eagain,
                )),
        );
        let before = counters();
        let p = dir.join("x.txt");
        with_retries("test write", || fs().write(&p, b"ok")).unwrap();
        let delta = counters().since(&before);
        assert_eq!(delta.retries, 2, "both transient faults were retried");
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "ok");
        drop(guard);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn hard_faults_are_not_retried() {
        let _g = lock_unpoisoned(&TEST_LOCK);
        let dir = tmp("hard");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let guard = Armed;
        inject(FaultPlan::new().with(FaultSpec::every(
            Some(IoOp::Write),
            "eva-cim-faultio-hard",
            FaultKind::Enospc,
        )));
        let before = counters();
        let p = dir.join("x.txt");
        assert!(with_retries("test write", || fs().write(&p, b"x")).is_err());
        assert_eq!(counters().since(&before).retries, 0);
        drop(guard);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn eintr_storm_is_deterministic_per_seed() {
        let _g = lock_unpoisoned(&TEST_LOCK);
        let schedule = |seed: u64| -> Vec<bool> {
            let mut plan = FaultPlan::new().with_eintr_storm(seed, 3, "storm");
            (0..32)
                .map(|_| plan.decide(IoOp::Write, Path::new("storm/x")).is_some())
                .collect()
        };
        assert_eq!(schedule(7), schedule(7), "same seed, same schedule");
        assert_ne!(schedule(7), schedule(8), "different seed, different walk");
        assert!(schedule(7).iter().any(|&b| b), "a storm injects something");
        assert!(!schedule(7).iter().all(|&b| b), "but not everything");
    }

    #[test]
    fn quarantine_is_idempotent_per_content() {
        let _g = lock_unpoisoned(&TEST_LOCK);
        let dir = tmp("quarantine");
        std::fs::remove_dir_all(&dir).ok();
        let before = counters();
        let name = format!("bad-{}.line", content_tag(b"garbage"));
        assert!(quarantine_bytes(&dir, &name, b"garbage", "parse error"));
        assert!(
            !quarantine_bytes(&dir, &name, b"garbage", "parse error"),
            "second sighting of the same entry is not re-quarantined"
        );
        assert_eq!(counters().since(&before).quarantined, 1);
        assert_eq!(std::fs::read_to_string(dir.join(&name)).unwrap(), "garbage");
        assert!(dir.join(format!("{name}.reason")).exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn quarantine_move_relocates_the_corrupt_file() {
        let _g = lock_unpoisoned(&TEST_LOCK);
        let dir = tmp("qmove");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(dir.join("traces")).unwrap();
        let src = dir.join("traces/trace-abc.bin");
        std::fs::write(&src, b"not a trace").unwrap();
        let qdir = dir.join("quarantine");
        let dst = quarantine_move(&qdir, &src, "bad magic").unwrap();
        assert!(!src.exists(), "the corrupt file no longer satisfies probes");
        assert_eq!(std::fs::read_to_string(dst).unwrap(), "not a trace");
        assert!(qdir.join("trace-abc.bin.reason").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
