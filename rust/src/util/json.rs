//! Minimal JSON reader/writer (no serde in this offline environment).
//!
//! Covers exactly what the framework needs: reading `artifacts/manifest.json`
//! and emitting report/result files. Supports the full JSON value grammar
//! with f64 numbers.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.  Objects use a `BTreeMap`, so serialization is
/// canonical: equal values always dump to identical bytes.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (JSON has only f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, keys sorted.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object field lookup; `None` for non-objects and missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array element lookup; `None` for non-arrays and out of range.
    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The numeric value truncated to `usize`, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The numeric value truncated to `u64`, if this is a number.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x as u64)
    }

    /// The boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Build an object from `(key, value)` pairs (keys are sorted by the
    /// underlying `BTreeMap`, which makes the serialization canonical).
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Like [`Json::get`] but with a descriptive error for missing keys.
    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing key '{key}'"))
    }

    /// Serialize compactly.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}

impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}

impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}

impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}

impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}

impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Returns an error message on malformed input.
pub fn parse(text: &str) -> Result<Json, String> {
    let bytes = text.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(format!("trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad utf8")?,
                                16,
                            )
                            .map_err(|_| "bad hex")?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let rest = &self.b[self.i..];
                    let len = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..len.min(rest.len())])
                        .map_err(|_| "bad utf8 in string")?;
                    s.push_str(chunk);
                    self.i += len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{"batch": 256, "names": ["a", "b"], "nested": {"x": 1.5, "ok": true, "nil": null}}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("batch").unwrap().as_usize(), Some(256));
        assert_eq!(
            v.get("names").unwrap().idx(1).unwrap().as_str(),
            Some("b")
        );
        assert_eq!(
            v.get("nested").unwrap().get("x").unwrap().as_f64(),
            Some(1.5)
        );
        let re = parse(&v.dump()).unwrap();
        assert_eq!(re, v);
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = parse(r#""a\n\"bé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\"bé"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
    }

    #[test]
    fn obj_builder_is_canonical() {
        let a = Json::obj(vec![("b", 2u64.into()), ("a", "x".into())]);
        let b = Json::obj(vec![("a", "x".into()), ("b", 2u64.into())]);
        assert_eq!(a.dump(), b.dump());
        assert_eq!(a.dump(), r#"{"a":"x","b":2}"#);
        assert_eq!(a.req("a").unwrap().as_str(), Some("x"));
        assert!(a.req("c").is_err());
        assert_eq!(a.get("b").unwrap().as_u64(), Some(2));
    }

    #[test]
    fn negative_and_exponent_numbers() {
        let v = parse("[-1.5e3, 0.25]").unwrap();
        assert_eq!(v.idx(0).unwrap().as_f64(), Some(-1500.0));
        assert_eq!(v.idx(1).unwrap().as_f64(), Some(0.25));
    }
}
