//! Deterministic PRNGs for workload generation and property tests.
//!
//! No external `rand` crate exists in this offline environment, so we ship
//! SplitMix64 (seeding) and xoshiro256** (bulk generation) — the standard
//! pairing recommended by Blackman & Vigna.

/// SplitMix64: used to expand a single `u64` seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// A generator starting from `seed`.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// The next 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256**: fast, high-quality 64-bit generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// A generator whose state is expanded from `seed` via [`SplitMix64`].
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// The next 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// The next 32-bit output (upper half of [`Rng::next_u64`]).
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)`. Lemire's rejection-free-in-practice method.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform `usize` in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.gen_range((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.gen_f64()
    }

    /// True with probability `p`.
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn gen_range_in_bounds() {
        let mut r = Rng::new(7);
        for n in [1u64, 2, 3, 10, 1000, u64::MAX / 2] {
            for _ in 0..50 {
                assert!(r.gen_range(n) < n);
            }
        }
    }

    #[test]
    fn gen_f64_unit_interval() {
        let mut r = Rng::new(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
