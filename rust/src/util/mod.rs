//! Infrastructure utilities: PRNG, statistics, tables, JSON, property tests.
//!
//! Everything here exists because the offline build environment provides no
//! third-party crates beyond the `xla` closure — see DESIGN.md §3.

pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod table;

pub use rng::Rng;
pub use table::TextTable;
