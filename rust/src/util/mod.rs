//! Infrastructure utilities: PRNG, statistics, tables, JSON, property tests.
//!
//! Everything here exists because the offline build environment provides no
//! third-party crates beyond the `xla` closure — see DESIGN.md §3.

pub mod faultio;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod table;

pub use rng::Rng;
pub use table::TextTable;

/// Lock a mutex, recovering the guard when a previous holder panicked.
///
/// The coordinator's worker pool shares result/trace/error state behind
/// mutexes; with plain `.lock().unwrap()`, one panicking worker poisons
/// the lock and every other worker then panics on acquisition, turning a
/// single bad design point into a pool-wide cascade.  The data guarded
/// here is either append-only or validated downstream, so the right
/// recovery is to take the guard and keep going — the original panic is
/// still reported through the pool's error channel.
pub fn lock_unpoisoned<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn lock_unpoisoned_recovers_after_a_panicking_holder() {
        let m = Arc::new(Mutex::new(0u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _guard = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.lock().is_err(), "lock should be poisoned");
        *lock_unpoisoned(&m) += 1;
        assert_eq!(*lock_unpoisoned(&m), 1);
    }
}
