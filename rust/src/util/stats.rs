//! Small statistics helpers used by the profiler, benches and reports.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean; 0.0 for an empty slice. Requires positive inputs.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let log_sum: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

/// Sample standard deviation; 0.0 for fewer than two samples.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
        / (xs.len() - 1) as f64;
    var.sqrt()
}

/// Linear-interpolation percentile, `p` in `[0, 100]`.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Peak resident-set size of this process in KiB (Linux `VmHWM`), or 0
/// when the platform doesn't expose it.  Used by the sweep stats report
/// to make the streaming pipeline's memory bound observable.
pub fn peak_rss_kb() -> u64 {
    if cfg!(target_os = "linux") {
        if let Ok(status) = std::fs::read_to_string("/proc/self/status") {
            for line in status.lines() {
                if let Some(rest) = line.strip_prefix("VmHWM:") {
                    return rest
                        .trim()
                        .trim_end_matches("kB")
                        .trim()
                        .parse()
                        .unwrap_or(0);
                }
            }
        }
    }
    0
}

/// Relative deviation |a-b| / |b| (the paper's Table V metric).
pub fn rel_dev(a: f64, b: f64) -> f64 {
    if b == 0.0 {
        return if a == 0.0 { 0.0 } else { f64::INFINITY };
    }
    ((a - b) / b).abs()
}

/// Simple wall-clock measurement harness used by the custom benches
/// (criterion is unavailable offline). Runs `f` for at least `min_iters`
/// iterations and ~`min_time_ms`, returning (iters, ns/iter).
pub fn time_it<F: FnMut()>(mut f: F, min_iters: u64, min_time_ms: u64) -> (u64, f64) {
    // warm-up
    f();
    let start = std::time::Instant::now();
    let mut iters = 0u64;
    while iters < min_iters
        || start.elapsed() < std::time::Duration::from_millis(min_time_ms)
    {
        f();
        iters += 1;
        if iters > 100_000_000 {
            break;
        }
    }
    let ns = start.elapsed().as_nanos() as f64 / iters as f64;
    (iters, ns)
}

/// Pareto frontier under "larger is better on both axes": `out[i]` is true
/// iff no other point dominates point `i` (strictly better on one axis, at
/// least as good on the other).  Duplicate points are all kept — they
/// dominate each other only weakly.  O(n²), fine for sweep-sized inputs.
pub fn pareto_front(points: &[(f64, f64)]) -> Vec<bool> {
    let dominates = |a: &(f64, f64), b: &(f64, f64)| {
        a.0 >= b.0 && a.1 >= b.1 && (a.0 > b.0 || a.1 > b.1)
    };
    points
        .iter()
        .map(|p| !points.iter().any(|q| dominates(q, p)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn geomean_basic() {
        let g = geomean(&[1.0, 4.0]);
        assert!((g - 2.0).abs() < 1e-12);
    }

    #[test]
    fn stddev_basic() {
        let s = stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn percentile_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn rel_dev_basic() {
        assert!((rel_dev(124.0, 100.0) - 0.24).abs() < 1e-12);
        assert_eq!(rel_dev(0.0, 0.0), 0.0);
    }

    #[test]
    fn peak_rss_parses_when_the_kernel_exposes_it() {
        // minimal/sandboxed kernels (gVisor) omit VmHWM from
        // /proc/self/status entirely — peak_rss_kb must degrade to 0
        // there, and parse a positive value where the line exists
        let has_line = std::fs::read_to_string("/proc/self/status")
            .map(|s| s.lines().any(|l| l.starts_with("VmHWM:")))
            .unwrap_or(false);
        let kb = peak_rss_kb();
        if has_line {
            assert!(kb > 0, "VmHWM present but parsed as 0");
        } else {
            assert_eq!(kb, 0);
        }
    }

    #[test]
    fn pareto_front_basic() {
        // (3,1) and (1,3) are frontier; (1,1) dominated by both; (2,2)
        // dominated by nothing; (3,3) dominates everything
        let pts = [(3.0, 1.0), (1.0, 3.0), (1.0, 1.0), (2.0, 2.0), (3.0, 3.0)];
        assert_eq!(
            pareto_front(&pts),
            vec![false, false, false, false, true]
        );
        let pts = [(3.0, 1.0), (1.0, 3.0), (2.0, 2.0), (1.0, 1.0)];
        assert_eq!(pareto_front(&pts), vec![true, true, true, false]);
    }

    #[test]
    fn pareto_front_keeps_duplicates_and_handles_edges() {
        assert!(pareto_front(&[]).is_empty());
        assert_eq!(pareto_front(&[(1.0, 1.0)]), vec![true]);
        // exact duplicates only weakly dominate each other: both stay
        assert_eq!(
            pareto_front(&[(2.0, 2.0), (2.0, 2.0), (1.0, 5.0)]),
            vec![true, true, true]
        );
    }
}
