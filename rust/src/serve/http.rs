//! Minimal HTTP/1.1 framing for the evaluation service.
//!
//! The offline build environment has no HTTP crate, and the service needs
//! almost nothing from the protocol: a request line, a `Content-Length`
//! header, a JSON body in, a JSON body out, `Connection: close`.  This
//! module implements exactly that over any `Read`/`Write` pair (generic so
//! the framing is unit-testable without sockets).  Keep-alive, chunked
//! transfer, multipart and TLS are deliberately out of scope — every
//! response closes the connection.

use std::io::{Read, Write};

/// Largest accepted request-header block (request line + headers).
pub const MAX_HEADER_BYTES: usize = 16 * 1024;
/// Largest accepted request body.
pub const MAX_BODY_BYTES: usize = 1024 * 1024;

/// One parsed HTTP request: just the parts the service routes on.
#[derive(Clone, Debug)]
pub struct Request {
    /// request method (`GET`, `POST`, ...), uppercased by the client
    pub method: String,
    /// request path with any `?query` suffix stripped
    pub path: String,
    /// raw request body (empty when the request carried none)
    pub body: String,
}

/// One response about to be written: status + JSON body + the service's
/// two observability headers.
#[derive(Clone, Debug)]
pub struct Response {
    /// HTTP status code (`200`, `400`, `404`, `405`, `500`, `503`, `504`)
    pub status: u16,
    /// response body — canonical JSON, newline-terminated
    pub body: String,
    /// `X-Eva-Cache` header value (`computed` / `cached` / `shared`);
    /// omitted on error responses
    pub cache: Option<&'static str>,
    /// `X-Eva-Ledger` header value: the canonical JSON sweep ledger
    /// (single line by construction)
    pub ledger: Option<String>,
}

/// Read and frame one HTTP request.
///
/// Errors are client-facing strings (the caller turns them into a `400`
/// envelope): oversized headers/body, a malformed request line, a closed
/// connection mid-request, or a non-UTF-8 body.
pub fn read_request<R: Read>(stream: &mut R) -> Result<Request, String> {
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    let header_end = loop {
        if let Some(i) = find_header_end(&buf) {
            break i;
        }
        if buf.len() > MAX_HEADER_BYTES {
            return Err("request headers too large".into());
        }
        let n = stream.read(&mut chunk).map_err(|e| format!("read: {e}"))?;
        if n == 0 {
            return Err("connection closed before a full request".into());
        }
        buf.extend_from_slice(&chunk[..n]);
    };

    let head = std::str::from_utf8(&buf[..header_end])
        .map_err(|_| "non-UTF-8 request headers".to_string())?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| "empty request line".to_string())?
        .to_string();
    let target = parts
        .next()
        .ok_or_else(|| "request line has no path".to_string())?;
    let path = target.split('?').next().unwrap_or(target).to_string();

    let mut content_length = 0usize;
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = v
                    .trim()
                    .parse()
                    .map_err(|_| "bad Content-Length header".to_string())?;
            }
        }
    }
    if content_length > MAX_BODY_BYTES {
        return Err("request body too large".into());
    }

    let mut body = buf[header_end + 4..].to_vec();
    while body.len() < content_length {
        let n = stream.read(&mut chunk).map_err(|e| format!("read: {e}"))?;
        if n == 0 {
            return Err("connection closed mid-body".into());
        }
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(content_length);
    let body =
        String::from_utf8(body).map_err(|_| "non-UTF-8 request body".to_string())?;
    Ok(Request { method, path, body })
}

/// Serialize one response (status line, headers, body) and flush.
pub fn write_response<W: Write>(stream: &mut W, r: &Response) -> std::io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n",
        r.status,
        reason(r.status),
        r.body.len()
    );
    if let Some(c) = r.cache {
        head.push_str("X-Eva-Cache: ");
        head.push_str(c);
        head.push_str("\r\n");
    }
    if let Some(l) = &r.ledger {
        // the ledger is a single-line canonical JSON object, so it is
        // header-safe by construction
        head.push_str("X-Eva-Ledger: ");
        head.push_str(l);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(r.body.as_bytes())?;
    stream.flush()
}

/// Canonical reason phrase for the status codes the service emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// Arm a connection's socket read/write timeouts: a client that sends
/// headers and then stalls (or never drains the response) is disconnected
/// instead of holding an HTTP worker forever.  `Duration::ZERO` disables
/// both timeouts.
pub fn configure_stream(
    stream: &std::net::TcpStream,
    timeout: std::time::Duration,
) -> std::io::Result<()> {
    let t = if timeout.is_zero() { None } else { Some(timeout) };
    stream.set_read_timeout(t)?;
    stream.set_write_timeout(t)
}

fn find_header_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_a_post_with_body() {
        let raw = b"POST /evaluate?x=1 HTTP/1.1\r\nHost: h\r\nContent-Length: 14\r\n\r\n{\"bench\":\"lcs\"".to_vec();
        let req = read_request(&mut raw.as_slice()).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/evaluate");
        assert_eq!(req.body, "{\"bench\":\"lcs\"");
    }

    #[test]
    fn frames_a_get_without_body() {
        let raw = b"GET /health HTTP/1.1\r\n\r\n".to_vec();
        let req = read_request(&mut raw.as_slice()).unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/health");
        assert_eq!(req.body, "");
    }

    #[test]
    fn header_name_is_case_insensitive() {
        let raw = b"POST /x HTTP/1.1\r\ncontent-LENGTH: 2\r\n\r\nok".to_vec();
        assert_eq!(read_request(&mut raw.as_slice()).unwrap().body, "ok");
    }

    #[test]
    fn truncated_requests_error_cleanly() {
        let raw = b"POST /x HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc".to_vec();
        assert!(read_request(&mut raw.as_slice()).is_err());
        let raw = b"GET /x HTTP/1.1\r\n".to_vec();
        assert!(read_request(&mut raw.as_slice()).is_err());
    }

    #[test]
    fn oversized_body_is_rejected_up_front() {
        let raw = format!(
            "POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            MAX_BODY_BYTES + 1
        )
        .into_bytes();
        assert!(read_request(&mut raw.as_slice()).is_err());
    }

    #[test]
    fn response_carries_observability_headers() {
        let r = Response {
            status: 200,
            body: "{}\n".into(),
            cache: Some("cached"),
            ledger: Some("{\"ledger\":\"sweep\"}".into()),
        };
        let mut out = Vec::new();
        write_response(&mut out, &r).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("Content-Length: 3\r\n"));
        assert!(text.contains("X-Eva-Cache: cached\r\n"));
        assert!(text.contains("X-Eva-Ledger: {\"ledger\":\"sweep\"}\r\n"));
        assert!(text.ends_with("\r\n\r\n{}\n"));
    }
}
