//! `eva-cim serve` — a long-lived evaluation service over the shared
//! caches.
//!
//! The CLI pays a cold process per query; this module keeps **one warm
//! process** — one [`Coordinator`] with its process-lifetime analysis
//! memo, one result/trace/artifact store, one staging pool — and answers
//! `evaluate` / `sweep` / `explore` requests over plain HTTP/1.1 (std-only
//! `TcpListener` + worker threads; the offline environment has no HTTP or
//! async crates).  Responses reuse the canonical-JSON [`Report`] rendering
//! byte-for-byte: the report **is** the wire format, so a served body is
//! identical to the CLI's `--format json` stdout for the same query.
//!
//! Routes:
//!
//! | route            | method | body                                    |
//! |------------------|--------|-----------------------------------------|
//! | `/health`        | GET    | liveness probe                          |
//! | `/stats`         | GET    | cumulative service + sweep-ledger counters |
//! | `/list`          | GET    | the `eva-cim list` catalog              |
//! | `/evaluate`      | POST   | one design point (`{"bench": ...}`)     |
//! | `/sweep`         | POST   | benches × configs × techs grid          |
//! | `/explore`       | POST   | Pareto grid + frontier                  |
//! | `/plan`          | POST   | offload plan for one design point       |
//!
//! Observability rides on headers, never on the (byte-stable) body:
//! `X-Eva-Cache` says whether the answer was `computed` (a simulation or
//! analysis ran), `cached` (every stage served from the memo/stores), or
//! `shared` (this request rode on a concurrent identical one), and
//! `X-Eva-Ledger` carries the canonical JSON sweep ledger
//! ([`ledger_json`]).  Errors use one JSON envelope:
//! `{"error":{"code":N,"message":...},"schema":1}`.
//!
//! Concurrency model: a nonblocking accept loop feeds a **bounded** job
//! queue (`--queue`; overflow is answered `503` immediately, applying
//! backpressure instead of unbounded buffering) drained by a fixed pool of
//! HTTP workers.  Identical in-flight requests are deduplicated by a
//! canonical request key — the FNV-1a hash of the normalized request JSON
//! ([`key::fnv1a`], the same hash family as the design-point keys) — so N
//! concurrent identical queries run the pipeline once and N−1 riders wait
//! on a condvar for the published bytes.  `SIGINT` or `SIGTERM` (see
//! [`install_signal_handlers`]) stops the accept loop, drains every job
//! already queued, joins the workers and exits.  A panicking request
//! handler is contained to a `500` envelope ([`crate::coordinator`]'s
//! worker containment plus a `catch_unwind` here) — it never takes the
//! pool down.
//!
//! Fault domains: an optional per-request deadline (`--request-timeout`)
//! answers `504` when an evaluating endpoint runs long — the computation
//! finishes on a detached thread and warms the caches for a retry — and
//! per-socket read/write timeouts (`--socket-timeout`) disconnect a
//! client that stalls mid-request or never drains its response, so a
//! slow peer cannot hold an HTTP worker hostage.  Sweep-level I/O faults
//! surface on the cumulative ledger (`io_retries`,
//! `entries_quarantined`, `degraded_mode` on `GET /stats`).

pub mod http;

use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::analyzer::LocalityRule;
use crate::api::{Cell, Evaluation, Report, Section};
use crate::config::{CimLevels, SystemConfig, Technology};
use crate::coordinator::{key, ledger_json, panic_message, Coordinator, SweepStats};
use crate::energy::device;
use crate::util::json::{self, Json};
use crate::util::lock_unpoisoned;
use crate::workloads;

/// Cache-control states reported in the `X-Eva-Cache` header.
pub const CACHE_COMPUTED: &str = "computed";
/// See [`CACHE_COMPUTED`]: every stage was served from caches.
pub const CACHE_CACHED: &str = "cached";
/// See [`CACHE_COMPUTED`]: the request rode on a concurrent identical one.
pub const CACHE_SHARED: &str = "shared";

/// How to run the service: bind address, pool sizing, and the base
/// [`Evaluation`] holding the server-wide defaults (scale, seed, staging
/// workers, cache dir, backend policy) that every request starts from.
pub struct ServeOptions {
    /// bind address, e.g. `127.0.0.1:7878` (port `0` picks a free port —
    /// the test harness's spawn idiom)
    pub addr: String,
    /// HTTP worker threads — the number of requests in flight at once
    /// (each request additionally stages with the base evaluation's
    /// `--jobs` staging workers)
    pub http_workers: usize,
    /// bounded job-queue capacity; accepted connections beyond it are
    /// answered `503` immediately
    pub queue: usize,
    /// per-request wall-clock deadline for the evaluating endpoints: a
    /// leader still computing when it expires is answered `504` while the
    /// computation finishes in the background (warming the caches for a
    /// retry); `None` — the default — disables the deadline
    pub request_timeout: Option<Duration>,
    /// socket read/write timeout for accepted connections — a client that
    /// stalls mid-request or never drains its response is disconnected
    /// instead of holding an HTTP worker; `Duration::ZERO` disables it
    pub socket_timeout: Duration,
    /// server-wide evaluation defaults; requests override per-field
    pub base: Evaluation,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7878".into(),
            http_workers: 4,
            queue: 64,
            request_timeout: None,
            socket_timeout: Duration::from_secs(30),
            base: Evaluation::new(),
        }
    }
}

/// request-scoped endpoint discriminator
#[derive(Clone, Copy, PartialEq, Eq)]
enum Kind {
    Evaluate,
    Sweep,
    Explore,
    Plan,
}

/// The computed answer for one deduplicated request — what the leader
/// publishes and every rider clones.
#[derive(Clone)]
struct Outcome {
    status: u16,
    body: String,
    ledger: Option<String>,
    cache: Option<&'static str>,
}

/// One in-flight computation: riders wait on the condvar until the leader
/// publishes the outcome.
struct Slot {
    followers: AtomicU64,
    ready: Mutex<Option<Outcome>>,
    cv: Condvar,
}

enum Role {
    Leader(Arc<Slot>),
    Follower(Arc<Slot>),
}

/// The in-flight request-dedup map, keyed by the canonical request key.
struct Inflight {
    map: Mutex<HashMap<u64, Arc<Slot>>>,
}

impl Inflight {
    fn new() -> Self {
        Self { map: Mutex::new(HashMap::new()) }
    }

    /// First caller for a key becomes the leader (and must
    /// [`Inflight::publish`] exactly once); later callers become
    /// followers of the leader's slot.
    fn join(&self, key: u64) -> Role {
        let mut map = lock_unpoisoned(&self.map);
        match map.get(&key) {
            Some(slot) => {
                slot.followers.fetch_add(1, Ordering::SeqCst);
                Role::Follower(Arc::clone(slot))
            }
            None => {
                let slot = Arc::new(Slot {
                    followers: AtomicU64::new(0),
                    ready: Mutex::new(None),
                    cv: Condvar::new(),
                });
                map.insert(key, Arc::clone(&slot));
                Role::Leader(slot)
            }
        }
    }

    /// Publish the leader's outcome (waking every follower), then retire
    /// the key so the next identical request starts fresh — which, with a
    /// warm memo, means `cached`, not `shared`.
    fn publish(&self, key: u64, slot: &Arc<Slot>, outcome: Outcome) {
        *lock_unpoisoned(&slot.ready) = Some(outcome);
        slot.cv.notify_all();
        lock_unpoisoned(&self.map).remove(&key);
    }
}

/// Block until the leader publishes, then clone the outcome.
fn wait_outcome(slot: &Slot) -> Outcome {
    let guard = lock_unpoisoned(&slot.ready);
    let guard = slot
        .cv
        .wait_while(guard, |o| o.is_none())
        .unwrap_or_else(|p| p.into_inner());
    // safety: wait_while only returns once the slot holds Some, and
    // leaders always publish (panics are converted to 500 outcomes)
    guard.clone().expect("leader published an outcome")
}

/// Cumulative service counters, exposed on `GET /stats` and printed as
/// the drain summary on shutdown.  All atomics: the HTTP workers update
/// them concurrently.
#[derive(Default)]
pub struct ServeStats {
    requests: AtomicU64,
    evaluate: AtomicU64,
    sweep: AtomicU64,
    explore: AtomicU64,
    plan: AtomicU64,
    list: AtomicU64,
    health: AtomicU64,
    stats_reads: AtomicU64,
    responses_ok: AtomicU64,
    client_errors: AtomicU64,
    server_errors: AtomicU64,
    queue_rejected: AtomicU64,
    served_computed: AtomicU64,
    served_cached: AtomicU64,
    dedup_shared: AtomicU64,
    // cumulative sweep ledger (summed over every request's SweepStats)
    points: AtomicU64,
    rows_from_cache: AtomicU64,
    rows_computed: AtomicU64,
    simulator_runs: AtomicU64,
    analyses_run: AtomicU64,
    analyses_cached: AtomicU64,
    replays_skipped: AtomicU64,
    trace_disk_hits: AtomicU64,
    replay_chunks_decoded: AtomicU64,
    replay_lanes_split: AtomicU64,
    groups_accepted: AtomicU64,
    groups_rejected: AtomicU64,
    // summed as whole pJ (rounded per request) — an atomic integer keeps
    // the counter lock-free like its siblings
    rejected_energy_pj: AtomicU64,
    // fault-domain counters: cumulative transient-I/O retries and
    // quarantined store entries, plus a sticky degraded-mode flag (0/1)
    io_retries: AtomicU64,
    entries_quarantined: AtomicU64,
    degraded: AtomicU64,
}

impl ServeStats {
    fn note_request(&self, req: &http::Request) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        let per_route = match req.path.as_str() {
            "/evaluate" => &self.evaluate,
            "/sweep" => &self.sweep,
            "/explore" => &self.explore,
            "/plan" => &self.plan,
            "/list" => &self.list,
            "/health" => &self.health,
            "/stats" => &self.stats_reads,
            _ => return,
        };
        per_route.fetch_add(1, Ordering::Relaxed);
    }

    fn note_response(&self, status: u16) {
        let bucket = match status {
            200..=299 => &self.responses_ok,
            400..=499 => &self.client_errors,
            _ => &self.server_errors,
        };
        bucket.fetch_add(1, Ordering::Relaxed);
    }

    fn note_cache(&self, cache: Option<&'static str>) {
        match cache {
            Some(c) if c == CACHE_COMPUTED => {
                self.served_computed.fetch_add(1, Ordering::Relaxed);
            }
            Some(c) if c == CACHE_CACHED => {
                self.served_cached.fetch_add(1, Ordering::Relaxed);
            }
            Some(c) if c == CACHE_SHARED => {
                self.dedup_shared.fetch_add(1, Ordering::Relaxed);
            }
            _ => {}
        }
    }

    fn note_rejected(&self) {
        self.queue_rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Fold one finished sweep's ledger into the cumulative totals.
    fn absorb(&self, s: &SweepStats) {
        self.points.fetch_add(s.points as u64, Ordering::Relaxed);
        self.rows_from_cache
            .fetch_add(s.rows_from_cache as u64, Ordering::Relaxed);
        self.rows_computed
            .fetch_add(s.rows_computed as u64, Ordering::Relaxed);
        self.simulator_runs.fetch_add(s.simulator_runs, Ordering::Relaxed);
        self.analyses_run.fetch_add(s.analyses_run, Ordering::Relaxed);
        self.analyses_cached.fetch_add(s.analyses_cached, Ordering::Relaxed);
        self.replays_skipped.fetch_add(s.replays_skipped, Ordering::Relaxed);
        self.trace_disk_hits.fetch_add(s.trace_disk_hits, Ordering::Relaxed);
        self.replay_chunks_decoded
            .fetch_add(s.replay_chunks_decoded, Ordering::Relaxed);
        self.replay_lanes_split
            .fetch_add(s.replay_lanes_split, Ordering::Relaxed);
        self.groups_accepted.fetch_add(s.groups_accepted, Ordering::Relaxed);
        self.groups_rejected.fetch_add(s.groups_rejected, Ordering::Relaxed);
        self.rejected_energy_pj
            .fetch_add(s.rejected_energy_pj.round() as u64, Ordering::Relaxed);
        self.io_retries.fetch_add(s.io_retries, Ordering::Relaxed);
        self.entries_quarantined
            .fetch_add(s.entries_quarantined, Ordering::Relaxed);
        if s.degraded_mode {
            // sticky: once any request ran degraded, /stats says so until
            // the process restarts (an operator signal, not a rate)
            self.degraded.store(1, Ordering::Relaxed);
        }
    }

    /// The `GET /stats` report: service counters + the cumulative sweep
    /// ledger, as a regular [`Report`] so the wire shape matches every
    /// other endpoint.
    pub fn report(&self) -> Report {
        let mut service = Section::new("service counters", &["metric", "value"]);
        for (name, v) in [
            ("requests", &self.requests),
            ("evaluate", &self.evaluate),
            ("sweep", &self.sweep),
            ("explore", &self.explore),
            ("plan", &self.plan),
            ("list", &self.list),
            ("health", &self.health),
            ("stats", &self.stats_reads),
            ("responses_ok", &self.responses_ok),
            ("client_errors", &self.client_errors),
            ("server_errors", &self.server_errors),
            ("queue_rejected", &self.queue_rejected),
            ("served_computed", &self.served_computed),
            ("served_cached", &self.served_cached),
            ("dedup_shared", &self.dedup_shared),
        ] {
            service.row(vec![Cell::str(name), Cell::int(v.load(Ordering::Relaxed))]);
        }
        let mut ledger =
            Section::new("cumulative sweep ledger", &["counter", "value"]);
        for (name, v) in [
            ("points", &self.points),
            ("rows_from_cache", &self.rows_from_cache),
            ("rows_computed", &self.rows_computed),
            ("simulator_runs", &self.simulator_runs),
            ("analyses_run", &self.analyses_run),
            ("analyses_cached", &self.analyses_cached),
            ("replays_skipped", &self.replays_skipped),
            ("trace_disk_hits", &self.trace_disk_hits),
            ("replay_chunks_decoded", &self.replay_chunks_decoded),
            ("replay_lanes_split", &self.replay_lanes_split),
            ("groups_accepted", &self.groups_accepted),
            ("groups_rejected", &self.groups_rejected),
            ("rejected_energy_pj", &self.rejected_energy_pj),
            ("io_retries", &self.io_retries),
            ("entries_quarantined", &self.entries_quarantined),
            ("degraded_mode", &self.degraded),
        ] {
            ledger.row(vec![Cell::str(name), Cell::int(v.load(Ordering::Relaxed))]);
        }
        Report::new("serve stats").with_section(service).with_section(ledger)
    }

    /// One-line human drain summary (stderr, on shutdown).
    fn summary(&self) -> String {
        format!(
            "{} requests ({} computed, {} cached, {} shared, {} rejected) | \
             cumulative: {} simulator runs, {} analyses run, {} analyses cached",
            self.requests.load(Ordering::Relaxed),
            self.served_computed.load(Ordering::Relaxed),
            self.served_cached.load(Ordering::Relaxed),
            self.dedup_shared.load(Ordering::Relaxed),
            self.queue_rejected.load(Ordering::Relaxed),
            self.simulator_runs.load(Ordering::Relaxed),
            self.analyses_run.load(Ordering::Relaxed),
            self.analyses_cached.load(Ordering::Relaxed),
        )
    }
}

// routers take the state by `&Arc` (not plain `&`) so a handler can hand
// a clone to a detached deadline thread that outlives the request
type Router = fn(&Arc<ServeState>, &http::Request) -> http::Response;

/// Everything the HTTP workers share: the base evaluation, the warm
/// coordinator, the dedup map and the counters.
pub struct ServeState {
    base: Evaluation,
    coord: Coordinator,
    inflight: Inflight,
    stats: ServeStats,
    router: Router,
    request_timeout: Option<Duration>,
}

impl ServeState {
    fn new(
        base: Evaluation,
        router: Router,
        request_timeout: Option<Duration>,
    ) -> Self {
        let coord = Coordinator::new(base.sweep_options());
        Self {
            base,
            coord,
            inflight: Inflight::new(),
            stats: ServeStats::default(),
            router,
            request_timeout,
        }
    }

    /// The cumulative service counters.
    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }
}

/// A bound (but not yet serving) evaluation service.
pub struct Server {
    listener: TcpListener,
    state: Arc<ServeState>,
    http_workers: usize,
    queue: usize,
    socket_timeout: Duration,
}

impl Server {
    /// Bind the listen socket and build the shared state.  Serving starts
    /// with [`Server::spawn`]; between the two, [`Server::addr`] reports
    /// the actual address (useful with port `0`).
    pub fn bind(opts: ServeOptions) -> Result<Server> {
        Self::bind_with_router(opts, route)
    }

    fn bind_with_router(opts: ServeOptions, router: Router) -> Result<Server> {
        let listener = TcpListener::bind(&opts.addr)
            .map_err(|e| anyhow!("binding {}: {e}", opts.addr))?;
        Ok(Server {
            listener,
            state: Arc::new(ServeState::new(
                opts.base,
                router,
                opts.request_timeout,
            )),
            http_workers: opts.http_workers.max(1),
            queue: opts.queue.max(1),
            socket_timeout: opts.socket_timeout,
        })
    }

    /// The bound socket address.
    pub fn addr(&self) -> SocketAddr {
        // safety: `bind` already succeeded, and a bound TCP listener
        // always has a local address
        self.listener.local_addr().expect("bound listener has an address")
    }

    /// Start the accept loop and the HTTP worker pool; returns
    /// immediately with a handle for joining or shutting down.
    ///
    /// The accept loop polls a nonblocking listener so it can observe the
    /// shutdown flags ([`ServerHandle::shutdown`] or `SIGINT`); on
    /// shutdown it stops accepting, closes the bounded queue, and the
    /// workers drain every job already accepted before exiting.
    pub fn spawn(self) -> Result<ServerHandle> {
        self.listener.set_nonblocking(true)?;
        let addr = self.listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let (tx, rx) = std::sync::mpsc::sync_channel::<TcpStream>(self.queue);
        let rx = Arc::new(Mutex::new(rx));

        let mut workers = Vec::with_capacity(self.http_workers);
        for _ in 0..self.http_workers {
            let rx = Arc::clone(&rx);
            let state = Arc::clone(&self.state);
            workers.push(std::thread::spawn(move || loop {
                // exactly one idle worker blocks in recv (it holds the
                // receiver lock only while waiting); a closed queue ends
                // the loop — that is the drain-complete signal
                let next = lock_unpoisoned(&rx).recv();
                match next {
                    Ok(mut stream) => handle_conn(&state, &mut stream),
                    Err(_) => break,
                }
            }));
        }

        let listener = self.listener;
        let state = Arc::clone(&self.state);
        let stop_flag = Arc::clone(&stop);
        let socket_timeout = self.socket_timeout;
        let accept = std::thread::spawn(move || {
            loop {
                if stop_flag.load(Ordering::SeqCst)
                    || SHUTDOWN.load(Ordering::SeqCst)
                {
                    break;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        let _ = stream.set_nonblocking(false);
                        let _ = http::configure_stream(&stream, socket_timeout);
                        match tx.try_send(stream) {
                            Ok(()) => {}
                            Err(std::sync::mpsc::TrySendError::Full(mut s)) => {
                                // bounded queue: answer 503 immediately
                                // instead of buffering without limit
                                state.stats.note_rejected();
                                let _ = http::write_response(
                                    &mut s,
                                    &error_response(
                                        503,
                                        "job queue full; retry later",
                                    ),
                                );
                            }
                            Err(std::sync::mpsc::TrySendError::Disconnected(
                                _,
                            )) => break,
                        }
                    }
                    Err(e)
                        if e.kind() == std::io::ErrorKind::WouldBlock =>
                    {
                        std::thread::sleep(Duration::from_millis(20));
                    }
                    Err(_) => std::thread::sleep(Duration::from_millis(20)),
                }
            }
            // graceful drain: close the queue, let the workers finish
            // everything already accepted, then join them
            drop(tx);
            for w in workers {
                let _ = w.join();
            }
        });

        Ok(ServerHandle { addr, stop, accept, state: self.state })
    }
}

/// A running service: join it (blocks until `SIGINT`) or shut it down
/// programmatically.  Either way the bounded queue is drained before the
/// handle returns.
pub struct ServerHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: std::thread::JoinHandle<()>,
    state: Arc<ServeState>,
}

impl ServerHandle {
    /// The bound socket address.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The shared state (stats) — live while the server runs.
    pub fn state(&self) -> &ServeState {
        &self.state
    }

    /// Block until the accept loop exits (SIGINT or
    /// [`ServerHandle::shutdown`] from another thread), with the queue
    /// fully drained; prints the drain summary to stderr.
    pub fn join(self) {
        let _ = self.accept.join();
        eprintln!("eva-cim serve: drained; {}", self.state.stats.summary());
    }

    /// Request a graceful shutdown and [`ServerHandle::join`] it.
    pub fn shutdown(self) {
        self.stop.store(true, Ordering::SeqCst);
        self.join();
    }
}

/// Process-wide shutdown flag, set by `SIGINT` or `SIGTERM`: the accept
/// loop polls it, so either signal drains in-flight jobs instead of
/// killing them mid-sweep.
static SHUTDOWN: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
extern "C" fn on_shutdown(_sig: i32) {
    // only async-signal-safe work here: set the flag, nothing else
    SHUTDOWN.store(true, Ordering::SeqCst);
}

/// Install `SIGINT` and `SIGTERM` handlers that request a graceful drain
/// (stop accepting, finish queued jobs, exit) — Ctrl-C and a
/// supervisor's plain `kill` terminate identically.  Unix-only; a no-op
/// elsewhere.  Uses the libc `signal(2)` symbol directly — the offline
/// environment has no signal-handling crate.
pub fn install_signal_handlers() {
    #[cfg(unix)]
    {
        extern "C" {
            fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
        }
        // SIGINT is 2 and SIGTERM is 15 on every unix the toolchain
        // targets
        let _ = unsafe { signal(2, on_shutdown) };
        let _ = unsafe { signal(15, on_shutdown) };
    }
}

/// One connection, end to end: frame the request, route it (panics
/// contained to a 500 envelope), count it, write the response.
fn handle_conn(state: &Arc<ServeState>, stream: &mut TcpStream) {
    let resp = match http::read_request(stream) {
        Ok(req) => {
            state.stats.note_request(&req);
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                (state.router)(state, &req)
            }))
            .unwrap_or_else(|p| {
                error_response(
                    500,
                    &format!(
                        "request handler panicked: {}",
                        panic_message(p.as_ref())
                    ),
                )
            })
        }
        Err(msg) => error_response(400, &msg),
    };
    state.stats.note_response(resp.status);
    let _ = http::write_response(stream, &resp);
}

/// The service's route table.
fn route(state: &Arc<ServeState>, req: &http::Request) -> http::Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/health") => ok_response(health_body()),
        ("GET", "/stats") => ok_response(state.stats.report().render_json()),
        ("GET", "/list") => ok_response(crate::api::list_report().render_json()),
        ("POST", "/evaluate") => handle_eval(state, Kind::Evaluate, req),
        ("POST", "/sweep") => handle_eval(state, Kind::Sweep, req),
        ("POST", "/explore") => handle_eval(state, Kind::Explore, req),
        ("POST", "/plan") => handle_eval(state, Kind::Plan, req),
        (_, "/health" | "/stats" | "/list") => {
            error_response(405, "this endpoint is GET-only")
        }
        (_, "/evaluate" | "/sweep" | "/explore" | "/plan") => {
            error_response(405, "this endpoint takes POST with a JSON body")
        }
        _ => error_response(
            404,
            &format!(
                "unknown route '{}' (endpoints: /health /stats /list \
                 /evaluate /sweep /explore /plan)",
                req.path
            ),
        ),
    }
}

/// The three evaluating endpoints share one path: parse + normalize the
/// request, dedup identical in-flight requests, compute through the warm
/// coordinator, and attach the cache state + ledger headers.
fn handle_eval(
    state: &Arc<ServeState>,
    kind: Kind,
    req: &http::Request,
) -> http::Response {
    let text = if req.body.trim().is_empty() { "{}" } else { req.body.as_str() };
    let body = match json::parse(text) {
        Ok(b) => b,
        Err(e) => return error_response(400, &format!("malformed JSON body: {e}")),
    };
    let (ev, norm) = match build_request(&state.base, kind, &body) {
        Ok(x) => x,
        Err(msg) => return error_response(400, &msg),
    };
    // the dedup key: canonical JSON of the *normalized* request (defaults
    // applied, object keys sorted), hashed with the same FNV-1a the
    // design-point keys use — formatting/key-order variants collapse
    let rkey = key::fnv1a(norm.dump().as_bytes());

    let outcome = match state.inflight.join(rkey) {
        Role::Leader(slot) => {
            // contain panics here too: a leader that dies without
            // publishing would hang every follower forever
            let mut o = match state.request_timeout {
                Some(deadline) => {
                    compute_with_deadline(state, kind, &ev, deadline)
                }
                None => std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                    || compute(state, kind, &ev),
                ))
                .unwrap_or_else(|p| {
                    error_outcome(
                        500,
                        &format!(
                            "request handler panicked: {}",
                            panic_message(p.as_ref())
                        ),
                    )
                }),
            };
            if o.cache.is_some() && slot.followers.load(Ordering::SeqCst) > 0 {
                // riders joined while we computed: this answer was shared
                o.cache = Some(CACHE_SHARED);
            }
            state.inflight.publish(rkey, &slot, o.clone());
            o
        }
        Role::Follower(slot) => {
            let mut o = wait_outcome(&slot);
            if o.cache.is_some() {
                o.cache = Some(CACHE_SHARED);
            }
            o
        }
    };
    state.stats.note_cache(outcome.cache);
    http::Response {
        status: outcome.status,
        body: outcome.body,
        cache: outcome.cache,
        ledger: outcome.ledger,
    }
}

/// Run the leader's computation on a detached thread and wait at most
/// `deadline` for its outcome.  On expiry the caller gets a `504`
/// envelope immediately — freeing the HTTP worker — while the thread
/// runs to completion in the background: its response bytes are
/// discarded, but every store and memo it warms makes the retried
/// request fast (often `cached`).  A panic on the detached thread is
/// contained to a `500` the same way the inline path contains it.
fn compute_with_deadline(
    state: &Arc<ServeState>,
    kind: Kind,
    ev: &Evaluation,
    deadline: Duration,
) -> Outcome {
    let (tx, rx) = std::sync::mpsc::channel::<Outcome>();
    let thread_state = Arc::clone(state);
    let thread_ev = ev.clone();
    let spawned = std::thread::Builder::new()
        .name("eva-serve-deadline".into())
        .spawn(move || {
            let o = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                || compute(&thread_state, kind, &thread_ev),
            ))
            .unwrap_or_else(|p| {
                error_outcome(
                    500,
                    &format!(
                        "request handler panicked: {}",
                        panic_message(p.as_ref())
                    ),
                )
            });
            // after a deadline expiry the receiver is gone; that's fine —
            // the send result is deliberately ignored
            let _ = tx.send(o);
        });
    match spawned {
        Ok(_detached) => rx.recv_timeout(deadline).unwrap_or_else(|_| {
            error_outcome(
                504,
                "request exceeded the server's --request-timeout deadline; \
                 the computation continues in the background and will warm \
                 the caches for a retry",
            )
        }),
        // spawn failure (thread-resource exhaustion): degrade to the
        // inline path — slower and undeadlined, but never a lost request
        Err(_) => std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            compute(state, kind, ev)
        }))
        .unwrap_or_else(|p| {
            error_outcome(
                500,
                &format!(
                    "request handler panicked: {}",
                    panic_message(p.as_ref())
                ),
            )
        }),
    }
}

/// Run one request's evaluation on the warm coordinator and derive the
/// cache state from the ledger: `cached` iff no simulation and no
/// analysis ran (every stage came from the memo/stores), else `computed`.
fn compute(state: &ServeState, kind: Kind, ev: &Evaluation) -> Outcome {
    let report = match kind {
        Kind::Explore => ev.explore_on(&state.coord),
        Kind::Plan => ev.plan_on(&state.coord),
        Kind::Evaluate | Kind::Sweep => ev.run_on(&state.coord),
    };
    match report {
        Ok(rep) => {
            let stats = rep.stats.unwrap_or_default();
            let cache = if stats.simulator_runs == 0 && stats.analyses_run == 0 {
                CACHE_CACHED
            } else {
                CACHE_COMPUTED
            };
            state.stats.absorb(&stats);
            Outcome {
                status: 200,
                body: rep.render_json(),
                ledger: Some(ledger_json(&stats, rep.elapsed_secs, rep.backend)),
                cache: Some(cache),
            }
        }
        Err(e) => error_outcome(500, &format!("{e:#}")),
    }
}

/// Build the request's [`Evaluation`] (the server base + per-field
/// overrides) and the normalized request object that keys dedup.
fn build_request(
    base: &Evaluation,
    kind: Kind,
    body: &Json,
) -> Result<(Evaluation, Json), String> {
    match kind {
        Kind::Evaluate => {
            check_fields(
                body,
                &["bench", "config", "tech", "cim", "rule", "scale", "seed",
                  "max_instructions", "replay_threads"],
            )?;
            let bench = body
                .req("bench")
                .map_err(|_| {
                    "evaluate needs a 'bench' field (GET /list for the catalog)"
                        .to_string()
                })?
                .as_str()
                .ok_or("'bench' must be a string")?
                .to_string();
            check_bench(&bench)?;
            let config = match body.get("config") {
                Some(v) => v
                    .as_str()
                    .ok_or("'config' must be a preset name")?
                    .to_string(),
                None => "c1".to_string(),
            };
            check_preset(&config)?;
            let techs = match body.get("tech") {
                Some(v) => {
                    let s = v.as_str().ok_or("'tech' must be a string")?;
                    vec![parse_tech(s)?]
                }
                None => Vec::new(),
            };
            let ev = apply_common(base.clone(), body)?
                .bench(&bench)
                .preset(&config)
                .techs(&techs);
            let benches = vec![bench];
            let configs = vec![config];
            Ok((ev, norm_obj("evaluate", &benches, &configs, &techs, body)))
        }
        Kind::Sweep => {
            check_fields(
                body,
                &["benches", "configs", "techs", "cim", "rule", "scale",
                  "seed", "max_instructions", "replay_threads"],
            )?;
            let benches = match body.get("benches") {
                Some(v) => str_list(v, "benches")?,
                None => workloads::NAMES.iter().map(|s| s.to_string()).collect(),
            };
            for b in &benches {
                check_bench(b)?;
            }
            let configs = match body.get("configs") {
                Some(v) => str_list(v, "configs")?,
                None => vec!["c1".to_string()],
            };
            for c in &configs {
                check_preset(c)?;
            }
            // same default as `eva-cim sweep --techs sram`, so bodies match
            // the CLI byte-for-byte
            let techs = match body.get("techs") {
                Some(v) => parse_techs(v)?,
                None => vec![Technology::SRAM],
            };
            let bench_refs: Vec<&str> =
                benches.iter().map(|s| s.as_str()).collect();
            let config_refs: Vec<&str> =
                configs.iter().map(|s| s.as_str()).collect();
            let ev = apply_common(base.clone(), body)?
                .benches(&bench_refs)
                .presets(&config_refs)
                .techs(&techs);
            Ok((ev, norm_obj("sweep", &benches, &configs, &techs, body)))
        }
        Kind::Explore => {
            check_fields(
                body,
                &["bench", "benches", "configs", "techs", "cim", "rule",
                  "scale", "seed", "max_instructions", "replay_threads"],
            )?;
            let benches = match (body.get("bench"), body.get("benches")) {
                (Some(_), Some(_)) => {
                    return Err("pass either 'bench' or 'benches', not both"
                        .to_string())
                }
                (Some(v), None) => {
                    vec![v.as_str().ok_or("'bench' must be a string")?.to_string()]
                }
                (None, Some(v)) => str_list(v, "benches")?,
                (None, None) => {
                    return Err(
                        "explore needs 'bench' or 'benches'".to_string()
                    )
                }
            };
            for b in &benches {
                check_bench(b)?;
            }
            let configs = match body.get("configs") {
                Some(v) => str_list(v, "configs")?,
                None => vec!["c1".to_string(), "c2".to_string(), "c3".to_string()],
            };
            for c in &configs {
                check_preset(c)?;
            }
            // CLI default: every registered technology
            let techs = match body.get("techs") {
                Some(v) => parse_techs(v)?,
                None => Technology::all(),
            };
            let bench_refs: Vec<&str> =
                benches.iter().map(|s| s.as_str()).collect();
            let config_refs: Vec<&str> =
                configs.iter().map(|s| s.as_str()).collect();
            let mut ev = apply_common(base.clone(), body)?;
            if body.get("cim").is_none() {
                // CLI default: --cim both
                ev = ev.cim(CimLevels::Both);
            }
            let ev = ev.benches(&bench_refs).presets(&config_refs).techs(&techs);
            Ok((ev, norm_obj("explore", &benches, &configs, &techs, body)))
        }
        Kind::Plan => {
            check_fields(
                body,
                &["bench", "config", "tech", "cim", "rule", "scale", "seed",
                  "max_instructions", "replay_threads", "policy", "min_ops",
                  "min_net_pj", "plan_level"],
            )?;
            let bench = body
                .req("bench")
                .map_err(|_| {
                    "plan needs a 'bench' field (GET /list for the catalog)"
                        .to_string()
                })?
                .as_str()
                .ok_or("'bench' must be a string")?
                .to_string();
            check_bench(&bench)?;
            let config = match body.get("config") {
                Some(v) => v
                    .as_str()
                    .ok_or("'config' must be a preset name")?
                    .to_string(),
                None => "c1".to_string(),
            };
            check_preset(&config)?;
            let techs = match body.get("tech") {
                Some(v) => {
                    let s = v.as_str().ok_or("'tech' must be a string")?;
                    vec![parse_tech(s)?]
                }
                None => Vec::new(),
            };
            let mut ev = apply_common(base.clone(), body)?
                .bench(&bench)
                .preset(&config)
                .techs(&techs);
            if let Some(v) = body.get("policy") {
                let s = v.as_str().ok_or("'policy' must be a string")?;
                ev = ev.policy(
                    crate::planner::PlanPolicy::from_name(s)
                        .ok_or_else(|| {
                            crate::planner::unknown_policy_message(s)
                        })?,
                );
            }
            if let Some(v) = body.get("min_ops") {
                ev = ev.min_ops(v.as_u64().ok_or("'min_ops' must be a number")?);
            }
            if let Some(v) = body.get("min_net_pj") {
                ev = ev.min_net_pj(
                    v.as_f64().ok_or("'min_net_pj' must be a number")?,
                );
            }
            if let Some(v) = body.get("plan_level") {
                let s = v.as_str().ok_or("'plan_level' must be a string")?;
                ev = ev.plan_level(
                    CimLevels::from_name(s)
                        .ok_or_else(|| format!("unknown cim levels '{s}'"))?,
                );
            }
            let benches = vec![bench];
            let configs = vec![config];
            // the evaluate-style preimage plus the planner knobs: two plan
            // requests differing only in policy/knobs must not share a
            // leader (the plans differ even though the analysis agrees)
            let mut norm = norm_obj("plan", &benches, &configs, &techs, body);
            if let Json::Obj(m) = &mut norm {
                for k in ["policy", "min_ops", "min_net_pj", "plan_level"] {
                    m.insert(
                        k.to_string(),
                        body.get(k).cloned().unwrap_or(Json::Null),
                    );
                }
            }
            Ok((ev, norm))
        }
    }
}

/// Apply the request fields every evaluating endpoint shares.
fn apply_common(mut ev: Evaluation, body: &Json) -> Result<Evaluation, String> {
    if let Some(v) = body.get("scale") {
        ev = ev.scale(v.as_usize().ok_or("'scale' must be a number")?);
    }
    if let Some(v) = body.get("seed") {
        ev = ev.seed(v.as_u64().ok_or("'seed' must be a number")?);
    }
    if let Some(v) = body.get("max_instructions") {
        ev = ev
            .max_instructions(v.as_u64().ok_or("'max_instructions' must be a number")?);
    }
    if let Some(v) = body.get("replay_threads") {
        ev = ev.replay_threads(
            v.as_usize().ok_or("'replay_threads' must be a number")?,
        );
    }
    if let Some(v) = body.get("rule") {
        let s = v.as_str().ok_or("'rule' must be a string")?;
        ev = ev.rule(
            LocalityRule::from_name(s)
                .ok_or_else(|| format!("unknown locality rule '{s}'"))?,
        );
    }
    if let Some(v) = body.get("cim") {
        let s = v.as_str().ok_or("'cim' must be a string")?;
        ev = ev.cim(
            CimLevels::from_name(s)
                .ok_or_else(|| format!("unknown cim levels '{s}'"))?,
        );
    }
    Ok(ev)
}

/// The normalized request object: the effective selection lists plus the
/// raw optional fields (absent → `null`).  Its canonical dump is the
/// dedup key's preimage, so two requests that differ only in JSON
/// formatting or key order normalize to identical bytes.
/// `replay_threads` is deliberately absent: it never changes the response
/// bytes (like every cache key, the dedup key ignores pure tuning knobs),
/// so concurrent requests differing only there still share one leader.
fn norm_obj(
    endpoint: &str,
    benches: &[String],
    configs: &[String],
    techs: &[Technology],
    body: &Json,
) -> Json {
    let passthrough =
        |k: &str| body.get(k).cloned().unwrap_or(Json::Null);
    Json::obj(vec![
        ("endpoint", endpoint.into()),
        (
            "benches",
            Json::Arr(benches.iter().map(|b| Json::from(b.as_str())).collect()),
        ),
        (
            "configs",
            Json::Arr(configs.iter().map(|c| Json::from(c.as_str())).collect()),
        ),
        (
            "techs",
            Json::Arr(techs.iter().map(|t| Json::from(t.name())).collect()),
        ),
        ("cim", passthrough("cim")),
        ("rule", passthrough("rule")),
        ("scale", passthrough("scale")),
        ("seed", passthrough("seed")),
        ("max_instructions", passthrough("max_instructions")),
    ])
}

fn check_fields(body: &Json, allowed: &[&str]) -> Result<(), String> {
    match body {
        Json::Obj(m) => {
            for k in m.keys() {
                if !allowed.contains(&k.as_str()) {
                    return Err(format!(
                        "unknown field '{k}' (allowed: {})",
                        allowed.join(", ")
                    ));
                }
            }
            Ok(())
        }
        _ => Err("request body must be a JSON object".to_string()),
    }
}

fn check_bench(name: &str) -> Result<(), String> {
    if workloads::NAMES.contains(&name) {
        Ok(())
    } else {
        Err(format!("unknown benchmark '{name}' (GET /list for the catalog)"))
    }
}

fn check_preset(name: &str) -> Result<(), String> {
    if SystemConfig::preset(name).is_some() {
        Ok(())
    } else {
        Err(format!("unknown preset '{name}' (GET /list for the catalog)"))
    }
}

fn parse_tech(name: &str) -> Result<Technology, String> {
    Technology::from_name(name).ok_or_else(|| device::unknown_tech_message(name))
}

fn parse_techs(v: &Json) -> Result<Vec<Technology>, String> {
    let arr = v
        .as_arr()
        .ok_or("'techs' must be an array of technology names")?;
    arr.iter()
        .map(|x| {
            let s = x
                .as_str()
                .ok_or_else(|| "'techs' must be an array of technology names"
                    .to_string())?;
            parse_tech(s)
        })
        .collect()
}

fn str_list(v: &Json, field: &str) -> Result<Vec<String>, String> {
    let arr = v
        .as_arr()
        .ok_or_else(|| format!("'{field}' must be an array of strings"))?;
    arr.iter()
        .map(|x| {
            x.as_str()
                .map(str::to_string)
                .ok_or_else(|| format!("'{field}' must be an array of strings"))
        })
        .collect()
}

fn health_body() -> String {
    let mut s = Json::obj(vec![
        ("schema", 1u64.into()),
        ("service", "eva-cim".into()),
        ("status", "ok".into()),
    ])
    .dump();
    s.push('\n');
    s
}

/// The error envelope every non-200 response uses.
fn error_body(status: u16, message: &str) -> String {
    let mut s = Json::obj(vec![
        (
            "error",
            Json::obj(vec![
                ("code", (status as u64).into()),
                ("message", message.into()),
            ]),
        ),
        ("schema", 1u64.into()),
    ])
    .dump();
    s.push('\n');
    s
}

fn error_outcome(status: u16, message: &str) -> Outcome {
    Outcome {
        status,
        body: error_body(status, message),
        ledger: None,
        cache: None,
    }
}

fn error_response(status: u16, message: &str) -> http::Response {
    http::Response {
        status,
        body: error_body(status, message),
        cache: None,
        ledger: None,
    }
}

fn ok_response(body: String) -> http::Response {
    http::Response { status: 200, body, cache: None, ledger: None }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::BackendSel;
    use std::io::{Read, Write};

    fn raw_request(
        addr: &SocketAddr,
        method: &str,
        path: &str,
        body: &str,
    ) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        write!(
            s,
            "{method} {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        )
        .unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    fn test_opts() -> ServeOptions {
        ServeOptions {
            addr: "127.0.0.1:0".into(),
            http_workers: 2,
            queue: 8,
            base: Evaluation::new().scale(2).jobs(1).backend(BackendSel::Native),
            ..ServeOptions::default()
        }
    }

    #[test]
    fn inflight_followers_share_the_leaders_outcome() {
        let inflight = Inflight::new();
        let Role::Leader(slot) = inflight.join(7) else {
            panic!("first join must lead")
        };
        let Role::Follower(fslot) = inflight.join(7) else {
            panic!("second join must follow")
        };
        let waiter = std::thread::spawn(move || wait_outcome(&fslot));
        assert_eq!(slot.followers.load(Ordering::SeqCst), 1);
        inflight.publish(
            7,
            &slot,
            Outcome {
                status: 200,
                body: "shared-body".into(),
                ledger: None,
                cache: Some(CACHE_COMPUTED),
            },
        );
        let got = waiter.join().unwrap();
        assert_eq!(got.status, 200);
        assert_eq!(got.body, "shared-body");
        // the key is retired: the next identical request leads again
        assert!(matches!(inflight.join(7), Role::Leader(_)));
    }

    #[test]
    fn request_keys_ignore_json_formatting_and_key_order() {
        let base = Evaluation::new();
        let a = json::parse(r#"{"bench":"lcs","scale":2}"#).unwrap();
        let b = json::parse(r#"{ "scale" : 2, "bench" : "lcs" }"#).unwrap();
        let (_, na) = build_request(&base, Kind::Evaluate, &a).unwrap();
        let (_, nb) = build_request(&base, Kind::Evaluate, &b).unwrap();
        assert_eq!(na.dump(), nb.dump());
        // a different scale is a different key
        let c = json::parse(r#"{"bench":"lcs","scale":3}"#).unwrap();
        let (_, nc) = build_request(&base, Kind::Evaluate, &c).unwrap();
        assert_ne!(na.dump(), nc.dump());
    }

    #[test]
    fn bad_requests_are_client_errors() {
        let base = Evaluation::new();
        let no_bench = json::parse("{}").unwrap();
        assert!(build_request(&base, Kind::Evaluate, &no_bench).is_err());
        let typo = json::parse(r#"{"bench":"lcs","benchs":[]}"#).unwrap();
        let err = build_request(&base, Kind::Evaluate, &typo).unwrap_err();
        assert!(err.contains("unknown field 'benchs'"), "{err}");
        let bad_bench = json::parse(r#"{"bench":"no_such"}"#).unwrap();
        assert!(build_request(&base, Kind::Evaluate, &bad_bench)
            .unwrap_err()
            .contains("unknown benchmark"));
        let bad_tech =
            json::parse(r#"{"bench":"lcs","tech":"unobtanium"}"#).unwrap();
        assert!(build_request(&base, Kind::Evaluate, &bad_tech).is_err());
    }

    fn panicking_router(
        state: &Arc<ServeState>,
        req: &http::Request,
    ) -> http::Response {
        if req.path == "/boom" {
            panic!("injected handler failure");
        }
        route(state, req)
    }

    #[test]
    fn a_panicking_handler_returns_500_without_killing_the_server() {
        let server =
            Server::bind_with_router(test_opts(), panicking_router).unwrap();
        let addr = server.addr();
        let handle = server.spawn().unwrap();

        let resp = raw_request(&addr, "GET", "/boom", "");
        assert!(resp.starts_with("HTTP/1.1 500 "), "{resp}");
        assert!(resp.contains("\"error\""), "{resp}");
        assert!(resp.contains("injected handler failure"), "{resp}");

        // the worker pool survived: the next request is served normally
        let resp = raw_request(&addr, "GET", "/health", "");
        assert!(resp.starts_with("HTTP/1.1 200 "), "{resp}");
        assert!(resp.contains("\"status\":\"ok\""), "{resp}");
        handle.shutdown();
    }

    #[test]
    fn plan_endpoint_computes_caches_and_rejects_bad_policies() {
        let server = Server::bind(test_opts()).unwrap();
        let addr = server.addr();
        let handle = server.spawn().unwrap();

        // cold: the leader simulates and plans — computed, with the plan
        // counters riding on the ledger header
        let body = r#"{"bench":"lcs"}"#;
        let resp = raw_request(&addr, "POST", "/plan", body);
        assert!(resp.starts_with("HTTP/1.1 200 "), "{resp}");
        assert!(resp.contains("X-Eva-Cache: computed"), "{resp}");
        assert!(resp.contains("\"metric\":\"groups accepted\""), "{resp}");
        assert!(resp.contains("\"groups_accepted\""), "{resp}");
        assert!(resp.contains("\"groups_rejected\""), "{resp}");

        // warm: the identical request hits the plan memo — cached, and the
        // body is byte-identical
        let resp2 = raw_request(&addr, "POST", "/plan", body);
        assert!(resp2.contains("X-Eva-Cache: cached"), "{resp2}");
        let body_of = |r: &str| r.split("\r\n\r\n").nth(1).unwrap().to_string();
        assert_eq!(body_of(&resp), body_of(&resp2));

        // a different policy is a different plan key: computed again
        let resp3 = raw_request(
            &addr,
            "POST",
            "/plan",
            r#"{"bench":"lcs","policy":"profitability"}"#,
        );
        assert!(resp3.starts_with("HTTP/1.1 200 "), "{resp3}");
        assert!(resp3.contains("X-Eva-Cache: computed"), "{resp3}");

        // the cumulative ledger on /stats carries the plan counters
        let stats = raw_request(&addr, "GET", "/stats", "");
        assert!(stats.contains("\"counter\":\"groups_accepted\""), "{stats}");
        assert!(stats.contains("\"metric\":\"plan\""), "{stats}");

        // unknown policy: 400 envelope with the did-you-mean diagnostic
        let resp4 = raw_request(
            &addr,
            "POST",
            "/plan",
            r#"{"bench":"lcs","policy":"profitabilty"}"#,
        );
        assert!(resp4.starts_with("HTTP/1.1 400 "), "{resp4}");
        assert!(resp4.contains("did you mean 'profitability'"), "{resp4}");
        handle.shutdown();
    }

    #[test]
    fn health_list_stats_and_routing_errors() {
        let server = Server::bind(test_opts()).unwrap();
        let addr = server.addr();
        let handle = server.spawn().unwrap();

        let resp = raw_request(&addr, "GET", "/health", "");
        assert!(resp.contains("\"service\":\"eva-cim\""), "{resp}");

        let resp = raw_request(&addr, "GET", "/list", "");
        assert!(resp.starts_with("HTTP/1.1 200 "), "{resp}");
        assert!(resp.contains("\"title\":\"list\""), "{resp}");

        let resp = raw_request(&addr, "GET", "/stats", "");
        assert!(resp.contains("\"metric\":\"requests\""), "{resp}");
        assert!(resp.contains("\"counter\":\"simulator_runs\""), "{resp}");

        let resp = raw_request(&addr, "GET", "/evaluate", "");
        assert!(resp.starts_with("HTTP/1.1 405 "), "{resp}");
        let resp = raw_request(&addr, "POST", "/nope", "{}");
        assert!(resp.starts_with("HTTP/1.1 404 "), "{resp}");
        let resp = raw_request(&addr, "POST", "/evaluate", "{not json");
        assert!(resp.starts_with("HTTP/1.1 400 "), "{resp}");
        assert!(resp.contains("malformed JSON"), "{resp}");
        handle.shutdown();
    }
}
