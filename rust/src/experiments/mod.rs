//! Experiment harness: one function per table/figure of the paper's
//! evaluation (§VI), shared by the CLI (`eva-cim table <id>`), the bench
//! targets (`cargo bench`) and the examples.
//!
//! Since the API redesign every entry point is a thin adapter over
//! [`crate::api::Evaluation`]: the builder owns all sim/analyze/reshape/
//! energy wiring (and the coordinator's cached sweep path), the adapters
//! only select the grid and pivot the resulting rows into the paper's
//! table shapes.  Each returns a structured [`Report`], so every
//! table/figure renders as text, CSV or canonical JSON from one value.

use anyhow::Result;

use crate::analyzer::LocalityRule;
use crate::api::{report, validate, BackendSel, Cell, Evaluation, Report, Section};
use crate::config::{CimLevels, Technology};
use crate::coordinator::SweepOptions;
use crate::energy::calib::{OP_ADD, OP_AND, OP_OR, OP_READ, OP_XOR};
use crate::runtime::Backend;
use crate::workloads;

/// The 17 paper benchmarks in Table VI order.
pub fn paper_benches() -> Vec<&'static str> {
    workloads::NAMES.to_vec()
}

/// Table III: cache energy (pJ) per operation, both levels, for every
/// *registered* technology (the paper's SRAM/FeFET rows first, then the
/// RRAM/STT-MRAM presets and any TOML-defined customs).
pub fn table3() -> Report {
    let mut s = Section::new(
        "Table III — cache energy (pJ) per operation",
        &["tech", "level", "config", "non-CiM read", "CiM-OR", "CiM-AND", "CiM-XOR", "CiM-ADDW32"],
    );
    for r in validate::device_grid(&Technology::all()) {
        s.row(vec![
            Cell::str(r.tech.name().to_uppercase()),
            Cell::str(r.level),
            Cell::str(r.geometry),
            Cell::num(r.e[OP_READ], 0),
            Cell::num(r.e[OP_OR], 0),
            Cell::num(r.e[OP_AND], 0),
            Cell::num(r.e[OP_XOR], 0),
            Cell::num(r.e[OP_ADD], 0),
        ]);
    }
    Report::new("table3").with_section(s)
}

/// Fig 11: access latency (cycles) of non-CiM and CiM operations.
pub fn fig11() -> Report {
    let mut s = Section::new(
        "Fig 11 — access latency (cycles) of non-CiM and CiM operations @1GHz",
        &["tech", "level", "read", "or", "and", "xor", "add"],
    );
    for r in validate::device_grid(&Technology::all()) {
        s.row(vec![
            Cell::str(r.tech.name().to_uppercase()),
            Cell::str(r.level),
            Cell::num(r.lat[OP_READ], 1),
            Cell::num(r.lat[OP_OR], 1),
            Cell::num(r.lat[OP_AND], 1),
            Cell::num(r.lat[OP_XOR], 1),
            Cell::num(r.lat[OP_ADD], 1),
        ]);
    }
    Report::new("fig11").with_section(s)
}

/// Table V: Eva-CiM vs array-level-only (DESTINY) energy on an LCS trace
/// (adapter over [`validate::destiny_comparison`]).
pub fn table5(backend: &mut dyn Backend, scale: usize) -> Result<Report> {
    validate::destiny_comparison(backend, scale)
}

/// Fig 12: CiM-supported memory-access fraction, Eva-CiM vs Jain [23]
/// (adapter over [`validate::macr_comparison`]).
pub fn fig12(runs: usize, scale: usize) -> Result<Report> {
    validate::macr_comparison(runs, scale)
}

/// Fig 13: MACR per benchmark with L1/other breakdown.
pub fn fig13(opts: SweepOptions) -> Result<Report> {
    let sweep = Evaluation::new()
        .preset("c1")
        .sweep(opts)
        .backend(BackendSel::Native)
        .rows()?;
    let mut s = Section::new(
        "Fig 13 — MACR per benchmark (top) and L1/other breakdown (bottom)",
        &["bench", "MACR", "L1 share", "other share", "accesses", "convertible"],
    );
    for r in &sweep.rows {
        s.row(vec![
            Cell::str(workloads::display_name(&r.bench)),
            Cell::pct(r.macr.ratio(), 1),
            Cell::pct(r.macr.l1_share(), 1),
            Cell::pct(1.0 - r.macr.l1_share(), 1),
            Cell::int(r.macr.total_accesses),
            Cell::int(r.macr.convertible),
        ]);
    }
    Ok(Report::new("fig13")
        .with_section(s)
        .with_ledger(sweep.stats, sweep.elapsed_secs, sweep.backend))
}

/// Table VI: speedup, energy improvement, processor/cache breakdown.
pub fn table6(opts: SweepOptions, backend: &mut dyn Backend) -> Result<Report> {
    let sweep = Evaluation::new().preset("c1").sweep(opts).rows_with(backend)?;
    let mut s = Section::new(
        "Table VI — speedup, energy improvement, improvement breakdown (CiM vs non-CiM)",
        &["bench", "speedup", "energy impr.", "ratio proc", "ratio caches", "MACR"],
    );
    for r in &sweep.rows {
        s.row(vec![
            Cell::str(workloads::display_name(&r.bench)),
            Cell::num(r.result.speedup, 2),
            Cell::num(r.result.improvement, 2),
            Cell::num(r.result.ratio_proc, 2),
            Cell::num(r.result.ratio_cache, 2),
            Cell::pct(r.macr.ratio(), 1),
        ]);
    }
    Ok(Report::new("table6")
        .with_section(s)
        .with_ledger(sweep.stats, sweep.elapsed_secs, sweep.backend))
}

/// Fig 14: energy improvement across the three cache configurations.
pub fn fig14(opts: SweepOptions, backend: &mut dyn Backend) -> Result<Report> {
    let sweep = Evaluation::new()
        .presets(&["c1", "c2", "c3"])
        .sweep(opts)
        .rows_with(backend)?;
    let s = report::pivot(
        "Fig 14 — energy improvement for CiM with different cache configurations",
        &paper_benches(),
        &sweep.rows,
        &[("c1 (32k/256k)", "c1"), ("c2 (64k/256k)", "c2"), ("c3 (64k/2M)", "c3")],
        |r| Cell::num(r.result.improvement, 2),
    );
    Ok(Report::new("fig14")
        .with_section(s)
        .with_ledger(sweep.stats, sweep.elapsed_secs, sweep.backend))
}

/// Fig 15: energy improvement with CiM in L1-only / L2-only / both.
pub fn fig15(opts: SweepOptions, backend: &mut dyn Backend) -> Result<Report> {
    let sweep = Evaluation::new()
        .preset("c1")
        .cim_variants(&[CimLevels::L1Only, CimLevels::L2Only, CimLevels::Both])
        .sweep(opts)
        .rows_with(backend)?;
    let s = report::pivot(
        "Fig 15 — energy improvement: CiM in L1 only, L2 only, both",
        &paper_benches(),
        &sweep.rows,
        &[("L1 only", "c1-l1"), ("L2 only", "c1-l2"), ("L1+L2", "c1-l1+l2")],
        |r| Cell::num(r.result.improvement, 2),
    );
    Ok(Report::new("fig15")
        .with_section(s)
        .with_ledger(sweep.stats, sweep.elapsed_secs, sweep.backend))
}

/// Fig 16: SRAM vs FeFET — energy improvement and speedup.
///
/// As in the paper, FeFET improvements are normalized to the *SRAM*
/// non-CiM baseline system.
pub fn fig16(opts: SweepOptions, backend: &mut dyn Backend) -> Result<Report> {
    let sweep = Evaluation::new()
        .preset("c1")
        .techs(&[Technology::SRAM, Technology::FEFET])
        .sweep(opts)
        .rows_with(backend)?;
    let mut s = Section::new(
        "Fig 16 — CMOS SRAM vs FeFET-RAM (energy improvement normalized to the SRAM baseline)",
        &["bench", "E-impr SRAM", "E-impr FeFET", "FeFET/SRAM", "speedup SRAM", "speedup FeFET"],
    );
    for b in paper_benches() {
        let find = |t: Technology| sweep.rows.iter().find(|r| r.bench == b && r.tech == t);
        if let (Some(sr), Some(fe)) = (find(Technology::SRAM), find(Technology::FEFET)) {
            // normalize FeFET's CiM energy to the SRAM baseline
            let fefet_norm = sr.result.total_base / fe.result.total_cim.max(1e-9);
            s.row(vec![
                Cell::str(workloads::display_name(b)),
                Cell::num(sr.result.improvement, 2),
                Cell::num(fefet_norm, 2),
                Cell::num(fefet_norm / sr.result.improvement.max(1e-9), 2),
                Cell::num(sr.result.speedup, 2),
                Cell::num(fe.result.speedup, 2),
            ]);
        }
    }
    Ok(Report::new("fig16")
        .with_section(s)
        .with_ledger(sweep.stats, sweep.elapsed_secs, sweep.backend))
}

/// Cross-technology design-space exploration (the generalization of
/// Figs 14–16): sweep `techs` × `presets` for each benchmark and rank the
/// results by Pareto dominance on (energy improvement, speedup) — adapter
/// over [`Evaluation::explore`], which documents the report shape.
pub fn explore(
    benches: &[&str],
    techs: &[Technology],
    presets: &[&str],
    cim: CimLevels,
    rule: LocalityRule,
    opts: SweepOptions,
    backend: &mut dyn Backend,
) -> Result<Report> {
    Evaluation::new()
        .benches(benches)
        .techs(techs)
        .presets(presets)
        .cim(cim)
        .rule(rule)
        .sweep(opts)
        .explore_with(backend)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;

    fn fast_opts() -> SweepOptions {
        SweepOptions { scale: 2, workers: 2, ..Default::default() }
    }

    #[test]
    fn table3_matches_published_anchor_values() {
        let s = table3().render();
        // spot-check the exact Table III numbers
        for v in ["61", "79", "314", "365", "34", "205"] {
            assert!(s.contains(v), "missing {v} in:\n{s}");
        }
    }

    #[test]
    fn fig11_add_is_slower_than_read() {
        let s = fig11().render();
        assert!(s.contains("6.0")); // SRAM L1 CiM-ADD
        assert!(s.contains("2.0")); // SRAM L1 read
    }

    #[test]
    fn fig12_eva_finds_more_than_jain() {
        let t = fig12(3, 2).unwrap();
        let s = t.render_csv();
        let lines: Vec<&str> = s.lines().collect();
        let parse_pct = |row: &str| -> f64 {
            row.split(',').nth(1).unwrap().trim_end_matches('%').parse().unwrap()
        };
        let eva = parse_pct(lines[1]);
        let jain = parse_pct(lines[2]);
        assert!(eva > jain, "eva {eva}% !> jain {jain}%");
    }

    #[test]
    fn table6_produces_all_17_rows() {
        let t = table6(fast_opts(), &mut NativeBackend).unwrap();
        assert_eq!(t.sections[0].num_rows(), 17);
    }

    #[test]
    fn explore_covers_the_tech_config_grid_and_marks_a_frontier() {
        let techs = [
            Technology::SRAM,
            Technology::FEFET,
            Technology::RRAM,
            Technology::STT_MRAM,
        ];
        let out = explore(
            &["lcs"],
            &techs,
            &["c1", "c2", "c3"],
            CimLevels::Both,
            LocalityRule::AnyCache,
            fast_opts(),
            &mut NativeBackend,
        )
        .unwrap();
        let (grid, frontier) = (&out.sections[0], &out.sections[1]);
        assert_eq!(grid.num_rows(), 12, "4 techs x 3 configs");
        assert!(frontier.num_rows() >= 1 && frontier.num_rows() <= 12);
        // grid frontier marks agree with the frontier section
        let marked = grid
            .rows
            .iter()
            .filter(|r| matches!(r.last(), Some(crate::api::Cell::Mark(true))))
            .count();
        assert_eq!(marked, frontier.num_rows());
        // every frontier row names a swept tech and preset
        for i in 0..frontier.num_rows() {
            let tech = match frontier.cell(i, "tech") {
                Some(crate::api::Cell::Str(t)) => t.clone(),
                other => panic!("tech cell: {other:?}"),
            };
            assert!(techs.iter().any(|t| t.name() == tech));
            let preset = match frontier.cell(i, "config") {
                Some(crate::api::Cell::Str(p)) => p.clone(),
                other => panic!("config cell: {other:?}"),
            };
            assert!(["c1", "c2", "c3"].contains(&preset.as_str()));
        }
    }

    #[test]
    fn table6_regenerates_identically_through_the_cache() {
        let dir = std::env::temp_dir()
            .join(format!("eva-cim-exp-cache-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let opts = SweepOptions {
            cache_dir: Some(dir.clone()),
            resume: true,
            ..fast_opts()
        };
        let cold = table6(opts.clone(), &mut NativeBackend).unwrap();
        let warm = table6(opts, &mut NativeBackend).unwrap();
        // one source of truth: every rendering is byte-identical
        assert_eq!(cold.render_json(), warm.render_json());
        assert_eq!(cold.render_csv(), warm.render_csv());
        std::fs::remove_dir_all(&dir).ok();
    }
}
