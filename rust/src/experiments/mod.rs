//! Experiment harness: one function per table/figure of the paper's
//! evaluation (§VI).  Shared by the CLI (`eva-cim table <id>`), the bench
//! targets (`cargo bench`) and the examples — DESIGN.md §4 maps each
//! experiment to its bench target.

use anyhow::Result;

use crate::analyzer::{self, baseline, LocalityRule};
use crate::config::{CimLevels, SystemConfig, Technology};
use crate::coordinator::{
    cross, format_stats, Coordinator, SweepOptions, SweepPoint, SweepRow,
};
use crate::energy::{self, calib::*};
use crate::profiler::ProfileInputs;
use crate::reshape;
use crate::runtime::Backend;
use crate::sim::{simulate, Limits};
use crate::util::stats;
use crate::util::table::{f, TextTable};
use crate::workloads;

/// The 17 paper benchmarks in Table VI order.
pub fn paper_benches() -> Vec<&'static str> {
    workloads::NAMES.to_vec()
}

/// Table III: cache energy (pJ) per operation, both levels, for every
/// *registered* technology (the paper's SRAM/FeFET rows first, then the
/// RRAM/STT-MRAM presets and any TOML-defined customs).
pub fn table3() -> TextTable {
    let mut t = TextTable::new(
        "Table III — cache energy (pJ) per operation",
        &["tech", "level", "config", "non-CiM read", "CiM-OR", "CiM-AND", "CiM-XOR", "CiM-ADDW32"],
    );
    for tech in Technology::all() {
        for (level, cap_kb, assoc) in [("L1", 64.0, 4.0), ("L2", 256.0, 8.0)] {
            let row = [cap_kb * 1024.0, assoc, 64.0, 4.0, tech.index() as f64,
                       if level == "L1" { 1.0 } else { 2.0 }];
            let (e, _) = energy::energy_latency(&row);
            t.row(vec![
                tech.name().to_uppercase(),
                level.into(),
                format!("{}-way/{}kB", assoc as u32, cap_kb as u32),
                f(e[OP_READ], 0),
                f(e[OP_OR], 0),
                f(e[OP_AND], 0),
                f(e[OP_XOR], 0),
                f(e[OP_ADD], 0),
            ]);
        }
    }
    t
}

/// Fig 11: access latency (cycles) of non-CiM and CiM operations.
pub fn fig11() -> TextTable {
    let mut t = TextTable::new(
        "Fig 11 — access latency (cycles) of non-CiM and CiM operations @1GHz",
        &["tech", "level", "read", "or", "and", "xor", "add"],
    );
    for tech in Technology::all() {
        for (level, cap_kb, assoc, lv) in [("L1", 64.0, 4.0, 1.0), ("L2", 256.0, 8.0, 2.0)] {
            let row = [cap_kb * 1024.0, assoc, 64.0, 4.0, tech.index() as f64, lv];
            let (_, l) = energy::energy_latency(&row);
            t.row(vec![
                tech.name().to_uppercase(),
                level.into(),
                f(l[OP_READ], 1),
                f(l[OP_OR], 1),
                f(l[OP_AND], 1),
                f(l[OP_XOR], 1),
                f(l[OP_ADD], 1),
            ]);
        }
    }
    t
}

/// Table V: Eva-CiM vs array-level-only (DESTINY) energy on an LCS trace.
///
/// The paper reports ≈24% deviation for both CiM and non-CiM instructions:
/// Eva-CiM adds the multi-level-hierarchy effects (misses, refills, core
/// interactions) that the array-only estimate omits.
pub fn table5(backend: &mut dyn Backend, scale: usize) -> Result<TextTable> {
    let cfg = SystemConfig::preset("c1").unwrap();
    let prog = workloads::build("lcs", scale, 42).unwrap();
    let trace = simulate(&prog, &cfg, Limits::default())?;
    let analysis = analyzer::analyze(&trace, &cfg, LocalityRule::AnyCache);
    let reshaped = reshape::reshape(&trace, &analysis.selection, &cfg);
    let inputs = ProfileInputs::new(&cfg, &reshaped);
    let res = backend.evaluate_batch(&[inputs.clone()])?.remove(0);

    // Eva-CiM's memory-side energy split into CiM vs non-CiM portions.
    // The CiM share includes the hierarchy's data-locality management:
    // cross-level operand moves and result readbacks (§IV-C) — exactly the
    // effects the array-only estimate cannot see.
    let (e1, _) = energy::energy_latency(&inputs.cfg_l1);
    let (e2, _) = energy::energy_latency(&inputs.cfg_l2);
    let mut overhead = 0.0;
    for c in &analysis.selection.candidates {
        let (rd_src, wr_dst, rd_back) = match c.level {
            crate::probes::MemLevel::L2 => (e1[OP_READ], e2[OP_WRITE], e2[OP_READ]),
            _ => (e2[OP_READ], e1[OP_WRITE], e1[OP_READ]),
        };
        overhead += c.moves as f64 * (rd_src + wr_dst);
        overhead += c.readbacks as f64 * rd_back;
        // rereads of operands shared with earlier candidates
        overhead += c.shared_loads.len() as f64 * rd_back;
    }
    let eva_cim = (res.comps_cim[COMP_CIM_L1] + res.comps_cim[COMP_CIM_L2]
        + overhead) / 1000.0;
    // compare at *array* level (÷ XBUS_FACTOR): DESTINY models the array
    // only, so the H-tree/bus transport must be excluded on both sides —
    // the remaining deviation is the hierarchy-event accounting (misses,
    // refills, I-fetch traffic) that Eva-CiM adds on top of DESTINY.
    let eva_non = (res.comps_cim[COMP_L1I] + res.comps_cim[COMP_L1D]
        + res.comps_cim[COMP_L2]) / XBUS_FACTOR / 1000.0;
    // array-only (DESTINY-style) estimate of the same reshaped activity
    let (d_cim, d_non) = energy::destiny_only_estimate(
        &inputs.counters_cim, &inputs.cfg_l1, &inputs.cfg_l2);
    let (d_cim, d_non) = (d_cim / 1000.0, d_non / 1000.0);

    let mut t = TextTable::new(
        "Table V — energy (nJ) comparison: array-only (DESTINY) vs Eva-CiM (LCS trace)",
        &["model", "CiM", "non-CiM"],
    );
    t.row(vec!["DESTINY (array-only)".into(), f(d_cim, 2), f(d_non, 2)]);
    t.row(vec!["Eva-CiM".into(), f(eva_cim, 2), f(eva_non, 2)]);
    t.row(vec![
        "Deviation".into(),
        format!("{:.1}%", stats::rel_dev(eva_cim, d_cim) * 100.0),
        format!("{:.1}%", stats::rel_dev(eva_non, d_non) * 100.0),
    ]);
    Ok(t)
}

/// Fig 12: CiM-supported memory-access fraction, Eva-CiM vs Jain [23],
/// LCS over `runs` random inputs on the 1 MB SPM-like config.
pub fn fig12(runs: usize, scale: usize) -> Result<TextTable> {
    let cfg = SystemConfig::preset("spm1mb").unwrap();
    let mut eva = Vec::new();
    let mut jain = Vec::new();
    for r in 0..runs {
        let prog = workloads::build("lcs", scale, 1000 + r as u64).unwrap();
        let trace = simulate(&prog, &cfg, Limits::default())?;
        let analysis = analyzer::analyze(&trace, &cfg, LocalityRule::AnyCache);
        eva.push(analysis.macr.ratio());
        jain.push(baseline::classify(&trace.ciq).cim_fraction());
    }
    let mut t = TextTable::new(
        &format!("Fig 12 — CiM-supported memory accesses on LCS ({runs} runs, 1MB config)"),
        &["method", "mean", "min", "max"],
    );
    for (name, xs) in [("Eva-CiM (IDG)", &eva), ("Jain et al. [23]", &jain)] {
        t.row(vec![
            name.into(),
            format!("{:.1}%", stats::mean(xs) * 100.0),
            format!("{:.1}%", stats::percentile(xs, 0.0) * 100.0),
            format!("{:.1}%", stats::percentile(xs, 100.0) * 100.0),
        ]);
    }
    Ok(t)
}

/// Shared sweep driver for Figs 13–16 / Table VI.  Every experiment goes
/// through the coordinator's cached path: set `opts.cache_dir` (CLI:
/// `--cache-dir`, with `--resume`) and regenerating one figure warms the
/// result + trace caches for all the others that share design points.
fn run_paper_sweep(
    configs: &[SystemConfig],
    opts: SweepOptions,
    backend: &mut dyn Backend,
) -> Result<Vec<SweepRow>> {
    let benches = paper_benches();
    let points: Vec<SweepPoint> = cross(&benches, configs, LocalityRule::AnyCache);
    let t0 = std::time::Instant::now();
    let (rows, stats) =
        Coordinator::new(opts).run_sweep_with_stats(&points, backend)?;
    // cache-effectiveness + scale ledger for `eva-cim table <id>` runs
    eprintln!("{}", format_stats(&stats, t0.elapsed().as_secs_f64()));
    Ok(rows)
}

/// Fig 13: MACR per benchmark with L1/other breakdown.
pub fn fig13(opts: SweepOptions) -> Result<TextTable> {
    let cfg = SystemConfig::preset("c1").unwrap();
    let mut backend = crate::runtime::NativeBackend;
    let rows = run_paper_sweep(&[cfg], opts, &mut backend)?;
    let mut t = TextTable::new(
        "Fig 13 — MACR per benchmark (top) and L1/other breakdown (bottom)",
        &["bench", "MACR", "L1 share", "other share", "accesses", "convertible"],
    );
    for r in &rows {
        t.row(vec![
            workloads::display_name(&r.bench).into(),
            format!("{:.1}%", r.macr.ratio() * 100.0),
            format!("{:.1}%", r.macr.l1_share() * 100.0),
            format!("{:.1}%", (1.0 - r.macr.l1_share()) * 100.0),
            format!("{}", r.macr.total_accesses),
            format!("{}", r.macr.convertible),
        ]);
    }
    Ok(t)
}

/// Table VI: speedup, energy improvement, processor/cache breakdown.
pub fn table6(opts: SweepOptions, backend: &mut dyn Backend) -> Result<TextTable> {
    let cfg = SystemConfig::preset("c1").unwrap();
    let rows = run_paper_sweep(&[cfg], opts, backend)?;
    let mut t = TextTable::new(
        "Table VI — speedup, energy improvement, improvement breakdown (CiM vs non-CiM)",
        &["bench", "speedup", "energy impr.", "ratio proc", "ratio caches", "MACR"],
    );
    for r in &rows {
        t.row(vec![
            workloads::display_name(&r.bench).into(),
            f(r.result.speedup, 2),
            f(r.result.improvement, 2),
            f(r.result.ratio_proc, 2),
            f(r.result.ratio_cache, 2),
            format!("{:.1}%", r.macr.ratio() * 100.0),
        ]);
    }
    Ok(t)
}

/// Fig 14: energy improvement across the three cache configurations.
pub fn fig14(opts: SweepOptions, backend: &mut dyn Backend) -> Result<TextTable> {
    let configs = [
        SystemConfig::preset("c1").unwrap(),
        SystemConfig::preset("c2").unwrap(),
        SystemConfig::preset("c3").unwrap(),
    ];
    let rows = run_paper_sweep(&configs, opts, backend)?;
    let mut t = TextTable::new(
        "Fig 14 — energy improvement for CiM with different cache configurations",
        &["bench", "c1 (32k/256k)", "c2 (64k/256k)", "c3 (64k/2M)"],
    );
    for b in paper_benches() {
        let get = |cn: &str| {
            rows.iter()
                .find(|r| r.bench == b && r.config_name == cn)
                .map(|r| f(r.result.improvement, 2))
                .unwrap_or_default()
        };
        t.row(vec![
            workloads::display_name(b).into(),
            get("c1"),
            get("c2"),
            get("c3"),
        ]);
    }
    Ok(t)
}

/// Fig 15: energy improvement with CiM in L1-only / L2-only / both.
pub fn fig15(opts: SweepOptions, backend: &mut dyn Backend) -> Result<TextTable> {
    let base = SystemConfig::preset("c1").unwrap();
    let configs: Vec<SystemConfig> = [CimLevels::L1Only, CimLevels::L2Only, CimLevels::Both]
        .into_iter()
        .map(|cl| {
            let mut c = base.clone().with_cim(cl);
            c.name = format!("c1-{}", cl.name());
            c
        })
        .collect();
    let rows = run_paper_sweep(&configs, opts, backend)?;
    let mut t = TextTable::new(
        "Fig 15 — energy improvement: CiM in L1 only, L2 only, both",
        &["bench", "L1 only", "L2 only", "L1+L2"],
    );
    for b in paper_benches() {
        let get = |cn: &str| {
            rows.iter()
                .find(|r| r.bench == b && r.config_name == cn)
                .map(|r| f(r.result.improvement, 2))
                .unwrap_or_default()
        };
        t.row(vec![
            workloads::display_name(b).into(),
            get("c1-l1"),
            get("c1-l2"),
            get("c1-l1+l2"),
        ]);
    }
    Ok(t)
}

/// Fig 16: SRAM vs FeFET — energy improvement and speedup.
///
/// As in the paper, FeFET improvements are normalized to the *SRAM*
/// non-CiM baseline system.
pub fn fig16(opts: SweepOptions, backend: &mut dyn Backend) -> Result<TextTable> {
    let configs: Vec<SystemConfig> = [Technology::SRAM, Technology::FEFET]
        .into_iter()
        .map(|tech| {
            let mut c = SystemConfig::preset("c1").unwrap().with_tech(tech);
            c.name = format!("c1-{}", tech.name());
            c
        })
        .collect();
    let rows = run_paper_sweep(&configs, opts, backend)?;
    let mut t = TextTable::new(
        "Fig 16 — CMOS SRAM vs FeFET-RAM (energy improvement normalized to the SRAM baseline)",
        &["bench", "E-impr SRAM", "E-impr FeFET", "FeFET/SRAM", "speedup SRAM", "speedup FeFET"],
    );
    for b in paper_benches() {
        let sram = rows
            .iter()
            .find(|r| r.bench == b && r.tech == Technology::SRAM);
        let fefet = rows
            .iter()
            .find(|r| r.bench == b && r.tech == Technology::FEFET);
        if let (Some(s), Some(fe)) = (sram, fefet) {
            // normalize FeFET's CiM energy to the SRAM baseline
            let fefet_norm = s.result.total_base / fe.result.total_cim.max(1e-9);
            t.row(vec![
                workloads::display_name(b).into(),
                f(s.result.improvement, 2),
                f(fefet_norm, 2),
                f(fefet_norm / s.result.improvement.max(1e-9), 2),
                f(s.result.speedup, 2),
                f(fe.result.speedup, 2),
            ]);
        }
    }
    Ok(t)
}

/// Output of [`explore`]: the full tech×config grid plus its Pareto
/// frontier, per benchmark.
pub struct ExploreOutcome {
    /// every evaluated design point, frontier members marked `*`
    pub grid: TextTable,
    /// the non-dominated (energy improvement, speedup) points only
    pub frontier: TextTable,
    /// `(bench, tech, config)` of each frontier member, grid order
    pub frontier_points: Vec<(String, Technology, String)>,
}

/// Cross-technology design-space exploration (the generalization of
/// Figs 14–16): sweep `techs` × `presets` for each benchmark and rank the
/// results by Pareto dominance on (energy improvement, speedup) — both
/// normalized to the design point's own non-CiM baseline, so frontier
/// membership answers "which device+geometry should I build for this
/// workload?".  All points go through the coordinator's cached path like
/// every other experiment.
pub fn explore(
    benches: &[&str],
    techs: &[Technology],
    presets: &[&str],
    cim: CimLevels,
    rule: LocalityRule,
    opts: SweepOptions,
    backend: &mut dyn Backend,
) -> Result<ExploreOutcome> {
    let mut configs = Vec::new();
    for preset in presets {
        let base = SystemConfig::preset(preset)
            .ok_or_else(|| anyhow::anyhow!("unknown preset '{preset}'"))?;
        for &tech in techs {
            let mut c = base.clone().with_tech(tech).with_cim(cim);
            c.name = format!("{preset}-{}", tech.name());
            configs.push(c);
        }
    }
    let points: Vec<SweepPoint> = cross(benches, &configs, rule);
    let t0 = std::time::Instant::now();
    let (rows, sweep_stats) =
        Coordinator::new(opts).run_sweep_with_stats(&points, backend)?;
    eprintln!("{}", format_stats(&sweep_stats, t0.elapsed().as_secs_f64()));

    let mut grid = TextTable::new(
        &format!(
            "explore — {} tech × {} config Pareto grid (* = frontier)",
            techs.len(),
            presets.len()
        ),
        &["bench", "tech", "config", "MACR", "E-impr", "speedup", "Pareto"],
    );
    let mut frontier = TextTable::new(
        "explore — Pareto frontier (non-dominated on E-impr × speedup)",
        &["bench", "tech", "config", "E-impr", "speedup"],
    );
    let mut frontier_points = Vec::new();
    for b in benches {
        let bench_rows: Vec<&SweepRow> =
            rows.iter().filter(|r| r.bench == *b).collect();
        let scores: Vec<(f64, f64)> = bench_rows
            .iter()
            .map(|r| (r.result.improvement, r.result.speedup))
            .collect();
        let on_front = stats::pareto_front(&scores);
        for (r, &front) in bench_rows.iter().zip(&on_front) {
            let preset = r
                .config_name
                .split('-')
                .next()
                .unwrap_or(&r.config_name)
                .to_string();
            grid.row(vec![
                workloads::display_name(&r.bench).into(),
                r.tech.name().into(),
                preset.clone(),
                format!("{:.1}%", r.macr.ratio() * 100.0),
                f(r.result.improvement, 2),
                f(r.result.speedup, 2),
                if front { "*".into() } else { String::new() },
            ]);
            if front {
                frontier.row(vec![
                    workloads::display_name(&r.bench).into(),
                    r.tech.name().into(),
                    preset.clone(),
                    f(r.result.improvement, 2),
                    f(r.result.speedup, 2),
                ]);
                frontier_points.push((r.bench.clone(), r.tech, preset));
            }
        }
    }
    Ok(ExploreOutcome { grid, frontier, frontier_points })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;

    fn fast_opts() -> SweepOptions {
        SweepOptions { scale: 2, workers: 2, ..Default::default() }
    }

    #[test]
    fn table3_matches_published_anchor_values() {
        let t = table3();
        let s = t.render();
        // spot-check the exact Table III numbers
        for v in ["61", "79", "314", "365", "34", "205"] {
            assert!(s.contains(v), "missing {v} in:\n{s}");
        }
    }

    #[test]
    fn fig11_add_is_slower_than_read() {
        let s = fig11().render();
        assert!(s.contains("6.0")); // SRAM L1 CiM-ADD
        assert!(s.contains("2.0")); // SRAM L1 read
    }

    #[test]
    fn fig12_eva_finds_more_than_jain() {
        let t = fig12(3, 2).unwrap();
        let s = t.to_csv();
        let lines: Vec<&str> = s.lines().collect();
        let parse_pct = |row: &str| -> f64 {
            row.split(',').nth(1).unwrap().trim_end_matches('%').parse().unwrap()
        };
        let eva = parse_pct(lines[1]);
        let jain = parse_pct(lines[2]);
        assert!(eva > jain, "eva {eva}% !> jain {jain}%");
    }

    #[test]
    fn table6_produces_all_17_rows() {
        let t = table6(fast_opts(), &mut NativeBackend).unwrap();
        assert_eq!(t.num_rows(), 17);
    }

    #[test]
    fn explore_covers_the_tech_config_grid_and_marks_a_frontier() {
        let techs = [
            Technology::SRAM,
            Technology::FEFET,
            Technology::RRAM,
            Technology::STT_MRAM,
        ];
        let out = explore(
            &["lcs"],
            &techs,
            &["c1", "c2", "c3"],
            CimLevels::Both,
            LocalityRule::AnyCache,
            fast_opts(),
            &mut NativeBackend,
        )
        .unwrap();
        assert_eq!(out.grid.num_rows(), 12, "4 techs x 3 configs");
        assert!(!out.frontier_points.is_empty());
        assert!(out.frontier_points.len() <= 12);
        // every frontier row names a swept tech and preset
        for (bench, tech, preset) in &out.frontier_points {
            assert_eq!(bench, "lcs");
            assert!(techs.contains(tech));
            assert!(["c1", "c2", "c3"].contains(&preset.as_str()));
        }
        // the frontier table mirrors frontier_points
        assert_eq!(out.frontier.num_rows(), out.frontier_points.len());
    }

    #[test]
    fn table6_regenerates_identically_through_the_cache() {
        let dir = std::env::temp_dir()
            .join(format!("eva-cim-exp-cache-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let opts = SweepOptions {
            cache_dir: Some(dir.clone()),
            resume: true,
            ..fast_opts()
        };
        let cold = table6(opts.clone(), &mut NativeBackend).unwrap();
        let warm = table6(opts, &mut NativeBackend).unwrap();
        assert_eq!(cold.to_csv(), warm.to_csv());
        std::fs::remove_dir_all(&dir).ok();
    }
}
