//! Set-associative cache model with LRU replacement, write-back +
//! write-allocate policy, MSHR merging, and bank mapping.
//!
//! This is the AccessProbe's view of the world: every access reports which
//! level serviced it, the bank the line lives in, and whether the request
//! merged into an outstanding miss — exactly the locality information the
//! IDG analyzer needs (paper §IV-A: "the data of an offloading candidate
//! need to be in the same memory bank").

use crate::config::CacheConfig;
use crate::probes::{MemAccessInfo, MemLevel, MemStats};

#[derive(Clone, Copy, Debug, Default)]
struct Line {
    tag: u32,
    valid: bool,
    dirty: bool,
    /// last-use stamp for LRU
    lru: u64,
}

/// One cache level.
pub struct Cache {
    sets: u32,
    ways: u32,
    line_shift: u32,
    banks: u32,
    /// access latency of this level in cycles
    pub latency: u64,
    lines: Vec<Line>,
    use_stamp: u64,
    mshr: Vec<(u32, u64)>, // (line address, ready tick)
    mshr_entries: usize,
}

/// Outcome of a single-level probe.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LevelOutcome {
    /// the line was resident in this level
    pub hit: bool,
    /// dirty line evicted (must be written back to the level below)
    pub writeback: Option<u32>,
    /// bank the accessed line maps to
    pub bank: u32,
    /// request was merged into an outstanding MSHR for the same line
    pub mshr_merged: bool,
}

impl Cache {
    /// A cache level shaped by `cfg` (capacity/assoc/line/banks/latency).
    pub fn new(cfg: &CacheConfig) -> Self {
        let sets = cfg.sets();
        assert!(sets.is_power_of_two(), "sets must be a power of two");
        Self {
            sets,
            ways: cfg.assoc,
            line_shift: cfg.line.trailing_zeros(),
            banks: cfg.banks,
            latency: cfg.latency,
            lines: vec![Line::default(); (sets * cfg.assoc) as usize],
            use_stamp: 0,
            mshr: Vec::new(),
            mshr_entries: cfg.mshr_entries,
        }
    }

    /// Line address (byte address with the line-offset bits dropped).
    #[inline]
    pub fn line_addr(&self, addr: u32) -> u32 {
        addr >> self.line_shift
    }

    #[inline]
    fn set_of(&self, line_addr: u32) -> u32 {
        line_addr & (self.sets - 1)
    }

    #[inline]
    fn tag_of(&self, line_addr: u32) -> u32 {
        line_addr >> self.sets.trailing_zeros()
    }

    /// Bank a line maps to (line interleaving across banks).
    #[inline]
    pub fn bank_of(&self, addr: u32) -> u32 {
        self.line_addr(addr) & (self.banks - 1)
    }

    /// Probe and update on an access; fills the line on a miss.
    pub fn access(&mut self, addr: u32, is_write: bool, now: u64) -> LevelOutcome {
        let la = self.line_addr(addr);
        let set = self.set_of(la);
        let tag = self.tag_of(la);
        let base = (set * self.ways) as usize;
        self.use_stamp += 1;
        let bank = self.bank_of(addr);

        // hit?
        for w in 0..self.ways as usize {
            let l = &mut self.lines[base + w];
            if l.valid && l.tag == tag {
                l.lru = self.use_stamp;
                if is_write {
                    l.dirty = true;
                }
                return LevelOutcome { hit: true, writeback: None, bank, mshr_merged: false };
            }
        }

        // miss: MSHR check (another outstanding miss on the same line?)
        self.mshr.retain(|&(_, ready)| ready > now);
        let merged = self.mshr.iter().any(|&(l, _)| l == la);
        if !merged && self.mshr.len() < self.mshr_entries {
            self.mshr.push((la, now + self.latency * 4));
        }

        // victim = invalid way or LRU
        let mut victim = 0usize;
        let mut best = u64::MAX;
        for w in 0..self.ways as usize {
            let l = &self.lines[base + w];
            if !l.valid {
                victim = w;
                break;
            }
            if l.lru < best {
                best = l.lru;
                victim = w;
            }
        }
        let v = &mut self.lines[base + victim];
        let writeback = if v.valid && v.dirty {
            // reconstruct victim line address: tag | set
            Some((v.tag << self.sets.trailing_zeros() | set) << self.line_shift)
        } else {
            None
        };
        *v = Line { tag, valid: true, dirty: is_write, lru: self.use_stamp };
        LevelOutcome { hit: false, writeback, bank, mshr_merged: merged }
    }

    /// Probe without side effects (used by the reshaper's locality check).
    pub fn peek(&self, addr: u32) -> bool {
        let la = self.line_addr(addr);
        let set = self.set_of(la);
        let tag = self.tag_of(la);
        let base = (set * self.ways) as usize;
        (0..self.ways as usize)
            .any(|w| self.lines[base + w].valid && self.lines[base + w].tag == tag)
    }

    /// Number of valid lines (for capacity invariants in tests).
    pub fn valid_lines(&self) -> usize {
        self.lines.iter().filter(|l| l.valid).count()
    }

    /// Total line slots (sets × ways).
    pub fn capacity_lines(&self) -> usize {
        self.lines.len()
    }
}

/// The full data-side hierarchy: L1D + shared L2 + DRAM.
pub struct MemHierarchy {
    /// L1 data cache
    pub l1d: Cache,
    /// L1 instruction cache
    pub l1i: Cache,
    /// unified second-level cache (data + instruction refills)
    pub l2: Cache,
    /// main-memory access latency in cycles
    pub dram_latency: u64,
    /// per-level hit/miss counters accumulated over the run
    pub stats: MemStats,
}

impl MemHierarchy {
    /// A hierarchy from the three cache shapes plus the DRAM latency.
    pub fn new(l1i: &CacheConfig, l1d: &CacheConfig, l2: &CacheConfig, dram_latency: u64) -> Self {
        Self {
            l1d: Cache::new(l1d),
            l1i: Cache::new(l1i),
            l2: Cache::new(l2),
            dram_latency,
            stats: MemStats::default(),
        }
    }

    /// Data access through the hierarchy; updates stats and returns the
    /// AccessProbe record.
    pub fn access_data(&mut self, addr: u32, size: u8, is_store: bool, now: u64) -> MemAccessInfo {
        let o1 = self.l1d.access(addr, is_store, now);
        if o1.hit {
            if is_store {
                self.stats.l1d_write_hits += 1;
            } else {
                self.stats.l1d_read_hits += 1;
            }
            return MemAccessInfo {
                addr,
                size,
                is_store,
                level: MemLevel::L1,
                bank: o1.bank,
                l1_hit: true,
                l2_hit: false,
                mshr_merged: false,
                latency: self.l1d.latency,
                issue_tick: now,
            };
        }
        if is_store {
            self.stats.l1d_write_misses += 1;
        } else {
            self.stats.l1d_read_misses += 1;
        }
        if o1.mshr_merged {
            self.stats.mshr_merges += 1;
        }
        if let Some(wb) = o1.writeback {
            // dirty victim written back into L2
            self.stats.writebacks += 1;
            let o = self.l2.access(wb, true, now);
            if o.hit {
                self.stats.l2_write_hits += 1;
            } else {
                self.stats.l2_write_misses += 1;
                self.stats.dram_writes += 1;
            }
        }

        // L2: the refill read (a store miss still *reads* the line first
        // under write-allocate)
        let o2 = self.l2.access(addr, false, now);
        if o2.hit {
            self.stats.l2_read_hits += 1;
            let lat = self.l1d.latency + self.l2.latency;
            return MemAccessInfo {
                addr,
                size,
                is_store,
                level: MemLevel::L2,
                bank: o2.bank,
                l1_hit: false,
                l2_hit: true,
                mshr_merged: o1.mshr_merged,
                latency: if o1.mshr_merged { self.l1d.latency + 1 } else { lat },
                issue_tick: now,
            };
        }
        self.stats.l2_read_misses += 1;
        if let Some(wb) = o2.writeback {
            self.stats.writebacks += 1;
            self.stats.dram_writes += 1;
            let _ = wb;
        }
        self.stats.dram_reads += 1;
        let lat = self.l1d.latency + self.l2.latency + self.dram_latency;
        MemAccessInfo {
            addr,
            size,
            is_store,
            level: MemLevel::Dram,
            bank: 0,
            l1_hit: false,
            l2_hit: false,
            mshr_merged: o1.mshr_merged,
            latency: if o1.mshr_merged { self.l1d.latency + self.l2.latency } else { lat },
            issue_tick: now,
        }
    }

    /// Instruction fetch access (L1I + shared L2).
    pub fn access_inst(&mut self, addr: u32, now: u64) -> u64 {
        let o1 = self.l1i.access(addr, false, now);
        if o1.hit {
            self.stats.l1i_hits += 1;
            return self.l1i.latency;
        }
        self.stats.l1i_misses += 1;
        let o2 = self.l2.access(addr, false, now);
        if o2.hit {
            self.stats.l2_read_hits += 1;
            self.l1i.latency + self.l2.latency
        } else {
            self.stats.l2_read_misses += 1;
            self.stats.dram_reads += 1;
            self.l1i.latency + self.l2.latency + self.dram_latency
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CacheConfig;

    fn small() -> CacheConfig {
        CacheConfig { capacity: 1024, assoc: 2, line: 64, banks: 4, latency: 2, mshr_entries: 4 }
    }

    #[test]
    fn hit_after_fill() {
        let mut c = Cache::new(&small());
        assert!(!c.access(0x100, false, 0).hit);
        assert!(c.access(0x100, false, 1).hit);
        assert!(c.access(0x13c, false, 2).hit); // same 64B line
        assert!(!c.access(0x140, false, 3).hit); // next line
    }

    #[test]
    fn lru_eviction_order() {
        // 1 kB, 2-way, 64 B lines -> 8 sets; set = line_addr % 8
        let mut c = Cache::new(&small());
        let set0 = |i: u32| i * 8 * 64; // addresses mapping to set 0
        c.access(set0(0), false, 0);
        c.access(set0(1), false, 1);
        c.access(set0(0), false, 2); // touch 0 -> 1 is LRU
        c.access(set0(2), false, 3); // evicts 1
        assert!(c.peek(set0(0)));
        assert!(!c.peek(set0(1)));
        assert!(c.peek(set0(2)));
    }

    #[test]
    fn dirty_eviction_reports_writeback() {
        let mut c = Cache::new(&small());
        let set0 = |i: u32| i * 8 * 64;
        c.access(set0(0), true, 0); // dirty
        c.access(set0(1), false, 1);
        let o = c.access(set0(2), false, 2); // evicts dirty line 0
        assert_eq!(o.writeback, Some(set0(0)));
    }

    #[test]
    fn capacity_never_exceeded() {
        let mut c = Cache::new(&small());
        for i in 0..10_000u32 {
            c.access(i * 64, (i % 3) == 0, i as u64);
        }
        assert!(c.valid_lines() <= c.capacity_lines());
        assert_eq!(c.valid_lines(), c.capacity_lines()); // saturated
    }

    #[test]
    fn bank_mapping_interleaves_lines() {
        let c = Cache::new(&small());
        assert_eq!(c.bank_of(0x000), 0);
        assert_eq!(c.bank_of(0x040), 1);
        assert_eq!(c.bank_of(0x080), 2);
        assert_eq!(c.bank_of(0x0c0), 3);
        assert_eq!(c.bank_of(0x100), 0);
        // same line -> same bank regardless of offset
        assert_eq!(c.bank_of(0x47), c.bank_of(0x40));
    }

    #[test]
    fn hierarchy_levels_and_stats() {
        let l1 = small();
        let l2 = CacheConfig { capacity: 4096, assoc: 4, line: 64, banks: 4, latency: 8, mshr_entries: 8 };
        let mut m = MemHierarchy::new(&l1, &l1, &l2, 100);
        let a = m.access_data(0x1000, 4, false, 0);
        assert_eq!(a.level, MemLevel::Dram);
        assert_eq!(a.latency, 2 + 8 + 100);
        let b = m.access_data(0x1000, 4, false, 10);
        assert_eq!(b.level, MemLevel::L1);
        assert_eq!(m.stats.l1d_read_hits, 1);
        assert_eq!(m.stats.l1d_read_misses, 1);
        assert_eq!(m.stats.dram_reads, 1);
    }

    #[test]
    fn l2_hit_path() {
        let l1 = small();
        let l2 = CacheConfig { capacity: 64 * 1024, assoc: 4, line: 64, banks: 4, latency: 8, mshr_entries: 8 };
        let mut m = MemHierarchy::new(&l1, &l1, &l2, 100);
        // fill L1 set 0 beyond capacity so the first line falls back to L2 only
        let set0 = |i: u32| i * 8 * 64;
        m.access_data(set0(0), 4, false, 0);
        m.access_data(set0(1), 4, false, 1);
        m.access_data(set0(2), 4, false, 2); // evicts set0(0) from L1 (clean)
        let a = m.access_data(set0(0), 4, false, 3);
        assert_eq!(a.level, MemLevel::L2);
        assert!(a.l2_hit && !a.l1_hit);
    }

    #[test]
    fn store_markings() {
        let l1 = small();
        let l2 = CacheConfig { capacity: 4096, assoc: 4, line: 64, banks: 4, latency: 8, mshr_entries: 8 };
        let mut m = MemHierarchy::new(&l1, &l1, &l2, 100);
        let a = m.access_data(0x40, 4, true, 0);
        assert!(a.is_store);
        assert_eq!(m.stats.l1d_write_misses, 1);
        let b = m.access_data(0x44, 4, true, 1);
        assert!(b.l1_hit);
        assert_eq!(m.stats.l1d_write_hits, 1);
    }
}
