//! Pre-decoded execution: the production simulation loop.
//!
//! The reference interpreter ([`super::core`]) re-derives everything about
//! an instruction — functional unit, execution latency, source registers,
//! operand class — on *every dynamic execution*, walking a 49-arm opcode
//! match per committed instruction.  The static program is tiny (hundreds
//! of instructions) but looped over millions of times, so that per-dynamic
//! work dominates every cold sweep.
//!
//! This module decodes each static instruction **once** at program load
//! into a flat [`DecodedOp`] array: the resolved functional-unit index and
//! pool class, execution latency, flattened source-register list with
//! int/float read counts, destination register, and an [`Exec`] selector
//! that collapses the 49 opcodes into ~15 execution classes (most ALU ops
//! become a single stored `fn` pointer).  The hot loop then runs one small
//! match per *class*, not one giant match per *opcode*, and never calls
//! back into [`crate::isa`] metadata.
//!
//! **Byte-identity contract.**  [`simulate_decoded_into`] must produce
//! exactly the commit stream, [`PipeStats`], [`crate::probes::MemStats`]
//! and [`TraceSummary`] of [`super::simulate_reference_into`] — same
//! values, same order, same fault points — so downstream Report JSON and
//! every cache key are unchanged and no knob enters the dedup preimage.
//! The loop below mirrors the reference loop statement-for-statement
//! (branch-predictor work is folded into the branch arms, which is
//! equivalent because nothing intervenes between the execute match and
//! the prediction block in the reference).  `rust/tests/sim_differential.rs`
//! pins the contract with randomized cross-checks; keep any edit here
//! mirrored in [`super::core`].

use crate::asm::Program;
use crate::config::SystemConfig;
use crate::isa::{FuncUnit, Instruction, Opcode, NUM_INT_REGS};
use crate::probes::{IState, PipeStats, StopReason, TraceSink, TraceSummary};

use super::bpred::BranchPredictor;
use super::cache::MemHierarchy;
use super::core::{init_arch, FuPools, Limits, SimError, Window};

/// Sentinel in [`DecodedOp::dest`] for "writes no register".
const NO_DEST: u8 = 0xFF;

/// Load width/destination class (resolved once at decode).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum LoadKind {
    /// `lw`: 32-bit load into an integer register
    Word,
    /// `lb`: sign-extended 8-bit load into an integer register
    Byte,
    /// `flw`: 32-bit load bit-cast into a float register
    Float,
}

/// Store width/source class (resolved once at decode).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum StoreKind {
    /// `sw`: 32-bit store from an integer register
    Word,
    /// `sb`: low-byte store from an integer register
    Byte,
    /// `fsw`: 32-bit store of a float register's bits
    Float,
}

/// Execution selector: which (small) hot-loop arm runs this instruction.
///
/// ALU-class opcodes carry their semantics as a stored `fn` pointer, so
/// `add`/`xor`/`div`/… all share one arm; only classes with structurally
/// different timing or side effects (memory, control flow, converts) get
/// their own variant.
#[derive(Clone, Copy)]
enum Exec {
    /// integer reg-reg op: `rd = f(rs1, rs2)`
    IntBin(fn(i32, i32) -> i32),
    /// integer reg-imm op: `rd = f(rs1, imm)` (`lui` folds in as
    /// `f(_, imm) = imm << 12`)
    IntImm(fn(i32, i32) -> i32),
    /// memory load (`lw`/`lb`/`flw`)
    Load(LoadKind),
    /// memory store (`sw`/`sb`/`fsw`)
    Store(StoreKind),
    /// conditional branch: taken iff `f(rs1, rs2)`
    Cond(fn(i32, i32) -> bool),
    /// unconditional jump-and-link to an immediate target
    Jal,
    /// unconditional jump-and-link to the data-dependent `rs1 + imm`
    Jalr,
    /// float reg-reg op: `fd = f(fs1, fs2)`
    FpBin(fn(f32, f32) -> f32),
    /// float compare into an integer register: `rd = f(fs1, fs2) as i32`
    FpCmp(fn(f32, f32) -> bool),
    /// float → int convert
    Fcvtws,
    /// int → float convert
    Fcvtsw,
    /// float register move
    Fmv,
    /// no operation
    Nop,
    /// stop the simulated program (checked at the loop top, never executed)
    Halt,
}

/// One statically decoded instruction: everything the hot loop needs,
/// pre-resolved so the per-dynamic-instruction work is field reads.
#[derive(Clone, Copy)]
pub struct DecodedOp {
    /// the original instruction word (emitted verbatim in each [`IState`])
    instr: Instruction,
    /// functional unit (emitted in each [`IState`])
    fu: FuncUnit,
    /// `fu.index()` — the [`PipeStats::fu_counts`] slot
    fu_idx: u8,
    /// [`FuPools`] pool class for `fu`
    fu_class: u8,
    /// execution latency in cycles (`Opcode::exec_latency`)
    exec_lat: u64,
    /// flattened source registers (`sources()` with the `None`s removed)
    srcs: [u8; 2],
    /// number of valid entries in `srcs`
    nsrcs: u8,
    /// integer register-file reads this instruction performs
    int_reads: u8,
    /// float register-file reads this instruction performs
    fp_reads: u8,
    /// destination register, or [`NO_DEST`]
    dest: u8,
    /// destination is in the integer register file
    dest_int: bool,
    /// hot-loop execution selector
    exec: Exec,
}

impl DecodedOp {
    fn new(instr: Instruction) -> Self {
        let fu = instr.op.func_unit();
        let mut srcs = [0u8; 2];
        let mut nsrcs = 0u8;
        let mut int_reads = 0u8;
        let mut fp_reads = 0u8;
        for s in instr.sources().into_iter().flatten() {
            srcs[nsrcs as usize] = s;
            nsrcs += 1;
            if s < NUM_INT_REGS {
                int_reads += 1;
            } else {
                fp_reads += 1;
            }
        }
        let (dest, dest_int) = match instr.dest() {
            Some(rd) => (rd, rd < NUM_INT_REGS),
            None => (NO_DEST, false),
        };

        use Opcode::*;
        let exec = match instr.op {
            Add => Exec::IntBin(|a, b| a.wrapping_add(b)),
            Sub => Exec::IntBin(|a, b| a.wrapping_sub(b)),
            And => Exec::IntBin(|a, b| a & b),
            Or => Exec::IntBin(|a, b| a | b),
            Xor => Exec::IntBin(|a, b| a ^ b),
            Sll => Exec::IntBin(|a, b| a.wrapping_shl(b as u32 & 31)),
            Srl => Exec::IntBin(|a, b| ((a as u32) >> (b as u32 & 31)) as i32),
            Sra => Exec::IntBin(|a, b| a >> (b as u32 & 31)),
            Slt => Exec::IntBin(|a, b| (a < b) as i32),
            Sltu => Exec::IntBin(|a, b| ((a as u32) < (b as u32)) as i32),
            Mul => Exec::IntBin(|a, b| a.wrapping_mul(b)),
            Div => Exec::IntBin(|a, b| if b == 0 { -1 } else { a.wrapping_div(b) }),
            Rem => Exec::IntBin(|a, b| if b == 0 { a } else { a.wrapping_rem(b) }),
            Addi => Exec::IntImm(|a, i| a.wrapping_add(i)),
            Andi => Exec::IntImm(|a, i| a & i),
            Ori => Exec::IntImm(|a, i| a | i),
            Xori => Exec::IntImm(|a, i| a ^ i),
            Slli => Exec::IntImm(|a, i| a.wrapping_shl(i as u32 & 31)),
            Srli => Exec::IntImm(|a, i| ((a as u32) >> (i as u32 & 31)) as i32),
            Srai => Exec::IntImm(|a, i| a >> (i as u32 & 31)),
            Slti => Exec::IntImm(|a, i| (a < i) as i32),
            Lui => Exec::IntImm(|_, i| i.wrapping_shl(12)),
            Lw => Exec::Load(LoadKind::Word),
            Lb => Exec::Load(LoadKind::Byte),
            Flw => Exec::Load(LoadKind::Float),
            Sw => Exec::Store(StoreKind::Word),
            Sb => Exec::Store(StoreKind::Byte),
            Fsw => Exec::Store(StoreKind::Float),
            Beq => Exec::Cond(|a, b| a == b),
            Bne => Exec::Cond(|a, b| a != b),
            Blt => Exec::Cond(|a, b| a < b),
            Bge => Exec::Cond(|a, b| a >= b),
            Bltu => Exec::Cond(|a, b| (a as u32) < (b as u32)),
            Bgeu => Exec::Cond(|a, b| (a as u32) >= (b as u32)),
            Jal => Exec::Jal,
            Jalr => Exec::Jalr,
            Fadd => Exec::FpBin(|a, b| a + b),
            Fsub => Exec::FpBin(|a, b| a - b),
            Fmul => Exec::FpBin(|a, b| a * b),
            Fdiv => Exec::FpBin(|a, b| a / b),
            Fmin => Exec::FpBin(|a, b| a.min(b)),
            Fmax => Exec::FpBin(|a, b| a.max(b)),
            Feq => Exec::FpCmp(|a, b| a == b),
            Flt => Exec::FpCmp(|a, b| a < b),
            Fcvtws => Exec::Fcvtws,
            Fcvtsw => Exec::Fcvtsw,
            Fmv => Exec::Fmv,
            Nop => Exec::Nop,
            Halt => Exec::Halt,
        };

        Self {
            instr,
            fu,
            fu_idx: fu.index() as u8,
            fu_class: FuPools::class(fu) as u8,
            exec_lat: instr.op.exec_latency(),
            srcs,
            nsrcs,
            int_reads,
            fp_reads,
            dest,
            dest_int,
            exec,
        }
    }
}

/// A program's text segment decoded once into flat [`DecodedOp`]s.
///
/// Build with [`DecodedProgram::new`] (cost: one pass over the *static*
/// instructions) and run it any number of times via
/// [`simulate_decoded_into`] / [`super::simulate_into`].
pub struct DecodedProgram {
    ops: Vec<DecodedOp>,
}

impl DecodedProgram {
    /// Decode every instruction of `prog`'s text segment.
    pub fn new(prog: &Program) -> Self {
        Self { ops: prog.instrs.iter().copied().map(DecodedOp::new).collect() }
    }

    /// Number of static instructions.
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    /// True for an empty text segment.
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }
}

/// Simulate `prog` on `cfg` through the pre-decoded path, committing each
/// instruction's I-state into `sink` as it retires.
///
/// Drop-in replacement for [`super::simulate_reference_into`]: identical
/// commit stream, statistics, summary and fault behavior, decode-once
/// dispatch instead of a per-dynamic-instruction opcode match.
pub fn simulate_decoded_into(
    prog: &Program,
    cfg: &SystemConfig,
    limits: Limits,
    sink: &mut dyn TraceSink,
) -> Result<TraceSummary, SimError> {
    let decoded = DecodedProgram::new(prog);
    let ops = &decoded.ops[..];

    let mut arch = init_arch(prog)?;

    let mut hier = MemHierarchy::new(&cfg.l1i, &cfg.l1d, &cfg.l2, cfg.dram.latency);
    let mut bpred = BranchPredictor::new(12);
    let mut pools = FuPools::new(cfg);
    let mut rob = Window::new(cfg.core.rob_entries);
    let mut iq = Window::new(cfg.core.iq_entries);
    let mut lsq = Window::new(cfg.core.lsq_entries);

    let mut pipe = PipeStats::default();

    let width = cfg.core.width.max(1) as u64;
    let mut fetch_cycle: u64 = 0;
    let mut fetch_slot: u64 = 0;
    let mut last_fetch_line: u32 = u32::MAX;
    let mut commit_cycle: u64 = 0;
    let mut commit_slot: u64 = 0;
    let mut last_commit: u64 = 0;

    let mut pc: u32 = 0;
    let mut reg_ready = [0u64; crate::isa::NUM_REGS as usize];
    let mut seq: u64 = 0;
    let stop;

    loop {
        if seq >= limits.max_instructions {
            stop = StopReason::MaxInstructions;
            break;
        }
        if pc as usize >= ops.len() {
            stop = StopReason::RanOffEnd;
            break;
        }
        let op = &ops[pc as usize];
        let instr = op.instr;
        if matches!(op.exec, Exec::Halt) {
            stop = StopReason::Halt;
            break;
        }

        // ---------------- fetch ------------------------------------------
        // I-cache: one access per 64 B line (8 instructions) or redirect.
        let line = pc / 8;
        if line != last_fetch_line {
            // text segment lives in its own half of the address space so
            // I-fetches never alias data lines in the shared L2
            let lat = hier.access_inst(0x8000_0000 | (pc * 8), fetch_cycle);
            if lat > hier.l1i.latency {
                fetch_cycle += lat - hier.l1i.latency; // miss stall
                fetch_slot = 0;
            }
            last_fetch_line = line;
        }
        let tick_fetch = fetch_cycle;
        fetch_slot += 1;
        if fetch_slot >= width {
            fetch_cycle += 1;
            fetch_slot = 0;
        }
        pipe.fetched += 1;

        // ---------------- decode / rename --------------------------------
        let tick_decode = tick_fetch + 1;
        let tick_rename = tick_decode + 1;
        pipe.decoded += 1;
        pipe.renamed += 1;

        // ---------------- dispatch (ROB/IQ allocation) -------------------
        let tick_dispatch = (tick_rename + 1)
            .max(rob.available())
            .max(iq.available());
        pipe.rob_writes += 1;
        pipe.iq_writes += 1;

        // ---------------- register read + issue --------------------------
        let mut ready = tick_dispatch;
        for &s in &op.srcs[..op.nsrcs as usize] {
            ready = ready.max(reg_ready[s as usize]);
        }
        pipe.int_rf_reads += op.int_reads as u64;
        pipe.fp_rf_reads += op.fp_reads as u64;
        pipe.fu_counts[op.fu_idx as usize] += 1;
        pipe.iq_reads += 1;
        let exec_lat = op.exec_lat;
        let tick_issue = pools.acquire_class(op.fu_class as usize, ready, exec_lat);
        iq.push(tick_issue);

        // ---------------- execute (functional) + memory -------------------
        // One match per *class*; the branch-predictor block the reference
        // runs after its opcode match is folded into the control-flow arms
        // (equivalent: `complete` is final before those arms and nothing
        // intervenes in the reference).
        let mut mem_info = None;
        let mut next_pc = pc + 1;
        let mut complete = tick_issue + exec_lat;

        match op.exec {
            Exec::IntBin(f) => {
                arch.set_r(instr.rd, f(arch.r(instr.rs1), arch.r(instr.rs2)));
            }
            Exec::IntImm(f) => {
                arch.set_r(instr.rd, f(arch.r(instr.rs1), instr.imm));
            }
            Exec::Load(kind) => {
                let addr = arch.r(instr.rs1).wrapping_add(instr.imm) as u32;
                let size = if kind == LoadKind::Byte { 1 } else { 4 };
                let info = hier.access_data(addr, size, false, tick_issue);
                pipe.lsq_reads += 1;
                lsq.push(tick_issue + info.latency);
                complete = tick_issue + info.latency;
                match kind {
                    LoadKind::Word => arch.set_r(instr.rd, arch.read_u32(addr, pc)? as i32),
                    LoadKind::Byte => arch.set_r(instr.rd, arch.read_u8(addr, pc)? as i8 as i32),
                    LoadKind::Float => {
                        arch.set_f(instr.rd, f32::from_bits(arch.read_u32(addr, pc)?))
                    }
                }
                mem_info = Some(info);
            }
            Exec::Store(kind) => {
                let addr = arch.r(instr.rs1).wrapping_add(instr.imm) as u32;
                let size = if kind == StoreKind::Byte { 1 } else { 4 };
                let info = hier.access_data(addr, size, true, tick_issue);
                pipe.lsq_writes += 1;
                lsq.push(tick_issue + 1); // store buffer absorbs the latency
                complete = tick_issue + 1;
                match kind {
                    StoreKind::Word => arch.write_u32(addr, arch.r(instr.rs2) as u32, pc)?,
                    StoreKind::Byte => arch.write_u8(addr, arch.r(instr.rs2) as u8, pc)?,
                    StoreKind::Float => arch.write_u32(addr, arch.f(instr.rs2).to_bits(), pc)?,
                }
                mem_info = Some(info);
            }
            Exec::Cond(f) => {
                let taken = f(arch.r(instr.rs1), arch.r(instr.rs2));
                let target = instr.imm as u32;
                if taken {
                    next_pc = target;
                }
                let pred = bpred.predict(pc);
                pipe.bpred_lookups += 1;
                let mispredicted = bpred.update(pc, taken, target, pred);
                if mispredicted {
                    pipe.bpred_mispredicts += 1;
                    fetch_cycle = complete + cfg.core.mispredict_penalty;
                    fetch_slot = 0;
                    last_fetch_line = u32::MAX; // redirect refetches the line
                } else if taken {
                    // correctly-predicted taken branch still pays the BTB
                    // redirect bubble (A9-style front end)
                    fetch_cycle = fetch_cycle.max(tick_fetch + 2);
                    fetch_slot = 0;
                }
            }
            Exec::Jal => {
                arch.set_r(instr.rd, (pc + 1) as i32);
                next_pc = instr.imm as u32;
                last_fetch_line = u32::MAX;
            }
            Exec::Jalr => {
                let t = (arch.r(instr.rs1).wrapping_add(instr.imm)) as u32;
                arch.set_r(instr.rd, (pc + 1) as i32);
                next_pc = t;
                // jalr targets are data-dependent — charge a redirect when
                // the target register wasn't ready at fetch
                if complete > tick_fetch + 2 {
                    fetch_cycle = complete;
                    fetch_slot = 0;
                }
                last_fetch_line = u32::MAX;
            }
            Exec::FpBin(f) => {
                arch.set_f(instr.rd, f(arch.f(instr.rs1), arch.f(instr.rs2)));
            }
            Exec::FpCmp(f) => {
                arch.set_r(instr.rd, f(arch.f(instr.rs1), arch.f(instr.rs2)) as i32);
            }
            Exec::Fcvtws => arch.set_r(instr.rd, arch.f(instr.rs1) as i32),
            Exec::Fcvtsw => arch.set_f(instr.rd, arch.r(instr.rs1) as f32),
            Exec::Fmv => {
                let v = arch.f(instr.rs1);
                arch.set_f(instr.rd, v);
            }
            Exec::Nop => {}
            Exec::Halt => unreachable!(),
        }

        // ---------------- writeback ----------------------------------------
        if op.dest != NO_DEST {
            reg_ready[op.dest as usize] = complete;
            if op.dest_int {
                pipe.int_rf_writes += 1;
            } else {
                pipe.fp_rf_writes += 1;
            }
        }

        // ---------------- commit (in order, `width` per cycle) ------------
        let mut tick_commit = (complete + 1).max(last_commit);
        if tick_commit > commit_cycle {
            commit_cycle = tick_commit;
            commit_slot = 0;
        }
        commit_slot += 1;
        if commit_slot >= width {
            commit_cycle += 1;
            commit_slot = 0;
        }
        tick_commit = tick_commit.max(commit_cycle);
        last_commit = tick_commit;
        rob.push(tick_commit);
        pipe.rob_reads += 1;

        sink.on_commit(IState {
            seq,
            pc,
            instr,
            fu: op.fu,
            tick_fetch,
            tick_decode,
            tick_rename,
            tick_dispatch,
            tick_issue,
            tick_complete: complete,
            tick_commit,
            mem: mem_info,
        });

        seq += 1;
        pc = next_pc;
    }

    Ok(TraceSummary {
        program: prog.name.clone(),
        cycles: last_commit.max(fetch_cycle) + 1,
        committed: seq,
        pipe,
        mem: hier.stats,
        stop,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::Asm;
    use crate::isa::{freg, NUM_OPCODES};
    use crate::probes::CollectSink;

    /// Every opcode decodes to metadata matching the `isa` ground truth.
    #[test]
    fn decode_table_matches_isa_metadata() {
        for x in 0..NUM_OPCODES {
            let opc = Opcode::from_u8(x).unwrap();
            // representative register choices: int dests/sources for int
            // ops, float ids for fp ops (sources() cares about r0 only)
            let (rd, rs1, rs2) = if opc.is_fp() && !opc.is_mem() {
                (freg(1), freg(2), freg(3))
            } else {
                (5u8, 6u8, 7u8)
            };
            let instr = Instruction::new(opc, rd, rs1, rs2, 4);
            let d = DecodedOp::new(instr);
            assert_eq!(d.fu, opc.func_unit(), "{opc:?}");
            assert_eq!(d.fu_idx as usize, opc.func_unit().index(), "{opc:?}");
            assert_eq!(
                d.fu_class as usize,
                FuPools::class(opc.func_unit()),
                "{opc:?}"
            );
            assert_eq!(d.exec_lat, opc.exec_latency(), "{opc:?}");
            let flat: Vec<u8> = instr.sources().into_iter().flatten().collect();
            assert_eq!(&d.srcs[..d.nsrcs as usize], &flat[..], "{opc:?}");
            assert_eq!(
                (d.int_reads + d.fp_reads) as usize,
                flat.len(),
                "{opc:?}"
            );
            match instr.dest() {
                Some(rd) => {
                    assert_eq!(d.dest, rd, "{opc:?}");
                    assert_eq!(d.dest_int, rd < NUM_INT_REGS, "{opc:?}");
                }
                None => assert_eq!(d.dest, NO_DEST, "{opc:?}"),
            }
        }
    }

    /// r0 destinations and sources vanish at decode, exactly like the
    /// reference's `dest()`/`sources()` filtering.
    #[test]
    fn zero_register_filtered() {
        let d = DecodedOp::new(Instruction::new(Opcode::Add, 0, 0, 5, 0));
        assert_eq!(d.dest, NO_DEST);
        assert_eq!(d.nsrcs, 1);
        assert_eq!(d.srcs[0], 5);
    }

    /// The stored fn pointers reproduce the reference's exact integer
    /// corner-case semantics.
    #[test]
    fn intbin_corner_semantics() {
        let f = |opc| match DecodedOp::new(Instruction::new(opc, 3, 4, 5, 0)).exec {
            Exec::IntBin(f) => f,
            _ => panic!("not IntBin"),
        };
        assert_eq!(f(Opcode::Div)(7, 0), -1); // divide by zero
        assert_eq!(f(Opcode::Div)(i32::MIN, -1), i32::MIN); // overflow wraps
        assert_eq!(f(Opcode::Rem)(7, 0), 7); // rem by zero yields rs1
        assert_eq!(f(Opcode::Sll)(1, 33), 2); // shift amount masked & 31
        assert_eq!(f(Opcode::Srl)(-1, 1), i32::MAX); // logical shift
    }

    /// Small end-to-end cross-check against the reference interpreter
    /// (the full randomized suite lives in `rust/tests/sim_differential.rs`).
    #[test]
    fn matches_reference_on_small_program() {
        let mut a = Asm::new("decode-smoke");
        let buf = a.data.alloc_i32("buf", &[3, 4, 0]);
        let top = a.label("top");
        a.li(1, buf as i32);
        a.lw(3, 1, 0);
        a.lw(4, 1, 4);
        a.li(5, 0);
        a.li(6, 10);
        a.bind(top);
        a.mul(7, 3, 4);
        a.add(5, 5, 7);
        a.addi(3, 3, 1);
        a.bne(3, 6, top);
        a.sw(5, 1, 8);
        a.halt();
        let prog = a.assemble();
        let cfg = SystemConfig::default();

        let mut ref_sink = CollectSink::default();
        let ref_sum = super::super::core::simulate_reference_into(
            &prog,
            &cfg,
            Limits::default(),
            &mut ref_sink,
        )
        .unwrap();
        let mut dec_sink = CollectSink::default();
        let dec_sum =
            simulate_decoded_into(&prog, &cfg, Limits::default(), &mut dec_sink).unwrap();

        assert_eq!(ref_sum, dec_sum);
        assert_eq!(ref_sink.ciq, dec_sink.ciq);
    }
}
