//! Branch predictor: gshare-style 2-bit counters + a direct-mapped BTB.

/// 2-bit saturating counter predictor with global history.
pub struct BranchPredictor {
    counters: Vec<u8>,
    history: u32,
    history_bits: u32,
    btb: Vec<(u32, u32)>, // (pc, target)
    /// total predictions made ([`BranchPredictor::predict`] calls)
    pub lookups: u64,
    /// resolved-wrong predictions (direction or taken-target mismatch)
    pub mispredicts: u64,
}

impl BranchPredictor {
    /// A predictor with `2^table_bits` counters and BTB entries (history
    /// length capped at 12 bits).
    pub fn new(table_bits: u32) -> Self {
        Self {
            counters: vec![1u8; 1 << table_bits], // weakly not-taken
            history: 0,
            history_bits: table_bits.min(12),
            btb: vec![(u32::MAX, 0); 1 << table_bits],
            lookups: 0,
            mispredicts: 0,
        }
    }

    #[inline]
    fn index(&self, pc: u32) -> usize {
        ((pc ^ (self.history & ((1 << self.history_bits) - 1))) as usize)
            & (self.counters.len() - 1)
    }

    /// Predict direction and target for a conditional branch at `pc`.
    pub fn predict(&mut self, pc: u32) -> (bool, Option<u32>) {
        self.lookups += 1;
        let taken = self.counters[self.index(pc)] >= 2;
        let (bpc, target) = self.btb[pc as usize & (self.btb.len() - 1)];
        let tgt = if bpc == pc { Some(target) } else { None };
        (taken, tgt)
    }

    /// Update with the resolved outcome; returns `true` on mispredict.
    pub fn update(&mut self, pc: u32, taken: bool, target: u32, predicted: (bool, Option<u32>)) -> bool {
        let idx = self.index(pc);
        let c = &mut self.counters[idx];
        if taken {
            *c = (*c + 1).min(3);
        } else {
            *c = c.saturating_sub(1);
        }
        self.history = (self.history << 1) | taken as u32;
        let btb_idx = pc as usize & (self.btb.len() - 1);
        self.btb[btb_idx] = (pc, target);

        let (pred_taken, pred_target) = predicted;
        let mispredicted = pred_taken != taken
            || (taken && pred_target != Some(target));
        if mispredicted {
            self.mispredicts += 1;
        }
        mispredicted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn learns_always_taken_loop() {
        let mut bp = BranchPredictor::new(10);
        let pc = 7;
        let mut wrong = 0;
        for _ in 0..1000 {
            let pred = bp.predict(pc);
            if bp.update(pc, true, 3, pred) {
                wrong += 1;
            }
        }
        // gshare needs ~history_bits iterations to saturate its history,
        // mispredicting once or twice per fresh index; then it locks in.
        assert!(wrong <= 30, "mispredicts: {wrong}");
        assert_eq!(bp.lookups, 1000);
    }

    #[test]
    fn learns_not_taken() {
        let mut bp = BranchPredictor::new(10);
        let pc = 20;
        // warm up
        for _ in 0..10 {
            let pred = bp.predict(pc);
            bp.update(pc, false, 99, pred);
        }
        let pred = bp.predict(pc);
        assert!(!pred.0);
        assert!(!bp.update(pc, false, 99, pred));
    }

    #[test]
    fn btb_miss_on_taken_counts_mispredict() {
        let mut bp = BranchPredictor::new(4);
        // force counter to predict taken but BTB is cold
        let pc = 3;
        for _ in 0..4 {
            let pred = bp.predict(pc);
            bp.update(pc, true, 42, pred);
        }
        // now alias another pc into the same BTB slot
        let alias = 3 + 16;
        let pred = bp.predict(alias);
        // whether taken or not, a taken resolution with unknown target mispredicts
        let mis = bp.update(alias, true, 55, pred);
        assert!(mis || pred.1 == Some(55));
    }
}
