//! The *reference* host-CPU model: functional EVA32 interpreter +
//! out-of-order timing, one opcode match per dynamic instruction.
//!
//! Functional-first organization (the standard trace-driven style): the
//! architectural state advances in program order, while a scoreboard-style
//! timing model assigns each committed instruction its pipeline timeline
//! (fetch → decode → rename → dispatch → issue → complete → commit, Fig 7)
//! under the machine's structural constraints:
//!
//! * register RAW dependencies through a ready-time scoreboard (physical
//!   register file semantics — WAW/WAR eliminated by renaming),
//! * functional-unit pools (int ALUs, mul/div, FP, memory ports),
//! * ROB / IQ / LSQ occupancy windows,
//! * gshare branch prediction with a mispredict refill penalty,
//! * I-cache fetch stalls and D-cache access latencies from [`MemHierarchy`].
//!
//! Only *committed* instructions are recorded (wrong-path work never enters
//! the CIQ) — exactly the view the paper's analyzer consumes.
//!
//! This module is the differential *oracle*: production simulation runs
//! through the pre-decoded path in [`super::decode`], which must produce
//! byte-identical commit streams, [`PipeStats`] and summaries
//! (`rust/tests/sim_differential.rs` pins the contract — the same
//! `replay_reference` discipline the warm-replay rebuild used).

use crate::asm::Program;
use crate::config::SystemConfig;
use crate::isa::{FuncUnit, Opcode, NUM_FP_REGS, NUM_INT_REGS};
use crate::probes::{
    CollectSink, IState, PipeStats, StopReason, Trace, TraceSink, TraceSummary,
};

use super::bpred::BranchPredictor;
use super::cache::MemHierarchy;

/// Simulation fault (bad memory access, bad jump target, ...).
#[derive(Debug, Clone, PartialEq)]
pub struct SimError {
    /// instruction index the faulting instruction was fetched from
    pub pc: u32,
    /// human-readable fault description
    pub msg: String,
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "simulation fault at pc={}: {}", self.pc, self.msg)
    }
}

impl std::error::Error for SimError {}

/// Run limits.
#[derive(Clone, Copy, Debug)]
pub struct Limits {
    /// committed-instruction budget before the run stops with
    /// [`StopReason::MaxInstructions`]
    pub max_instructions: u64,
}

impl Default for Limits {
    fn default() -> Self {
        Self { max_instructions: 20_000_000 }
    }
}

/// Architectural state of the functional machine (shared between the
/// reference interpreter here and the pre-decoded path in
/// [`super::decode`] so the two cannot diverge on memory semantics).
pub(super) struct ArchState {
    regs: [i32; NUM_INT_REGS as usize],
    fregs: [f32; NUM_FP_REGS as usize],
    mem: Vec<u8>,
}

impl ArchState {
    fn new(dmem_size: u32) -> Self {
        let size = dmem_size.next_power_of_two().max(4096) as usize;
        Self {
            regs: [0; NUM_INT_REGS as usize],
            fregs: [0.0; NUM_FP_REGS as usize],
            mem: vec![0; size],
        }
    }

    #[inline]
    fn bound(&self, addr: u32, pc: u32, size: u32) -> Result<usize, SimError> {
        let a = addr as usize;
        if addr & (size - 1) != 0 && size == 4 {
            return Err(SimError { pc, msg: format!("unaligned word access 0x{addr:x}") });
        }
        if a + size as usize > self.mem.len() {
            return Err(SimError { pc, msg: format!("address 0x{addr:x} out of bounds") });
        }
        Ok(a)
    }

    pub(super) fn read_u32(&self, addr: u32, pc: u32) -> Result<u32, SimError> {
        let a = self.bound(addr, pc, 4)?;
        Ok(u32::from_le_bytes(self.mem[a..a + 4].try_into().unwrap()))
    }

    pub(super) fn write_u32(&mut self, addr: u32, v: u32, pc: u32) -> Result<(), SimError> {
        let a = self.bound(addr, pc, 4)?;
        self.mem[a..a + 4].copy_from_slice(&v.to_le_bytes());
        Ok(())
    }

    pub(super) fn read_u8(&self, addr: u32, pc: u32) -> Result<u8, SimError> {
        let a = self.bound(addr, pc, 1)?;
        Ok(self.mem[a])
    }

    pub(super) fn write_u8(&mut self, addr: u32, v: u8, pc: u32) -> Result<(), SimError> {
        let a = self.bound(addr, pc, 1)?;
        self.mem[a] = v;
        Ok(())
    }

    #[inline]
    pub(super) fn r(&self, r: u8) -> i32 {
        if r == 0 {
            0
        } else if r < NUM_INT_REGS {
            self.regs[r as usize]
        } else {
            // reading an fp register through an int path: raw bits
            self.fregs[(r - NUM_INT_REGS) as usize].to_bits() as i32
        }
    }

    #[inline]
    pub(super) fn f(&self, r: u8) -> f32 {
        debug_assert!(r >= NUM_INT_REGS);
        self.fregs[(r - NUM_INT_REGS) as usize]
    }

    #[inline]
    pub(super) fn set_r(&mut self, r: u8, v: i32) {
        if r == 0 {
            return;
        }
        if r < NUM_INT_REGS {
            self.regs[r as usize] = v;
        } else {
            self.fregs[(r - NUM_INT_REGS) as usize] = f32::from_bits(v as u32);
        }
    }

    #[inline]
    pub(super) fn set_f(&mut self, r: u8, v: f32) {
        debug_assert!(r >= NUM_INT_REGS);
        self.fregs[(r - NUM_INT_REGS) as usize] = v;
    }
}

/// Build the initial architectural state for `prog`: zeroed registers, the
/// data image written into memory, and the stack pointer parked at the top
/// of data memory (16-byte aligned).  Shared by the reference interpreter
/// and the pre-decoded path so program setup cannot diverge.
pub(super) fn init_arch(prog: &Program) -> Result<ArchState, SimError> {
    let mut arch = ArchState::new(prog.dmem_size.max(4096));
    for w in &prog.data {
        arch.write_u32(w.addr, w.value, 0)?;
    }
    // stack pointer at top of memory, 16-byte aligned
    let sp_init = (arch.mem.len() as u32 - 16) & !15;
    arch.regs[crate::isa::SP as usize] = sp_init as i32;
    Ok(arch)
}

/// FU pool: per-class next-free ticks.
pub(super) struct FuPools {
    pools: [Vec<u64>; 4], // alu(+branch), muldiv, fp, mem
}

impl FuPools {
    pub(super) fn new(cfg: &SystemConfig) -> Self {
        Self {
            pools: [
                vec![0; cfg.core.int_alu_units.max(1)],
                vec![0; cfg.core.int_mul_units.max(1)],
                vec![0; cfg.core.fp_units.max(1)],
                vec![0; cfg.core.mem_ports.max(1)],
            ],
        }
    }

    /// Pool index for a functional unit (the decode pass caches this so the
    /// hot loop indexes straight into `pools`).
    pub(super) fn class(fu: FuncUnit) -> usize {
        match fu {
            FuncUnit::IntAlu | FuncUnit::Branch => 0,
            FuncUnit::IntMul | FuncUnit::IntDiv => 1,
            FuncUnit::FpAlu | FuncUnit::FpMul | FuncUnit::FpDiv => 2,
            FuncUnit::MemRead | FuncUnit::MemWrite => 3,
        }
    }

    /// Earliest tick at/after `ready` when a unit is free; books the unit
    /// for `busy` cycles.
    fn acquire(&mut self, fu: FuncUnit, ready: u64, busy: u64) -> u64 {
        self.acquire_class(Self::class(fu), ready, busy)
    }

    /// [`FuPools::acquire`] with the pool index already resolved — the
    /// pre-decoded path carries the class in each [`super::decode::DecodedOp`].
    pub(super) fn acquire_class(&mut self, class: usize, ready: u64, busy: u64) -> u64 {
        let pool = &mut self.pools[class];
        let (idx, &free) = pool
            .iter()
            .enumerate()
            .min_by_key(|(_, &t)| t)
            .unwrap();
        let start = ready.max(free);
        pool[idx] = start + busy;
        start
    }
}

/// Sliding window over the last `n` ticks (ROB/IQ/LSQ occupancy model).
pub(super) struct Window {
    ring: Vec<u64>,
    head: usize,
}

impl Window {
    pub(super) fn new(n: usize) -> Self {
        Self { ring: vec![0; n.max(1)], head: 0 }
    }

    /// Tick at which a slot frees up for a new entry.
    pub(super) fn available(&self) -> u64 {
        self.ring[self.head]
    }

    /// Record the tick at which the newly inserted entry releases its slot.
    pub(super) fn push(&mut self, release_tick: u64) {
        self.ring[self.head] = release_tick;
        self.head = (self.head + 1) % self.ring.len();
    }
}

/// [`simulate_reference_into`], materializing the full [`Trace`] (the
/// legacy batch view — a thin adapter).
pub fn simulate_reference(
    prog: &Program,
    cfg: &SystemConfig,
    limits: Limits,
) -> Result<Trace, SimError> {
    let mut sink = CollectSink::default();
    let summary = simulate_reference_into(prog, cfg, limits, &mut sink)?;
    Ok(Trace::from_parts(summary, sink.ciq))
}

/// Simulate `prog` on `cfg` with the reference interpreter, committing each
/// instruction's I-state into `sink` as it retires.  Peak memory is the
/// simulator's own state plus whatever the sink retains — an online sink
/// makes the whole sim→analysis pipeline O(window) instead of
/// O(instructions).
///
/// This is the differential oracle: production code calls
/// [`super::simulate_into`], which dispatches to the pre-decoded loop in
/// [`super::decode`].  Both paths must stay byte-identical; keep any edit
/// here mirrored there (and covered by `rust/tests/sim_differential.rs`).
pub fn simulate_reference_into(
    prog: &Program,
    cfg: &SystemConfig,
    limits: Limits,
    sink: &mut dyn TraceSink,
) -> Result<TraceSummary, SimError> {
    let mut arch = init_arch(prog)?;

    let mut hier = MemHierarchy::new(&cfg.l1i, &cfg.l1d, &cfg.l2, cfg.dram.latency);
    let mut bpred = BranchPredictor::new(12);
    let mut pools = FuPools::new(cfg);
    let mut rob = Window::new(cfg.core.rob_entries);
    let mut iq = Window::new(cfg.core.iq_entries);
    let mut lsq = Window::new(cfg.core.lsq_entries);

    let mut pipe = PipeStats::default();

    let width = cfg.core.width.max(1) as u64;
    let mut fetch_cycle: u64 = 0;
    let mut fetch_slot: u64 = 0;
    let mut last_fetch_line: u32 = u32::MAX;
    let mut commit_cycle: u64 = 0;
    let mut commit_slot: u64 = 0;
    let mut last_commit: u64 = 0;

    let mut pc: u32 = 0;
    let mut reg_ready = [0u64; crate::isa::NUM_REGS as usize];
    let mut seq: u64 = 0;
    let stop;

    loop {
        if seq >= limits.max_instructions {
            stop = StopReason::MaxInstructions;
            break;
        }
        if pc as usize >= prog.instrs.len() {
            stop = StopReason::RanOffEnd;
            break;
        }
        let instr = prog.instrs[pc as usize];
        if instr.op == Opcode::Halt {
            stop = StopReason::Halt;
            break;
        }

        // ---------------- fetch ------------------------------------------
        // I-cache: one access per 64 B line (8 instructions) or redirect.
        let line = pc / 8;
        if line != last_fetch_line {
            // text segment lives in its own half of the address space so
            // I-fetches never alias data lines in the shared L2
            let lat = hier.access_inst(0x8000_0000 | (pc * 8), fetch_cycle);
            if lat > hier.l1i.latency {
                fetch_cycle += lat - hier.l1i.latency; // miss stall
                fetch_slot = 0;
            }
            last_fetch_line = line;
        }
        let tick_fetch = fetch_cycle;
        fetch_slot += 1;
        if fetch_slot >= width {
            fetch_cycle += 1;
            fetch_slot = 0;
        }
        pipe.fetched += 1;

        // ---------------- decode / rename --------------------------------
        let tick_decode = tick_fetch + 1;
        let tick_rename = tick_decode + 1;
        pipe.decoded += 1;
        pipe.renamed += 1;

        // ---------------- dispatch (ROB/IQ allocation) -------------------
        let tick_dispatch = (tick_rename + 1)
            .max(rob.available())
            .max(iq.available());
        pipe.rob_writes += 1;
        pipe.iq_writes += 1;

        // ---------------- register read + issue --------------------------
        let [s1, s2] = instr.sources();
        let mut ready = tick_dispatch;
        for s in [s1, s2].into_iter().flatten() {
            ready = ready.max(reg_ready[s as usize]);
            if s < NUM_INT_REGS {
                pipe.int_rf_reads += 1;
            } else {
                pipe.fp_rf_reads += 1;
            }
        }
        let fu = instr.op.func_unit();
        pipe.fu_counts[fu.index()] += 1;
        pipe.iq_reads += 1;
        let exec_lat = instr.op.exec_latency();
        let tick_issue = pools.acquire(fu, ready, exec_lat);
        iq.push(tick_issue);

        // ---------------- execute (functional) + memory -------------------
        let mut mem_info = None;
        let mut next_pc = pc + 1;
        let mut taken = false;
        let mut target = pc + 1;
        let mut complete = tick_issue + exec_lat;

        use Opcode::*;
        match instr.op {
            Add => arch.set_r(instr.rd, arch.r(instr.rs1).wrapping_add(arch.r(instr.rs2))),
            Sub => arch.set_r(instr.rd, arch.r(instr.rs1).wrapping_sub(arch.r(instr.rs2))),
            And => arch.set_r(instr.rd, arch.r(instr.rs1) & arch.r(instr.rs2)),
            Or => arch.set_r(instr.rd, arch.r(instr.rs1) | arch.r(instr.rs2)),
            Xor => arch.set_r(instr.rd, arch.r(instr.rs1) ^ arch.r(instr.rs2)),
            Sll => arch.set_r(instr.rd, arch.r(instr.rs1).wrapping_shl(arch.r(instr.rs2) as u32 & 31)),
            Srl => arch.set_r(instr.rd, ((arch.r(instr.rs1) as u32) >> (arch.r(instr.rs2) as u32 & 31)) as i32),
            Sra => arch.set_r(instr.rd, arch.r(instr.rs1) >> (arch.r(instr.rs2) as u32 & 31)),
            Slt => arch.set_r(instr.rd, (arch.r(instr.rs1) < arch.r(instr.rs2)) as i32),
            Sltu => arch.set_r(instr.rd, ((arch.r(instr.rs1) as u32) < (arch.r(instr.rs2) as u32)) as i32),
            Mul => arch.set_r(instr.rd, arch.r(instr.rs1).wrapping_mul(arch.r(instr.rs2))),
            Div => {
                let d = arch.r(instr.rs2);
                arch.set_r(instr.rd, if d == 0 { -1 } else { arch.r(instr.rs1).wrapping_div(d) });
            }
            Rem => {
                let d = arch.r(instr.rs2);
                arch.set_r(instr.rd, if d == 0 { arch.r(instr.rs1) } else { arch.r(instr.rs1).wrapping_rem(d) });
            }
            Addi => arch.set_r(instr.rd, arch.r(instr.rs1).wrapping_add(instr.imm)),
            Andi => arch.set_r(instr.rd, arch.r(instr.rs1) & instr.imm),
            Ori => arch.set_r(instr.rd, arch.r(instr.rs1) | instr.imm),
            Xori => arch.set_r(instr.rd, arch.r(instr.rs1) ^ instr.imm),
            Slli => arch.set_r(instr.rd, arch.r(instr.rs1).wrapping_shl(instr.imm as u32 & 31)),
            Srli => arch.set_r(instr.rd, ((arch.r(instr.rs1) as u32) >> (instr.imm as u32 & 31)) as i32),
            Srai => arch.set_r(instr.rd, arch.r(instr.rs1) >> (instr.imm as u32 & 31)),
            Slti => arch.set_r(instr.rd, (arch.r(instr.rs1) < instr.imm) as i32),
            Lui => arch.set_r(instr.rd, instr.imm.wrapping_shl(12)),
            Lw | Lb | Flw => {
                let addr = arch.r(instr.rs1).wrapping_add(instr.imm) as u32;
                let size = if instr.op == Lb { 1 } else { 4 };
                let info = hier.access_data(addr, size, false, tick_issue);
                pipe.lsq_reads += 1;
                lsq.push(tick_issue + info.latency);
                complete = tick_issue + info.latency;
                match instr.op {
                    Lw => arch.set_r(instr.rd, arch.read_u32(addr, pc)? as i32),
                    Lb => arch.set_r(instr.rd, arch.read_u8(addr, pc)? as i8 as i32),
                    _ => arch.set_f(instr.rd, f32::from_bits(arch.read_u32(addr, pc)?)),
                }
                mem_info = Some(info);
            }
            Sw | Sb | Fsw => {
                let addr = arch.r(instr.rs1).wrapping_add(instr.imm) as u32;
                let size = if instr.op == Sb { 1 } else { 4 };
                let info = hier.access_data(addr, size, true, tick_issue);
                pipe.lsq_writes += 1;
                lsq.push(tick_issue + 1); // store buffer absorbs the latency
                complete = tick_issue + 1;
                match instr.op {
                    Sw => arch.write_u32(addr, arch.r(instr.rs2) as u32, pc)?,
                    Sb => arch.write_u8(addr, arch.r(instr.rs2) as u8, pc)?,
                    _ => arch.write_u32(addr, arch.f(instr.rs2).to_bits(), pc)?,
                }
                mem_info = Some(info);
            }
            Beq | Bne | Blt | Bge | Bltu | Bgeu => {
                let a = arch.r(instr.rs1);
                let b = arch.r(instr.rs2);
                taken = match instr.op {
                    Beq => a == b,
                    Bne => a != b,
                    Blt => a < b,
                    Bge => a >= b,
                    Bltu => (a as u32) < (b as u32),
                    _ => (a as u32) >= (b as u32),
                };
                target = instr.imm as u32;
                if taken {
                    next_pc = target;
                }
            }
            Jal => {
                arch.set_r(instr.rd, (pc + 1) as i32);
                next_pc = instr.imm as u32;
                taken = true;
                target = next_pc;
            }
            Jalr => {
                let t = (arch.r(instr.rs1).wrapping_add(instr.imm)) as u32;
                arch.set_r(instr.rd, (pc + 1) as i32);
                next_pc = t;
                taken = true;
                target = t;
            }
            Fadd => arch.set_f(instr.rd, arch.f(instr.rs1) + arch.f(instr.rs2)),
            Fsub => arch.set_f(instr.rd, arch.f(instr.rs1) - arch.f(instr.rs2)),
            Fmul => arch.set_f(instr.rd, arch.f(instr.rs1) * arch.f(instr.rs2)),
            Fdiv => arch.set_f(instr.rd, arch.f(instr.rs1) / arch.f(instr.rs2)),
            Fmin => arch.set_f(instr.rd, arch.f(instr.rs1).min(arch.f(instr.rs2))),
            Fmax => arch.set_f(instr.rd, arch.f(instr.rs1).max(arch.f(instr.rs2))),
            Feq => arch.set_r(instr.rd, (arch.f(instr.rs1) == arch.f(instr.rs2)) as i32),
            Flt => arch.set_r(instr.rd, (arch.f(instr.rs1) < arch.f(instr.rs2)) as i32),
            Fcvtws => arch.set_r(instr.rd, arch.f(instr.rs1) as i32),
            Fcvtsw => arch.set_f(instr.rd, arch.r(instr.rs1) as f32),
            Fmv => {
                let v = arch.f(instr.rs1);
                arch.set_f(instr.rd, v);
            }
            Nop => {}
            Halt => unreachable!(),
        }

        // ---------------- branch prediction --------------------------------
        if instr.op.is_cond_branch() {
            let pred = bpred.predict(pc);
            pipe.bpred_lookups += 1;
            let mispredicted = bpred.update(pc, taken, target, pred);
            if mispredicted {
                pipe.bpred_mispredicts += 1;
                fetch_cycle = complete + cfg.core.mispredict_penalty;
                fetch_slot = 0;
                last_fetch_line = u32::MAX; // redirect refetches the line
            } else if taken {
                // correctly-predicted taken branch still pays the BTB
                // redirect bubble (A9-style front end)
                fetch_cycle = fetch_cycle.max(tick_fetch + 2);
                fetch_slot = 0;
            }
        } else if matches!(instr.op, Jal | Jalr) {
            // unconditional: jalr targets are data-dependent — charge a
            // redirect when the target register wasn't ready at fetch
            if instr.op == Jalr && complete > tick_fetch + 2 {
                fetch_cycle = complete;
                fetch_slot = 0;
            }
            last_fetch_line = u32::MAX;
        }

        // ---------------- writeback ----------------------------------------
        if let Some(rd) = instr.dest() {
            reg_ready[rd as usize] = complete;
            if rd < NUM_INT_REGS {
                pipe.int_rf_writes += 1;
            } else {
                pipe.fp_rf_writes += 1;
            }
        }

        // ---------------- commit (in order, `width` per cycle) ------------
        let mut tick_commit = (complete + 1).max(last_commit);
        if tick_commit > commit_cycle {
            commit_cycle = tick_commit;
            commit_slot = 0;
        }
        commit_slot += 1;
        if commit_slot >= width {
            commit_cycle += 1;
            commit_slot = 0;
        }
        tick_commit = tick_commit.max(commit_cycle);
        last_commit = tick_commit;
        rob.push(tick_commit);
        pipe.rob_reads += 1;

        sink.on_commit(IState {
            seq,
            pc,
            instr,
            fu,
            tick_fetch,
            tick_decode,
            tick_rename,
            tick_dispatch,
            tick_issue,
            tick_complete: complete,
            tick_commit,
            mem: mem_info,
        });

        seq += 1;
        pc = next_pc;
    }

    Ok(TraceSummary {
        program: prog.name.clone(),
        cycles: last_commit.max(fetch_cycle) + 1,
        committed: seq,
        pipe,
        mem: hier.stats,
        stop,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::Asm;
    use crate::config::SystemConfig;

    fn run(asm: Asm) -> Trace {
        let prog = asm.assemble();
        simulate_reference(&prog, &SystemConfig::default(), Limits::default()).unwrap()
    }

    #[test]
    fn arithmetic_loop_computes_sum() {
        // sum 1..=10 into r3, store to memory, reload and halt
        let mut a = Asm::new("sum");
        let out = a.data.alloc_i32("out", &[0]);
        let top = a.label("top");
        a.li(1, 0); // i
        a.li(2, 10);
        a.li(3, 0); // acc
        a.bind(top);
        a.addi(1, 1, 1);
        a.add(3, 3, 1);
        a.bne(1, 2, top);
        a.li(4, out as i32);
        a.sw(3, 4, 0);
        a.lw(5, 4, 0);
        a.halt();
        let t = run(a);
        assert_eq!(t.stop, StopReason::Halt);
        // 10 iterations * 3 + 3 setup + 3 tail
        assert_eq!(t.committed, 3 + 30 + 3);
        let last = t.ciq.last().unwrap();
        assert_eq!(last.instr.op, Opcode::Lw);
        assert!(last.mem.is_some());
        assert!(t.cycles > 0);
    }

    #[test]
    fn memory_roundtrip_values() {
        let mut a = Asm::new("mem");
        let buf = a.data.alloc_i32("buf", &[11, 22, 33]);
        a.li(1, buf as i32);
        a.lw(2, 1, 4); // 22
        a.addi(2, 2, 100);
        a.sw(2, 1, 8);
        a.lw(3, 1, 8); // 122
        a.li(4, 122);
        let ok = a.label("ok");
        a.beq(3, 4, ok);
        // wrong value -> run off end (test would fail on committed count)
        a.bind(ok);
        a.halt();
        let t = run(a);
        assert_eq!(t.stop, StopReason::Halt);
    }

    #[test]
    fn fp_arithmetic() {
        let mut a = Asm::new("fp");
        let xs = a.data.alloc_f32("xs", &[1.5, 2.5]);
        a.li(1, xs as i32);
        a.flw(0, 1, 0);
        a.flw(1, 1, 4);
        a.fadd(2, 0, 1);
        a.fsw(2, 1, 0);
        a.lw(2, 1, 0);
        a.halt();
        let t = run(a);
        assert_eq!(t.stop, StopReason::Halt);
        // the reloaded word must be the bits of 4.0f32
        let lw = t.ciq.last().unwrap();
        assert_eq!(lw.instr.op, Opcode::Lw);
    }

    #[test]
    fn commit_order_and_seq_dense() {
        let mut a = Asm::new("t");
        for i in 0..20 {
            a.addi(1, 1, i);
        }
        a.halt();
        let t = run(a);
        for (i, is) in t.ciq.iter().enumerate() {
            assert_eq!(is.seq, i as u64);
            assert!(is.tick_fetch <= is.tick_decode);
            assert!(is.tick_decode <= is.tick_rename);
            assert!(is.tick_rename <= is.tick_dispatch);
            assert!(is.tick_dispatch <= is.tick_issue);
            assert!(is.tick_issue <= is.tick_complete);
            assert!(is.tick_complete < is.tick_commit);
        }
        // in-order commit
        for w in t.ciq.windows(2) {
            assert!(w[0].tick_commit <= w[1].tick_commit);
        }
    }

    #[test]
    fn raw_dependency_serializes() {
        // dependent chain must take longer than independent work (two ALUs)
        let mut chain = Asm::new("chain");
        chain.li(1, 1);
        for _ in 0..100 {
            chain.add(1, 1, 1); // 1-cycle RAW chain, fully serialized
        }
        chain.halt();
        let tc = run(chain);

        let mut indep = Asm::new("indep");
        indep.li(1, 1);
        indep.li(2, 1);
        for i in 0..50 {
            indep.add(3 + (i % 2) as u8 * 2, 1, 2); // no chain
            indep.add(4 + (i % 2) as u8 * 2, 2, 1);
        }
        indep.halt();
        let ti = run(indep);
        assert!(
            tc.cycles > ti.cycles,
            "chain {} !> indep {}",
            tc.cycles,
            ti.cycles
        );
    }

    #[test]
    fn dcache_hits_after_first_touch() {
        let mut a = Asm::new("t");
        let buf = a.data.alloc_i32("buf", &[0; 16]);
        a.li(1, buf as i32);
        for _ in 0..8 {
            a.lw(2, 1, 0); // same word
        }
        a.halt();
        let t = run(a);
        assert_eq!(t.mem.l1d_read_misses, 1);
        assert_eq!(t.mem.l1d_read_hits, 7);
    }

    #[test]
    fn branch_predictor_reduces_cycles_on_regular_loop() {
        let mut a = Asm::new("loop");
        let top = a.label("top");
        a.li(1, 0);
        a.li(2, 2000);
        a.bind(top);
        a.addi(1, 1, 1);
        a.bne(1, 2, top);
        a.halt();
        let t = run(a);
        // a well-predicted 2-instruction loop on a 2-wide core should be
        // close to 1 cycle/iteration; mispredicts would add ~8 each
        assert!(t.pipe.bpred_mispredicts < 30, "{}", t.pipe.bpred_mispredicts);
        assert!(t.cpi() < 2.0, "cpi {}", t.cpi());
    }

    #[test]
    fn out_of_bounds_faults() {
        let mut a = Asm::new("bad");
        a.li(1, 0x7fff_fff0u32 as i32);
        a.lw(2, 1, 0);
        a.halt();
        let prog = a.assemble();
        let r = simulate_reference(&prog, &SystemConfig::default(), Limits::default());
        assert!(r.is_err());
    }

    #[test]
    fn max_instruction_limit() {
        let mut a = Asm::new("inf");
        let top = a.label("top");
        a.bind(top);
        a.addi(1, 1, 1);
        a.jump(top);
        let prog = a.assemble();
        let t = simulate_reference(
            &prog,
            &SystemConfig::default(),
            Limits { max_instructions: 1000 },
        )
        .unwrap();
        assert_eq!(t.stop, StopReason::MaxInstructions);
        assert_eq!(t.committed, 1000);
    }

    #[test]
    fn pipe_stats_consistent() {
        let mut a = Asm::new("t");
        let buf = a.data.alloc_i32("buf", &[1, 2]);
        a.li(1, buf as i32);
        a.lw(2, 1, 0);
        a.lw(3, 1, 4);
        a.add(4, 2, 3);
        a.sw(4, 1, 0);
        a.halt();
        let t = run(a);
        assert_eq!(t.pipe.fetched, t.committed);
        assert_eq!(t.pipe.lsq_reads, 2);
        assert_eq!(t.pipe.lsq_writes, 1);
        assert_eq!(
            t.pipe.fu_counts[FuncUnit::MemRead.index()],
            2
        );
        assert_eq!(t.pipe.int_rf_writes, 4); // li, lw, lw, add
    }
}
