//! The GEM5-substitute: EVA32 functional + timing simulation with probes.
//!
//! * [`core`] — functional interpreter + out-of-order timing model (Fig 7)
//! * [`cache`] — L1I/L1D/L2/DRAM hierarchy with MSHRs and banks (Fig 8)
//! * [`bpred`] — gshare branch predictor
//!
//! The output is a [`crate::probes::Trace`]: the committed instruction
//! queue with per-instruction I-state plus pipeline/memory statistics.

pub mod bpred;
pub mod cache;
pub mod core;

pub use core::{simulate, simulate_into, Limits, SimError};
