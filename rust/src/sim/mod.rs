//! The GEM5-substitute: EVA32 functional + timing simulation with probes.
//!
//! * [`core`] — the *reference* interpreter: functional execution + the
//!   out-of-order timing model (Fig 7), one opcode match per dynamic
//!   instruction.  Kept as the differential oracle.
//! * [`decode`] — the *production* path: each static instruction is
//!   decoded once into a flat [`decode::DecodedOp`] array and the same
//!   timing loop runs off pre-resolved metadata with per-class fast
//!   paths.  Byte-identical to the reference by contract
//!   (`rust/tests/sim_differential.rs`).
//! * [`cache`] — L1I/L1D/L2/DRAM hierarchy with MSHRs and banks (Fig 8)
//! * [`bpred`] — gshare branch predictor
//!
//! The output is a [`crate::probes::Trace`]: the committed instruction
//! queue with per-instruction I-state plus pipeline/memory statistics.
//! [`simulate`] / [`simulate_into`] are the entry points the rest of the
//! system uses; they dispatch to the pre-decoded loop.  Because both
//! paths produce identical bytes, the choice is invisible downstream:
//! no cache key, ledger counter or report changes with the path taken.

pub mod bpred;
pub mod cache;
pub mod core;
pub mod decode;

pub use core::{simulate_reference, simulate_reference_into, Limits, SimError};

use std::sync::atomic::{AtomicBool, Ordering};

use crate::asm::Program;
use crate::config::SystemConfig;
use crate::probes::{CollectSink, Trace, TraceSink, TraceSummary};

/// Process-global test seam: when set, [`simulate_into`] routes through
/// the reference interpreter instead of the pre-decoded loop.
///
/// This exists so the differential suite can drive the *whole* stack
/// (coordinator, caches, report rendering) over the oracle path and
/// assert byte-identical output.  It is deliberately not a config knob:
/// it cannot enter any cache key or dedup preimage, and production code
/// never sets it.
static FORCE_REFERENCE: AtomicBool = AtomicBool::new(false);

/// Route [`simulate_into`] through the reference interpreter (`true`) or
/// the pre-decoded path (`false`, the default).  Test-only seam — see
/// [`FORCE_REFERENCE`]; tests that flip it must restore `false` and must
/// not run concurrently with other simulations in the same process.
pub fn force_reference_path(on: bool) {
    FORCE_REFERENCE.store(on, Ordering::SeqCst);
}

/// Simulate `prog` on `cfg`, committing each instruction's I-state into
/// `sink` as it retires.  Peak memory is the simulator's own state plus
/// whatever the sink retains — an online sink makes the whole
/// sim→analysis pipeline O(window) instead of O(instructions).
///
/// Runs the pre-decoded loop ([`decode::simulate_decoded_into`]) unless
/// the [`force_reference_path`] test seam is set; both paths are
/// byte-identical, so callers never observe the difference.
pub fn simulate_into(
    prog: &Program,
    cfg: &SystemConfig,
    limits: Limits,
    sink: &mut dyn TraceSink,
) -> Result<TraceSummary, SimError> {
    if FORCE_REFERENCE.load(Ordering::SeqCst) {
        simulate_reference_into(prog, cfg, limits, sink)
    } else {
        decode::simulate_decoded_into(prog, cfg, limits, sink)
    }
}

/// Simulate `prog` on `cfg`, materializing the full [`Trace`] (the legacy
/// batch view — a thin adapter over [`simulate_into`]).
pub fn simulate(prog: &Program, cfg: &SystemConfig, limits: Limits) -> Result<Trace, SimError> {
    let mut sink = CollectSink::default();
    let summary = simulate_into(prog, cfg, limits, &mut sink)?;
    Ok(Trace::from_parts(summary, sink.ciq))
}
