//! The profiling stage (paper §V-C): system-level energy + performance.
//!
//! [`ProfileInputs`] is one design point (config rows + counter vectors +
//! perf vector); [`evaluate_native`] mirrors the AOT'd graph exactly, and
//! is both the fallback backend and the cross-validation reference for the
//! PJRT path.

use crate::config::SystemConfig;
use crate::energy::calib::*;
use crate::energy::{self, CfgRow};
use crate::reshape::{CounterSet, Reshaped, NPERF, P_CIM_ADD_L1, P_CIM_ADD_L2,
                     P_COMMITTED, P_CYCLES, P_REMOVED};

/// One design point handed to the profiler backend.
#[derive(Clone, Debug)]
pub struct ProfileInputs {
    /// L1 design-point row (geometry + tech + level columns)
    pub cfg_l1: CfgRow,
    /// L2 design-point row
    pub cfg_l2: CfgRow,
    /// event counters of the unmodified (baseline) trace
    pub counters_base: CounterSet,
    /// event counters of the reshaped (CiM) trace
    pub counters_cim: CounterSet,
    /// performance vector (cycles, committed, removed, CiM-add counts, …)
    pub perf: [f64; NPERF],
}

impl ProfileInputs {
    /// Assemble the profiler inputs for one config + reshaped trace.
    pub fn new(cfg: &SystemConfig, reshaped: &Reshaped) -> Self {
        let (cfg_l1, cfg_l2) = energy::cfg_rows(cfg);
        Self {
            cfg_l1,
            cfg_l2,
            counters_base: reshaped.base.clone(),
            counters_cim: reshaped.cim.clone(),
            perf: reshaped.perf,
        }
    }
}

/// Full profiler output for one design point (the 12-tuple of the AOT
/// graph, structured).
#[derive(Clone, Debug, Default)]
pub struct ProfileResult {
    /// per-component energy (pJ) of the baseline system
    pub comps_base: [f64; NCOMP],
    /// per-component energy (pJ) of the CiM system
    pub comps_cim: [f64; NCOMP],
    /// baseline total energy (pJ), DRAM excluded (§VI-B scope)
    pub total_base: f64,
    /// CiM total energy (pJ), DRAM excluded
    pub total_cim: f64,
    /// energy improvement = baseline / CiM (> 1 means CiM wins)
    pub improvement: f64,
    /// constant-CPI speedup (§V-C2)
    pub speedup: f64,
    /// share of the improvement contributed by the processor side
    pub ratio_proc: f64,
    /// share of the improvement contributed by the caches
    pub ratio_cache: f64,
    /// per-op L1 energies (pJ) at this design point
    pub e_l1: [f64; NOPS],
    /// per-op L1 latencies (cycles)
    pub lat_l1: [f64; NOPS],
    /// per-op L2 energies (pJ)
    pub e_l2: [f64; NOPS],
    /// per-op L2 latencies (cycles)
    pub lat_l2: [f64; NOPS],
}

/// Evaluate one design point natively (mirror of `model._evaluate`).
pub fn evaluate_native(inp: &ProfileInputs) -> ProfileResult {
    let (e_l1, lat_l1) = energy::energy_latency(&inp.cfg_l1);
    let (e_l2, lat_l2) = energy::energy_latency(&inp.cfg_l2);
    let unit = energy::unit_energy(&inp.cfg_l1, &inp.cfg_l2);

    let comps_base = energy::aggregate(&inp.counters_base, &unit);
    let comps_cim = energy::aggregate(&inp.counters_cim, &unit);
    // the paper's improvement metric covers "host CPU and cache" (§VI-B):
    // DRAM traffic is reported as a component but excluded from the totals
    let total_base: f64 = comps_base.iter().sum::<f64>() - comps_base[COMP_DRAM];
    let total_cim: f64 = comps_cim.iter().sum::<f64>() - comps_cim[COMP_DRAM];
    let improvement = total_base / total_cim.max(1e-9);

    let cycles = inp.perf[P_CYCLES];
    let committed = inp.perf[P_COMMITTED].max(1.0);
    let removed = inp.perf[P_REMOVED];
    let cpi = cycles / committed;
    let extra_l1 = (lat_l1[OP_ADD] - lat_l1[OP_READ]).max(0.0);
    let extra_l2 = (lat_l2[OP_ADD] - lat_l2[OP_READ]).max(0.0);
    let cycles_cim = cycles - removed * cpi
        + inp.perf[P_CIM_ADD_L1] * extra_l1
        + inp.perf[P_CIM_ADD_L2] * extra_l2;
    let speedup = cycles / cycles_cim.max(1.0);

    let proc_base = comps_base[COMP_CORE] + comps_base[COMP_LEAK];
    let proc_cim = comps_cim[COMP_CORE] + comps_cim[COMP_LEAK];
    let delta_total = total_base - total_cim;
    let (ratio_proc, ratio_cache) = if delta_total.abs() < 1e-9 {
        (0.0, 0.0)
    } else {
        let rp = (proc_base - proc_cim) / delta_total;
        (rp, 1.0 - rp)
    };

    ProfileResult {
        comps_base,
        comps_cim,
        total_base,
        total_cim,
        improvement,
        speedup,
        ratio_proc,
        ratio_cache,
        e_l1,
        lat_l1,
        e_l2,
        lat_l2,
    }
}

/// Batched native evaluation (signature-compatible with the PJRT backend).
pub fn evaluate_native_batch(inputs: &[ProfileInputs]) -> Vec<ProfileResult> {
    inputs.iter().map(evaluate_native).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyzer::{analyze, LocalityRule};
    use crate::asm::Asm;
    use crate::reshape::reshape;
    use crate::sim::{simulate, Limits};

    fn inputs() -> ProfileInputs {
        let mut a = Asm::new("t");
        let buf = a.data.alloc_i32("buf", &[1, 2, 3, 4, 5, 6, 7, 8]);
        a.li(1, buf as i32);
        a.lw(9, 1, 0);
        for _ in 0..10 {
            a.lw(2, 1, 0);
            a.lw(3, 1, 4);
            a.add(4, 2, 3);
            a.sw(4, 1, 8);
        }
        a.halt();
        let cfg = SystemConfig::default();
        let t = simulate(&a.assemble(), &cfg, Limits::default()).unwrap();
        let an = analyze(&t, &cfg, LocalityRule::AnyCache);
        let r = reshape(&t, &an.selection, &cfg);
        ProfileInputs::new(&cfg, &r)
    }

    #[test]
    fn improvement_and_speedup_sane_for_cim_friendly_kernel() {
        let res = evaluate_native(&inputs());
        assert!(res.total_base > 0.0);
        assert!(res.total_cim > 0.0);
        assert!(res.improvement > 1.0, "improvement {}", res.improvement);
        assert!(res.speedup > 0.9, "speedup {}", res.speedup);
        assert!((res.ratio_proc + res.ratio_cache - 1.0).abs() < 1e-9);
    }

    #[test]
    fn identity_when_counters_equal() {
        let mut inp = inputs();
        inp.counters_cim = inp.counters_base.clone();
        inp.perf[P_REMOVED] = 0.0;
        inp.perf[P_CIM_ADD_L1] = 0.0;
        inp.perf[P_CIM_ADD_L2] = 0.0;
        let res = evaluate_native(&inp);
        assert!((res.improvement - 1.0).abs() < 1e-12);
        assert!((res.speedup - 1.0).abs() < 1e-12);
    }

    #[test]
    fn totals_are_component_sums_excluding_dram() {
        let res = evaluate_native(&inputs());
        let s: f64 = res.comps_base.iter().sum::<f64>() - res.comps_base[COMP_DRAM];
        assert!((s - res.total_base).abs() < 1e-6);
    }

    #[test]
    fn fefet_improves_more_than_sram() {
        // same workload, same counters; switch the technology column
        let mut inp_sram = inputs();
        let mut inp_fefet = inp_sram.clone();
        inp_sram.cfg_l1[CFG_TECH] = 0.0;
        inp_sram.cfg_l2[CFG_TECH] = 0.0;
        inp_fefet.cfg_l1[CFG_TECH] = 1.0;
        inp_fefet.cfg_l2[CFG_TECH] = 1.0;
        let rs = evaluate_native(&inp_sram);
        let rf = evaluate_native(&inp_fefet);
        // FeFET's cheaper reads shrink the baseline too, but its CiM ops
        // against tiny read energy gives bigger relative benefit (Fig 16)
        assert!(rf.speedup >= rs.speedup);
    }
}
