//! `eva-cim` — the Eva-CiM command-line launcher (L3 leader entrypoint).
//!
//! ```text
//! eva-cim list                                   benchmarks, presets, techs
//! eva-cim run <bench> [--config c1] [--tech sram] [--cim both]
//!                     [--scale N] [--seed N] [--rule any|level|bank]
//!                     [--backend auto|native|pjrt]
//! eva-cim asm <file.s> [--config c1]             run a text-assembly file
//! eva-cim plan <bench> [--policy accept-all|profitability] [--config c1]
//!               [--tech sram] [--cim both] [--min-ops N] [--min-net-pj X]
//!               [--plan-level l1|l2|l1+l2]       price every CiM offload
//! eva-cim sweep [--benches a,b] [--configs c1,c2] [--techs sram,fefet]
//!               [--scale N] [--jobs N] [--chunk N] [--replay-threads N]
//!               [--csv out.csv] [--cache-dir DIR] [--resume] [--fsync]
//! eva-cim explore --bench <b> [--techs all] [--configs c1,c2,c3]
//!               [--cache-dir DIR] [--resume] [--csv out.csv]
//! eva-cim serve [--addr 127.0.0.1:7878] [--http-workers N] [--queue N]
//!               [--jobs N] [--cache-dir DIR] [--request-timeout SECS]
//!               [--socket-timeout SECS]       long-lived JSON service
//!                                             (see docs/SERVING.md)
//! eva-cim table <table3|table5|table6|fig11|fig12|fig13|fig14|fig15|fig16>
//!               [--cache-dir DIR] [--resume] [--jobs N]
//! eva-cim validate                               Table V + Fig 12
//! eva-cim sensitivity <bench> [--config c1]      DSE gradient (PJRT)
//! eva-cim calib                                  print calibration constants
//! ```
//!
//! Every command is a thin composition over [`eva_cim::api::Evaluation`]
//! and produces a structured [`eva_cim::api::Report`], so every command
//! additionally accepts:
//!
//! * `--format table|json|csv` — render the same report as aligned text
//!   (default), canonical machine-readable JSON, or CSV;
//! * `--csv <file>` — additionally write the CSV rendering to a file;
//! * `--tech-file <file.toml>` (repeatable) — register custom device
//!   technologies from `[tech.<name>]` sections before flags like
//!   `--tech`/`--techs` are resolved.
//!
//! Sweep ledgers (cache effectiveness, stage-factoring counters, scale)
//! go to stderr, never stdout, so `eva-cim <cmd> --format json | jq`
//! always sees pure JSON.  Under `--format json` the ledger is *also*
//! printed to stderr as one canonical JSON object (`"ledger":"sweep"`,
//! with `analyses_run`/`analyses_cached`/`replays_skipped` et al.), so
//! machine consumers get the counters without perturbing stdout.
//!
//! (clap is unavailable in this offline environment; flags are parsed by
//! the tiny matcher in [`cli`].)

// Same deliberate style-lint set as the library crate root.
#![allow(
    clippy::too_many_arguments,
    clippy::needless_range_loop,
    clippy::manual_flatten,
    clippy::type_complexity,
    clippy::new_without_default,
    clippy::unnecessary_map_or
)]

use std::process::ExitCode;

use eva_cim::analyzer::LocalityRule;
use eva_cim::api::{BackendSel, Cell, Evaluation, Format, Report, Section};
use eva_cim::config::{CimLevels, SystemConfig, Technology};
use eva_cim::coordinator::format_stats;
use eva_cim::energy::{calib, device};
use eva_cim::experiments;
use eva_cim::runtime::PjrtRuntime;
use eva_cim::workloads;

mod cli {
    /// Boolean switches: take no value (`sweep --resume --jobs 4`), but an
    /// explicit `--resume false` is still honored.  Every other flag
    /// requires a value, and a missing one is a hard error — a trailing
    /// `--csv` must not silently write to a file named "true".
    const SWITCHES: &[&str] = &["resume", "fsync"];

    const BOOL_WORDS: &[&str] =
        &["true", "false", "1", "0", "yes", "no", "on", "off"];

    /// Minimal flag parser: positionals + `--key value` pairs + switches.
    pub struct Args {
        pub positional: Vec<String>,
        flags: Vec<(String, String)>,
    }

    impl Args {
        pub fn parse(argv: &[String]) -> Result<Self, String> {
            let mut positional = Vec::new();
            let mut flags = Vec::new();
            let mut it = argv.iter().peekable();
            while let Some(a) = it.next() {
                if let Some(key) = a.strip_prefix("--") {
                    let val = if SWITCHES.contains(&key) {
                        match it.peek() {
                            Some(v) if BOOL_WORDS.contains(&v.as_str()) => {
                                it.next().unwrap().clone()
                            }
                            _ => "true".to_string(),
                        }
                    } else {
                        let v = it
                            .next()
                            .ok_or_else(|| format!("flag --{key} needs a value"))?;
                        if v.starts_with("--") {
                            return Err(format!("flag --{key} needs a value"));
                        }
                        v.clone()
                    };
                    flags.push((key.to_string(), val));
                } else {
                    positional.push(a.clone());
                }
            }
            Ok(Self { positional, flags })
        }

        pub fn flag(&self, key: &str) -> Option<&str> {
            self.flags
                .iter()
                .rev()
                .find(|(k, _)| k == key)
                .map(|(_, v)| v.as_str())
        }

        /// Every occurrence of a repeatable flag, in order.
        pub fn flag_all(&self, key: &str) -> Vec<&str> {
            self.flags
                .iter()
                .filter(|(k, _)| k == key)
                .map(|(_, v)| v.as_str())
                .collect()
        }

        pub fn flag_or(&self, key: &str, default: &str) -> String {
            self.flag(key).unwrap_or(default).to_string()
        }

        pub fn usize_flag(&self, key: &str, default: usize) -> Result<usize, String> {
            match self.flag(key) {
                None => Ok(default),
                Some(v) => v.parse().map_err(|_| format!("--{key} needs a number")),
            }
        }

        pub fn bool_flag(&self, key: &str) -> Result<bool, String> {
            match self.flag(key) {
                None => Ok(false),
                Some("true") | Some("1") | Some("yes") | Some("on") => Ok(true),
                Some("false") | Some("0") | Some("no") | Some("off") => Ok(false),
                Some(v) => Err(format!("--{key}: expected a boolean, got '{v}'")),
            }
        }
    }
}

fn parse_rule(s: &str) -> Result<LocalityRule, String> {
    LocalityRule::from_name(s).ok_or_else(|| format!("unknown locality rule '{s}'"))
}

/// Parse a `--key SECS` duration flag (fractional seconds accepted;
/// `0` means "disabled" to every caller).
fn secs_flag(
    args: &cli::Args,
    key: &str,
    default: &str,
) -> Result<std::time::Duration, String> {
    let v = args.flag_or(key, default);
    let secs: f64 = v
        .parse()
        .map_err(|_| format!("--{key} needs a number of seconds"))?;
    if !secs.is_finite() || secs < 0.0 {
        return Err(format!("--{key} must be a non-negative number of seconds"));
    }
    Ok(std::time::Duration::from_secs_f64(secs))
}

fn parse_backend(s: &str) -> Result<BackendSel, String> {
    BackendSel::from_name(s).ok_or_else(|| format!("unknown backend '{s}'"))
}

/// Register every `[tech.<name>]` section of each `--tech-file` argument.
/// Must run before `--tech`/`--techs` flags are resolved.
fn load_tech_files(args: &cli::Args) -> Result<(), String> {
    for path in args.flag_all("tech-file") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {path}: {e}"))?;
        let registered = eva_cim::config::parse::register_technologies(&text)
            .map_err(|e| format!("{path}: {e}"))?;
        if registered.is_empty() {
            return Err(format!(
                "{path}: no [tech.<name>] sections found in tech file"
            ));
        }
    }
    Ok(())
}

/// Resolve a `--tech`-style name or fail with the registry's listing +
/// did-you-mean diagnostic.
fn parse_tech(name: &str) -> Result<Technology, String> {
    Technology::from_name(name).ok_or_else(|| device::unknown_tech_message(name))
}

fn build_config(args: &cli::Args) -> Result<SystemConfig, String> {
    let mut cfg = if let Some(path) = args.flag("config-file") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {path}: {e}"))?;
        eva_cim::config::parse::parse(&text).map_err(|e| e.to_string())?
    } else {
        let preset = args.flag_or("config", "c1");
        SystemConfig::preset(&preset)
            .ok_or_else(|| format!("unknown preset '{preset}'"))?
    };
    if let Some(t) = args.flag("tech") {
        cfg.tech = parse_tech(t)?;
    }
    if let Some(c) = args.flag("cim") {
        cfg.cim_levels =
            CimLevels::from_name(c).ok_or_else(|| format!("unknown cim levels '{c}'"))?;
    }
    Ok(cfg)
}

/// Seed an [`Evaluation`] with the sizing/worker-pool/cache flags shared
/// by every sweeping command: `--scale`, `--seed`, `--jobs` (alias
/// `--workers`), `--chunk`, `--replay-threads`, `--cache-dir`,
/// `--resume`, `--fsync`, `--rule`, `--backend`, `--max-instructions`.
fn eval_from_args(args: &cli::Args) -> Result<Evaluation, String> {
    let mut ev = Evaluation::new()
        .scale(args.usize_flag("scale", 0)?)
        .seed(args.usize_flag("seed", 42)? as u64)
        .chunk(args.usize_flag("chunk", 0)?)
        .replay_threads(args.usize_flag("replay-threads", 0)?)
        .resume(args.bool_flag("resume")?)
        .fsync(args.bool_flag("fsync")?)
        .rule(parse_rule(&args.flag_or("rule", "any"))?)
        .backend(parse_backend(&args.flag_or("backend", "auto"))?);
    let default_jobs = eva_cim::coordinator::SweepOptions::default().workers;
    ev = ev.jobs(
        args.usize_flag("jobs", args.usize_flag("workers", default_jobs)?)?,
    );
    if let Some(dir) = args.flag("cache-dir") {
        ev = ev.cache_dir(dir);
    }
    if let Some(v) = args.flag("max-instructions") {
        let n: u64 = v
            .parse()
            .map_err(|_| "--max-instructions needs a number".to_string())?;
        ev = ev.max_instructions(n);
    }
    Ok(ev)
}

/// Render a finished report: sweep ledger to stderr, the report itself to
/// stdout in the `--format` of choice, plus the optional `--csv <file>`
/// export (which always goes through `Report::render_csv`).
fn emit(report: &Report, args: &cli::Args) -> Result<(), String> {
    let name = args.flag_or("format", "table");
    let format = Format::from_name(&name)
        .ok_or_else(|| format!("unknown format '{name}' (table|json|csv)"))?;
    if let Some(stats) = &report.stats {
        // the *resolved* backend matters: auto may have fallen back from
        // pjrt to the native mirror
        let backend = report
            .backend
            .map(|b| format!(" | backend {b}"))
            .unwrap_or_default();
        eprintln!("{}{backend}", format_stats(stats, report.elapsed_secs));
        if format == Format::Json {
            // machine-readable ledger twin — still stderr, so stdout
            // stays canonical (and byte-stable cold-vs-cached) JSON
            eprintln!(
                "{}",
                eva_cim::coordinator::ledger_json(
                    stats,
                    report.elapsed_secs,
                    report.backend
                )
            );
        }
    }
    print!("{}", report.render_as(format));
    if let Some(path) = args.flag("csv") {
        std::fs::write(path, report.render_csv()).map_err(|e| e.to_string())?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn err_str(e: anyhow::Error) -> String {
    format!("{e:#}")
}

fn cmd_list(args: &cli::Args) -> Result<(), String> {
    // the catalog lives in the facade so `GET /list` serves the same bytes
    emit(&eva_cim::api::list_report(), args)
}

/// `eva-cim serve`: one warm process answering evaluate/sweep/explore/list
/// requests over the shared caches — see `docs/SERVING.md` for the
/// endpoint reference and `eva_cim::serve` for the machinery.
fn cmd_serve(args: &cli::Args) -> Result<(), String> {
    let mut base = eval_from_args(args)?;
    if args.flag("resume").is_none() {
        // a long-lived service wants warm starts by default; an explicit
        // `--resume false` still wins
        base = base.resume(true);
    }
    // 0 disables either timeout: --request-timeout 0 means no deadline,
    // --socket-timeout 0 means no socket timeout
    let request_timeout = secs_flag(args, "request-timeout", "0")?;
    let opts = eva_cim::serve::ServeOptions {
        addr: args.flag_or("addr", "127.0.0.1:7878"),
        http_workers: args.usize_flag("http-workers", 4)?,
        queue: args.usize_flag("queue", 64)?,
        request_timeout: if request_timeout.is_zero() {
            None
        } else {
            Some(request_timeout)
        },
        socket_timeout: secs_flag(args, "socket-timeout", "30")?,
        base,
    };
    eva_cim::serve::install_signal_handlers();
    let server = eva_cim::serve::Server::bind(opts).map_err(err_str)?;
    eprintln!(
        "eva-cim serve: listening on http://{} \
         (endpoints: /health /stats /list /evaluate /sweep /explore /plan; \
         Ctrl-C or SIGTERM drains in-flight jobs and exits)",
        server.addr()
    );
    let handle = server.spawn().map_err(err_str)?;
    handle.join();
    Ok(())
}

fn cmd_run(args: &cli::Args) -> Result<(), String> {
    let bench = args
        .positional
        .get(1)
        .ok_or("usage: eva-cim run <bench> [flags]")?;
    let report = eval_from_args(args)?
        .bench(bench)
        .config(build_config(args)?)
        .single()
        .map_err(err_str)?;
    emit(&report, args)
}

/// `eva-cim plan`: run the offload planner on one benchmark ×
/// configuration and print every candidate group's priced decision —
/// accepted and rejected, each with its cost-term ledger and (for
/// rejections) a machine-readable reason.
fn cmd_plan(args: &cli::Args) -> Result<(), String> {
    let bench = args
        .positional
        .get(1)
        .ok_or("usage: eva-cim plan <bench> [--policy accept-all|profitability] [flags]")?;
    let mut ev = eval_from_args(args)?
        .bench(bench)
        .config(build_config(args)?);
    if let Some(p) = args.flag("policy") {
        ev = ev.policy(
            eva_cim::planner::PlanPolicy::from_name(p)
                .ok_or_else(|| eva_cim::planner::unknown_policy_message(p))?,
        );
    }
    if let Some(v) = args.flag("min-ops") {
        let n: u64 =
            v.parse().map_err(|_| "--min-ops needs a number".to_string())?;
        ev = ev.min_ops(n);
    }
    if let Some(v) = args.flag("min-net-pj") {
        let pj: f64 =
            v.parse().map_err(|_| "--min-net-pj needs a number".to_string())?;
        ev = ev.min_net_pj(pj);
    }
    if let Some(v) = args.flag("plan-level") {
        ev = ev.plan_level(
            CimLevels::from_name(v)
                .ok_or_else(|| format!("unknown cim levels '{v}'"))?,
        );
    }
    let report = ev.plan().map_err(err_str)?;
    emit(&report, args)
}

fn cmd_asm(args: &cli::Args) -> Result<(), String> {
    let path = args
        .positional
        .get(1)
        .ok_or("usage: eva-cim asm <file.s> [flags]")?;
    let text = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
    let prog = eva_cim::asm::parser::parse(path, &text).map_err(|e| e.to_string())?;
    let report = eval_from_args(args)?
        .config(build_config(args)?)
        .single_program(&prog)
        .map_err(err_str)?;
    emit(&report, args)
}

fn cmd_sweep(args: &cli::Args) -> Result<(), String> {
    let benches: Vec<String> = args
        .flag_or("benches", &workloads::NAMES.join(","))
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let bench_refs: Vec<&str> = benches.iter().map(|s| s.as_str()).collect();
    let presets: Vec<String> = args
        .flag_or("configs", "c1")
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let preset_refs: Vec<&str> = presets.iter().map(|s| s.as_str()).collect();
    let techs: Vec<Technology> = args
        .flag_or("techs", "sram")
        .split(',')
        .map(|t| parse_tech(t.trim()))
        .collect::<Result<_, _>>()?;
    let mut ev = eval_from_args(args)?
        .benches(&bench_refs)
        .presets(&preset_refs)
        .techs(&techs);
    if let Some(c) = args.flag("cim") {
        ev = ev.cim(
            CimLevels::from_name(c).ok_or_else(|| format!("unknown cim levels '{c}'"))?,
        );
    }
    // requested policy; the completion ledger names the *resolved* backend
    eprintln!(
        "sweep: {} points ({} benches x {} configs), backend={} (requested), cache={}",
        bench_refs.len() * preset_refs.len() * techs.len(),
        bench_refs.len(),
        preset_refs.len() * techs.len(),
        args.flag_or("backend", "auto"),
        args.flag("cache-dir").unwrap_or("off"),
    );
    let report = ev.run().map_err(err_str)?;
    emit(&report, args)
}

/// `eva-cim explore`: sweep tech × cache-config for one or more benchmarks
/// and print the Pareto grid + frontier (the cross-technology
/// generalization of the paper's Figs 14–16).
fn cmd_explore(args: &cli::Args) -> Result<(), String> {
    let benches: Vec<String> = match (args.flag("bench"), args.flag("benches")) {
        (Some(b), None) => vec![b.to_string()],
        (None, Some(bs)) => bs.split(',').map(|s| s.trim().to_string()).collect(),
        (Some(_), Some(_)) => {
            return Err("pass either --bench or --benches, not both".into())
        }
        (None, None) => {
            return Err(
                "usage: eva-cim explore --bench <b> [--techs t1,t2] \
                 [--configs c1,c2,c3] [--cim both] [--cache-dir DIR] [--resume]"
                    .into(),
            )
        }
    };
    let bench_refs: Vec<&str> = benches.iter().map(|s| s.as_str()).collect();
    let techs: Vec<Technology> = match args.flag("techs") {
        // the advertised default: every registered technology
        None | Some("all") => Technology::all(),
        Some(ts) => ts
            .split(',')
            .map(|t| parse_tech(t.trim()))
            .collect::<Result<_, _>>()?,
    };
    let presets: Vec<String> = args
        .flag_or("configs", "c1,c2,c3")
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let preset_refs: Vec<&str> = presets.iter().map(|s| s.as_str()).collect();
    let cim = CimLevels::from_name(&args.flag_or("cim", "both"))
        .ok_or_else(|| format!("unknown cim levels '{}'", args.flag_or("cim", "both")))?;
    eprintln!(
        "explore: {} benches x {} techs x {} configs = {} points",
        bench_refs.len(),
        techs.len(),
        preset_refs.len(),
        bench_refs.len() * techs.len() * preset_refs.len(),
    );
    let report = eval_from_args(args)?
        .benches(&bench_refs)
        .techs(&techs)
        .presets(&preset_refs)
        .cim(cim)
        .explore()
        .map_err(err_str)?;
    emit(&report, args)
}

fn cmd_table(args: &cli::Args) -> Result<(), String> {
    let id = args
        .positional
        .get(1)
        .ok_or("usage: eva-cim table <id> (table3|table5|table6|fig11..fig16|calib)")?;
    let opts = eval_from_args(args)?.sweep_options();
    // the paper tables/figures only evaluate the AOT-covered pair
    let mut backend = parse_backend(&args.flag_or("backend", "auto"))?
        .resolve(&[Technology::SRAM, Technology::FEFET])
        .map_err(err_str)?;
    let report = match id.as_str() {
        "table3" => experiments::table3(),
        "fig11" => experiments::fig11(),
        "table5" => {
            experiments::table5(backend.as_mut(), opts.scale).map_err(err_str)?
        }
        "fig12" => experiments::fig12(20, opts.scale).map_err(err_str)?,
        "fig13" => experiments::fig13(opts).map_err(err_str)?,
        "table6" => experiments::table6(opts, backend.as_mut()).map_err(err_str)?,
        "fig14" => experiments::fig14(opts, backend.as_mut()).map_err(err_str)?,
        "fig15" => experiments::fig15(opts, backend.as_mut()).map_err(err_str)?,
        "fig16" => experiments::fig16(opts, backend.as_mut()).map_err(err_str)?,
        _ => return Err(format!("unknown table id '{id}'")),
    };
    emit(&report, args)
}

fn cmd_validate(args: &cli::Args) -> Result<(), String> {
    let mut backend = parse_backend(&args.flag_or("backend", "auto"))?
        .resolve(&[Technology::SRAM])
        .map_err(err_str)?;
    let report = Report::new("validate")
        .merged(experiments::table5(backend.as_mut(), 0).map_err(err_str)?)
        .merged(experiments::fig12(20, 0).map_err(err_str)?);
    emit(&report, args)
}

fn cmd_sensitivity(args: &cli::Args) -> Result<(), String> {
    let bench = args
        .positional
        .get(1)
        .ok_or("usage: eva-cim sensitivity <bench> [flags]")?;
    let cfg = build_config(args)?;
    let scale = args.usize_flag("scale", 0)?;
    let mut rt = PjrtRuntime::load(&PjrtRuntime::default_dir())
        .map_err(|e| format!("sensitivity needs the PJRT artifacts: {e:#}"))?;
    let prog = workloads::build(bench, scale, 42)
        .ok_or_else(|| format!("unknown benchmark '{bench}'"))?;
    let trace = eva_cim::sim::simulate(&prog, &cfg, eva_cim::sim::Limits::default())
        .map_err(|e| e.to_string())?;
    let analysis =
        eva_cim::analyzer::analyze(&trace, &cfg, LocalityRule::AnyCache);
    let reshaped = eva_cim::reshape::reshape(&trace, &analysis.selection, &cfg);
    let inputs = eva_cim::profiler::ProfileInputs::new(&cfg, &reshaped);
    let (g1, g2) = rt.sensitivity(&[inputs]).map_err(|e| format!("{e:#}"))?;
    let mut s = Section::new(
        &format!(
            "d(total CiM energy)/d(cfg) for {bench} on {} (* discrete — \
             gradient not actionable)",
            cfg.name
        ),
        &["param", "dE/dp (L1)", "dE/dp (L2)"],
    );
    let names = ["capacity(B)", "assoc", "line", "banks", "tech*", "level*"];
    for i in 0..names.len() {
        s.row(vec![
            Cell::str(names[i]),
            Cell::sci(g1[0][i], 3),
            Cell::sci(g2[0][i], 3),
        ]);
    }
    emit(&Report::new("sensitivity").with_section(s), args)
}

fn cmd_calib(args: &cli::Args) -> Result<(), String> {
    let mut unit = Section::new(
        "static per-event unit energies (pJ) — energy/calib.rs",
        &["counter", "pJ/event"],
    );
    let u = calib::static_unit_energy();
    for (i, name) in eva_cim::reshape::counters::COUNTER_NAMES.iter().enumerate() {
        if u[i] != 0.0 {
            unit.row(vec![Cell::str(*name), Cell::num(u[i], 1)]);
        }
    }
    let report = Report::new("calib")
        .merged(experiments::table3())
        .merged(experiments::fig11())
        .with_section(unit);
    emit(&report, args)
}

const USAGE: &str = "usage: eva-cim <list|run|asm|plan|sweep|explore|serve|table|validate|sensitivity|calib> [flags]
common flags: --format table|json|csv, --csv <file>, --tech-file <file.toml>
try: eva-cim list";

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match cli::Args::parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{USAGE}");
            return ExitCode::FAILURE;
        }
    };
    // custom technologies first: every later flag may reference them
    if let Err(e) = load_tech_files(&args) {
        eprintln!("error: {e}");
        return ExitCode::FAILURE;
    }
    // fail a typo'd --format before any (potentially minutes-long) sweep
    if let Some(f) = args.flag("format") {
        if Format::from_name(f).is_none() {
            eprintln!("error: unknown format '{f}' (table|json|csv)");
            return ExitCode::FAILURE;
        }
    }
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("");
    let result = match cmd {
        "list" => cmd_list(&args),
        "run" => cmd_run(&args),
        "asm" => cmd_asm(&args),
        "plan" => cmd_plan(&args),
        "sweep" => cmd_sweep(&args),
        "explore" => cmd_explore(&args),
        "serve" => cmd_serve(&args),
        "table" => cmd_table(&args),
        "validate" => cmd_validate(&args),
        "sensitivity" => cmd_sensitivity(&args),
        "calib" => cmd_calib(&args),
        "" | "help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(format!("unknown command '{other}'\n{USAGE}")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}
