//! `eva-cim` — the Eva-CiM command-line launcher (L3 leader entrypoint).
//!
//! ```text
//! eva-cim list                                   benchmarks, presets, techs
//! eva-cim run <bench> [--config c1] [--tech sram] [--cim both]
//!                     [--scale N] [--seed N] [--rule any|level|bank]
//!                     [--backend auto|native|pjrt]
//! eva-cim asm <file.s> [--config c1]             run a text-assembly file
//! eva-cim sweep [--benches a,b] [--configs c1,c2] [--techs sram,fefet]
//!               [--scale N] [--jobs N] [--chunk N] [--csv out.csv]
//!               [--cache-dir DIR] [--resume]
//! eva-cim explore --bench <b> [--techs all] [--configs c1,c2,c3]
//!               [--cache-dir DIR] [--resume] [--csv out.csv]
//! eva-cim table <table3|table5|table6|fig11|fig12|fig13|fig14|fig15|fig16>
//!               [--cache-dir DIR] [--resume] [--jobs N]
//! eva-cim validate                               Table V + Fig 12
//! eva-cim sensitivity <bench> [--config c1]      DSE gradient (PJRT)
//! eva-cim calib                                  print calibration constants
//! ```
//!
//! Every command additionally accepts `--tech-file <file.toml>` (repeatable)
//! to register custom device technologies from `[tech.<name>]` sections
//! before flags like `--tech`/`--techs` are resolved.
//!
//! (clap is unavailable in this offline environment; flags are parsed by
//! the tiny matcher in [`cli`].)

// Same deliberate style-lint set as the library crate root.
#![allow(
    clippy::too_many_arguments,
    clippy::needless_range_loop,
    clippy::manual_flatten,
    clippy::type_complexity,
    clippy::new_without_default,
    clippy::unnecessary_map_or
)]

use std::process::ExitCode;

use eva_cim::analyzer::{analyze, LocalityRule, StreamOutcome};
use eva_cim::config::{CimLevels, SystemConfig, Technology};
use eva_cim::coordinator::{cross, format_stats, Coordinator, SweepOptions};
use eva_cim::energy::calib;
use eva_cim::energy::device;
use eva_cim::experiments;
use eva_cim::pipeline::run_pipelined;
use eva_cim::probes::TraceSummary;
use eva_cim::profiler::ProfileInputs;
use eva_cim::reshape::{reshape, reshape_from_deltas, DeltaSink, Reshaped};
use eva_cim::runtime::{best_backend, Backend, NativeBackend, PjrtRuntime};
use eva_cim::sim::{simulate, Limits};
use eva_cim::util::table::f as fnum;
use eva_cim::util::TextTable;
use eva_cim::workloads;

mod cli {
    /// Boolean switches: take no value (`sweep --resume --jobs 4`), but an
    /// explicit `--resume false` is still honored.  Every other flag
    /// requires a value, and a missing one is a hard error — a trailing
    /// `--csv` must not silently write to a file named "true".
    const SWITCHES: &[&str] = &["resume"];

    const BOOL_WORDS: &[&str] =
        &["true", "false", "1", "0", "yes", "no", "on", "off"];

    /// Minimal flag parser: positionals + `--key value` pairs + switches.
    pub struct Args {
        pub positional: Vec<String>,
        flags: Vec<(String, String)>,
    }

    impl Args {
        pub fn parse(argv: &[String]) -> Result<Self, String> {
            let mut positional = Vec::new();
            let mut flags = Vec::new();
            let mut it = argv.iter().peekable();
            while let Some(a) = it.next() {
                if let Some(key) = a.strip_prefix("--") {
                    let val = if SWITCHES.contains(&key) {
                        match it.peek() {
                            Some(v) if BOOL_WORDS.contains(&v.as_str()) => {
                                it.next().unwrap().clone()
                            }
                            _ => "true".to_string(),
                        }
                    } else {
                        let v = it
                            .next()
                            .ok_or_else(|| format!("flag --{key} needs a value"))?;
                        if v.starts_with("--") {
                            return Err(format!("flag --{key} needs a value"));
                        }
                        v.clone()
                    };
                    flags.push((key.to_string(), val));
                } else {
                    positional.push(a.clone());
                }
            }
            Ok(Self { positional, flags })
        }

        pub fn flag(&self, key: &str) -> Option<&str> {
            self.flags
                .iter()
                .rev()
                .find(|(k, _)| k == key)
                .map(|(_, v)| v.as_str())
        }

        /// Every occurrence of a repeatable flag, in order.
        pub fn flag_all(&self, key: &str) -> Vec<&str> {
            self.flags
                .iter()
                .filter(|(k, _)| k == key)
                .map(|(_, v)| v.as_str())
                .collect()
        }

        pub fn flag_or(&self, key: &str, default: &str) -> String {
            self.flag(key).unwrap_or(default).to_string()
        }

        pub fn usize_flag(&self, key: &str, default: usize) -> Result<usize, String> {
            match self.flag(key) {
                None => Ok(default),
                Some(v) => v.parse().map_err(|_| format!("--{key} needs a number")),
            }
        }

        pub fn bool_flag(&self, key: &str) -> Result<bool, String> {
            match self.flag(key) {
                None => Ok(false),
                Some("true") | Some("1") | Some("yes") | Some("on") => Ok(true),
                Some("false") | Some("0") | Some("no") | Some("off") => Ok(false),
                Some(v) => Err(format!("--{key}: expected a boolean, got '{v}'")),
            }
        }
    }
}

fn parse_rule(s: &str) -> Result<LocalityRule, String> {
    LocalityRule::from_name(s).ok_or_else(|| format!("unknown locality rule '{s}'"))
}

/// Register every `[tech.<name>]` section of each `--tech-file` argument.
/// Must run before `--tech`/`--techs` flags are resolved.
fn load_tech_files(args: &cli::Args) -> Result<(), String> {
    for path in args.flag_all("tech-file") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {path}: {e}"))?;
        let registered = eva_cim::config::parse::register_technologies(&text)
            .map_err(|e| format!("{path}: {e}"))?;
        if registered.is_empty() {
            return Err(format!(
                "{path}: no [tech.<name>] sections found in tech file"
            ));
        }
    }
    Ok(())
}

/// Resolve a `--tech`-style name or fail with the registry's listing +
/// did-you-mean diagnostic.
fn parse_tech(name: &str) -> Result<Technology, String> {
    Technology::from_name(name).ok_or_else(|| device::unknown_tech_message(name))
}

fn build_config(args: &cli::Args) -> Result<SystemConfig, String> {
    let mut cfg = if let Some(path) = args.flag("config-file") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {path}: {e}"))?;
        eva_cim::config::parse::parse(&text).map_err(|e| e.to_string())?
    } else {
        let preset = args.flag_or("config", "c1");
        SystemConfig::preset(&preset)
            .ok_or_else(|| format!("unknown preset '{preset}'"))?
    };
    if let Some(t) = args.flag("tech") {
        cfg.tech = parse_tech(t)?;
    }
    if let Some(c) = args.flag("cim") {
        cfg.cim_levels =
            CimLevels::from_name(c).ok_or_else(|| format!("unknown cim levels '{c}'"))?;
    }
    Ok(cfg)
}

/// Sweep options shared by `sweep` and `table`: sizing, the worker pool
/// (`--jobs`, with `--workers` kept as an alias), and the on-disk cache
/// (`--cache-dir`, `--resume`, `--chunk`).
fn sweep_opts_from_args(args: &cli::Args) -> Result<SweepOptions, String> {
    let defaults = SweepOptions::default();
    let workers =
        args.usize_flag("jobs", args.usize_flag("workers", defaults.workers)?)?;
    Ok(SweepOptions {
        scale: args.usize_flag("scale", 0)?,
        seed: args.usize_flag("seed", 42)? as u64,
        workers,
        chunk: args.usize_flag("chunk", 0)?,
        cache_dir: args.flag("cache-dir").map(std::path::PathBuf::from),
        resume: args.bool_flag("resume")?,
        ..defaults
    })
}

/// Resolve `--backend`.  `techs` is every technology the command will
/// evaluate: the AOT'd PJRT graphs only cover the frozen SRAM/FeFET
/// table, so `auto` must resolve to the native mirror whenever a registry
/// technology (rram, stt-mram, TOML customs) is in play, and an explicit
/// `--backend pjrt` fails up front instead of after the simulation.
fn make_backend(kind: &str, techs: &[Technology]) -> Result<Box<dyn Backend>, String> {
    let outside_table =
        techs.iter().find(|t| t.index() >= calib::NTECH).copied();
    match kind {
        "native" => Ok(Box::new(NativeBackend)),
        "pjrt" => {
            if let Some(t) = outside_table {
                return Err(format!(
                    "the pjrt backend only covers the {}-row AOT tech table \
                     (sram/fefet); technology '{}' needs --backend native",
                    calib::NTECH,
                    t.name()
                ));
            }
            PjrtRuntime::load(&PjrtRuntime::default_dir())
                .map(|rt| Box::new(rt) as Box<dyn Backend>)
                .map_err(|e| format!("{e:#}"))
        }
        "auto" => {
            if outside_table.is_some() {
                Ok(Box::new(NativeBackend))
            } else {
                Ok(best_backend(&PjrtRuntime::default_dir()))
            }
        }
        _ => Err(format!("unknown backend '{kind}'")),
    }
}

fn cmd_list() -> Result<(), String> {
    println!("benchmarks (Table IV):");
    for n in workloads::NAMES {
        println!("  {:10} {}", n, workloads::display_name(n));
    }
    println!("\nconfig presets:");
    for p in SystemConfig::preset_names() {
        let c = SystemConfig::preset(p).unwrap();
        println!(
            "  {:8} L1 {} / L2 {}",
            p,
            c.l1d.pretty(),
            c.l2.pretty()
        );
    }
    println!("\ntechnologies (--tech; extend via --tech-file or [tech.<name>]):");
    for tech in Technology::all() {
        let m = device::model_of(tech);
        let aliases = if m.aliases.is_empty() {
            String::new()
        } else {
            format!("  aliases: {}", m.aliases.join(", "))
        };
        println!(
            "  {:10} {}{aliases}",
            tech.name(),
            if device::is_builtin(tech) { "built-in" } else { "custom  " },
        );
    }
    println!("\ncim levels: none, l1, l2, both");
    Ok(())
}

/// Run the pipelined sim→analyze→reshape stack for one program.
fn stream_single(
    prog: &eva_cim::asm::Program,
    cfg: &SystemConfig,
    rule: LocalityRule,
) -> Result<(TraceSummary, StreamOutcome, Reshaped), String> {
    let (summary, outcome, deltas) = run_pipelined(
        prog,
        cfg,
        Limits::default(),
        rule,
        DeltaSink::default(),
        None,
    )
    .map_err(|e| e.to_string())?;
    let reshaped = reshape_from_deltas(&summary, &deltas, cfg);
    Ok((summary, outcome, reshaped))
}

fn report_single(
    cfg: &SystemConfig,
    summary: &TraceSummary,
    outcome: &StreamOutcome,
    reshaped: &Reshaped,
    backend: &mut dyn Backend,
) -> Result<(), String> {
    let inputs = ProfileInputs::new(cfg, reshaped);
    let res = backend
        .evaluate_batch(&[inputs])
        .map_err(|e| format!("{e:#}"))?
        .remove(0);

    println!("program          : {}", summary.program);
    println!("committed instrs : {}", summary.committed);
    println!("cycles           : {}  (CPI {:.2})", summary.cycles, summary.cpi());
    println!("IDG nodes        : {} ({} eligible)", outcome.idg_nodes.0, outcome.idg_nodes.1);
    println!("candidates       : {}", outcome.candidates);
    println!(
        "analysis window  : peak {} instrs (streamed, sim ∥ analyze)",
        outcome.peak_window
    );
    println!("MACR             : {:.1}%  (L1 share {:.1}%)",
             outcome.macr.ratio() * 100.0, outcome.macr.l1_share() * 100.0);
    println!("offloaded instrs : {}  CiM ops: {}", reshaped.removed, reshaped.cim_op_count);
    println!("backend          : {}", backend.name());
    println!();
    let mut t = TextTable::new("profile", &["metric", "baseline", "CiM", "ratio"]);
    t.row(vec![
        "energy (uJ)".into(),
        fnum(res.total_base / 1e6, 2),
        fnum(res.total_cim / 1e6, 2),
        fnum(res.improvement, 2),
    ]);
    t.row(vec![
        "speedup".into(),
        "1.00".into(),
        fnum(res.speedup, 2),
        fnum(res.speedup, 2),
    ]);
    println!("{}", t.render());
    let mut c = TextTable::new(
        "energy breakdown (uJ)",
        &["component", "baseline", "CiM"],
    );
    for i in 0..calib::NCOMP {
        c.row(vec![
            calib::COMP_NAMES[i].into(),
            fnum(res.comps_base[i] / 1e6, 3),
            fnum(res.comps_cim[i] / 1e6, 3),
        ]);
    }
    println!("{}", c.render());
    println!("improvement breakdown: processor {:.2}, caches {:.2}",
             res.ratio_proc, res.ratio_cache);
    Ok(())
}

fn cmd_run(args: &cli::Args) -> Result<(), String> {
    let bench = args
        .positional
        .get(1)
        .ok_or("usage: eva-cim run <bench> [flags]")?;
    let cfg = build_config(args)?;
    let scale = args.usize_flag("scale", 0)?;
    let seed = args.usize_flag("seed", 42)? as u64;
    let rule = parse_rule(&args.flag_or("rule", "any"))?;
    let mut backend = make_backend(&args.flag_or("backend", "auto"), &[cfg.tech])?;

    let prog = workloads::build(bench, scale, seed)
        .ok_or_else(|| format!("unknown benchmark '{bench}' (see `eva-cim list`)"))?;
    let (summary, outcome, reshaped) = stream_single(&prog, &cfg, rule)?;
    report_single(&cfg, &summary, &outcome, &reshaped, backend.as_mut())
}

fn cmd_asm(args: &cli::Args) -> Result<(), String> {
    let path = args
        .positional
        .get(1)
        .ok_or("usage: eva-cim asm <file.s> [flags]")?;
    let text = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
    let prog = eva_cim::asm::parser::parse(path, &text).map_err(|e| e.to_string())?;
    let cfg = build_config(args)?;
    let rule = parse_rule(&args.flag_or("rule", "any"))?;
    let mut backend = make_backend(&args.flag_or("backend", "auto"), &[cfg.tech])?;
    let (summary, outcome, reshaped) = stream_single(&prog, &cfg, rule)?;
    report_single(&cfg, &summary, &outcome, &reshaped, backend.as_mut())
}

fn cmd_sweep(args: &cli::Args) -> Result<(), String> {
    let benches: Vec<String> = args
        .flag_or("benches", &workloads::NAMES.join(","))
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let bench_refs: Vec<&str> = benches.iter().map(|s| s.as_str()).collect();
    let mut configs = Vec::new();
    for preset in args.flag_or("configs", "c1").split(',') {
        let base = SystemConfig::preset(preset.trim())
            .ok_or_else(|| format!("unknown preset '{preset}'"))?;
        for tech in args.flag_or("techs", "sram").split(',') {
            let tech = parse_tech(tech.trim())?;
            let mut c = base.clone().with_tech(tech);
            c.name = format!("{}-{}", preset.trim(), tech.name());
            if let Some(cim) = args.flag("cim") {
                c.cim_levels = CimLevels::from_name(cim)
                    .ok_or_else(|| format!("unknown cim levels '{cim}'"))?;
            }
            configs.push(c);
        }
    }
    let rule = parse_rule(&args.flag_or("rule", "any"))?;
    let opts = sweep_opts_from_args(args)?;
    let swept: Vec<Technology> = configs.iter().map(|c| c.tech).collect();
    let mut backend = make_backend(&args.flag_or("backend", "auto"), &swept)?;
    let points = cross(&bench_refs, &configs, rule);
    eprintln!(
        "sweep: {} points ({} benches x {} configs), backend={}, cache={}",
        points.len(),
        bench_refs.len(),
        configs.len(),
        backend.name(),
        opts.cache_dir
            .as_deref()
            .map(|d| d.display().to_string())
            .unwrap_or_else(|| "off".into()),
    );
    let t0 = std::time::Instant::now();
    let (rows, stats) = Coordinator::new(opts)
        .run_sweep_with_stats(&points, backend.as_mut())
        .map_err(|e| format!("{e:#}"))?;
    let dt = t0.elapsed();
    let mut t = TextTable::new(
        "sweep results",
        &["bench", "config", "MACR", "speedup", "E-impr", "proc", "caches"],
    );
    for r in &rows {
        t.row(vec![
            workloads::display_name(&r.bench).into(),
            r.config_name.clone(),
            format!("{:.1}%", r.macr.ratio() * 100.0),
            fnum(r.result.speedup, 2),
            fnum(r.result.improvement, 2),
            fnum(r.result.ratio_proc, 2),
            fnum(r.result.ratio_cache, 2),
        ]);
    }
    println!("{}", t.render());
    eprintln!("{}", format_stats(&stats, dt.as_secs_f64()));
    if let Some(csv) = args.flag("csv") {
        std::fs::write(csv, t.to_csv()).map_err(|e| e.to_string())?;
        eprintln!("wrote {csv}");
    }
    Ok(())
}

/// `eva-cim explore`: sweep tech × cache-config for one or more benchmarks
/// and print the Pareto grid + frontier (the cross-technology
/// generalization of the paper's Figs 14–16).
fn cmd_explore(args: &cli::Args) -> Result<(), String> {
    let benches: Vec<String> = match (args.flag("bench"), args.flag("benches")) {
        (Some(b), None) => vec![b.to_string()],
        (None, Some(bs)) => bs.split(',').map(|s| s.trim().to_string()).collect(),
        (Some(_), Some(_)) => {
            return Err("pass either --bench or --benches, not both".into())
        }
        (None, None) => {
            return Err(
                "usage: eva-cim explore --bench <b> [--techs t1,t2] \
                 [--configs c1,c2,c3] [--cim both] [--cache-dir DIR] [--resume]"
                    .into(),
            )
        }
    };
    let bench_refs: Vec<&str> = benches.iter().map(|s| s.as_str()).collect();
    let techs: Vec<Technology> = match args.flag("techs") {
        // the advertised default: every registered technology
        None | Some("all") => Technology::all(),
        Some(ts) => ts
            .split(',')
            .map(|t| parse_tech(t.trim()))
            .collect::<Result<_, _>>()?,
    };
    let presets: Vec<String> = args
        .flag_or("configs", "c1,c2,c3")
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let preset_refs: Vec<&str> = presets.iter().map(|s| s.as_str()).collect();
    let cim = CimLevels::from_name(&args.flag_or("cim", "both"))
        .ok_or_else(|| format!("unknown cim levels '{}'", args.flag_or("cim", "both")))?;
    let rule = parse_rule(&args.flag_or("rule", "any"))?;
    let opts = sweep_opts_from_args(args)?;
    let mut backend = make_backend(&args.flag_or("backend", "auto"), &techs)?;
    eprintln!(
        "explore: {} benches x {} techs x {} configs = {} points, backend={}",
        bench_refs.len(),
        techs.len(),
        preset_refs.len(),
        bench_refs.len() * techs.len() * preset_refs.len(),
        backend.name(),
    );
    let out = experiments::explore(
        &bench_refs,
        &techs,
        &preset_refs,
        cim,
        rule,
        opts,
        backend.as_mut(),
    )
    .map_err(|e| format!("{e:#}"))?;
    println!("{}", out.grid.render());
    println!("{}", out.frontier.render());
    if let Some(csv) = args.flag("csv") {
        std::fs::write(csv, out.grid.to_csv()).map_err(|e| e.to_string())?;
        eprintln!("wrote {csv}");
    }
    Ok(())
}

fn cmd_table(args: &cli::Args) -> Result<(), String> {
    let id = args
        .positional
        .get(1)
        .ok_or("usage: eva-cim table <id> (table3|table5|table6|fig11..fig16|calib)")?;
    let opts = sweep_opts_from_args(args)?;
    // the paper tables/figures only evaluate the AOT-covered pair
    let mut backend = make_backend(
        &args.flag_or("backend", "auto"),
        &[Technology::SRAM, Technology::FEFET],
    )?;
    let err = |e: anyhow::Error| format!("{e:#}");
    let table = match id.as_str() {
        "table3" => experiments::table3(),
        "fig11" => experiments::fig11(),
        "table5" => experiments::table5(backend.as_mut(), opts.scale).map_err(err)?,
        "fig12" => experiments::fig12(20, opts.scale).map_err(err)?,
        "fig13" => experiments::fig13(opts).map_err(err)?,
        "table6" => experiments::table6(opts, backend.as_mut()).map_err(err)?,
        "fig14" => experiments::fig14(opts, backend.as_mut()).map_err(err)?,
        "fig15" => experiments::fig15(opts, backend.as_mut()).map_err(err)?,
        "fig16" => experiments::fig16(opts, backend.as_mut()).map_err(err)?,
        _ => return Err(format!("unknown table id '{id}'")),
    };
    println!("{}", table.render());
    if let Some(csv) = args.flag("csv") {
        std::fs::write(csv, table.to_csv()).map_err(|e| e.to_string())?;
        eprintln!("wrote {csv}");
    }
    Ok(())
}

fn cmd_validate(args: &cli::Args) -> Result<(), String> {
    let mut backend =
        make_backend(&args.flag_or("backend", "auto"), &[Technology::SRAM])?;
    let t5 = experiments::table5(backend.as_mut(), 0).map_err(|e| format!("{e:#}"))?;
    println!("{}", t5.render());
    let t12 = experiments::fig12(20, 0).map_err(|e| format!("{e:#}"))?;
    println!("{}", t12.render());
    Ok(())
}

fn cmd_sensitivity(args: &cli::Args) -> Result<(), String> {
    let bench = args
        .positional
        .get(1)
        .ok_or("usage: eva-cim sensitivity <bench> [flags]")?;
    let cfg = build_config(args)?;
    let scale = args.usize_flag("scale", 0)?;
    let mut rt = PjrtRuntime::load(&PjrtRuntime::default_dir())
        .map_err(|e| format!("sensitivity needs the PJRT artifacts: {e:#}"))?;
    let prog = workloads::build(bench, scale, 42)
        .ok_or_else(|| format!("unknown benchmark '{bench}'"))?;
    let trace = simulate(&prog, &cfg, Limits::default()).map_err(|e| e.to_string())?;
    let analysis = analyze(&trace, &cfg, LocalityRule::AnyCache);
    let reshaped = reshape(&trace, &analysis.selection, &cfg);
    let inputs = ProfileInputs::new(&cfg, &reshaped);
    let (g1, g2) = rt.sensitivity(&[inputs]).map_err(|e| format!("{e:#}"))?;
    println!("d(total CiM energy)/d(cfg) for {bench} on {}:", cfg.name);
    let names = ["capacity(B)", "assoc", "line", "banks", "tech*", "level*"];
    let mut t = TextTable::new("(* discrete — gradient not actionable)",
                               &["param", "dE/dp (L1)", "dE/dp (L2)"]);
    for i in 0..names.len() {
        t.row(vec![names[i].into(), format!("{:+.3e}", g1[0][i]), format!("{:+.3e}", g2[0][i])]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_calib() -> Result<(), String> {
    println!("{}", experiments::table3().render());
    println!("{}", experiments::fig11().render());
    let u = calib::static_unit_energy();
    let mut t = TextTable::new(
        "static per-event unit energies (pJ) — energy/calib.rs",
        &["counter", "pJ/event"],
    );
    for (i, name) in eva_cim::reshape::counters::COUNTER_NAMES.iter().enumerate() {
        if u[i] != 0.0 {
            t.row(vec![name.to_string(), fnum(u[i], 1)]);
        }
    }
    println!("{}", t.render());
    Ok(())
}

const USAGE: &str = "usage: eva-cim <list|run|asm|sweep|explore|table|validate|sensitivity|calib> [flags]
try: eva-cim list";

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match cli::Args::parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{USAGE}");
            return ExitCode::FAILURE;
        }
    };
    // custom technologies first: every later flag may reference them
    if let Err(e) = load_tech_files(&args) {
        eprintln!("error: {e}");
        return ExitCode::FAILURE;
    }
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("");
    let result = match cmd {
        "list" => cmd_list(),
        "run" => cmd_run(&args),
        "asm" => cmd_asm(&args),
        "sweep" => cmd_sweep(&args),
        "explore" => cmd_explore(&args),
        "table" => cmd_table(&args),
        "validate" => cmd_validate(&args),
        "sensitivity" => cmd_sensitivity(&args),
        "calib" => cmd_calib(),
        "" | "help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(format!("unknown command '{other}'\n{USAGE}")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}
