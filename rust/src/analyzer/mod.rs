//! The analysis stage — the cornerstone of Eva-CiM (paper §IV).
//!
//! * [`stream`] — the online, bounded-window analyzer (the production core)
//! * [`rut`] — Register Usage Table + Index Hash Table (Algorithm 1 step 1)
//! * [`idg`] — Instruction Dependency Graph construction (Algorithm 2)
//! * [`select`] — offloading-candidate partition + locality (Alg. 1 step 3)
//! * [`macr`] — memory-access conversion ratio (Fig 13 metric)
//! * [`baseline`] — the compile-time classifier of [23] (Fig 12 comparator)
//!
//! [`analyze`] is the batch API: a thin adapter that feeds a materialized
//! trace through the streaming core.  The legacy whole-forest
//! implementation survives as [`analyze_batch`] — it is the independent
//! oracle the streaming path is proven byte-identical against
//! (`tests/streaming_equivalence.rs`).

pub mod baseline;
pub mod idg;
pub mod macr;
pub mod rut;
pub mod select;
pub mod stream;

pub use idg::{build_forest, CimOp, IdgForest};
pub use macr::Macr;
pub use select::{select, Candidate, LocalityRule, Selection};
pub use stream::{
    CandidateRecord, CandidateSink, CollectCandidates, OnlineAnalyzer, StreamOutcome,
};

use crate::config::SystemConfig;
use crate::probes::Trace;

/// Full analysis result for one trace.
pub struct Analysis {
    /// offloading candidates + rejection accounting
    pub selection: Selection,
    /// memory-access conversion ratio accounting
    pub macr: Macr,
    /// IDG statistics: (total nodes, eligible nodes)
    pub idg_nodes: (u64, u64),
}

/// Assemble the batch-shaped [`Analysis`] from a finished stream: sort the
/// collected candidates into program order (the batch report order) and
/// copy the aggregates over.
pub fn analysis_from_stream(out: StreamOutcome, sink: CollectCandidates) -> Analysis {
    let mut candidates = sink.candidates;
    candidates.sort_by_key(|c| c.root_seq);
    Analysis {
        selection: Selection {
            candidates,
            rejected_locality: out.rejected_locality,
            rejected_no_loads: out.rejected_no_loads,
            rejected_dram: out.rejected_dram,
        },
        macr: out.macr,
        idg_nodes: out.idg_nodes,
    }
}

/// Run the complete analysis stage on a trace under `cfg`'s CiM placement.
///
/// Batch adapter over the streaming core: results are identical to the
/// legacy [`analyze_batch`], but the analysis itself runs in O(window)
/// state even though the input here is already materialized.
pub fn analyze(trace: &Trace, cfg: &SystemConfig, rule: LocalityRule) -> Analysis {
    let mut oa = OnlineAnalyzer::new(cfg.cim_levels, rule, CollectCandidates::default());
    for is in &trace.ciq {
        oa.push(is);
    }
    let (out, sink) = oa.finish();
    analysis_from_stream(out, sink)
}

/// The legacy batch implementation: build the whole IDG forest, then
/// select globally.  Kept as the equivalence oracle and reference
/// implementation of Algorithms 1–2.
pub fn analyze_batch(trace: &Trace, cfg: &SystemConfig, rule: LocalityRule) -> Analysis {
    let forest = build_forest(&trace.ciq);
    let eligible = forest.nodes.iter().filter(|n| n.eligible).count() as u64;
    let total = forest.nodes.len() as u64;
    let selection = select(&forest, &trace.ciq, cfg.cim_levels, rule);
    let macr = macr::compute(&trace.ciq, &selection);
    Analysis { selection, macr, idg_nodes: (total, eligible) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::Asm;
    use crate::sim::{simulate, Limits};

    #[test]
    fn analyze_end_to_end() {
        let mut a = Asm::new("t");
        let buf = a.data.alloc_i32("buf", &[1, 2, 3, 4, 5, 6, 7, 8]);
        a.li(1, buf as i32);
        a.lw(9, 1, 0);
        for _ in 0..3 {
            a.lw(2, 1, 0);
            a.lw(3, 1, 4);
            a.add(4, 2, 3);
            a.sw(4, 1, 8);
        }
        a.halt();
        let cfg = SystemConfig::default();
        let t = simulate(&a.assemble(), &cfg, Limits::default()).unwrap();
        let an = analyze(&t, &cfg, LocalityRule::AnyCache);
        assert!(!an.selection.candidates.is_empty());
        assert!(an.macr.ratio() > 0.3);
        assert!(an.idg_nodes.1 <= an.idg_nodes.0);
    }
}
