//! Memory Access Conversion Ratio (MACR) — paper §VI-C / Fig 13.
//!
//! MACR = (memory accesses with proper locality that CiM operations can
//! replace) / (all regular memory accesses).  The breakdown splits the
//! convertible accesses by the cache level that owned the data (Fig 13
//! bottom: L1 accesses vs other accesses).

use crate::probes::{IState, MemLevel};

use super::select::Selection;

/// MACR metrics for one program/config.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Macr {
    /// total data-side memory accesses (loads + stores) in the trace
    pub total_accesses: u64,
    /// accesses replaced by CiM ops (claimed loads + absorbed stores)
    pub convertible: u64,
    /// convertible accesses whose data was in L1
    pub convertible_l1: u64,
    /// convertible accesses whose data was in L2 (or moved)
    pub convertible_other: u64,
    /// number of CiM operations that replace them
    pub cim_ops: u64,
}

impl Macr {
    /// The MACR itself: convertible / total accesses (0 for empty traces).
    pub fn ratio(&self) -> f64 {
        if self.total_accesses == 0 {
            0.0
        } else {
            self.convertible as f64 / self.total_accesses as f64
        }
    }

    /// Fraction of convertible accesses whose data sat in L1 (Fig 13
    /// bottom).
    pub fn l1_share(&self) -> f64 {
        if self.convertible == 0 {
            0.0
        } else {
            self.convertible_l1 as f64 / self.convertible as f64
        }
    }
}

/// Compute MACR from a selection over a trace.
pub fn compute(ciq: &[IState], sel: &Selection) -> Macr {
    let mut m = Macr {
        total_accesses: ciq.iter().filter(|i| i.mem.is_some()).count() as u64,
        ..Default::default()
    };
    for c in &sel.candidates {
        m.cim_ops += c.members.len() as u64;
        for &ls in &c.loads {
            m.convertible += 1;
            match ciq[ls as usize].mem.unwrap().level {
                MemLevel::L1 => m.convertible_l1 += 1,
                _ => m.convertible_other += 1,
            }
        }
        if let Some(ss) = c.absorbed_store {
            m.convertible += 1;
            match ciq[ss as usize].mem.unwrap().level {
                MemLevel::L1 => m.convertible_l1 += 1,
                _ => m.convertible_other += 1,
            }
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyzer::idg::build_forest;
    use crate::analyzer::select::{select, LocalityRule};
    use crate::asm::Asm;
    use crate::config::{CimLevels, SystemConfig};
    use crate::sim::{simulate, Limits};

    #[test]
    fn macr_in_unit_interval_and_counts_consistent() {
        let mut a = Asm::new("t");
        let buf = a.data.alloc_i32("buf", &[1, 2, 3, 4, 5, 6, 7, 8]);
        a.li(1, buf as i32);
        a.lw(9, 1, 0);
        // 4 convertible patterns + some non-convertible traffic
        for k in 0..4 {
            a.lw(2, 1, 0);
            a.lw(3, 1, 4);
            a.add(4, 2, 3);
            a.sw(4, 1, 8 + 4 * k);
        }
        a.lw(5, 1, 12);
        a.mul(6, 5, 5);
        a.sw(6, 1, 16);
        a.halt();
        let prog = a.assemble();
        let t = simulate(&prog, &SystemConfig::default(), Limits::default()).unwrap();
        let f = build_forest(&t.ciq);
        let sel = select(&f, &t.ciq, CimLevels::Both, LocalityRule::AnyCache);
        let m = compute(&t.ciq, &sel);
        assert!(m.ratio() > 0.0 && m.ratio() <= 1.0, "macr {}", m.ratio());
        assert_eq!(m.convertible, m.convertible_l1 + m.convertible_other);
        assert!(m.convertible <= m.total_accesses);
        assert!(m.cim_ops > 0);
    }

    #[test]
    fn zero_when_nothing_selected() {
        let mut a = Asm::new("t");
        a.li(1, 1);
        a.mul(2, 1, 1);
        a.halt();
        let prog = a.assemble();
        let t = simulate(&prog, &SystemConfig::default(), Limits::default()).unwrap();
        let f = build_forest(&t.ciq);
        let sel = select(&f, &t.ciq, CimLevels::Both, LocalityRule::AnyCache);
        let m = compute(&t.ciq, &sel);
        assert_eq!(m.ratio(), 0.0);
    }
}
