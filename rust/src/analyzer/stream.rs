//! Online, bounded-window analysis — the streaming core of the analyzer.
//!
//! The batch pipeline (paper Algorithms 1–2) materializes the whole
//! committed-instruction queue, builds the full IDG forest, then selects
//! offloading candidates in one global pass.  [`OnlineAnalyzer`] produces
//! *identical* results (see `tests/streaming_equivalence.rs`) from a
//! single forward pass over the commit stream, retaining only the *live*
//! instructions in a slab:
//!
//! * **Producer resolution is O(1) and needs no history.**  The RUT/IHT
//!   pair exists so a consumer can find its operand's producer without
//!   searching; online, the producer of register `r` is simply the last
//!   committed write to `r`, tracked in a `last_write` array.  A producer
//!   is therefore always still *live* (un-overwritten) when consumed, so
//!   it is always still in the slab.
//! * **A value's fate is sealed by its overwrite.**  Once the destination
//!   register of an instruction is rewritten, nothing later in the stream
//!   can consume it: its consumer summary is final and no future IDG node
//!   can attach to it.  We call such an entry *closed*; closed entries
//!   that no claim group needs are freed immediately.
//! * **Claims only interact inside connected dependency groups.**  The
//!   batch selector visits eligible roots deepest-first and claims
//!   subtrees; two roots can only contend when their subtrees share an
//!   instruction, which makes them members of the same weakly-connected
//!   group of IDG edges.  The analyzer tracks those groups with a
//!   union–find over slab entries and *retires* a group — running the
//!   exact batch selection order over just its members — the moment every
//!   member is closed.  Retired entries are freed.
//! * **Consumer lists are summarized, not stored.**  Selection needs a
//!   node's consumers only to count *outside* consumers and to identify a
//!   lone absorbable store.  Consumers that can never become tree members
//!   (stores, branches, non-CiM ops, ineligible nodes) fold into a
//!   counter plus one sample record, so a base-pointer register consumed
//!   by every access in a long run costs O(1), not O(trace).
//!
//! Peak memory is O(live dependency state): open values, plus claim
//! groups awaiting their last overwrite.  Loop-structured programs
//! (registers rewritten every iteration) hold a few dozen entries
//! regardless of instruction count; the degenerate worst case is one
//! connected eligible region spanning the whole program — exactly the
//! case where the batch forest is irreducible too.
//!
//! Candidates are announced to a [`CandidateSink`] as they are finalized,
//! carrying the per-instruction payloads reshaping needs, so downstream
//! counters fold incrementally and nothing requires the materialized
//! trace.

use std::collections::HashSet;

use crate::config::CimLevels;
use crate::probes::{IState, InstrInfo, MemLevel};

use super::idg::{cim_op_of, CimOp};
use super::macr::Macr;
use super::select::{Candidate, LocalityRule};

/// One finalized offloading candidate plus the instruction payloads that
/// reshaping needs (aligned with `candidate.members` / `candidate.loads`).
pub struct CandidateRecord {
    /// the finalized candidate (members, loads, level, op kinds)
    pub candidate: Candidate,
    /// instruction payloads of `candidate.members`, same order
    pub member_infos: Vec<InstrInfo>,
    /// instruction payloads of `candidate.loads`, same order
    pub load_infos: Vec<InstrInfo>,
    /// payload of `candidate.absorbed_store`, when present
    pub absorbed: Option<InstrInfo>,
}

/// Receives candidates as the analyzer finalizes them.
pub trait CandidateSink {
    /// Called once per finalized candidate, in retirement order.  The
    /// record is handed over *by value*: the analyzer is done with it, so
    /// a sink that keeps (parts of) it takes ownership instead of cloning
    /// heap payloads on the hot path.
    fn on_candidate(&mut self, rec: CandidateRecord);
}

/// The adapter sink for the batch API: keep the candidates, drop the
/// instruction payloads.
#[derive(Default)]
pub struct CollectCandidates {
    /// every candidate announced so far, in retirement order
    pub candidates: Vec<Candidate>,
}

impl CandidateSink for CollectCandidates {
    fn on_candidate(&mut self, rec: CandidateRecord) {
        self.candidates.push(rec.candidate);
    }
}

/// Aggregate analysis results of one stream (everything `analyze`
/// reports, minus the candidate list — that went to the sink).
#[derive(Clone, Copy, Debug, Default)]
pub struct StreamOutcome {
    /// memory-access conversion ratio accounting
    pub macr: Macr,
    /// (total IDG nodes, eligible IDG nodes)
    pub idg_nodes: (u64, u64),
    /// accepted offloading candidates
    pub candidates: u64,
    /// eligible subtrees rejected by locality / placement constraints
    pub rejected_locality: u64,
    /// eligible subtrees rejected for having no load operands at all
    pub rejected_no_loads: u64,
    /// eligible subtrees rejected because an operand lived in DRAM
    pub rejected_dram: u64,
    /// maximum number of live instructions held at once (the streaming
    /// window)
    pub peak_window: usize,
}

/// Slab index of a live entry.
type Slot = u32;

/// IDG child edge (the streaming twin of `idg::Child`).  Node edges carry
/// the child's eligibility so subtree walks never dereference ineligible
/// children — those may already be freed.
#[derive(Clone, Copy, Debug)]
enum SChild {
    /// immediate / absent / zero-register operand
    Imm,
    /// pre-trace register value — not offloadable
    Init,
    /// produced by a non-CiM, non-load instruction — not offloadable
    External,
    /// leaf load (slot of the load's live entry)
    Load(Slot),
    /// another CiM node
    Node { slot: Slot, eligible: bool },
}

/// The one consumer record a node retains: the first consumer that can
/// never become a tree member (the absorbed-store candidate).
#[derive(Clone, Copy)]
struct OutsideRec {
    seq: u64,
    /// `Some` when that consumer is a store; `data_is_this` marks the
    /// store's *data* slot (operand 1), the absorbed-store condition.
    store: Option<StoreUse>,
}

#[derive(Clone, Copy)]
struct StoreUse {
    data_is_this: bool,
    info: InstrInfo,
}

/// IDG node payload for a CiM-supported instruction.
struct NodeData {
    op: CimOp,
    children: [SChild; 2],
    eligible: bool,
    subtree_loads: u32,
    /// total consumer edges (one per source slot, like the batch CSR)
    edges_total: u32,
    /// consumer seqs that are eligible CiM nodes — the only consumers
    /// that may end up *inside* a candidate; bounded by the claim group
    member_edges: Vec<u64>,
    /// consumer edges that can never be members (stores, branches,
    /// non-CiM ops, ineligible nodes)
    outside_count: u32,
    /// the first such edge — only consulted when `outside_count == 1`
    first_outside: Option<OutsideRec>,
}

/// Per-claim-group bookkeeping, stored on the union–find root.
struct CompData {
    /// slots of all group members (eligible nodes + their leaf loads)
    members: Vec<Slot>,
    /// members whose destination register has not been overwritten yet
    open_count: u32,
}

/// One live instruction.
struct Entry {
    seq: u64,
    info: InstrInfo,
    /// destination register not yet overwritten (value still consumable)
    open: bool,
    node: Option<NodeData>,
    /// member of the claim union–find (eligible node or consumed load)
    uf_member: bool,
    /// union–find parent slot (self = root)
    uf_parent: Slot,
    /// group payload while this entry is a union–find root
    comp: Option<Box<CompData>>,
}

/// The streaming analyzer: a [`crate::probes::TraceSink`] that performs
/// IDG construction, candidate selection, MACR accounting and candidate
/// emission online.
pub struct OnlineAnalyzer<S: CandidateSink> {
    rule: LocalityRule,
    cim_levels: CimLevels,
    sink: S,
    /// slot of the last committed write per architectural register
    last_write: [Option<Slot>; crate::isa::NUM_REGS as usize],
    /// live entries; `None` slots are on the free list
    slab: Vec<Option<Entry>>,
    free: Vec<Slot>,
    live: usize,
    peak_window: usize,
    started: bool,
    next_seq: u64,
    // aggregates
    total_nodes: u64,
    eligible_nodes: u64,
    macr: Macr,
    candidate_count: u64,
    rejected_locality: u64,
    rejected_no_loads: u64,
    rejected_dram: u64,
}

impl<S: CandidateSink> OnlineAnalyzer<S> {
    /// An analyzer for one commit stream under the given CiM placement and
    /// locality rule; finalized candidates are announced to `sink`.
    pub fn new(cim_levels: CimLevels, rule: LocalityRule, sink: S) -> Self {
        Self {
            rule,
            cim_levels,
            sink,
            last_write: [None; crate::isa::NUM_REGS as usize],
            slab: Vec::new(),
            free: Vec::new(),
            live: 0,
            peak_window: 0,
            started: false,
            next_seq: 0,
            total_nodes: 0,
            eligible_nodes: 0,
            macr: Macr::default(),
            candidate_count: 0,
            rejected_locality: 0,
            rejected_no_loads: 0,
            rejected_dram: 0,
        }
    }

    #[inline]
    fn entry(&self, s: Slot) -> &Entry {
        self.slab[s as usize].as_ref().expect("stale slot")
    }

    #[inline]
    fn entry_mut(&mut self, s: Slot) -> &mut Entry {
        self.slab[s as usize].as_mut().expect("stale slot")
    }

    fn alloc(&mut self, e: Entry) -> Slot {
        self.live += 1;
        self.peak_window = self.peak_window.max(self.live);
        match self.free.pop() {
            Some(s) => {
                self.slab[s as usize] = Some(e);
                s
            }
            None => {
                self.slab.push(Some(e));
                (self.slab.len() - 1) as Slot
            }
        }
    }

    fn release(&mut self, s: Slot) {
        debug_assert!(self.slab[s as usize].is_some(), "double free");
        self.slab[s as usize] = None;
        self.free.push(s);
        self.live -= 1;
    }

    /// Consume one committed instruction.
    pub fn push(&mut self, is: &IState) {
        let seq = is.seq;
        if self.started {
            debug_assert_eq!(seq, self.next_seq, "commit stream must be dense");
        }
        self.started = true;
        self.next_seq = seq + 1;
        let instr = is.instr;
        let info = InstrInfo::of(is);
        if is.mem.is_some() {
            self.macr.total_accesses += 1;
        }
        let track = !matches!(self.cim_levels, CimLevels::None);

        // ---- resolve source producers (the online RUT/IHT) ---------------
        let srcs = instr.sources();
        let mut producers: [Option<Slot>; 2] = [None, None];
        for slot in 0..2 {
            if let Some(r) = srcs[slot] {
                producers[slot] = self.last_write[r as usize];
            }
        }

        // ---- IDG node construction (Algorithm 2, one step) ---------------
        let mut union_targets: [Option<Slot>; 2] = [None, None];
        let mut node_eligible = false;
        let node = cim_op_of(instr.op).map(|op| {
            let mut children = [SChild::Imm, SChild::Imm];
            let mut eligible = true;
            let mut loads = 0u32;
            for slot in 0..2 {
                children[slot] = match srcs[slot] {
                    None => SChild::Imm,
                    Some(_) => match producers[slot] {
                        None => {
                            eligible = false;
                            SChild::Init
                        }
                        Some(p) => {
                            let pe = self.entry(p);
                            if pe.info.instr.op.is_load() {
                                loads += 1;
                                union_targets[slot] = Some(p);
                                SChild::Load(p)
                            } else if let Some(pn) = pe.node.as_ref() {
                                if pn.eligible {
                                    loads += pn.subtree_loads;
                                    union_targets[slot] = Some(p);
                                } else {
                                    eligible = false;
                                }
                                SChild::Node { slot: p, eligible: pn.eligible }
                            } else {
                                eligible = false;
                                SChild::External
                            }
                        }
                    },
                };
            }
            node_eligible = eligible;
            NodeData {
                op,
                children,
                eligible,
                subtree_loads: loads,
                edges_total: 0,
                member_edges: Vec::new(),
                outside_count: 0,
                first_outside: None,
            }
        });
        if node.is_some() {
            self.total_nodes += 1;
            if node_eligible {
                self.eligible_nodes += 1;
            }
        }

        // ---- record consumer edges on producer nodes ---------------------
        // One edge per source slot, mirroring the batch CSR's duplicates.
        // Only this instruction's member-candidacy (an *eligible* CiM
        // node can end up inside a candidate; nothing else can) decides
        // whether the edge is kept by seq or folded into the summary.
        let is_member_candidate = node_eligible; // node implied eligible
        let is_store = instr.op.is_store();
        for (slot, p) in producers.iter().enumerate() {
            if let Some(p) = *p {
                let pe = self.entry_mut(p);
                if let Some(nd) = pe.node.as_mut() {
                    nd.edges_total += 1;
                    if is_member_candidate {
                        nd.member_edges.push(seq);
                    } else {
                        nd.outside_count += 1;
                        if nd.first_outside.is_none() {
                            let store = if is_store {
                                Some(StoreUse { data_is_this: slot == 1, info })
                            } else {
                                None
                            };
                            nd.first_outside = Some(OutsideRec { seq, store });
                        }
                    }
                }
            }
        }

        // ---- allocate the live entry if anything can still need it --------
        let open = instr.dest().is_some();
        let keep = open || (node_eligible && track);
        let slot = if keep {
            Some(self.alloc(Entry {
                seq,
                info,
                open,
                node,
                uf_member: false,
                uf_parent: 0,
                comp: None,
            }))
        } else {
            None
        };

        // ---- claim-group wiring (eligible nodes only) ---------------------
        // With CiM disabled entirely, selection is a no-op in the batch
        // path too, so no groups ever form and entries die on overwrite.
        if node_eligible && track {
            let s = slot.expect("eligible node is always kept");
            self.uf_add(s);
            for t in union_targets.into_iter().flatten() {
                self.uf_add(t);
                self.uf_union(s, t);
            }
            // a value-less eligible node (dest r0, all-immediate
            // operands) may already be complete
            let root = self.find(s);
            if self.entry(root).comp.as_ref().map_or(false, |c| c.open_count == 0) {
                self.retire(root);
            }
        }

        // ---- destination bookkeeping: overwrite closes the old value ------
        if let Some(rd) = instr.dest() {
            if let Some(old) = self.last_write[rd as usize] {
                self.close(old);
            }
            self.last_write[rd as usize] = slot;
        }
    }

    /// End of stream: every still-open value is dead now; close them all,
    /// retiring the remaining groups, and hand back the aggregates.
    pub fn finish(mut self) -> (StreamOutcome, S) {
        for s in 0..self.slab.len() {
            if self.slab[s].as_ref().map_or(false, |e| e.open) {
                self.close(s as Slot);
            }
        }
        debug_assert_eq!(self.live, 0, "all entries must retire at finish");
        let outcome = StreamOutcome {
            macr: self.macr,
            idg_nodes: (self.total_nodes, self.eligible_nodes),
            candidates: self.candidate_count,
            rejected_locality: self.rejected_locality,
            rejected_no_loads: self.rejected_no_loads,
            rejected_dram: self.rejected_dram,
            peak_window: self.peak_window,
        };
        (outcome, self.sink)
    }

    // ---- union–find over slab entries ------------------------------------

    fn uf_add(&mut self, s: Slot) {
        let e = self.entry_mut(s);
        if !e.uf_member {
            e.uf_member = true;
            e.uf_parent = s;
            let open_count = e.open as u32;
            e.comp = Some(Box::new(CompData { members: vec![s], open_count }));
        }
    }

    fn find(&mut self, s: Slot) -> Slot {
        let mut root = s;
        loop {
            let p = self.entry(root).uf_parent;
            if p == root {
                break;
            }
            root = p;
        }
        // path compression
        let mut cur = s;
        while cur != root {
            let next = self.entry(cur).uf_parent;
            self.entry_mut(cur).uf_parent = root;
            cur = next;
        }
        root
    }

    fn uf_union(&mut self, a: Slot, b: Slot) {
        let ra = self.find(a);
        let rb = self.find(b);
        if ra == rb {
            return;
        }
        let la = self.entry(ra).comp.as_ref().map_or(0, |c| c.members.len());
        let lb = self.entry(rb).comp.as_ref().map_or(0, |c| c.members.len());
        let (win, lose) = if la >= lb { (ra, rb) } else { (rb, ra) };
        let lost = self
            .entry_mut(lose)
            .comp
            .take()
            .expect("losing root without comp");
        self.entry_mut(lose).uf_parent = win;
        let wc = self
            .entry_mut(win)
            .comp
            .as_mut()
            .expect("winning root without comp");
        wc.members.extend(lost.members);
        wc.open_count += lost.open_count;
    }

    /// The destination of `s` was overwritten: its value is dead.
    fn close(&mut self, s: Slot) {
        let e = self.entry_mut(s);
        debug_assert!(e.open, "closing an already-closed entry");
        e.open = false;
        let member = e.uf_member;
        if member {
            let root = self.find(s);
            let done = {
                let comp = self
                    .entry_mut(root)
                    .comp
                    .as_mut()
                    .expect("live group without comp data");
                comp.open_count -= 1;
                comp.open_count == 0
            };
            if done {
                self.retire(root);
            }
        } else {
            // nothing can reference a closed non-member: free it now
            self.release(s);
        }
    }

    // ---- group retirement: the batch selection pass, scoped ---------------

    /// Every member of this group is closed: no future instruction can
    /// consume or claim any of them, so the candidate partition of the
    /// group is now decidable.  Visit its eligible roots deepest-first —
    /// exactly the batch order — with claim sets scoped to the group
    /// (claims cannot cross groups by construction), then free the
    /// group's entries.
    fn retire(&mut self, root: Slot) {
        let comp = self
            .entry_mut(root)
            .comp
            .take()
            .expect("retiring a group twice");
        let mut roots: Vec<Slot> = comp
            .members
            .iter()
            .copied()
            .filter(|&s| self.entry(s).node.as_ref().map_or(false, |n| n.eligible))
            .collect();
        roots.sort_unstable_by_key(|&s| std::cmp::Reverse(self.entry(s).seq));
        let mut claimed_nodes: HashSet<u64> = HashSet::new();
        let mut claimed_loads: HashSet<u64> = HashSet::new();
        for r in roots {
            self.try_candidate(r, &mut claimed_nodes, &mut claimed_loads);
        }
        for &m in &comp.members {
            self.release(m);
        }
    }

    /// One root's selection attempt — a line-for-line mirror of the batch
    /// `select` loop body (`select.rs`), over live entries.
    fn try_candidate(
        &mut self,
        root: Slot,
        claimed_nodes: &mut HashSet<u64>,
        claimed_loads: &mut HashSet<u64>,
    ) {
        let root_seq = self.entry(root).seq;
        if claimed_nodes.contains(&root_seq) {
            return;
        }
        // subtree walk in the exact batch order (LIFO, slot order)
        let mut member_slots_all: Vec<Slot> = Vec::new();
        let mut all_load_slots: Vec<Slot> = Vec::new();
        let mut stack = vec![root];
        while let Some(s) = stack.pop() {
            member_slots_all.push(s);
            let children = self.entry(s).node.as_ref().expect("member is a node").children;
            for c in children {
                match c {
                    SChild::Load(ls) => all_load_slots.push(ls),
                    SChild::Node { slot, eligible: true } => stack.push(slot),
                    _ => {}
                }
            }
        }
        let mut members: Vec<u64> = Vec::with_capacity(member_slots_all.len());
        let mut member_slots: Vec<Slot> = Vec::with_capacity(member_slots_all.len());
        for &ms in &member_slots_all {
            let sq = self.entry(ms).seq;
            if !claimed_nodes.contains(&sq) {
                members.push(sq);
                member_slots.push(ms);
            }
        }
        if members.is_empty() {
            return;
        }
        if all_load_slots.is_empty() {
            self.rejected_no_loads += 1;
            return;
        }

        // ---- locality: where do the leaf operands live? -------------------
        let mut levels: Vec<MemLevel> = Vec::with_capacity(all_load_slots.len());
        let mut banks: Vec<u32> = Vec::new();
        let mut dram = false;
        for &ls in &all_load_slots {
            let mem = self.entry(ls).info.mem.expect("load without access info");
            if mem.level == MemLevel::Dram {
                dram = true;
            }
            levels.push(mem.level);
            banks.push(mem.bank);
        }
        if dram {
            self.rejected_dram += 1;
            return;
        }
        let deepest = if levels.iter().any(|&l| l == MemLevel::L2) {
            MemLevel::L2
        } else {
            MemLevel::L1
        };
        let same_level = levels.iter().all(|&l| l == levels[0]);
        let same_bank = same_level && banks.iter().all(|&b| b == banks[0]);
        let ok = match self.rule {
            LocalityRule::AnyCache => true,
            LocalityRule::SameLevel => same_level,
            LocalityRule::SameBank => same_bank,
        };
        if !ok {
            self.rejected_locality += 1;
            return;
        }

        // ---- placement: is a CiM array available at that level? -----------
        let level = if match deepest {
            MemLevel::L1 => self.cim_levels.l1(),
            MemLevel::L2 => self.cim_levels.l2(),
            MemLevel::Dram => false,
        } {
            deepest
        } else if deepest == MemLevel::L2 && self.cim_levels.l1() {
            MemLevel::L1
        } else {
            self.rejected_locality += 1;
            return;
        };
        let exec_is_l2 = level == MemLevel::L2;
        let moves = levels
            .iter()
            .filter(|&&l| (l == MemLevel::L2) != exec_is_l2)
            .count() as u32;

        // ---- store absorption & readbacks ---------------------------------
        // `outside` of the batch loop = consumers outside this candidate:
        // the permanently-outside summary plus any member-candidate edge
        // whose node did not end up in `members`.
        let is_member = |sq: u64| members.contains(&sq);
        let mut absorbed_store: Option<u64> = None;
        let mut absorbed_info: Option<InstrInfo> = None;
        let mut readbacks = 0u32;
        for (i, &ms) in member_slots.iter().enumerate() {
            let m_seq = members[i];
            let nd = self.entry(ms).node.as_ref().expect("member is a node");
            if nd.edges_total == 0 {
                continue;
            }
            let outside_members = nd
                .member_edges
                .iter()
                .filter(|&&cs| !is_member(cs))
                .count();
            let total_outside = nd.outside_count as usize + outside_members;
            let absorbable = m_seq == root_seq
                && total_outside == 1
                && nd.outside_count == 1
                && nd
                    .first_outside
                    .as_ref()
                    .map_or(false, |c| c.store.map_or(false, |su| su.data_is_this))
                && absorbed_store.is_none();
            if absorbable {
                let c = nd.first_outside.as_ref().expect("checked above");
                absorbed_store = Some(c.seq);
                absorbed_info = c.store.map(|su| su.info);
            } else if total_outside > 0 {
                readbacks += 1;
            }
        }

        // ---- claim ---------------------------------------------------------
        let mut loads: Vec<u64> = Vec::new();
        let mut load_slots: Vec<Slot> = Vec::new();
        let mut shared: Vec<u64> = Vec::new();
        for &ls in &all_load_slots {
            let sq = self.entry(ls).seq;
            if claimed_loads.contains(&sq) {
                shared.push(sq);
            } else {
                claimed_loads.insert(sq);
                loads.push(sq);
                load_slots.push(ls);
            }
        }
        for &m in &members {
            claimed_nodes.insert(m);
        }
        let ops: Vec<CimOp> = member_slots
            .iter()
            .map(|&ms| self.entry(ms).node.as_ref().expect("member is a node").op)
            .collect();

        // ---- aggregates (the online macr::compute) -------------------------
        self.macr.cim_ops += members.len() as u64;
        for &ls in &load_slots {
            self.macr.convertible += 1;
            match self.entry(ls).info.mem.expect("load without access info").level {
                MemLevel::L1 => self.macr.convertible_l1 += 1,
                _ => self.macr.convertible_other += 1,
            }
        }
        if let Some(info) = &absorbed_info {
            self.macr.convertible += 1;
            match info.mem.expect("store without access info").level {
                MemLevel::L1 => self.macr.convertible_l1 += 1,
                _ => self.macr.convertible_other += 1,
            }
        }
        self.candidate_count += 1;

        // ---- emit ----------------------------------------------------------
        let member_infos: Vec<InstrInfo> =
            member_slots.iter().map(|&ms| self.entry(ms).info).collect();
        let load_infos: Vec<InstrInfo> =
            load_slots.iter().map(|&ls| self.entry(ls).info).collect();
        let rec = CandidateRecord {
            candidate: Candidate {
                root_seq,
                members,
                loads,
                shared_loads: shared,
                absorbed_store,
                readbacks,
                moves,
                level,
                ops,
            },
            member_infos,
            load_infos,
            absorbed: absorbed_info,
        };
        self.sink.on_candidate(rec);
    }
}

impl<S: CandidateSink> crate::probes::TraceSink for OnlineAnalyzer<S> {
    fn on_commit(&mut self, is: IState) {
        self.push(&is);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::Asm;
    use crate::config::SystemConfig;
    use crate::sim::{simulate, Limits};

    fn stream_all(
        trace: &crate::probes::Trace,
        cfg: &SystemConfig,
        rule: LocalityRule,
    ) -> (StreamOutcome, Vec<Candidate>) {
        let mut oa =
            OnlineAnalyzer::new(cfg.cim_levels, rule, CollectCandidates::default());
        for is in &trace.ciq {
            oa.push(is);
        }
        let (out, sink) = oa.finish();
        let mut cands = sink.candidates;
        cands.sort_by_key(|c| c.root_seq);
        (out, cands)
    }

    #[test]
    fn canonical_pattern_selected_online() {
        let mut a = Asm::new("t");
        let buf = a.data.alloc_i32("buf", &[3, 4, 0]);
        a.li(1, buf as i32);
        a.lw(9, 1, 0); // warm the line
        a.lw(2, 1, 0);
        a.lw(3, 1, 4);
        a.add(4, 2, 3);
        a.sw(4, 1, 8);
        a.halt();
        let cfg = SystemConfig::default();
        let t = simulate(&a.assemble(), &cfg, Limits::default()).unwrap();
        let (out, cands) = stream_all(&t, &cfg, LocalityRule::AnyCache);
        assert_eq!(cands.len(), 1);
        let c = &cands[0];
        assert_eq!(c.loads.len(), 2);
        assert!(c.absorbed_store.is_some());
        assert_eq!(c.readbacks, 0);
        assert_eq!(out.candidates, 1);
        assert!(out.macr.ratio() > 0.0);
    }

    #[test]
    fn window_stays_bounded_on_loops() {
        // the loop counter lives in memory, so every register is
        // rewritten each iteration and the live set must stay O(loop
        // body) no matter how many iterations run
        let mut a = Asm::new("loop");
        let buf = a.data.alloc_i32("buf", &[1, 2, 0, 0, 0, 0, 0, 0]);
        a.li(1, buf as i32);
        a.li(9, buf as i32 + 16); // counter cell
        let top = a.label("top");
        a.bind(top);
        a.lw(2, 1, 0);
        a.lw(3, 1, 4);
        a.add(4, 2, 3);
        a.sw(4, 1, 8);
        a.lw(7, 9, 0);
        a.addi(7, 7, 1);
        a.sw(7, 9, 0);
        a.li(8, 500);
        a.bne(7, 8, top);
        a.halt();
        let cfg = SystemConfig::default();
        let t = simulate(&a.assemble(), &cfg, Limits::default()).unwrap();
        assert!(t.committed > 4000, "committed {}", t.committed);
        let (out, _) = stream_all(&t, &cfg, LocalityRule::AnyCache);
        assert!(
            out.peak_window < 64,
            "window {} should not scale with the {}-instruction trace",
            out.peak_window,
            t.committed
        );
    }

    #[test]
    fn base_pointer_consumers_stay_o1() {
        // a base register consumed by every access must not accumulate
        // per-consumer state: its node folds consumers into a counter
        let mut a = Asm::new("base");
        let buf = a.data.alloc_i32("buf", &[0; 64]);
        a.li(1, buf as i32);
        for k in 0..200 {
            a.lw(2, 1, (k % 16) * 4);
        }
        a.halt();
        let cfg = SystemConfig::default();
        let t = simulate(&a.assemble(), &cfg, Limits::default()).unwrap();
        let (out, _) = stream_all(&t, &cfg, LocalityRule::AnyCache);
        // live set: the li node + at most two in-flight loads
        assert!(out.peak_window < 8, "window {}", out.peak_window);
    }

    #[test]
    fn cim_none_emits_nothing_but_still_counts() {
        let mut a = Asm::new("t");
        let buf = a.data.alloc_i32("buf", &[3, 4, 0]);
        a.li(1, buf as i32);
        a.lw(2, 1, 0);
        a.lw(3, 1, 4);
        a.add(4, 2, 3);
        a.sw(4, 1, 8);
        a.halt();
        let mut cfg = SystemConfig::default();
        cfg.cim_levels = CimLevels::None;
        let t = simulate(&a.assemble(), &cfg, Limits::default()).unwrap();
        let (out, cands) = stream_all(&t, &cfg, LocalityRule::AnyCache);
        assert!(cands.is_empty());
        assert_eq!(out.candidates, 0);
        assert_eq!(
            out.rejected_no_loads + out.rejected_locality + out.rejected_dram,
            0
        );
        assert!(out.idg_nodes.0 > 0, "node counting is placement-independent");
        assert_eq!(out.macr.total_accesses, 3);
    }
}
