//! Instruction Dependency Graph construction — paper Algorithm 2.
//!
//! A node is created for every committed instruction whose opcode the CiM
//! module supports (the `CiMSet`).  Children are the producers of its source
//! operands, resolved in O(1) through the RUT/IHT; a child is a *leaf* when
//! it is a load (LEAF_TRUE in the paper) or an immediate.  Producers that
//! are neither loads nor CiM-supported ops break offloadability for that
//! operand (`Child::External`), as do operands holding pre-trace register
//! values (`Child::Init`).

use crate::isa::Opcode;
use crate::probes::IState;

use super::rut::{build as build_tables, Iht, Rut};

/// CiM-supported operation kinds (Table III columns).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CimOp {
    /// bitwise OR on the sense amps
    Or,
    /// bitwise AND on the sense amps
    And,
    /// bitwise XOR on the sense amps (also the compare class, see
    /// [`cim_op_of`])
    Xor,
    /// word-width addition on the sense-amp adder (ADDW32)
    Add,
}

impl CimOp {
    /// Lower-case operation name (`"or"`, `"and"`, `"xor"`, `"add"`).
    pub fn name(&self) -> &'static str {
        match self {
            CimOp::Or => "or",
            CimOp::And => "and",
            CimOp::Xor => "xor",
            CimOp::Add => "add",
        }
    }
}

/// The CiM-supported instruction set: which opcodes can become in-memory
/// operations.  Immediate variants are included (Fig 4(b)).  As in the
/// STT-CiM design of [23] and the compute caches of [20]:
/// * subtraction runs on the sense-amp adder → ADD energy/latency class;
/// * comparison is a bitwise SA operation (no carry chain) → XOR class,
///   i.e. read-like latency per Fig 11.
pub fn cim_op_of(op: Opcode) -> Option<CimOp> {
    use Opcode::*;
    match op {
        Or | Ori => Some(CimOp::Or),
        And | Andi => Some(CimOp::And),
        Xor | Xori => Some(CimOp::Xor),
        Slt | Slti | Sltu => Some(CimOp::Xor),
        Add | Addi | Sub => Some(CimOp::Add),
        _ => None,
    }
}

/// One operand edge in the IDG.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Child {
    /// no operand in this slot
    None,
    /// immediate operand
    Imm,
    /// initial (pre-trace) register value — not offloadable
    Init,
    /// produced by a non-CiM, non-load instruction (seq) — not offloadable
    External(u64),
    /// leaf load (LEAF_TRUE): seq of the load instruction
    Load(u64),
    /// another CiM-supported node (index into the forest arena)
    Node(usize),
}

/// IDG node: one CiM-supported committed instruction.
#[derive(Clone, Debug)]
pub struct IdgNode {
    /// CIQ sequence index of the instruction
    pub seq: u64,
    /// CiM operation class of the instruction
    pub op: CimOp,
    /// producers of the two source operands
    pub children: [Child; 2],
    /// every child is Imm / Load / eligible Node — the node can execute
    /// entirely in memory
    pub eligible: bool,
    /// number of load leaves in this node's eligible subtree
    pub subtree_loads: u32,
}

/// Sentinel in [`IdgForest::node_idx`]: the instruction is not a CiM op.
pub const NO_NODE: u32 = u32::MAX;

/// The whole forest plus consumer cross-references.
///
/// All cross-references are dense seq-indexed vectors, not hash maps: the
/// analyzer walks millions of committed instructions per sweep and hashing
/// dominated its profile (see EXPERIMENTS.md §Perf).
pub struct IdgForest {
    /// node arena, in commit order
    pub nodes: Vec<IdgNode>,
    /// seq -> node index (NO_NODE when the instruction is not a CiM op)
    pub node_idx: Vec<u32>,
    /// CSR consumer lists: consumers of seq s are
    /// `consumer_data[consumer_ptr[s]..consumer_ptr[s+1]]`
    consumer_ptr: Vec<u32>,
    consumer_data: Vec<u64>,
    /// the Register Usage Table the forest was built with
    pub rut: Rut,
    /// the Index Hash Table the forest was built with
    pub iht: Iht,
}

impl IdgForest {
    /// Node index for a CiM-op instruction seq (panics otherwise).
    pub fn node_of_seq(&self, seq: u64) -> usize {
        let i = self.node_idx[seq as usize];
        debug_assert_ne!(i, NO_NODE);
        i as usize
    }

    /// Consumer seqs of the value produced at `seq`.
    pub fn consumers(&self, seq: u64) -> &[u64] {
        let s = seq as usize;
        &self.consumer_data
            [self.consumer_ptr[s] as usize..self.consumer_ptr[s + 1] as usize]
    }
}

/// Build the IDG forest for a committed instruction queue (Algorithm 2).
///
/// Single forward pass: because producers always precede consumers in the
/// CIQ, child nodes already exist when a node is created, and eligibility
/// and subtree load counts fold bottom-up without recursion.
pub fn build_forest(ciq: &[IState]) -> IdgForest {
    let (rut, iht) = build_tables(ciq);
    let mut nodes: Vec<IdgNode> = Vec::new();
    let mut node_idx: Vec<u32> = vec![NO_NODE; ciq.len()];

    // consumer cross-reference in CSR form: count, prefix-sum, fill —
    // two flat allocations instead of one Vec per instruction
    let mut consumer_ptr = vec![0u32; ciq.len() + 1];
    for (k, _) in ciq.iter().enumerate() {
        for src in iht.entries[k].sources.iter().flatten() {
            if let Some(p) = rut.producer(src.0, src.1) {
                consumer_ptr[p as usize + 1] += 1;
            }
        }
    }
    for i in 0..ciq.len() {
        consumer_ptr[i + 1] += consumer_ptr[i];
    }
    let mut consumer_data = vec![0u64; *consumer_ptr.last().unwrap() as usize];
    let mut fill = consumer_ptr.clone();
    for (k, is) in ciq.iter().enumerate() {
        for src in iht.entries[k].sources.iter().flatten() {
            if let Some(p) = rut.producer(src.0, src.1) {
                consumer_data[fill[p as usize] as usize] = is.seq;
                fill[p as usize] += 1;
            }
        }
    }

    for (k, is) in ciq.iter().enumerate() {

        let Some(op) = cim_op_of(is.instr.op) else { continue };

        let mut children = [Child::None, Child::None];
        let mut eligible = true;
        let mut loads = 0u32;
        for slot in 0..2 {
            children[slot] = match iht.entries[k].sources[slot] {
                None => {
                    // reg-imm ops carry the immediate in slot 1; reads of r0
                    // are constants too
                    if slot == 1 || is.instr.op.has_imm() {
                        Child::Imm
                    } else {
                        Child::Imm // r0 source ≡ constant zero
                    }
                }
                Some((r, n)) => match rut.producer(r, n) {
                    None => {
                        eligible = false;
                        Child::Init
                    }
                    Some(p) => {
                        let pis = &ciq[p as usize];
                        if pis.instr.op.is_load() {
                            loads += 1;
                            Child::Load(p)
                        } else if node_idx[p as usize] != NO_NODE {
                            let ni = node_idx[p as usize] as usize;
                            let n: &IdgNode = &nodes[ni];
                            if n.eligible {
                                loads += n.subtree_loads;
                            } else {
                                eligible = false;
                            }
                            Child::Node(ni)
                        } else {
                            eligible = false;
                            Child::External(p)
                        }
                    }
                },
            };
        }
        node_idx[k] = nodes.len() as u32;
        nodes.push(IdgNode { seq: is.seq, op, children, eligible, subtree_loads: loads });
    }

    IdgForest { nodes, node_idx, consumer_ptr, consumer_data, rut, iht }
}

impl IdgForest {
    /// Collect the eligible subtree rooted at `idx`: member node indices
    /// (including the root) and leaf load seqs.
    pub fn subtree(&self, idx: usize) -> (Vec<usize>, Vec<u64>) {
        debug_assert!(self.nodes[idx].eligible);
        let mut members = Vec::new();
        let mut loads = Vec::new();
        let mut stack = vec![idx];
        while let Some(i) = stack.pop() {
            members.push(i);
            for c in self.nodes[i].children {
                match c {
                    Child::Load(seq) => loads.push(seq),
                    Child::Node(ci) if self.nodes[ci].eligible => stack.push(ci),
                    _ => {}
                }
            }
        }
        (members, loads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::Asm;
    use crate::config::SystemConfig;
    use crate::sim::{simulate, Limits};

    fn trace(asm: Asm) -> Vec<IState> {
        let prog = asm.assemble();
        simulate(&prog, &SystemConfig::default(), Limits::default())
            .unwrap()
            .ciq
    }

    /// The canonical Load-Load-OP-Store pattern of Fig 3/4(a).
    #[test]
    fn load_load_op_store_pattern() {
        let mut a = Asm::new("t");
        let buf = a.data.alloc_i32("buf", &[3, 4, 0]);
        a.li(1, buf as i32);
        a.lw(2, 1, 0);
        a.lw(3, 1, 4);
        a.add(4, 2, 3);
        a.sw(4, 1, 8);
        a.halt();
        let ciq = trace(a);
        let f = build_forest(&ciq);
        // nodes: the li (addi) and the add
        assert_eq!(f.nodes.len(), 2);
        let add = f.nodes.iter().find(|n| n.op == CimOp::Add && n.subtree_loads == 2)
            .expect("add node with two load leaves");
        assert!(add.eligible);
        assert!(matches!(add.children[0], Child::Load(_)));
        assert!(matches!(add.children[1], Child::Load(_)));
        // the add's consumer is the store
        let consumers = f.consumers(add.seq);
        assert_eq!(consumers.len(), 1);
        assert_eq!(ciq[consumers[0] as usize].instr.op, Opcode::Sw);
    }

    /// Fig 4(b): one operand replaced by an immediate.
    #[test]
    fn load_imm_variant() {
        let mut a = Asm::new("t");
        let buf = a.data.alloc_i32("buf", &[3]);
        a.li(1, buf as i32);
        a.lw(2, 1, 0);
        a.addi(3, 2, 7);
        a.sw(3, 1, 0);
        a.halt();
        let ciq = trace(a);
        let f = build_forest(&ciq);
        let node = f.nodes.iter().find(|n| n.subtree_loads == 1).unwrap();
        assert!(node.eligible);
        assert!(matches!(node.children[0], Child::Load(_)));
        assert_eq!(node.children[1], Child::Imm);
    }

    /// Fig 4(c)/Fig 5: chained ops form one connected multi-node tree.
    #[test]
    fn chained_ops_fold_subtree_loads() {
        let mut a = Asm::new("t");
        let buf = a.data.alloc_i32("buf", &[1, 2, 3, 4]);
        a.li(1, buf as i32);
        a.lw(2, 1, 0);
        a.lw(3, 1, 4);
        a.add(4, 2, 3); // node A: 2 loads
        a.lw(5, 1, 8);
        a.add(6, 4, 5); // node B: A + 1 load = 3 loads
        a.sw(6, 1, 12);
        a.halt();
        let ciq = trace(a);
        let f = build_forest(&ciq);
        let b = f.nodes.iter().find(|n| n.subtree_loads == 3).expect("root");
        assert!(b.eligible);
        let bi = f.node_of_seq(b.seq);
        let (members, loads) = f.subtree(bi);
        assert_eq!(members.len(), 2);
        assert_eq!(loads.len(), 3);
    }

    /// A mul in the dataflow breaks eligibility (External child).
    #[test]
    fn external_producer_breaks_eligibility() {
        let mut a = Asm::new("t");
        let buf = a.data.alloc_i32("buf", &[3, 4]);
        a.li(1, buf as i32);
        a.lw(2, 1, 0);
        a.lw(3, 1, 4);
        a.mul(4, 2, 3); // not in CiMSet
        a.add(5, 4, 2); // add with External child
        a.sw(5, 1, 0);
        a.halt();
        let ciq = trace(a);
        let f = build_forest(&ciq);
        let add = f
            .nodes
            .iter()
            .find(|n| matches!(n.children[0], Child::External(_)))
            .expect("add with external child");
        assert!(!add.eligible);
    }

    /// Values live before the trace (Init) are not offloadable.
    #[test]
    fn init_value_not_offloadable() {
        let mut a = Asm::new("t");
        // r9 never written: initial value
        a.add(4, 9, 9);
        a.halt();
        let ciq = trace(a);
        let f = build_forest(&ciq);
        assert_eq!(f.nodes.len(), 1);
        assert!(!f.nodes[0].eligible);
        assert_eq!(f.nodes[0].children[0], Child::Init);
    }

    /// Edges only point backwards in commit order.
    #[test]
    fn edges_point_backwards() {
        let mut a = Asm::new("t");
        let buf = a.data.alloc_i32("buf", &[1, 2, 3, 4, 5, 6, 7, 8]);
        a.li(1, buf as i32);
        let top = a.label("top");
        a.li(2, 0);
        a.li(5, 0);
        a.bind(top);
        a.lw(3, 1, 0);
        a.lw(4, 1, 4);
        a.add(3, 3, 4);
        a.sw(3, 1, 8);
        a.addi(2, 2, 1);
        a.li(6, 4);
        a.bne(2, 6, top);
        a.halt();
        let ciq = trace(a);
        let f = build_forest(&ciq);
        for n in &f.nodes {
            for c in n.children {
                match c {
                    Child::Load(s) | Child::External(s) => assert!(s < n.seq),
                    Child::Node(i) => assert!(f.nodes[i].seq < n.seq),
                    _ => {}
                }
            }
        }
    }
}
