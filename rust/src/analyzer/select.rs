//! Offloading-candidate selection — paper Algorithm 1 step 3.
//!
//! Partitions the IDG forest into maximal eligible subtrees, then applies
//! the data-locality and CiM-placement constraints: every leaf operand must
//! reside in a CiM-capable cache level; operands split across levels incur
//! an operand *move* (the paper's §IV-C write-back-and-forward), and the
//! op executes at the deepest involved level.

use crate::config::CimLevels;
use crate::probes::{IState, MemLevel};

use super::idg::{CimOp, IdgForest};

/// How strictly operand locality is enforced (DESIGN.md ablation axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LocalityRule {
    /// operands may live in different cache levels; cross-level operands
    /// are moved to the deepest level first (paper §IV-C, the default)
    AnyCache,
    /// all operands must already sit in the same cache level
    SameLevel,
    /// all operands must sit in the same level *and* the same bank
    SameBank,
}

impl LocalityRule {
    /// Canonical name — the single source of truth shared by the CLI
    /// parser and the sweep-cache key (coordinator/key.rs).
    pub fn name(&self) -> &'static str {
        match self {
            LocalityRule::AnyCache => "any",
            LocalityRule::SameLevel => "level",
            LocalityRule::SameBank => "bank",
        }
    }

    /// Parse a canonical name or CLI alias.
    pub fn from_name(s: &str) -> Option<LocalityRule> {
        match s.to_ascii_lowercase().as_str() {
            "any" | "anycache" => Some(LocalityRule::AnyCache),
            "level" | "samelevel" => Some(LocalityRule::SameLevel),
            "bank" | "samebank" => Some(LocalityRule::SameBank),
            _ => None,
        }
    }
}

/// One offloading candidate: a connected group of CiM-suitable nodes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Candidate {
    /// CIQ seq of the subtree root (the outermost consumer)
    pub root_seq: u64,
    /// CiM-op instruction seqs removed from the CPU stream (root first)
    pub members: Vec<u64>,
    /// load seqs newly claimed (removed) by this candidate
    pub loads: Vec<u64>,
    /// loads shared with an earlier candidate (data reread in memory; the
    /// instruction was already removed there)
    pub shared_loads: Vec<u64>,
    /// store absorbed by the CiM op (result written in place)
    pub absorbed_store: Option<u64>,
    /// member results still consumed by the CPU → must be read back
    pub readbacks: u32,
    /// cross-level operand movements (write-back + forward)
    pub moves: u32,
    /// cache level the CiM ops execute in
    pub level: MemLevel,
    /// op kind per member (same order as `members`)
    pub ops: Vec<CimOp>,
}

impl Candidate {
    /// Instructions eliminated from the CPU pipeline.
    pub fn removed_count(&self) -> u64 {
        (self.members.len() + self.loads.len()) as u64
            + self.absorbed_store.is_some() as u64
    }
}

/// Selection output.
#[derive(Debug, Default)]
pub struct Selection {
    /// accepted offloading candidates, in program order
    pub candidates: Vec<Candidate>,
    /// eligible subtrees rejected by locality / placement constraints
    pub rejected_locality: u64,
    /// eligible subtrees rejected for having no load operands at all
    pub rejected_no_loads: u64,
    /// eligible subtrees rejected because an operand lived in DRAM
    pub rejected_dram: u64,
}

/// Select offloading candidates from the forest.
///
/// Roots are visited in descending commit order so the outermost consumer
/// claims the largest connected region first (Fig 5's partition).
pub fn select(
    forest: &IdgForest,
    ciq: &[IState],
    cim_levels: CimLevels,
    rule: LocalityRule,
) -> Selection {
    let mut sel = Selection::default();
    if matches!(cim_levels, CimLevels::None) {
        return sel;
    }
    // dense seq-indexed claim bitmaps (hashing dominated the profile)
    let mut claimed_nodes = vec![false; ciq.len()];
    let mut claimed_loads = vec![false; ciq.len()];

    // candidate roots: eligible nodes, deepest-seq first
    let mut order: Vec<usize> = (0..forest.nodes.len())
        .filter(|&i| forest.nodes[i].eligible)
        .collect();
    order.sort_by_key(|&i| std::cmp::Reverse(forest.nodes[i].seq));

    for root in order {
        if claimed_nodes[forest.nodes[root].seq as usize] {
            continue;
        }
        let (member_idxs, all_loads) = forest.subtree(root);
        // skip members already claimed by a larger tree (shouldn't happen
        // with descending order, but a node can be shared by two parents)
        let members: Vec<u64> = member_idxs
            .iter()
            .map(|&i| forest.nodes[i].seq)
            .filter(|s| !claimed_nodes[*s as usize])
            .collect();
        if members.is_empty() {
            continue;
        }
        if all_loads.is_empty() {
            sel.rejected_no_loads += 1;
            continue;
        }

        // ---- locality: where do the leaf operands live? -------------------
        let mut levels: Vec<MemLevel> = Vec::with_capacity(all_loads.len());
        let mut banks: Vec<u32> = Vec::new();
        let mut dram = false;
        for &ls in &all_loads {
            let mem = ciq[ls as usize].mem.expect("load without access info");
            if mem.level == MemLevel::Dram {
                dram = true;
            }
            levels.push(mem.level);
            banks.push(mem.bank);
        }
        if dram {
            sel.rejected_dram += 1;
            continue;
        }
        let deepest = if levels.iter().any(|&l| l == MemLevel::L2) {
            MemLevel::L2
        } else {
            MemLevel::L1
        };
        let same_level = levels.iter().all(|&l| l == levels[0]);
        let same_bank = same_level && banks.iter().all(|&b| b == banks[0]);
        let ok = match rule {
            LocalityRule::AnyCache => true,
            LocalityRule::SameLevel => same_level,
            LocalityRule::SameBank => same_bank,
        };
        if !ok {
            sel.rejected_locality += 1;
            continue;
        }

        // ---- placement: is a CiM array available at that level? -----------
        let level = if match deepest {
            MemLevel::L1 => cim_levels.l1(),
            MemLevel::L2 => cim_levels.l2(),
            MemLevel::Dram => false,
        } {
            deepest
        } else if deepest == MemLevel::L2 && cim_levels.l1() {
            // operands bubble up into L1 on access; run the op there
            MemLevel::L1
        } else {
            // L1-resident data with CiM only in L2: wholesale relocation
            // would cost more than it saves — the access stays regular
            // (this is why L2-only trails in Fig 15: L1 soaks up most
            // accesses in a complete hierarchy)
            sel.rejected_locality += 1;
            continue;
        };
        // operand moves: leaves not already at the execution level
        let exec_is_l2 = level == MemLevel::L2;
        let moves = levels
            .iter()
            .filter(|&&l| (l == MemLevel::L2) != exec_is_l2)
            .count() as u32;

        // ---- store absorption & readbacks ---------------------------------
        // members are few; linear membership test beats hashing here
        let is_member = |s: u64| members.contains(&s);
        let mut absorbed_store = None;
        let mut readbacks = 0u32;
        for &m in &members {
            let consumers = forest.consumers(m);
            if consumers.is_empty() {
                continue;
            }
            let outside: Vec<u64> = consumers
                .iter()
                .copied()
                .filter(|c| !is_member(*c))
                .collect();
            if m == forest.nodes[root].seq
                && outside.len() == 1
                && ciq[outside[0] as usize].instr.op.is_store()
                // the store's *data* operand must be this value (slot 1)
                && forest.iht.entries[outside[0] as usize].sources[1]
                    .map(|(r, n)| forest.rut.producer(r, n) == Some(m))
                    .unwrap_or(false)
                && absorbed_store.is_none()
            {
                absorbed_store = Some(outside[0]);
            } else if !outside.is_empty() {
                readbacks += 1;
            }
        }

        // ---- claim ---------------------------------------------------------
        let mut loads = Vec::new();
        let mut shared = Vec::new();
        for &ls in &all_loads {
            if claimed_loads[ls as usize] {
                shared.push(ls);
            } else {
                claimed_loads[ls as usize] = true;
                loads.push(ls);
            }
        }
        for &m in &members {
            claimed_nodes[m as usize] = true;
        }
        let ops = members
            .iter()
            .map(|&m| forest.nodes[forest.node_of_seq(m)].op)
            .collect();

        sel.candidates.push(Candidate {
            root_seq: forest.nodes[root].seq,
            members,
            loads,
            shared_loads: shared,
            absorbed_store,
            readbacks,
            moves,
            level,
            ops,
        });
    }
    // report in program order
    sel.candidates.sort_by_key(|c| c.root_seq);
    sel
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyzer::idg::build_forest;
    use crate::asm::Asm;
    use crate::config::SystemConfig;
    use crate::sim::{simulate, Limits};

    fn run(asm: Asm) -> (Vec<IState>, IdgForest) {
        let prog = asm.assemble();
        let ciq = simulate(&prog, &SystemConfig::default(), Limits::default())
            .unwrap()
            .ciq;
        let f = build_forest(&ciq);
        (ciq, f)
    }

    fn lls_program() -> Asm {
        // the canonical pattern, with data pre-touched so operands are in L1
        let mut a = Asm::new("t");
        let buf = a.data.alloc_i32("buf", &[3, 4, 0]);
        a.li(1, buf as i32);
        a.lw(9, 1, 0); // warm the line
        a.lw(2, 1, 0);
        a.lw(3, 1, 4);
        a.add(4, 2, 3);
        a.sw(4, 1, 8);
        a.halt();
        a
    }

    #[test]
    fn selects_load_load_op_store() {
        let (ciq, f) = run(lls_program());
        let sel = select(&f, &ciq, CimLevels::Both, LocalityRule::AnyCache);
        assert_eq!(sel.candidates.len(), 1);
        let c = &sel.candidates[0];
        assert_eq!(c.members.len(), 1);
        assert_eq!(c.ops, vec![CimOp::Add]);
        assert_eq!(c.loads.len(), 2);
        assert!(c.absorbed_store.is_some());
        assert_eq!(c.readbacks, 0);
        assert_eq!(c.level, MemLevel::L1);
        assert_eq!(c.removed_count(), 4); // add + 2 loads + store
    }

    #[test]
    fn cim_none_selects_nothing() {
        let (ciq, f) = run(lls_program());
        let sel = select(&f, &ciq, CimLevels::None, LocalityRule::AnyCache);
        assert!(sel.candidates.is_empty());
    }

    #[test]
    fn readback_when_result_reused() {
        let mut a = Asm::new("t");
        let buf = a.data.alloc_i32("buf", &[3, 4]);
        a.li(1, buf as i32);
        a.lw(9, 1, 0);
        a.lw(2, 1, 0);
        a.lw(3, 1, 4);
        a.add(4, 2, 3);
        a.mul(5, 4, 4); // result consumed by a non-store
        a.sw(5, 1, 0);
        a.halt();
        let (ciq, f) = run(a);
        let sel = select(&f, &ciq, CimLevels::Both, LocalityRule::AnyCache);
        assert_eq!(sel.candidates.len(), 1);
        let c = &sel.candidates[0];
        assert!(c.absorbed_store.is_none());
        assert_eq!(c.readbacks, 1);
    }

    #[test]
    fn pure_imm_trees_rejected() {
        let mut a = Asm::new("t");
        a.li(1, 5);
        a.addi(2, 1, 3);
        a.addi(3, 2, 4);
        a.halt();
        let (ciq, f) = run(a);
        let sel = select(&f, &ciq, CimLevels::Both, LocalityRule::AnyCache);
        assert!(sel.candidates.is_empty());
        assert!(sel.rejected_no_loads >= 1);
    }

    #[test]
    fn cold_loads_from_dram_rejected() {
        // first-touch loads are serviced by DRAM -> candidate rejected
        let mut a = Asm::new("t");
        let buf = a.data.alloc_i32("buf", &[3, 4, 0]);
        a.li(1, buf as i32);
        a.lw(2, 1, 0); // cold: DRAM
        a.addi(4, 2, 1);
        a.sw(4, 1, 8);
        a.halt();
        let (ciq, f) = run(a);
        let sel = select(&f, &ciq, CimLevels::Both, LocalityRule::AnyCache);
        assert!(sel.candidates.is_empty());
        assert_eq!(sel.rejected_dram, 1);
    }

    #[test]
    fn chained_tree_claimed_once() {
        let mut a = Asm::new("t");
        let buf = a.data.alloc_i32("buf", &[1, 2, 3, 4]);
        a.li(1, buf as i32);
        a.lw(9, 1, 0);
        a.lw(2, 1, 0);
        a.lw(3, 1, 4);
        a.add(4, 2, 3);
        a.lw(5, 1, 8);
        a.add(6, 4, 5);
        a.sw(6, 1, 12);
        a.halt();
        let (ciq, f) = run(a);
        let sel = select(&f, &ciq, CimLevels::Both, LocalityRule::AnyCache);
        assert_eq!(sel.candidates.len(), 1);
        let c = &sel.candidates[0];
        assert_eq!(c.members.len(), 2); // both adds in ONE candidate
        assert_eq!(c.loads.len(), 3);
        assert_eq!(c.removed_count(), 2 + 3 + 1);
    }

    #[test]
    fn l2_resident_operand_with_l1_only_cim_runs_in_l1() {
        let (ciq, f) = run(lls_program());
        let sel = select(&f, &ciq, CimLevels::L1Only, LocalityRule::AnyCache);
        assert_eq!(sel.candidates.len(), 1);
        assert_eq!(sel.candidates[0].level, MemLevel::L1);
    }

    #[test]
    fn l2_only_cim_rejects_l1_resident_candidates() {
        // wholesale relocation of L1-resident operands into L2 costs more
        // than it saves; the access stays regular (Fig 15's L2-only gap)
        let (ciq, f) = run(lls_program());
        let sel = select(&f, &ciq, CimLevels::L2Only, LocalityRule::AnyCache);
        assert!(sel.candidates.is_empty());
        assert!(sel.rejected_locality >= 1);
    }
}
