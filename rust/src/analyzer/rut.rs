//! Register Usage Table (RUT) and Index Hash Table (IHT) — paper §IV-B.
//!
//! The RUT keeps, per architectural register, the list of CIQ sequence
//! indices of instructions that wrote it.  The IHT records, per committed
//! instruction, its source registers together with the *position* (`n_i`)
//! each register's write-list had when the instruction committed.  Together
//! they let the IDG builder find the producer of any operand in O(1),
//! avoiding the recursive search Algorithm 2 warns about.

use crate::isa::{NUM_REGS, RegId};
use crate::probes::IState;

/// Per-register commit history of destination writes.
pub struct Rut {
    /// `writes[r]` = CIQ seq indices of instructions with destination `r`
    pub writes: Vec<Vec<u64>>,
}

/// Per-instruction source bookkeeping: `(register, n_i)` pairs, where `n_i`
/// is the number of writes to `register` committed *before* this
/// instruction — so `writes[r][n_i - 1]` is the producer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct IhtEntry {
    /// up to two `(register, n_i)` source records; `None` for unused slots
    pub sources: [Option<(RegId, u32)>; 2],
}

/// The Index Hash Table: one [`IhtEntry`] per committed instruction.
pub struct Iht {
    /// entries in CIQ order (indexed by sequence number)
    pub entries: Vec<IhtEntry>,
}

/// Build RUT and IHT from the committed instruction queue in one pass
/// (Algorithm 1 step 1).
pub fn build(ciq: &[IState]) -> (Rut, Iht) {
    let mut writes: Vec<Vec<u64>> = vec![Vec::new(); NUM_REGS as usize];
    let mut entries = Vec::with_capacity(ciq.len());

    for is in ciq {
        let mut sources = [None, None];
        for (slot, src) in is.instr.sources().into_iter().enumerate() {
            if let Some(r) = src {
                sources[slot] = Some((r, writes[r as usize].len() as u32));
            }
        }
        entries.push(IhtEntry { sources });
        if let Some(rd) = is.instr.dest() {
            writes[rd as usize].push(is.seq);
        }
    }
    (Rut { writes }, Iht { entries })
}

impl Rut {
    /// Sequence index of the instruction that produced the value `r` held
    /// when position `n` was recorded; `None` = initial register value.
    pub fn producer(&self, r: RegId, n: u32) -> Option<u64> {
        if n == 0 {
            None
        } else {
            self.writes[r as usize].get(n as usize - 1).copied()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{FuncUnit, Instruction, Opcode};
    use crate::probes::IState;

    fn istate(seq: u64, instr: Instruction) -> IState {
        IState {
            seq,
            pc: seq as u32,
            instr,
            fu: FuncUnit::IntAlu,
            tick_fetch: 0,
            tick_decode: 0,
            tick_rename: 0,
            tick_dispatch: 0,
            tick_issue: 0,
            tick_complete: 0,
            tick_commit: 0,
            mem: None,
        }
    }

    #[test]
    fn tracks_producers_through_rewrites() {
        // 0: addi r1, r0, 5
        // 1: addi r1, r1, 1     (reads r1 written by 0)
        // 2: add  r2, r1, r1    (reads r1 written by 1, twice)
        let ciq = vec![
            istate(0, Instruction::new(Opcode::Addi, 1, 0, 0, 5)),
            istate(1, Instruction::new(Opcode::Addi, 1, 1, 0, 1)),
            istate(2, Instruction::new(Opcode::Add, 2, 1, 1, 0)),
        ];
        let (rut, iht) = build(&ciq);
        assert_eq!(rut.writes[1], vec![0, 1]);
        assert_eq!(rut.writes[2], vec![2]);

        // instruction 1 read r1 when it had 1 write -> producer = seq 0
        let (r, n) = iht.entries[1].sources[0].unwrap();
        assert_eq!(r, 1);
        assert_eq!(rut.producer(r, n), Some(0));

        // instruction 2 read r1 when it had 2 writes -> producer = seq 1
        let (r, n) = iht.entries[2].sources[0].unwrap();
        assert_eq!(rut.producer(r, n), Some(1));
        let (r2, n2) = iht.entries[2].sources[1].unwrap();
        assert_eq!(rut.producer(r2, n2), Some(1));
    }

    #[test]
    fn initial_values_have_no_producer() {
        let ciq = vec![istate(0, Instruction::new(Opcode::Add, 2, 3, 4, 0))];
        let (rut, iht) = build(&ciq);
        let (r, n) = iht.entries[0].sources[0].unwrap();
        assert_eq!(r, 3);
        assert_eq!(n, 0);
        assert_eq!(rut.producer(r, n), None);
    }

    #[test]
    fn r0_never_tracked() {
        let ciq = vec![
            istate(0, Instruction::new(Opcode::Addi, 0, 0, 0, 5)), // writes r0
            istate(1, Instruction::new(Opcode::Add, 1, 0, 0, 0)),
        ];
        let (rut, iht) = build(&ciq);
        assert!(rut.writes[0].is_empty());
        assert_eq!(iht.entries[1].sources, [None, None]);
    }

    #[test]
    fn store_sources_recorded() {
        // sw r7, 4(r2): reads base r2 (slot 0) and data r7 (slot 1)
        let ciq = vec![
            istate(0, Instruction::new(Opcode::Addi, 7, 0, 0, 1)),
            istate(1, Instruction::new(Opcode::Sw, 0, 2, 7, 4)),
        ];
        let (rut, iht) = build(&ciq);
        let (rdata, n) = iht.entries[1].sources[1].unwrap();
        assert_eq!(rdata, 7);
        assert_eq!(rut.producer(rdata, n), Some(0));
    }
}
