//! Baseline comparator: the compile-time CC/NC/WR classifier of Jain et
//! al. [23] ("Computing in Memory With Spin-Transfer Torque Magnetic RAM"),
//! used for the Fig 12 validation.
//!
//! [23] assumes a single-level non-cacheable scratchpad with ideal locality
//! and classifies memory accesses at compile time into writes (WR),
//! non-convertible reads (NC), and CiM-convertible reads (CC): a read is CC
//! when it is one of the *two* operands of a CiM-suitable op, and every two
//! CC reads are replaced by one CiM instruction.  No dependence chains, no
//! immediate variants, no store absorption — which is why Eva-CiM's IDG
//! finds more convertible accesses (≈65% vs ≈58% on LCS in the paper).

use crate::probes::IState;

use super::idg::cim_op_of;
use super::rut::build as build_tables;

/// Access breakdown in the style of [23].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct JainBreakdown {
    /// memory writes (WR)
    pub writes: u64,
    /// non-convertible reads (NC)
    pub nc_reads: u64,
    /// CiM-convertible reads (CC)
    pub cc_reads: u64,
    /// CiM instructions created (= cc_reads / 2)
    pub cim_instructions: u64,
}

impl JainBreakdown {
    /// All classified memory accesses (WR + NC + CC).
    pub fn total(&self) -> u64 {
        self.writes + self.nc_reads + self.cc_reads
    }

    /// Fraction of memory accesses that become CiM-supported.
    pub fn cim_fraction(&self) -> f64 {
        let t = self.total();
        if t == 0 {
            0.0
        } else {
            self.cc_reads as f64 / t as f64
        }
    }
}

/// Classify the trace the way [23]'s compile-time pass would.
///
/// A read is CC when a CiM-suitable operation consumes it ("reads triggered
/// by CiM instructions"); every two CC reads are replaced by one CiM
/// instruction.  Locality is assumed ideal (single-level SPM), so no
/// level/bank checks apply — but unlike Eva-CiM's IDG, there are no
/// dependence chains and no store absorption.
pub fn classify(ciq: &[IState]) -> JainBreakdown {
    let (rut, iht) = build_tables(ciq);
    let mut out = JainBreakdown::default();
    let mut cc = vec![false; ciq.len()];

    for (k, is) in ciq.iter().enumerate() {
        if cim_op_of(is.instr.op).is_none() {
            continue;
        }
        for src in iht.entries[k].sources.iter().flatten() {
            if let Some(p) = rut.producer(src.0, src.1) {
                if ciq[p as usize].instr.op.is_load() {
                    cc[p as usize] = true;
                }
            }
        }
    }

    for (k, is) in ciq.iter().enumerate() {
        if is.mem.is_none() {
            continue;
        }
        if is.instr.op.is_store() {
            out.writes += 1;
        } else if cc[k] {
            out.cc_reads += 1;
        } else {
            out.nc_reads += 1;
        }
    }
    out.cim_instructions = out.cc_reads / 2;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::Asm;
    use crate::config::SystemConfig;
    use crate::sim::{simulate, Limits};

    fn trace(asm: Asm) -> Vec<IState> {
        simulate(&asm.assemble(), &SystemConfig::default(), Limits::default())
            .unwrap()
            .ciq
    }

    #[test]
    fn classifies_pair_as_cc() {
        let mut a = Asm::new("t");
        let buf = a.data.alloc_i32("buf", &[3, 4, 0]);
        a.li(1, buf as i32);
        a.lw(2, 1, 0);
        a.lw(3, 1, 4);
        a.add(4, 2, 3);
        a.sw(4, 1, 8);
        a.halt();
        let b = classify(&trace(a));
        assert_eq!(b.cc_reads, 2);
        assert_eq!(b.writes, 1);
        assert_eq!(b.nc_reads, 0);
        assert_eq!(b.cim_instructions, 1);
        assert!((b.cim_fraction() - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn pointer_chase_loads_are_nc() {
        // loads feeding only address computation of further loads are NC
        let mut a = Asm::new("t");
        let buf = a.data.alloc_i32("buf", &[4, 8, 0]);
        a.li(1, buf as i32);
        a.lw(2, 1, 0); // feeds the next load's base: NC
        a.lw(3, 2, 0);
        a.mul(4, 3, 3); // mul is not CiM-suitable: its operand load is NC
        a.sw(4, 1, 8);
        a.halt();
        let b = classify(&trace(a));
        assert_eq!(b.cc_reads, 0);
        assert_eq!(b.nc_reads, 2);
    }

    #[test]
    fn mul_pair_not_cc() {
        let mut a = Asm::new("t");
        let buf = a.data.alloc_i32("buf", &[3, 4]);
        a.li(1, buf as i32);
        a.lw(2, 1, 0);
        a.lw(3, 1, 4);
        a.mul(4, 2, 3);
        a.sw(4, 1, 0);
        a.halt();
        let b = classify(&trace(a));
        assert_eq!(b.cc_reads, 0);
        assert_eq!(b.nc_reads, 2);
    }

    #[test]
    fn eva_cim_beats_jain_on_chained_patterns() {
        // a chained reduction with store absorption: the IDG claims the
        // store and the whole chain; [23] only sees the paired reads
        use crate::analyzer::{analyze, LocalityRule};
        use crate::config::SystemConfig;
        let cfg = SystemConfig::default();
        let prog = crate::workloads::build("lcs", 1, 3).unwrap();
        let t = crate::sim::simulate(&prog, &cfg, crate::sim::Limits::default())
            .unwrap();
        let eva = analyze(&t, &cfg, LocalityRule::AnyCache).macr.ratio();
        let jain = classify(&t.ciq).cim_fraction();
        assert!(eva > jain, "eva {eva} !> jain {jain}");
    }
}
