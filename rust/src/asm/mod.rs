//! Assembly front ends: the builder eDSL (used by `workloads/`) and a text
//! assembler for `.s` files (used by the CLI `run --asm`).

pub mod builder;
pub mod parser;
pub mod program;

pub use builder::{Asm, Label};
pub use program::{DataBuilder, DataWord, Program};
