//! Program container: code + initial data image + symbol table.

use std::sync::Arc;

use crate::isa::Instruction;

/// Word-aligned data-memory image entry.
#[derive(Clone, Debug)]
pub struct DataWord {
    /// byte address (word-aligned)
    pub addr: u32,
    /// initial 32-bit value (f32 values are bit-cast)
    pub value: u32,
}

/// A complete EVA32 program: the unit fed to the simulator.
#[derive(Clone, Debug, Default)]
pub struct Program {
    /// program name — a shared handle so every per-run summary can carry
    /// it without re-allocating (sweeps clone it once per simulation)
    pub name: Arc<str>,
    /// the text segment, indexed by absolute instruction index
    pub instrs: Vec<Instruction>,
    /// initial data-memory contents (word granularity)
    pub data: Vec<DataWord>,
    /// named data symbols: (name, base address, size in bytes)
    pub symbols: Vec<(String, u32, u32)>,
    /// total bytes of data memory the program requires
    pub dmem_size: u32,
}

impl Program {
    /// An empty program with a name.
    pub fn new(name: &str) -> Self {
        Self { name: name.into(), ..Default::default() }
    }

    /// Encode the text segment into 64-bit words (the "binary").
    pub fn encode_text(&self) -> Vec<u64> {
        self.instrs.iter().map(|i| i.encode()).collect()
    }

    /// Decode a binary back into a program (no data/symbols).
    pub fn decode_text(name: &str, words: &[u64]) -> Option<Self> {
        let instrs: Option<Vec<_>> =
            words.iter().map(|w| Instruction::decode(*w)).collect();
        Some(Self {
            name: name.into(),
            instrs: instrs?,
            ..Default::default()
        })
    }

    /// Base address of a named data symbol.
    pub fn symbol(&self, name: &str) -> Option<u32> {
        self.symbols
            .iter()
            .find(|(n, _, _)| n == name)
            .map(|(_, addr, _)| *addr)
    }

    /// Full disassembly listing.
    pub fn listing(&self) -> String {
        let mut s = String::new();
        for (i, instr) in self.instrs.iter().enumerate() {
            s.push_str(&format!("{i:6}:  {}\n", instr.disasm()));
        }
        s
    }
}

/// Bump allocator building the initial data image for a workload.
#[derive(Debug, Default)]
pub struct DataBuilder {
    next: u32,
    words: Vec<DataWord>,
    symbols: Vec<(String, u32, u32)>,
}

impl DataBuilder {
    /// An empty image starting at address 0.
    pub fn new() -> Self {
        Self::default()
    }

    /// Reserve `bytes` (rounded up to a word) and name the region.
    pub fn alloc(&mut self, name: &str, bytes: u32) -> u32 {
        let base = self.next;
        let rounded = (bytes + 3) & !3;
        self.symbols.push((name.to_string(), base, rounded));
        self.next += rounded;
        base
    }

    /// Allocate and initialize an i32 array.
    pub fn alloc_i32(&mut self, name: &str, values: &[i32]) -> u32 {
        let base = self.alloc(name, (values.len() * 4) as u32);
        for (i, v) in values.iter().enumerate() {
            self.words.push(DataWord {
                addr: base + (i * 4) as u32,
                value: *v as u32,
            });
        }
        base
    }

    /// Allocate and initialize an f32 array (bit-cast into words).
    pub fn alloc_f32(&mut self, name: &str, values: &[f32]) -> u32 {
        let base = self.alloc(name, (values.len() * 4) as u32);
        for (i, v) in values.iter().enumerate() {
            self.words.push(DataWord {
                addr: base + (i * 4) as u32,
                value: v.to_bits(),
            });
        }
        base
    }

    /// Total bytes allocated so far.
    pub fn size(&self) -> u32 {
        self.next
    }

    /// Merge into a program (consumes the builder).
    pub fn finish(self, prog: &mut Program) {
        prog.data = self.words;
        prog.symbols = self.symbols;
        // leave headroom for stack (64 kB) above the data segment
        prog.dmem_size = self.next + 64 * 1024;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Instruction, Opcode};

    #[test]
    fn encode_decode_text() {
        let mut p = Program::new("t");
        p.instrs.push(Instruction::new(Opcode::Addi, 1, 0, 0, 5));
        p.instrs.push(Instruction::halt());
        let words = p.encode_text();
        let q = Program::decode_text("t", &words).unwrap();
        assert_eq!(q.instrs, p.instrs);
    }

    #[test]
    fn data_builder_layout() {
        let mut db = DataBuilder::new();
        let a = db.alloc_i32("a", &[1, 2, 3]);
        let b = db.alloc_f32("b", &[1.5]);
        assert_eq!(a, 0);
        assert_eq!(b, 12);
        let mut p = Program::new("t");
        db.finish(&mut p);
        assert_eq!(p.symbol("a"), Some(0));
        assert_eq!(p.symbol("b"), Some(12));
        assert_eq!(p.data.len(), 4);
        assert_eq!(p.data[3].value, 1.5f32.to_bits());
        assert!(p.dmem_size >= 16 + 64 * 1024 - 4);
    }

    #[test]
    fn alloc_rounds_to_words() {
        let mut db = DataBuilder::new();
        db.alloc("x", 5);
        let y = db.alloc("y", 4);
        assert_eq!(y, 8);
    }
}
