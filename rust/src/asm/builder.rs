//! Assembler eDSL: the front end the 17 workloads are written in.
//!
//! Labels are first-class: branch/jump targets may be bound after use and
//! are resolved (as absolute instruction indices) at [`Asm::assemble`].

use crate::isa::{freg, Instruction, Opcode, RegId, R0};

use super::program::{DataBuilder, Program};

/// Forward-referencable code label.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Label(usize);

/// The program-under-construction: emitted instructions, labels awaiting
/// resolution, and the data image.  One emitter method per opcode (plus
/// the usual pseudo-instructions: `li`, `mv`, `jump`, `ret`), each
/// returning `&mut Self` for chaining.
#[derive(Debug)]
pub struct Asm {
    name: String,
    instrs: Vec<Instruction>,
    /// label -> bound instruction index
    labels: Vec<Option<usize>>,
    label_names: Vec<String>,
    /// (instruction index, label) pairs whose imm awaits resolution
    fixups: Vec<(usize, Label)>,
    /// the workload's initial data-memory image (allocate via
    /// [`DataBuilder::alloc_i32`] et al.; folded in by [`Asm::assemble`])
    pub data: DataBuilder,
}

impl Asm {
    /// An empty program-under-construction.
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            instrs: Vec::new(),
            labels: Vec::new(),
            label_names: Vec::new(),
            fixups: Vec::new(),
            data: DataBuilder::new(),
        }
    }

    /// Instructions emitted so far (the next instruction's index).
    pub fn len(&self) -> usize {
        self.instrs.len()
    }

    /// No instructions emitted yet?
    pub fn is_empty(&self) -> bool {
        self.instrs.is_empty()
    }

    /// Create an (unbound) label.
    pub fn label(&mut self, name: &str) -> Label {
        self.labels.push(None);
        self.label_names.push(name.to_string());
        Label(self.labels.len() - 1)
    }

    /// Bind `label` to the next emitted instruction.
    pub fn bind(&mut self, label: Label) {
        assert!(
            self.labels[label.0].is_none(),
            "label '{}' bound twice",
            self.label_names[label.0]
        );
        self.labels[label.0] = Some(self.instrs.len());
    }

    fn emit(&mut self, i: Instruction) -> &mut Self {
        self.instrs.push(i);
        self
    }

    fn emit_branch(&mut self, op: Opcode, rs1: RegId, rs2: RegId, l: Label) -> &mut Self {
        self.fixups.push((self.instrs.len(), l));
        self.emit(Instruction::new(op, R0, rs1, rs2, 0))
    }

    // ---- integer reg-reg ---------------------------------------------------
    /// `rd = rs1 + rs2`
    pub fn add(&mut self, rd: RegId, rs1: RegId, rs2: RegId) -> &mut Self {
        self.emit(Instruction::new(Opcode::Add, rd, rs1, rs2, 0))
    }
    /// `rd = rs1 - rs2`
    pub fn sub(&mut self, rd: RegId, rs1: RegId, rs2: RegId) -> &mut Self {
        self.emit(Instruction::new(Opcode::Sub, rd, rs1, rs2, 0))
    }
    /// `rd = rs1 & rs2`
    pub fn and(&mut self, rd: RegId, rs1: RegId, rs2: RegId) -> &mut Self {
        self.emit(Instruction::new(Opcode::And, rd, rs1, rs2, 0))
    }
    /// `rd = rs1 | rs2`
    pub fn or(&mut self, rd: RegId, rs1: RegId, rs2: RegId) -> &mut Self {
        self.emit(Instruction::new(Opcode::Or, rd, rs1, rs2, 0))
    }
    /// `rd = rs1 ^ rs2`
    pub fn xor(&mut self, rd: RegId, rs1: RegId, rs2: RegId) -> &mut Self {
        self.emit(Instruction::new(Opcode::Xor, rd, rs1, rs2, 0))
    }
    /// `rd = rs1 << rs2` (logical)
    pub fn sll(&mut self, rd: RegId, rs1: RegId, rs2: RegId) -> &mut Self {
        self.emit(Instruction::new(Opcode::Sll, rd, rs1, rs2, 0))
    }
    /// `rd = rs1 >> rs2` (logical)
    pub fn srl(&mut self, rd: RegId, rs1: RegId, rs2: RegId) -> &mut Self {
        self.emit(Instruction::new(Opcode::Srl, rd, rs1, rs2, 0))
    }
    /// `rd = rs1 >> rs2` (arithmetic)
    pub fn sra(&mut self, rd: RegId, rs1: RegId, rs2: RegId) -> &mut Self {
        self.emit(Instruction::new(Opcode::Sra, rd, rs1, rs2, 0))
    }
    /// `rd = (rs1 < rs2)` signed
    pub fn slt(&mut self, rd: RegId, rs1: RegId, rs2: RegId) -> &mut Self {
        self.emit(Instruction::new(Opcode::Slt, rd, rs1, rs2, 0))
    }
    /// `rd = (rs1 < rs2)` unsigned
    pub fn sltu(&mut self, rd: RegId, rs1: RegId, rs2: RegId) -> &mut Self {
        self.emit(Instruction::new(Opcode::Sltu, rd, rs1, rs2, 0))
    }
    /// `rd = rs1 * rs2`
    pub fn mul(&mut self, rd: RegId, rs1: RegId, rs2: RegId) -> &mut Self {
        self.emit(Instruction::new(Opcode::Mul, rd, rs1, rs2, 0))
    }
    /// `rd = rs1 / rs2` (signed)
    pub fn div(&mut self, rd: RegId, rs1: RegId, rs2: RegId) -> &mut Self {
        self.emit(Instruction::new(Opcode::Div, rd, rs1, rs2, 0))
    }
    /// `rd = rs1 % rs2` (signed)
    pub fn rem(&mut self, rd: RegId, rs1: RegId, rs2: RegId) -> &mut Self {
        self.emit(Instruction::new(Opcode::Rem, rd, rs1, rs2, 0))
    }

    // ---- integer reg-imm ---------------------------------------------------
    /// `rd = rs1 + imm`
    pub fn addi(&mut self, rd: RegId, rs1: RegId, imm: i32) -> &mut Self {
        self.emit(Instruction::new(Opcode::Addi, rd, rs1, R0, imm))
    }
    /// `rd = rs1 & imm`
    pub fn andi(&mut self, rd: RegId, rs1: RegId, imm: i32) -> &mut Self {
        self.emit(Instruction::new(Opcode::Andi, rd, rs1, R0, imm))
    }
    /// `rd = rs1 | imm`
    pub fn ori(&mut self, rd: RegId, rs1: RegId, imm: i32) -> &mut Self {
        self.emit(Instruction::new(Opcode::Ori, rd, rs1, R0, imm))
    }
    /// `rd = rs1 ^ imm`
    pub fn xori(&mut self, rd: RegId, rs1: RegId, imm: i32) -> &mut Self {
        self.emit(Instruction::new(Opcode::Xori, rd, rs1, R0, imm))
    }
    /// `rd = rs1 << imm` (logical)
    pub fn slli(&mut self, rd: RegId, rs1: RegId, imm: i32) -> &mut Self {
        self.emit(Instruction::new(Opcode::Slli, rd, rs1, R0, imm))
    }
    /// `rd = rs1 >> imm` (logical)
    pub fn srli(&mut self, rd: RegId, rs1: RegId, imm: i32) -> &mut Self {
        self.emit(Instruction::new(Opcode::Srli, rd, rs1, R0, imm))
    }
    /// `rd = rs1 >> imm` (arithmetic)
    pub fn srai(&mut self, rd: RegId, rs1: RegId, imm: i32) -> &mut Self {
        self.emit(Instruction::new(Opcode::Srai, rd, rs1, R0, imm))
    }
    /// `rd = (rs1 < imm)` signed
    pub fn slti(&mut self, rd: RegId, rs1: RegId, imm: i32) -> &mut Self {
        self.emit(Instruction::new(Opcode::Slti, rd, rs1, R0, imm))
    }
    /// `rd = imm << 12` (load upper immediate)
    pub fn lui(&mut self, rd: RegId, imm: i32) -> &mut Self {
        self.emit(Instruction::new(Opcode::Lui, rd, R0, R0, imm))
    }
    /// Load a full 32-bit constant (lui+ori when it doesn't fit an imm).
    pub fn li(&mut self, rd: RegId, value: i32) -> &mut Self {
        self.addi(rd, R0, value)
    }
    /// `rd = rs` (register move pseudo-instruction).
    pub fn mv(&mut self, rd: RegId, rs: RegId) -> &mut Self {
        self.addi(rd, rs, 0)
    }

    // ---- memory --------------------------------------------------------------
    /// `rd = mem32[base + off]`
    pub fn lw(&mut self, rd: RegId, base: RegId, off: i32) -> &mut Self {
        self.emit(Instruction::new(Opcode::Lw, rd, base, R0, off))
    }
    /// `mem32[base + off] = value`
    pub fn sw(&mut self, value: RegId, base: RegId, off: i32) -> &mut Self {
        self.emit(Instruction::new(Opcode::Sw, R0, base, value, off))
    }
    /// `rd = mem8[base + off]` (sign-extended)
    pub fn lb(&mut self, rd: RegId, base: RegId, off: i32) -> &mut Self {
        self.emit(Instruction::new(Opcode::Lb, rd, base, R0, off))
    }
    /// `mem8[base + off] = value`
    pub fn sb(&mut self, value: RegId, base: RegId, off: i32) -> &mut Self {
        self.emit(Instruction::new(Opcode::Sb, R0, base, value, off))
    }
    /// `f{fd} = mem32[base + off]` (float load; `fd` is a float index)
    pub fn flw(&mut self, fd: u8, base: RegId, off: i32) -> &mut Self {
        self.emit(Instruction::new(Opcode::Flw, freg(fd), base, R0, off))
    }
    /// `mem32[base + off] = f{fs}` (float store; `fs` is a float index)
    pub fn fsw(&mut self, fs: u8, base: RegId, off: i32) -> &mut Self {
        self.emit(Instruction::new(Opcode::Fsw, R0, base, freg(fs), off))
    }

    // ---- branches (label-based) ------------------------------------------
    /// Branch to `l` if `rs1 == rs2`.
    pub fn beq(&mut self, rs1: RegId, rs2: RegId, l: Label) -> &mut Self {
        self.emit_branch(Opcode::Beq, rs1, rs2, l)
    }
    /// Branch to `l` if `rs1 != rs2`.
    pub fn bne(&mut self, rs1: RegId, rs2: RegId, l: Label) -> &mut Self {
        self.emit_branch(Opcode::Bne, rs1, rs2, l)
    }
    /// Branch to `l` if `rs1 < rs2` (signed).
    pub fn blt(&mut self, rs1: RegId, rs2: RegId, l: Label) -> &mut Self {
        self.emit_branch(Opcode::Blt, rs1, rs2, l)
    }
    /// Branch to `l` if `rs1 >= rs2` (signed).
    pub fn bge(&mut self, rs1: RegId, rs2: RegId, l: Label) -> &mut Self {
        self.emit_branch(Opcode::Bge, rs1, rs2, l)
    }
    /// Branch to `l` if `rs1 < rs2` (unsigned).
    pub fn bltu(&mut self, rs1: RegId, rs2: RegId, l: Label) -> &mut Self {
        self.emit_branch(Opcode::Bltu, rs1, rs2, l)
    }
    /// Branch to `l` if `rs1 >= rs2` (unsigned).
    pub fn bgeu(&mut self, rs1: RegId, rs2: RegId, l: Label) -> &mut Self {
        self.emit_branch(Opcode::Bgeu, rs1, rs2, l)
    }
    /// Unconditional jump to `l` (link discarded).
    pub fn jump(&mut self, l: Label) -> &mut Self {
        self.fixups.push((self.instrs.len(), l));
        self.emit(Instruction::new(Opcode::Jal, R0, R0, R0, 0))
    }
    /// Jump-and-link to `l` (`rd` receives the return index).
    pub fn jal(&mut self, rd: RegId, l: Label) -> &mut Self {
        self.fixups.push((self.instrs.len(), l));
        self.emit(Instruction::new(Opcode::Jal, rd, R0, R0, 0))
    }
    /// Indirect jump-and-link through `rs1`.
    pub fn jalr(&mut self, rd: RegId, rs1: RegId) -> &mut Self {
        self.emit(Instruction::new(Opcode::Jalr, rd, rs1, R0, 0))
    }
    /// Return through the conventional `ra` register.
    pub fn ret(&mut self) -> &mut Self {
        self.jalr(R0, crate::isa::RA)
    }

    // ---- floating point ----------------------------------------------------
    /// `f{fd} = f{fs1} + f{fs2}`
    pub fn fadd(&mut self, fd: u8, fs1: u8, fs2: u8) -> &mut Self {
        self.emit(Instruction::new(Opcode::Fadd, freg(fd), freg(fs1), freg(fs2), 0))
    }
    /// `f{fd} = f{fs1} - f{fs2}`
    pub fn fsub(&mut self, fd: u8, fs1: u8, fs2: u8) -> &mut Self {
        self.emit(Instruction::new(Opcode::Fsub, freg(fd), freg(fs1), freg(fs2), 0))
    }
    /// `f{fd} = f{fs1} * f{fs2}`
    pub fn fmul(&mut self, fd: u8, fs1: u8, fs2: u8) -> &mut Self {
        self.emit(Instruction::new(Opcode::Fmul, freg(fd), freg(fs1), freg(fs2), 0))
    }
    /// `f{fd} = f{fs1} / f{fs2}`
    pub fn fdiv(&mut self, fd: u8, fs1: u8, fs2: u8) -> &mut Self {
        self.emit(Instruction::new(Opcode::Fdiv, freg(fd), freg(fs1), freg(fs2), 0))
    }
    /// `f{fd} = min(f{fs1}, f{fs2})`
    pub fn fmin(&mut self, fd: u8, fs1: u8, fs2: u8) -> &mut Self {
        self.emit(Instruction::new(Opcode::Fmin, freg(fd), freg(fs1), freg(fs2), 0))
    }
    /// `f{fd} = max(f{fs1}, f{fs2})`
    pub fn fmax(&mut self, fd: u8, fs1: u8, fs2: u8) -> &mut Self {
        self.emit(Instruction::new(Opcode::Fmax, freg(fd), freg(fs1), freg(fs2), 0))
    }
    /// `rd(int) = (f{fs1} == f{fs2})`
    pub fn feq(&mut self, rd: RegId, fs1: u8, fs2: u8) -> &mut Self {
        self.emit(Instruction::new(Opcode::Feq, rd, freg(fs1), freg(fs2), 0))
    }
    /// `rd(int) = (f{fs1} < f{fs2})`
    pub fn flt(&mut self, rd: RegId, fs1: u8, fs2: u8) -> &mut Self {
        self.emit(Instruction::new(Opcode::Flt, rd, freg(fs1), freg(fs2), 0))
    }
    /// `rd(int) = (i32) f{fs1}` (float → int convert)
    pub fn fcvt_w_s(&mut self, rd: RegId, fs1: u8) -> &mut Self {
        self.emit(Instruction::new(Opcode::Fcvtws, rd, freg(fs1), R0, 0))
    }
    /// `f{fd} = (f32) rs1` (int → float convert)
    pub fn fcvt_s_w(&mut self, fd: u8, rs1: RegId) -> &mut Self {
        self.emit(Instruction::new(Opcode::Fcvtsw, freg(fd), rs1, R0, 0))
    }
    /// `f{fd} = f{fs1}` (float register move)
    pub fn fmv(&mut self, fd: u8, fs1: u8) -> &mut Self {
        self.emit(Instruction::new(Opcode::Fmv, freg(fd), freg(fs1), R0, 0))
    }

    // ---- misc ----------------------------------------------------------------
    /// No operation.
    pub fn nop(&mut self) -> &mut Self {
        self.emit(Instruction::nop())
    }
    /// Stop the simulated program.
    pub fn halt(&mut self) -> &mut Self {
        self.emit(Instruction::halt())
    }

    /// Resolve labels and produce the program.
    pub fn assemble(mut self) -> Program {
        for (idx, label) in &self.fixups {
            let target = self.labels[label.0].unwrap_or_else(|| {
                panic!(
                    "unbound label '{}' used at instruction {idx}",
                    self.label_names[label.0]
                )
            });
            self.instrs[*idx].imm = target as i32;
        }
        let mut prog = Program::new(&self.name);
        prog.instrs = std::mem::take(&mut self.instrs);
        self.data.finish(&mut prog);
        prog
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Opcode;

    #[test]
    fn forward_and_backward_labels_resolve() {
        let mut a = Asm::new("t");
        let top = a.label("top");
        let done = a.label("done");
        a.li(1, 0);
        a.bind(top);
        a.addi(1, 1, 1);
        a.li(2, 10);
        a.beq(1, 2, done); // forward
        a.jump(top); // backward
        a.bind(done);
        a.halt();
        let p = a.assemble();
        assert_eq!(p.instrs[3].op, Opcode::Beq);
        assert_eq!(p.instrs[3].imm, 5); // 'done' = index of halt
        assert_eq!(p.instrs[4].op, Opcode::Jal);
        assert_eq!(p.instrs[4].imm, 1); // 'top'
    }

    #[test]
    #[should_panic(expected = "unbound label")]
    fn unbound_label_panics() {
        let mut a = Asm::new("t");
        let l = a.label("missing");
        a.jump(l);
        let _ = a.assemble();
    }

    #[test]
    #[should_panic(expected = "bound twice")]
    fn double_bind_panics() {
        let mut a = Asm::new("t");
        let l = a.label("l");
        a.bind(l);
        a.nop();
        a.bind(l);
    }

    #[test]
    fn data_and_code_together() {
        let mut a = Asm::new("t");
        let arr = a.data.alloc_i32("arr", &[7, 8, 9]);
        a.li(1, arr as i32);
        a.lw(2, 1, 4);
        a.halt();
        let p = a.assemble();
        assert_eq!(p.symbol("arr"), Some(arr));
        assert_eq!(p.instrs.len(), 3);
        assert_eq!(p.data.len(), 3);
    }
}
