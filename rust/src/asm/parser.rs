//! Text assembler: parses `.s`-style EVA32 assembly into a [`Program`].
//!
//! Grammar (one statement per line, `#` comments):
//!
//! ```text
//! label:
//!     addi r1, r0, 5
//!     lw   r2, 8(r1)
//!     beq  r1, r2, label
//!     fadd f0, f1, f2
//!     halt
//! ```
//!
//! Branch targets may be labels or absolute instruction indices.

use crate::isa::{Instruction, Opcode, RegId, NUM_FP_REGS, NUM_INT_REGS, R0};

use super::program::Program;

/// A syntax error, tagged with the 1-based source line it occurred on.
#[derive(Debug, PartialEq)]
pub struct ParseError {
    /// 1-based line number in the source text
    pub line: usize,
    /// what went wrong (`"unknown mnemonic 'bogus'"`, ...)
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

fn err(line: usize, msg: impl Into<String>) -> ParseError {
    ParseError { line, msg: msg.into() }
}

fn parse_reg(tok: &str, line: usize) -> Result<RegId, ParseError> {
    let tok = tok.trim();
    if let Some(n) = tok.strip_prefix('r') {
        let i: u8 = n.parse().map_err(|_| err(line, format!("bad register '{tok}'")))?;
        if i >= NUM_INT_REGS {
            return Err(err(line, format!("integer register out of range '{tok}'")));
        }
        Ok(i)
    } else if let Some(n) = tok.strip_prefix('f') {
        let i: u8 = n.parse().map_err(|_| err(line, format!("bad register '{tok}'")))?;
        if i >= NUM_FP_REGS {
            return Err(err(line, format!("float register out of range '{tok}'")));
        }
        Ok(NUM_INT_REGS + i)
    } else {
        Err(err(line, format!("expected register, got '{tok}'")))
    }
}

fn parse_imm(tok: &str, line: usize) -> Result<i32, ParseError> {
    let tok = tok.trim();
    let (neg, body) = match tok.strip_prefix('-') {
        Some(b) => (true, b),
        None => (false, tok),
    };
    let v = if let Some(hex) = body.strip_prefix("0x") {
        i64::from_str_radix(hex, 16)
    } else {
        body.parse::<i64>()
    }
    .map_err(|_| err(line, format!("bad immediate '{tok}'")))?;
    let v = if neg { -v } else { v };
    i32::try_from(v).map_err(|_| err(line, format!("immediate overflow '{tok}'")))
}

/// `8(r2)` → (offset, base-register)
fn parse_mem_operand(tok: &str, line: usize) -> Result<(i32, RegId), ParseError> {
    let tok = tok.trim();
    let open = tok
        .find('(')
        .ok_or_else(|| err(line, format!("expected off(base), got '{tok}'")))?;
    if !tok.ends_with(')') {
        return Err(err(line, format!("expected off(base), got '{tok}'")));
    }
    let off = if open == 0 { 0 } else { parse_imm(&tok[..open], line)? };
    let base = parse_reg(&tok[open + 1..tok.len() - 1], line)?;
    Ok((off, base))
}

enum Target {
    Label(String),
    Abs(i32),
}

fn parse_target(tok: &str) -> Target {
    let tok = tok.trim();
    match tok.parse::<i32>() {
        Ok(v) => Target::Abs(v),
        Err(_) => Target::Label(tok.to_string()),
    }
}

/// Parse assembly text into a program named `name`.
pub fn parse(name: &str, text: &str) -> Result<Program, ParseError> {
    use Opcode::*;
    let mut instrs: Vec<Instruction> = Vec::new();
    let mut labels: std::collections::HashMap<String, usize> =
        std::collections::HashMap::new();
    let mut fixups: Vec<(usize, String, usize)> = Vec::new(); // instr, label, line

    for (lineno, raw) in text.lines().enumerate() {
        let line = lineno + 1;
        let mut src = raw;
        if let Some(p) = src.find('#') {
            src = &src[..p];
        }
        let mut src = src.trim();
        // labels (possibly followed by an instruction on the same line)
        while let Some(colon) = src.find(':') {
            let lbl = src[..colon].trim();
            if lbl.is_empty() || lbl.contains(char::is_whitespace) {
                return Err(err(line, format!("bad label '{lbl}'")));
            }
            if labels.insert(lbl.to_string(), instrs.len()).is_some() {
                return Err(err(line, format!("duplicate label '{lbl}'")));
            }
            src = src[colon + 1..].trim();
        }
        if src.is_empty() {
            continue;
        }

        let (mn, rest) = match src.find(char::is_whitespace) {
            Some(p) => (&src[..p], src[p..].trim()),
            None => (src, ""),
        };
        let op = Opcode::from_mnemonic(mn)
            .ok_or_else(|| err(line, format!("unknown mnemonic '{mn}'")))?;
        let ops: Vec<&str> = if rest.is_empty() {
            Vec::new()
        } else {
            rest.split(',').map(|s| s.trim()).collect()
        };
        let need = |n: usize| -> Result<(), ParseError> {
            if ops.len() == n {
                Ok(())
            } else {
                Err(err(line, format!("'{mn}' expects {n} operands, got {}", ops.len())))
            }
        };

        let instr = match op {
            Nop | Halt => {
                need(0)?;
                Instruction::new(op, R0, R0, R0, 0)
            }
            Lui => {
                need(2)?;
                Instruction::new(op, parse_reg(ops[0], line)?, R0, R0, parse_imm(ops[1], line)?)
            }
            Lw | Lb | Flw => {
                need(2)?;
                let rd = parse_reg(ops[0], line)?;
                let (off, base) = parse_mem_operand(ops[1], line)?;
                Instruction::new(op, rd, base, R0, off)
            }
            Sw | Sb | Fsw => {
                need(2)?;
                let val = parse_reg(ops[0], line)?;
                let (off, base) = parse_mem_operand(ops[1], line)?;
                Instruction::new(op, R0, base, val, off)
            }
            Beq | Bne | Blt | Bge | Bltu | Bgeu => {
                need(3)?;
                let rs1 = parse_reg(ops[0], line)?;
                let rs2 = parse_reg(ops[1], line)?;
                match parse_target(ops[2]) {
                    Target::Abs(t) => Instruction::new(op, R0, rs1, rs2, t),
                    Target::Label(l) => {
                        fixups.push((instrs.len(), l, line));
                        Instruction::new(op, R0, rs1, rs2, 0)
                    }
                }
            }
            Jal => {
                need(2)?;
                let rd = parse_reg(ops[0], line)?;
                match parse_target(ops[1]) {
                    Target::Abs(t) => Instruction::new(op, rd, R0, R0, t),
                    Target::Label(l) => {
                        fixups.push((instrs.len(), l, line));
                        Instruction::new(op, rd, R0, R0, 0)
                    }
                }
            }
            Jalr => {
                need(3)?;
                Instruction::new(
                    op,
                    parse_reg(ops[0], line)?,
                    parse_reg(ops[1], line)?,
                    R0,
                    parse_imm(ops[2], line)?,
                )
            }
            Addi | Andi | Ori | Xori | Slli | Srli | Srai | Slti => {
                need(3)?;
                Instruction::new(
                    op,
                    parse_reg(ops[0], line)?,
                    parse_reg(ops[1], line)?,
                    R0,
                    parse_imm(ops[2], line)?,
                )
            }
            Fcvtws | Fcvtsw | Fmv => {
                need(2)?;
                Instruction::new(
                    op,
                    parse_reg(ops[0], line)?,
                    parse_reg(ops[1], line)?,
                    R0,
                    0,
                )
            }
            // three-register forms (int and fp)
            _ => {
                need(3)?;
                Instruction::new(
                    op,
                    parse_reg(ops[0], line)?,
                    parse_reg(ops[1], line)?,
                    parse_reg(ops[2], line)?,
                    0,
                )
            }
        };
        instrs.push(instr);
    }

    for (idx, label, line) in fixups {
        let target = *labels
            .get(&label)
            .ok_or_else(|| err(line, format!("undefined label '{label}'")))?;
        instrs[idx].imm = target as i32;
    }

    let mut prog = Program::new(name);
    prog.instrs = instrs;
    prog.dmem_size = 64 * 1024;
    Ok(prog)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::freg;

    #[test]
    fn parses_basic_program() {
        let p = parse(
            "t",
            r#"
            # simple loop
            start:
                addi r1, r0, 0
                addi r2, r0, 10
            loop:
                addi r1, r1, 1
                bne  r1, r2, loop
                lw   r3, 8(r1)
                sw   r3, -4(r2)
                fadd f0, f1, f2
                halt
            "#,
        )
        .unwrap();
        assert_eq!(p.instrs.len(), 8);
        assert_eq!(p.instrs[3].op, Opcode::Bne);
        assert_eq!(p.instrs[3].imm, 2); // 'loop'
        assert_eq!(p.instrs[4].disasm(), "lw r3, 8(r1)");
        assert_eq!(p.instrs[5].disasm(), "sw r3, -4(r2)");
        assert_eq!(p.instrs[6].rd, freg(0));
    }

    #[test]
    fn disasm_parse_roundtrip() {
        // every parse-able disasm must re-parse to the same instruction
        let p = parse(
            "t",
            "add r1, r2, r3\naddi r4, r1, -9\nlw r5, 0(r4)\n\
             sw r5, 12(r2)\nbeq r1, r2, 0\njal r1, 3\njalr r0, r1, 0\n\
             fmul f1, f2, f3\nfcvt.w.s r6, f1\nlui r7, 4096\nhalt",
        )
        .unwrap();
        for i in &p.instrs {
            let text = i.disasm();
            let q = parse("r", &text).unwrap();
            assert_eq!(&q.instrs[0], i, "roundtrip of '{text}'");
        }
    }

    #[test]
    fn rejects_unknown_mnemonic_and_bad_reg() {
        assert!(parse("t", "bogus r1, r2, r3").is_err());
        assert!(parse("t", "add r1, r2, r99").is_err());
        assert!(parse("t", "add r1, r2").is_err());
        assert!(parse("t", "beq r1, r2, nowhere").is_err());
    }

    #[test]
    fn duplicate_label_rejected() {
        assert!(parse("t", "a:\nnop\na:\nnop").is_err());
    }

    #[test]
    fn hex_and_negative_immediates() {
        let p = parse("t", "addi r1, r0, 0x10\naddi r2, r0, -0x10").unwrap();
        assert_eq!(p.instrs[0].imm, 16);
        assert_eq!(p.instrs[1].imm, -16);
    }
}
