//! # Eva-CiM (reproduction)
//!
//! A system-level performance and energy evaluation framework for
//! computing-in-memory (CiM) architectures, reproducing Gao, Reis, Hu &
//! Zhuo, *Eva-CiM*, IEEE TCAD 2020 — built as a three-layer Rust + JAX +
//! Pallas stack (AOT via the PJRT C API).
//!
//! Pipeline (paper Fig 1):
//!
//! ```text
//!  workloads/ ──► sim/ (EVA32 OoO core + caches, probes) ──► probes::Trace
//!        Trace ──► analyzer/ (IDG, RUT/IHT, candidate selection, MACR)
//!   candidates ──► planner/ (profitability model; accepted groups only)
//!     accepted ──► reshape/ (CiM trace + performance counters)
//!     counters ──► profiler/ via runtime/ (AOT'd JAX graph on PJRT)
//!                  or energy/ (native mirror) ──► report/
//! ```
//!
//! The [`api`] module is the public front door: a typed
//! [`api::Evaluation`] builder that composes the whole pipeline (and the
//! coordinator's cached sweep engine) behind one call and returns a
//! structured [`api::Report`] renderable as text, CSV or canonical JSON.
//!
//! See DESIGN.md for the full system inventory and experiment index.

// Style lints we deliberately don't chase (correctness lints stay on —
// CI runs clippy with `-D warnings`).
#![allow(
    clippy::too_many_arguments,
    clippy::needless_range_loop,
    clippy::manual_flatten,
    clippy::type_complexity,
    clippy::new_without_default,
    clippy::unnecessary_map_or
)]
// Every public item in the evaluator core must be documented; CI enforces
// this via `RUSTDOCFLAGS="-D warnings" cargo doc --no-deps`.
#![warn(missing_docs)]

pub mod analyzer;
pub mod api;
pub mod asm;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod experiments;
pub mod isa;
pub mod pipeline;
pub mod planner;
pub mod probes;
pub mod profiler;
pub mod reshape;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod util;
pub mod workloads;
