//! PJRT runtime: loads the AOT'd HLO-text artifacts and executes them on
//! the CPU PJRT client — the request-path bridge between the Rust
//! coordinator and the JAX/Pallas compute graphs.  Python never runs here.
//!
//! Interchange format is HLO *text* (`HloModuleProto::from_text_file`):
//! jax ≥ 0.5 emits serialized protos with 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md and python/compile/aot.py).
//!
//! The PJRT path needs the `xla` bindings, which the offline build image
//! does not ship; it is therefore gated behind the off-by-default `pjrt`
//! cargo feature.  Without it, [`PjrtRuntime`] is an API-compatible stub
//! whose `load` always fails, and [`best_backend`] falls back to the
//! native mirror — every caller keeps compiling either way.

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::profiler::{ProfileInputs, ProfileResult};

/// Abstraction over the two profiler backends.
pub trait Backend {
    /// Evaluate a batch of design points (one [`ProfileResult`] each).
    fn evaluate_batch(&mut self, inputs: &[ProfileInputs]) -> Result<Vec<ProfileResult>>;
    /// Stable backend identifier (`"native"` / `"pjrt"`) — part of the
    /// sweep result-cache key, because the two compute in f64 vs f32.
    fn name(&self) -> &'static str;
}

/// Native Rust mirror backend (no PJRT) — fallback and cross-check.
pub struct NativeBackend;

impl Backend for NativeBackend {
    fn evaluate_batch(&mut self, inputs: &[ProfileInputs]) -> Result<Vec<ProfileResult>> {
        Ok(crate::profiler::evaluate_native_batch(inputs))
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Default artifact directory: `$EVA_CIM_ARTIFACTS` or repo `artifacts/`.
fn default_artifacts_dir() -> PathBuf {
    if let Ok(d) = std::env::var("EVA_CIM_ARTIFACTS") {
        return PathBuf::from(d);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use std::path::{Path, PathBuf};

    use anyhow::{anyhow, bail, Context, Result};

    use crate::energy::calib::{
        group_matrix_f32, static_unit_energy_f32, tech_table_f32, CFG_TECH,
        NCFG, NCOMP, NOPS, NTECH, NTECH_PARAMS,
    };
    use crate::profiler::{ProfileInputs, ProfileResult};
    use crate::reshape::{NC, NPERF};
    use crate::util::json;

    /// The PJRT-backed runtime.
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
        profiler: xla::PjRtLoadedExecutable,
        energy_model: xla::PjRtLoadedExecutable,
        sensitivity: Option<xla::PjRtLoadedExecutable>,
        /// design-point batch the artifacts were lowered at
        pub batch: usize,
        /// total PJRT executions issued (perf accounting)
        pub executions: u64,
    }

    fn load_exe(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))
    }

    /// Build an f32 literal of shape `[rows, cols]` from flattened data.
    fn matrix_literal(rows: usize, cols: usize, data: &[f32]) -> Result<xla::Literal> {
        debug_assert_eq!(data.len(), rows * cols);
        Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
    }

    /// Neutral padding rows (anchor geometry, zero counters, unit perf).
    const PAD_CFG_L1: [f32; NCFG] = [65536.0, 4.0, 64.0, 4.0, 0.0, 1.0];
    const PAD_CFG_L2: [f32; NCFG] = [262144.0, 8.0, 64.0, 4.0, 0.0, 2.0];
    const PAD_PERF: [f32; NPERF] = [1.0, 1.0, 0.0, 0.0, 0.0, 1.0];

    /// Flattened, padded input tensors for one profiler/sensitivity chunk.
    struct ChunkArgs {
        cfg1: Vec<f32>,
        cfg2: Vec<f32>,
        cb: Vec<f32>,
        cc: Vec<f32>,
        pf: Vec<f32>,
    }

    /// The AOT'd graphs were lowered against the frozen two-row
    /// `TECH_TABLE` literal, so registry technologies beyond SRAM/FeFET
    /// (RRAM, STT-MRAM, TOML customs) cannot be evaluated on this
    /// backend — reject them with a pointer to the native mirror rather
    /// than silently clamping to the wrong device.
    fn check_tech_in_table(rows: &[[f64; NCFG]]) -> Result<()> {
        for r in rows {
            let idx = r[CFG_TECH] as usize;
            if idx >= NTECH {
                bail!(
                    "technology index {idx} is outside the {NTECH}-row AOT \
                     tech table (PJRT artifacts only cover sram/fefet); \
                     use --backend native for registry technologies"
                );
            }
        }
        Ok(())
    }

    fn pack_chunk(chunk: &[ProfileInputs], b: usize) -> ChunkArgs {
        let mut a = ChunkArgs {
            cfg1: Vec::with_capacity(b * NCFG),
            cfg2: Vec::with_capacity(b * NCFG),
            cb: Vec::with_capacity(b * NC),
            cc: Vec::with_capacity(b * NC),
            pf: Vec::with_capacity(b * NPERF),
        };
        for inp in chunk {
            a.cfg1.extend(inp.cfg_l1.iter().map(|&x| x as f32));
            a.cfg2.extend(inp.cfg_l2.iter().map(|&x| x as f32));
            a.cb.extend(inp.counters_base.as_f32());
            a.cc.extend(inp.counters_cim.as_f32());
            a.pf.extend(inp.perf.iter().map(|&x| x as f32));
        }
        for _ in chunk.len()..b {
            a.cfg1.extend(PAD_CFG_L1);
            a.cfg2.extend(PAD_CFG_L2);
            a.cb.extend([0f32; NC]);
            a.cc.extend([0f32; NC]);
            a.pf.extend(PAD_PERF);
        }
        a
    }

    impl PjrtRuntime {
        /// Default artifact directory: `$EVA_CIM_ARTIFACTS` or repo `artifacts/`.
        pub fn default_dir() -> PathBuf {
            super::default_artifacts_dir()
        }

        /// Load the artifacts and compile them on the PJRT CPU client.
        pub fn load(dir: &Path) -> Result<Self> {
            let manifest_path = dir.join("manifest.json");
            let manifest_text = std::fs::read_to_string(&manifest_path)
                .with_context(|| format!("reading {manifest_path:?} (run `make artifacts`)"))?;
            let manifest = json::parse(&manifest_text)
                .map_err(|e| anyhow!("manifest parse error: {e}"))?;
            let batch = manifest
                .get("batch")
                .and_then(|b| b.as_usize())
                .ok_or_else(|| anyhow!("manifest missing batch"))?;

            // schema cross-check: the Python and Rust constants must agree
            for (key, want) in [
                ("ncfg", NCFG),
                ("nops", NOPS),
                ("nc", NC),
                ("ncomp", NCOMP),
                ("nperf", NPERF),
                ("ntech", NTECH),
                ("ntech_params", NTECH_PARAMS),
            ] {
                let got = manifest.get(key).and_then(|v| v.as_usize());
                if got != Some(want) {
                    bail!(
                        "manifest {key}={got:?} but Rust expects {want} — \
                         regenerate artifacts (make artifacts)"
                    );
                }
            }

            let client = xla::PjRtClient::cpu()?;
            let profiler = load_exe(&client, &dir.join("profiler.hlo.txt"))?;
            let energy_model = load_exe(&client, &dir.join("energy_model.hlo.txt"))?;
            let sensitivity = load_exe(&client, &dir.join("sensitivity.hlo.txt")).ok();
            Ok(Self { client, profiler, energy_model, sensitivity, batch, executions: 0 })
        }

        /// Name of the PJRT platform the client runs on (e.g. `"cpu"`).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        fn run(&mut self, exe_kind: u8, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
            let exe = match exe_kind {
                0 => &self.profiler,
                1 => &self.energy_model,
                _ => self
                    .sensitivity
                    .as_ref()
                    .ok_or_else(|| anyhow!("sensitivity artifact missing"))?,
            };
            self.executions += 1;
            let result = exe.execute::<xla::Literal>(args)?[0][0].to_literal_sync()?;
            Ok(result.to_tuple()?)
        }

        fn profile_args(&self, chunk: &[ProfileInputs]) -> Result<[xla::Literal; 8]> {
            for inp in chunk {
                check_tech_in_table(&[inp.cfg_l1, inp.cfg_l2])?;
            }
            let b = self.batch;
            let a = pack_chunk(chunk, b);
            Ok([
                matrix_literal(b, NCFG, &a.cfg1)?,
                matrix_literal(b, NCFG, &a.cfg2)?,
                matrix_literal(NTECH, NTECH_PARAMS, &tech_table_f32())?,
                xla::Literal::vec1(&static_unit_energy_f32()),
                matrix_literal(NC, NCOMP, &group_matrix_f32())?,
                matrix_literal(b, NC, &a.cb)?,
                matrix_literal(b, NC, &a.cc)?,
                matrix_literal(b, NPERF, &a.pf)?,
            ])
        }

        /// Execute the `energy_model` artifact: per-op energies and latencies
        /// for a batch of design-point rows.
        pub fn energy_latency(
            &mut self,
            rows: &[[f64; NCFG]],
        ) -> Result<(Vec<[f64; NOPS]>, Vec<[f64; NOPS]>)> {
            check_tech_in_table(rows)?;
            let b = self.batch;
            let mut energies = Vec::with_capacity(rows.len());
            let mut lats = Vec::with_capacity(rows.len());
            for chunk in rows.chunks(b) {
                let mut flat = Vec::with_capacity(b * NCFG);
                for r in chunk {
                    flat.extend(r.iter().map(|&x| x as f32));
                }
                for _ in chunk.len()..b {
                    flat.extend(PAD_CFG_L1);
                }
                let cfg = matrix_literal(b, NCFG, &flat)?;
                let tech = matrix_literal(NTECH, NTECH_PARAMS, &tech_table_f32())?;
                let outs = self.run(1, &[cfg, tech])?;
                if outs.len() != 2 {
                    bail!("energy_model returned {} outputs, want 2", outs.len());
                }
                let e: Vec<f32> = outs[0].to_vec()?;
                let l: Vec<f32> = outs[1].to_vec()?;
                for i in 0..chunk.len() {
                    let mut er = [0.0; NOPS];
                    let mut lr = [0.0; NOPS];
                    for j in 0..NOPS {
                        er[j] = e[i * NOPS + j] as f64;
                        lr[j] = l[i * NOPS + j] as f64;
                    }
                    energies.push(er);
                    lats.push(lr);
                }
            }
            Ok((energies, lats))
        }

        /// Execute the `profiler` artifact over a set of design points.
        pub fn evaluate_profile(
            &mut self,
            inputs: &[ProfileInputs],
        ) -> Result<Vec<ProfileResult>> {
            let mut results = Vec::with_capacity(inputs.len());
            for chunk in inputs.chunks(self.batch) {
                let args = self.profile_args(chunk)?;
                let outs = self.run(0, &args)?;
                if outs.len() != 12 {
                    bail!("profiler returned {} outputs, want 12", outs.len());
                }
                let vecs: Vec<Vec<f32>> = outs
                    .iter()
                    .map(|l| l.to_vec::<f32>())
                    .collect::<std::result::Result<_, _>>()?;
                for i in 0..chunk.len() {
                    let mut r = ProfileResult::default();
                    for j in 0..NCOMP {
                        r.comps_base[j] = vecs[0][i * NCOMP + j] as f64;
                        r.comps_cim[j] = vecs[1][i * NCOMP + j] as f64;
                    }
                    r.total_base = vecs[2][i] as f64;
                    r.total_cim = vecs[3][i] as f64;
                    r.improvement = vecs[4][i] as f64;
                    r.speedup = vecs[5][i] as f64;
                    r.ratio_proc = vecs[6][i] as f64;
                    r.ratio_cache = vecs[7][i] as f64;
                    for j in 0..NOPS {
                        r.e_l1[j] = vecs[8][i * NOPS + j] as f64;
                        r.lat_l1[j] = vecs[9][i * NOPS + j] as f64;
                        r.e_l2[j] = vecs[10][i * NOPS + j] as f64;
                        r.lat_l2[j] = vecs[11][i * NOPS + j] as f64;
                    }
                    results.push(r);
                }
            }
            Ok(results)
        }

        /// Execute the `sensitivity` artifact: d(mean CiM energy)/d(cfg).
        pub fn sensitivity(
            &mut self,
            inputs: &[ProfileInputs],
        ) -> Result<(Vec<[f64; NCFG]>, Vec<[f64; NCFG]>)> {
            if self.sensitivity.is_none() {
                bail!("sensitivity artifact missing");
            }
            let mut g1_all = Vec::new();
            let mut g2_all = Vec::new();
            for chunk in inputs.chunks(self.batch) {
                let args = self.profile_args(chunk)?;
                let outs = self.run(2, &args)?;
                if outs.len() != 2 {
                    bail!("sensitivity returned {} outputs, want 2", outs.len());
                }
                let g1: Vec<f32> = outs[0].to_vec()?;
                let g2: Vec<f32> = outs[1].to_vec()?;
                for i in 0..chunk.len() {
                    let mut a = [0.0; NCFG];
                    let mut bb = [0.0; NCFG];
                    for j in 0..NCFG {
                        a[j] = g1[i * NCFG + j] as f64;
                        bb[j] = g2[i * NCFG + j] as f64;
                    }
                    g1_all.push(a);
                    g2_all.push(bb);
                }
            }
            Ok((g1_all, g2_all))
        }
    }

    impl super::Backend for PjrtRuntime {
        fn evaluate_batch(&mut self, inputs: &[ProfileInputs]) -> Result<Vec<ProfileResult>> {
            self.evaluate_profile(inputs)
        }

        fn name(&self) -> &'static str {
            "pjrt"
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::PjrtRuntime;

#[cfg(not(feature = "pjrt"))]
mod pjrt_stub {
    use std::path::{Path, PathBuf};

    use anyhow::{bail, Result};

    use crate::energy::calib::{NCFG, NOPS};
    use crate::profiler::{ProfileInputs, ProfileResult};

    /// API-compatible stand-in for the PJRT runtime when the `pjrt` feature
    /// (and its `xla` dependency) is absent. `load` always fails, so no
    /// other method is reachable on a constructed value.
    pub struct PjrtRuntime {
        /// design-point batch the artifacts were lowered at
        pub batch: usize,
        /// total PJRT executions issued (perf accounting)
        pub executions: u64,
    }

    impl PjrtRuntime {
        /// Default artifact directory: `$EVA_CIM_ARTIFACTS` or repo `artifacts/`.
        pub fn default_dir() -> PathBuf {
            super::default_artifacts_dir()
        }

        /// Always fails: the binary was built without the `pjrt` feature.
        pub fn load(_dir: &Path) -> Result<Self> {
            bail!(
                "eva-cim was built without the `pjrt` cargo feature; \
                 rebuild with --features pjrt and an xla checkout to use PJRT"
            );
        }

        /// Stub: reports `"unavailable"`.
        pub fn platform(&self) -> String {
            "unavailable".into()
        }

        /// Stub: always fails (`pjrt` feature disabled).
        pub fn energy_latency(
            &mut self,
            _rows: &[[f64; NCFG]],
        ) -> Result<(Vec<[f64; NOPS]>, Vec<[f64; NOPS]>)> {
            bail!("pjrt feature disabled");
        }

        /// Stub: always fails (`pjrt` feature disabled).
        pub fn evaluate_profile(
            &mut self,
            _inputs: &[ProfileInputs],
        ) -> Result<Vec<ProfileResult>> {
            bail!("pjrt feature disabled");
        }

        /// Stub: always fails (`pjrt` feature disabled).
        pub fn sensitivity(
            &mut self,
            _inputs: &[ProfileInputs],
        ) -> Result<(Vec<[f64; NCFG]>, Vec<[f64; NCFG]>)> {
            bail!("pjrt feature disabled");
        }
    }

    impl super::Backend for PjrtRuntime {
        fn evaluate_batch(&mut self, _inputs: &[ProfileInputs]) -> Result<Vec<ProfileResult>> {
            bail!("pjrt feature disabled");
        }

        fn name(&self) -> &'static str {
            "pjrt"
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use pjrt_stub::PjrtRuntime;

/// Load the PJRT backend if artifacts exist, else fall back to native.
pub fn best_backend(dir: &Path) -> Box<dyn Backend> {
    match PjrtRuntime::load(dir) {
        Ok(rt) => Box::new(rt),
        Err(e) => {
            eprintln!("warning: PJRT backend unavailable ({e:#}); using native mirror");
            Box::new(NativeBackend)
        }
    }
}
