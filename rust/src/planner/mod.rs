//! The offload planner: a profitability-model decision layer between the
//! analyzer's candidate stream and the reshape/energy fold.
//!
//! The streaming analyzer ([`crate::analyzer::stream::OnlineAnalyzer`])
//! finds dependency-closed candidate groups and — historically — accepted
//! them wholesale.  This module turns that implicit select-everything
//! pass into an explicit, auditable decision: every
//! [`CandidateRecord`] the analyzer emits is *priced* against the
//! registered device model and either forwarded to the reshape fold or
//! rejected with a machine-readable reason.  The output is a typed
//! [`OffloadPlan`]: one [`GroupDecision`] per candidate group, each with
//! a per-group [`CostLedger`] of the cost terms behind the verdict.
//!
//! Two policies are registered:
//!
//! * [`PlanPolicy::AcceptAll`] — the default, and **byte-identical to the
//!   pre-planner pipeline**: every group is priced (the ledger is still
//!   reported) but none is rejected, so the [`DeltaSink`] the planner
//!   feeds is exactly what a bare sink would have accumulated.  Existing
//!   cache keys, golden reports and dedup preimages are untouched.
//! * [`PlanPolicy::Profitability`] — the cost-model-driven policy: a
//!   group is offloaded only when the energy it saves (displaced core
//!   events + displaced hierarchy transfers) beats what the offload
//!   costs (in-array CiM ops + operand marshalling + result readback),
//!   subject to the [`PlanKnobs`] thresholds.
//!
//! The pricing model ([`Pricer`]) is a first-order mirror of the reshape
//! fold's event accounting, expressed in pJ via the same sources the
//! energy stage uses: per-op array energies from
//! [`crate::energy::energy_latency`] (device-registry coefficients,
//! geometry-scaled), core-event unit energies from
//! [`crate::energy::calib::static_unit_energy`], and the
//! [`XBUS_FACTOR`] H-tree/bus transport multiplier on *hierarchy*
//! accesses — which in-array CiM ops never pay (that asymmetry is the
//! entire CiM value proposition, and the reason the model can reject a
//! group whose host/CiM interaction traffic outweighs it).
//!
//! Planning is keyed and cached like analysis: see
//! [`crate::coordinator::key::plan_key`], which embeds
//! [`PLANNER_SCHEMA`], the policy name, every threshold knob and the
//! device-model content.

use std::collections::HashMap;

use crate::analyzer::stream::{CandidateRecord, CandidateSink};
use crate::analyzer::CimOp;
use crate::config::{CimLevels, SystemConfig};
use crate::energy;
use crate::energy::calib::{
    static_unit_energy, NOPS, OP_ADD, OP_AND, OP_OR, OP_READ, OP_WRITE, OP_XOR,
    XBUS_FACTOR,
};
use crate::probes::MemLevel;
use crate::reshape::counters::{C_DRAM_READS, C_FETCH, C_INT_ALU, C_LSQ_READS,
                               C_LSQ_WRITES, NC};
use crate::reshape::DeltaSink;
use crate::util::json::Json;

/// Version stamp of the planner's decision semantics.  Bump on any change
/// to the pricing terms, the rejection precedence or the knob set — it is
/// embedded in every plan cache key, so stale plans become unreachable.
pub const PLANNER_SCHEMA: u64 = 1;

/// A registered offload-decision policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanPolicy {
    /// Accept every candidate group the analyzer emits (the default;
    /// byte-identical to the pre-planner pipeline).
    AcceptAll,
    /// Offload a group only when the profitability model says the saved
    /// energy beats the offload cost, subject to [`PlanKnobs`].
    Profitability,
}

impl PlanPolicy {
    /// Canonical name — the single source of truth shared by the CLI
    /// parser, `eva-cim list`, and the plan cache key.
    pub fn name(&self) -> &'static str {
        match self {
            PlanPolicy::AcceptAll => "accept-all",
            PlanPolicy::Profitability => "profitability",
        }
    }

    /// Parse a canonical name or alias.
    pub fn from_name(s: &str) -> Option<PlanPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "accept-all" | "accept_all" | "all" => Some(PlanPolicy::AcceptAll),
            "profitability" | "profit" | "cost-model" => {
                Some(PlanPolicy::Profitability)
            }
            _ => None,
        }
    }

    /// Every registered policy, in listing order.
    pub fn all() -> &'static [PlanPolicy] {
        &[PlanPolicy::AcceptAll, PlanPolicy::Profitability]
    }

    /// One-line description for `eva-cim list`.
    pub fn describe(&self) -> &'static str {
        match self {
            PlanPolicy::AcceptAll => {
                "offload every candidate group (default; pre-planner behavior)"
            }
            PlanPolicy::Profitability => {
                "offload only groups whose saved energy beats the offload cost"
            }
        }
    }

    /// Accepted aliases, comma-separated (for `eva-cim list`).
    pub fn aliases(&self) -> &'static str {
        match self {
            PlanPolicy::AcceptAll => "accept_all, all",
            PlanPolicy::Profitability => "profit, cost-model",
        }
    }

    /// The threshold knobs this policy starts from (CLI flags override).
    /// `accept-all` never consults its knobs; `profitability` skips
    /// singleton groups by default — a lone CiM op rarely amortizes the
    /// host-side orchestration it takes to set up.
    pub fn default_knobs(&self) -> PlanKnobs {
        match self {
            PlanPolicy::AcceptAll => PlanKnobs::default(),
            PlanPolicy::Profitability => {
                PlanKnobs { min_ops: 2, ..PlanKnobs::default() }
            }
        }
    }
}

/// Diagnostic for an unrecognized `--policy` value: lists every
/// registered policy and suggests the nearest one by edit distance
/// (mirrors [`crate::energy::device::unknown_tech_message`]).
pub fn unknown_policy_message(query: &str) -> String {
    let names: Vec<&str> = PlanPolicy::all().iter().map(|p| p.name()).collect();
    let q = query.to_ascii_lowercase();
    let best = names
        .iter()
        .map(|c| (crate::energy::device::levenshtein(&q, c), *c))
        .min()
        .filter(|&(d, _)| d <= 3);
    let mut msg = format!(
        "unknown planner policy '{query}' (registered: {})",
        names.join(", ")
    );
    if let Some((_, s)) = best {
        msg.push_str(&format!("; did you mean '{s}'?"));
    }
    msg
}

/// Threshold knobs of the profitability model.  Every field is part of
/// the plan cache key ([`crate::coordinator::key::plan_key`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PlanKnobs {
    /// Reject groups with fewer CiM-op members than this
    /// (`group_below_min_ops`).
    pub min_ops: u64,
    /// Reject groups whose net saving (saved − cost, pJ) falls below this
    /// (`interaction_cost_exceeds_savings`).
    pub min_net_pj: f64,
    /// Planner-side placement filter: groups whose owning cache level is
    /// not enabled here are rejected (`level_mismatch`).  Defaults to
    /// both levels — the analyzer's own placement already applied.
    pub level: CimLevels,
}

impl Default for PlanKnobs {
    fn default() -> Self {
        Self { min_ops: 1, min_net_pj: 0.0, level: CimLevels::Both }
    }
}

/// Machine-readable reason a candidate group was not offloaded.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// The group's owning cache level is not enabled by
    /// [`PlanKnobs::level`].
    LevelMismatch,
    /// The group has fewer CiM ops than [`PlanKnobs::min_ops`].
    GroupBelowMinOps,
    /// The host↔CiM interaction cost (marshalling + readback) plus the
    /// in-array op energy exceeds the displaced baseline energy by more
    /// than [`PlanKnobs::min_net_pj`] allows.
    InteractionCostExceedsSavings,
}

impl RejectReason {
    /// Stable serialized name (part of the report/JSON contract).
    pub fn name(&self) -> &'static str {
        match self {
            RejectReason::LevelMismatch => "level_mismatch",
            RejectReason::GroupBelowMinOps => "group_below_min_ops",
            RejectReason::InteractionCostExceedsSavings => {
                "interaction_cost_exceeds_savings"
            }
        }
    }

    /// Every reason, in rejection-precedence order (the order
    /// [`judge`] checks them).
    pub fn all() -> &'static [RejectReason] {
        &[
            RejectReason::LevelMismatch,
            RejectReason::GroupBelowMinOps,
            RejectReason::InteractionCostExceedsSavings,
        ]
    }
}

/// Per-group cost terms behind a decision, all in pJ.  The first three
/// are what the offload *costs*, the last two what it *saves*; see
/// [`Pricer::price`] for where each number comes from.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CostLedger {
    /// in-array CiM op energy at the owning level (no transport)
    pub cim_op_pj: f64,
    /// operand marshalling: cross-level moves + rereads of operands
    /// shared with earlier groups (hierarchy accesses, transport paid)
    pub marshal_pj: f64,
    /// result readback into the core: hierarchy read + LSQ slot
    pub readback_pj: f64,
    /// displaced core events: fetch/ALU/LSQ of the removed instructions
    pub saved_core_pj: f64,
    /// displaced hierarchy transfers: the removed loads' cache/DRAM
    /// traffic and the absorbed store's write-back
    pub saved_xfer_pj: f64,
}

impl CostLedger {
    /// Total offload-side cost (pJ).
    pub fn cost_pj(&self) -> f64 {
        self.cim_op_pj + self.marshal_pj + self.readback_pj
    }

    /// Total displaced baseline energy (pJ).
    pub fn saved_pj(&self) -> f64 {
        self.saved_core_pj + self.saved_xfer_pj
    }

    /// Net saving (pJ): positive means the offload wins.
    pub fn net_pj(&self) -> f64 {
        self.saved_pj() - self.cost_pj()
    }

    /// `(term name, pJ)` pairs in stable serialization order.
    pub fn terms(&self) -> [(&'static str, f64); 5] {
        [
            ("cim_op_pj", self.cim_op_pj),
            ("marshal_pj", self.marshal_pj),
            ("readback_pj", self.readback_pj),
            ("saved_core_pj", self.saved_core_pj),
            ("saved_xfer_pj", self.saved_xfer_pj),
        ]
    }

    /// Canonical JSON object of the terms plus the derived totals.
    pub fn to_json(&self) -> Json {
        let mut entries: Vec<(&str, Json)> =
            self.terms().iter().map(|&(k, v)| (k, v.into())).collect();
        entries.push(("cost_pj", self.cost_pj().into()));
        entries.push(("saved_pj", self.saved_pj().into()));
        entries.push(("net_pj", self.net_pj().into()));
        Json::obj(entries)
    }
}

/// The planner's verdict on one candidate group.
#[derive(Clone, Debug)]
pub struct GroupDecision {
    /// emission index of the group (retirement order, 0-based)
    pub index: u64,
    /// cache level the group's CiM ops would execute in
    pub level: MemLevel,
    /// CiM-op member count of the group
    pub ops: u64,
    /// host instructions the offload removes (members + claimed loads +
    /// absorbed store)
    pub removed: u64,
    /// cross-level operand moves the offload requires
    pub moves: u32,
    /// result readbacks the offload requires
    pub readbacks: u32,
    /// the cost terms behind the verdict
    pub ledger: CostLedger,
    /// `None` = offloaded; `Some(reason)` = kept on the host
    pub rejected: Option<RejectReason>,
}

impl GroupDecision {
    /// Whether the group was offloaded.
    pub fn accepted(&self) -> bool {
        self.rejected.is_none()
    }

    /// Canonical JSON rendering (stable field set and order).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("index", self.index.into()),
            ("level", self.level.name().into()),
            ("ops", self.ops.into()),
            ("removed", self.removed.into()),
            ("moves", (self.moves as u64).into()),
            ("readbacks", (self.readbacks as u64).into()),
            ("ledger", self.ledger.to_json()),
            (
                "rejected",
                match self.rejected {
                    Some(r) => r.name().into(),
                    None => Json::Null,
                },
            ),
        ])
    }
}

/// One aggregated report row: identical decisions collapsed, with a
/// count.  Loop-structured code emits the same group shape thousands of
/// times; aggregation keeps plan reports bounded without losing a single
/// distinct verdict.
#[derive(Clone, Debug)]
pub struct PlanRow {
    /// how many candidate groups share this exact decision
    pub count: u64,
    /// representative decision (first occurrence, retirement order)
    pub decision: GroupDecision,
}

/// The typed output of a planning pass: every group's decision, plus the
/// policy and knobs that produced it.
#[derive(Clone, Debug)]
pub struct OffloadPlan {
    /// the policy that judged the groups
    pub policy: PlanPolicy,
    /// the thresholds the policy ran with
    pub knobs: PlanKnobs,
    /// one verdict per candidate group, in retirement order
    pub decisions: Vec<GroupDecision>,
}

impl OffloadPlan {
    /// Number of offloaded groups.
    pub fn groups_accepted(&self) -> u64 {
        self.decisions.iter().filter(|d| d.accepted()).count() as u64
    }

    /// Number of rejected groups.
    pub fn groups_rejected(&self) -> u64 {
        self.decisions.len() as u64 - self.groups_accepted()
    }

    /// Summed offload-side energy (CiM ops + marshalling + readback, pJ)
    /// the plan declined to spend — the ledger counter surfaced as
    /// `rejected_energy_pj`.
    pub fn rejected_energy_pj(&self) -> f64 {
        self.decisions
            .iter()
            .filter(|d| !d.accepted())
            .map(|d| d.ledger.cost_pj())
            .sum()
    }

    /// Summed net saving (pJ) of the accepted groups.
    pub fn accepted_net_pj(&self) -> f64 {
        self.decisions
            .iter()
            .filter(|d| d.accepted())
            .map(|d| d.ledger.net_pj())
            .sum()
    }

    /// Summed CiM-op count of the accepted groups.
    pub fn accepted_ops(&self) -> u64 {
        self.decisions.iter().filter(|d| d.accepted()).map(|d| d.ops).sum()
    }

    /// Collapse identical decisions into [`PlanRow`]s, first-occurrence
    /// (retirement) order — deterministic, so reports stay byte-stable.
    pub fn rows(&self) -> Vec<PlanRow> {
        let mut index: HashMap<(u8, u64, u64, u32, u32, [u64; 5], u8), usize> =
            HashMap::new();
        let mut rows: Vec<PlanRow> = Vec::new();
        for d in &self.decisions {
            let t = d.ledger.terms();
            let key = (
                match d.level {
                    MemLevel::L1 => 0u8,
                    MemLevel::L2 => 1,
                    MemLevel::Dram => 2,
                },
                d.ops,
                d.removed,
                d.moves,
                d.readbacks,
                [
                    t[0].1.to_bits(),
                    t[1].1.to_bits(),
                    t[2].1.to_bits(),
                    t[3].1.to_bits(),
                    t[4].1.to_bits(),
                ],
                match d.rejected {
                    None => 0u8,
                    Some(RejectReason::LevelMismatch) => 1,
                    Some(RejectReason::GroupBelowMinOps) => 2,
                    Some(RejectReason::InteractionCostExceedsSavings) => 3,
                },
            );
            match index.get(&key) {
                Some(&ri) => rows[ri].count += 1,
                None => {
                    index.insert(key, rows.len());
                    rows.push(PlanRow { count: 1, decision: d.clone() });
                }
            }
        }
        rows
    }

    /// Canonical JSON rendering of the whole plan (stable across runs).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("planner_schema", PLANNER_SCHEMA.into()),
            ("policy", self.policy.name().into()),
            ("min_ops", self.knobs.min_ops.into()),
            ("min_net_pj", self.knobs.min_net_pj.into()),
            ("level", self.knobs.level.name().into()),
            ("groups_accepted", self.groups_accepted().into()),
            ("groups_rejected", self.groups_rejected().into()),
            ("rejected_energy_pj", self.rejected_energy_pj().into()),
            (
                "decisions",
                Json::Arr(self.decisions.iter().map(|d| d.to_json()).collect()),
            ),
        ])
    }
}

/// Prices candidate groups against one design point's device model.
///
/// Construction resolves the per-op array energies at the config's
/// geometry + technology once; pricing a record is then a handful of
/// multiply-adds on the hot path.
pub struct Pricer {
    e1: [f64; NOPS],
    e2: [f64; NOPS],
    unit: [f64; NC],
}

impl Pricer {
    /// A pricer for one system configuration (its technology's registered
    /// [`crate::energy::device::DeviceModel`] supplies the coefficients).
    pub fn new(cfg: &SystemConfig) -> Self {
        let (r1, r2) = energy::cfg_rows(cfg);
        let (e1, _) = energy::energy_latency(&r1);
        let (e2, _) = energy::energy_latency(&r2);
        Self { e1, e2, unit: static_unit_energy() }
    }

    /// Price one candidate group: what offloading it costs vs. what it
    /// displaces.  First-order mirror of the reshape fold's event
    /// accounting (see the module docs for the term-by-term rationale).
    pub fn price(&self, rec: &CandidateRecord) -> CostLedger {
        let c = &rec.candidate;
        let (own, other) = match c.level {
            MemLevel::L2 => (&self.e2, &self.e1),
            _ => (&self.e1, &self.e2),
        };

        // in-array CiM ops: array energy only, no H-tree/bus transport
        let cim_op_pj: f64 = c.ops.iter().map(|&op| own[op_index(op)]).sum();

        // operand marshalling: each cross-level move reads the source
        // level and writes the owning level through the hierarchy; each
        // operand shared with an earlier group is reread at the owning
        // level
        let marshal_pj = c.moves as f64
            * XBUS_FACTOR
            * (other[OP_READ] + own[OP_WRITE])
            + c.shared_loads.len() as f64 * XBUS_FACTOR * own[OP_READ];

        // result readback: the core still needs the value in a register —
        // one hierarchy read at the owning level plus an LSQ slot
        let readback_pj = c.readbacks as f64
            * (XBUS_FACTOR * own[OP_READ] + self.unit[C_LSQ_READS]);

        // displaced core events: every removed instruction stops being
        // fetched; members stop occupying the ALU; claimed loads and the
        // absorbed store free their LSQ slots
        let removed = c.removed_count() as f64;
        let mut saved_core_pj = removed * self.unit[C_FETCH]
            + c.members.len() as f64 * self.unit[C_INT_ALU]
            + c.loads.len() as f64 * self.unit[C_LSQ_READS];
        if c.absorbed_store.is_some() {
            saved_core_pj += self.unit[C_LSQ_WRITES];
        }

        // displaced transfers: each claimed load's hierarchy traffic at
        // its observed hit level; the absorbed store's write-back at the
        // owning level
        let mut saved_xfer_pj = 0.0;
        for li in &rec.load_infos {
            saved_xfer_pj += match &li.mem {
                Some(m) if m.l1_hit => XBUS_FACTOR * self.e1[OP_READ],
                Some(m) if m.l2_hit => {
                    XBUS_FACTOR * (self.e1[OP_READ] + self.e2[OP_READ])
                }
                Some(_) => {
                    XBUS_FACTOR * (self.e1[OP_READ] + self.e2[OP_READ])
                        + self.unit[C_DRAM_READS]
                }
                None => XBUS_FACTOR * self.e1[OP_READ],
            };
        }
        if c.absorbed_store.is_some() {
            saved_xfer_pj += XBUS_FACTOR * own[OP_WRITE];
        }

        CostLedger {
            cim_op_pj,
            marshal_pj,
            readback_pj,
            saved_core_pj,
            saved_xfer_pj,
        }
    }
}

/// Map a CiM op kind to its per-op energy column.
fn op_index(op: CimOp) -> usize {
    match op {
        CimOp::Or => OP_OR,
        CimOp::And => OP_AND,
        CimOp::Xor => OP_XOR,
        CimOp::Add => OP_ADD,
    }
}

/// Apply `policy` to one priced group.  Rejection precedence (first hit
/// wins): level filter, then group size, then profitability.
pub fn judge(
    policy: PlanPolicy,
    knobs: &PlanKnobs,
    rec: &CandidateRecord,
    ledger: &CostLedger,
) -> Option<RejectReason> {
    match policy {
        PlanPolicy::AcceptAll => None,
        PlanPolicy::Profitability => {
            let level_ok = match rec.candidate.level {
                MemLevel::L1 => knobs.level.l1(),
                MemLevel::L2 => knobs.level.l2(),
                MemLevel::Dram => false,
            };
            if !level_ok {
                Some(RejectReason::LevelMismatch)
            } else if (rec.candidate.ops.len() as u64) < knobs.min_ops {
                Some(RejectReason::GroupBelowMinOps)
            } else if ledger.net_pj() < knobs.min_net_pj {
                Some(RejectReason::InteractionCostExceedsSavings)
            } else {
                None
            }
        }
    }
}

/// The planning [`CandidateSink`]: prices every record, records the
/// decision, and forwards **accepted** groups (by reference, no clone) to
/// an inner [`DeltaSink`] — which is exactly how the plan "feeds the
/// reshape/energy stage with accepted groups only".  With
/// [`PlanPolicy::AcceptAll`] the inner sink's final state is
/// byte-identical to a bare `DeltaSink` fed directly
/// (`rust/tests/planner_equivalence.rs` is the contract).
pub struct PlanSink {
    pricer: Pricer,
    policy: PlanPolicy,
    knobs: PlanKnobs,
    /// reshape deltas of the accepted groups
    pub deltas: DeltaSink,
    decisions: Vec<GroupDecision>,
}

impl PlanSink {
    /// A planning sink for one design point.
    pub fn new(cfg: &SystemConfig, policy: PlanPolicy, knobs: PlanKnobs) -> Self {
        Self {
            pricer: Pricer::new(cfg),
            policy,
            knobs,
            deltas: DeltaSink::default(),
            decisions: Vec::new(),
        }
    }

    /// Finish planning: the typed plan plus the accepted-groups deltas.
    pub fn finish(self) -> (OffloadPlan, DeltaSink) {
        (
            OffloadPlan {
                policy: self.policy,
                knobs: self.knobs,
                decisions: self.decisions,
            },
            self.deltas,
        )
    }
}

impl CandidateSink for PlanSink {
    fn on_candidate(&mut self, rec: CandidateRecord) {
        let ledger = self.pricer.price(&rec);
        let rejected = judge(self.policy, &self.knobs, &rec, &ledger);
        if rejected.is_none() {
            self.deltas.fold(&rec);
        }
        let c = &rec.candidate;
        self.decisions.push(GroupDecision {
            index: self.decisions.len() as u64,
            level: c.level,
            ops: c.ops.len() as u64,
            removed: c.removed_count(),
            moves: c.moves,
            readbacks: c.readbacks,
            ledger,
            rejected,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyzer::select::Candidate;

    fn record(
        level: MemLevel,
        ops: Vec<CimOp>,
        readbacks: u32,
        moves: u32,
    ) -> CandidateRecord {
        let members: Vec<u64> = (0..ops.len() as u64).collect();
        CandidateRecord {
            candidate: Candidate {
                root_seq: 0,
                members,
                loads: vec![100],
                shared_loads: vec![],
                absorbed_store: None,
                readbacks,
                moves,
                level,
                ops,
            },
            member_infos: vec![],
            load_infos: vec![],
            absorbed: None,
        }
    }

    fn pricer() -> Pricer {
        Pricer::new(&SystemConfig::default())
    }

    #[test]
    fn policy_names_round_trip() {
        for p in PlanPolicy::all() {
            assert_eq!(PlanPolicy::from_name(p.name()), Some(*p));
        }
        assert_eq!(PlanPolicy::from_name("profit"),
                   Some(PlanPolicy::Profitability));
        assert_eq!(PlanPolicy::from_name("nope"), None);
    }

    #[test]
    fn unknown_policy_suggests_nearest() {
        let msg = unknown_policy_message("profitabilty");
        assert!(msg.contains("accept-all"), "{msg}");
        assert!(msg.contains("did you mean 'profitability'?"), "{msg}");
        // hopeless queries list the registry without a suggestion
        let msg = unknown_policy_message("zzzzzzzzzzzz");
        assert!(!msg.contains("did you mean"), "{msg}");
    }

    #[test]
    fn every_rejection_reason_is_reachable_and_stable() {
        let p = pricer();
        let knobs = PlanKnobs {
            min_ops: 2,
            min_net_pj: 0.0,
            level: CimLevels::L2Only,
        };
        // L1 group against an L2-only plan level -> level_mismatch
        let r1 = record(MemLevel::L1, vec![CimOp::Add, CimOp::Add], 0, 0);
        let l1 = p.price(&r1);
        assert_eq!(
            judge(PlanPolicy::Profitability, &knobs, &r1, &l1),
            Some(RejectReason::LevelMismatch)
        );
        // singleton L2 group -> group_below_min_ops
        let r2 = record(MemLevel::L2, vec![CimOp::Add], 0, 0);
        let l2 = p.price(&r2);
        assert_eq!(
            judge(PlanPolicy::Profitability, &knobs, &r2, &l2),
            Some(RejectReason::GroupBelowMinOps)
        );
        // an impossible net threshold -> interaction_cost_exceeds_savings
        let hard = PlanKnobs { min_net_pj: 1e15, ..knobs };
        let r3 = record(MemLevel::L2, vec![CimOp::Add, CimOp::Or], 1, 1);
        let l3 = p.price(&r3);
        assert_eq!(
            judge(PlanPolicy::Profitability, &hard, &r3, &l3),
            Some(RejectReason::InteractionCostExceedsSavings)
        );
        // the serialized names are the documented contract
        let names: Vec<&str> =
            RejectReason::all().iter().map(|r| r.name()).collect();
        assert_eq!(
            names,
            vec![
                "level_mismatch",
                "group_below_min_ops",
                "interaction_cost_exceeds_savings"
            ]
        );
        // and accept-all never rejects anything
        for (r, l) in [(&r1, &l1), (&r2, &l2), (&r3, &l3)] {
            assert_eq!(judge(PlanPolicy::AcceptAll, &hard, r, l), None);
        }
    }

    #[test]
    fn pricer_charges_interaction_and_credits_displacement() {
        let p = pricer();
        let free = record(MemLevel::L1, vec![CimOp::Add, CimOp::Add], 0, 0);
        let costly = record(MemLevel::L1, vec![CimOp::Add, CimOp::Add], 3, 3);
        let lf = p.price(&free);
        let lc = p.price(&costly);
        // same ops, same displacement — only the interaction terms move
        assert_eq!(lf.cim_op_pj, lc.cim_op_pj);
        assert_eq!(lf.saved_core_pj, lc.saved_core_pj);
        assert!(lc.marshal_pj > lf.marshal_pj);
        assert!(lc.readback_pj > lf.readback_pj);
        assert!(lc.net_pj() < lf.net_pj());
        // every term is non-negative and the totals are consistent
        for (_, v) in lc.terms() {
            assert!(v >= 0.0);
        }
        assert!((lc.cost_pj() - (lc.cim_op_pj + lc.marshal_pj + lc.readback_pj))
            .abs() < 1e-12);
        assert!((lc.net_pj() - (lc.saved_pj() - lc.cost_pj())).abs() < 1e-12);
    }

    #[test]
    fn plan_counters_and_json_are_stable() {
        let cfg = SystemConfig::default();
        let mut sink = PlanSink::new(
            &cfg,
            PlanPolicy::Profitability,
            PlanKnobs { min_ops: 2, ..PlanKnobs::default() },
        );
        // two identical accepted groups, one rejected singleton
        sink.on_candidate(record(MemLevel::L1, vec![CimOp::Add, CimOp::Or], 0, 0));
        sink.on_candidate(record(MemLevel::L1, vec![CimOp::Add, CimOp::Or], 0, 0));
        sink.on_candidate(record(MemLevel::L1, vec![CimOp::Add], 1, 0));
        let (plan, _) = sink.finish();
        assert_eq!(plan.groups_accepted(), 2);
        assert_eq!(plan.groups_rejected(), 1);
        assert!(plan.rejected_energy_pj() > 0.0);
        // identical decisions aggregate into one row, first-seen order
        let rows = plan.rows();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].count, 2);
        assert!(rows[0].decision.accepted());
        assert_eq!(rows[1].count, 1);
        assert_eq!(
            rows[1].decision.rejected,
            Some(RejectReason::GroupBelowMinOps)
        );
        // canonical JSON is deterministic and carries the contract fields
        let j = plan.to_json().dump();
        assert_eq!(j, plan.to_json().dump());
        for needle in [
            "\"planner_schema\":1",
            "\"policy\":\"profitability\"",
            "\"groups_accepted\":2",
            "\"groups_rejected\":1",
            "\"group_below_min_ops\"",
            "\"cim_op_pj\"",
        ] {
            assert!(j.contains(needle), "missing {needle} in {j}");
        }
    }

    #[test]
    fn accept_all_forwards_every_group_to_the_deltas() {
        let cfg = SystemConfig::default();
        let mut planned = PlanSink::new(
            &cfg,
            PlanPolicy::AcceptAll,
            PlanPolicy::AcceptAll.default_knobs(),
        );
        let mut bare = DeltaSink::default();
        for rec in [
            record(MemLevel::L1, vec![CimOp::Add], 1, 0),
            record(MemLevel::L2, vec![CimOp::Or, CimOp::Xor], 0, 2),
        ] {
            bare.fold(&rec);
            planned.on_candidate(rec);
        }
        let (plan, deltas) = planned.finish();
        assert_eq!(plan.groups_rejected(), 0);
        assert_eq!(deltas.removed, bare.removed);
        assert_eq!(deltas.cim_op_count, bare.cim_op_count);
        assert_eq!(deltas.cim_add, bare.cim_add);
        assert_eq!(deltas.delta.0, bare.delta.0);
    }
}
