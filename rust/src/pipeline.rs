//! Pipelined simulate → analyze execution.
//!
//! The simulator and the online analyzer are both single-pass consumers of
//! the commit stream, so they can overlap: a dedicated simulator thread
//! commits [`IState`] batches into a *bounded* channel while the calling
//! thread drains them into an [`OnlineAnalyzer`].  Peak memory is
//! O(channel depth + analysis window), never O(trace), and wall-clock
//! approaches max(sim time, analysis time) instead of their sum.
//!
//! [`run_streaming`] is the sequential variant (same O(window) memory, no
//! thread) — useful where spawning is undesirable and as the fairest
//! baseline for the `perf_hotpaths` pipelining comparison.
//!
//! Warm-trace replay reuses this producer∥consumer shape one level down:
//! `coordinator::trace_store` decodes spill chunks on N worker lanes
//! over the same kind of bounded channel, with sequence-numbered
//! reassembly so the [`AnalyzerFanout`] still observes records in strict
//! commit order (see [`crate::coordinator::trace_store::TraceStore::replay_with`]).
//!
//! The simulator side of the channel runs the pre-decoded cold path
//! ([`crate::sim::decode`]) via [`simulate_into`]'s dispatch — the commit
//! stream entering the channel is byte-identical either way, so nothing
//! at this layer (or below it in the cache stack) can tell the paths
//! apart.

use std::sync::mpsc;

use crate::analyzer::{CandidateSink, LocalityRule, OnlineAnalyzer, StreamOutcome};
use crate::asm::Program;
use crate::config::SystemConfig;
use crate::probes::{IState, TraceSink, TraceSummary};
use crate::sim::{simulate_into, Limits, SimError};

/// Instructions per channel message: large enough to amortize the channel,
/// small enough to keep both stages busy.
pub const BATCH: usize = 4096;

/// In-flight batches before the simulator blocks (backpressure bound).
const DEPTH: usize = 8;

/// Sink that batches committed records into the channel, optionally teeing
/// each record into a secondary sink first (disk spill, collection, ...).
struct ChannelSink<'a> {
    tx: mpsc::SyncSender<Vec<IState>>,
    buf: Vec<IState>,
    tee: Option<&'a mut (dyn TraceSink + Send)>,
}

impl ChannelSink<'_> {
    fn flush(&mut self) {
        if !self.buf.is_empty() {
            let batch = std::mem::take(&mut self.buf);
            // a closed channel means the consumer is gone; the simulation
            // result will surface whatever went wrong
            let _ = self.tx.send(batch);
        }
    }
}

impl TraceSink for ChannelSink<'_> {
    fn on_commit(&mut self, is: IState) {
        if let Some(t) = self.tee.as_mut() {
            t.on_commit(is.clone());
        }
        self.buf.push(is);
        if self.buf.len() >= BATCH {
            let batch =
                std::mem::replace(&mut self.buf, Vec::with_capacity(BATCH));
            let _ = self.tx.send(batch);
        }
    }
}

/// A broadcast tee over the commit stream: one pass feeds K independent
/// [`OnlineAnalyzer`]s (different CiM placements and/or locality rules
/// over the *same* trace).  Each analyzer sees every record by reference,
/// so the fan-out costs K pushes per instruction, not K stream replays —
/// the core of the stage-factored sweep (`coordinator`): a trace is
/// simulated or replayed once and every analysis variant rides along.
///
/// Also a [`TraceSink`], so [`crate::coordinator::trace_store::TraceStore::replay`]
/// can drive it directly.
pub struct AnalyzerFanout<S: CandidateSink> {
    analyzers: Vec<OnlineAnalyzer<S>>,
}

impl<S: CandidateSink> AnalyzerFanout<S> {
    /// A fan-out over the given analyzers (one lane per analyzer).
    pub fn new(analyzers: Vec<OnlineAnalyzer<S>>) -> Self {
        Self { analyzers }
    }

    /// Number of analysis lanes.
    pub fn len(&self) -> usize {
        self.analyzers.len()
    }

    /// True when there are no lanes (every push is a no-op).
    pub fn is_empty(&self) -> bool {
        self.analyzers.is_empty()
    }

    /// Feed one committed record to every lane.
    pub fn push(&mut self, is: &IState) {
        for a in &mut self.analyzers {
            a.push(is);
        }
    }

    /// End of stream: finish every lane, in lane order.
    pub fn finish(self) -> Vec<(StreamOutcome, S)> {
        self.analyzers.into_iter().map(|a| a.finish()).collect()
    }
}

impl<S: CandidateSink> TraceSink for AnalyzerFanout<S> {
    fn on_commit(&mut self, is: IState) {
        self.push(&is);
    }
}

/// Simulate `prog` with the simulator on its own thread, analyzing the
/// commit stream concurrently.  `tee` additionally receives every record
/// on the simulator thread (e.g. a chunked disk spill writer).
pub fn run_pipelined<S: CandidateSink>(
    prog: &Program,
    cfg: &SystemConfig,
    limits: Limits,
    rule: LocalityRule,
    sink: S,
    tee: Option<&mut (dyn TraceSink + Send)>,
) -> Result<(TraceSummary, StreamOutcome, S), SimError> {
    let fanout =
        AnalyzerFanout::new(vec![OnlineAnalyzer::new(cfg.cim_levels, rule, sink)]);
    let (summary, mut outs) = run_pipelined_fanout(prog, cfg, limits, fanout, tee)?;
    let (outcome, sink) = outs.pop().expect("single-lane fanout");
    Ok((summary, outcome, sink))
}

/// [`run_pipelined`] over a multi-lane [`AnalyzerFanout`]: one simulation,
/// K concurrent analyses.  Outcomes come back in lane order.
pub fn run_pipelined_fanout<S: CandidateSink>(
    prog: &Program,
    cfg: &SystemConfig,
    limits: Limits,
    mut fanout: AnalyzerFanout<S>,
    tee: Option<&mut (dyn TraceSink + Send)>,
) -> Result<(TraceSummary, Vec<(StreamOutcome, S)>), SimError> {
    let (tx, rx) = mpsc::sync_channel::<Vec<IState>>(DEPTH);
    let summary = std::thread::scope(|scope| {
        // own the receiver inside the scope: if an analyzer panics while
        // draining, unwinding drops `rx`, which unblocks a simulator
        // thread waiting on the full channel so the scope's implicit join
        // terminates and the panic propagates instead of deadlocking
        let rx = rx;
        let handle = scope.spawn(move || {
            let mut csink =
                ChannelSink { tx, buf: Vec::with_capacity(BATCH), tee };
            let res = simulate_into(prog, cfg, limits, &mut csink);
            csink.flush();
            res
            // csink (and with it the sender) drops here, closing the
            // channel and ending the consumer loop below
        });
        for batch in rx.iter() {
            for is in &batch {
                fanout.push(is);
            }
        }
        handle.join().expect("simulator thread panicked")
    })?;
    Ok((summary, fanout.finish()))
}

/// Sequential streaming: same O(window) memory as [`run_pipelined`], on
/// the calling thread.
pub fn run_streaming<S: CandidateSink>(
    prog: &Program,
    cfg: &SystemConfig,
    limits: Limits,
    rule: LocalityRule,
    sink: S,
) -> Result<(TraceSummary, StreamOutcome, S), SimError> {
    let mut analyzer = OnlineAnalyzer::new(cfg.cim_levels, rule, sink);
    let summary = simulate_into(prog, cfg, limits, &mut analyzer)?;
    let (outcome, sink) = analyzer.finish();
    Ok((summary, outcome, sink))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyzer::{analyze_batch, CollectCandidates};
    use crate::probes::CollectSink;
    use crate::sim::simulate;
    use crate::workloads;

    #[test]
    fn pipelined_matches_batch_and_sequential() {
        let prog = workloads::build("lcs", 2, 7).unwrap();
        let cfg = SystemConfig::preset("c1").unwrap();
        let trace = simulate(&prog, &cfg, Limits::default()).unwrap();
        let batch = analyze_batch(&trace, &cfg, LocalityRule::AnyCache);

        let (summary, out, sink) = run_pipelined(
            &prog,
            &cfg,
            Limits::default(),
            LocalityRule::AnyCache,
            CollectCandidates::default(),
            None,
        )
        .unwrap();
        assert_eq!(summary.committed, trace.committed);
        assert_eq!(summary.cycles, trace.cycles);
        let analysis = crate::analyzer::analysis_from_stream(out, sink);
        assert_eq!(analysis.selection.candidates, batch.selection.candidates);
        assert_eq!(analysis.macr, batch.macr);
        assert_eq!(analysis.idg_nodes, batch.idg_nodes);

        let (s2, out2, sink2) = run_streaming(
            &prog,
            &cfg,
            Limits::default(),
            LocalityRule::AnyCache,
            CollectCandidates::default(),
        )
        .unwrap();
        assert_eq!(s2.committed, summary.committed);
        let a2 = crate::analyzer::analysis_from_stream(out2, sink2);
        assert_eq!(a2.selection.candidates, batch.selection.candidates);
    }

    #[test]
    fn tee_sees_the_whole_stream() {
        let prog = workloads::build("lcs", 2, 7).unwrap();
        let cfg = SystemConfig::preset("c1").unwrap();
        let mut collect = CollectSink::default();
        let (summary, _, _) = run_pipelined(
            &prog,
            &cfg,
            Limits::default(),
            LocalityRule::AnyCache,
            CollectCandidates::default(),
            Some(&mut collect),
        )
        .unwrap();
        assert_eq!(collect.ciq.len() as u64, summary.committed);
        for (i, is) in collect.ciq.iter().enumerate() {
            assert_eq!(is.seq, i as u64);
        }
    }

    #[test]
    fn fanout_lanes_match_individual_runs() {
        use crate::config::CimLevels;
        use crate::reshape::DeltaSink;

        let prog = workloads::build("lcs", 2, 7).unwrap();
        let cfg = SystemConfig::preset("c1").unwrap();
        let specs = [
            (CimLevels::L1Only, LocalityRule::AnyCache),
            (CimLevels::Both, LocalityRule::SameBank),
            (CimLevels::L2Only, LocalityRule::SameLevel),
        ];
        let fanout = AnalyzerFanout::new(
            specs
                .iter()
                .map(|&(cim, rule)| {
                    OnlineAnalyzer::new(cim, rule, DeltaSink::default())
                })
                .collect(),
        );
        assert_eq!(fanout.len(), specs.len());
        assert!(!fanout.is_empty());
        let (summary, lanes) =
            run_pipelined_fanout(&prog, &cfg, Limits::default(), fanout, None)
                .unwrap();
        assert_eq!(lanes.len(), specs.len());
        for ((cim, rule), (out, deltas)) in specs.into_iter().zip(&lanes) {
            let mut c2 = cfg.clone();
            c2.cim_levels = cim;
            let (s2, o2, d2) = run_pipelined(
                &prog,
                &c2,
                Limits::default(),
                rule,
                DeltaSink::default(),
                None,
            )
            .unwrap();
            assert_eq!(s2.committed, summary.committed);
            assert_eq!(o2.macr, out.macr);
            assert_eq!(o2.candidates, out.candidates);
            assert_eq!(o2.idg_nodes, out.idg_nodes);
            assert_eq!(d2.delta.0, deltas.delta.0);
            assert_eq!(d2.removed, deltas.removed);
            assert_eq!(d2.cim_add, deltas.cim_add);
        }
    }

    #[test]
    fn simulator_fault_propagates_through_the_pipeline() {
        let mut a = crate::asm::Asm::new("bad");
        a.li(1, 0x7fff_fff0u32 as i32);
        a.lw(2, 1, 0);
        a.halt();
        let prog = a.assemble();
        let cfg = SystemConfig::default();
        let r = run_pipelined(
            &prog,
            &cfg,
            Limits::default(),
            LocalityRule::AnyCache,
            CollectCandidates::default(),
            None,
        );
        assert!(r.is_err());
    }
}
