//! Probe records: the *I-state* of Table I and the probe streams of Table II.
//!
//! The simulator (`sim/`) plays the role of GEM5-with-probes (paper Fig 2):
//! `InstProbe`/`PipeProbe` observe the pipeline, `RequestProbe`/`AccessProbe`
//! observe the LSQ↔memory packets.  The simulator *commits* one [`IState`]
//! record at a time into a [`TraceSink`]; a sink may analyze the stream
//! online with O(window) memory (`analyzer::stream`), spill it to disk in
//! chunks (`coordinator::trace_store`), or — the legacy batch view —
//! collect it into a materialized [`Trace`] via [`CollectSink`].  Only
//! *committed* instructions reach a sink (wrong-path work never enters the
//! CIQ).

use crate::isa::{FuncUnit, Instruction};

/// Memory hierarchy level that serviced an access.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MemLevel {
    L1,
    L2,
    Dram,
}

impl MemLevel {
    pub fn name(&self) -> &'static str {
        match self {
            MemLevel::L1 => "L1",
            MemLevel::L2 => "L2",
            MemLevel::Dram => "DRAM",
        }
    }
}

/// AccessProbe + RequestProbe record for one memory instruction
/// (Table I rows: "Request from master", "Memory access",
/// "Response from slave").
#[derive(Clone, Copy, Debug)]
pub struct MemAccessInfo {
    /// request address (virtual = physical in this substrate)
    pub addr: u32,
    pub size: u8,
    pub is_store: bool,
    /// level whose array serviced the request (data residency)
    pub level: MemLevel,
    /// bank id within the servicing level's array
    pub bank: u32,
    pub l1_hit: bool,
    pub l2_hit: bool,
    /// request was merged into an outstanding MSHR for the same line
    pub mshr_merged: bool,
    /// total access latency in cycles (request issue → data)
    pub latency: u64,
    /// tick at which the LSQ issued the request
    pub issue_tick: u64,
}

/// InstProbe record: one committed instruction with its pipeline timeline.
#[derive(Clone, Debug)]
pub struct IState {
    /// sequence index in the committed instruction queue (CIQ)
    pub seq: u64,
    /// instruction index in the program text (the "PC")
    pub pc: u32,
    pub instr: Instruction,
    pub fu: FuncUnit,
    // pipeline stage ticks (Fig 7's seven stages, writeback folded into
    // complete)
    pub tick_fetch: u64,
    pub tick_decode: u64,
    pub tick_rename: u64,
    pub tick_dispatch: u64,
    pub tick_issue: u64,
    pub tick_complete: u64,
    pub tick_commit: u64,
    /// memory access info for loads/stores
    pub mem: Option<MemAccessInfo>,
}

/// PipeProbe aggregate: functional-unit and structure activity counters
/// (the McPAT-facing half of the trace).
#[derive(Clone, Debug, Default)]
pub struct PipeStats {
    pub fetched: u64,
    pub decoded: u64,
    pub renamed: u64,
    pub iq_reads: u64,
    pub iq_writes: u64,
    pub rob_reads: u64,
    pub rob_writes: u64,
    pub int_rf_reads: u64,
    pub int_rf_writes: u64,
    pub fp_rf_reads: u64,
    pub fp_rf_writes: u64,
    pub fu_counts: [u64; crate::isa::func_unit::NUM_FUNC_UNITS],
    pub bpred_lookups: u64,
    pub bpred_mispredicts: u64,
    pub lsq_reads: u64,
    pub lsq_writes: u64,
}

/// AccessProbe aggregate: per-level hit/miss counters.
#[derive(Clone, Debug, Default)]
pub struct MemStats {
    pub l1i_hits: u64,
    pub l1i_misses: u64,
    pub l1d_read_hits: u64,
    pub l1d_read_misses: u64,
    pub l1d_write_hits: u64,
    pub l1d_write_misses: u64,
    pub l2_read_hits: u64,
    pub l2_read_misses: u64,
    pub l2_write_hits: u64,
    pub l2_write_misses: u64,
    pub dram_reads: u64,
    pub dram_writes: u64,
    /// writebacks of dirty lines (counted as writes to the lower level)
    pub writebacks: u64,
    pub mshr_merges: u64,
}

/// Why the simulation stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    Halt,
    MaxInstructions,
    /// PC ran past the end of the text segment
    RanOffEnd,
}

/// The per-instruction facts downstream consumers (reshaping, MACR) need
/// once the pipeline timeline is no longer relevant: the instruction word,
/// its functional unit and its memory access, without the stage ticks.
#[derive(Clone, Copy, Debug)]
pub struct InstrInfo {
    pub instr: Instruction,
    pub fu: FuncUnit,
    pub mem: Option<MemAccessInfo>,
}

impl InstrInfo {
    pub fn of(is: &IState) -> Self {
        Self { instr: is.instr, fu: is.fu, mem: is.mem }
    }
}

/// Aggregate output of one simulation: everything a [`Trace`] carries
/// *except* the committed instruction queue.  This is the O(1)-size half
/// of the modeling product; the O(instructions) half streams through a
/// [`TraceSink`].
#[derive(Clone, Debug)]
pub struct TraceSummary {
    pub program: String,
    pub pipe: PipeStats,
    pub mem: MemStats,
    pub cycles: u64,
    pub committed: u64,
    pub stop: StopReason,
}

impl TraceSummary {
    pub fn cpi(&self) -> f64 {
        if self.committed == 0 {
            0.0
        } else {
            self.cycles as f64 / self.committed as f64
        }
    }
}

/// Consumer of the committed-instruction stream.  The simulator calls
/// [`TraceSink::on_commit`] once per committed instruction, in commit
/// order (`seq` is dense and ascending).  Implementations must not assume
/// the stream is ever materialized: the whole point of the sink interface
/// is that analysis, spilling and transport all run in O(window) memory.
pub trait TraceSink {
    fn on_commit(&mut self, is: IState);
}

/// The trivial sink: buffer every record (the legacy batch view).
#[derive(Default)]
pub struct CollectSink {
    pub ciq: Vec<IState>,
}

impl TraceSink for CollectSink {
    fn on_commit(&mut self, is: IState) {
        self.ciq.push(is);
    }
}

/// Full output of one simulation: the materialized modeling-stage product.
#[derive(Clone, Debug)]
pub struct Trace {
    pub program: String,
    /// the committed instruction queue with I-state per entry
    pub ciq: Vec<IState>,
    pub pipe: PipeStats,
    pub mem: MemStats,
    pub cycles: u64,
    pub committed: u64,
    pub stop: StopReason,
}

impl Trace {
    /// Assemble a materialized trace from its streaming halves.
    pub fn from_parts(summary: TraceSummary, ciq: Vec<IState>) -> Self {
        Self {
            program: summary.program,
            ciq,
            pipe: summary.pipe,
            mem: summary.mem,
            cycles: summary.cycles,
            committed: summary.committed,
            stop: summary.stop,
        }
    }

    /// The O(1)-size aggregate view of this trace.
    pub fn summary(&self) -> TraceSummary {
        TraceSummary {
            program: self.program.clone(),
            pipe: self.pipe.clone(),
            mem: self.mem.clone(),
            cycles: self.cycles,
            committed: self.committed,
            stop: self.stop,
        }
    }

    pub fn cpi(&self) -> f64 {
        if self.committed == 0 {
            0.0
        } else {
            self.cycles as f64 / self.committed as f64
        }
    }

    /// Total data-side memory accesses (the MACR denominator).
    pub fn data_accesses(&self) -> u64 {
        self.ciq.iter().filter(|i| i.mem.is_some()).count() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mem_level_names() {
        assert_eq!(MemLevel::L1.name(), "L1");
        assert_eq!(MemLevel::Dram.name(), "DRAM");
    }

    #[test]
    fn trace_cpi() {
        let t = Trace {
            program: "t".into(),
            ciq: vec![],
            pipe: PipeStats::default(),
            mem: MemStats::default(),
            cycles: 150,
            committed: 100,
            stop: StopReason::Halt,
        };
        assert!((t.cpi() - 1.5).abs() < 1e-12);
        assert!((t.summary().cpi() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn from_parts_summary_roundtrip() {
        let t = Trace {
            program: "p".into(),
            ciq: vec![],
            pipe: PipeStats { fetched: 7, ..Default::default() },
            mem: MemStats { l1d_read_hits: 3, ..Default::default() },
            cycles: 42,
            committed: 7,
            stop: StopReason::MaxInstructions,
        };
        let back = Trace::from_parts(t.summary(), t.ciq.clone());
        assert_eq!(back.program, t.program);
        assert_eq!(back.pipe.fetched, 7);
        assert_eq!(back.mem.l1d_read_hits, 3);
        assert_eq!(back.cycles, 42);
        assert_eq!(back.committed, 7);
        assert_eq!(back.stop, StopReason::MaxInstructions);
    }
}
