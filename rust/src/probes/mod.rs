//! Probe records: the *I-state* of Table I and the probe streams of Table II.
//!
//! The simulator (`sim/`) plays the role of GEM5-with-probes (paper Fig 2):
//! `InstProbe`/`PipeProbe` observe the pipeline, `RequestProbe`/`AccessProbe`
//! observe the LSQ↔memory packets.  The simulator *commits* one [`IState`]
//! record at a time into a [`TraceSink`]; a sink may analyze the stream
//! online with O(window) memory (`analyzer::stream`), spill it to disk in
//! chunks (`coordinator::trace_store`), or — the legacy batch view —
//! collect it into a materialized [`Trace`] via [`CollectSink`].  Only
//! *committed* instructions reach a sink (wrong-path work never enters the
//! CIQ).

use crate::isa::{FuncUnit, Instruction};

/// Memory hierarchy level that serviced an access.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MemLevel {
    /// serviced by the L1 data (or instruction) cache
    L1,
    /// serviced by the unified L2
    L2,
    /// serviced by main memory
    Dram,
}

impl MemLevel {
    /// Display name (`"L1"`, `"L2"`, `"DRAM"`).
    pub fn name(&self) -> &'static str {
        match self {
            MemLevel::L1 => "L1",
            MemLevel::L2 => "L2",
            MemLevel::Dram => "DRAM",
        }
    }
}

/// AccessProbe + RequestProbe record for one memory instruction
/// (Table I rows: "Request from master", "Memory access",
/// "Response from slave").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MemAccessInfo {
    /// request address (virtual = physical in this substrate)
    pub addr: u32,
    /// access width in bytes
    pub size: u8,
    /// true for stores, false for loads
    pub is_store: bool,
    /// level whose array serviced the request (data residency)
    pub level: MemLevel,
    /// bank id within the servicing level's array
    pub bank: u32,
    /// hit in the L1 data cache
    pub l1_hit: bool,
    /// hit in the L2 (only meaningful when `l1_hit` is false)
    pub l2_hit: bool,
    /// request was merged into an outstanding MSHR for the same line
    pub mshr_merged: bool,
    /// total access latency in cycles (request issue → data)
    pub latency: u64,
    /// tick at which the LSQ issued the request
    pub issue_tick: u64,
}

/// InstProbe record: one committed instruction with its pipeline timeline.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct IState {
    /// sequence index in the committed instruction queue (CIQ)
    pub seq: u64,
    /// instruction index in the program text (the "PC")
    pub pc: u32,
    /// the decoded instruction word
    pub instr: Instruction,
    /// functional unit that executed it
    pub fu: FuncUnit,
    /// tick the instruction was fetched (Fig 7 stage 1)
    pub tick_fetch: u64,
    /// tick it was decoded
    pub tick_decode: u64,
    /// tick its registers were renamed
    pub tick_rename: u64,
    /// tick it was dispatched to the issue queue
    pub tick_dispatch: u64,
    /// tick it issued to its functional unit
    pub tick_issue: u64,
    /// tick it completed execution (writeback folded in)
    pub tick_complete: u64,
    /// tick it committed
    pub tick_commit: u64,
    /// memory access info for loads/stores
    pub mem: Option<MemAccessInfo>,
}

/// PipeProbe aggregate: functional-unit and structure activity counters
/// (the McPAT-facing half of the trace).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PipeStats {
    /// instructions fetched (wrong-path included)
    pub fetched: u64,
    /// instructions decoded
    pub decoded: u64,
    /// instructions renamed
    pub renamed: u64,
    /// issue-queue read ports exercised
    pub iq_reads: u64,
    /// issue-queue write ports exercised
    pub iq_writes: u64,
    /// reorder-buffer reads
    pub rob_reads: u64,
    /// reorder-buffer writes
    pub rob_writes: u64,
    /// integer register-file reads
    pub int_rf_reads: u64,
    /// integer register-file writes
    pub int_rf_writes: u64,
    /// floating-point register-file reads
    pub fp_rf_reads: u64,
    /// floating-point register-file writes
    pub fp_rf_writes: u64,
    /// executions per functional unit
    pub fu_counts: [u64; crate::isa::func_unit::NUM_FUNC_UNITS],
    /// branch-predictor lookups
    pub bpred_lookups: u64,
    /// branch mispredictions
    pub bpred_mispredicts: u64,
    /// load/store-queue reads
    pub lsq_reads: u64,
    /// load/store-queue writes
    pub lsq_writes: u64,
}

/// AccessProbe aggregate: per-level hit/miss counters.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct MemStats {
    /// L1I fetch hits
    pub l1i_hits: u64,
    /// L1I fetch misses
    pub l1i_misses: u64,
    /// L1D load hits
    pub l1d_read_hits: u64,
    /// L1D load misses
    pub l1d_read_misses: u64,
    /// L1D store hits
    pub l1d_write_hits: u64,
    /// L1D store misses
    pub l1d_write_misses: u64,
    /// L2 read hits
    pub l2_read_hits: u64,
    /// L2 read misses
    pub l2_read_misses: u64,
    /// L2 write hits
    pub l2_write_hits: u64,
    /// L2 write misses
    pub l2_write_misses: u64,
    /// main-memory reads
    pub dram_reads: u64,
    /// main-memory writes
    pub dram_writes: u64,
    /// writebacks of dirty lines (counted as writes to the lower level)
    pub writebacks: u64,
    /// requests merged into outstanding MSHRs
    pub mshr_merges: u64,
}

/// Why the simulation stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// the program executed its `halt`
    Halt,
    /// the `Limits::max_instructions` budget ran out
    MaxInstructions,
    /// PC ran past the end of the text segment
    RanOffEnd,
}

/// The per-instruction facts downstream consumers (reshaping, MACR) need
/// once the pipeline timeline is no longer relevant: the instruction word,
/// its functional unit and its memory access, without the stage ticks.
#[derive(Clone, Copy, Debug)]
pub struct InstrInfo {
    /// the decoded instruction word
    pub instr: Instruction,
    /// functional unit that executed it
    pub fu: FuncUnit,
    /// memory access info for loads/stores
    pub mem: Option<MemAccessInfo>,
}

impl InstrInfo {
    /// Project the timeline-free facts out of a full I-state record.
    pub fn of(is: &IState) -> Self {
        Self { instr: is.instr, fu: is.fu, mem: is.mem }
    }
}

/// Aggregate output of one simulation: everything a [`Trace`] carries
/// *except* the committed instruction queue.  This is the O(1)-size half
/// of the modeling product; the O(instructions) half streams through a
/// [`TraceSink`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TraceSummary {
    /// program name (shared handle — cloning a summary is allocation-free
    /// on this field)
    pub program: std::sync::Arc<str>,
    /// pipeline activity counters
    pub pipe: PipeStats,
    /// memory hierarchy hit/miss counters
    pub mem: MemStats,
    /// simulated cycles
    pub cycles: u64,
    /// committed instructions
    pub committed: u64,
    /// why the simulation ended
    pub stop: StopReason,
}

impl TraceSummary {
    /// Cycles per committed instruction (0.0 for an empty run).
    pub fn cpi(&self) -> f64 {
        if self.committed == 0 {
            0.0
        } else {
            self.cycles as f64 / self.committed as f64
        }
    }
}

/// Consumer of the committed-instruction stream.  The simulator calls
/// [`TraceSink::on_commit`] once per committed instruction, in commit
/// order (`seq` is dense and ascending).  Implementations must not assume
/// the stream is ever materialized: the whole point of the sink interface
/// is that analysis, spilling and transport all run in O(window) memory.
///
/// Driving the simulator with a custom sink:
///
/// ```
/// use eva_cim::config::SystemConfig;
/// use eva_cim::probes::{IState, TraceSink};
/// use eva_cim::sim::{simulate_into, Limits};
///
/// /// Counts committed memory instructions without retaining the stream.
/// #[derive(Default)]
/// struct MemOpCounter(u64);
///
/// impl TraceSink for MemOpCounter {
///     fn on_commit(&mut self, is: IState) {
///         if is.mem.is_some() {
///             self.0 += 1;
///         }
///     }
/// }
///
/// let mut a = eva_cim::asm::Asm::new("doc-sink");
/// let buf = a.data.alloc_i32("buf", &[1, 2, 3, 4]);
/// a.li(1, buf as i32);
/// a.lw(2, 1, 0); // load
/// a.lw(3, 1, 4); // load
/// a.add(4, 2, 3);
/// a.sw(4, 1, 8); // store
/// a.halt();
///
/// let cfg = SystemConfig::default();
/// let mut sink = MemOpCounter::default();
/// let summary =
///     simulate_into(&a.assemble(), &cfg, Limits::default(), &mut sink).unwrap();
/// assert_eq!(sink.0, 3); // two loads + one store
/// assert!(summary.committed >= 5);
/// ```
pub trait TraceSink {
    /// Receive one committed instruction record.
    fn on_commit(&mut self, is: IState);
}

/// The trivial sink: buffer every record (the legacy batch view).
#[derive(Default)]
pub struct CollectSink {
    /// the materialized committed-instruction queue
    pub ciq: Vec<IState>,
}

impl TraceSink for CollectSink {
    fn on_commit(&mut self, is: IState) {
        self.ciq.push(is);
    }
}

/// Full output of one simulation: the materialized modeling-stage product.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Trace {
    /// program name (shared handle, see [`TraceSummary::program`])
    pub program: std::sync::Arc<str>,
    /// the committed instruction queue with I-state per entry
    pub ciq: Vec<IState>,
    /// pipeline activity counters
    pub pipe: PipeStats,
    /// memory hierarchy hit/miss counters
    pub mem: MemStats,
    /// simulated cycles
    pub cycles: u64,
    /// committed instructions
    pub committed: u64,
    /// why the simulation ended
    pub stop: StopReason,
}

impl Trace {
    /// Assemble a materialized trace from its streaming halves.
    pub fn from_parts(summary: TraceSummary, ciq: Vec<IState>) -> Self {
        Self {
            program: summary.program,
            ciq,
            pipe: summary.pipe,
            mem: summary.mem,
            cycles: summary.cycles,
            committed: summary.committed,
            stop: summary.stop,
        }
    }

    /// The O(1)-size aggregate view of this trace.
    pub fn summary(&self) -> TraceSummary {
        TraceSummary {
            program: self.program.clone(),
            pipe: self.pipe.clone(),
            mem: self.mem.clone(),
            cycles: self.cycles,
            committed: self.committed,
            stop: self.stop,
        }
    }

    /// Cycles per committed instruction (0.0 for an empty run).
    pub fn cpi(&self) -> f64 {
        if self.committed == 0 {
            0.0
        } else {
            self.cycles as f64 / self.committed as f64
        }
    }

    /// Total data-side memory accesses (the MACR denominator).
    pub fn data_accesses(&self) -> u64 {
        self.ciq.iter().filter(|i| i.mem.is_some()).count() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mem_level_names() {
        assert_eq!(MemLevel::L1.name(), "L1");
        assert_eq!(MemLevel::Dram.name(), "DRAM");
    }

    #[test]
    fn trace_cpi() {
        let t = Trace {
            program: "t".into(),
            ciq: vec![],
            pipe: PipeStats::default(),
            mem: MemStats::default(),
            cycles: 150,
            committed: 100,
            stop: StopReason::Halt,
        };
        assert!((t.cpi() - 1.5).abs() < 1e-12);
        assert!((t.summary().cpi() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn from_parts_summary_roundtrip() {
        let t = Trace {
            program: "p".into(),
            ciq: vec![],
            pipe: PipeStats { fetched: 7, ..Default::default() },
            mem: MemStats { l1d_read_hits: 3, ..Default::default() },
            cycles: 42,
            committed: 7,
            stop: StopReason::MaxInstructions,
        };
        let back = Trace::from_parts(t.summary(), t.ciq.clone());
        assert_eq!(back.program, t.program);
        assert_eq!(back.pipe.fetched, 7);
        assert_eq!(back.mem.l1d_read_hits, 3);
        assert_eq!(back.cycles, 42);
        assert_eq!(back.committed, 7);
        assert_eq!(back.stop, StopReason::MaxInstructions);
    }
}
