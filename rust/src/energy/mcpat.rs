//! McPAT-lite: per-counter unit energies and component aggregation — the
//! native mirror of `python/compile/model.py` (`_unit_energy` + the
//! profile_agg kernel).

use crate::reshape::counters::*;

use super::array::{energy_latency, CfgRow};
use super::calib::*;

/// Assemble the per-counter unit-energy vector (pJ/event) for one design
/// point.  Core events, DRAM and leakage come from the calibrated static
/// vector; cache and CiM columns come from the array model.
pub fn unit_energy(cfg_l1: &CfgRow, cfg_l2: &CfgRow) -> [f64; NC] {
    let (e1, _) = energy_latency(cfg_l1);
    let (e2, _) = energy_latency(cfg_l2);
    let mut u = static_unit_energy();

    // hierarchy accesses pay the H-tree/bus transport on top of the
    // array access; CiM ops below do not (they compute in-array)
    let rd1 = e1[OP_READ] * XBUS_FACTOR;
    let wr1 = e1[OP_WRITE] * XBUS_FACTOR;
    let rd2 = e2[OP_READ] * XBUS_FACTOR;
    let wr2 = e2[OP_WRITE] * XBUS_FACTOR;
    let fill1 = rd1 + wr1; // miss = probe + refill
    let fill2 = rd2 + wr2;
    u[C_L1I_HITS] = rd1;
    u[C_L1I_MISSES] = fill1;
    u[C_L1D_READ_HITS] = rd1;
    u[C_L1D_READ_MISSES] = fill1;
    u[C_L1D_WRITE_HITS] = wr1;
    u[C_L1D_WRITE_MISSES] = fill1;
    u[C_L2_READ_HITS] = rd2;
    u[C_L2_READ_MISSES] = fill2;
    u[C_L2_WRITE_HITS] = wr2;
    u[C_L2_WRITE_MISSES] = fill2;
    u[C_CIM_L1_OR] = e1[OP_OR];
    u[C_CIM_L1_AND] = e1[OP_AND];
    u[C_CIM_L1_XOR] = e1[OP_XOR];
    u[C_CIM_L1_ADD] = e1[OP_ADD];
    u[C_CIM_L2_OR] = e2[OP_OR];
    u[C_CIM_L2_AND] = e2[OP_AND];
    u[C_CIM_L2_XOR] = e2[OP_XOR];
    u[C_CIM_L2_ADD] = e2[OP_ADD];
    u
}

/// Aggregate counters × unit energies into component energies (pJ).
pub fn aggregate(counters: &CounterSet, unit: &[f64; NC]) -> [f64; NCOMP] {
    let mut comps = [0.0; NCOMP];
    for i in 0..NC {
        comps[comp_of_counter(i)] += counters[i] * unit[i];
    }
    comps
}

/// Array-level-only energy estimate: what DESTINY alone would report for a
/// trace's memory operations (no core, no hierarchy interactions beyond the
/// per-access op type).  Used by the Table V validation bench.
pub fn destiny_only_estimate(
    counters: &CounterSet,
    cfg_l1: &CfgRow,
    cfg_l2: &CfgRow,
) -> (f64, f64) {
    let (e1, _) = energy_latency(cfg_l1);
    let (e2, _) = energy_latency(cfg_l2);
    // non-CiM: every access (instruction fetches included) billed at its
    // level's flat read/write cost — no miss/refill hierarchy effects
    let reads_l1 = counters[C_L1D_READ_HITS]
        + counters[C_L1D_READ_MISSES]
        + counters[C_L1I_HITS]
        + counters[C_L1I_MISSES];
    let writes_l1 = counters[C_L1D_WRITE_HITS] + counters[C_L1D_WRITE_MISSES];
    let reads_l2 = counters[C_L2_READ_HITS] + counters[C_L2_READ_MISSES];
    let writes_l2 = counters[C_L2_WRITE_HITS] + counters[C_L2_WRITE_MISSES];
    let non_cim = reads_l1 * e1[OP_READ]
        + writes_l1 * e1[OP_WRITE]
        + reads_l2 * e2[OP_READ]
        + writes_l2 * e2[OP_WRITE];
    let cim = counters[C_CIM_L1_OR] * e1[OP_OR]
        + counters[C_CIM_L1_AND] * e1[OP_AND]
        + counters[C_CIM_L1_XOR] * e1[OP_XOR]
        + counters[C_CIM_L1_ADD] * e1[OP_ADD]
        + counters[C_CIM_L2_OR] * e2[OP_OR]
        + counters[C_CIM_L2_AND] * e2[OP_AND]
        + counters[C_CIM_L2_XOR] * e2[OP_XOR]
        + counters[C_CIM_L2_ADD] * e2[OP_ADD];
    (cim, non_cim)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::energy::array::cfg_rows;

    #[test]
    fn unit_energy_fills_dynamic_columns() {
        let cfg = SystemConfig::preset("c2").unwrap();
        let (r1, r2) = cfg_rows(&cfg);
        let u = unit_energy(&r1, &r2);
        // c2's L1 is exactly the Table III anchor; hierarchy accesses add
        // the H-tree/bus factor, CiM ops stay at array level
        assert!((u[C_L1D_READ_HITS] - 61.0 * XBUS_FACTOR).abs() < 1e-9);
        assert!((u[C_CIM_L1_ADD] - 79.0).abs() < 1e-9);
        assert!((u[C_L2_READ_HITS] - 314.0 * XBUS_FACTOR).abs() < 1e-9);
        assert!((u[C_CIM_L2_XOR] - 365.0).abs() < 1e-9);
        // miss costs more than hit
        assert!(u[C_L1D_READ_MISSES] > u[C_L1D_READ_HITS]);
    }

    #[test]
    fn aggregate_totals_match_dot_product() {
        let cfg = SystemConfig::default();
        let (r1, r2) = cfg_rows(&cfg);
        let u = unit_energy(&r1, &r2);
        let mut c = CounterSet::default();
        for i in 0..NC {
            c[i] = (i as f64 + 1.0) * 10.0;
        }
        let comps = aggregate(&c, &u);
        let total: f64 = comps.iter().sum();
        let dot: f64 = (0..NC).map(|i| c[i] * u[i]).sum();
        assert!((total - dot).abs() < 1e-6);
        assert!(comps[COMP_CORE] > 0.0);
        assert!(comps[COMP_LEAK] > 0.0);
    }

    #[test]
    fn destiny_estimate_counts_only_memory() {
        let cfg = SystemConfig::default();
        let (r1, r2) = cfg_rows(&cfg);
        let mut c = CounterSet::default();
        c[C_FETCH] = 1e9; // core activity must not matter
        c[C_L1D_READ_HITS] = 10.0;
        c[C_CIM_L1_ADD] = 2.0;
        let (cim, non_cim) = destiny_only_estimate(&c, &r1, &r2);
        let (e1, _) = energy_latency(&r1);
        assert!((non_cim - 10.0 * e1[OP_READ]).abs() < 1e-9);
        assert!((cim - 2.0 * e1[OP_ADD]).abs() < 1e-9);
    }
}
