//! Calibration constants — single source of truth on the Rust side.
//!
//! MUST mirror `python/compile/kernels/constants.py`: the same Table III /
//! Fig 11 anchors feed both the AOT'd Pallas kernels and the native Rust
//! model, and `runtime_artifacts.rs` cross-validates the two paths.
//!
//! The two-row [`TECH_TABLE`] is the **PJRT artifact contract**: the AOT
//! graphs are lowered against a `[NTECH, NTECH_PARAMS]` input literal, so
//! it stays frozen at SRAM + FeFET.  The open-ended registry of runtime
//! technologies lives in [`crate::energy::device`]; its SRAM/FeFET
//! built-ins are constructed *from* these rows and must stay
//! byte-identical to them (`rust/tests/device_registry.rs`).
//!
//! Table VI calibration (core event energies, DRAM, leakage) lives in
//! [`static_unit_energy`]; DESIGN.md §5 explains how the values were set.

/// Table III column: non-CiM read.
pub const OP_READ: usize = 0;
/// Table III column: non-CiM write (interpolated in the paper's table).
pub const OP_WRITE: usize = 1;
/// Table III column: in-array CiM OR.
pub const OP_OR: usize = 2;
/// Table III column: in-array CiM AND.
pub const OP_AND: usize = 3;
/// Table III column: in-array CiM XOR.
pub const OP_XOR: usize = 4;
/// Table III column: in-array CiM 32-bit add.
pub const OP_ADD: usize = 5;
/// Number of per-op table columns.
pub const NOPS: usize = 6;
/// Display names of the op columns, in table order.
pub const OP_NAMES: [&str; NOPS] = ["read", "write", "cim_or", "cim_and", "cim_xor", "cim_add"];

/// Config-row column: capacity in bytes.
pub const CFG_CAPACITY: usize = 0;
/// Config-row column: associativity (ways).
pub const CFG_ASSOC: usize = 1;
/// Config-row column: line size in bytes.
pub const CFG_LINE: usize = 2;
/// Config-row column: bank count.
pub const CFG_BANKS: usize = 3;
/// Config-row column: technology registry index.
pub const CFG_TECH: usize = 4;
/// Config-row column: cache level (1 or 2).
pub const CFG_LEVEL: usize = 5;
/// Number of config-row columns (one cache level per row).
pub const NCFG: usize = 6;

/// Technology rows in the AOT'd tech-table literal (SRAM, FeFET — frozen).
pub const NTECH: usize = 2;
/// Parameters per technology row: energy + latency × two levels × [`NOPS`].
pub const NTECH_PARAMS: usize = 4 * NOPS;

/// Anchor geometry of Table III: L1 capacity 64 kB.
pub const ANCHOR_L1_CAP: f64 = 64.0 * 1024.0;
/// Anchor geometry of Table III: L1 associativity (4-way).
pub const ANCHOR_ASSOC: f64 = 4.0;
/// Bank count both anchor rows were characterized at.
pub const ANCHOR_BANKS: f64 = 4.0;
/// Associativity power-law exponent of the interpolation.
pub const ASSOC_EXP: f64 = 0.15;

/// H-tree / bus transport multiplier for *hierarchy* accesses: a regular
/// read moves the line from the array through the H-tree, output drivers
/// and bus to the LSQ (McPAT counts ≈2–4× the array-access energy at L1);
/// a CiM operation computes inside the array and never pays this — the
/// very asymmetry that makes CiM attractive.
pub const XBUS_FACTOR: f64 = 4.0;

/// `[NTECH][E_L1(6) | E_L2(6) | LAT_L1(6) | LAT_L2(6)]`
/// Energies in pJ (Table III; write column interpolated), latencies in
/// cycles at 1 GHz (Fig 11).
pub const TECH_TABLE: [[f64; NTECH_PARAMS]; NTECH] = [
    // SRAM:  read   write  or     and    xor    add
    [61.0, 70.0, 71.0, 72.0, 79.0, 79.0,
     314.0, 360.0, 341.0, 344.0, 365.0, 365.0,
     2.0, 2.0, 2.0, 2.0, 2.0, 6.0,
     8.0, 8.0, 8.0, 8.0, 8.0, 12.0],
    // FeFET
    [34.0, 44.0, 35.0, 88.0, 105.0, 105.0,
     70.0, 91.0, 72.0, 146.0, 205.0, 205.0,
     1.0, 1.0, 1.0, 1.0, 1.0, 4.0,
     5.0, 5.0, 5.0, 5.0, 5.0, 9.0],
];

/// Offset of the L1 energy block in a tech-table row.
pub const TP_E_L1: usize = 0;
/// Offset of the L2 energy block in a tech-table row.
pub const TP_E_L2: usize = NOPS;
/// Offset of the L1 latency block in a tech-table row.
pub const TP_LAT_L1: usize = 2 * NOPS;
/// Offset of the L2 latency block in a tech-table row.
pub const TP_LAT_L2: usize = 3 * NOPS;

/// Flattened tech table as f32 (the PJRT input literal).
pub fn tech_table_f32() -> Vec<f32> {
    TECH_TABLE.iter().flatten().map(|&x| x as f32).collect()
}

use crate::reshape::counters::*;

/// Per-event static unit energies (pJ), 45 nm Cortex-A9-class core.
///
/// Cache/CiM columns (22..42) are placeholders — the profiler overwrites
/// them from the array model; only core events, DRAM and leakage matter
/// here.  These values set Table VI's absolute improvement band: a
/// Cortex-A9 @45 nm burns ~0.25 W/core at 1 GHz ⇒ ≈230 pJ/instruction at
/// CPI≈1.  The host-side share of an offloaded instruction (≈200 pJ) plus
/// the H-tree/bus transport of the cache accesses it removes (XBUS_FACTOR ×
/// Table III array energy) dominates the 35–365 pJ in-array CiM op that
/// replaces them — reproducing the paper's "improvement mainly contributed
/// by the host side" with small ± cache-side contributions.
pub fn static_unit_energy() -> [f64; NC] {
    let mut u = [0.0f64; NC];
    u[C_FETCH] = 50.0;
    u[C_DECODE] = 19.0;
    u[C_RENAME] = 25.0;
    u[C_IQ_READS] = 13.0;
    u[C_IQ_WRITES] = 15.0;
    u[C_ROB_READS] = 13.0;
    u[C_ROB_WRITES] = 15.0;
    u[C_INT_RF_READS] = 8.0;
    u[C_INT_RF_WRITES] = 10.0;
    u[C_FP_RF_READS] = 11.0;
    u[C_FP_RF_WRITES] = 14.0;
    u[C_INT_ALU] = 63.0;
    u[C_INT_MUL] = 155.0;
    u[C_INT_DIV] = 375.0;
    u[C_FP_ALU] = 113.0;
    u[C_FP_MUL] = 188.0;
    u[C_FP_DIV] = 500.0;
    u[C_BRANCH] = 25.0;
    u[C_BPRED_LOOKUPS] = 9.0;
    u[C_BPRED_MISPREDICTS] = 125.0;
    u[C_LSQ_READS] = 19.0;
    u[C_LSQ_WRITES] = 23.0;
    u[C_DRAM_READS] = 6000.0;
    u[C_DRAM_WRITES] = 6500.0;
    u[C_CYCLES] = 25.0; // leakage pJ/cycle (core + caches)
    u
}

/// [`static_unit_energy`] as f32 (the PJRT input literal).
pub fn static_unit_energy_f32() -> Vec<f32> {
    static_unit_energy().iter().map(|&x| x as f32).collect()
}

/// Number of report components.
pub const NCOMP: usize = 8;
/// Component index: core (fetch/decode/execute structures).
pub const COMP_CORE: usize = 0;
/// Component index: L1 instruction cache.
pub const COMP_L1I: usize = 1;
/// Component index: L1 data cache.
pub const COMP_L1D: usize = 2;
/// Component index: unified L2.
pub const COMP_L2: usize = 3;
/// Component index: main memory.
pub const COMP_DRAM: usize = 4;
/// Component index: in-array CiM ops at L1.
pub const COMP_CIM_L1: usize = 5;
/// Component index: in-array CiM ops at L2.
pub const COMP_CIM_L2: usize = 6;
/// Component index: leakage.
pub const COMP_LEAK: usize = 7;
/// Display names of the components, in index order.
pub const COMP_NAMES: [&str; NCOMP] =
    ["core", "l1i", "l1d", "l2", "dram", "cim_l1", "cim_l2", "leak"];

/// counter index → component index (mirrors `constants.group_matrix`).
pub fn comp_of_counter(i: usize) -> usize {
    match i {
        0..=21 => COMP_CORE,
        22..=23 => COMP_L1I,
        24..=27 => COMP_L1D,
        28..=31 => COMP_L2,
        32..=33 => COMP_DRAM,
        34..=37 => COMP_CIM_L1,
        38..=41 => COMP_CIM_L2,
        42 => COMP_LEAK,
        _ => panic!("counter index {i} out of range"),
    }
}

/// The `[NC][NCOMP]` one-hot grouping matrix flattened to f32 (PJRT input).
pub fn group_matrix_f32() -> Vec<f32> {
    let mut g = vec![0f32; NC * NCOMP];
    for i in 0..NC {
        g[i * NCOMP + comp_of_counter(i)] = 1.0;
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tech_table_shape_and_anchors() {
        assert_eq!(TECH_TABLE[0][TP_E_L1 + OP_READ], 61.0); // Table III
        assert_eq!(TECH_TABLE[0][TP_E_L2 + OP_ADD], 365.0);
        assert_eq!(TECH_TABLE[1][TP_E_L1 + OP_READ], 34.0);
        assert_eq!(TECH_TABLE[1][TP_E_L2 + OP_XOR], 205.0);
        // Fig 11: SRAM CiM-ADD ≈ read + 4 cycles
        assert_eq!(
            TECH_TABLE[0][TP_LAT_L1 + OP_ADD] - TECH_TABLE[0][TP_LAT_L1 + OP_READ],
            4.0
        );
    }

    #[test]
    fn group_matrix_partitions() {
        let g = group_matrix_f32();
        for i in 0..NC {
            let row: f32 = g[i * NCOMP..(i + 1) * NCOMP].iter().sum();
            assert_eq!(row, 1.0);
        }
    }

    #[test]
    fn static_units_populated() {
        let u = static_unit_energy();
        assert!(u[C_FETCH] > 0.0);
        assert!(u[C_DRAM_READS] > 1000.0);
        assert!(u[C_CYCLES] > 0.0);
        // cache/CiM columns left to the array model
        assert_eq!(u[C_L1D_READ_HITS], 0.0);
        assert_eq!(u[C_CIM_L1_ADD], 0.0);
    }
}
