//! Device/array/core energy models — the modeling-stage "CiM module model"
//! (paper §V-B) plus the McPAT-lite per-event core model (§V-C).
//!
//! * [`device`] — the pluggable device-technology registry: parametric
//!   [`device::DeviceModel`]s (built-in SRAM/FeFET/RRAM/STT-MRAM plus
//!   anything registered from TOML) with per-device scaling rules.
//! * [`array`] — the DESTINY-lite power-law interpolation that turns a
//!   registered model + cache geometry into per-op energies/latencies.
//! * [`calib`] — calibration constants shared with the Python/Pallas side
//!   (the legacy two-row `TECH_TABLE` is the PJRT artifact contract).
//! * [`mcpat`] — per-counter unit energies and component aggregation.
//!
//! Everything here is the *native mirror* of the AOT'd JAX graph; the
//! PJRT path (`runtime/`) must agree with it to float32 tolerance
//! (cross-checked in `rust/tests/runtime_artifacts.rs`).

pub mod array;
pub mod calib;
pub mod device;
pub mod mcpat;

pub use array::{cfg_row, cfg_rows, energy_latency, CfgRow};
pub use mcpat::{aggregate, destiny_only_estimate, unit_energy};
