//! Device/array/core energy models — the modeling-stage "CiM module model"
//! (paper §V-B) plus the McPAT-lite per-event core model (§V-C).
//!
//! Everything here is the *native mirror* of the AOT'd JAX graph; the
//! PJRT path (`runtime/`) must agree with it to float32 tolerance
//! (cross-checked in `rust/tests/runtime_artifacts.rs`).

pub mod array;
pub mod calib;
pub mod mcpat;

pub use array::{cfg_row, cfg_rows, energy_latency, CfgRow};
pub use mcpat::{aggregate, destiny_only_estimate, unit_energy};
