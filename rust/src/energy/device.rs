//! Pluggable device-technology registry and parametric array model.
//!
//! The paper evaluates exactly two memory technologies (CMOS SRAM and
//! FeFET-RAM, Table III / Fig 11).  This module generalizes that pair into
//! an open, DESTINY-style analytic model: a [`DeviceModel`] carries per-op
//! read/write/or/and/xor/add energy and latency coefficients at the two
//! published anchor geometries plus a [`ScalingRule`] describing how they
//! extrapolate with capacity, associativity and banking.  Models live in a
//! process-wide registry; [`crate::config::Technology`] is an interned
//! handle (id + name) into it.
//!
//! Built-ins (always present, in this id order):
//!
//! | id | name       | aliases                | source                     |
//! |----|------------|------------------------|----------------------------|
//! | 0  | `sram`     | `cmos`                 | Table III / Fig 11 anchors |
//! | 1  | `fefet`    | `fefet-ram`            | Table III / Fig 11 anchors |
//! | 2  | `rram`     | `reram`                | representative published   |
//! | 3  | `stt-mram` | `sttram`, `stt`, `mram`| representative published   |
//!
//! The SRAM and FeFET built-ins are constructed *from* the legacy
//! [`TECH_TABLE`] anchor rows, so every energy/latency they produce is
//! byte-identical to the pre-registry model (`tests/device_registry.rs`
//! is the contract).  The RRAM and STT-MRAM presets are representative
//! values compiled from the published CiM-prototype literature (see the
//! CiM landscape survey, arXiv 2401.14428): both are resistive
//! technologies with cheap reads and expensive writes, RRAM with the
//! widest read/write asymmetry, STT-MRAM with the longer write latency.
//! They are starting points for exploration — override any coefficient
//! from a `[tech.<name>]` TOML section (see `config::parse`).
//!
//! Registering a custom technology:
//!
//! ```
//! use eva_cim::config::Technology;
//! use eva_cim::energy::device::DeviceModel;
//! use eva_cim::energy::calib::OP_WRITE;
//!
//! // start from the FeFET built-in, halve the write energy
//! let mut model = DeviceModel::based_on(Technology::FEFET, "doc-ecram").unwrap();
//! model.e_l1[OP_WRITE] /= 2.0;
//! model.e_l2[OP_WRITE] /= 2.0;
//! let tech = eva_cim::energy::device::register(model).unwrap();
//!
//! assert_eq!(tech.name(), "doc-ecram");
//! assert_eq!(Technology::from_name("doc-ecram"), Some(tech));
//! // the array model picks the new coefficients up immediately
//! let row = eva_cim::energy::cfg_row(
//!     &eva_cim::config::CacheConfig::new(64 * 1024, 4, 3),
//!     tech,
//!     1,
//! );
//! let (e, _) = eva_cim::energy::energy_latency(&row);
//! assert!((e[OP_WRITE] - 22.0).abs() < 1e-9); // half of FeFET's 44 pJ
//! ```

use std::collections::HashMap;
use std::sync::{OnceLock, RwLock, RwLockReadGuard};

use crate::config::Technology;
use crate::util::json::Json;

use super::calib::{NOPS, NTECH_PARAMS, TECH_TABLE, TP_E_L1, TP_E_L2, TP_LAT_L1, TP_LAT_L2};

/// How a device's anchor coefficients extrapolate across geometries.
///
/// The model is the power-law interpolation of `energy/array.rs`,
/// generalized so every constant is per-device:
///
/// ```text
/// cap_eff = cap · anchor_banks / banks
/// E(cap, assoc) = E_L1 · (cap_eff / anchor_l1_cap)^bE
///                      · (assoc / anchor_l1_assoc)^assoc_exp
/// bE  = (ln(E_L2/E_L1) − assoc_exp·ln(anchor_l2_assoc/anchor_l1_assoc))
///       / ln(anchor_l2_cap/anchor_l1_cap)
/// lat(cap) = LAT_L1 · (cap_eff / anchor_l1_cap)^bL
/// bL  = ln(LAT_L2/LAT_L1) / ln(anchor_l2_cap/anchor_l1_cap)
/// ```
///
/// The default reproduces the legacy constants (64 kB/4-way L1 and
/// 256 kB/8-way L2 anchors, 4 banks, associativity exponent 0.15)
/// bit-for-bit.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScalingRule {
    /// capacity (bytes) of the level-1 anchor point
    pub anchor_l1_cap: f64,
    /// capacity (bytes) of the level-2 anchor point
    pub anchor_l2_cap: f64,
    /// associativity of the level-1 anchor point
    pub anchor_l1_assoc: f64,
    /// associativity of the level-2 anchor point
    pub anchor_l2_assoc: f64,
    /// bank count both anchors were characterized at
    pub anchor_banks: f64,
    /// associativity power-law exponent
    pub assoc_exp: f64,
}

impl Default for ScalingRule {
    fn default() -> Self {
        Self {
            anchor_l1_cap: super::calib::ANCHOR_L1_CAP,
            anchor_l2_cap: 4.0 * super::calib::ANCHOR_L1_CAP,
            anchor_l1_assoc: super::calib::ANCHOR_ASSOC,
            anchor_l2_assoc: 2.0 * super::calib::ANCHOR_ASSOC,
            anchor_banks: super::calib::ANCHOR_BANKS,
            assoc_exp: super::calib::ASSOC_EXP,
        }
    }
}

/// One device technology: per-op anchor coefficients + scaling rule.
///
/// Energies are pJ per operation at the anchor geometries; latencies are
/// cycles at 1 GHz.  Op order is the Table III column order of
/// `energy/calib.rs`: read, write, or, and, xor, add.
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceModel {
    /// registry name (interned lowercase on registration)
    pub name: String,
    /// alternative lookup names (e.g. `cmos` for `sram`)
    pub aliases: Vec<String>,
    /// per-op energy (pJ) at the L1 anchor geometry
    pub e_l1: [f64; NOPS],
    /// per-op energy (pJ) at the L2 anchor geometry
    pub e_l2: [f64; NOPS],
    /// per-op latency (cycles) at the L1 anchor geometry
    pub lat_l1: [f64; NOPS],
    /// per-op latency (cycles) at the L2 anchor geometry
    pub lat_l2: [f64; NOPS],
    /// capacity/associativity/banking extrapolation rule
    pub scaling: ScalingRule,
}

/// Error raised by [`register`] / [`DeviceModel::validate`].
#[derive(Debug)]
pub struct DeviceError(
    /// what was wrong with the model or the registration
    pub String,
);

impl std::fmt::Display for DeviceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "device model error: {}", self.0)
    }
}

impl std::error::Error for DeviceError {}

impl DeviceModel {
    /// A new model cloned from a registered technology's coefficients —
    /// the usual starting point for a custom device (override a handful
    /// of fields rather than supplying all 24 coefficients).
    pub fn based_on(base: Technology, name: &str) -> Result<DeviceModel, DeviceError> {
        let mut m = model_of(base);
        m.name = name.to_ascii_lowercase();
        m.aliases = Vec::new();
        Ok(m)
    }

    /// Flatten to the legacy `TECH_TABLE` row layout
    /// `[E_L1(6) | E_L2(6) | LAT_L1(6) | LAT_L2(6)]`.
    pub fn params(&self) -> [f64; NTECH_PARAMS] {
        let mut p = [0.0; NTECH_PARAMS];
        p[TP_E_L1..TP_E_L1 + NOPS].copy_from_slice(&self.e_l1);
        p[TP_E_L2..TP_E_L2 + NOPS].copy_from_slice(&self.e_l2);
        p[TP_LAT_L1..TP_LAT_L1 + NOPS].copy_from_slice(&self.lat_l1);
        p[TP_LAT_L2..TP_LAT_L2 + NOPS].copy_from_slice(&self.lat_l2);
        p
    }

    /// Check the model is usable by the power-law interpolation: every
    /// coefficient finite and positive (ratios are taken through `ln`),
    /// anchors positive with distinct L1/L2 capacities.
    pub fn validate(&self) -> Result<(), DeviceError> {
        let name = &self.name;
        if name.is_empty() || !name.bytes().all(|b| b.is_ascii_graphic()) {
            return Err(DeviceError(format!("bad technology name '{name}'")));
        }
        for (what, xs) in [
            ("e_l1", &self.e_l1),
            ("e_l2", &self.e_l2),
            ("lat_l1", &self.lat_l1),
            ("lat_l2", &self.lat_l2),
        ] {
            for (j, &x) in xs.iter().enumerate() {
                if !x.is_finite() || x <= 0.0 {
                    return Err(DeviceError(format!(
                        "{name}: {what}[{}] = {x} must be finite and positive",
                        super::calib::OP_NAMES[j]
                    )));
                }
            }
        }
        let s = &self.scaling;
        for (what, x) in [
            ("anchor_l1_cap", s.anchor_l1_cap),
            ("anchor_l2_cap", s.anchor_l2_cap),
            ("anchor_l1_assoc", s.anchor_l1_assoc),
            ("anchor_l2_assoc", s.anchor_l2_assoc),
            ("anchor_banks", s.anchor_banks),
        ] {
            if !x.is_finite() || x <= 0.0 {
                return Err(DeviceError(format!(
                    "{name}: {what} = {x} must be finite and positive"
                )));
            }
        }
        if !s.assoc_exp.is_finite() {
            return Err(DeviceError(format!("{name}: assoc_exp must be finite")));
        }
        if s.anchor_l2_cap == s.anchor_l1_cap {
            return Err(DeviceError(format!(
                "{name}: anchor capacities must differ (the capacity exponent \
                 is fit between them)"
            )));
        }
        Ok(())
    }

    /// True when the physical content (coefficients + scaling, not the
    /// cosmetic name/aliases) is identical.
    pub fn same_params(&self, other: &DeviceModel) -> bool {
        self.e_l1 == other.e_l1
            && self.e_l2 == other.e_l2
            && self.lat_l1 == other.lat_l1
            && self.lat_l2 == other.lat_l2
            && self.scaling == other.scaling
    }

    /// Canonical JSON of the physical content — the piece of a design
    /// point's cache identity contributed by the technology.  Two
    /// technologies with the same name but different coefficients hash
    /// differently, so the sweep result cache can never serve stale rows
    /// across a parameter edit.
    pub fn content_json(&self) -> Json {
        let arr = |xs: &[f64; NOPS]| Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect());
        let s = &self.scaling;
        Json::obj(vec![
            ("e_l1", arr(&self.e_l1)),
            ("e_l2", arr(&self.e_l2)),
            ("lat_l1", arr(&self.lat_l1)),
            ("lat_l2", arr(&self.lat_l2)),
            (
                "scaling",
                Json::obj(vec![
                    ("anchor_l1_cap", s.anchor_l1_cap.into()),
                    ("anchor_l2_cap", s.anchor_l2_cap.into()),
                    ("anchor_l1_assoc", s.anchor_l1_assoc.into()),
                    ("anchor_l2_assoc", s.anchor_l2_assoc.into()),
                    ("anchor_banks", s.anchor_banks.into()),
                    ("assoc_exp", s.assoc_exp.into()),
                ]),
            ),
        ])
    }
}

struct Entry {
    /// interned name — `Technology::name` hands out this `&'static str`
    name: &'static str,
    builtin: bool,
    model: DeviceModel,
}

struct Registry {
    entries: Vec<Entry>,
    /// lowercase name/alias → id
    by_name: HashMap<String, u16>,
}

impl Registry {
    fn insert(&mut self, model: DeviceModel, builtin: bool) -> Technology {
        let id = self.entries.len() as u16;
        let name: &'static str = Box::leak(model.name.clone().into_boxed_str());
        self.by_name.insert(model.name.clone(), id);
        for a in &model.aliases {
            self.by_name.insert(a.to_ascii_lowercase(), id);
        }
        self.entries.push(Entry { name, builtin, model });
        Technology::from_id(id)
    }
}

fn builtin(name: &str, aliases: &[&str], table_row: &[f64; NTECH_PARAMS]) -> DeviceModel {
    let pick = |at: usize| {
        let mut xs = [0.0; NOPS];
        xs.copy_from_slice(&table_row[at..at + NOPS]);
        xs
    };
    DeviceModel {
        name: name.to_string(),
        aliases: aliases.iter().map(|s| s.to_string()).collect(),
        e_l1: pick(TP_E_L1),
        e_l2: pick(TP_E_L2),
        lat_l1: pick(TP_LAT_L1),
        lat_l2: pick(TP_LAT_L2),
        scaling: ScalingRule::default(),
    }
}

/// The RRAM preset: widest read/write asymmetry of the four built-ins
/// (representative 1T1R ReRAM numbers — cheap line reads, expensive
/// SET/RESET writes, logic ops close to reads, carry-add the priciest).
fn rram_preset() -> DeviceModel {
    DeviceModel {
        name: "rram".into(),
        aliases: vec!["reram".into()],
        e_l1: [28.0, 190.0, 30.0, 30.0, 62.0, 68.0],
        e_l2: [121.0, 810.0, 130.0, 130.0, 264.0, 290.0],
        lat_l1: [2.0, 5.0, 2.0, 2.0, 3.0, 7.0],
        lat_l2: [7.0, 16.0, 7.0, 7.0, 10.0, 14.0],
        scaling: ScalingRule::default(),
    }
}

/// The STT-MRAM preset: moderate read energy, high write energy with the
/// longest write latency (spin-transfer switching time).
fn stt_mram_preset() -> DeviceModel {
    DeviceModel {
        name: "stt-mram".into(),
        aliases: vec!["sttram".into(), "stt".into(), "mram".into()],
        e_l1: [35.0, 162.0, 38.0, 38.0, 80.0, 86.0],
        e_l2: [148.0, 695.0, 161.0, 161.0, 330.0, 352.0],
        lat_l1: [2.0, 6.0, 2.0, 2.0, 3.0, 7.0],
        lat_l2: [6.0, 14.0, 6.0, 6.0, 8.0, 12.0],
        scaling: ScalingRule::default(),
    }
}

fn registry() -> &'static RwLock<Registry> {
    static REG: OnceLock<RwLock<Registry>> = OnceLock::new();
    REG.get_or_init(|| {
        let mut r = Registry { entries: Vec::new(), by_name: HashMap::new() };
        // id order is a stable contract: sram=0, fefet=1 mirror the legacy
        // TECH_TABLE rows (and the AOT'd tech-table literal); rram=2 and
        // stt-mram=3 extend it
        r.insert(builtin("sram", &["cmos"], &TECH_TABLE[0]), true);
        r.insert(builtin("fefet", &["fefet-ram"], &TECH_TABLE[1]), true);
        r.insert(rram_preset(), true);
        r.insert(stt_mram_preset(), true);
        RwLock::new(r)
    })
}

fn read() -> RwLockReadGuard<'static, Registry> {
    registry().read().unwrap_or_else(|p| p.into_inner())
}

/// Register (or update) a device technology and return its handle.
///
/// * a new name registers a new technology;
/// * re-registering a name with identical physical content returns the
///   existing handle (idempotent — re-parsing the same TOML is free);
/// * re-registering a *custom* name with different content replaces the
///   coefficients **and alias set** in place (existing [`Technology`]
///   handles pick the new values up; sweep caches stay correct because
///   the content hash covers the coefficients);
/// * redefining a built-in with different content is an error.
pub fn register(model: DeviceModel) -> Result<Technology, DeviceError> {
    let mut model = model;
    model.name = model.name.to_ascii_lowercase();
    model.validate()?;
    let mut reg = registry().write().unwrap_or_else(|p| p.into_inner());
    if let Some(&id) = reg.by_name.get(&model.name) {
        // snapshot the facts before mutating (the guard can't hand out
        // disjoint field borrows across its Deref)
        let canonical = reg.entries[id as usize].name;
        let is_builtin = reg.entries[id as usize].builtin;
        let same = reg.entries[id as usize].model.same_params(&model);
        if canonical != model.name {
            return Err(DeviceError(format!(
                "'{}' is an alias of '{canonical}'; register under a \
                 distinct name",
                model.name
            )));
        }
        if same {
            return Ok(Technology::from_id(id));
        }
        if is_builtin {
            return Err(DeviceError(format!(
                "cannot redefine built-in technology '{}'",
                model.name
            )));
        }
        // validate every alias before touching any state: a late conflict
        // must not leave half the aliases registered against stale params
        let aliases: Vec<String> =
            model.aliases.iter().map(|a| a.to_ascii_lowercase()).collect();
        for a in &aliases {
            if reg.by_name.get(a).is_some_and(|&other| other != id) {
                return Err(DeviceError(format!(
                    "alias '{a}' already names another technology"
                )));
            }
        }
        // drop this id's old aliases (keep its canonical name), then
        // install the new set — lookup must mirror the current model
        let keep = model.name.clone();
        reg.by_name.retain(|k, v| *v != id || *k == keep);
        for a in aliases {
            reg.by_name.insert(a, id);
        }
        reg.entries[id as usize].model = model;
        return Ok(Technology::from_id(id));
    }
    for a in &model.aliases {
        if reg.by_name.contains_key(&a.to_ascii_lowercase()) {
            return Err(DeviceError(format!(
                "alias '{a}' already names another technology"
            )));
        }
    }
    if reg.entries.len() >= u16::MAX as usize {
        return Err(DeviceError("technology registry full".into()));
    }
    Ok(reg.insert(model, false))
}

/// Resolve a name or alias (case-insensitive) to its handle.
pub fn lookup(name: &str) -> Option<Technology> {
    read()
        .by_name
        .get(&name.to_ascii_lowercase())
        .map(|&id| Technology::from_id(id))
}

/// The interned registry name of a handle.
pub fn name_of(tech: Technology) -> &'static str {
    let reg = read();
    match reg.entries.get(tech.index()) {
        Some(e) => e.name,
        None => "?", // unreachable through the public API
    }
}

/// Snapshot of a technology's model (clone; the registry stays shared).
pub fn model_of(tech: Technology) -> DeviceModel {
    with_model(tech.index(), |m| m.clone())
}

/// Run `f` against the model at `index` under the registry read lock —
/// the allocation-free hot path for the array model.  An index beyond
/// every registered entry (a malformed config row) resolves to the
/// legacy `min(NTECH - 1)` clamp — FeFET — so garbage rows produce the
/// same deterministic numbers regardless of what else was registered.
pub fn with_model<R>(index: usize, f: impl FnOnce(&DeviceModel) -> R) -> R {
    let reg = read();
    let i = if index < reg.entries.len() {
        index
    } else {
        super::calib::NTECH - 1
    };
    f(&reg.entries[i].model)
}

/// All registered technologies, in id (registration) order.
pub fn all() -> Vec<Technology> {
    let n = read().entries.len() as u16;
    (0..n).map(Technology::from_id).collect()
}

/// True for the four models the crate ships with.
pub fn is_builtin(tech: Technology) -> bool {
    read().entries.get(tech.index()).is_some_and(|e| e.builtin)
}

/// Diagnostic for an unrecognized `--tech`/`tech =` value: lists every
/// registered name and suggests the nearest one by edit distance.
pub fn unknown_tech_message(query: &str) -> String {
    let reg = read();
    let names: Vec<&str> = reg.entries.iter().map(|e| e.name).collect();
    let mut candidates: Vec<&str> = reg.by_name.keys().map(|s| s.as_str()).collect();
    candidates.sort_unstable();
    let q = query.to_ascii_lowercase();
    let best = candidates
        .iter()
        .map(|c| (levenshtein(&q, c), *c))
        .min()
        .filter(|&(d, _)| d <= 3);
    let mut msg = format!(
        "unknown technology '{query}' (registered: {})",
        names.join(", ")
    );
    if let Some((_, s)) = best {
        msg.push_str(&format!(" — did you mean '{s}'?"));
    } else {
        msg.push_str("; load custom technologies with --tech-file or a [tech.<name>] section");
    }
    msg
}

/// Classic dynamic-programming edit distance (small inputs only).  Shared
/// with the planner's `--policy` did-you-mean diagnostic.
pub(crate) fn levenshtein(a: &str, b: &str) -> usize {
    let (a, b): (Vec<char>, Vec<char>) = (a.chars().collect(), b.chars().collect());
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for i in 1..=a.len() {
        cur[0] = i;
        for j in 1..=b.len() {
            let sub = prev[j - 1] + usize::from(a[i - 1] != b[j - 1]);
            cur[j] = sub.min(prev[j] + 1).min(cur[j - 1] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::calib::{NTECH, OP_READ, OP_WRITE};

    #[test]
    fn builtin_ids_and_names_are_stable() {
        assert_eq!(Technology::SRAM.index(), 0);
        assert_eq!(Technology::FEFET.index(), 1);
        assert_eq!(Technology::RRAM.index(), 2);
        assert_eq!(Technology::STT_MRAM.index(), 3);
        assert_eq!(Technology::SRAM.name(), "sram");
        assert_eq!(Technology::STT_MRAM.name(), "stt-mram");
        assert!(all().len() >= 4);
        for t in [Technology::SRAM, Technology::FEFET, Technology::RRAM] {
            assert!(is_builtin(t));
        }
    }

    #[test]
    fn builtins_flatten_to_the_legacy_table_rows() {
        for (i, tech) in [Technology::SRAM, Technology::FEFET].into_iter().enumerate() {
            assert_eq!(model_of(tech).params(), TECH_TABLE[i]);
        }
        assert_eq!(NTECH, 2, "the AOT tech-table literal stays two rows");
    }

    #[test]
    fn lookup_covers_names_and_aliases_case_insensitively() {
        assert_eq!(lookup("SRAM"), Some(Technology::SRAM));
        assert_eq!(lookup("cmos"), Some(Technology::SRAM));
        assert_eq!(lookup("fefet-ram"), Some(Technology::FEFET));
        assert_eq!(lookup("ReRAM"), Some(Technology::RRAM));
        assert_eq!(lookup("mram"), Some(Technology::STT_MRAM));
        assert_eq!(lookup("no-such-device"), None);
    }

    #[test]
    fn register_is_idempotent_and_guards_builtins() {
        let m = model_of(Technology::SRAM);
        // identical content under the same name: same handle back
        assert_eq!(register(m.clone()).unwrap(), Technology::SRAM);
        // different content under a built-in name: rejected
        let mut hacked = m.clone();
        hacked.e_l1[OP_READ] *= 2.0;
        assert!(register(hacked).is_err());
        // an alias cannot be registered as a standalone name
        let mut aliased = m;
        aliased.name = "cmos".into();
        assert!(register(aliased).is_err());
    }

    #[test]
    fn custom_registration_roundtrips_and_updates_in_place() {
        let mut m = DeviceModel::based_on(Technology::RRAM, "test-dev-a").unwrap();
        m.aliases = vec!["test-dev-a-alias".into()];
        let t = register(m.clone()).unwrap();
        assert_eq!(t.name(), "test-dev-a");
        assert_eq!(lookup("test-dev-a-alias"), Some(t));
        assert!(!is_builtin(t));
        // in-place update: same handle, new coefficients
        m.e_l1[OP_WRITE] = 99.5;
        let t2 = register(m.clone()).unwrap();
        assert_eq!(t, t2);
        assert_eq!(model_of(t).e_l1[OP_WRITE], 99.5);
        // replacing the alias set prunes the old lookups
        m.e_l1[OP_WRITE] = 100.0;
        m.aliases = vec!["test-dev-a-alias2".into()];
        register(m).unwrap();
        assert_eq!(lookup("test-dev-a-alias"), None, "stale alias must be pruned");
        assert_eq!(lookup("test-dev-a-alias2"), Some(t));
        assert_eq!(lookup("test-dev-a"), Some(t), "canonical name survives");
    }

    #[test]
    fn failed_alias_update_leaves_no_partial_state() {
        let mut m = DeviceModel::based_on(Technology::RRAM, "test-dev-b").unwrap();
        let t = register(m.clone()).unwrap();
        // conflicting alias ("sram" is taken) with edited coefficients:
        // the whole update must be rejected atomically
        m.e_l1[OP_READ] = 55.0;
        m.aliases = vec!["test-dev-b-fresh".into(), "sram".into()];
        assert!(register(m).is_err());
        assert_eq!(lookup("test-dev-b-fresh"), None, "no partial alias insert");
        assert_ne!(model_of(t).e_l1[OP_READ], 55.0, "model must be unchanged");
    }

    #[test]
    fn validate_rejects_degenerate_models() {
        let mut m = DeviceModel::based_on(Technology::SRAM, "test-bad").unwrap();
        m.e_l1[OP_READ] = 0.0;
        assert!(m.validate().is_err());
        let mut m = DeviceModel::based_on(Technology::SRAM, "test-bad").unwrap();
        m.lat_l2[OP_READ] = f64::NAN;
        assert!(m.validate().is_err());
        let mut m = DeviceModel::based_on(Technology::SRAM, "test-bad").unwrap();
        m.scaling.anchor_l2_cap = m.scaling.anchor_l1_cap;
        assert!(m.validate().is_err());
        let mut m = DeviceModel::based_on(Technology::SRAM, "test-bad").unwrap();
        m.name = "has space".into();
        assert!(m.validate().is_err());
    }

    #[test]
    fn content_json_is_canonical_and_parameter_sensitive() {
        let a = model_of(Technology::SRAM).content_json().dump();
        let b = model_of(Technology::SRAM).content_json().dump();
        assert_eq!(a, b);
        let mut m = model_of(Technology::SRAM);
        m.e_l1[OP_READ] += 1.0;
        assert_ne!(m.content_json().dump(), a);
        // scaling-rule edits are part of the identity too
        let mut m = model_of(Technology::SRAM);
        m.scaling.assoc_exp = 0.2;
        assert_ne!(m.content_json().dump(), a);
    }

    #[test]
    fn unknown_tech_message_suggests_nearest() {
        let msg = unknown_tech_message("sramm");
        assert!(msg.contains("did you mean 'sram'"), "{msg}");
        assert!(msg.contains("fefet"), "{msg}");
        let far = unknown_tech_message("zzzzzzzzzz");
        assert!(!far.contains("did you mean"), "{far}");
    }

    #[test]
    fn levenshtein_basics() {
        assert_eq!(levenshtein("", ""), 0);
        assert_eq!(levenshtein("sram", "sram"), 0);
        assert_eq!(levenshtein("sram", "srm"), 1);
        assert_eq!(levenshtein("kitten", "sitting"), 3);
    }
}
