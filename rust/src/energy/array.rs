//! Native DESTINY-lite array model — the Rust mirror of the L1 Pallas
//! kernel (`python/compile/kernels/cim_energy.py`, oracle in `ref.py`).
//!
//! Power-law interpolation anchored at the published Table III points
//! (shown here with the default [`ScalingRule`] constants; every constant
//! is per-device in the registry — see [`crate::energy::device`]):
//!
//! ```text
//! E(cap, assoc) = E_L1 · (cap_eff / 64 kB)^bE · (assoc / 4)^0.15
//! bE = (ln(E_L2 / E_L1) − 0.15·ln 2) / ln 4
//! lat(cap)      = LAT_L1 · (cap_eff / 64 kB)^bL,   bL = ln(L2/L1)/ln 4
//! cap_eff       = cap · 4 / banks
//! ```
//!
//! Exactness at the anchors is tested below; the PJRT artifact is
//! cross-checked against this mirror in `rust/tests/runtime_artifacts.rs`,
//! and the registry built-ins against the legacy `TECH_TABLE` in
//! `rust/tests/device_registry.rs`.
//!
//! [`ScalingRule`]: crate::energy::device::ScalingRule

use crate::config::{CacheConfig, SystemConfig, Technology};

use super::calib::*;
use super::device;

/// A design-point row (what the AOT graph calls `cfg[B, NCFG]`).
pub type CfgRow = [f64; NCFG];

/// Build a config row for one cache level of a system config.
pub fn cfg_row(cache: &CacheConfig, tech: Technology, level: u32) -> CfgRow {
    [
        cache.capacity as f64,
        cache.assoc as f64,
        cache.line as f64,
        cache.banks as f64,
        tech.index() as f64,
        level as f64,
    ]
}

/// L1 and L2 rows for a system config.
pub fn cfg_rows(cfg: &SystemConfig) -> (CfgRow, CfgRow) {
    (cfg_row(&cfg.l1d, cfg.tech, 1), cfg_row(&cfg.l2, cfg.tech, 2))
}

/// Per-op energy (pJ) and latency (cycles) for one design point.
///
/// The technology column of the row indexes the device registry;
/// out-of-range indices clamp to the last registered model (the legacy
/// `min(NTECH - 1)` behavior).
pub fn energy_latency(row: &CfgRow) -> ([f64; NOPS], [f64; NOPS]) {
    let cap = row[CFG_CAPACITY];
    let assoc = row[CFG_ASSOC].max(1.0);
    let banks = row[CFG_BANKS].max(1.0);
    let tech = row[CFG_TECH] as usize;

    device::with_model(tech, |m| {
        let s = &m.scaling;
        let cap_ratio_ln = (s.anchor_l2_cap / s.anchor_l1_cap).ln();
        let assoc_ratio_ln = (s.anchor_l2_assoc / s.anchor_l1_assoc).ln();
        let cap_eff = cap * (s.anchor_banks / banks);
        let cap_n = (cap_eff / s.anchor_l1_cap).ln();
        let assoc_f = (assoc / s.anchor_l1_assoc).powf(s.assoc_exp);

        let mut energy = [0.0; NOPS];
        let mut lat = [0.0; NOPS];
        for j in 0..NOPS {
            let e1 = m.e_l1[j];
            let e2 = m.e_l2[j];
            let be = ((e2 / e1).ln() - s.assoc_exp * assoc_ratio_ln) / cap_ratio_ln;
            energy[j] = e1 * (be * cap_n).exp() * assoc_f;

            let l1 = m.lat_l1[j];
            let l2 = m.lat_l2[j];
            let bl = (l2 / l1).ln() / cap_ratio_ln;
            lat[j] = l1 * (bl * cap_n).exp();
        }
        (energy, lat)
    })
}

/// Batched version matching the AOT `energy_model` artifact signature.
pub fn energy_latency_batch(rows: &[CfgRow]) -> (Vec<[f64; NOPS]>, Vec<[f64; NOPS]>) {
    let mut es = Vec::with_capacity(rows.len());
    let mut ls = Vec::with_capacity(rows.len());
    for r in rows {
        let (e, l) = energy_latency(r);
        es.push(e);
        ls.push(l);
    }
    (es, ls)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn anchor_row(cap_kb: f64, assoc: f64, tech: usize) -> CfgRow {
        [cap_kb * 1024.0, assoc, 64.0, 4.0, tech as f64, 1.0]
    }

    #[test]
    fn reproduces_table3_anchors_exactly() {
        for tech in 0..NTECH {
            let (e1, l1) = energy_latency(&anchor_row(64.0, 4.0, tech));
            let (e2, l2) = energy_latency(&anchor_row(256.0, 8.0, tech));
            for j in 0..NOPS {
                let t = &TECH_TABLE[tech];
                assert!((e1[j] - t[TP_E_L1 + j]).abs() / t[TP_E_L1 + j] < 1e-9,
                    "tech {tech} op {j} L1: {} vs {}", e1[j], t[TP_E_L1 + j]);
                assert!((e2[j] - t[TP_E_L2 + j]).abs() / t[TP_E_L2 + j] < 1e-9,
                    "tech {tech} op {j} L2: {} vs {}", e2[j], t[TP_E_L2 + j]);
                assert!((l1[j] - t[TP_LAT_L1 + j]).abs() < 1e-9);
                assert!((l2[j] - t[TP_LAT_L2 + j]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn rram_and_stt_anchors_reproduce_their_presets() {
        use crate::config::Technology;
        for tech in [Technology::RRAM, Technology::STT_MRAM] {
            let m = device::model_of(tech);
            let (e1, l1) = energy_latency(&anchor_row(64.0, 4.0, tech.index()));
            let (e2, l2) = energy_latency(&anchor_row(256.0, 8.0, tech.index()));
            for j in 0..NOPS {
                assert!((e1[j] - m.e_l1[j]).abs() / m.e_l1[j] < 1e-9);
                assert!((e2[j] - m.e_l2[j]).abs() / m.e_l2[j] < 1e-9);
                assert!((l1[j] - m.lat_l1[j]).abs() < 1e-9);
                assert!((l2[j] - m.lat_l2[j]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn energy_monotone_in_capacity() {
        let caps = [16.0, 32.0, 64.0, 256.0, 2048.0];
        for tech in 0..NTECH {
            let mut prev = 0.0;
            for &c in &caps {
                let (e, _) = energy_latency(&anchor_row(c, 4.0, tech));
                assert!(e[OP_READ] > prev, "cap {c} tech {tech}");
                prev = e[OP_READ];
            }
        }
    }

    #[test]
    fn fefet_reads_cheaper_logic_pricier() {
        // Table III structure: FeFET read ≪ SRAM read, FeFET XOR > FeFET OR
        let (es, _) = energy_latency(&anchor_row(64.0, 4.0, 0));
        let (ef, _) = energy_latency(&anchor_row(64.0, 4.0, 1));
        assert!(ef[OP_READ] < es[OP_READ]);
        assert!(ef[OP_XOR] > ef[OP_OR]);
    }

    #[test]
    fn resistive_presets_have_expensive_writes() {
        // the structural signature of RRAM/STT-MRAM: write ≫ read
        for tech in [2usize, 3] {
            let (e, _) = energy_latency(&anchor_row(64.0, 4.0, tech));
            assert!(e[OP_WRITE] > 3.0 * e[OP_READ], "tech {tech}");
        }
    }

    #[test]
    fn out_of_range_tech_clamps_to_fefet_deterministically() {
        // malformed rows resolve to the legacy min(NTECH-1) clamp, never
        // to whatever technology happened to be registered last
        let fefet = energy_latency(&anchor_row(64.0, 4.0, 1));
        let mut row = anchor_row(64.0, 4.0, 1);
        row[CFG_TECH] = 99.0;
        assert_eq!(energy_latency(&row), fefet);
    }

    #[test]
    fn banks_reduce_effective_bitline_energy() {
        let mut few = anchor_row(256.0, 4.0, 0);
        few[CFG_BANKS] = 2.0;
        let mut many = anchor_row(256.0, 4.0, 0);
        many[CFG_BANKS] = 8.0;
        let (ef, _) = energy_latency(&few);
        let (em, _) = energy_latency(&many);
        assert!(em[OP_READ] < ef[OP_READ]);
    }

    #[test]
    fn cfg_rows_from_system() {
        let cfg = SystemConfig::preset("c1").unwrap();
        let (r1, r2) = cfg_rows(&cfg);
        assert_eq!(r1[CFG_CAPACITY], 32.0 * 1024.0);
        assert_eq!(r2[CFG_CAPACITY], 256.0 * 1024.0);
        assert_eq!(r1[CFG_LEVEL], 1.0);
        assert_eq!(r2[CFG_LEVEL], 2.0);
        assert_eq!(r1[CFG_TECH], 0.0);
    }
}
