//! EVA32: the mini RISC ISA the simulated host CPU executes.
//!
//! The paper instruments an ARM Cortex-A9 under GEM5; the analysis stage,
//! however, only consumes the committed-instruction stream (mnemonic, source
//! and destination registers, memory request info — Table I).  EVA32 is a
//! compact load/store ISA that produces the same interface: 32 integer
//! registers, 16 float registers, word-addressed memory ops with
//! base+offset addressing, and the usual Load-Load-OP-Store dataflow whose
//! patterns (Fig 4) the IDG analyzer mines.
//!
//! Instructions encode into a fixed 64-bit word
//! (`[op:8][rd:8][rs1:8][rs2:8][imm:32]`) — see [`Instruction::encode`].

pub mod func_unit;

pub use func_unit::FuncUnit;

/// Unified register namespace: `r0`..`r31` are integer (r0 ≡ 0),
/// `f0`..`f15` are float and live at ids 32..48.
pub type RegId = u8;

/// Number of integer registers (`r0`..`r31`).
pub const NUM_INT_REGS: u8 = 32;
/// Number of float registers (`f0`..`f15`).
pub const NUM_FP_REGS: u8 = 16;
/// Total register-file size (integer + float namespaces).
pub const NUM_REGS: u8 = NUM_INT_REGS + NUM_FP_REGS;

/// Zero register (always reads 0; writes discarded).
pub const R0: RegId = 0;
/// Return-address register by convention.
pub const RA: RegId = 1;
/// Stack pointer by convention.
pub const SP: RegId = 2;

/// First float register id.
pub const F0: RegId = NUM_INT_REGS;

/// Make a float register id from its index (`freg(3)` == `f3`).
pub const fn freg(i: u8) -> RegId {
    debug_assert!(i < NUM_FP_REGS);
    NUM_INT_REGS + i
}

/// Assembly name of a register id (`"r5"`, `"f3"`).
pub fn reg_name(r: RegId) -> String {
    if r < NUM_INT_REGS {
        format!("r{r}")
    } else {
        format!("f{}", r - NUM_INT_REGS)
    }
}

/// EVA32 opcodes.
///
/// Grouped as: integer register-register, integer register-immediate,
/// memory, control flow (branch/jump targets are *absolute instruction
/// indices*), f32 floating point, and misc.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Opcode {
    /// `rd = rs1 + rs2`
    Add = 0,
    /// `rd = rs1 - rs2`
    Sub,
    /// `rd = rs1 & rs2`
    And,
    /// `rd = rs1 | rs2`
    Or,
    /// `rd = rs1 ^ rs2`
    Xor,
    /// `rd = rs1 << rs2` (logical)
    Sll,
    /// `rd = rs1 >> rs2` (logical)
    Srl,
    /// `rd = rs1 >> rs2` (arithmetic)
    Sra,
    /// `rd = (rs1 < rs2)` signed
    Slt,
    /// `rd = (rs1 < rs2)` unsigned
    Sltu,
    /// `rd = rs1 * rs2`
    Mul,
    /// `rd = rs1 / rs2` (signed; 0-divisor yields 0)
    Div,
    /// `rd = rs1 % rs2` (signed; 0-divisor yields rs1)
    Rem,
    /// `rd = rs1 + imm`
    Addi,
    /// `rd = rs1 & imm`
    Andi,
    /// `rd = rs1 | imm`
    Ori,
    /// `rd = rs1 ^ imm`
    Xori,
    /// `rd = rs1 << imm` (logical)
    Slli,
    /// `rd = rs1 >> imm` (logical)
    Srli,
    /// `rd = rs1 >> imm` (arithmetic)
    Srai,
    /// `rd = (rs1 < imm)` signed
    Slti,
    /// `rd = imm << 12` (load upper immediate)
    Lui,
    /// `rd = mem32[rs1 + imm]`
    Lw,
    /// `mem32[rs1 + imm] = rs2`
    Sw,
    /// `rd = mem8[rs1 + imm]` (sign-extended)
    Lb,
    /// `mem8[rs1 + imm] = rs2`
    Sb,
    /// `fd = mem32[rs1 + imm]` (float load)
    Flw,
    /// `mem32[rs1 + imm] = fs2` (float store)
    Fsw,
    /// branch to `imm` if `rs1 == rs2`
    Beq,
    /// branch to `imm` if `rs1 != rs2`
    Bne,
    /// branch to `imm` if `rs1 < rs2` (signed)
    Blt,
    /// branch to `imm` if `rs1 >= rs2` (signed)
    Bge,
    /// branch to `imm` if `rs1 < rs2` (unsigned)
    Bltu,
    /// branch to `imm` if `rs1 >= rs2` (unsigned)
    Bgeu,
    /// `rd = next index; jump imm`
    Jal,
    /// `rd = next index; jump rs1 + imm`
    Jalr,
    /// `fd = fs1 + fs2`
    Fadd,
    /// `fd = fs1 - fs2`
    Fsub,
    /// `fd = fs1 * fs2`
    Fmul,
    /// `fd = fs1 / fs2`
    Fdiv,
    /// `fd = min(fs1, fs2)`
    Fmin,
    /// `fd = max(fs1, fs2)`
    Fmax,
    /// `rd(int) = (fs1 == fs2)`
    Feq,
    /// `rd(int) = (fs1 < fs2)`
    Flt,
    /// `rd(int) = (i32) fs1` (float → int convert)
    Fcvtws,
    /// `fd = (f32) rs1` (int → float convert)
    Fcvtsw,
    /// `fd = fs1` (float register move)
    Fmv,
    /// no operation
    Nop,
    /// stop the simulated program
    Halt,
}

/// Number of opcodes (contiguous discriminants `0..NUM_OPCODES`).
pub const NUM_OPCODES: u8 = Opcode::Halt as u8 + 1;

impl Opcode {
    /// Decode an opcode byte; `None` when out of range.
    pub fn from_u8(x: u8) -> Option<Opcode> {
        if x < NUM_OPCODES {
            // SAFETY: repr(u8), contiguous discriminants 0..NUM_OPCODES
            Some(unsafe { std::mem::transmute::<u8, Opcode>(x) })
        } else {
            None
        }
    }

    /// Assembly mnemonic (`"add"`, `"fcvt.w.s"`, ...).
    pub fn mnemonic(&self) -> &'static str {
        use Opcode::*;
        match self {
            Add => "add",
            Sub => "sub",
            And => "and",
            Or => "or",
            Xor => "xor",
            Sll => "sll",
            Srl => "srl",
            Sra => "sra",
            Slt => "slt",
            Sltu => "sltu",
            Mul => "mul",
            Div => "div",
            Rem => "rem",
            Addi => "addi",
            Andi => "andi",
            Ori => "ori",
            Xori => "xori",
            Slli => "slli",
            Srli => "srli",
            Srai => "srai",
            Slti => "slti",
            Lui => "lui",
            Lw => "lw",
            Sw => "sw",
            Lb => "lb",
            Sb => "sb",
            Flw => "flw",
            Fsw => "fsw",
            Beq => "beq",
            Bne => "bne",
            Blt => "blt",
            Bge => "bge",
            Bltu => "bltu",
            Bgeu => "bgeu",
            Jal => "jal",
            Jalr => "jalr",
            Fadd => "fadd",
            Fsub => "fsub",
            Fmul => "fmul",
            Fdiv => "fdiv",
            Fmin => "fmin",
            Fmax => "fmax",
            Feq => "feq",
            Flt => "flt",
            Fcvtws => "fcvt.w.s",
            Fcvtsw => "fcvt.s.w",
            Fmv => "fmv",
            Nop => "nop",
            Halt => "halt",
        }
    }

    /// Look an opcode up by its assembly mnemonic.
    pub fn from_mnemonic(s: &str) -> Option<Opcode> {
        (0..NUM_OPCODES)
            .filter_map(Opcode::from_u8)
            .find(|op| op.mnemonic() == s)
    }

    /// Memory load (integer or float)?
    pub fn is_load(&self) -> bool {
        matches!(self, Opcode::Lw | Opcode::Lb | Opcode::Flw)
    }

    /// Memory store (integer or float)?
    pub fn is_store(&self) -> bool {
        matches!(self, Opcode::Sw | Opcode::Sb | Opcode::Fsw)
    }

    /// Any memory access (load or store)?
    pub fn is_mem(&self) -> bool {
        self.is_load() || self.is_store()
    }

    /// Any control-flow instruction (conditional branch or jump)?
    pub fn is_branch(&self) -> bool {
        matches!(
            self,
            Opcode::Beq
                | Opcode::Bne
                | Opcode::Blt
                | Opcode::Bge
                | Opcode::Bltu
                | Opcode::Bgeu
                | Opcode::Jal
                | Opcode::Jalr
        )
    }

    /// Conditional branches only (predicted by the branch predictor).
    pub fn is_cond_branch(&self) -> bool {
        matches!(
            self,
            Opcode::Beq
                | Opcode::Bne
                | Opcode::Blt
                | Opcode::Bge
                | Opcode::Bltu
                | Opcode::Bgeu
        )
    }

    /// Floating-point instruction (including float loads/stores)?
    pub fn is_fp(&self) -> bool {
        matches!(
            self,
            Opcode::Fadd
                | Opcode::Fsub
                | Opcode::Fmul
                | Opcode::Fdiv
                | Opcode::Fmin
                | Opcode::Fmax
                | Opcode::Feq
                | Opcode::Flt
                | Opcode::Fcvtws
                | Opcode::Fcvtsw
                | Opcode::Fmv
                | Opcode::Flw
                | Opcode::Fsw
        )
    }

    /// Does this opcode use the immediate operand?
    pub fn has_imm(&self) -> bool {
        use Opcode::*;
        matches!(
            self,
            Addi | Andi | Ori | Xori | Slli | Srli | Srai | Slti | Lui | Lw
                | Sw | Lb | Sb | Flw | Fsw | Beq | Bne | Blt | Bge | Bltu
                | Bgeu | Jal | Jalr
        )
    }

    /// The functional unit that executes this opcode (PipeProbe events).
    pub fn func_unit(&self) -> FuncUnit {
        use Opcode::*;
        match self {
            Mul => FuncUnit::IntMul,
            Div | Rem => FuncUnit::IntDiv,
            Fadd | Fsub | Fmin | Fmax | Feq | Flt | Fcvtws | Fcvtsw | Fmv => {
                FuncUnit::FpAlu
            }
            Fmul => FuncUnit::FpMul,
            Fdiv => FuncUnit::FpDiv,
            Lw | Lb | Flw => FuncUnit::MemRead,
            Sw | Sb | Fsw => FuncUnit::MemWrite,
            Beq | Bne | Blt | Bge | Bltu | Bgeu | Jal | Jalr => FuncUnit::Branch,
            _ => FuncUnit::IntAlu,
        }
    }

    /// Execution latency in cycles, excluding memory (A9-class pipeline).
    pub fn exec_latency(&self) -> u64 {
        use FuncUnit::*;
        match self.func_unit() {
            IntAlu | Branch | MemWrite => 1,
            MemRead => 1, // address generation; cache latency added on top
            IntMul => 3,
            IntDiv => 12,
            FpAlu => 3,
            FpMul => 4,
            FpDiv => 15,
        }
    }
}

/// One EVA32 instruction.
///
/// Field use by class:
/// * ALU reg-reg:   `rd, rs1, rs2`
/// * ALU reg-imm:   `rd, rs1, imm`
/// * load:          `rd, rs1(base), imm(offset)`
/// * store:         `rs2(value), rs1(base), imm(offset)`
/// * branch:        `rs1, rs2, imm(absolute target index)`
/// * jal:           `rd, imm(target)` — `jalr`: `rd, rs1, imm`
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Instruction {
    /// operation
    pub op: Opcode,
    /// destination register (meaning depends on the class above)
    pub rd: RegId,
    /// first source register / address base
    pub rs1: RegId,
    /// second source register / store data
    pub rs2: RegId,
    /// immediate operand / memory offset / branch target index
    pub imm: i32,
}

impl Instruction {
    /// Assemble an instruction from its raw fields.
    pub fn new(op: Opcode, rd: RegId, rs1: RegId, rs2: RegId, imm: i32) -> Self {
        Self { op, rd, rs1, rs2, imm }
    }

    /// The canonical `nop`.
    pub fn nop() -> Self {
        Self::new(Opcode::Nop, R0, R0, R0, 0)
    }

    /// The canonical `halt`.
    pub fn halt() -> Self {
        Self::new(Opcode::Halt, R0, R0, R0, 0)
    }

    /// Destination register, if the instruction writes one.
    pub fn dest(&self) -> Option<RegId> {
        use Opcode::*;
        match self.op {
            Sw | Sb | Fsw | Beq | Bne | Blt | Bge | Bltu | Bgeu | Nop | Halt => {
                None
            }
            Jal | Jalr => {
                if self.rd == R0 {
                    None
                } else {
                    Some(self.rd)
                }
            }
            _ => {
                if self.rd == R0 {
                    None // writes to r0 are discarded
                } else {
                    Some(self.rd)
                }
            }
        }
    }

    /// Source registers in operand order (left, right).
    pub fn sources(&self) -> [Option<RegId>; 2] {
        use Opcode::*;
        let nz = |r: RegId| if r == R0 { None } else { Some(r) };
        match self.op {
            Nop | Halt | Lui | Jal => [None, None],
            // loads read the base register only
            Lw | Lb | Flw => [nz(self.rs1), None],
            // stores read base (rs1) and data (rs2)
            Sw | Sb | Fsw => [nz(self.rs1), nz(self.rs2)],
            Jalr => [nz(self.rs1), None],
            Addi | Andi | Ori | Xori | Slli | Srli | Srai | Slti | Fcvtsw => {
                [nz(self.rs1), None]
            }
            Fcvtws | Fmv => [nz(self.rs1), None],
            _ => [nz(self.rs1), nz(self.rs2)],
        }
    }

    /// Encode into the fixed 64-bit word `[op:8][rd:8][rs1:8][rs2:8][imm:32]`.
    pub fn encode(&self) -> u64 {
        ((self.op as u64) << 56)
            | ((self.rd as u64) << 48)
            | ((self.rs1 as u64) << 40)
            | ((self.rs2 as u64) << 32)
            | (self.imm as u32 as u64)
    }

    /// Decode from the 64-bit word; `None` on an invalid opcode byte.
    pub fn decode(word: u64) -> Option<Self> {
        let op = Opcode::from_u8((word >> 56) as u8)?;
        let rd = ((word >> 48) & 0xff) as u8;
        let rs1 = ((word >> 40) & 0xff) as u8;
        let rs2 = ((word >> 32) & 0xff) as u8;
        if rd >= NUM_REGS || rs1 >= NUM_REGS || rs2 >= NUM_REGS {
            return None;
        }
        Some(Self::new(op, rd, rs1, rs2, word as u32 as i32))
    }

    /// Human-readable assembly text.
    pub fn disasm(&self) -> String {
        use Opcode::*;
        let m = self.op.mnemonic();
        let r = reg_name;
        match self.op {
            Nop | Halt => m.to_string(),
            Lui => format!("{m} {}, {}", r(self.rd), self.imm),
            Jal => format!("{m} {}, {}", r(self.rd), self.imm),
            Jalr => format!("{m} {}, {}, {}", r(self.rd), r(self.rs1), self.imm),
            Lw | Lb | Flw => {
                format!("{m} {}, {}({})", r(self.rd), self.imm, r(self.rs1))
            }
            Sw | Sb | Fsw => {
                format!("{m} {}, {}({})", r(self.rs2), self.imm, r(self.rs1))
            }
            Beq | Bne | Blt | Bge | Bltu | Bgeu => {
                format!("{m} {}, {}, {}", r(self.rs1), r(self.rs2), self.imm)
            }
            _ if self.op.has_imm() => {
                format!("{m} {}, {}, {}", r(self.rd), r(self.rs1), self.imm)
            }
            Fmv | Fcvtws | Fcvtsw => {
                format!("{m} {}, {}", r(self.rd), r(self.rs1))
            }
            _ => format!(
                "{m} {}, {}, {}",
                r(self.rd),
                r(self.rs1),
                r(self.rs2)
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opcode_u8_roundtrip() {
        for x in 0..NUM_OPCODES {
            let op = Opcode::from_u8(x).unwrap();
            assert_eq!(op as u8, x);
        }
        assert!(Opcode::from_u8(NUM_OPCODES).is_none());
    }

    #[test]
    fn mnemonic_roundtrip() {
        for x in 0..NUM_OPCODES {
            let op = Opcode::from_u8(x).unwrap();
            assert_eq!(Opcode::from_mnemonic(op.mnemonic()), Some(op));
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let cases = [
            Instruction::new(Opcode::Add, 3, 4, 5, 0),
            Instruction::new(Opcode::Addi, 7, 3, 0, -42),
            Instruction::new(Opcode::Lw, 9, SP, 0, 1024),
            Instruction::new(Opcode::Sw, 0, SP, 9, -8),
            Instruction::new(Opcode::Beq, 0, 4, 5, 12345),
            Instruction::new(Opcode::Fadd, freg(1), freg(2), freg(3), 0),
            Instruction::halt(),
        ];
        for i in cases {
            assert_eq!(Instruction::decode(i.encode()), Some(i), "{}", i.disasm());
        }
    }

    #[test]
    fn decode_rejects_bad_opcode_and_regs() {
        assert!(Instruction::decode(0xff << 56).is_none());
        // valid opcode, out-of-range register
        let bad = ((Opcode::Add as u64) << 56) | (200u64 << 48);
        assert!(Instruction::decode(bad).is_none());
    }

    #[test]
    fn dest_and_sources() {
        let add = Instruction::new(Opcode::Add, 3, 4, 5, 0);
        assert_eq!(add.dest(), Some(3));
        assert_eq!(add.sources(), [Some(4), Some(5)]);

        let sw = Instruction::new(Opcode::Sw, 0, 2, 7, 4);
        assert_eq!(sw.dest(), None);
        assert_eq!(sw.sources(), [Some(2), Some(7)]);

        let lw = Instruction::new(Opcode::Lw, 5, 2, 0, 8);
        assert_eq!(lw.dest(), Some(5));
        assert_eq!(lw.sources(), [Some(2), None]);

        // r0 writes are discarded, r0 reads are not dependencies
        let to_zero = Instruction::new(Opcode::Add, 0, 0, 5, 0);
        assert_eq!(to_zero.dest(), None);
        assert_eq!(to_zero.sources(), [None, Some(5)]);
    }

    #[test]
    fn func_units_sensible() {
        assert_eq!(Opcode::Add.func_unit(), FuncUnit::IntAlu);
        assert_eq!(Opcode::Mul.func_unit(), FuncUnit::IntMul);
        assert_eq!(Opcode::Lw.func_unit(), FuncUnit::MemRead);
        assert_eq!(Opcode::Fsw.func_unit(), FuncUnit::MemWrite);
        assert_eq!(Opcode::Fdiv.func_unit(), FuncUnit::FpDiv);
        assert_eq!(Opcode::Beq.func_unit(), FuncUnit::Branch);
    }

    #[test]
    fn disasm_formats() {
        assert_eq!(
            Instruction::new(Opcode::Lw, 5, 2, 0, 8).disasm(),
            "lw r5, 8(r2)"
        );
        assert_eq!(
            Instruction::new(Opcode::Sw, 0, 2, 7, -4).disasm(),
            "sw r7, -4(r2)"
        );
        assert_eq!(
            Instruction::new(Opcode::Fadd, freg(0), freg(1), freg(2), 0)
                .disasm(),
            "fadd f0, f1, f2"
        );
    }

    #[test]
    fn fp_classification() {
        assert!(Opcode::Fadd.is_fp());
        assert!(Opcode::Flw.is_fp());
        assert!(!Opcode::Add.is_fp());
    }
}
