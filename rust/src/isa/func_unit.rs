//! Functional-unit classification (PipeProbe events / McPAT counters).

/// The functional units of the modelled out-of-order core.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum FuncUnit {
    /// integer ALU (adds, logic, shifts, compares)
    IntAlu = 0,
    /// integer multiplier
    IntMul,
    /// integer divider
    IntDiv,
    /// float ALU (add/sub, min/max, compares, converts, moves)
    FpAlu,
    /// float multiplier
    FpMul,
    /// float divider
    FpDiv,
    /// branch/jump unit
    Branch,
    /// memory-read port (address generation + cache access)
    MemRead,
    /// memory-write port
    MemWrite,
}

/// Number of functional units (dense indices `0..NUM_FUNC_UNITS`).
pub const NUM_FUNC_UNITS: usize = 9;

impl FuncUnit {
    /// Every unit, in index order.
    pub fn all() -> [FuncUnit; NUM_FUNC_UNITS] {
        use FuncUnit::*;
        [IntAlu, IntMul, IntDiv, FpAlu, FpMul, FpDiv, Branch, MemRead, MemWrite]
    }

    /// Snake-case counter name (`"int_alu"`, `"mem_read"`, ...).
    pub fn name(&self) -> &'static str {
        use FuncUnit::*;
        match self {
            IntAlu => "int_alu",
            IntMul => "int_mul",
            IntDiv => "int_div",
            FpAlu => "fp_alu",
            FpMul => "fp_mul",
            FpDiv => "fp_div",
            Branch => "branch",
            MemRead => "mem_read",
            MemWrite => "mem_write",
        }
    }

    /// Dense array index (the discriminant).
    pub fn index(&self) -> usize {
        *self as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indices_are_dense() {
        for (i, fu) in FuncUnit::all().iter().enumerate() {
            assert_eq!(fu.index(), i);
        }
    }

    #[test]
    fn names_unique() {
        let names: std::collections::HashSet<_> =
            FuncUnit::all().iter().map(|f| f.name()).collect();
        assert_eq!(names.len(), NUM_FUNC_UNITS);
    }
}
