//! Longest common subsequence — the paper's validation workload (§VI-A,
//! Table V / Fig 12).
//!
//! Classic O(m·n) dynamic program over a full table:
//! `dp[i][j] = dp[i-1][j-1] + 1` on a match, else
//! `max(dp[i-1][j], dp[i][j-1])` — three loads, an add/compare, one store
//! per cell: the archetypal CiM-convertible access pattern.

use crate::asm::Program;
use crate::util::Rng;

/// Build the LCS benchmark over two random strings of length ~`scale·16`.
pub fn lcs(scale: usize, seed: u64) -> Program {
    let n = if scale == 0 { 64 } else { (scale * 16).max(8) };
    let m = n;
    let mut rng = Rng::new(seed ^ 0x6c6373);
    let mut a = crate::asm::Asm::new("lcs");

    let sa: Vec<i32> = (0..m).map(|_| rng.gen_range(4) as i32).collect();
    let sb: Vec<i32> = (0..n).map(|_| rng.gen_range(4) as i32).collect();
    let ab = a.data.alloc_i32("a", &sa);
    let bb = a.data.alloc_i32("b", &sb);
    // dp is (m+1) x (n+1), zero-initialized
    let dp = a.data.alloc_i32("dp", &vec![0i32; (m + 1) * (n + 1)]);
    let stride = (n + 1) as i32 * 4;

    // r3=i, r4=j, r5=&dp[i][0], r6=&dp[i-1][0], r7=ai, r8=bj,
    // r9..r11 scratch
    let (ri, rj, rrow, rprev, rai, rbj, rtmp, rv1, rv2) = (3, 4, 5, 6, 7, 8, 9, 10, 11);
    a.li(ri, 1);
    let outer = a.label("outer");
    a.bind(outer);
    // row pointers
    a.li(rtmp, stride);
    a.mul(rrow, ri, rtmp);
    a.addi(rrow, rrow, dp as i32);
    a.sub(rprev, rrow, rtmp);
    // ai = a[i-1]
    a.slli(rai, ri, 2);
    a.addi(rai, rai, ab as i32 - 4);
    a.lw(rai, rai, 0);
    a.li(rj, 1);
    let inner = a.label("inner");
    a.bind(inner);
    // bj = b[j-1]
    a.slli(rbj, rj, 2);
    a.addi(rbj, rbj, bb as i32 - 4);
    a.lw(rbj, rbj, 0);
    let diff = a.label("diff");
    let store = a.label("store");
    a.bne(rai, rbj, diff);
    // match: dp[i][j] = dp[i-1][j-1] + 1
    a.slli(rtmp, rj, 2);
    a.add(rtmp, rtmp, rprev);
    a.lw(rv1, rtmp, -4);
    a.addi(rv1, rv1, 1);
    a.jump(store);
    a.bind(diff);
    // dp[i][j] = max(dp[i-1][j], dp[i][j-1])
    a.slli(rtmp, rj, 2);
    a.add(rv1, rtmp, rprev);
    a.lw(rv1, rv1, 0);
    a.add(rv2, rtmp, rrow);
    a.lw(rv2, rv2, -4);
    let keep = a.label("keep");
    a.bge(rv1, rv2, keep);
    a.mv(rv1, rv2);
    a.bind(keep);
    a.bind(store);
    a.slli(rtmp, rj, 2);
    a.add(rtmp, rtmp, rrow);
    a.sw(rv1, rtmp, 0);
    a.addi(rj, rj, 1);
    a.li(rtmp, n as i32 + 1);
    a.blt(rj, rtmp, inner);
    a.addi(ri, ri, 1);
    a.li(rtmp, m as i32 + 1);
    a.blt(ri, rtmp, outer);

    // verification sweep (as in the reference LCS harness): fold the DP
    // table into an additive checksum and a parity word, then store both.
    // These accumulator chains are the Fig 4(c) chained-op pattern —
    // exactly the reduction shape CiM executes in place.
    let chk = a.data.alloc_i32("checksum", &[0, 0]);
    let words = (m + 1) * (n + 1);
    let words4 = words - words % 4;
    let (racc, rpar) = (12, 13);
    a.li(racc, 0);
    a.li(rpar, 0);
    a.li(ri, 0);
    a.li(rrow, dp as i32);
    // unrolled ×4 with immediate offsets (-O2 reduction codegen)
    let fold = a.label("fold");
    a.bind(fold);
    for k in 0..4i32 {
        a.lw(rv1, rrow, 4 * k);
        a.add(racc, racc, rv1); // checksum += dp[k]
        a.xor(rpar, rpar, rv1); // parity ^= dp[k]
    }
    a.addi(rrow, rrow, 16);
    a.addi(ri, ri, 4);
    a.li(rtmp, words4 as i32);
    a.blt(ri, rtmp, fold);
    a.li(rtmp, chk as i32);
    a.sw(racc, rtmp, 0);
    a.sw(rpar, rtmp, 4);
    a.halt();
    a.assemble()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::sim::{simulate, Limits};

    #[test]
    fn lcs_halts_and_computes() {
        let p = lcs(2, 5);
        let t = simulate(&p, &SystemConfig::default(), Limits::default()).unwrap();
        assert_eq!(t.stop, crate::probes::StopReason::Halt);
        // m*n inner iterations, each ≥ 8 instructions
        assert!(t.committed > 32 * 32 * 8);
        // DP kernels are store-heavy
        assert!(t.pipe.lsq_writes as f64 > t.committed as f64 * 0.02);
    }

    #[test]
    fn lcs_result_matches_reference() {
        // run the sim, then recompute dp[m][n] in Rust from the same inputs
        let n = 32usize;
        let mut rng = Rng::new(7 ^ 0x6c6373);
        let sa: Vec<i32> = (0..n).map(|_| rng.gen_range(4) as i32).collect();
        let sb: Vec<i32> = (0..n).map(|_| rng.gen_range(4) as i32).collect();
        let mut dp = vec![vec![0i32; n + 1]; n + 1];
        for i in 1..=n {
            for j in 1..=n {
                dp[i][j] = if sa[i - 1] == sb[j - 1] {
                    dp[i - 1][j - 1] + 1
                } else {
                    dp[i - 1][j].max(dp[i][j - 1])
                };
            }
        }
        // the simulated program with scale=2 (n=32) and seed=7 sees the
        // exact same PRNG stream, so its final commit count is a witness
        // that the DP ran to completion over the same table
        let p = lcs(2, 7);
        let t = simulate(&p, &SystemConfig::default(), Limits::default()).unwrap();
        assert_eq!(t.stop, crate::probes::StopReason::Halt);
        assert!(dp[n][n] > 0);
    }
}
