//! Multimedia benchmark (Table IV): MPEG-2 decode kernels (M2D).
//!
//! Two phases per 8×8 block, mirroring the decoder's hot loops:
//! 1. a butterfly inverse-transform pass over the coefficient rows
//!    (load/add/sub/shift/store), and
//! 2. motion compensation: `out = (ref + residual) & 0xff` — the
//!    load-load-add-mask-store shape (the `andi` clamp is a CiM-AND
//!    pattern, Fig 4(b)).

use crate::asm::{Asm, Program};
use crate::util::Rng;

/// Build the M2D benchmark: inverse transform + motion compensation over
/// `scale·24` random 8×8 blocks (scale 0 = the default 96 blocks).
pub fn mpeg2_decode(scale: usize, seed: u64) -> Program {
    let blocks = if scale == 0 { 96 } else { (scale * 24).max(4) };
    let mut rng = Rng::new(seed ^ 0x6d3264);
    let mut a = Asm::new("m2d");

    let coef: Vec<i32> = (0..blocks * 64)
        .map(|_| rng.gen_range(512) as i32 - 256)
        .collect();
    let refs: Vec<i32> = (0..blocks * 64)
        .map(|_| rng.gen_range(256) as i32)
        .collect();
    let cb = a.data.alloc_i32("coef", &coef);
    let rb = a.data.alloc_i32("ref", &refs);
    let out = a.data.alloc_i32("out", &vec![0i32; blocks * 64]);

    // r3=block, r4=base(coef), r5=row, r6..r13 scratch, r14=base(ref/out)
    let (rblk, rbase, rrow, ra0, ra1, ra2, ra3, rtmp, rt2, rrbase, robase, ri) =
        (3, 4, 5, 6, 7, 8, 12, 9, 10, 14, 15, 16);
    a.li(rblk, 0);
    let block = a.label("block");
    a.bind(block);
    a.li(rtmp, 64 * 4);
    a.mul(rbase, rblk, rtmp);
    a.addi(rbase, rbase, cb as i32);

    // ---- phase 1: butterfly transform over 8 rows ------------------------
    a.li(rrow, 0);
    let row = a.label("row");
    a.bind(row);
    // addr = base + row*32 ; pairwise butterflies on (0,4), (1,5), (2,6), (3,7)
    a.slli(rtmp, rrow, 5);
    a.add(rtmp, rtmp, rbase);
    for pair in 0..4u8 {
        let off = pair as i32 * 4;
        a.lw(ra0, rtmp, off);
        a.lw(ra1, rtmp, off + 16);
        a.add(ra2, ra0, ra1); // s = a + b
        a.sub(ra3, ra0, ra1); // d = a - b
        a.srai(ra2, ra2, 1);
        a.srai(ra3, ra3, 1);
        a.sw(ra2, rtmp, off);
        a.sw(ra3, rtmp, off + 16);
    }
    a.addi(rrow, rrow, 1);
    a.li(rt2, 8);
    a.blt(rrow, rt2, row);

    // ---- phase 2: motion compensation out = (ref + coef) & 0xff ----------
    // unrolled ×4 with immediate offsets and pointer bumps (-O2 style):
    // every pixel is the full Load-Load-OP-Store pattern of Fig 3.
    a.li(rtmp, 64 * 4);
    a.mul(rrbase, rblk, rtmp);
    a.addi(robase, rrbase, out as i32);
    a.addi(rrbase, rrbase, rb as i32);
    a.mv(rt2, rbase); // residual pointer
    a.li(ri, 0);
    let mc = a.label("mc");
    a.bind(mc);
    for k in 0..4i32 {
        a.lw(ra0, rt2, 4 * k); // residual
        a.lw(ra1, rrbase, 4 * k); // reference pixel
        a.add(ra2, ra0, ra1);
        a.andi(ra2, ra2, 0xff); // clamp to 8 bits (CiM-AND pattern)
        a.sw(ra2, robase, 4 * k);
    }
    a.addi(rt2, rt2, 16);
    a.addi(rrbase, rrbase, 16);
    a.addi(robase, robase, 16);
    a.addi(ri, ri, 4);
    a.li(rtmp, 64);
    a.blt(ri, rtmp, mc);
    // restore block-base pointers consumed by the bumps
    a.addi(rrbase, rrbase, -(64 * 4));
    a.addi(robase, robase, -(64 * 4));

    a.addi(rblk, rblk, 1);
    a.li(rtmp, blocks as i32);
    a.blt(rblk, rtmp, block);
    a.halt();
    a.assemble()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::sim::{simulate, Limits};

    #[test]
    fn m2d_halts_and_is_store_heavy() {
        let t = simulate(&mpeg2_decode(1, 3), &SystemConfig::default(), Limits::default())
            .unwrap();
        assert_eq!(t.stop, crate::probes::StopReason::Halt);
        assert!(t.pipe.lsq_writes > 1000);
    }

    #[test]
    fn m2d_has_and_patterns() {
        use crate::analyzer::{analyze, LocalityRule};
        let cfg = SystemConfig::default();
        let t = simulate(&mpeg2_decode(1, 3), &cfg, Limits::default()).unwrap();
        let an = analyze(&t, &cfg, LocalityRule::AnyCache);
        // the andi clamp feeds from an add of two loads: eligible chains
        assert!(!an.selection.candidates.is_empty());
        let has_and = an
            .selection
            .candidates
            .iter()
            .any(|c| c.ops.contains(&crate::analyzer::CimOp::And));
        assert!(has_and, "expected CiM-AND candidates in m2d");
    }
}
