//! Machine-learning benchmarks (Table IV): naive bayes, decision tree,
//! linear SVM, linear regression, k-means.
//!
//! These are the *inference/one-epoch kernels* the paper's accelerator
//! workloads exercise: per-sample feature loops dominated by load-load-op
//! chains (NB, SVM, KM are integer fixed-point; LiR uses the FPU).

use crate::asm::{Asm, Program};
use crate::util::Rng;

/// Naive Bayes inference: per sample, per feature, accumulate the class
/// log-likelihood from a per-(feature, value, class) table; pick argmax.
pub fn naive_bayes(scale: usize, seed: u64) -> Program {
    let samples = if scale == 0 { 400 } else { scale.max(2) * 40 };
    let features = 16usize;
    let mut rng = Rng::new(seed ^ 0x6e62);
    let mut a = Asm::new("nb");

    let x: Vec<i32> = (0..samples * features)
        .map(|_| rng.gen_range(2) as i32)
        .collect();
    // log-prob table (scaled by 1024): [feature][value][class]
    let table: Vec<i32> = (0..features * 2 * 2)
        .map(|_| -(rng.gen_range(3000) as i32) - 16)
        .collect();
    let xb = a.data.alloc_i32("x", &x);
    let tb = a.data.alloc_i32("table", &table);
    let out = a.data.alloc_i32("pred", &vec![0i32; samples]);

    // -O2-style codegen: the feature loop is fully unrolled with
    // immediate-offset addressing (the per-(feature,value) table slot base
    // is a compile-time constant), pointers bump across samples.
    // r3=i, r4=&x[i*F], r6=tmp, r7=v, r20=score0, r21=score1, r8=acc
    let (ri, rx, rt, rv, rs0, rs1, racc, rtmp) = (3, 4, 6, 7, 20, 21, 8, 9);
    a.li(ri, 0);
    a.li(rx, xb as i32);
    let sample_loop = a.label("sample");
    a.bind(sample_loop);
    a.li(rs0, 0);
    a.li(rs1, 0);
    for j in 0..features {
        a.lw(rv, rx, (j * 4) as i32); // v = x[i][j] in {0,1}
        // &table[j][v][class] = tb + j*16 + v*8 + class*4
        a.slli(rt, rv, 3);
        a.lw(racc, rt, tb as i32 + (j * 16) as i32);
        a.add(rs0, rs0, racc); // score0 += logp(class 0)
        a.lw(racc, rt, tb as i32 + (j * 16) as i32 + 4);
        a.add(rs1, rs1, racc); // score1 += logp(class 1)
    }
    // pred = score1 > score0
    a.slt(racc, rs0, rs1);
    a.slli(rtmp, ri, 2);
    a.addi(rtmp, rtmp, out as i32);
    a.sw(racc, rtmp, 0);
    a.addi(rx, rx, features as i32 * 4);
    a.addi(ri, ri, 1);
    a.li(rtmp, samples as i32);
    a.blt(ri, rtmp, sample_loop);
    a.halt();
    a.assemble()
}

/// Decision-tree inference: array-encoded complete binary tree; each sample
/// walks `depth` levels comparing a feature against a threshold.
pub fn decision_tree(scale: usize, seed: u64) -> Program {
    let samples = if scale == 0 { 500 } else { scale.max(2) * 50 };
    let depth = 10usize;
    let features = 8usize;
    let nodes = (1 << depth) - 1;
    let mut rng = Rng::new(seed ^ 0x6474);
    let mut a = Asm::new("dt");

    let x: Vec<i32> = (0..samples * features)
        .map(|_| rng.gen_range(1000) as i32)
        .collect();
    let feat_idx: Vec<i32> = (0..nodes)
        .map(|_| rng.gen_range(features as u64) as i32)
        .collect();
    let thresh: Vec<i32> = (0..nodes).map(|_| rng.gen_range(1000) as i32).collect();
    let xb = a.data.alloc_i32("x", &x);
    let fb = a.data.alloc_i32("feat", &feat_idx);
    let tb = a.data.alloc_i32("thresh", &thresh);
    let out = a.data.alloc_i32("leaf", &vec![0i32; samples]);

    let (ri, rx, rn, rl, rf, rt, rv, rtmp) = (3, 4, 5, 6, 7, 8, 9, 10);
    a.li(ri, 0);
    let sample = a.label("sample");
    a.bind(sample);
    a.li(rtmp, features as i32 * 4);
    a.mul(rx, ri, rtmp);
    a.addi(rx, rx, xb as i32);
    a.li(rn, 0); // node index
    a.li(rl, 0); // level
    let walk = a.label("walk");
    a.bind(walk);
    // f = feat[n]; t = thresh[n]
    a.slli(rtmp, rn, 2);
    a.addi(rf, rtmp, fb as i32);
    a.lw(rf, rf, 0);
    a.addi(rt, rtmp, tb as i32);
    a.lw(rt, rt, 0);
    // v = x[i][f]
    a.slli(rv, rf, 2);
    a.add(rv, rv, rx);
    a.lw(rv, rv, 0);
    // n = 2n + 1 + (v > t)
    a.slt(rtmp, rt, rv);
    a.slli(rn, rn, 1);
    a.addi(rn, rn, 1);
    a.add(rn, rn, rtmp);
    a.addi(rl, rl, 1);
    a.li(rtmp, depth as i32 - 1);
    a.blt(rl, rtmp, walk);
    // store the reached pseudo-leaf id
    a.slli(rtmp, ri, 2);
    a.addi(rtmp, rtmp, out as i32);
    a.sw(rn, rtmp, 0);
    a.addi(ri, ri, 1);
    a.li(rtmp, samples as i32);
    a.blt(ri, rtmp, sample);
    a.halt();
    a.assemble()
}

/// Linear SVM inference over *binary* features (bag-of-words style, the
/// text-processing setting of [20]): the dot product degenerates to a
/// masked sum `acc += w[j] & m` with `m = -x[j]` — and/add chains over
/// loaded values, i.e. CiM-AND + CiM-ADD patterns.
pub fn svm(scale: usize, seed: u64) -> Program {
    let samples = if scale == 0 { 300 } else { scale.max(2) * 30 };
    let features = 32usize;
    let mut rng = Rng::new(seed ^ 0x73766d);
    let mut a = Asm::new("svm");

    // store features pre-expanded as 0 / -1 masks (what a vectorizing
    // compiler materializes for branch-free masked sums)
    let x: Vec<i32> = (0..samples * features)
        .map(|_| -(rng.gen_range(2) as i32))
        .collect();
    let w: Vec<i32> = (0..features)
        .map(|_| rng.gen_range(256) as i32 - 128)
        .collect();
    let xb = a.data.alloc_i32("x", &x);
    let wb = a.data.alloc_i32("w", &w);
    let out = a.data.alloc_i32("pred", &vec![0i32; samples]);

    let (ri, rx, racc, rxv, rwv, rtmp) = (3, 4, 6, 7, 8, 9);
    a.li(ri, 0);
    a.li(rx, xb as i32);
    let sample = a.label("sample");
    a.bind(sample);
    a.li(racc, 0);
    // fully unrolled masked sum: acc += w[j] & mask[j]
    for j in 0..features {
        a.lw(rxv, rx, (j * 4) as i32);
        a.lw(rwv, 0, wb as i32 + (j * 4) as i32);
        a.and(rwv, rwv, rxv);
        a.add(racc, racc, rwv);
    }
    // pred = acc > 0
    a.slt(rtmp, 0, racc);
    a.slli(rxv, ri, 2);
    a.addi(rxv, rxv, out as i32);
    a.sw(rtmp, rxv, 0);
    a.addi(rx, rx, features as i32 * 4);
    a.addi(ri, ri, 1);
    a.li(rtmp, samples as i32);
    a.blt(ri, rtmp, sample);
    a.halt();
    a.assemble()
}

/// Linear regression, one SGD epoch (f32): w ← w + lr·(y − w·x)·x.
pub fn linear_regression(scale: usize, seed: u64) -> Program {
    let samples = if scale == 0 { 250 } else { scale.max(2) * 25 };
    let features = 16usize;
    let mut rng = Rng::new(seed ^ 0x6c6972);
    let mut a = Asm::new("lir");

    let x: Vec<f32> = (0..samples * features)
        .map(|_| rng.uniform(-1.0, 1.0) as f32)
        .collect();
    let y: Vec<f32> = (0..samples).map(|_| rng.uniform(-2.0, 2.0) as f32).collect();
    let w: Vec<f32> = vec![0.0; features];
    let lr: Vec<f32> = vec![0.01];
    let xb = a.data.alloc_f32("x", &x);
    let yb = a.data.alloc_f32("y", &y);
    let wb = a.data.alloc_f32("w", &w);
    let lrb = a.data.alloc_f32("lr", &lr);

    // int regs: r3=i, r4=&x[i], r5=j, r6=tmp/addr
    // fp: f0=acc/pred, f1=xv, f2=wv, f3=err, f4=lr, f5=y
    let (ri, rx, rj, rtmp) = (3, 4, 5, 6);
    a.li(rtmp, lrb as i32);
    a.flw(4, rtmp, 0);
    a.li(ri, 0);
    let sample = a.label("sample");
    a.bind(sample);
    a.li(rtmp, features as i32 * 4);
    a.mul(rx, ri, rtmp);
    a.addi(rx, rx, xb as i32);
    // pred = w·x
    a.fcvt_s_w(0, 0); // f0 = 0.0
    a.li(rj, 0);
    let dot = a.label("dot");
    a.bind(dot);
    a.slli(rtmp, rj, 2);
    a.add(rtmp, rtmp, rx);
    a.flw(1, rtmp, 0);
    a.slli(rtmp, rj, 2);
    a.addi(rtmp, rtmp, wb as i32);
    a.flw(2, rtmp, 0);
    a.fmul(1, 1, 2);
    a.fadd(0, 0, 1);
    a.addi(rj, rj, 1);
    a.li(rtmp, features as i32);
    a.blt(rj, rtmp, dot);
    // err = lr * (y[i] - pred)
    a.slli(rtmp, ri, 2);
    a.addi(rtmp, rtmp, yb as i32);
    a.flw(5, rtmp, 0);
    a.fsub(3, 5, 0);
    a.fmul(3, 3, 4);
    // w[j] += err * x[i][j]
    a.li(rj, 0);
    let upd = a.label("upd");
    a.bind(upd);
    a.slli(rtmp, rj, 2);
    a.add(rtmp, rtmp, rx);
    a.flw(1, rtmp, 0);
    a.fmul(1, 1, 3);
    a.slli(rtmp, rj, 2);
    a.addi(rtmp, rtmp, wb as i32);
    a.flw(2, rtmp, 0);
    a.fadd(2, 2, 1);
    a.fsw(2, rtmp, 0);
    a.addi(rj, rj, 1);
    a.li(rtmp, features as i32);
    a.blt(rj, rtmp, upd);
    a.addi(ri, ri, 1);
    a.li(rtmp, samples as i32);
    a.blt(ri, rtmp, sample);
    a.halt();
    a.assemble()
}

/// K-means assignment + accumulation step (integer L2 distances).
pub fn kmeans(scale: usize, seed: u64) -> Program {
    let points = if scale == 0 { 300 } else { scale.max(2) * 30 };
    let k = 4usize;
    let dims = 8usize;
    let mut rng = Rng::new(seed ^ 0x6b6d);
    let mut a = Asm::new("km");

    let x: Vec<i32> = (0..points * dims)
        .map(|_| rng.gen_range(256) as i32)
        .collect();
    let c: Vec<i32> = (0..k * dims).map(|_| rng.gen_range(256) as i32).collect();
    let xb = a.data.alloc_i32("x", &x);
    let cb = a.data.alloc_i32("c", &c);
    let assign = a.data.alloc_i32("assign", &vec![0i32; points]);
    let sums = a.data.alloc_i32("sums", &vec![0i32; k * dims]);
    let counts = a.data.alloc_i32("counts", &vec![0i32; k]);

    let (ri, rx, rk, rd, rbest, rbdist, rdist, rdiff, rtmp, rc) =
        (3, 4, 5, 6, 7, 8, 9, 10, 11, 12);
    a.li(ri, 0);
    let point = a.label("point");
    a.bind(point);
    a.li(rtmp, dims as i32 * 4);
    a.mul(rx, ri, rtmp);
    a.addi(rx, rx, xb as i32);
    a.li(rbest, 0);
    a.li(rbdist, 0x7fffffff);
    a.li(rk, 0);
    let cent = a.label("cent");
    a.bind(cent);
    a.li(rtmp, dims as i32 * 4);
    a.mul(rc, rk, rtmp);
    a.addi(rc, rc, cb as i32);
    a.li(rdist, 0);
    a.li(rd, 0);
    let dim = a.label("dim");
    a.bind(dim);
    a.slli(rtmp, rd, 2);
    a.add(rdiff, rtmp, rx);
    a.lw(rdiff, rdiff, 0);
    a.add(rtmp, rtmp, rc);
    a.lw(rtmp, rtmp, 0);
    a.sub(rdiff, rdiff, rtmp);
    a.mul(rdiff, rdiff, rdiff);
    a.add(rdist, rdist, rdiff);
    a.addi(rd, rd, 1);
    a.li(rtmp, dims as i32);
    a.blt(rd, rtmp, dim);
    // keep min
    let skip = a.label("skip");
    a.bge(rdist, rbdist, skip);
    a.mv(rbdist, rdist);
    a.mv(rbest, rk);
    a.bind(skip);
    a.addi(rk, rk, 1);
    a.li(rtmp, k as i32);
    a.blt(rk, rtmp, cent);
    // assign[i] = best; counts[best]++; sums[best] += x[i]
    a.slli(rtmp, ri, 2);
    a.addi(rtmp, rtmp, assign as i32);
    a.sw(rbest, rtmp, 0);
    a.slli(rtmp, rbest, 2);
    a.addi(rtmp, rtmp, counts as i32);
    a.lw(rdist, rtmp, 0);
    a.addi(rdist, rdist, 1);
    a.sw(rdist, rtmp, 0);
    a.li(rtmp, dims as i32 * 4);
    a.mul(rc, rbest, rtmp);
    a.addi(rc, rc, sums as i32);
    a.li(rd, 0);
    let acc = a.label("acc");
    a.bind(acc);
    a.slli(rtmp, rd, 2);
    a.add(rdiff, rtmp, rx);
    a.lw(rdiff, rdiff, 0);
    a.add(rtmp, rtmp, rc);
    a.lw(rdist, rtmp, 0);
    a.add(rdist, rdist, rdiff);
    a.sw(rdist, rtmp, 0);
    a.addi(rd, rd, 1);
    a.li(rtmp, dims as i32);
    a.blt(rd, rtmp, acc);
    a.addi(ri, ri, 1);
    a.li(rtmp, points as i32);
    a.blt(ri, rtmp, point);
    a.halt();
    a.assemble()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::sim::{simulate, Limits};

    fn runs(p: Program) -> crate::probes::Trace {
        simulate(&p, &SystemConfig::default(), Limits::default()).unwrap()
    }

    #[test]
    fn all_ml_benchmarks_halt() {
        for f in [naive_bayes, decision_tree, svm, linear_regression, kmeans] {
            let t = runs(f(2, 7));
            assert_eq!(t.stop, crate::probes::StopReason::Halt, "{}", t.program);
            assert!(t.committed > 1000, "{}: {}", t.program, t.committed);
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let a = runs(naive_bayes(2, 9));
        let b = runs(naive_bayes(2, 9));
        assert_eq!(a.committed, b.committed);
        assert_eq!(a.cycles, b.cycles);
    }

    #[test]
    fn seed_changes_trace() {
        let a = runs(decision_tree(2, 1));
        let b = runs(decision_tree(2, 2));
        // different thresholds -> different walk paths -> different counts
        assert_ne!(a.cycles, b.cycles);
    }

    #[test]
    fn lir_uses_fpu() {
        let t = runs(linear_regression(2, 3));
        assert!(t.pipe.fp_rf_writes > 0);
        assert!(t.pipe.fu_counts[crate::isa::FuncUnit::FpMul.index()] > 0);
    }
}
