//! Graph-processing benchmarks (Table IV): BFS, DFS, betweenness
//! centrality, SSSP (Bellman-Ford), connected components, PageRank.
//!
//! Graphs are random CSR structures from the seeded PRNG.  The kernels are
//! the classic edge-centric loops: `dist[v] = min(dist[v], dist[u]+w)`
//! relaxations, `sigma[v] += sigma[u]` path counting, label propagation —
//! the load-load-add-store shapes CiM targets, interleaved with pointer
//! chasing the host must keep.

use crate::asm::{Asm, Program};
use crate::util::Rng;

struct Csr {
    row: Vec<i32>,
    col: Vec<i32>,
    n: usize,
    m: usize,
}

fn random_graph(n: usize, avg_deg: usize, rng: &mut Rng) -> Csr {
    let mut row = Vec::with_capacity(n + 1);
    let mut col = Vec::new();
    row.push(0);
    for u in 0..n {
        let deg = 1 + rng.gen_range((2 * avg_deg - 1) as u64) as usize;
        for _ in 0..deg {
            let mut v = rng.gen_range(n as u64) as usize;
            if v == u {
                v = (v + 1) % n;
            }
            col.push(v as i32);
        }
        row.push(col.len() as i32);
    }
    let m = col.len();
    Csr { row, col, n, m }
}

fn graph_size(scale: usize) -> usize {
    if scale == 0 {
        192
    } else {
        (scale * 48).max(16)
    }
}

/// Breadth-first search with an explicit worklist and visited flags.
pub fn bfs(scale: usize, seed: u64) -> Program {
    let mut rng = Rng::new(seed ^ 0x626673);
    let g = random_graph(graph_size(scale), 4, &mut rng);
    let mut a = Asm::new("bfs");

    let rowb = a.data.alloc_i32("row", &g.row);
    let colb = a.data.alloc_i32("col", &g.col);
    let visited = a.data.alloc_i32("visited", &vec![0i32; g.n]);
    let wl = a.data.alloc_i32("wl", &vec![0i32; g.n + 4]);
    let depth = a.data.alloc_i32("depth", &vec![0i32; g.n]);

    // r3=head, r4=tail, r5=u, r6=e, r7=end, r8=v, r9..r11 scratch
    let (rh, rt, ru, re, rend, rv, rtmp, rt2, rdu) = (3, 4, 5, 6, 7, 8, 9, 10, 12);
    // visited[0]=1; wl[0]=0; head=0; tail=1
    a.li(rtmp, visited as i32);
    a.li(rt2, 1);
    a.sw(rt2, rtmp, 0);
    a.li(rtmp, wl as i32);
    a.sw(0, rtmp, 0);
    a.li(rh, 0);
    a.li(rt, 1);
    let pop = a.label("pop");
    let done = a.label("done");
    a.bind(pop);
    a.bge(rh, rt, done);
    // u = wl[head++]
    a.slli(rtmp, rh, 2);
    a.addi(rtmp, rtmp, wl as i32);
    a.lw(ru, rtmp, 0);
    a.addi(rh, rh, 1);
    // du = depth[u] + 1
    a.slli(rtmp, ru, 2);
    a.addi(rtmp, rtmp, depth as i32);
    a.lw(rdu, rtmp, 0);
    a.addi(rdu, rdu, 1);
    // e = row[u]; end = row[u+1]
    a.slli(rtmp, ru, 2);
    a.addi(rtmp, rtmp, rowb as i32);
    a.lw(re, rtmp, 0);
    a.lw(rend, rtmp, 4);
    let edges = a.label("edges");
    let next_u = a.label("next_u");
    a.bind(edges);
    a.bge(re, rend, next_u);
    // v = col[e]
    a.slli(rtmp, re, 2);
    a.addi(rtmp, rtmp, colb as i32);
    a.lw(rv, rtmp, 0);
    a.addi(re, re, 1);
    // if visited[v] continue
    a.slli(rtmp, rv, 2);
    a.addi(rtmp, rtmp, visited as i32);
    a.lw(rt2, rtmp, 0);
    a.bne(rt2, 0, edges);
    // mark + enqueue + depth
    a.li(rt2, 1);
    a.sw(rt2, rtmp, 0);
    a.slli(rtmp, rv, 2);
    a.addi(rtmp, rtmp, depth as i32);
    a.sw(rdu, rtmp, 0);
    a.slli(rtmp, rt, 2);
    a.addi(rtmp, rtmp, wl as i32);
    a.sw(rv, rtmp, 0);
    a.addi(rt, rt, 1);
    a.jump(edges);
    a.bind(next_u);
    a.jump(pop);
    a.bind(done);
    a.halt();
    a.assemble()
}

/// Depth-first search (explicit stack; same data structures as BFS).
pub fn dfs(scale: usize, seed: u64) -> Program {
    let mut rng = Rng::new(seed ^ 0x646673);
    let g = random_graph(graph_size(scale), 4, &mut rng);
    let mut a = Asm::new("dfs");

    let rowb = a.data.alloc_i32("row", &g.row);
    let colb = a.data.alloc_i32("col", &g.col);
    let visited = a.data.alloc_i32("visited", &vec![0i32; g.n]);
    let stack = a.data.alloc_i32("stack", &vec![0i32; g.n * 8]);
    let order = a.data.alloc_i32("order", &vec![0i32; g.n]);

    let (rsp, ru, re, rend, rv, rtmp, rt2, rcnt) = (3, 5, 6, 7, 8, 9, 10, 11);
    // push 0
    a.li(rtmp, stack as i32);
    a.sw(0, rtmp, 0);
    a.li(rsp, 1);
    a.li(rcnt, 0);
    let pop = a.label("pop");
    let done = a.label("done");
    a.bind(pop);
    a.beq(rsp, 0, done);
    a.addi(rsp, rsp, -1);
    a.slli(rtmp, rsp, 2);
    a.addi(rtmp, rtmp, stack as i32);
    a.lw(ru, rtmp, 0);
    // if visited[u] continue
    a.slli(rtmp, ru, 2);
    a.addi(rtmp, rtmp, visited as i32);
    a.lw(rt2, rtmp, 0);
    a.bne(rt2, 0, pop);
    a.li(rt2, 1);
    a.sw(rt2, rtmp, 0);
    // order[u] = cnt++
    a.slli(rtmp, ru, 2);
    a.addi(rtmp, rtmp, order as i32);
    a.sw(rcnt, rtmp, 0);
    a.addi(rcnt, rcnt, 1);
    // push unvisited neighbors
    a.slli(rtmp, ru, 2);
    a.addi(rtmp, rtmp, rowb as i32);
    a.lw(re, rtmp, 0);
    a.lw(rend, rtmp, 4);
    let edges = a.label("edges");
    a.bind(edges);
    let next = a.label("next");
    a.bge(re, rend, next);
    a.slli(rtmp, re, 2);
    a.addi(rtmp, rtmp, colb as i32);
    a.lw(rv, rtmp, 0);
    a.addi(re, re, 1);
    a.slli(rtmp, rv, 2);
    a.addi(rtmp, rtmp, visited as i32);
    a.lw(rt2, rtmp, 0);
    a.bne(rt2, 0, edges);
    a.slli(rtmp, rsp, 2);
    a.addi(rtmp, rtmp, stack as i32);
    a.sw(rv, rtmp, 0);
    a.addi(rsp, rsp, 1);
    a.jump(edges);
    a.bind(next);
    a.jump(pop);
    a.bind(done);
    a.halt();
    a.assemble()
}

/// Single-source shortest paths: Bellman-Ford rounds over an edge list.
pub fn sssp(scale: usize, seed: u64) -> Program {
    let mut rng = Rng::new(seed ^ 0x737370);
    let g = random_graph(graph_size(scale), 4, &mut rng);
    // flatten to an edge list with weights
    let mut src = Vec::with_capacity(g.m);
    let mut dst = Vec::with_capacity(g.m);
    let mut wgt = Vec::with_capacity(g.m);
    for u in 0..g.n {
        for e in g.row[u] as usize..g.row[u + 1] as usize {
            src.push(u as i32);
            dst.push(g.col[e]);
            wgt.push(1 + rng.gen_range(9) as i32);
        }
    }
    let rounds = 6usize;
    let mut a = Asm::new("sssp");
    let sb = a.data.alloc_i32("src", &src);
    let db = a.data.alloc_i32("dst", &dst);
    let wb = a.data.alloc_i32("wgt", &wgt);
    let mut dist0 = vec![0x0fff_ffffi32; g.n];
    dist0[0] = 0;
    let dist = a.data.alloc_i32("dist", &dist0);

    let (rr, re, ru, rv, rw, rdu, rdv, rtmp, rnd) = (3, 4, 5, 6, 7, 8, 10, 11, 12);
    let rpe = 13; // running edge pointer (src; dst/wgt at fixed offsets)
    let dst_off = (db - sb) as i32;
    let wgt_off = (wb - sb) as i32;
    a.li(rr, 0);
    let round = a.label("round");
    a.bind(round);
    a.li(re, 0);
    a.li(rpe, sb as i32);
    let edge = a.label("edge");
    a.bind(edge);
    a.lw(ru, rpe, 0);
    a.lw(rv, rpe, dst_off);
    a.lw(rw, rpe, wgt_off);
    a.addi(rpe, rpe, 4);
    // nd = dist[u] + w
    a.slli(rtmp, ru, 2);
    a.addi(rtmp, rtmp, dist as i32);
    a.lw(rdu, rtmp, 0);
    a.add(rnd, rdu, rw);
    // if nd < dist[v]: dist[v] = nd
    a.slli(rtmp, rv, 2);
    a.addi(rtmp, rtmp, dist as i32);
    a.lw(rdv, rtmp, 0);
    let skip = a.label("skip");
    a.bge(rnd, rdv, skip);
    a.sw(rnd, rtmp, 0);
    a.bind(skip);
    a.addi(re, re, 1);
    a.li(rtmp, src.len() as i32);
    a.blt(re, rtmp, edge);
    a.addi(rr, rr, 1);
    a.li(rtmp, rounds as i32);
    a.blt(rr, rtmp, round);
    a.halt();
    a.assemble()
}

/// Connected components by label propagation over the edge list.
pub fn ccomp(scale: usize, seed: u64) -> Program {
    let mut rng = Rng::new(seed ^ 0x6363);
    let g = random_graph(graph_size(scale), 3, &mut rng);
    let mut src = Vec::new();
    let mut dst = Vec::new();
    for u in 0..g.n {
        for e in g.row[u] as usize..g.row[u + 1] as usize {
            src.push(u as i32);
            dst.push(g.col[e]);
        }
    }
    let rounds = 8usize;
    let mut a = Asm::new("ccomp");
    let sb = a.data.alloc_i32("src", &src);
    let db = a.data.alloc_i32("dst", &dst);
    let labels0: Vec<i32> = (0..g.n as i32).collect();
    let lab = a.data.alloc_i32("labels", &labels0);

    let (rr, re, ru, rv, rlu, rlv, rtmp) = (3, 4, 5, 6, 7, 8, 9);
    a.li(rr, 0);
    let round = a.label("round");
    a.bind(round);
    a.li(re, 0);
    let edge = a.label("edge");
    a.bind(edge);
    a.slli(rtmp, re, 2);
    a.addi(ru, rtmp, sb as i32);
    a.lw(ru, ru, 0);
    a.slli(rtmp, re, 2);
    a.addi(rv, rtmp, db as i32);
    a.lw(rv, rv, 0);
    a.slli(ru, ru, 2);
    a.addi(ru, ru, lab as i32);
    a.lw(rlu, ru, 0);
    a.slli(rv, rv, 2);
    a.addi(rv, rv, lab as i32);
    a.lw(rlv, rv, 0);
    // min-select through explicit compares (what csel-less codegen emits);
    // slt over two loaded labels is a CiM compare pattern
    let rt_cmp = 12;
    let no_min = a.label("no_min");
    let after = a.label("after");
    a.slt(rt_cmp, rlu, rlv);
    a.beq(rt_cmp, 0, no_min);
    a.sw(rlu, rv, 0); // label[v] = label[u]
    a.jump(after);
    a.bind(no_min);
    let equal = a.label("equal");
    a.slt(rt_cmp, rlv, rlu);
    a.beq(rt_cmp, 0, equal);
    a.sw(rlv, ru, 0); // label[u] = label[v]
    a.bind(equal);
    a.bind(after);
    a.addi(re, re, 1);
    a.li(rtmp, src.len() as i32);
    a.blt(re, rtmp, edge);
    a.addi(rr, rr, 1);
    a.li(rtmp, rounds as i32);
    a.blt(rr, rtmp, round);
    a.halt();
    a.assemble()
}

/// PageRank power iterations, push-style fixed-point (Q16) — the standard
/// integer formulation embedded graph frameworks use: a per-iteration
/// contribution array (`contrib[u] = rank[u] / deg[u]`), then an
/// edge-centric scatter `acc[v] += contrib[u]` whose Load-Load-ADD-Store
/// body is the archetypal CiM pattern, then a gather
/// `rank[v] = base + (damp·acc[v]) >> 16`.
pub fn pagerank(scale: usize, seed: u64) -> Program {
    let mut rng = Rng::new(seed ^ 0x7072);
    let g = random_graph(graph_size(scale), 4, &mut rng);
    let mut src = Vec::new();
    let mut dst = Vec::new();
    for u in 0..g.n {
        for e in g.row[u] as usize..g.row[u + 1] as usize {
            src.push(u as i32);
            dst.push(g.col[e]);
        }
    }
    let deg: Vec<i32> = (0..g.n)
        .map(|u| (g.row[u + 1] - g.row[u]).max(1))
        .collect();
    let iters = 4usize;
    let one_q16 = 1 << 16;
    let mut a = Asm::new("prank");
    let sb = a.data.alloc_i32("src", &src);
    let db = a.data.alloc_i32("dst", &dst);
    let degb = a.data.alloc_i32("deg", &deg);
    let rank = a.data.alloc_i32("rank", &vec![one_q16 / g.n as i32; g.n]);
    let contrib = a.data.alloc_i32("contrib", &vec![0i32; g.n]);
    let acc = a.data.alloc_i32("acc", &vec![0i32; g.n]);
    let base_q16 = (0.15 * one_q16 as f64 / g.n as f64) as i32;
    let damp_q16 = (0.85 * one_q16 as f64) as i32;

    let (rit, re, ru, rv, rc, rtmp, rt2, ri, rdamp) = (3, 4, 5, 6, 7, 9, 10, 11, 12);
    a.li(rdamp, damp_q16);
    a.li(rit, 0);
    let iter = a.label("iter");
    a.bind(iter);
    // phase A: contrib[u] = rank[u] / deg[u]; acc[u] = 0
    a.li(ri, 0);
    let phase_a = a.label("phase_a");
    a.bind(phase_a);
    a.slli(rtmp, ri, 2);
    a.addi(rt2, rtmp, rank as i32);
    a.lw(rc, rt2, 0);
    a.addi(rt2, rtmp, degb as i32);
    a.lw(rt2, rt2, 0);
    a.div(rc, rc, rt2);
    a.addi(rt2, rtmp, contrib as i32);
    a.sw(rc, rt2, 0);
    a.addi(rt2, rtmp, acc as i32);
    a.sw(0, rt2, 0);
    a.addi(ri, ri, 1);
    a.li(rtmp, g.n as i32);
    a.blt(ri, rtmp, phase_a);
    // phase B: edge scatter acc[v] += contrib[u]  (Load-Load-ADD-Store)
    a.li(re, 0);
    let edge = a.label("edge");
    a.bind(edge);
    a.slli(rtmp, re, 2);
    a.addi(ru, rtmp, sb as i32);
    a.lw(ru, ru, 0);
    a.addi(rv, rtmp, db as i32);
    a.lw(rv, rv, 0);
    a.slli(ru, ru, 2);
    a.lw(rc, ru, contrib as i32);
    a.slli(rv, rv, 2);
    a.lw(rt2, rv, acc as i32);
    a.add(rt2, rt2, rc);
    a.sw(rt2, rv, acc as i32);
    a.addi(re, re, 1);
    a.li(rtmp, src.len() as i32);
    a.blt(re, rtmp, edge);
    // phase C: rank[i] = base + (damp * acc[i]) >> 16
    a.li(ri, 0);
    let gather = a.label("gather");
    a.bind(gather);
    a.slli(rtmp, ri, 2);
    a.addi(rt2, rtmp, acc as i32);
    a.lw(rc, rt2, 0);
    a.mul(rc, rc, rdamp);
    a.srai(rc, rc, 16);
    a.addi(rc, rc, base_q16);
    a.addi(rt2, rtmp, rank as i32);
    a.sw(rc, rt2, 0);
    a.addi(ri, ri, 1);
    a.li(rtmp, g.n as i32);
    a.blt(ri, rtmp, gather);
    a.addi(rit, rit, 1);
    a.li(rtmp, iters as i32);
    a.blt(rit, rtmp, iter);
    a.halt();
    a.assemble()
}

/// Betweenness centrality (simplified Brandes): forward BFS with path
/// counting (`sigma[v] += sigma[u]`), then a dependency sweep over edges.
pub fn betweenness(scale: usize, seed: u64) -> Program {
    let mut rng = Rng::new(seed ^ 0x6263);
    let g = random_graph(graph_size(scale), 4, &mut rng);
    let mut src = Vec::new();
    let mut dst = Vec::new();
    for u in 0..g.n {
        for e in g.row[u] as usize..g.row[u + 1] as usize {
            src.push(u as i32);
            dst.push(g.col[e]);
        }
    }
    let mut a = Asm::new("bc");
    let rowb = a.data.alloc_i32("row", &g.row);
    let colb = a.data.alloc_i32("col", &g.col);
    let sb = a.data.alloc_i32("esrc", &src);
    let db = a.data.alloc_i32("edst", &dst);
    let mut dist0 = vec![-1i32; g.n];
    dist0[0] = 0;
    let dist = a.data.alloc_i32("dist", &dist0);
    let mut sig0 = vec![0i32; g.n];
    sig0[0] = 1;
    let sigma = a.data.alloc_i32("sigma", &sig0);
    let wl = a.data.alloc_i32("wl", &vec![0i32; g.n + 4]);
    let delta = a.data.alloc_f32("delta", &vec![0.0f32; g.n]);

    let (rh, rt, ru, re, rend, rv, rtmp, rt2, rdu, rsu) = (3, 4, 5, 6, 7, 8, 9, 10, 11, 12);
    // BFS with sigma accumulation
    a.li(rtmp, wl as i32);
    a.sw(0, rtmp, 0);
    a.li(rh, 0);
    a.li(rt, 1);
    let pop = a.label("pop");
    let fwd_done = a.label("fwd_done");
    a.bind(pop);
    a.bge(rh, rt, fwd_done);
    a.slli(rtmp, rh, 2);
    a.addi(rtmp, rtmp, wl as i32);
    a.lw(ru, rtmp, 0);
    a.addi(rh, rh, 1);
    a.slli(rtmp, ru, 2);
    a.addi(rtmp, rtmp, dist as i32);
    a.lw(rdu, rtmp, 0);
    a.addi(rdu, rdu, 1);
    a.slli(rtmp, ru, 2);
    a.addi(rtmp, rtmp, sigma as i32);
    a.lw(rsu, rtmp, 0);
    a.slli(rtmp, ru, 2);
    a.addi(rtmp, rtmp, rowb as i32);
    a.lw(re, rtmp, 0);
    a.lw(rend, rtmp, 4);
    let edges = a.label("edges");
    let next_u = a.label("next_u");
    a.bind(edges);
    a.bge(re, rend, next_u);
    a.slli(rtmp, re, 2);
    a.addi(rtmp, rtmp, colb as i32);
    a.lw(rv, rtmp, 0);
    a.addi(re, re, 1);
    a.slli(rv, rv, 2);
    // dv = dist[v]
    a.addi(rtmp, rv, dist as i32);
    a.lw(rt2, rtmp, 0);
    let not_new = a.label("not_new");
    // if dist[v] < 0: discover
    a.bge(rt2, 0, not_new);
    a.sw(rdu, rtmp, 0);
    a.srli(rt2, rv, 2);
    a.slli(rtmp, rt, 2);
    a.addi(rtmp, rtmp, wl as i32);
    a.sw(rt2, rtmp, 0);
    a.addi(rt, rt, 1);
    a.li(rt2, 0);
    a.addi(rtmp, rv, dist as i32);
    a.lw(rt2, rtmp, 0);
    a.bind(not_new);
    // if dist[v] == du: sigma[v] += sigma[u]
    let no_acc = a.label("no_acc");
    a.bne(rt2, rdu, no_acc);
    a.addi(rtmp, rv, sigma as i32);
    a.lw(rt2, rtmp, 0);
    a.add(rt2, rt2, rsu);
    a.sw(rt2, rtmp, 0);
    a.bind(no_acc);
    a.jump(edges);
    a.bind(next_u);
    a.jump(pop);
    a.bind(fwd_done);
    // dependency sweep: for tree edges (dist[v] == dist[u]+1):
    // delta[u] += 1 + delta[v]   (f32)
    a.li(re, 0);
    let dep = a.label("dep");
    let done = a.label("done");
    a.bind(dep);
    a.li(rtmp, src.len() as i32);
    a.bge(re, rtmp, done);
    a.slli(rtmp, re, 2);
    a.addi(ru, rtmp, sb as i32);
    a.lw(ru, ru, 0);
    a.slli(rtmp, re, 2);
    a.addi(rv, rtmp, db as i32);
    a.lw(rv, rv, 0);
    a.addi(re, re, 1);
    a.slli(ru, ru, 2);
    a.slli(rv, rv, 2);
    a.addi(rtmp, ru, dist as i32);
    a.lw(rdu, rtmp, 0);
    a.addi(rtmp, rv, dist as i32);
    a.lw(rt2, rtmp, 0);
    a.addi(rdu, rdu, 1);
    a.bne(rt2, rdu, dep);
    // delta[u] += 1 + delta[v]
    a.addi(rtmp, rv, delta as i32);
    a.flw(1, rtmp, 0);
    a.li(rt2, 1);
    a.fcvt_s_w(2, rt2);
    a.fadd(1, 1, 2);
    a.addi(rtmp, ru, delta as i32);
    a.flw(3, rtmp, 0);
    a.fadd(3, 3, 1);
    a.fsw(3, rtmp, 0);
    a.jump(dep);
    a.bind(done);
    a.halt();
    a.assemble()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::probes::StopReason;
    use crate::sim::{simulate, Limits};

    #[test]
    fn all_graph_benchmarks_halt() {
        for (name, f) in [
            ("bfs", bfs as fn(usize, u64) -> Program),
            ("dfs", dfs),
            ("sssp", sssp),
            ("ccomp", ccomp),
            ("prank", pagerank),
            ("bc", betweenness),
        ] {
            let t = simulate(&f(1, 3), &SystemConfig::default(), Limits::default())
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(t.stop, StopReason::Halt, "{name}");
            assert!(t.committed > 2000, "{name}: {}", t.committed);
        }
    }

    #[test]
    fn bfs_visits_reachable_nodes() {
        // the worklist head should have advanced far beyond the source
        let t = simulate(&bfs(1, 3), &SystemConfig::default(), Limits::default()).unwrap();
        // BFS on a connected-ish random graph with 48+ nodes must execute
        // many edge iterations
        assert!(t.pipe.lsq_reads > 100);
    }

    #[test]
    fn pagerank_exercises_integer_division() {
        let t = simulate(&pagerank(1, 3), &SystemConfig::default(), Limits::default()).unwrap();
        assert!(t.pipe.fu_counts[crate::isa::FuncUnit::IntDiv.index()] > 50);
    }

    #[test]
    fn pagerank_scatter_is_cim_convertible() {
        use crate::analyzer::{analyze, LocalityRule};
        let cfg = SystemConfig::default();
        let t = simulate(&pagerank(1, 3), &cfg, Limits::default()).unwrap();
        let an = analyze(&t, &cfg, LocalityRule::AnyCache);
        assert!(an.macr.ratio() > 0.15, "PR MACR {}", an.macr.ratio());
    }
}
