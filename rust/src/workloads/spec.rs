//! SPEC 2006 kernel extracts (Table IV): astar, h264ref, hmmer, mcf.
//!
//! Full SPEC binaries are neither available nor runnable on EVA32; following
//! the substitution rule (DESIGN.md §2) each benchmark is represented by its
//! documented hot kernel with synthetic inputs:
//! * astar   — grid path search: open-set scan + neighbor relaxation
//! * h264ref — SAD motion estimation over candidate offsets
//! * hmmer   — Viterbi profile-HMM dynamic program
//! * mcf     — reduced-cost arc sweep of network simplex pricing

use crate::asm::{Asm, Program};
use crate::util::Rng;

/// astar: repeated open-set minimum scan + neighbor relaxation on a grid.
pub fn astar(scale: usize, seed: u64) -> Program {
    let w = if scale == 0 { 24 } else { (scale * 6).max(8) };
    let n = w * w;
    let mut rng = Rng::new(seed ^ 0x617374);
    let mut a = Asm::new("astar");

    let cost: Vec<i32> = (0..n).map(|_| 1 + rng.gen_range(9) as i32).collect();
    let cb = a.data.alloc_i32("cost", &cost);
    let inf = 0x0fff_ffff;
    let mut g0 = vec![inf; n];
    g0[0] = 0;
    let gsc = a.data.alloc_i32("g", &g0);
    let mut open0 = vec![0i32; n];
    open0[0] = 1;
    let open = a.data.alloc_i32("open", &open0);
    let hcost: Vec<i32> = (0..n)
        .map(|i| {
            let (x, y) = (i % w, i / w);
            ((w - 1 - x) + (w - 1 - y)) as i32
        })
        .collect();
    let hb = a.data.alloc_i32("h", &hcost);

    // r3=iter, r4=i, r5=best, r6=bestf, r7..r13 scratch
    let (rit, ri, rbest, rbf, rv, rtmp, rt2, rg, rnb) = (3, 4, 5, 6, 7, 9, 10, 11, 12);
    let iters = (n / 2).max(8) as i32;
    a.li(rit, 0);
    let iter = a.label("iter");
    let done = a.label("done");
    a.bind(iter);
    a.li(rtmp, iters);
    a.bge(rit, rtmp, done);
    // scan open set for min f = g + h
    a.li(rbest, -1);
    a.li(rbf, inf);
    a.li(ri, 0);
    let scan = a.label("scan");
    let scan_next = a.label("scan_next");
    a.bind(scan);
    a.slli(rtmp, ri, 2);
    a.addi(rt2, rtmp, open as i32);
    a.lw(rt2, rt2, 0);
    a.beq(rt2, 0, scan_next);
    a.slli(rtmp, ri, 2);
    a.addi(rt2, rtmp, gsc as i32);
    a.lw(rg, rt2, 0);
    a.addi(rt2, rtmp, hb as i32);
    a.lw(rt2, rt2, 0);
    a.add(rg, rg, rt2); // f = g + h
    a.bge(rg, rbf, scan_next);
    a.mv(rbf, rg);
    a.mv(rbest, ri);
    a.bind(scan_next);
    a.addi(ri, ri, 1);
    a.li(rtmp, n as i32);
    a.blt(ri, rtmp, scan);
    // nothing open -> done
    a.blt(rbest, 0, done);
    // close best
    a.slli(rtmp, rbest, 2);
    a.addi(rtmp, rtmp, open as i32);
    a.sw(0, rtmp, 0);
    // relax the 2 forward neighbors (x+1, y+1)
    a.slli(rtmp, rbest, 2);
    a.addi(rtmp, rtmp, gsc as i32);
    a.lw(rg, rtmp, 0);
    for (delta, guard) in [(1i32, true), (w as i32, false)] {
        let skip = a.label(if guard { "skip_r" } else { "skip_d" });
        a.addi(rnb, rbest, delta);
        a.li(rtmp, n as i32);
        a.bge(rnb, rtmp, skip);
        // ng = g[best] + cost[nb]
        a.slli(rtmp, rnb, 2);
        a.addi(rt2, rtmp, cb as i32);
        a.lw(rt2, rt2, 0);
        a.add(rv, rg, rt2);
        a.slli(rtmp, rnb, 2);
        a.addi(rt2, rtmp, gsc as i32);
        a.lw(rtmp, rt2, 0);
        a.bge(rv, rtmp, skip);
        a.sw(rv, rt2, 0);
        a.slli(rtmp, rnb, 2);
        a.addi(rtmp, rtmp, open as i32);
        a.li(rt2, 1);
        a.sw(rt2, rtmp, 0);
        a.bind(skip);
    }
    a.addi(rit, rit, 1);
    a.jump(iter);
    a.bind(done);
    a.halt();
    a.assemble()
}

/// h264ref: SAD-based motion estimation — for each candidate offset, sum
/// `|cur[i] − ref[i+off]|` over a 16×16 block; keep the argmin.
pub fn h264ref(scale: usize, seed: u64) -> Program {
    let blocks = if scale == 0 { 24 } else { (scale * 6).max(2) };
    let bsz = 256usize; // 16x16
    let noff = 9usize;
    let mut rng = Rng::new(seed ^ 0x683264);
    let mut a = Asm::new("h264ref");

    let cur: Vec<i32> = (0..blocks * bsz).map(|_| rng.gen_range(256) as i32).collect();
    let refs: Vec<i32> = (0..blocks * bsz + 64)
        .map(|_| rng.gen_range(256) as i32)
        .collect();
    let offsets: Vec<i32> = (0..noff).map(|i| i as i32 * 4).collect();
    let cb = a.data.alloc_i32("cur", &cur);
    let rb = a.data.alloc_i32("ref", &refs);
    let ob = a.data.alloc_i32("offs", &offsets);
    let best = a.data.alloc_i32("best", &vec![0i32; blocks]);

    let (rblk, rcb, roff, ri, rsad, ra0, ra1, rtmp, rt2, rbsad, rboff) =
        (3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13);
    a.li(rblk, 0);
    let block = a.label("block");
    a.bind(block);
    a.li(rtmp, bsz as i32 * 4);
    a.mul(rcb, rblk, rtmp);
    a.addi(rcb, rcb, cb as i32);
    a.li(rbsad, 0x0fffffff);
    a.li(rboff, 0);
    a.li(roff, 0);
    let cand = a.label("cand");
    a.bind(cand);
    // off = offs[roff]; refbase = rb + blk*bsz*4 + off
    a.slli(rtmp, roff, 2);
    a.addi(rtmp, rtmp, ob as i32);
    a.lw(rt2, rtmp, 0);
    a.li(rtmp, bsz as i32 * 4);
    a.mul(ra0, rblk, rtmp);
    a.add(ra0, ra0, rt2);
    a.addi(ra0, ra0, rb as i32); // ra0 = ref base
    a.li(rsad, 0);
    a.li(ri, 0);
    let pix = a.label("pix");
    a.bind(pix);
    a.slli(rtmp, ri, 2);
    a.add(rt2, rtmp, rcb);
    a.lw(ra1, rt2, 0); // cur
    a.add(rt2, rtmp, ra0);
    a.lw(rt2, rt2, 0); // ref
    a.sub(ra1, ra1, rt2);
    // |d| = (d ^ (d >> 31)) - (d >> 31)
    a.srai(rt2, ra1, 31);
    a.xor(ra1, ra1, rt2);
    a.sub(ra1, ra1, rt2);
    a.add(rsad, rsad, ra1);
    a.addi(ri, ri, 1);
    a.li(rtmp, bsz as i32);
    a.blt(ri, rtmp, pix);
    // keep min
    let keep = a.label("keep");
    a.bge(rsad, rbsad, keep);
    a.mv(rbsad, rsad);
    a.mv(rboff, roff);
    a.bind(keep);
    a.addi(roff, roff, 1);
    a.li(rtmp, noff as i32);
    a.blt(roff, rtmp, cand);
    a.slli(rtmp, rblk, 2);
    a.addi(rtmp, rtmp, best as i32);
    a.sw(rboff, rtmp, 0);
    a.addi(rblk, rblk, 1);
    a.li(rtmp, blocks as i32);
    a.blt(rblk, rtmp, block);
    a.halt();
    a.assemble()
}

/// hmmer: Viterbi DP over a profile HMM (integer log-space scores):
/// `V[t][j] = emit[j][obs[t]] + max(V[t-1][j] + stay, V[t-1][j-1] + move)`.
pub fn hmmer(scale: usize, seed: u64) -> Program {
    let states = 32usize;
    let steps = if scale == 0 { 96 } else { (scale * 24).max(8) };
    let alphabet = 4usize;
    let mut rng = Rng::new(seed ^ 0x686d6d);
    let mut a = Asm::new("hmmer");

    let emit: Vec<i32> = (0..states * alphabet)
        .map(|_| -(rng.gen_range(100) as i32))
        .collect();
    let obs: Vec<i32> = (0..steps).map(|_| rng.gen_range(alphabet as u64) as i32).collect();
    let trans: Vec<i32> = vec![-3, -7]; // stay, move penalties
    let eb = a.data.alloc_i32("emit", &emit);
    let obsb = a.data.alloc_i32("obs", &obs);
    let tb = a.data.alloc_i32("trans", &trans);
    let v0 = a.data.alloc_i32("v0", &vec![0i32; states]);
    let v1 = a.data.alloc_i32("v1", &vec![0i32; states]);

    // r3=t, r4=j, r5=obs_t, r6=prev base, r7=cur base, r8..r13 scratch
    let (rt_, rj, robs, rprev, rcur, rs1, rs2, rtmp, rt2, rstay, rmove) =
        (3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13);
    a.li(rtmp, tb as i32);
    a.lw(rstay, rtmp, 0);
    a.lw(rmove, rtmp, 4);
    a.li(rprev, v0 as i32);
    a.li(rcur, v1 as i32);
    a.li(rt_, 0);
    let step = a.label("step");
    a.bind(step);
    // obs_t
    a.slli(rtmp, rt_, 2);
    a.addi(rtmp, rtmp, obsb as i32);
    a.lw(robs, rtmp, 0);
    a.li(rj, 0);
    let state = a.label("state");
    a.bind(state);
    // stay = V[t-1][j] + stay_penalty
    a.slli(rtmp, rj, 2);
    a.add(rt2, rtmp, rprev);
    a.lw(rs1, rt2, 0);
    a.add(rs1, rs1, rstay);
    // move = V[t-1][j-1] + move_penalty (j=0: reuse stay)
    let no_move = a.label("no_move");
    a.beq(rj, 0, no_move);
    a.lw(rs2, rt2, -4);
    a.add(rs2, rs2, rmove);
    let pick = a.label("pick");
    a.bge(rs1, rs2, pick);
    a.mv(rs1, rs2);
    a.bind(pick);
    a.bind(no_move);
    // + emit[j][obs_t]: emit base + (j*alphabet + obs)*4
    a.slli(rt2, rj, 2);
    a.slli(rt2, rt2, 2); // j*16 = j*alphabet*4
    a.slli(rtmp, robs, 2);
    a.add(rt2, rt2, rtmp);
    a.addi(rt2, rt2, eb as i32);
    a.lw(rt2, rt2, 0);
    a.add(rs1, rs1, rt2);
    // V[t][j] = rs1
    a.slli(rtmp, rj, 2);
    a.add(rtmp, rtmp, rcur);
    a.sw(rs1, rtmp, 0);
    a.addi(rj, rj, 1);
    a.li(rtmp, states as i32);
    a.blt(rj, rtmp, state);
    // swap prev/cur
    a.mv(rt2, rprev);
    a.mv(rprev, rcur);
    a.mv(rcur, rt2);
    a.addi(rt_, rt_, 1);
    a.li(rtmp, steps as i32);
    a.blt(rt_, rtmp, step);
    a.halt();
    a.assemble()
}

/// mcf: network-simplex pricing sweep — reduced cost per arc,
/// `rc = cost[a] + pot[src[a]] − pot[dst[a]]`, flow bump on negative arcs.
pub fn mcf(scale: usize, seed: u64) -> Program {
    let nodes = if scale == 0 { 128 } else { (scale * 32).max(8) };
    let arcs = nodes * 4;
    let rounds = 4usize;
    let mut rng = Rng::new(seed ^ 0x6d6366);
    let mut a = Asm::new("mcf");

    let src: Vec<i32> = (0..arcs).map(|_| rng.gen_range(nodes as u64) as i32).collect();
    let dst: Vec<i32> = (0..arcs).map(|_| rng.gen_range(nodes as u64) as i32).collect();
    let cost: Vec<i32> = (0..arcs).map(|_| rng.gen_range(40) as i32 - 20).collect();
    let pot: Vec<i32> = (0..nodes).map(|_| rng.gen_range(30) as i32).collect();
    let sb = a.data.alloc_i32("src", &src);
    let db = a.data.alloc_i32("dst", &dst);
    let cb = a.data.alloc_i32("cost", &cost);
    let pb = a.data.alloc_i32("pot", &pot);
    let fb = a.data.alloc_i32("flow", &vec![0i32; arcs]);
    let cnt = a.data.alloc_i32("ncount", &[0]);

    let (rr, ra_, ru, rv, rc, rtmp, rt2, rneg) = (3, 4, 5, 6, 7, 9, 10, 11);
    a.li(rr, 0);
    let round = a.label("round");
    a.bind(round);
    a.li(rneg, 0);
    a.li(ra_, 0);
    let arc = a.label("arc");
    a.bind(arc);
    a.slli(rtmp, ra_, 2);
    a.addi(ru, rtmp, sb as i32);
    a.lw(ru, ru, 0);
    a.slli(rtmp, ra_, 2);
    a.addi(rv, rtmp, db as i32);
    a.lw(rv, rv, 0);
    a.slli(rtmp, ra_, 2);
    a.addi(rc, rtmp, cb as i32);
    a.lw(rc, rc, 0);
    // rc += pot[u]; rc -= pot[v]
    a.slli(rtmp, ru, 2);
    a.addi(rtmp, rtmp, pb as i32);
    a.lw(rt2, rtmp, 0);
    a.add(rc, rc, rt2);
    a.slli(rtmp, rv, 2);
    a.addi(rtmp, rtmp, pb as i32);
    a.lw(rt2, rtmp, 0);
    a.sub(rc, rc, rt2);
    let skip = a.label("skip");
    a.bge(rc, 0, skip);
    // negative reduced cost: bump flow, count
    a.slli(rtmp, ra_, 2);
    a.addi(rtmp, rtmp, fb as i32);
    a.lw(rt2, rtmp, 0);
    a.addi(rt2, rt2, 1);
    a.sw(rt2, rtmp, 0);
    a.addi(rneg, rneg, 1);
    a.bind(skip);
    a.addi(ra_, ra_, 1);
    a.li(rtmp, arcs as i32);
    a.blt(ra_, rtmp, arc);
    // store the round's negative-arc count
    a.li(rtmp, cnt as i32);
    a.sw(rneg, rtmp, 0);
    a.addi(rr, rr, 1);
    a.li(rtmp, rounds as i32);
    a.blt(rr, rtmp, round);
    a.halt();
    a.assemble()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::probes::StopReason;
    use crate::sim::{simulate, Limits};

    #[test]
    fn all_spec_kernels_halt() {
        for (name, f) in [
            ("astar", astar as fn(usize, u64) -> Program),
            ("h264ref", h264ref),
            ("hmmer", hmmer),
            ("mcf", mcf),
        ] {
            let t = simulate(&f(1, 3), &SystemConfig::default(), Limits::default())
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(t.stop, StopReason::Halt, "{name}");
            assert!(t.committed > 5000, "{name}: {}", t.committed);
        }
    }

    #[test]
    fn h264_heavier_in_alu_than_loads() {
        // SAD is compute-dense: ALU ops should outnumber loads
        let t = simulate(&h264ref(1, 3), &SystemConfig::default(), Limits::default()).unwrap();
        let alu = t.pipe.fu_counts[crate::isa::FuncUnit::IntAlu.index()];
        assert!(alu > t.pipe.lsq_reads);
    }
}
