//! The 17 benchmark applications of Table IV, hand-compiled to EVA32.
//!
//! | category           | benchmarks                                   |
//! |--------------------|----------------------------------------------|
//! | machine learning   | nb, dt, svm, lir, km                         |
//! | string processing  | lcs                                          |
//! | multimedia         | m2d (MPEG-2 decode kernels)                  |
//! | graph processing   | bfs, dfs, bc, sssp, ccomp, prank             |
//! | SPEC 2006 (kernels)| astar, h264ref, hmmer, mcf                   |
//!
//! Every builder takes `(scale, seed)`: `scale = 0` selects the default
//! problem size (tuned for ~10⁵ committed instructions — big enough for
//! stable MACR/energy statistics, small enough to sweep 17×N design points);
//! inputs are generated with the seeded in-tree PRNG so runs reproduce.

pub mod graph;
pub mod lcs;
pub mod media;
pub mod ml;
pub mod spec;

use crate::asm::Program;

/// All benchmark names, in Table IV order.
pub const NAMES: [&str; 17] = [
    "nb", "dt", "svm", "lir", "km", "lcs", "m2d", "bfs", "dfs", "bc",
    "sssp", "ccomp", "prank", "astar", "h264ref", "hmmer", "mcf",
];

/// Paper display names (Table VI header order).
pub const DISPLAY: [(&str, &str); 17] = [
    ("nb", "NB"), ("dt", "DT"), ("svm", "SVM"), ("lir", "LiR"), ("km", "KM"),
    ("lcs", "LCS"), ("m2d", "M2D"), ("bfs", "BFS"), ("dfs", "DFS"),
    ("bc", "BC"), ("sssp", "SSSP"), ("ccomp", "CCOMP"), ("prank", "PR"),
    ("astar", "astar"), ("h264ref", "h264ref"), ("hmmer", "hmmer"),
    ("mcf", "mcf"),
];

/// Paper display name for a benchmark key (`"?"` for unknown keys).
pub fn display_name(key: &str) -> &'static str {
    DISPLAY
        .iter()
        .find(|(k, _)| *k == key)
        .map(|(_, d)| *d)
        .unwrap_or("?")
}

/// Build a benchmark program by name. `None` for unknown names.
pub fn build(name: &str, scale: usize, seed: u64) -> Option<Program> {
    Some(match name {
        "nb" => ml::naive_bayes(scale, seed),
        "dt" => ml::decision_tree(scale, seed),
        "svm" => ml::svm(scale, seed),
        "lir" => ml::linear_regression(scale, seed),
        "km" | "kmeans" => ml::kmeans(scale, seed),
        "lcs" => lcs::lcs(scale, seed),
        "m2d" => media::mpeg2_decode(scale, seed),
        "bfs" => graph::bfs(scale, seed),
        "dfs" => graph::dfs(scale, seed),
        "bc" => graph::betweenness(scale, seed),
        "sssp" => graph::sssp(scale, seed),
        "ccomp" => graph::ccomp(scale, seed),
        "prank" | "pr" => graph::pagerank(scale, seed),
        "astar" => spec::astar(scale, seed),
        "h264ref" => spec::h264ref(scale, seed),
        "hmmer" => spec::hmmer(scale, seed),
        "mcf" => spec::mcf(scale, seed),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_all_names() {
        for n in NAMES {
            assert!(build(n, 4, 1).is_some(), "missing workload {n}");
        }
        assert!(build("bogus", 4, 1).is_none());
    }

    #[test]
    fn display_names_match_paper() {
        assert_eq!(display_name("prank"), "PR");
        assert_eq!(display_name("km"), "KM");
        assert_eq!(display_name("lir"), "LiR");
    }
}
