//! Deterministic work-stealing shard queue for sweep staging.
//!
//! A sweep's pending point-indices are treated as one logical array; the
//! queue hands out contiguous chunks via a single atomic cursor.  Workers
//! that land on cheap points (memoized traces) immediately steal the next
//! chunk, so load-balancing is automatic and — unlike static partitioning
//! — no worker idles while another drains a queue of cold simulations.
//! Chunking (rather than single-point claims) keeps cursor contention
//! negligible for large sweeps.

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};

/// A shared queue over `0..len` that hands out chunks of work.
pub struct ChunkQueue {
    len: usize,
    chunk: usize,
    cursor: AtomicUsize,
}

impl ChunkQueue {
    /// `chunk == 0` picks an automatic size: enough chunks for ~4 claims
    /// per worker, clamped to `[1, 64]` points.
    pub fn new(len: usize, chunk: usize, workers: usize) -> Self {
        let chunk = if chunk > 0 {
            chunk
        } else {
            (len / (workers.max(1) * 4)).clamp(1, 64)
        };
        Self { len, chunk, cursor: AtomicUsize::new(0) }
    }

    /// The resolved (possibly auto-sized) chunk length.
    pub fn chunk_size(&self) -> usize {
        self.chunk
    }

    /// Claim the next chunk; `None` once the queue is drained.
    pub fn claim(&self) -> Option<Range<usize>> {
        let start = self.cursor.fetch_add(self.chunk, Ordering::Relaxed);
        if start >= self.len {
            None
        } else {
            Some(start..(start + self.chunk).min(self.len))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_every_index_exactly_once() {
        let q = ChunkQueue::new(103, 10, 4);
        let mut seen = vec![0u32; 103];
        while let Some(r) = q.claim() {
            for i in r {
                seen[i] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1));
    }

    #[test]
    fn auto_chunk_is_clamped() {
        assert_eq!(ChunkQueue::new(10, 0, 4).chunk_size(), 1);
        assert_eq!(ChunkQueue::new(10_000, 0, 4).chunk_size(), 64);
        assert_eq!(ChunkQueue::new(0, 0, 1).chunk_size(), 1);
        assert!(ChunkQueue::new(0, 0, 1).claim().is_none());
    }

    #[test]
    fn concurrent_claims_are_disjoint() {
        let q = ChunkQueue::new(1000, 7, 8);
        let counts: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        std::thread::scope(|scope| {
            for _ in 0..8 {
                scope.spawn(|| {
                    while let Some(r) = q.claim() {
                        for i in r {
                            counts[i].fetch_add(1, Ordering::Relaxed);
                        }
                    }
                });
            }
        });
        assert!(counts.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }
}
