//! Stable content hashing for the sweep caches.
//!
//! Cache keys must be identical across processes, platforms and runs, so
//! nothing here may depend on `std::collections::HashMap`'s randomized
//! hasher or on struct memory layout.  Instead, the identity of a design
//! point is its *canonical JSON serialization* (object keys sorted by the
//! underlying `BTreeMap`), hashed with FNV-1a 64.  Any change to any field
//! of the workload identity or the [`SystemConfig`] — including cosmetic
//! ones like the config name — therefore produces a different key and a
//! cache miss; stale reuse is impossible by construction.

use crate::config::{CacheConfig, SystemConfig};
use crate::util::json::Json;

use super::{SweepOptions, SweepPoint};

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0100_0000_01b3;

/// FNV-1a over a byte string — stable, dependency-free, and fast enough
/// for the handful of hashes a sweep needs.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

fn cache_to_json(c: &CacheConfig) -> Json {
    Json::obj(vec![
        ("capacity", c.capacity.into()),
        ("assoc", c.assoc.into()),
        ("line", c.line.into()),
        ("banks", c.banks.into()),
        ("latency", c.latency.into()),
        ("mshr_entries", c.mshr_entries.into()),
    ])
}

/// Canonical serialization of a full [`SystemConfig`] (every field).
///
/// The technology is serialized as its *name plus full device-model
/// content* (coefficients + scaling rule), not just the name: a custom
/// `[tech.<name>]` whose parameters are edited between runs must miss the
/// result cache, and two differently-named technologies with identical
/// physics intentionally hash differently too (the name is part of the
/// design-point identity, like the config name).  Adding the model
/// content was a key-schema change: caches written by pre-registry
/// builds miss wholesale rather than ever serving stale rows
/// (`rust/tests/device_registry.rs` pins that behavior).
pub fn config_to_json(cfg: &SystemConfig) -> Json {
    Json::obj(vec![
        ("name", cfg.name.as_str().into()),
        (
            "core",
            Json::obj(vec![
                ("width", cfg.core.width.into()),
                ("rob_entries", cfg.core.rob_entries.into()),
                ("iq_entries", cfg.core.iq_entries.into()),
                ("lsq_entries", cfg.core.lsq_entries.into()),
                ("mispredict_penalty", cfg.core.mispredict_penalty.into()),
                ("int_alu_units", cfg.core.int_alu_units.into()),
                ("int_mul_units", cfg.core.int_mul_units.into()),
                ("fp_units", cfg.core.fp_units.into()),
                ("mem_ports", cfg.core.mem_ports.into()),
            ]),
        ),
        ("l1i", cache_to_json(&cfg.l1i)),
        ("l1d", cache_to_json(&cfg.l1d)),
        ("l2", cache_to_json(&cfg.l2)),
        (
            "dram",
            Json::obj(vec![
                ("size", cfg.dram.size.into()),
                ("latency", cfg.dram.latency.into()),
            ]),
        ),
        ("tech", cfg.tech.name().into()),
        ("tech_model", crate::energy::device::model_of(cfg.tech).content_json()),
        ("cim_levels", cfg.cim_levels.name().into()),
        ("clock_ghz", cfg.clock_ghz.into()),
    ])
}

/// Key for the design-point result cache: content hash of
/// `(bench, scale, seed, max_instructions, SystemConfig, LocalityRule,
/// backend)`.  The evaluating backend is part of the identity because the
/// PJRT artifacts compute in f32 while the native mirror uses f64 — rows
/// from one must never satisfy a resume on the other.
pub fn point_key(p: &SweepPoint, opts: &SweepOptions, backend: &str) -> String {
    let payload = Json::obj(vec![
        ("bench", p.bench.as_str().into()),
        ("scale", opts.scale.into()),
        ("seed", opts.seed.into()),
        ("max_instructions", opts.max_instructions.into()),
        ("rule", p.rule.name().into()),
        ("backend", backend.into()),
        ("config", config_to_json(&p.config)),
    ])
    .dump();
    format!("{:016x}", fnv1a(payload.as_bytes()))
}

/// Key for the trace store: only what affects *simulation* — the workload
/// identity plus core, cache-geometry, DRAM and clock parameters.  The
/// technology and CiM-placement columns are deliberately excluded, so one
/// spilled trace serves every tech/placement variant of a geometry.
pub fn trace_key(bench: &str, cfg: &SystemConfig, opts: &SweepOptions) -> String {
    let mut sim_cfg = cfg.clone();
    sim_cfg.name = String::new();
    sim_cfg.tech = crate::config::Technology::SRAM;
    sim_cfg.cim_levels = crate::config::CimLevels::Both;
    let payload = Json::obj(vec![
        ("bench", bench.into()),
        ("scale", opts.scale.into()),
        ("seed", opts.seed.into()),
        ("max_instructions", opts.max_instructions.into()),
        ("config", config_to_json(&sim_cfg)),
    ])
    .dump();
    format!("{:016x}", fnv1a(payload.as_bytes()))
}

/// Key for the analysis-artifact store: the trace identity crossed with
/// everything the *analyzer* (and nothing the energy fold) consumes —
/// CiM placement, locality rule, and the analyzer schema version
/// ([`super::analysis_store::ANALYZER_SCHEMA`]).  Technology is
/// deliberately excluded: it only enters the per-tech energy fold, so one
/// artifact serves every technology variant of a design point.
pub fn analysis_key(
    trace_key: &str,
    cim: crate::config::CimLevels,
    rule: crate::analyzer::LocalityRule,
) -> String {
    let payload = Json::obj(vec![
        ("trace", trace_key.into()),
        ("cim_levels", cim.name().into()),
        ("rule", rule.name().into()),
        ("analyzer_schema", super::analysis_store::ANALYZER_SCHEMA.into()),
    ])
    .dump();
    format!("{:016x}", fnv1a(payload.as_bytes()))
}

/// Key for a planning pass: the analysis identity crossed with everything
/// the *planner* consumes — the policy, every threshold knob, the planner
/// schema version ([`crate::planner::PLANNER_SCHEMA`]), and the full
/// config serialization.  Unlike the analysis key, the config (hence the
/// device-model content) *is* included: profitability prices groups in
/// pJ using the technology's registered coefficients, so editing a custom
/// tech must invalidate its plans even though it never invalidates the
/// analysis.  With the default `accept-all` policy this key is consulted
/// only by the plan path itself — existing trace/analysis/result keys are
/// untouched.
pub fn plan_key(
    analysis_key: &str,
    cfg: &SystemConfig,
    policy: crate::planner::PlanPolicy,
    knobs: &crate::planner::PlanKnobs,
) -> String {
    let payload = Json::obj(vec![
        ("analysis", analysis_key.into()),
        ("planner_schema", crate::planner::PLANNER_SCHEMA.into()),
        ("policy", policy.name().into()),
        ("min_ops", knobs.min_ops.into()),
        ("min_net_pj", knobs.min_net_pj.into()),
        ("plan_level", knobs.level.name().into()),
        ("config", config_to_json(cfg)),
    ])
    .dump();
    format!("{:016x}", fnv1a(payload.as_bytes()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyzer::LocalityRule;
    use crate::config::Technology;

    fn opts() -> SweepOptions {
        SweepOptions { scale: 4, seed: 7, ..Default::default() }
    }

    fn point(cfg: SystemConfig) -> SweepPoint {
        SweepPoint { bench: "lcs".into(), config: cfg, rule: LocalityRule::AnyCache }
    }

    #[test]
    fn fnv1a_matches_reference_vectors() {
        // published FNV-1a 64 test vectors
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn point_key_is_deterministic() {
        let p = point(SystemConfig::preset("c1").unwrap());
        assert_eq!(
            point_key(&p, &opts(), "native"),
            point_key(&p, &opts(), "native")
        );
    }

    #[test]
    fn point_key_changes_with_every_identity_field() {
        let base = point(SystemConfig::preset("c1").unwrap());
        let k0 = point_key(&base, &opts(), "native");

        let mut p = base.clone();
        p.bench = "km".into();
        assert_ne!(point_key(&p, &opts(), "native"), k0);

        let mut p = base.clone();
        p.rule = LocalityRule::SameBank;
        assert_ne!(point_key(&p, &opts(), "native"), k0);

        let mut p = base.clone();
        p.config.tech = Technology::FEFET;
        assert_ne!(point_key(&p, &opts(), "native"), k0);

        let mut p = base.clone();
        p.config.l1d.capacity *= 2;
        assert_ne!(point_key(&p, &opts(), "native"), k0);

        let mut o = opts();
        o.seed = 8;
        assert_ne!(point_key(&base, &o, "native"), k0);

        let mut o = opts();
        o.scale = 5;
        assert_ne!(point_key(&base, &o, "native"), k0);

        assert_ne!(point_key(&base, &opts(), "pjrt"), k0);
    }

    #[test]
    fn analysis_key_covers_placement_and_rule_but_not_tech() {
        use crate::config::CimLevels;

        let cfg = SystemConfig::preset("c1").unwrap();
        let tk = trace_key("lcs", &cfg, &opts());
        let k0 = analysis_key(&tk, CimLevels::Both, LocalityRule::AnyCache);
        assert_eq!(
            k0,
            analysis_key(&tk, CimLevels::Both, LocalityRule::AnyCache),
            "analysis key must be deterministic"
        );
        assert_ne!(k0, analysis_key(&tk, CimLevels::L1Only, LocalityRule::AnyCache));
        assert_ne!(k0, analysis_key(&tk, CimLevels::Both, LocalityRule::SameBank));
        // tech variants share the trace key, hence the analysis key
        let tk_fefet =
            trace_key("lcs", &cfg.clone().with_tech(Technology::FEFET), &opts());
        assert_eq!(
            analysis_key(&tk_fefet, CimLevels::Both, LocalityRule::AnyCache),
            k0
        );
        // a different trace is a different analysis
        let mut bigger = cfg.clone();
        bigger.l1d.capacity *= 2;
        let tk2 = trace_key("lcs", &bigger, &opts());
        assert_ne!(analysis_key(&tk2, CimLevels::Both, LocalityRule::AnyCache), k0);
    }

    #[test]
    fn trace_key_ignores_tech_and_placement() {
        let cfg = SystemConfig::preset("c1").unwrap();
        let sram = trace_key("lcs", &cfg, &opts());
        let fefet = trace_key("lcs", &cfg.clone().with_tech(Technology::FEFET), &opts());
        assert_eq!(sram, fefet);
        let rram = trace_key("lcs", &cfg.clone().with_tech(Technology::RRAM), &opts());
        assert_eq!(sram, rram);
        let mut bigger = cfg.clone();
        bigger.l1d.capacity *= 2;
        assert_ne!(trace_key("lcs", &bigger, &opts()), sram);
    }

    #[test]
    fn plan_key_covers_policy_knobs_and_tech() {
        use crate::config::CimLevels;
        use crate::planner::{PlanKnobs, PlanPolicy};

        let cfg = SystemConfig::preset("c1").unwrap();
        let tk = trace_key("lcs", &cfg, &opts());
        let ak = analysis_key(&tk, CimLevels::Both, LocalityRule::AnyCache);
        let knobs = PlanKnobs::default();
        let k0 = plan_key(&ak, &cfg, PlanPolicy::AcceptAll, &knobs);
        assert_eq!(k0, plan_key(&ak, &cfg, PlanPolicy::AcceptAll, &knobs));
        assert_ne!(k0, plan_key(&ak, &cfg, PlanPolicy::Profitability, &knobs));
        let k = PlanKnobs { min_ops: 3, ..knobs };
        assert_ne!(k0, plan_key(&ak, &cfg, PlanPolicy::AcceptAll, &k));
        let k = PlanKnobs { min_net_pj: 5.0, ..knobs };
        assert_ne!(k0, plan_key(&ak, &cfg, PlanPolicy::AcceptAll, &k));
        let k = PlanKnobs { level: CimLevels::L1Only, ..knobs };
        assert_ne!(k0, plan_key(&ak, &cfg, PlanPolicy::AcceptAll, &k));
        // unlike the analysis key, the plan key covers the technology:
        // pricing depends on the device-model coefficients
        let fefet = cfg.clone().with_tech(Technology::FEFET);
        assert_ne!(k0, plan_key(&ak, &fefet, PlanPolicy::AcceptAll, &knobs));
        // and a different analysis is a different plan
        assert_ne!(
            k0,
            plan_key(
                &analysis_key(&tk, CimLevels::L1Only, LocalityRule::AnyCache),
                &cfg,
                PlanPolicy::AcceptAll,
                &knobs
            )
        );
    }

    #[test]
    fn point_key_covers_custom_tech_parameters() {
        use crate::energy::device::{self, DeviceModel};

        let mut m =
            DeviceModel::based_on(Technology::RRAM, "key-test-dev").unwrap();
        let t = device::register(m.clone()).unwrap();
        let p = point(SystemConfig::preset("c1").unwrap().with_tech(t));
        let k0 = point_key(&p, &opts(), "native");

        // same geometry + same tech name, edited coefficients: new key
        m.e_l1[crate::energy::calib::OP_ADD] += 5.0;
        device::register(m).unwrap();
        let k1 = point_key(&p, &opts(), "native");
        assert_ne!(k0, k1, "coefficient edit must invalidate the cache key");

        // distinct from every built-in's key as well
        for b in [Technology::SRAM, Technology::RRAM] {
            let pb = point(SystemConfig::preset("c1").unwrap().with_tech(b));
            assert_ne!(point_key(&pb, &opts(), "native"), k1);
        }
    }
}
