//! The L3 coordinator: design-space-exploration sweeps.
//!
//! The coordinator is the leader of a worker pool: simulation + analysis +
//! reshaping jobs (CPU-bound, trace-heavy) fan out across `std::thread`
//! workers, traces are memoized per (benchmark, cache geometry) — the same
//! trace serves every technology and CiM-placement variant — and the
//! resulting design points are *batched* into PJRT executions of the AOT'd
//! profiler graph (256 points per call, padded).
//!
//! This is the paper's tool-chain glue (Fig 1) turned into a runtime: one
//! `sweep` call regenerates any of Figs 13–16 / Table VI.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::analyzer::{analyze, LocalityRule, Macr};
use crate::config::SystemConfig;
use crate::probes::Trace;
use crate::profiler::{ProfileInputs, ProfileResult};
use crate::reshape::reshape;
use crate::runtime::Backend;
use crate::sim::{simulate, Limits};
use crate::workloads;

/// One design point of a sweep.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub bench: String,
    pub config: SystemConfig,
    pub rule: LocalityRule,
}

/// Per-point sweep output.
#[derive(Clone, Debug)]
pub struct SweepRow {
    pub bench: String,
    pub config_name: String,
    pub tech: crate::config::Technology,
    pub cim_levels: crate::config::CimLevels,
    pub macr: Macr,
    pub committed: u64,
    pub cycles: u64,
    pub removed: u64,
    pub cim_ops: u64,
    pub result: ProfileResult,
}

/// Workload sizing knobs for a sweep.
#[derive(Clone, Copy, Debug)]
pub struct SweepOptions {
    /// problem-size hint handed to the workload generators
    pub scale: usize,
    pub seed: u64,
    pub max_instructions: u64,
    pub workers: usize,
}

impl Default for SweepOptions {
    fn default() -> Self {
        Self {
            scale: 0, // 0 = workload default
            seed: 42,
            max_instructions: 5_000_000,
            workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
                .min(8),
        }
    }
}

/// Key for the trace memo: geometry fields that affect simulation.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
struct SimKey {
    bench: String,
    l1i: (u32, u32, u32, u64),
    l1d: (u32, u32, u32, u64),
    l2: (u32, u32, u32, u64),
    dram_latency: u64,
    scale: usize,
    seed: u64,
}

impl SimKey {
    fn new(bench: &str, cfg: &SystemConfig, opts: &SweepOptions) -> Self {
        let k = |c: &crate::config::CacheConfig| (c.capacity, c.assoc, c.line, c.latency);
        Self {
            bench: bench.to_string(),
            l1i: k(&cfg.l1i),
            l1d: k(&cfg.l1d),
            l2: k(&cfg.l2),
            dram_latency: cfg.dram.latency,
            scale: opts.scale,
            seed: opts.seed,
        }
    }
}

/// The sweep driver.
pub struct Coordinator {
    pub opts: SweepOptions,
}

impl Coordinator {
    pub fn new(opts: SweepOptions) -> Self {
        Self { opts }
    }

    /// Simulate (with memoization), analyze and reshape every point, then
    /// evaluate the whole batch through `backend`.
    pub fn run_sweep(
        &self,
        points: &[SweepPoint],
        backend: &mut dyn Backend,
    ) -> Result<Vec<SweepRow>> {
        let opts = self.opts;
        let memo: Mutex<HashMap<SimKey, Arc<Trace>>> = Mutex::new(HashMap::new());
        let next: Mutex<usize> = Mutex::new(0);
        let staged: Mutex<Vec<Option<(SweepRow, ProfileInputs)>>> =
            Mutex::new((0..points.len()).map(|_| None).collect());
        let errors: Mutex<Vec<String>> = Mutex::new(Vec::new());

        std::thread::scope(|scope| {
            for _ in 0..opts.workers.max(1) {
                scope.spawn(|| loop {
                    let idx = {
                        let mut n = next.lock().unwrap();
                        if *n >= points.len() {
                            return;
                        }
                        let i = *n;
                        *n += 1;
                        i
                    };
                    let p = &points[idx];
                    match Self::stage_point(p, &opts, &memo) {
                        Ok(pair) => {
                            staged.lock().unwrap()[idx] = Some(pair);
                        }
                        Err(e) => {
                            errors
                                .lock()
                                .unwrap()
                                .push(format!("{}/{}: {e:#}", p.bench, p.config.name));
                        }
                    }
                });
            }
        });

        let errors = errors.into_inner().unwrap();
        if !errors.is_empty() {
            return Err(anyhow!("sweep failures: {}", errors.join("; ")));
        }
        let staged: Vec<(SweepRow, ProfileInputs)> = staged
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|o| o.expect("staged point missing"))
            .collect();

        // batched profiler evaluation (one PJRT execute per 256 points)
        let inputs: Vec<ProfileInputs> =
            staged.iter().map(|(_, i)| i.clone()).collect();
        let results = backend.evaluate_batch(&inputs)?;
        Ok(staged
            .into_iter()
            .zip(results)
            .map(|((mut row, _), res)| {
                row.result = res;
                row
            })
            .collect())
    }

    fn stage_point(
        p: &SweepPoint,
        opts: &SweepOptions,
        memo: &Mutex<HashMap<SimKey, Arc<Trace>>>,
    ) -> Result<(SweepRow, ProfileInputs)> {
        let key = SimKey::new(&p.bench, &p.config, opts);
        let cached = memo.lock().unwrap().get(&key).cloned();
        let trace = match cached {
            Some(t) => t,
            None => {
                let prog = workloads::build(&p.bench, opts.scale, opts.seed)
                    .ok_or_else(|| anyhow!("unknown benchmark '{}'", p.bench))?;
                let t = simulate(
                    &prog,
                    &p.config,
                    Limits { max_instructions: opts.max_instructions },
                )?;
                let t = Arc::new(t);
                memo.lock().unwrap().insert(key, t.clone());
                t
            }
        };
        let analysis = analyze(&trace, &p.config, p.rule);
        let reshaped = reshape(&trace, &analysis.selection, &p.config);
        let inputs = ProfileInputs::new(&p.config, &reshaped);
        let row = SweepRow {
            bench: p.bench.clone(),
            config_name: p.config.name.clone(),
            tech: p.config.tech,
            cim_levels: p.config.cim_levels,
            macr: analysis.macr,
            committed: trace.committed,
            cycles: trace.cycles,
            removed: reshaped.removed,
            cim_ops: reshaped.cim_op_count,
            result: ProfileResult::default(),
        };
        Ok((row, inputs))
    }
}

/// Cartesian-product helper: benches × configs, one point each.
pub fn cross(
    benches: &[&str],
    configs: &[SystemConfig],
    rule: LocalityRule,
) -> Vec<SweepPoint> {
    let mut points = Vec::new();
    for b in benches {
        for c in configs {
            points.push(SweepPoint {
                bench: b.to_string(),
                config: c.clone(),
                rule,
            });
        }
    }
    points
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;

    #[test]
    fn sweep_two_benches_two_configs_native() {
        let cfgs = [
            SystemConfig::preset("c1").unwrap(),
            SystemConfig::preset("c2").unwrap(),
        ];
        let points = cross(&["lcs", "kmeans"], &cfgs, LocalityRule::AnyCache);
        let coord = Coordinator::new(SweepOptions {
            scale: 8,
            workers: 2,
            ..Default::default()
        });
        let rows = coord.run_sweep(&points, &mut NativeBackend).unwrap();
        assert_eq!(rows.len(), 4);
        for r in rows {
            assert!(r.committed > 0);
            assert!(r.result.total_base > 0.0);
            assert!(r.result.improvement > 0.0);
        }
    }

    #[test]
    fn unknown_bench_errors() {
        let points = cross(
            &["no_such_bench"],
            &[SystemConfig::default()],
            LocalityRule::AnyCache,
        );
        let coord = Coordinator::new(SweepOptions { workers: 1, ..Default::default() });
        assert!(coord.run_sweep(&points, &mut NativeBackend).is_err());
    }
}
