//! The L3 coordinator: design-space-exploration sweeps.
//!
//! The per-point pipeline is *stage-factored* (paper Fig 2, §IV) into
//! four independently keyed stages:
//!
//! 1. **simulate** — keyed by [`key::trace_key`] (workload + geometry;
//!    technology and CiM placement excluded), spilled chunk-by-chunk to
//!    disk ([`trace_store`]);
//! 2. **analyze** — keyed by [`key::analysis_key`] (trace key × CiM
//!    placement × locality rule × analyzer schema), producing a
//!    persistable [`analysis_store::AnalysisArtifact`] (stream outcome +
//!    reshape deltas) stored in `analysis/` and memoized in-process;
//! 3. **plan** — keyed by [`key::plan_key`] (analysis key × policy ×
//!    threshold knobs × planner schema × device-model content), judging
//!    every candidate group through the offload profitability model
//!    ([`crate::planner`]) and feeding only the *accepted* groups to the
//!    fold.  The key's invalidation rule is stricter than the analysis
//!    key's: the technology IS included, because profitability prices
//!    groups with the registered device coefficients — editing a custom
//!    tech invalidates its plans but never its analyses.  Under the
//!    default `accept-all` policy this stage is the identity (the
//!    analyzer's deltas pass through unchanged), so sweeps skip it
//!    entirely and stay byte-identical to the three-stage pipeline; it
//!    runs only on the explicit plan path ([`Coordinator::run_plan`]),
//!    memoized in-process ([`PlanArtifact`]);
//! 4. **energy fold** — per technology, microseconds, never cached.
//!
//! The scheduler exploits the factoring: design points are grouped by
//! trace, then by analysis key, and the worker pool claims whole *trace
//! groups* from a work-stealing queue ([`shard`]).  A group with K
//! uncached analyses replays (or simulates) its trace **once** through a
//! broadcast [`crate::pipeline::AnalyzerFanout`] that feeds all K online
//! analyzers in a single pass; technology-only variants skip replay and
//! analysis entirely and just re-fold energy from the shared artifact.
//! A sweep over T technologies × P placements therefore runs P analyses,
//! not T·P — and with a warm artifact store, zero.
//!
//! Warm-trace replay is parallel on two axes.  *Within* one replay the
//! spill's chunk framing lets [`trace_store`] decode chunks on
//! [`SweepOptions::replay_threads`] worker lanes (zero-copy, reassembled
//! in commit order before the fan-out sees a record).  *Across* lanes,
//! when idle workers exceed the remaining trace groups and the group's
//! trace is warm on disk, the scheduler splits the group's K analysis
//! lanes into concurrent passes — each pass replays the spill through
//! its own fan-out subset — instead of one sequential K-lane pass (the
//! interactive small-sweep corner; extra *replays* are cheap once the
//! spill is warm, extra *simulations* never happen: a cold trace still
//! simulates once through a full fan-out).  Both paths are
//! byte-identical to sequential replay and observable in the ledger via
//! [`SweepStats::replay_chunks_decoded`] /
//! [`SweepStats::replay_lanes_split`].
//!
//! The *cold* path — stage 1 when no spilled trace exists — runs the
//! simulator's pre-decoded loop ([`crate::sim::decode`]) through the
//! normal [`crate::sim::simulate_into`] dispatch.  The decoded path is
//! byte-identical to the reference interpreter, so trace keys, spilled
//! bytes, artifacts and ledger counters (`simulator_runs` in particular)
//! are unchanged by it.
//!
//! Completed design points are persisted to an append-only JSONL result
//! cache ([`cache`]) keyed by a stable content hash ([`key`]) of
//! `(bench, scale, seed, SystemConfig, LocalityRule, backend)`.  A
//! resumed sweep — or any superset of a prior sweep — recomputes only the
//! missing points and returns rows byte-identical to a cold run
//! ([`persist`] keeps the serialization canonical).
//!
//! Surviving design points are *batched* into PJRT executions of the
//! AOT'd profiler graph (256 points per call, padded).  This is the
//! paper's tool-chain glue (Fig 1) turned into a runtime: one `sweep`
//! call regenerates any of Figs 13–16 / Table VI.

pub mod analysis_store;
pub mod cache;
pub mod key;
pub mod persist;
pub mod shard;
pub mod trace_store;

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::analyzer::{LocalityRule, Macr, OnlineAnalyzer, StreamOutcome};
use crate::config::{CimLevels, SystemConfig};
use crate::pipeline::{self, AnalyzerFanout};
use crate::probes::TraceSummary;
use crate::profiler::{ProfileInputs, ProfileResult};
use crate::reshape::{reshape_from_deltas, DeltaSink};
use crate::runtime::Backend;
use crate::sim::Limits;
use crate::util::faultio;
use crate::util::json::Json;
use crate::util::lock_unpoisoned;
use crate::workloads;

use analysis_store::{AnalysisArtifact, AnalysisStore};
use cache::ResultCache;
use shard::ChunkQueue;
use trace_store::TraceStore;

/// Name of the quarantine directory under the cache root: store entries
/// that fail decode are preserved here (payload + `.reason` file) by all
/// three stores instead of being silently skipped — see
/// [`crate::util::faultio`].
pub const QUARANTINE_DIR: &str = "quarantine";

/// One design point of a sweep.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    /// workload name (see `workloads::NAMES`)
    pub bench: String,
    /// full system configuration (geometry, tech, placement)
    pub config: SystemConfig,
    /// data-locality rule used during candidate selection
    pub rule: LocalityRule,
}

/// Per-point sweep output.
#[derive(Clone, Debug)]
pub struct SweepRow {
    /// workload name
    pub bench: String,
    /// display name of the evaluated configuration
    pub config_name: String,
    /// device technology of the evaluated configuration
    pub tech: crate::config::Technology,
    /// CiM placement of the evaluated configuration
    pub cim_levels: crate::config::CimLevels,
    /// memory-access conversion ratio accounting
    pub macr: Macr,
    /// committed instructions in the simulated trace
    pub committed: u64,
    /// simulated cycles
    pub cycles: u64,
    /// instructions removed from the host stream by offloading
    pub removed: u64,
    /// in-array CiM operations in the reshaped trace
    pub cim_ops: u64,
    /// profiler output (energy/speedup/breakdowns)
    pub result: ProfileResult,
}

/// Workload sizing + execution knobs for a sweep.
#[derive(Clone, Debug)]
pub struct SweepOptions {
    /// problem-size hint handed to the workload generators
    pub scale: usize,
    /// workload input RNG seed (part of the trace identity)
    pub seed: u64,
    /// simulator instruction budget per design point
    pub max_instructions: u64,
    /// worker-pool size for staging
    pub workers: usize,
    /// trace groups per work-stealing chunk (0 = auto-size from queue
    /// length)
    pub chunk: usize,
    /// decode-lane count for warm-trace replay (0 = auto: available
    /// parallelism, capped at 8).  `1` forces the sequential zero-copy
    /// path; any value produces byte-identical rows.  Deliberately *not*
    /// part of any cache key ([`key::point_key`] is field-selective).
    pub replay_threads: usize,
    /// root of the on-disk design-point + trace + artifact cache; `None`
    /// disables persistence entirely
    pub cache_dir: Option<PathBuf>,
    /// serve previously cached rows instead of recomputing them (writes
    /// happen whenever `cache_dir` is set, regardless of this flag)
    pub resume: bool,
    /// fsync store appends / spills before relying on them (the
    /// crash-consistency policy knob; default off — a lost tail line only
    /// costs a recompute).  Like `replay_threads`, deliberately *not*
    /// part of any cache key.
    pub fsync: bool,
}

impl Default for SweepOptions {
    fn default() -> Self {
        Self {
            scale: 0, // 0 = workload default
            seed: 42,
            max_instructions: 5_000_000,
            workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
                .min(8),
            chunk: 0,
            replay_threads: 0,
            cache_dir: None,
            resume: false,
            fsync: false,
        }
    }
}

/// What a sweep actually did — the cache-effectiveness and scale ledger.
#[derive(Clone, Copy, Debug, Default)]
pub struct SweepStats {
    /// total design points in the sweep
    pub points: usize,
    /// rows served from the on-disk result cache (no staging, no profiling)
    pub rows_from_cache: usize,
    /// rows staged + profiled in this run
    pub rows_computed: usize,
    /// actual cycle-level simulator invocations
    pub simulator_runs: u64,
    /// online analyses actually executed (one per uncached analysis key,
    /// *not* one per design point — the stage-factoring win)
    pub analyses_run: u64,
    /// analyses served from the artifact store / in-process memo
    pub analyses_cached: u64,
    /// staged design points that needed no trace replay or simulation of
    /// their own (they shared another point's pass or a cached artifact)
    pub replays_skipped: u64,
    /// traces replayed from the on-disk spill store
    pub trace_disk_hits: u64,
    /// spill chunks physically decoded during warm-trace replay (a
    /// worker-split group decodes its chunks once per pass, so this
    /// counts decode work, not unique chunks)
    pub replay_chunks_decoded: u64,
    /// analysis lanes that ran in worker-split replay passes instead of
    /// one sequential fan-out pass (nonzero proves the split path ran)
    pub replay_lanes_split: u64,
    /// work-stealing chunks claimed by the worker pool
    pub chunks_claimed: u64,
    /// largest online-analysis window over all staged points (instructions)
    pub peak_window: u64,
    /// longest trace analyzed (committed instructions)
    pub longest_trace: u64,
    /// process peak RSS in KiB at sweep end (0 when unavailable)
    pub peak_rss_kb: u64,
    /// candidate groups the offload planner accepted (plan runs only;
    /// sweeps don't plan and report 0)
    pub groups_accepted: u64,
    /// candidate groups the offload planner rejected
    pub groups_rejected: u64,
    /// summed offload-side energy (pJ) of the rejected groups — what the
    /// planner declined to spend
    pub rejected_energy_pj: f64,
    /// transient I/O operations retried (and resolved) during this run
    pub io_retries: u64,
    /// store entries quarantined during this run (undecodable JSONL
    /// lines, corrupt trace spills)
    pub entries_quarantined: u64,
    /// true when a store was unavailable and the run fell back to the
    /// in-memory memo only — answers are still correct, persistence is
    /// lost until the cache dir recovers
    pub degraded_mode: bool,
}

/// One-line human rendering of the interesting ledger entries, shared by
/// the `sweep` and `table` CLI paths.
pub fn format_stats(stats: &SweepStats, secs: f64) -> String {
    let mut line = format!(
        "{} design points in {:.2}s ({} cached, {} computed, {} simulated, \
         {} chunks) | stages: {} analyses run, {} cached, {} replays \
         skipped | replay: {} chunks decoded, {} lanes split | scale: \
         longest trace {} instrs, peak window {} \
         ({:.4}% of trace), peak RSS {} MiB",
        stats.points,
        secs,
        stats.rows_from_cache,
        stats.rows_computed,
        stats.simulator_runs,
        stats.chunks_claimed,
        stats.analyses_run,
        stats.analyses_cached,
        stats.replays_skipped,
        stats.replay_chunks_decoded,
        stats.replay_lanes_split,
        stats.longest_trace,
        stats.peak_window,
        if stats.longest_trace > 0 {
            stats.peak_window as f64 / stats.longest_trace as f64 * 100.0
        } else {
            0.0
        },
        stats.peak_rss_kb / 1024,
    );
    // the plan segment only appears when a planner actually judged groups
    // — sweep ledger lines are unchanged by the planner's existence
    if stats.groups_accepted > 0 || stats.groups_rejected > 0 {
        line.push_str(&format!(
            " | plan: {} groups accepted, {} rejected ({:.1} pJ declined)",
            stats.groups_accepted,
            stats.groups_rejected,
            stats.rejected_energy_pj,
        ));
    }
    // the fault segment only appears when something actually went wrong —
    // fault-free ledger lines are byte-identical to pre-hardening output
    if stats.io_retries > 0 || stats.entries_quarantined > 0 || stats.degraded_mode
    {
        line.push_str(&format!(
            " | faults: {} io retries, {} entries quarantined{}",
            stats.io_retries,
            stats.entries_quarantined,
            if stats.degraded_mode { ", degraded (in-memory only)" } else { "" },
        ));
    }
    line
}

/// Canonical JSON rendering of the sweep ledger (stderr companion of
/// [`format_stats`] for `--format json` runs — the report body itself
/// stays byte-stable cold-vs-cached, so the ledger never rides on it).
pub fn ledger_json(stats: &SweepStats, secs: f64, backend: Option<&str>) -> String {
    Json::obj(vec![
        ("ledger", "sweep".into()),
        ("points", (stats.points as u64).into()),
        ("rows_from_cache", (stats.rows_from_cache as u64).into()),
        ("rows_computed", (stats.rows_computed as u64).into()),
        ("simulator_runs", stats.simulator_runs.into()),
        ("analyses_run", stats.analyses_run.into()),
        ("analyses_cached", stats.analyses_cached.into()),
        ("replays_skipped", stats.replays_skipped.into()),
        ("trace_disk_hits", stats.trace_disk_hits.into()),
        ("replay_chunks_decoded", stats.replay_chunks_decoded.into()),
        ("replay_lanes_split", stats.replay_lanes_split.into()),
        ("chunks_claimed", stats.chunks_claimed.into()),
        ("peak_window", stats.peak_window.into()),
        ("longest_trace", stats.longest_trace.into()),
        ("peak_rss_kb", stats.peak_rss_kb.into()),
        ("groups_accepted", stats.groups_accepted.into()),
        ("groups_rejected", stats.groups_rejected.into()),
        ("rejected_energy_pj", stats.rejected_energy_pj.into()),
        ("io_retries", stats.io_retries.into()),
        ("entries_quarantined", stats.entries_quarantined.into()),
        ("degraded_mode", stats.degraded_mode.into()),
        ("elapsed_secs", secs.into()),
        ("backend", backend.unwrap_or("").into()),
    ])
    .dump()
}

/// Shared atomic counters the worker pool updates while staging.
#[derive(Default)]
struct StageCounters {
    simulator_runs: AtomicU64,
    analyses_run: AtomicU64,
    analyses_cached: AtomicU64,
    replays_skipped: AtomicU64,
    trace_disk_hits: AtomicU64,
    replay_chunks_decoded: AtomicU64,
    replay_lanes_split: AtomicU64,
    chunks_claimed: AtomicU64,
    peak_window: AtomicU64,
    longest_trace: AtomicU64,
    /// nonzero when a worker lost a store (spill/append failure) and the
    /// sweep kept going from memory — folded into
    /// [`SweepStats::degraded_mode`]
    degraded: AtomicU64,
}

/// All design points of one sweep that share one analysis artifact:
/// same trace, same CiM placement, same locality rule — they differ only
/// in technology (and config name), which the energy fold applies.
struct AnalysisGroup {
    akey: String,
    cim: CimLevels,
    rule: LocalityRule,
    /// positions into the sweep's `todo` list
    points: Vec<usize>,
}

/// All design points of one sweep that share one simulated trace.
struct TraceGroup {
    tkey: String,
    /// `todo` position of a representative point (bench + geometry for
    /// simulation and error labels)
    rep: usize,
    analyses: Vec<AnalysisGroup>,
}

/// One planned design point — everything [`Coordinator::run_plan`]
/// produces, memoized under its [`key::plan_key`] for the life of the
/// coordinator (the serving layer's warm path).
pub struct PlanArtifact {
    /// simulated-trace summary backing the plan
    pub summary: TraceSummary,
    /// streaming-analyzer outcome (MACR, rejection counters, peak window)
    pub outcome: StreamOutcome,
    /// the typed offload plan: every group's cost ledger and decision
    pub plan: crate::planner::OffloadPlan,
    /// reshape deltas folded from the *accepted* groups only — what the
    /// energy stage sees
    pub deltas: DeltaSink,
}

/// The sweep driver.
pub struct Coordinator {
    /// sizing/caching/worker-pool knobs for every sweep this driver runs
    pub opts: SweepOptions,
    /// analysis artifacts memoized for the life of this coordinator, so
    /// `--cache-dir`-less runs (and repeated sweeps on one driver) also
    /// dedupe the analysis stage
    memo: Mutex<HashMap<String, Arc<AnalysisArtifact>>>,
    /// plan artifacts memoized by [`key::plan_key`] — the plan stage's
    /// analogue of `memo` (plans are not persisted to disk: they replay
    /// from the spilled trace in milliseconds when cold)
    plan_memo: Mutex<HashMap<String, Arc<PlanArtifact>>>,
}

impl Coordinator {
    /// A driver with the given options.
    pub fn new(opts: SweepOptions) -> Self {
        Self {
            opts,
            memo: Mutex::new(HashMap::new()),
            plan_memo: Mutex::new(HashMap::new()),
        }
    }

    /// [`Coordinator::run_sweep_with_stats`], discarding the stats.
    pub fn run_sweep(
        &self,
        points: &[SweepPoint],
        backend: &mut dyn Backend,
    ) -> Result<Vec<SweepRow>> {
        Ok(self.run_sweep_with_stats(points, backend)?.0)
    }

    /// Resolve every point — from the result cache where possible, else
    /// by the stage-factored simulate → analyze → energy-fold pipeline —
    /// and report what was reused vs recomputed.
    pub fn run_sweep_with_stats(
        &self,
        points: &[SweepPoint],
        backend: &mut dyn Backend,
    ) -> Result<(Vec<SweepRow>, SweepStats)> {
        self.run_sweep_with_stats_using(points, &self.opts, backend)
    }

    /// [`Coordinator::run_sweep_with_stats`] with per-call options.
    ///
    /// This is the serving seam: a process-lifetime coordinator (one
    /// analysis memo, one set of on-disk stores) can run sweeps whose
    /// sizing knobs differ per request — `eva-cim serve` hands every
    /// request's options here while `self.opts` only provides the
    /// defaults.  Sharing the memo across heterogeneous options is safe
    /// because artifacts are looked up by [`key::analysis_key`], which
    /// already embeds every option that affects the analysis.
    pub fn run_sweep_with_stats_using(
        &self,
        points: &[SweepPoint],
        opts: &SweepOptions,
        backend: &mut dyn Backend,
    ) -> Result<(Vec<SweepRow>, SweepStats)> {
        let mut stats = SweepStats { points: points.len(), ..Default::default() };
        let io_before = faultio::counters();

        // A store that cannot open degrades the run to the in-memory memo
        // (warn once, flag the ledger) instead of erroring the sweep: an
        // unwritable cache dir must never take down a long-lived service.
        let mut degraded = false;
        let mut degrade = |what: &str, e: &anyhow::Error| {
            if !degraded {
                eprintln!(
                    "warning: {what} unavailable, continuing without \
                     persistence (degraded mode): {e:#}"
                );
            }
            degraded = true;
        };
        let result_cache = match &opts.cache_dir {
            Some(dir) => match ResultCache::open_with(dir, opts.fsync) {
                Ok(c) => Some(c),
                Err(e) => {
                    degrade("result cache", &e);
                    None
                }
            },
            None => None,
        };
        let traces = match &opts.cache_dir {
            Some(dir) => match TraceStore::open_with(&dir.join("traces"), opts.fsync)
            {
                Ok(t) => Some(t),
                Err(e) => {
                    degrade("trace store", &e);
                    None
                }
            },
            None => None,
        };
        let artifacts = match &opts.cache_dir {
            Some(dir) => {
                match AnalysisStore::open_with(&dir.join("analysis"), opts.fsync) {
                    Ok(s) => Some(s),
                    Err(e) => {
                        degrade("analysis store", &e);
                        None
                    }
                }
            }
            None => None,
        };

        let keys: Vec<String> = points
            .iter()
            .map(|p| key::point_key(p, opts, backend.name()))
            .collect();
        let mut slots: Vec<Option<SweepRow>> = vec![None; points.len()];

        if opts.resume {
            if let Some(c) = &result_cache {
                match c.load() {
                    Ok(existing) => {
                        for (slot, k) in slots.iter_mut().zip(&keys) {
                            if let Some(row) = existing.get(k) {
                                *slot = Some(row.clone());
                                stats.rows_from_cache += 1;
                            }
                        }
                    }
                    Err(e) => degrade("result-cache resume", &e),
                }
            }
        }

        let todo: Vec<usize> =
            (0..points.len()).filter(|&i| slots[i].is_none()).collect();
        stats.rows_computed = todo.len();
        let counters = StageCounters::default();

        if !todo.is_empty() {
            // re-plan the sweep: group points by trace, then by analysis
            // key — the scheduler's unit of work is one trace group
            let mut groups: Vec<TraceGroup> = Vec::new();
            {
                let mut by_tkey: HashMap<String, usize> = HashMap::new();
                for (ti, &pi) in todo.iter().enumerate() {
                    let p = &points[pi];
                    let tkey = key::trace_key(&p.bench, &p.config, opts);
                    let akey =
                        key::analysis_key(&tkey, p.config.cim_levels, p.rule);
                    let gi = match by_tkey.get(&tkey) {
                        Some(&gi) => gi,
                        None => {
                            by_tkey.insert(tkey.clone(), groups.len());
                            groups.push(TraceGroup {
                                tkey,
                                rep: ti,
                                analyses: Vec::new(),
                            });
                            groups.len() - 1
                        }
                    };
                    let g = &mut groups[gi];
                    match g.analyses.iter_mut().find(|a| a.akey == akey) {
                        Some(a) => a.points.push(ti),
                        None => g.analyses.push(AnalysisGroup {
                            akey,
                            cim: p.config.cim_levels,
                            rule: p.rule,
                            points: vec![ti],
                        }),
                    }
                }
            }

            // warm the in-process memo from the on-disk artifact store so
            // workers need a single lookup path.  Only this sweep's
            // analysis keys are deserialized (the store may hold the
            // history of many unrelated sweeps), and the file isn't
            // touched at all when the memo already covers every key.
            if let Some(store) = &artifacts {
                let wanted: std::collections::HashSet<String> = {
                    let memo = lock_unpoisoned(&self.memo);
                    groups
                        .iter()
                        .flat_map(|g| g.analyses.iter())
                        .filter(|a| !memo.contains_key(&a.akey))
                        .map(|a| a.akey.clone())
                        .collect()
                };
                if !wanted.is_empty() {
                    let loaded = store.load_wanted(&wanted)?;
                    let mut memo = lock_unpoisoned(&self.memo);
                    for (k, art) in loaded {
                        memo.entry(k).or_insert_with(|| Arc::new(art));
                    }
                }
            }

            // when the sweep has fewer trace groups than workers, the
            // surplus workers would idle while each group runs its K-lane
            // fan-out sequentially — tell every group how many concurrent
            // split passes the surplus could cover (1 = no split)
            let split_hint = if groups.len() < opts.workers.max(1) {
                opts.workers.max(1).div_ceil(groups.len().max(1))
            } else {
                1
            };

            let queue = ChunkQueue::new(groups.len(), opts.chunk, opts.workers);
            let staged: Mutex<Vec<Option<(SweepRow, ProfileInputs)>>> =
                Mutex::new((0..todo.len()).map(|_| None).collect());
            let errors: Mutex<Vec<String>> = Mutex::new(Vec::new());

            std::thread::scope(|scope| {
                for _ in 0..opts.workers.max(1) {
                    scope.spawn(|| {
                        while let Some(range) = queue.claim() {
                            counters.chunks_claimed.fetch_add(1, Ordering::Relaxed);
                            for gi in range {
                                let g = &groups[gi];
                                let rep = &points[todo[g.rep]];
                                // A panicking trace group must not take
                                // the pool down: contain it, report it as
                                // a sweep failure, and keep the other
                                // workers staging (the shared mutexes are
                                // poison-tolerant, see `lock_unpoisoned`).
                                let result = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(|| {
                                        Self::stage_group(
                                            points,
                                            &todo,
                                            g,
                                            opts,
                                            split_hint,
                                            &self.memo,
                                            artifacts.as_ref(),
                                            traces.as_ref(),
                                            &counters,
                                        )
                                    }),
                                );
                                match result {
                                    Ok(Ok(pairs)) => {
                                        let mut staged = lock_unpoisoned(&staged);
                                        for (ti, pair) in pairs {
                                            staged[ti] = Some(pair);
                                        }
                                    }
                                    Ok(Err(e)) => {
                                        lock_unpoisoned(&errors).push(format!(
                                            "{}: {e:#}",
                                            group_label(g, rep)
                                        ));
                                    }
                                    Err(payload) => {
                                        lock_unpoisoned(&errors).push(format!(
                                            "{}: worker panicked: {}",
                                            group_label(g, rep),
                                            panic_message(&payload)
                                        ));
                                    }
                                }
                            }
                        }
                    });
                }
            });

            let errors = errors.into_inner().unwrap_or_else(|p| p.into_inner());
            if !errors.is_empty() {
                return Err(anyhow!("sweep failures: {}", errors.join("; ")));
            }
            let staged: Vec<(SweepRow, ProfileInputs)> = staged
                .into_inner()
                .unwrap_or_else(|p| p.into_inner())
                .into_iter()
                // safety: every staging worker fills its own slot, and a
                // worker that failed instead pushed to `errors` — which
                // returned above
                .map(|o| o.expect("staged point missing"))
                .collect();

            // batched profiler evaluation (one PJRT execute per 256 points)
            let inputs: Vec<ProfileInputs> =
                staged.iter().map(|(_, i)| i.clone()).collect();
            let results = backend.evaluate_batch(&inputs)?;
            let mut append_warned = false;
            for ((pi, (mut row, _)), res) in
                todo.iter().copied().zip(staged).zip(results)
            {
                row.result = res;
                if let Some(c) = &result_cache {
                    // best-effort, like the trace spill: a full disk must
                    // not throw away rows that are already computed
                    if let Err(e) = c.append(&keys[pi], &row) {
                        if !append_warned {
                            eprintln!("warning: result-cache append failed: {e:#}");
                            append_warned = true;
                        }
                        degraded = true;
                    }
                }
                slots[pi] = Some(row);
            }
        }

        stats.simulator_runs = counters.simulator_runs.load(Ordering::Relaxed);
        stats.analyses_run = counters.analyses_run.load(Ordering::Relaxed);
        stats.analyses_cached = counters.analyses_cached.load(Ordering::Relaxed);
        stats.replays_skipped = counters.replays_skipped.load(Ordering::Relaxed);
        stats.trace_disk_hits = counters.trace_disk_hits.load(Ordering::Relaxed);
        stats.replay_chunks_decoded =
            counters.replay_chunks_decoded.load(Ordering::Relaxed);
        stats.replay_lanes_split =
            counters.replay_lanes_split.load(Ordering::Relaxed);
        stats.chunks_claimed = counters.chunks_claimed.load(Ordering::Relaxed);
        stats.peak_window = counters.peak_window.load(Ordering::Relaxed);
        stats.longest_trace = counters.longest_trace.load(Ordering::Relaxed);
        stats.peak_rss_kb = crate::util::stats::peak_rss_kb();
        stats.degraded_mode =
            degraded || counters.degraded.load(Ordering::Relaxed) > 0;
        let io_delta = faultio::counters().since(&io_before);
        stats.io_retries = io_delta.retries;
        stats.entries_quarantined = io_delta.quarantined;

        let rows = slots
            .into_iter()
            // safety: resume fills cached slots and every remaining index
            // is in `todo`, whose workers either filled the slot or pushed
            // an error — which returned above
            .map(|o| o.expect("sweep slot missing"))
            .collect();
        Ok((rows, stats))
    }

    /// Run the plan stage for one design point: simulate (or replay the
    /// spilled trace), stream candidates through a
    /// [`crate::planner::PlanSink`] judging every group with `policy` ×
    /// `knobs`, and memoize the resulting [`PlanArtifact`] under its
    /// [`key::plan_key`].
    ///
    /// The acquisition ladder mirrors [`Coordinator::stage_group`]:
    /// memo hit → warm-trace replay (multi-lane decode, same
    /// `replay_threads` budget) → pipelined simulate with a best-effort
    /// trace spill.  A plan run therefore *warms* the same trace store
    /// sweeps use, and vice versa — only the analysis lane differs (a
    /// planning sink instead of a bare delta sink).
    pub fn run_plan(
        &self,
        point: &SweepPoint,
        policy: crate::planner::PlanPolicy,
        knobs: &crate::planner::PlanKnobs,
        opts: &SweepOptions,
    ) -> Result<(Arc<PlanArtifact>, SweepStats)> {
        let mut stats = SweepStats { points: 1, ..Default::default() };
        let tkey = key::trace_key(&point.bench, &point.config, opts);
        let akey = key::analysis_key(&tkey, point.config.cim_levels, point.rule);
        let pkey = key::plan_key(&akey, &point.config, policy, knobs);

        if let Some(art) = lock_unpoisoned(&self.plan_memo).get(&pkey).cloned() {
            stats.rows_from_cache = 1;
            stats.analyses_cached = 1;
            stats.replays_skipped = 1;
            Self::fill_plan_stats(&mut stats, &art);
            return Ok((art, stats));
        }
        stats.rows_computed = 1;
        stats.analyses_run = 1;
        let io_before = faultio::counters();

        let disk = match &opts.cache_dir {
            Some(dir) => match TraceStore::open_with(&dir.join("traces"), opts.fsync)
            {
                Ok(t) => Some(t),
                Err(e) => {
                    eprintln!(
                        "warning: trace store unavailable, planning without \
                         persistence (degraded mode): {e:#}"
                    );
                    stats.degraded_mode = true;
                    None
                }
            },
            None => None,
        };
        let build_sink =
            || crate::planner::PlanSink::new(&point.config, policy, *knobs);

        // warm path: replay the spilled trace through one planning lane
        let mut replayed = None;
        if let Some(d) = &disk {
            let mut fanout = AnalyzerFanout::new(vec![OnlineAnalyzer::new(
                point.config.cim_levels,
                point.rule,
                build_sink(),
            )]);
            if let Some((summary, chunks)) =
                d.replay_with(&tkey, &mut fanout, effective_replay_threads(opts))
            {
                stats.trace_disk_hits = 1;
                stats.replay_chunks_decoded = chunks;
                // safety: the fanout above was built from exactly one
                // sink, so finish() returns exactly one lane
                let lane = fanout.finish().pop().expect("one planning lane");
                replayed = Some((summary, lane.0, lane.1));
            }
        }

        // cold path: pipelined simulate + plan, teeing the trace to disk
        let (summary, outcome, sink) = match replayed {
            Some(x) => x,
            None => {
                let prog = workloads::build(&point.bench, opts.scale, opts.seed)
                    .ok_or_else(|| {
                        anyhow!("unknown benchmark '{}'", point.bench)
                    })?;
                stats.simulator_runs = 1;
                let limits = Limits { max_instructions: opts.max_instructions };
                // best-effort spill, same contract as `stage_group`
                let mut spill = match disk.as_ref().map(|d| d.writer(&tkey)) {
                    Some(Ok(w)) => Some(w),
                    Some(Err(e)) => {
                        eprintln!("warning: trace spill failed: {e:#}");
                        stats.degraded_mode = true;
                        None
                    }
                    None => None,
                };
                let (summary, outcome, sink) = pipeline::run_pipelined(
                    &prog,
                    &point.config,
                    limits,
                    point.rule,
                    build_sink(),
                    spill.as_mut().map(|s| {
                        s as &mut (dyn crate::probes::TraceSink + Send)
                    }),
                )?;
                if let Some(w) = spill {
                    if let Err(e) = w.finish(&summary) {
                        eprintln!("warning: trace spill failed: {e:#}");
                        stats.degraded_mode = true;
                    }
                }
                (summary, outcome, sink)
            }
        };

        let (plan, deltas) = sink.finish();
        let art = Arc::new(PlanArtifact { summary, outcome, plan, deltas });
        Self::fill_plan_stats(&mut stats, &art);
        let io_delta = faultio::counters().since(&io_before);
        stats.io_retries = io_delta.retries;
        stats.entries_quarantined = io_delta.quarantined;
        lock_unpoisoned(&self.plan_memo).insert(pkey, Arc::clone(&art));
        Ok((art, stats))
    }

    /// Plan-derived ledger fields shared by the memo-hit and computed
    /// paths of [`Coordinator::run_plan`].
    fn fill_plan_stats(stats: &mut SweepStats, art: &PlanArtifact) {
        stats.groups_accepted = art.plan.groups_accepted();
        stats.groups_rejected = art.plan.groups_rejected();
        stats.rejected_energy_pj = art.plan.rejected_energy_pj();
        stats.peak_window = art.outcome.peak_window as u64;
        stats.longest_trace = art.summary.committed;
        stats.peak_rss_kb = crate::util::stats::peak_rss_kb();
    }

    /// Stage one trace group through the factored pipeline.
    ///
    /// Artifact acquisition, cheapest first:
    /// 1. the in-process memo (pre-warmed from the on-disk artifact
    ///    store) — no replay, no analysis;
    /// 2. replay the spilled trace through a broadcast fan-out feeding
    ///    every still-missing analysis — as one multi-lane-decode pass,
    ///    or (when `split > 1` says workers are idle and the spill is
    ///    warm) as `split` concurrent passes each feeding a subset of
    ///    the analysis lanes;
    /// 3. simulate, pipelined: the simulator runs on its own thread while
    ///    this thread drives one full fan-out, teeing records into a
    ///    chunked disk spill when a cache dir is set.
    ///
    /// Every point then pays only the per-technology energy fold.
    #[allow(clippy::too_many_arguments)]
    fn stage_group(
        points: &[SweepPoint],
        todo: &[usize],
        group: &TraceGroup,
        opts: &SweepOptions,
        split: usize,
        memo: &Mutex<HashMap<String, Arc<AnalysisArtifact>>>,
        artifacts: Option<&AnalysisStore>,
        disk: Option<&TraceStore>,
        counters: &StageCounters,
    ) -> Result<Vec<(usize, (SweepRow, ProfileInputs))>> {
        // 1) memo lookup per analysis key
        let mut resolved: Vec<Option<Arc<AnalysisArtifact>>> =
            Vec::with_capacity(group.analyses.len());
        {
            let memo = lock_unpoisoned(memo);
            for a in &group.analyses {
                resolved.push(memo.get(&a.akey).cloned());
            }
        }
        let missing: Vec<usize> = (0..group.analyses.len())
            .filter(|&ai| resolved[ai].is_none())
            .collect();
        counters
            .analyses_cached
            .fetch_add((group.analyses.len() - missing.len()) as u64, Ordering::Relaxed);

        let staged_points: u64 =
            group.analyses.iter().map(|a| a.points.len() as u64).sum();
        let mut passes = 0u64;

        if !missing.is_empty() {
            counters
                .analyses_run
                .fetch_add(missing.len() as u64, Ordering::Relaxed);
            let rep = &points[todo[group.rep]];
            let build_fanout = || {
                AnalyzerFanout::new(
                    missing
                        .iter()
                        .map(|&ai| {
                            let a = &group.analyses[ai];
                            OnlineAnalyzer::new(a.cim, a.rule, DeltaSink::default())
                        })
                        .collect(),
                )
            };

            // 2) disk replay, worker-split when the scheduler says the
            // pool is otherwise idle and the spill is warm: each pass
            // replays the trace through its own lane subset concurrently
            let threads = effective_replay_threads(opts);
            let mut replayed: Option<(TraceSummary, Vec<_>)> = None;
            if let Some(d) = disk {
                if split > 1 && missing.len() > 1 && d.contains(&group.tkey) {
                    if let Some((summary, lanes, chunks)) =
                        Self::replay_split(d, group, &missing, split, threads)
                    {
                        counters.trace_disk_hits.fetch_add(1, Ordering::Relaxed);
                        counters
                            .replay_chunks_decoded
                            .fetch_add(chunks, Ordering::Relaxed);
                        counters
                            .replay_lanes_split
                            .fetch_add(missing.len() as u64, Ordering::Relaxed);
                        replayed = Some((summary, lanes));
                    }
                }
                if replayed.is_none() {
                    let mut fanout = build_fanout();
                    if let Some((summary, chunks)) =
                        d.replay_with(&group.tkey, &mut fanout, threads)
                    {
                        counters.trace_disk_hits.fetch_add(1, Ordering::Relaxed);
                        counters
                            .replay_chunks_decoded
                            .fetch_add(chunks, Ordering::Relaxed);
                        replayed = Some((summary, fanout.finish()));
                    }
                    // corrupt/missing spill: the fan-out may have consumed
                    // partial records — discard it and simulate with a
                    // fresh one below
                }
            }

            // 3) pipelined simulate + fan-out analyze
            let (summary, lanes) = match replayed {
                Some(x) => x,
                None => {
                    let prog = workloads::build(&rep.bench, opts.scale, opts.seed)
                        .ok_or_else(|| {
                            anyhow!("unknown benchmark '{}'", rep.bench)
                        })?;
                    counters.simulator_runs.fetch_add(1, Ordering::Relaxed);
                    let limits =
                        Limits { max_instructions: opts.max_instructions };
                    // best-effort spill: a full disk must not fail the
                    // sweep, only future reuse
                    let mut spill = match disk.map(|d| d.writer(&group.tkey)) {
                        Some(Ok(w)) => Some(w),
                        Some(Err(e)) => {
                            eprintln!("warning: trace spill failed: {e:#}");
                            counters.degraded.fetch_add(1, Ordering::Relaxed);
                            None
                        }
                        None => None,
                    };
                    let (summary, lanes) = pipeline::run_pipelined_fanout(
                        &prog,
                        &rep.config,
                        limits,
                        build_fanout(),
                        spill
                            .as_mut()
                            .map(|s| s as &mut (dyn crate::probes::TraceSink + Send)),
                    )?;
                    if let Some(w) = spill {
                        if let Err(e) = w.finish(&summary) {
                            eprintln!("warning: trace spill failed: {e:#}");
                            counters.degraded.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    (summary, lanes)
                }
            };
            passes = 1;

            // publish the new artifacts: disk appends (best-effort, with
            // their own writer lock) happen BEFORE taking the memo lock,
            // so other workers' stage-1 lookups never stall behind I/O
            let new_arts: Vec<(usize, Arc<AnalysisArtifact>)> = missing
                .iter()
                .copied()
                .zip(lanes)
                .map(|(ai, (outcome, deltas))| {
                    let art = Arc::new(AnalysisArtifact::new(
                        summary.clone(),
                        outcome,
                        deltas,
                    ));
                    (ai, art)
                })
                .collect();
            if let Some(store) = artifacts {
                let mut append_warned = false;
                for (ai, art) in &new_arts {
                    if let Err(e) = store.append(&group.analyses[*ai].akey, art) {
                        if !append_warned {
                            eprintln!(
                                "warning: analysis-store append failed: {e:#}"
                            );
                            append_warned = true;
                        }
                        counters.degraded.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            let mut memo = lock_unpoisoned(memo);
            for (ai, art) in new_arts {
                memo.insert(group.analyses[ai].akey.clone(), Arc::clone(&art));
                resolved[ai] = Some(art);
            }
        }
        counters
            .replays_skipped
            .fetch_add(staged_points - passes, Ordering::Relaxed);

        // 4) per-point energy fold — the only per-technology work
        let mut out = Vec::with_capacity(staged_points as usize);
        for (a, art) in group.analyses.iter().zip(&resolved) {
            // safety: the resolve loop above ran every analysis index and
            // bailed out on failure, so each entry is Some here
            let art = art.as_ref().expect("artifact resolved above");
            for &ti in &a.points {
                let p = &points[todo[ti]];
                out.push((ti, Self::fold_energy(p, art, counters)));
            }
        }
        Ok(out)
    }

    /// Replay one warm spill as concurrent worker-split passes, each
    /// feeding a contiguous subset of the group's missing analysis lanes
    /// through its own fan-out.  Lane results come back in `missing`
    /// order — indistinguishable from one sequential full-fan-out pass.
    /// Returns `None` (fall back to the normal ladder) if any pass finds
    /// the spill missing or corrupt; the decode-lane budget `threads` is
    /// divided across the passes so the two parallelism axes compose
    /// instead of multiplying.
    fn replay_split(
        disk: &TraceStore,
        group: &TraceGroup,
        missing: &[usize],
        split: usize,
        threads: usize,
    ) -> Option<(TraceSummary, Vec<(StreamOutcome, DeltaSink)>, u64)> {
        let passes = split.min(missing.len());
        let per_pass = missing.len().div_ceil(passes);
        let pass_threads = (threads / passes).max(1);
        let results: Vec<Option<_>> = std::thread::scope(|scope| {
            let handles: Vec<_> = missing
                .chunks(per_pass)
                .map(|subset| {
                    scope.spawn(move || {
                        let mut fanout = AnalyzerFanout::new(
                            subset
                                .iter()
                                .map(|&ai| {
                                    let a = &group.analyses[ai];
                                    OnlineAnalyzer::new(
                                        a.cim,
                                        a.rule,
                                        DeltaSink::default(),
                                    )
                                })
                                .collect(),
                        );
                        let (summary, chunks) = disk.replay_with(
                            &group.tkey,
                            &mut fanout,
                            pass_threads,
                        )?;
                        Some((summary, fanout.finish(), chunks))
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| match h.join() {
                    Ok(r) => r,
                    // re-raise into the caller's panic containment
                    Err(payload) => std::panic::resume_unwind(payload),
                })
                .collect()
        });
        let mut summary: Option<TraceSummary> = None;
        let mut lanes = Vec::with_capacity(missing.len());
        let mut chunks = 0u64;
        for pass in results {
            let (s, pass_lanes, pass_chunks) = pass?;
            summary.get_or_insert(s);
            lanes.extend(pass_lanes);
            chunks += pass_chunks;
        }
        Some((summary?, lanes, chunks))
    }

    /// Fold a shared analysis artifact into one point's sweep row +
    /// profiler inputs (stage 3: the per-technology energy fold).
    fn fold_energy(
        p: &SweepPoint,
        art: &AnalysisArtifact,
        counters: &StageCounters,
    ) -> (SweepRow, ProfileInputs) {
        counters
            .peak_window
            .fetch_max(art.outcome.peak_window as u64, Ordering::Relaxed);
        counters
            .longest_trace
            .fetch_max(art.summary.committed, Ordering::Relaxed);
        let reshaped = reshape_from_deltas(&art.summary, &art.deltas, &p.config);
        let inputs = ProfileInputs::new(&p.config, &reshaped);
        let row = SweepRow {
            bench: p.bench.clone(),
            config_name: p.config.name.clone(),
            tech: p.config.tech,
            cim_levels: p.config.cim_levels,
            macr: art.outcome.macr,
            committed: art.summary.committed,
            cycles: art.summary.cycles,
            removed: reshaped.removed,
            cim_ops: reshaped.cim_op_count,
            result: ProfileResult::default(),
        };
        (row, inputs)
    }
}

/// Resolve [`SweepOptions::replay_threads`]: an explicit setting wins,
/// `0` mirrors the worker-pool auto-sizing (available parallelism,
/// capped at 8).
fn effective_replay_threads(opts: &SweepOptions) -> usize {
    if opts.replay_threads > 0 {
        opts.replay_threads
    } else {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(8)
    }
}

/// Error label for a failed trace group: since one pass serves many
/// design points, name the representative point *and* enumerate the
/// placement/rule lanes so a failing analysis can be narrowed down
/// without re-running points one by one.
fn group_label(g: &TraceGroup, rep: &SweepPoint) -> String {
    let points: usize = g.analyses.iter().map(|a| a.points.len()).sum();
    let lanes: Vec<String> = g
        .analyses
        .iter()
        .map(|a| format!("{}/{}", a.cim.name(), a.rule.name()))
        .collect();
    format!(
        "{}/{} (trace group: {points} points; analyses: {})",
        rep.bench,
        rep.config.name,
        lanes.join(", ")
    )
}

/// Best-effort rendering of a contained worker panic payload (shared with
/// the serving layer's request-handler containment).
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".into()
    }
}

/// Cartesian-product helper: benches × configs, one point each.
pub fn cross(
    benches: &[&str],
    configs: &[SystemConfig],
    rule: LocalityRule,
) -> Vec<SweepPoint> {
    let mut points = Vec::new();
    for b in benches {
        for c in configs {
            points.push(SweepPoint {
                bench: b.to_string(),
                config: c.clone(),
                rule,
            });
        }
    }
    points
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;

    #[test]
    fn sweep_two_benches_two_configs_native() {
        let cfgs = [
            SystemConfig::preset("c1").unwrap(),
            SystemConfig::preset("c2").unwrap(),
        ];
        let points = cross(&["lcs", "kmeans"], &cfgs, LocalityRule::AnyCache);
        let coord = Coordinator::new(SweepOptions {
            scale: 8,
            workers: 2,
            ..Default::default()
        });
        let (rows, stats) = coord
            .run_sweep_with_stats(&points, &mut NativeBackend)
            .unwrap();
        assert_eq!(rows.len(), 4);
        for r in rows {
            assert!(r.committed > 0);
            assert!(r.result.total_base > 0.0);
            assert!(r.result.improvement > 0.0);
        }
        // no cache dir: everything computed, nothing reused from disk —
        // four distinct traces, one analysis each
        assert_eq!(stats.rows_from_cache, 0);
        assert_eq!(stats.rows_computed, 4);
        assert_eq!(stats.simulator_runs, 4);
        assert_eq!(stats.analyses_run, 4);
        assert_eq!(stats.analyses_cached, 0);
        assert_eq!(stats.replays_skipped, 0);
        assert_eq!(stats.trace_disk_hits, 0);
        assert!(stats.chunks_claimed >= 1);
    }

    #[test]
    fn tech_variants_share_one_simulation_and_one_analysis() {
        // same bench + geometry + placement, two tech variants -> one
        // simulation AND one analysis; the second point only re-folds
        // energy
        let mut fefet = SystemConfig::preset("c1").unwrap();
        fefet.tech = crate::config::Technology::FEFET;
        fefet.name = "c1-fefet".into();
        let points = cross(
            &["lcs"],
            &[SystemConfig::preset("c1").unwrap(), fefet],
            LocalityRule::AnyCache,
        );
        let coord = Coordinator::new(SweepOptions {
            scale: 4,
            workers: 1,
            ..Default::default()
        });
        let (rows, stats) = coord
            .run_sweep_with_stats(&points, &mut NativeBackend)
            .unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(stats.simulator_runs, 1);
        assert_eq!(stats.analyses_run, 1);
        assert_eq!(stats.replays_skipped, 1);

        // a second sweep on the same driver hits the in-process memo even
        // without a cache dir: no simulation, no analysis, pure fold
        let (rows2, stats2) = coord
            .run_sweep_with_stats(&points, &mut NativeBackend)
            .unwrap();
        assert_eq!(rows2.len(), 2);
        assert_eq!(stats2.simulator_runs, 0);
        assert_eq!(stats2.analyses_run, 0);
        assert_eq!(stats2.analyses_cached, 1);
        assert_eq!(stats2.replays_skipped, 2);
        for (a, b) in rows.iter().zip(&rows2) {
            assert_eq!(
                persist::row_to_json(a).dump(),
                persist::row_to_json(b).dump(),
                "memoized artifacts must fold to identical rows"
            );
        }
    }

    #[test]
    fn placement_variants_fan_out_of_one_replay() {
        // one trace, three placements: one simulation, three analyses in
        // a single broadcast pass
        let base = SystemConfig::preset("c1").unwrap();
        let cfgs: Vec<SystemConfig> = [
            crate::config::CimLevels::L1Only,
            crate::config::CimLevels::L2Only,
            crate::config::CimLevels::Both,
        ]
        .into_iter()
        .map(|cim| {
            let mut c = base.clone().with_cim(cim);
            c.name = format!("c1-{}", cim.name());
            c
        })
        .collect();
        let points = cross(&["lcs"], &cfgs, LocalityRule::AnyCache);
        let coord = Coordinator::new(SweepOptions {
            scale: 4,
            workers: 2,
            ..Default::default()
        });
        let (rows, stats) = coord
            .run_sweep_with_stats(&points, &mut NativeBackend)
            .unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(stats.simulator_runs, 1);
        assert_eq!(stats.analyses_run, 3);
        assert_eq!(stats.replays_skipped, 2);
    }

    #[test]
    fn unknown_bench_errors() {
        let points = cross(
            &["no_such_bench"],
            &[SystemConfig::default()],
            LocalityRule::AnyCache,
        );
        let coord =
            Coordinator::new(SweepOptions { workers: 1, ..Default::default() });
        assert!(coord.run_sweep(&points, &mut NativeBackend).is_err());
    }

    #[test]
    fn run_plan_memoizes_and_matches_sweep_deltas() {
        use crate::planner::{PlanKnobs, PlanPolicy};

        let point = SweepPoint {
            bench: "lcs".into(),
            config: SystemConfig::preset("c1").unwrap(),
            rule: LocalityRule::AnyCache,
        };
        let coord = Coordinator::new(SweepOptions {
            scale: 4,
            workers: 1,
            ..Default::default()
        });
        let knobs = PlanKnobs::default();
        let (art, stats) = coord
            .run_plan(&point, PlanPolicy::AcceptAll, &knobs, &coord.opts)
            .unwrap();
        assert_eq!(stats.simulator_runs, 1);
        assert_eq!(stats.analyses_run, 1);
        assert_eq!(stats.groups_rejected, 0);
        assert_eq!(
            stats.groups_accepted,
            art.plan.groups_accepted(),
            "ledger counters mirror the plan"
        );
        assert!(art.summary.committed > 0);

        // accept-all planning folds the same deltas a sweep's bare
        // analysis produces — the identity contract, at the artifact level
        let (rows, _) = coord
            .run_sweep_with_stats(std::slice::from_ref(&point), &mut NativeBackend)
            .unwrap();
        assert_eq!(rows[0].removed, {
            let reshaped = reshape_from_deltas(&art.summary, &art.deltas, &point.config);
            reshaped.removed
        });

        // second plan: pure memo hit, counters say so
        let (art2, stats2) = coord
            .run_plan(&point, PlanPolicy::AcceptAll, &knobs, &coord.opts)
            .unwrap();
        assert_eq!(stats2.simulator_runs, 0);
        assert_eq!(stats2.analyses_run, 0);
        assert_eq!(stats2.rows_from_cache, 1);
        assert!(Arc::ptr_eq(&art, &art2));

        // a different policy is a different plan key — recomputed, and the
        // profitability default knobs reject at least the 1-op groups
        let (art3, stats3) = coord
            .run_plan(
                &point,
                PlanPolicy::Profitability,
                &PlanPolicy::Profitability.default_knobs(),
                &coord.opts,
            )
            .unwrap();
        assert_eq!(stats3.rows_computed, 1);
        assert_eq!(
            art3.plan.groups_accepted() + art3.plan.groups_rejected(),
            art.plan.groups_accepted(),
            "both plans judged the same candidate stream"
        );
    }
}
