//! The L3 coordinator: design-space-exploration sweeps.
//!
//! The coordinator is the leader of a worker pool: simulation + analysis +
//! reshaping jobs (CPU-bound, trace-heavy) fan out across `std::thread`
//! workers that pull deterministic point-chunks from a shared
//! work-stealing queue ([`shard`]).  Each point runs the *streaming*
//! pipeline: a simulator thread commits I-states into a bounded channel
//! and the online analyzer folds them into reshape deltas on the fly
//! ([`crate::pipeline`]), so peak memory per point is O(analysis window),
//! not O(trace).  With a cache directory, traces spill to disk in chunks
//! through the same sink interface ([`trace_store`]) and later
//! technology/placement variants *replay* them chunk-by-chunk — across
//! processes; without one, the legacy in-memory memo keeps materialized
//! traces so variants still share one simulation.  Completed design
//! points are persisted to an append-only JSONL result cache ([`cache`])
//! keyed by a stable content hash ([`key`]) of `(bench, scale, seed,
//! SystemConfig, LocalityRule, backend)`.
//! A resumed sweep — or any superset of a prior sweep — recomputes only
//! the missing points and returns rows byte-identical to a cold run
//! ([`persist`] keeps the serialization canonical).
//!
//! Surviving design points are *batched* into PJRT executions of the
//! AOT'd profiler graph (256 points per call, padded).  This is the
//! paper's tool-chain glue (Fig 1) turned into a runtime: one `sweep`
//! call regenerates any of Figs 13–16 / Table VI.

pub mod cache;
pub mod key;
pub mod persist;
pub mod shard;
pub mod trace_store;

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::analyzer::{LocalityRule, Macr, OnlineAnalyzer, StreamOutcome};
use crate::config::SystemConfig;
use crate::pipeline;
use crate::probes::{CollectSink, Trace, TraceSummary};
use crate::profiler::{ProfileInputs, ProfileResult};
use crate::reshape::{reshape_from_deltas, DeltaSink};
use crate::runtime::Backend;
use crate::sim::Limits;
use crate::util::lock_unpoisoned;
use crate::workloads;

use cache::ResultCache;
use shard::ChunkQueue;
use trace_store::TraceStore;

/// One design point of a sweep.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    /// workload name (see `workloads::NAMES`)
    pub bench: String,
    /// full system configuration (geometry, tech, placement)
    pub config: SystemConfig,
    /// data-locality rule used during candidate selection
    pub rule: LocalityRule,
}

/// Per-point sweep output.
#[derive(Clone, Debug)]
pub struct SweepRow {
    /// workload name
    pub bench: String,
    /// display name of the evaluated configuration
    pub config_name: String,
    /// device technology of the evaluated configuration
    pub tech: crate::config::Technology,
    /// CiM placement of the evaluated configuration
    pub cim_levels: crate::config::CimLevels,
    /// memory-access conversion ratio accounting
    pub macr: Macr,
    /// committed instructions in the simulated trace
    pub committed: u64,
    /// simulated cycles
    pub cycles: u64,
    /// instructions removed from the host stream by offloading
    pub removed: u64,
    /// in-array CiM operations in the reshaped trace
    pub cim_ops: u64,
    /// profiler output (energy/speedup/breakdowns)
    pub result: ProfileResult,
}

/// Workload sizing + execution knobs for a sweep.
#[derive(Clone, Debug)]
pub struct SweepOptions {
    /// problem-size hint handed to the workload generators
    pub scale: usize,
    /// workload input RNG seed (part of the trace identity)
    pub seed: u64,
    /// simulator instruction budget per design point
    pub max_instructions: u64,
    /// worker-pool size for staging
    pub workers: usize,
    /// points per work-stealing chunk (0 = auto-size from queue length)
    pub chunk: usize,
    /// root of the on-disk design-point + trace cache; `None` disables
    /// persistence entirely
    pub cache_dir: Option<PathBuf>,
    /// serve previously cached rows instead of recomputing them (writes
    /// happen whenever `cache_dir` is set, regardless of this flag)
    pub resume: bool,
}

impl Default for SweepOptions {
    fn default() -> Self {
        Self {
            scale: 0, // 0 = workload default
            seed: 42,
            max_instructions: 5_000_000,
            workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
                .min(8),
            chunk: 0,
            cache_dir: None,
            resume: false,
        }
    }
}

/// What a sweep actually did — the cache-effectiveness and scale ledger.
#[derive(Clone, Copy, Debug, Default)]
pub struct SweepStats {
    /// total design points in the sweep
    pub points: usize,
    /// rows served from the on-disk result cache (no staging, no profiling)
    pub rows_from_cache: usize,
    /// rows staged + profiled in this run
    pub rows_computed: usize,
    /// actual cycle-level simulator invocations
    pub simulator_runs: u64,
    /// traces served from the in-process memo
    pub trace_mem_hits: u64,
    /// traces served from the on-disk spill store
    pub trace_disk_hits: u64,
    /// work-stealing chunks claimed by the worker pool
    pub chunks_claimed: u64,
    /// largest online-analysis window over all staged points (instructions)
    pub peak_window: u64,
    /// longest trace analyzed (committed instructions)
    pub longest_trace: u64,
    /// process peak RSS in KiB at sweep end (0 when unavailable)
    pub peak_rss_kb: u64,
}

/// One-line human rendering of the interesting ledger entries, shared by
/// the `sweep` and `table` CLI paths.
pub fn format_stats(stats: &SweepStats, secs: f64) -> String {
    format!(
        "{} design points in {:.2}s ({} cached, {} computed, {} simulated, \
         {} chunks) | scale: longest trace {} instrs, peak window {} \
         ({:.4}% of trace), peak RSS {} MiB",
        stats.points,
        secs,
        stats.rows_from_cache,
        stats.rows_computed,
        stats.simulator_runs,
        stats.chunks_claimed,
        stats.longest_trace,
        stats.peak_window,
        if stats.longest_trace > 0 {
            stats.peak_window as f64 / stats.longest_trace as f64 * 100.0
        } else {
            0.0
        },
        stats.peak_rss_kb / 1024,
    )
}

/// Shared atomic counters the worker pool updates while staging.
#[derive(Default)]
struct StageCounters {
    simulator_runs: AtomicU64,
    trace_mem_hits: AtomicU64,
    trace_disk_hits: AtomicU64,
    chunks_claimed: AtomicU64,
    peak_window: AtomicU64,
    longest_trace: AtomicU64,
}

/// The sweep driver.
pub struct Coordinator {
    /// sizing/caching/worker-pool knobs for every sweep this driver runs
    pub opts: SweepOptions,
}

impl Coordinator {
    /// A driver with the given options.
    pub fn new(opts: SweepOptions) -> Self {
        Self { opts }
    }

    /// [`Coordinator::run_sweep_with_stats`], discarding the stats.
    pub fn run_sweep(
        &self,
        points: &[SweepPoint],
        backend: &mut dyn Backend,
    ) -> Result<Vec<SweepRow>> {
        Ok(self.run_sweep_with_stats(points, backend)?.0)
    }

    /// Resolve every point — from the result cache where possible, else by
    /// simulate → analyze → reshape → batched profiler evaluation — and
    /// report what was reused vs recomputed.
    pub fn run_sweep_with_stats(
        &self,
        points: &[SweepPoint],
        backend: &mut dyn Backend,
    ) -> Result<(Vec<SweepRow>, SweepStats)> {
        let opts = &self.opts;
        let mut stats = SweepStats { points: points.len(), ..Default::default() };

        let result_cache = match &opts.cache_dir {
            Some(dir) => Some(ResultCache::open(dir)?),
            None => None,
        };
        let traces = match &opts.cache_dir {
            Some(dir) => Some(TraceStore::open(&dir.join("traces"))?),
            None => None,
        };

        let keys: Vec<String> = points
            .iter()
            .map(|p| key::point_key(p, opts, backend.name()))
            .collect();
        let mut slots: Vec<Option<SweepRow>> = vec![None; points.len()];

        if opts.resume {
            if let Some(c) = &result_cache {
                let existing = c.load()?;
                for (slot, k) in slots.iter_mut().zip(&keys) {
                    if let Some(row) = existing.get(k) {
                        *slot = Some(row.clone());
                        stats.rows_from_cache += 1;
                    }
                }
            }
        }

        let todo: Vec<usize> =
            (0..points.len()).filter(|&i| slots[i].is_none()).collect();
        stats.rows_computed = todo.len();
        let counters = StageCounters::default();

        if !todo.is_empty() {
            let queue = ChunkQueue::new(todo.len(), opts.chunk, opts.workers);
            let memo: Mutex<HashMap<String, Arc<Trace>>> = Mutex::new(HashMap::new());
            let staged: Mutex<Vec<Option<(SweepRow, ProfileInputs)>>> =
                Mutex::new((0..todo.len()).map(|_| None).collect());
            let errors: Mutex<Vec<String>> = Mutex::new(Vec::new());

            std::thread::scope(|scope| {
                for _ in 0..opts.workers.max(1) {
                    scope.spawn(|| {
                        while let Some(range) = queue.claim() {
                            counters.chunks_claimed.fetch_add(1, Ordering::Relaxed);
                            for ti in range {
                                let p = &points[todo[ti]];
                                // A panicking design point must not take
                                // the pool down: contain it, report it as
                                // a sweep failure, and keep the other
                                // workers staging (the shared mutexes are
                                // poison-tolerant, see `lock_unpoisoned`).
                                let result = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(|| {
                                        Self::stage_point(
                                            p,
                                            opts,
                                            &memo,
                                            traces.as_ref(),
                                            &counters,
                                        )
                                    }),
                                );
                                match result {
                                    Ok(Ok(pair)) => {
                                        lock_unpoisoned(&staged)[ti] = Some(pair);
                                    }
                                    Ok(Err(e)) => {
                                        lock_unpoisoned(&errors).push(format!(
                                            "{}/{}: {e:#}",
                                            p.bench, p.config.name
                                        ));
                                    }
                                    Err(payload) => {
                                        lock_unpoisoned(&errors).push(format!(
                                            "{}/{}: worker panicked: {}",
                                            p.bench,
                                            p.config.name,
                                            panic_message(&payload)
                                        ));
                                    }
                                }
                            }
                        }
                    });
                }
            });

            let errors = errors.into_inner().unwrap_or_else(|p| p.into_inner());
            if !errors.is_empty() {
                return Err(anyhow!("sweep failures: {}", errors.join("; ")));
            }
            let staged: Vec<(SweepRow, ProfileInputs)> = staged
                .into_inner()
                .unwrap_or_else(|p| p.into_inner())
                .into_iter()
                .map(|o| o.expect("staged point missing"))
                .collect();

            // batched profiler evaluation (one PJRT execute per 256 points)
            let inputs: Vec<ProfileInputs> =
                staged.iter().map(|(_, i)| i.clone()).collect();
            let results = backend.evaluate_batch(&inputs)?;
            let mut append_warned = false;
            for ((ti, (mut row, _)), res) in
                todo.iter().copied().zip(staged).zip(results)
            {
                row.result = res;
                if let Some(c) = &result_cache {
                    // best-effort, like the trace spill: a full disk must
                    // not throw away rows that are already computed
                    if let Err(e) = c.append(&keys[ti], &row) {
                        if !append_warned {
                            eprintln!("warning: result-cache append failed: {e:#}");
                            append_warned = true;
                        }
                    }
                }
                slots[ti] = Some(row);
            }
        }

        stats.simulator_runs = counters.simulator_runs.load(Ordering::Relaxed);
        stats.trace_mem_hits = counters.trace_mem_hits.load(Ordering::Relaxed);
        stats.trace_disk_hits = counters.trace_disk_hits.load(Ordering::Relaxed);
        stats.chunks_claimed = counters.chunks_claimed.load(Ordering::Relaxed);
        stats.peak_window = counters.peak_window.load(Ordering::Relaxed);
        stats.longest_trace = counters.longest_trace.load(Ordering::Relaxed);
        stats.peak_rss_kb = crate::util::stats::peak_rss_kb();

        let rows = slots
            .into_iter()
            .map(|o| o.expect("sweep slot missing"))
            .collect();
        Ok((rows, stats))
    }

    /// Stage one design point through the streaming pipeline.
    ///
    /// Trace acquisition, cheapest first:
    /// 1. the in-memory memo (populated only when no cache dir is set) —
    ///    stream-analyze the materialized CIQ in place;
    /// 2. the on-disk spill store — *replay* the trace chunk-by-chunk
    ///    into the online analyzer, never materializing it;
    /// 3. simulate, pipelined: the simulator runs on its own thread while
    ///    this thread analyzes, teeing records into a chunked disk spill
    ///    (with a cache dir) or a collect sink feeding the memo (without).
    fn stage_point(
        p: &SweepPoint,
        opts: &SweepOptions,
        memo: &Mutex<HashMap<String, Arc<Trace>>>,
        disk: Option<&TraceStore>,
        counters: &StageCounters,
    ) -> Result<(SweepRow, ProfileInputs)> {
        let tkey = key::trace_key(&p.bench, &p.config, opts);

        // 1) in-memory memo
        let cached = lock_unpoisoned(memo).get(&tkey).cloned();
        if let Some(t) = cached {
            counters.trace_mem_hits.fetch_add(1, Ordering::Relaxed);
            let mut analyzer =
                OnlineAnalyzer::new(p.config.cim_levels, p.rule, DeltaSink::default());
            for is in &t.ciq {
                analyzer.push(is);
            }
            let (outcome, deltas) = analyzer.finish();
            return Ok(Self::assemble_point(p, &t.summary(), &outcome, &deltas, counters));
        }

        // 2) disk replay (O(chunk) memory)
        if let Some(d) = disk {
            let mut analyzer =
                OnlineAnalyzer::new(p.config.cim_levels, p.rule, DeltaSink::default());
            if let Some(summary) = d.replay(&tkey, &mut analyzer) {
                counters.trace_disk_hits.fetch_add(1, Ordering::Relaxed);
                let (outcome, deltas) = analyzer.finish();
                return Ok(Self::assemble_point(p, &summary, &outcome, &deltas, counters));
            }
            // corrupt/missing spill: the analyzer may have consumed partial
            // records — discard it and fall through to a fresh simulation
        }

        // 3) pipelined simulate + analyze
        let prog = workloads::build(&p.bench, opts.scale, opts.seed)
            .ok_or_else(|| anyhow!("unknown benchmark '{}'", p.bench))?;
        counters.simulator_runs.fetch_add(1, Ordering::Relaxed);
        let limits = Limits { max_instructions: opts.max_instructions };

        if let Some(d) = disk {
            // best-effort spill: a full disk must not fail the sweep, only
            // future reuse
            match d.writer(&tkey) {
                Ok(mut spill) => {
                    let (summary, outcome, deltas) = pipeline::run_pipelined(
                        &prog,
                        &p.config,
                        limits,
                        p.rule,
                        DeltaSink::default(),
                        Some(&mut spill),
                    )?;
                    if let Err(e) = spill.finish(&summary) {
                        eprintln!("warning: trace spill failed: {e:#}");
                    }
                    Ok(Self::assemble_point(p, &summary, &outcome, &deltas, counters))
                }
                Err(e) => {
                    eprintln!("warning: trace spill failed: {e:#}");
                    let (summary, outcome, deltas) = pipeline::run_pipelined(
                        &prog,
                        &p.config,
                        limits,
                        p.rule,
                        DeltaSink::default(),
                        None,
                    )?;
                    Ok(Self::assemble_point(p, &summary, &outcome, &deltas, counters))
                }
            }
        } else {
            // no disk: materialize via a tee so the memo can serve the
            // other tech/placement variants of this geometry (the legacy
            // memory profile — bounded-memory sweeps want a cache dir)
            let mut collect = CollectSink::default();
            let (summary, outcome, deltas) = pipeline::run_pipelined(
                &prog,
                &p.config,
                limits,
                p.rule,
                DeltaSink::default(),
                Some(&mut collect),
            )?;
            let staged = Self::assemble_point(p, &summary, &outcome, &deltas, counters);
            let trace = Arc::new(Trace::from_parts(summary, collect.ciq));
            lock_unpoisoned(memo).insert(tkey, trace);
            Ok(staged)
        }
    }

    /// Fold a finished stream into the sweep row + profiler inputs.
    fn assemble_point(
        p: &SweepPoint,
        summary: &TraceSummary,
        outcome: &StreamOutcome,
        deltas: &DeltaSink,
        counters: &StageCounters,
    ) -> (SweepRow, ProfileInputs) {
        counters
            .peak_window
            .fetch_max(outcome.peak_window as u64, Ordering::Relaxed);
        counters
            .longest_trace
            .fetch_max(summary.committed, Ordering::Relaxed);
        let reshaped = reshape_from_deltas(summary, deltas, &p.config);
        let inputs = ProfileInputs::new(&p.config, &reshaped);
        let row = SweepRow {
            bench: p.bench.clone(),
            config_name: p.config.name.clone(),
            tech: p.config.tech,
            cim_levels: p.config.cim_levels,
            macr: outcome.macr,
            committed: summary.committed,
            cycles: summary.cycles,
            removed: reshaped.removed,
            cim_ops: reshaped.cim_op_count,
            result: ProfileResult::default(),
        };
        (row, inputs)
    }
}

/// Best-effort rendering of a contained worker panic payload.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".into()
    }
}

/// Cartesian-product helper: benches × configs, one point each.
pub fn cross(
    benches: &[&str],
    configs: &[SystemConfig],
    rule: LocalityRule,
) -> Vec<SweepPoint> {
    let mut points = Vec::new();
    for b in benches {
        for c in configs {
            points.push(SweepPoint {
                bench: b.to_string(),
                config: c.clone(),
                rule,
            });
        }
    }
    points
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;

    #[test]
    fn sweep_two_benches_two_configs_native() {
        let cfgs = [
            SystemConfig::preset("c1").unwrap(),
            SystemConfig::preset("c2").unwrap(),
        ];
        let points = cross(&["lcs", "kmeans"], &cfgs, LocalityRule::AnyCache);
        let coord = Coordinator::new(SweepOptions {
            scale: 8,
            workers: 2,
            ..Default::default()
        });
        let (rows, stats) = coord
            .run_sweep_with_stats(&points, &mut NativeBackend)
            .unwrap();
        assert_eq!(rows.len(), 4);
        for r in rows {
            assert!(r.committed > 0);
            assert!(r.result.total_base > 0.0);
            assert!(r.result.improvement > 0.0);
        }
        // no cache dir: everything computed, nothing reused from disk
        assert_eq!(stats.rows_from_cache, 0);
        assert_eq!(stats.rows_computed, 4);
        assert_eq!(stats.simulator_runs, 4);
        assert_eq!(stats.trace_disk_hits, 0);
        assert!(stats.chunks_claimed >= 1);
    }

    #[test]
    fn trace_memo_dedups_same_geometry() {
        // same bench + geometry, two tech variants -> one simulation
        let mut fefet = SystemConfig::preset("c1").unwrap();
        fefet.tech = crate::config::Technology::FEFET;
        fefet.name = "c1-fefet".into();
        let points = cross(
            &["lcs"],
            &[SystemConfig::preset("c1").unwrap(), fefet],
            LocalityRule::AnyCache,
        );
        let coord = Coordinator::new(SweepOptions {
            scale: 4,
            workers: 1,
            ..Default::default()
        });
        let (rows, stats) = coord
            .run_sweep_with_stats(&points, &mut NativeBackend)
            .unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(stats.simulator_runs, 1);
        assert_eq!(stats.trace_mem_hits, 1);
    }

    #[test]
    fn unknown_bench_errors() {
        let points = cross(
            &["no_such_bench"],
            &[SystemConfig::default()],
            LocalityRule::AnyCache,
        );
        let coord =
            Coordinator::new(SweepOptions { workers: 1, ..Default::default() });
        assert!(coord.run_sweep(&points, &mut NativeBackend).is_err());
    }
}
