//! On-disk spill store for simulation traces.
//!
//! The simulator is the most expensive stage of a sweep, and its output
//! depends only on (workload, core, cache geometry) — not on technology or
//! CiM placement.  Spilling each trace to `traces/trace-<key>.bin` lets
//! the same trace serve every tech/placement variant *across processes*,
//! not just within one coordinator's in-memory memo.
//!
//! Format: a versioned little-endian binary stream (no third-party
//! serialization crates exist in this environment).  Loads are
//! best-effort: any corruption is treated as a cache miss and the trace is
//! re-simulated and re-written.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::isa::{FuncUnit, Instruction};
use crate::probes::{
    IState, MemAccessInfo, MemLevel, MemStats, PipeStats, StopReason, Trace,
};

const MAGIC: u32 = 0x4543_5452; // "ECTR"
const VERSION: u32 = 1;

/// A directory of spilled traces, addressed by content-hash key.
pub struct TraceStore {
    dir: PathBuf,
}

impl TraceStore {
    pub fn open(dir: &Path) -> Result<Self> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating trace store {dir:?}"))?;
        Ok(Self { dir: dir.to_path_buf() })
    }

    fn path_for(&self, key: &str) -> PathBuf {
        self.dir.join(format!("trace-{key}.bin"))
    }

    /// Load a spilled trace; any missing/corrupt file is a miss.
    pub fn load(&self, key: &str) -> Option<Trace> {
        let bytes = std::fs::read(self.path_for(key)).ok()?;
        decode(&bytes).ok()
    }

    /// Spill a trace. Written to a temp file and renamed, so concurrent
    /// processes never observe a half-written trace.
    pub fn store(&self, key: &str, trace: &Trace) -> Result<()> {
        let bytes = encode(trace);
        let tmp = self
            .dir
            .join(format!("trace-{key}.tmp.{}", std::process::id()));
        std::fs::write(&tmp, &bytes).with_context(|| format!("writing {tmp:?}"))?;
        std::fs::rename(&tmp, self.path_for(key))
            .with_context(|| format!("publishing trace {key}"))?;
        Ok(())
    }
}

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn u8(&mut self, x: u8) {
        self.buf.push(x);
    }

    fn u32(&mut self, x: u32) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    fn u64(&mut self, x: u64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        let end = self
            .i
            .checked_add(n)
            .filter(|&e| e <= self.b.len())
            .ok_or_else(|| format!("truncated trace at byte {}", self.i))?;
        let s = &self.b[self.i..end];
        self.i = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str(&mut self) -> Result<String, String> {
        let n = self.u32()? as usize;
        String::from_utf8(self.take(n)?.to_vec()).map_err(|_| "bad utf8".to_string())
    }
}

fn level_to_u8(l: MemLevel) -> u8 {
    match l {
        MemLevel::L1 => 0,
        MemLevel::L2 => 1,
        MemLevel::Dram => 2,
    }
}

fn level_from_u8(x: u8) -> Result<MemLevel, String> {
    match x {
        0 => Ok(MemLevel::L1),
        1 => Ok(MemLevel::L2),
        2 => Ok(MemLevel::Dram),
        _ => Err(format!("bad mem level {x}")),
    }
}

fn stop_to_u8(s: StopReason) -> u8 {
    match s {
        StopReason::Halt => 0,
        StopReason::MaxInstructions => 1,
        StopReason::RanOffEnd => 2,
    }
}

fn stop_from_u8(x: u8) -> Result<StopReason, String> {
    match x {
        0 => Ok(StopReason::Halt),
        1 => Ok(StopReason::MaxInstructions),
        2 => Ok(StopReason::RanOffEnd),
        _ => Err(format!("bad stop reason {x}")),
    }
}

fn pipe_fields(p: &PipeStats) -> [u64; 16] {
    [
        p.fetched,
        p.decoded,
        p.renamed,
        p.iq_reads,
        p.iq_writes,
        p.rob_reads,
        p.rob_writes,
        p.int_rf_reads,
        p.int_rf_writes,
        p.fp_rf_reads,
        p.fp_rf_writes,
        p.bpred_lookups,
        p.bpred_mispredicts,
        p.lsq_reads,
        p.lsq_writes,
        0, // reserved
    ]
}

fn pipe_from_fields(
    f: [u64; 16],
    fu_counts: [u64; crate::isa::func_unit::NUM_FUNC_UNITS],
) -> PipeStats {
    PipeStats {
        fetched: f[0],
        decoded: f[1],
        renamed: f[2],
        iq_reads: f[3],
        iq_writes: f[4],
        rob_reads: f[5],
        rob_writes: f[6],
        int_rf_reads: f[7],
        int_rf_writes: f[8],
        fp_rf_reads: f[9],
        fp_rf_writes: f[10],
        fu_counts,
        bpred_lookups: f[11],
        bpred_mispredicts: f[12],
        lsq_reads: f[13],
        lsq_writes: f[14],
    }
}

fn mem_fields(m: &MemStats) -> [u64; 14] {
    [
        m.l1i_hits,
        m.l1i_misses,
        m.l1d_read_hits,
        m.l1d_read_misses,
        m.l1d_write_hits,
        m.l1d_write_misses,
        m.l2_read_hits,
        m.l2_read_misses,
        m.l2_write_hits,
        m.l2_write_misses,
        m.dram_reads,
        m.dram_writes,
        m.writebacks,
        m.mshr_merges,
    ]
}

fn mem_from_fields(f: [u64; 14]) -> MemStats {
    MemStats {
        l1i_hits: f[0],
        l1i_misses: f[1],
        l1d_read_hits: f[2],
        l1d_read_misses: f[3],
        l1d_write_hits: f[4],
        l1d_write_misses: f[5],
        l2_read_hits: f[6],
        l2_read_misses: f[7],
        l2_write_hits: f[8],
        l2_write_misses: f[9],
        dram_reads: f[10],
        dram_writes: f[11],
        writebacks: f[12],
        mshr_merges: f[13],
    }
}

/// Serialize a trace to the versioned binary format.
pub fn encode(t: &Trace) -> Vec<u8> {
    let mut w = Writer { buf: Vec::with_capacity(64 + t.ciq.len() * 96) };
    w.u32(MAGIC);
    w.u32(VERSION);
    w.str(&t.program);
    w.u64(t.cycles);
    w.u64(t.committed);
    w.u8(stop_to_u8(t.stop));
    for x in pipe_fields(&t.pipe) {
        w.u64(x);
    }
    for x in t.pipe.fu_counts {
        w.u64(x);
    }
    for x in mem_fields(&t.mem) {
        w.u64(x);
    }
    w.u64(t.ciq.len() as u64);
    for is in &t.ciq {
        w.u64(is.seq);
        w.u32(is.pc);
        w.u64(is.instr.encode());
        w.u8(is.fu as u8);
        w.u64(is.tick_fetch);
        w.u64(is.tick_decode);
        w.u64(is.tick_rename);
        w.u64(is.tick_dispatch);
        w.u64(is.tick_issue);
        w.u64(is.tick_complete);
        w.u64(is.tick_commit);
        match &is.mem {
            None => w.u8(0),
            Some(m) => {
                w.u8(1);
                w.u32(m.addr);
                w.u8(m.size);
                w.u8(m.is_store as u8);
                w.u8(level_to_u8(m.level));
                w.u32(m.bank);
                w.u8(m.l1_hit as u8);
                w.u8(m.l2_hit as u8);
                w.u8(m.mshr_merged as u8);
                w.u64(m.latency);
                w.u64(m.issue_tick);
            }
        }
    }
    w.buf
}

/// Parse a trace from the binary format; errors on any inconsistency.
pub fn decode(bytes: &[u8]) -> Result<Trace, String> {
    let mut r = Reader { b: bytes, i: 0 };
    if r.u32()? != MAGIC {
        return Err("bad magic".into());
    }
    if r.u32()? != VERSION {
        return Err("unsupported trace version".into());
    }
    let program = r.str()?;
    let cycles = r.u64()?;
    let committed = r.u64()?;
    let stop = stop_from_u8(r.u8()?)?;
    let mut pf = [0u64; 16];
    for x in pf.iter_mut() {
        *x = r.u64()?;
    }
    let mut fu_counts = [0u64; crate::isa::func_unit::NUM_FUNC_UNITS];
    for x in fu_counts.iter_mut() {
        *x = r.u64()?;
    }
    let pipe = pipe_from_fields(pf, fu_counts);
    let mut mf = [0u64; 14];
    for x in mf.iter_mut() {
        *x = r.u64()?;
    }
    let mem = mem_from_fields(mf);
    let n = r.u64()? as usize;
    let mut ciq = Vec::with_capacity(n.min(1 << 24));
    for _ in 0..n {
        let seq = r.u64()?;
        let pc = r.u32()?;
        let instr = Instruction::decode(r.u64()?).ok_or("bad instruction word")?;
        let fu_idx = r.u8()? as usize;
        let fu = *FuncUnit::all()
            .get(fu_idx)
            .ok_or_else(|| format!("bad func unit {fu_idx}"))?;
        let tick_fetch = r.u64()?;
        let tick_decode = r.u64()?;
        let tick_rename = r.u64()?;
        let tick_dispatch = r.u64()?;
        let tick_issue = r.u64()?;
        let tick_complete = r.u64()?;
        let tick_commit = r.u64()?;
        let mem_info = match r.u8()? {
            0 => None,
            1 => Some(MemAccessInfo {
                addr: r.u32()?,
                size: r.u8()?,
                is_store: r.u8()? != 0,
                level: level_from_u8(r.u8()?)?,
                bank: r.u32()?,
                l1_hit: r.u8()? != 0,
                l2_hit: r.u8()? != 0,
                mshr_merged: r.u8()? != 0,
                latency: r.u64()?,
                issue_tick: r.u64()?,
            }),
            x => return Err(format!("bad mem flag {x}")),
        };
        ciq.push(IState {
            seq,
            pc,
            instr,
            fu,
            tick_fetch,
            tick_decode,
            tick_rename,
            tick_dispatch,
            tick_issue,
            tick_complete,
            tick_commit,
            mem: mem_info,
        });
    }
    if r.i != bytes.len() {
        return Err(format!("trailing bytes at {}", r.i));
    }
    Ok(Trace { program, ciq, pipe, mem, cycles, committed, stop })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::sim::{simulate, Limits};
    use crate::workloads;

    fn sample_trace() -> Trace {
        let prog = workloads::build("lcs", 2, 3).unwrap();
        let cfg = SystemConfig::preset("c1").unwrap();
        simulate(&prog, &cfg, Limits::default()).unwrap()
    }

    fn assert_traces_equal(a: &Trace, b: &Trace) {
        assert_eq!(a.program, b.program);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.committed, b.committed);
        assert_eq!(a.stop, b.stop);
        assert_eq!(pipe_fields(&a.pipe), pipe_fields(&b.pipe));
        assert_eq!(a.pipe.fu_counts, b.pipe.fu_counts);
        assert_eq!(mem_fields(&a.mem), mem_fields(&b.mem));
        assert_eq!(a.ciq.len(), b.ciq.len());
        for (x, y) in a.ciq.iter().zip(&b.ciq) {
            assert_eq!(x.seq, y.seq);
            assert_eq!(x.instr, y.instr);
            assert_eq!(x.fu, y.fu);
            assert_eq!(x.tick_commit, y.tick_commit);
            assert_eq!(x.mem.is_some(), y.mem.is_some());
            if let (Some(m), Some(n)) = (&x.mem, &y.mem) {
                assert_eq!(m.addr, n.addr);
                assert_eq!(m.level, n.level);
                assert_eq!(m.latency, n.latency);
            }
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let t = sample_trace();
        let decoded = decode(&encode(&t)).unwrap();
        assert_traces_equal(&t, &decoded);
    }

    #[test]
    fn decode_rejects_corruption() {
        let t = sample_trace();
        let mut bytes = encode(&t);
        assert!(decode(&bytes[..bytes.len() - 1]).is_err());
        bytes[0] ^= 0xff;
        assert!(decode(&bytes).is_err());
        assert!(decode(b"").is_err());
    }

    #[test]
    fn store_roundtrip_via_disk() {
        let dir = std::env::temp_dir().join(format!(
            "eva-cim-trace-store-test-{}",
            std::process::id()
        ));
        let store = TraceStore::open(&dir).unwrap();
        let t = sample_trace();
        assert!(store.load("k1").is_none());
        store.store("k1", &t).unwrap();
        let back = store.load("k1").unwrap();
        assert_traces_equal(&t, &back);
        std::fs::remove_dir_all(&dir).ok();
    }
}
