//! On-disk spill store for simulation traces.
//!
//! The simulator is the most expensive stage of a sweep, and its output
//! depends only on (workload, core, cache geometry) — not on technology or
//! CiM placement.  Spilling each trace to `traces/trace-<key>.bin` lets
//! the same trace serve every tech/placement variant *across processes*,
//! not just within one coordinator's in-memory memo.
//!
//! Format (version 3, chunk-framed): a versioned little-endian binary
//! stream (no third-party serialization crates exist in this environment):
//!
//! ```text
//! magic  version
//! (count>0, nbytes, nbytes × u8)*       — chunks of `count` I-state records
//! 0u32                                  — chunk terminator
//! program cycles committed stop pipe fu mem   — the TraceSummary trailer
//! ```
//!
//! Each chunk header carries both its record count and its exact byte
//! length, so a reader can find every chunk boundary *without decoding a
//! single record*.  That is what makes warm replay fast and parallel:
//! the chunk scanner slurps whole chunks into reusable buffers with one
//! bulk read each, the records are decoded in place from the buffer
//! (no per-field reader calls), and — because chunks are independent
//! once their boundaries are known — [`TraceStore::replay_with`] can
//! decode them on N worker lanes and reassemble the stream in sequence
//! order before feeding the sink.  Corruption checks are unchanged from
//! v2: magic, version, `SANITY_LIMIT` on counts/lengths/byte sizes,
//! per-chunk byte-exactness, the end-of-stream probe, and the trailer
//! record-count cross-check.
//!
//! The chunked layout serves the streaming pipeline on both sides: a
//! [`SpillWriter`] is a [`TraceSink`] that writes records as the simulator
//! commits them (the summary trailer lands in `finish`), and
//! [`TraceStore::replay`] feeds a sink chunk-by-chunk without ever
//! materializing the trace — both O(chunk) memory.  Loads are
//! best-effort: any corruption (or a version-1/-2 file from an older
//! build) is treated as a cache miss, the corrupt spill is quarantined
//! to `<cache-dir>/quarantine/` so it stops satisfying
//! [`TraceStore::contains`] probes (see [`crate::util::faultio`]), and
//! the trace is re-simulated and re-published.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::{mpsc, Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use crate::isa::{FuncUnit, Instruction};
use crate::probes::{
    CollectSink, IState, MemAccessInfo, MemLevel, MemStats, PipeStats,
    StopReason, Trace, TraceSink, TraceSummary,
};
use crate::util::faultio::{self, IoOp, StoreIo as _};
use crate::util::lock_unpoisoned;

const MAGIC: u32 = 0x4543_5452; // "ECTR"
const VERSION: u32 = 3;

/// Records per chunk: bounds both writer batching and replay memory.
const CHUNK_RECORDS: u32 = 4096;

/// Upper bound accepted for on-disk chunk counts, chunk byte lengths and
/// string lengths — anything larger is corruption, not data.
const SANITY_LIMIT: u32 = 1 << 24;

/// A directory of spilled traces, addressed by content-hash key.
pub struct TraceStore {
    dir: PathBuf,
    /// `<cache-dir>/quarantine/` — corrupt spills are renamed here (with
    /// a `.reason` file) so they stop satisfying existence probes
    quarantine: PathBuf,
    /// `fsync` spills before publishing (crash-consistency policy knob)
    fsync: bool,
}

impl TraceStore {
    /// Open (creating if needed) the spill directory.
    pub fn open(dir: &Path) -> Result<Self> {
        Self::open_with(dir, false)
    }

    /// [`TraceStore::open`] with an explicit fsync-before-publish policy.
    pub fn open_with(dir: &Path, fsync: bool) -> Result<Self> {
        faultio::with_retries("creating trace store", || {
            faultio::fs().create_dir_all(dir)
        })
        .with_context(|| format!("creating trace store {dir:?}"))?;
        let quarantine = dir
            .parent()
            .unwrap_or(dir)
            .join(super::QUARANTINE_DIR);
        Ok(Self { dir: dir.to_path_buf(), quarantine, fsync })
    }

    fn path_for(&self, key: &str) -> PathBuf {
        self.dir.join(format!("trace-{key}.bin"))
    }

    /// True when a spill for `key` has been published.  A cheap existence
    /// probe only — the file may still turn out corrupt on replay, so
    /// callers must treat a later replay miss as authoritative.
    pub fn contains(&self, key: &str) -> bool {
        self.path_for(key).exists()
    }

    /// Stream a spilled trace into `sink` chunk-by-chunk; returns the
    /// summary trailer on success.  Any missing/corrupt/old-version file
    /// is a miss (`None`) — note the sink may already have consumed
    /// records by then, so treat its state as tainted on a miss.
    pub fn replay(&self, key: &str, sink: &mut dyn TraceSink) -> Option<TraceSummary> {
        self.replay_with(key, sink, 1).map(|(summary, _)| summary)
    }

    /// [`TraceStore::replay`] with an explicit decode-lane count; returns
    /// the summary and the number of chunks decoded.
    ///
    /// `lanes <= 1` decodes on the calling thread (zero-copy chunk
    /// decode, one bulk read per chunk).  `lanes >= 2` adds a pipelined
    /// scanner thread plus `lanes` decode workers over bounded channels;
    /// decoded chunks are reassembled in sequence order, so `sink` sees
    /// records in exactly the committed order regardless of lane count.
    pub fn replay_with(
        &self,
        key: &str,
        sink: &mut dyn TraceSink,
        lanes: usize,
    ) -> Option<(TraceSummary, u64)> {
        let path = self.path_for(key);
        let f = faultio::fs().open_read(&path).ok()?;
        let r = BufReader::new(f);
        let res = if lanes >= 2 {
            decode_stream_parallel(r, sink, lanes)
        } else {
            decode_stream_zero_copy(r, sink)
        };
        match res {
            Ok(out) => Some(out),
            Err(e) => {
                // a corrupt spill is a miss — but it must not keep
                // satisfying `contains` probes, so move it aside
                faultio::quarantine_move(
                    &self.quarantine,
                    &path,
                    &format!("corrupt trace spill: {e}"),
                );
                None
            }
        }
    }

    /// Reference replay: walks records one at a time through per-field
    /// reader calls — the pre-zero-copy decode path, kept as the
    /// differential oracle for the chunk decoder (`rust/tests/
    /// replay_parallel.rs`) and as the `perf_hotpaths` bench baseline.
    pub fn replay_reference(
        &self,
        key: &str,
        sink: &mut dyn TraceSink,
    ) -> Option<TraceSummary> {
        let f = std::fs::File::open(self.path_for(key)).ok()?;
        let mut r = BufReader::new(f);
        decode_stream_reference(&mut r, sink).ok()
    }

    /// Load a spilled trace, materialized; any missing/corrupt file is a
    /// miss.
    pub fn load(&self, key: &str) -> Option<Trace> {
        let mut sink = CollectSink::default();
        let summary = self.replay(key, &mut sink)?;
        Some(Trace::from_parts(summary, sink.ciq))
    }

    /// Open a streaming spill for `key`.  Feed it as a [`TraceSink`], then
    /// call [`SpillWriter::finish`] with the simulation summary; the trace
    /// is written to a temp file and renamed, so concurrent processes
    /// never observe a half-written trace.  Dropping without `finish`
    /// discards the temp file.
    ///
    /// The temp name carries a per-writer token on top of the pid: two
    /// sweep workers cold-spilling the same trace key concurrently (same
    /// geometry, different tech variants) must not truncate each other's
    /// in-progress file — last rename wins, both files stay intact.
    pub fn writer(&self, key: &str) -> Result<SpillWriter> {
        use std::sync::atomic::{AtomicU64, Ordering};
        static WRITER_TOKEN: AtomicU64 = AtomicU64::new(0);
        let token = WRITER_TOKEN.fetch_add(1, Ordering::Relaxed);
        let tmp = self
            .dir
            .join(format!("trace-{key}.tmp.{}.{token}", std::process::id()));
        let file = faultio::with_retries("creating trace spill", || {
            faultio::fs().create(&tmp)
        })
        .with_context(|| format!("creating {tmp:?}"))?;
        let mut w = SpillWriter {
            tmp,
            final_path: self.path_for(key),
            file: Some(BufWriter::new(file)),
            chunk: Vec::new(),
            pending: 0,
            error: None,
            finished: false,
            fsync: self.fsync,
        };
        let mut header = Writer { buf: Vec::with_capacity(8) };
        header.u32(MAGIC);
        header.u32(VERSION);
        w.write_bytes(&header.buf);
        Ok(w)
    }

    /// Spill a materialized trace (adapter over [`TraceStore::writer`]).
    pub fn store(&self, key: &str, trace: &Trace) -> Result<()> {
        let mut w = self.writer(key)?;
        for is in &trace.ciq {
            w.on_commit(is.clone());
        }
        w.finish(&trace.summary())
    }
}

/// Streaming trace spill: a [`TraceSink`] writing chunk-framed records.
/// IO errors are held internally (a full disk must not fail the sweep,
/// only future reuse) and surfaced by [`SpillWriter::finish`].
pub struct SpillWriter {
    tmp: PathBuf,
    final_path: PathBuf,
    file: Option<BufWriter<std::fs::File>>,
    chunk: Vec<u8>,
    pending: u32,
    error: Option<String>,
    finished: bool,
    fsync: bool,
}

impl SpillWriter {
    fn write_bytes(&mut self, bytes: &[u8]) {
        if self.error.is_some() {
            return;
        }
        let Some(f) = self.file.as_mut() else { return };
        // the BufWriter hides individual syscalls, so consult the fault
        // injector explicitly — a spill write fault latches like a real one
        if let Err(e) = faultio::fs()
            .probe(IoOp::Write, &self.tmp)
            .and_then(|()| f.write_all(bytes))
        {
            self.error = Some(e.to_string());
            self.file = None;
        }
    }

    fn flush_chunk(&mut self) {
        if self.pending == 0 {
            return;
        }
        let count = self.pending.to_le_bytes();
        let nbytes = (self.chunk.len() as u32).to_le_bytes();
        let mut chunk = std::mem::take(&mut self.chunk);
        self.pending = 0;
        self.write_bytes(&count);
        self.write_bytes(&nbytes);
        self.write_bytes(&chunk);
        chunk.clear();
        self.chunk = chunk; // reuse the allocation
    }

    /// Seal the spill with the summary trailer and publish it atomically.
    pub fn finish(mut self, summary: &TraceSummary) -> Result<()> {
        self.flush_chunk();
        let mut tail = Writer { buf: Vec::with_capacity(256) };
        tail.u32(0); // chunk terminator
        tail.summary(summary);
        self.write_bytes(&tail.buf);
        if self.error.is_none() {
            if let Some(f) = self.file.as_mut() {
                if let Err(e) = f.flush() {
                    self.error = Some(e.to_string());
                }
            }
        }
        if self.error.is_none() && self.fsync {
            if let Some(f) = self.file.as_ref() {
                let res = faultio::with_retries("fsyncing trace spill", || {
                    faultio::fs().fsync(&self.tmp, f.get_ref())
                });
                if let Err(e) = res {
                    self.error = Some(e.to_string());
                }
            }
        }
        self.file = None; // close before rename
        if let Some(e) = self.error.take() {
            // Drop removes the temp file
            return Err(anyhow!("writing trace spill: {e}"));
        }
        let res = faultio::with_retries("publishing trace spill", || {
            faultio::fs().rename(&self.tmp, &self.final_path)
        })
        .with_context(|| format!("publishing trace {:?}", self.final_path));
        if res.is_ok() {
            self.finished = true;
        }
        res
    }
}

impl TraceSink for SpillWriter {
    fn on_commit(&mut self, is: IState) {
        if self.error.is_some() {
            return;
        }
        let mut w = Writer { buf: std::mem::take(&mut self.chunk) };
        w.istate(&is);
        self.chunk = w.buf;
        self.pending += 1;
        if self.pending >= CHUNK_RECORDS {
            self.flush_chunk();
        }
    }
}

impl Drop for SpillWriter {
    fn drop(&mut self) {
        if !self.finished {
            self.file = None;
            let _ = std::fs::remove_file(&self.tmp);
        }
    }
}

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn u8(&mut self, x: u8) {
        self.buf.push(x);
    }

    fn u32(&mut self, x: u32) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    fn u64(&mut self, x: u64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    fn istate(&mut self, is: &IState) {
        self.u64(is.seq);
        self.u32(is.pc);
        self.u64(is.instr.encode());
        self.u8(is.fu as u8);
        self.u64(is.tick_fetch);
        self.u64(is.tick_decode);
        self.u64(is.tick_rename);
        self.u64(is.tick_dispatch);
        self.u64(is.tick_issue);
        self.u64(is.tick_complete);
        self.u64(is.tick_commit);
        match &is.mem {
            None => self.u8(0),
            Some(m) => {
                self.u8(1);
                self.u32(m.addr);
                self.u8(m.size);
                self.u8(m.is_store as u8);
                self.u8(level_to_u8(m.level));
                self.u32(m.bank);
                self.u8(m.l1_hit as u8);
                self.u8(m.l2_hit as u8);
                self.u8(m.mshr_merged as u8);
                self.u64(m.latency);
                self.u64(m.issue_tick);
            }
        }
    }

    fn summary(&mut self, s: &TraceSummary) {
        self.str(&s.program);
        self.u64(s.cycles);
        self.u64(s.committed);
        self.u8(stop_to_u8(s.stop));
        for x in pipe_fields(&s.pipe) {
            self.u64(x);
        }
        for x in s.pipe.fu_counts {
            self.u64(x);
        }
        for x in mem_fields(&s.mem) {
            self.u64(x);
        }
    }
}

// ---------------------------------------------------------------------------
// Reader primitives (header/trailer + the reference per-record path).
// `&[u8]` implements `Read`, so the same helpers serve in-memory slices
// (tests, `decode`) and buffered files (`replay`).

fn r_u8<R: Read>(r: &mut R) -> Result<u8, String> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b).map_err(|e| format!("reading trace: {e}"))?;
    Ok(b[0])
}

fn r_u32<R: Read>(r: &mut R) -> Result<u32, String> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b).map_err(|e| format!("reading trace: {e}"))?;
    Ok(u32::from_le_bytes(b))
}

fn r_u64<R: Read>(r: &mut R) -> Result<u64, String> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b).map_err(|e| format!("reading trace: {e}"))?;
    Ok(u64::from_le_bytes(b))
}

fn r_str<R: Read>(r: &mut R) -> Result<String, String> {
    let n = r_u32(r)?;
    if n > SANITY_LIMIT {
        return Err(format!("implausible string length {n}"));
    }
    let mut buf = vec![0u8; n as usize];
    r.read_exact(&mut buf).map_err(|e| format!("reading trace: {e}"))?;
    String::from_utf8(buf).map_err(|_| "bad utf8".to_string())
}

/// True when the source is exhausted (trailing bytes are corruption).
fn at_end<R: Read>(r: &mut R) -> Result<bool, String> {
    let mut probe = [0u8; 1];
    match r.read(&mut probe) {
        Ok(0) => Ok(true),
        Ok(_) => Ok(false),
        Err(e) => Err(format!("reading trace: {e}")),
    }
}

fn r_istate<R: Read>(r: &mut R) -> Result<IState, String> {
    let seq = r_u64(r)?;
    let pc = r_u32(r)?;
    let instr = Instruction::decode(r_u64(r)?).ok_or("bad instruction word")?;
    let fu_idx = r_u8(r)? as usize;
    let fu = *FuncUnit::all()
        .get(fu_idx)
        .ok_or_else(|| format!("bad func unit {fu_idx}"))?;
    let tick_fetch = r_u64(r)?;
    let tick_decode = r_u64(r)?;
    let tick_rename = r_u64(r)?;
    let tick_dispatch = r_u64(r)?;
    let tick_issue = r_u64(r)?;
    let tick_complete = r_u64(r)?;
    let tick_commit = r_u64(r)?;
    let mem = match r_u8(r)? {
        0 => None,
        1 => Some(MemAccessInfo {
            addr: r_u32(r)?,
            size: r_u8(r)?,
            is_store: r_u8(r)? != 0,
            level: level_from_u8(r_u8(r)?)?,
            bank: r_u32(r)?,
            l1_hit: r_u8(r)? != 0,
            l2_hit: r_u8(r)? != 0,
            mshr_merged: r_u8(r)? != 0,
            latency: r_u64(r)?,
            issue_tick: r_u64(r)?,
        }),
        x => return Err(format!("bad mem flag {x}")),
    };
    Ok(IState {
        seq,
        pc,
        instr,
        fu,
        tick_fetch,
        tick_decode,
        tick_rename,
        tick_dispatch,
        tick_issue,
        tick_complete,
        tick_commit,
        mem,
    })
}

/// Parse the summary trailer (everything after the chunk terminator).
fn read_trailer<R: Read>(r: &mut R) -> Result<TraceSummary, String> {
    let program = r_str(r)?;
    let cycles = r_u64(r)?;
    let committed = r_u64(r)?;
    let stop = stop_from_u8(r_u8(r)?)?;
    let mut pf = [0u64; 16];
    for x in pf.iter_mut() {
        *x = r_u64(r)?;
    }
    let mut fu_counts = [0u64; crate::isa::func_unit::NUM_FUNC_UNITS];
    for x in fu_counts.iter_mut() {
        *x = r_u64(r)?;
    }
    let pipe = pipe_from_fields(pf, fu_counts);
    let mut mf = [0u64; 14];
    for x in mf.iter_mut() {
        *x = r_u64(r)?;
    }
    let mem = mem_from_fields(mf);
    Ok(TraceSummary { program: program.into(), pipe, mem, cycles, committed, stop })
}

// ---------------------------------------------------------------------------
// Zero-copy chunk decode: one bulk read per chunk, records decoded in
// place from the buffer.

/// Cursor over one fully-read chunk body.
struct Slice<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Slice<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        let end = self
            .i
            .checked_add(n)
            .filter(|&e| e <= self.b.len())
            .ok_or_else(|| format!("truncated chunk at byte {}", self.i))?;
        let s = &self.b[self.i..end];
        self.i = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, String> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, String> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }
}

/// Decode one record in place from the chunk buffer (the slice twin of
/// [`r_istate`] — no per-field reader calls, no intermediate copies).
fn istate_from_slice(s: &mut Slice) -> Result<IState, String> {
    let seq = s.u64()?;
    let pc = s.u32()?;
    let instr = Instruction::decode(s.u64()?).ok_or("bad instruction word")?;
    let fu_idx = s.u8()? as usize;
    let fu = *FuncUnit::all()
        .get(fu_idx)
        .ok_or_else(|| format!("bad func unit {fu_idx}"))?;
    let tick_fetch = s.u64()?;
    let tick_decode = s.u64()?;
    let tick_rename = s.u64()?;
    let tick_dispatch = s.u64()?;
    let tick_issue = s.u64()?;
    let tick_complete = s.u64()?;
    let tick_commit = s.u64()?;
    let mem = match s.u8()? {
        0 => None,
        1 => Some(MemAccessInfo {
            addr: s.u32()?,
            size: s.u8()?,
            is_store: s.u8()? != 0,
            level: level_from_u8(s.u8()?)?,
            bank: s.u32()?,
            l1_hit: s.u8()? != 0,
            l2_hit: s.u8()? != 0,
            mshr_merged: s.u8()? != 0,
            latency: s.u64()?,
            issue_tick: s.u64()?,
        }),
        x => return Err(format!("bad mem flag {x}")),
    };
    Ok(IState {
        seq,
        pc,
        instr,
        fu,
        tick_fetch,
        tick_decode,
        tick_rename,
        tick_dispatch,
        tick_issue,
        tick_complete,
        tick_commit,
        mem,
    })
}

/// Decode exactly `count` records from a chunk buffer into `out`
/// (cleared first; its allocation is reused across chunks).  The buffer
/// must be consumed exactly — a leftover or shortfall means the chunk
/// header lied about its framing.
fn decode_chunk_into(
    buf: &[u8],
    count: u32,
    out: &mut Vec<IState>,
) -> Result<(), String> {
    out.clear();
    out.reserve(count as usize);
    let mut s = Slice { b: buf, i: 0 };
    for _ in 0..count {
        out.push(istate_from_slice(&mut s)?);
    }
    if s.i != buf.len() {
        return Err(format!(
            "chunk framing mismatch: {} bytes left after {count} records",
            buf.len() - s.i
        ));
    }
    Ok(())
}

/// Reads chunk frames (header + whole body) from a v3 stream without
/// decoding records — the boundary scanner that makes chunk decode
/// independent and therefore parallelizable.
struct ChunkScanner<R: Read> {
    r: R,
    /// records promised by the chunk headers so far (cross-checked
    /// against the trailer's committed count in [`ChunkScanner::finish`])
    records: u64,
}

impl<R: Read> ChunkScanner<R> {
    /// Validate the stream header and position at the first chunk.
    fn new(mut r: R) -> Result<Self, String> {
        if r_u32(&mut r)? != MAGIC {
            return Err("bad magic".into());
        }
        if r_u32(&mut r)? != VERSION {
            return Err("unsupported trace version".into());
        }
        Ok(Self { r, records: 0 })
    }

    /// Read the next chunk body into `buf` (cleared and resized); returns
    /// its record count, or `None` at the chunk terminator.
    fn next_chunk(&mut self, buf: &mut Vec<u8>) -> Result<Option<u32>, String> {
        let count = r_u32(&mut self.r)?;
        if count == 0 {
            return Ok(None);
        }
        if count > SANITY_LIMIT {
            return Err(format!("implausible chunk size {count}"));
        }
        let nbytes = r_u32(&mut self.r)?;
        if nbytes > SANITY_LIMIT {
            return Err(format!("implausible chunk byte length {nbytes}"));
        }
        buf.clear();
        buf.resize(nbytes as usize, 0);
        self.r
            .read_exact(buf)
            .map_err(|e| format!("reading trace: {e}"))?;
        self.records += count as u64;
        Ok(Some(count))
    }

    /// Parse the trailer after the terminator, verify end-of-stream and
    /// the record-count cross-check.
    fn finish(mut self) -> Result<TraceSummary, String> {
        let summary = read_trailer(&mut self.r)?;
        if !at_end(&mut self.r)? {
            return Err("trailing bytes after trailer".into());
        }
        if self.records != summary.committed {
            return Err(format!(
                "record count {} disagrees with trailer committed {}",
                self.records, summary.committed
            ));
        }
        Ok(summary)
    }
}

/// Sequential zero-copy decode: scan chunk boundaries, bulk-read each
/// chunk into one reusable buffer, decode records in place, feed the
/// sink.  Returns the trailer and the number of chunks decoded.
fn decode_stream_zero_copy<R: Read>(
    r: R,
    sink: &mut dyn TraceSink,
) -> Result<(TraceSummary, u64), String> {
    let mut scanner = ChunkScanner::new(r)?;
    let mut buf: Vec<u8> = Vec::new();
    let mut recs: Vec<IState> = Vec::new();
    let mut chunks: u64 = 0;
    while let Some(count) = scanner.next_chunk(&mut buf)? {
        decode_chunk_into(&buf, count, &mut recs)?;
        chunks += 1;
        for is in recs.drain(..) {
            sink.on_commit(is);
        }
    }
    Ok((scanner.finish()?, chunks))
}

/// Pipelined multi-lane decode: a scanner thread finds chunk boundaries
/// and ships whole chunk buffers to `lanes` decode workers over a
/// bounded channel; the calling thread reassembles decoded chunks in
/// sequence order and feeds the sink, so the record stream is
/// byte-identical to the sequential path.  Buffers recycle from the
/// workers back to the scanner, keeping memory O(lanes × chunk).
fn decode_stream_parallel<R: Read + Send>(
    r: R,
    sink: &mut dyn TraceSink,
    lanes: usize,
) -> Result<(TraceSummary, u64), String> {
    let lanes = lanes.max(2);
    // scanner -> workers: (sequence number, record count, chunk bytes)
    let (tx_work, rx_work) = mpsc::sync_channel::<(u64, u32, Vec<u8>)>(lanes * 2);
    let rx_work = Arc::new(Mutex::new(rx_work));
    // workers -> reassembly: (sequence number, decoded records)
    let (tx_done, rx_done) =
        mpsc::sync_channel::<(u64, Result<Vec<IState>, String>)>(lanes * 2 + 2);
    // scanner -> reassembly: the trailer (or the scan error) + chunk count
    let (tx_tail, rx_tail) =
        mpsc::sync_channel::<Result<(TraceSummary, u64), String>>(1);
    // workers -> scanner: spent chunk buffers for reuse
    let (tx_free, rx_free) = mpsc::channel::<Vec<u8>>();

    std::thread::scope(|scope| {
        scope.spawn(move || {
            let scan = || -> Result<(TraceSummary, u64), String> {
                let mut scanner = ChunkScanner::new(r)?;
                let mut idx: u64 = 0;
                loop {
                    let mut buf = rx_free.try_recv().unwrap_or_default();
                    match scanner.next_chunk(&mut buf)? {
                        Some(count) => {
                            if tx_work.send((idx, count, buf)).is_err() {
                                return Err(
                                    "replay decode lanes exited early".into()
                                );
                            }
                            idx += 1;
                        }
                        None => break,
                    }
                }
                Ok((scanner.finish()?, idx))
            };
            let result = scan();
            // close the work queue so the lanes drain and exit, then
            // publish the tail (capacity 1: the send cannot block)
            drop(tx_work);
            let _ = tx_tail.send(result);
        });
        for _ in 0..lanes {
            let rx_work = Arc::clone(&rx_work);
            let tx_done = tx_done.clone();
            let tx_free = tx_free.clone();
            scope.spawn(move || {
                loop {
                    // hold the lock only while waiting for one frame;
                    // decode happens after it is released
                    let frame = lock_unpoisoned(&rx_work).recv();
                    let Ok((idx, count, buf)) = frame else { break };
                    let mut recs = Vec::with_capacity(count as usize);
                    let res =
                        decode_chunk_into(&buf, count, &mut recs).map(|_| recs);
                    let _ = tx_free.send(buf);
                    if tx_done.send((idx, res)).is_err() {
                        break;
                    }
                }
            });
        }
        // only the workers may hold done/free senders, so the loops below
        // terminate when they exit
        drop(tx_done);
        drop(tx_free);

        // In-order reassembly on the calling thread.  This loop drains
        // rx_done to disconnection unconditionally (even after an error),
        // so no worker or scanner can block on a full channel while the
        // scope waits to join them.
        let mut pending: std::collections::HashMap<u64, Vec<IState>> =
            std::collections::HashMap::new();
        let mut next: u64 = 0;
        let mut first_err: Option<String> = None;
        for (idx, res) in rx_done.iter() {
            match res {
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                Ok(recs) => {
                    if first_err.is_none() {
                        pending.insert(idx, recs);
                        while let Some(recs) = pending.remove(&next) {
                            for is in recs {
                                sink.on_commit(is);
                            }
                            next += 1;
                        }
                    }
                }
            }
        }
        let tail = rx_tail
            .recv()
            .unwrap_or_else(|_| Err("replay scanner exited".into()));
        if let Some(e) = first_err {
            return Err(e);
        }
        let (summary, chunks) = tail?;
        if next != chunks || !pending.is_empty() {
            return Err(format!(
                "chunk reassembly incomplete: fed {next} of {chunks} chunks"
            ));
        }
        Ok((summary, chunks))
    })
}

/// Reference decoder: the pre-zero-copy replay path, one record at a
/// time through per-field reader calls.  Decodes the same v3 framing
/// (the per-chunk byte length is read and ignored), so it stays a valid
/// differential oracle for [`decode_stream_zero_copy`] and the honest
/// baseline for the replay bench.
fn decode_stream_reference<R: Read>(
    r: &mut R,
    sink: &mut dyn TraceSink,
) -> Result<TraceSummary, String> {
    if r_u32(r)? != MAGIC {
        return Err("bad magic".into());
    }
    if r_u32(r)? != VERSION {
        return Err("unsupported trace version".into());
    }
    let mut records: u64 = 0;
    loop {
        let n = r_u32(r)?;
        if n == 0 {
            break;
        }
        if n > SANITY_LIMIT {
            return Err(format!("implausible chunk size {n}"));
        }
        let nbytes = r_u32(r)?;
        if nbytes > SANITY_LIMIT {
            return Err(format!("implausible chunk byte length {nbytes}"));
        }
        for _ in 0..n {
            sink.on_commit(r_istate(r)?);
            records += 1;
        }
    }
    let summary = read_trailer(r)?;
    if !at_end(r)? {
        return Err("trailing bytes after trailer".into());
    }
    if records != summary.committed {
        return Err(format!(
            "record count {records} disagrees with trailer committed {committed}",
            committed = summary.committed
        ));
    }
    Ok(summary)
}

fn level_to_u8(l: MemLevel) -> u8 {
    match l {
        MemLevel::L1 => 0,
        MemLevel::L2 => 1,
        MemLevel::Dram => 2,
    }
}

fn level_from_u8(x: u8) -> Result<MemLevel, String> {
    match x {
        0 => Ok(MemLevel::L1),
        1 => Ok(MemLevel::L2),
        2 => Ok(MemLevel::Dram),
        _ => Err(format!("bad mem level {x}")),
    }
}

pub(crate) fn stop_to_u8(s: StopReason) -> u8 {
    match s {
        StopReason::Halt => 0,
        StopReason::MaxInstructions => 1,
        StopReason::RanOffEnd => 2,
    }
}

pub(crate) fn stop_from_u8(x: u8) -> Result<StopReason, String> {
    match x {
        0 => Ok(StopReason::Halt),
        1 => Ok(StopReason::MaxInstructions),
        2 => Ok(StopReason::RanOffEnd),
        _ => Err(format!("bad stop reason {x}")),
    }
}

pub(crate) fn pipe_fields(p: &PipeStats) -> [u64; 16] {
    [
        p.fetched,
        p.decoded,
        p.renamed,
        p.iq_reads,
        p.iq_writes,
        p.rob_reads,
        p.rob_writes,
        p.int_rf_reads,
        p.int_rf_writes,
        p.fp_rf_reads,
        p.fp_rf_writes,
        p.bpred_lookups,
        p.bpred_mispredicts,
        p.lsq_reads,
        p.lsq_writes,
        0, // reserved
    ]
}

pub(crate) fn pipe_from_fields(
    f: [u64; 16],
    fu_counts: [u64; crate::isa::func_unit::NUM_FUNC_UNITS],
) -> PipeStats {
    PipeStats {
        fetched: f[0],
        decoded: f[1],
        renamed: f[2],
        iq_reads: f[3],
        iq_writes: f[4],
        rob_reads: f[5],
        rob_writes: f[6],
        int_rf_reads: f[7],
        int_rf_writes: f[8],
        fp_rf_reads: f[9],
        fp_rf_writes: f[10],
        fu_counts,
        bpred_lookups: f[11],
        bpred_mispredicts: f[12],
        lsq_reads: f[13],
        lsq_writes: f[14],
    }
}

pub(crate) fn mem_fields(m: &MemStats) -> [u64; 14] {
    [
        m.l1i_hits,
        m.l1i_misses,
        m.l1d_read_hits,
        m.l1d_read_misses,
        m.l1d_write_hits,
        m.l1d_write_misses,
        m.l2_read_hits,
        m.l2_read_misses,
        m.l2_write_hits,
        m.l2_write_misses,
        m.dram_reads,
        m.dram_writes,
        m.writebacks,
        m.mshr_merges,
    ]
}

pub(crate) fn mem_from_fields(f: [u64; 14]) -> MemStats {
    MemStats {
        l1i_hits: f[0],
        l1i_misses: f[1],
        l1d_read_hits: f[2],
        l1d_read_misses: f[3],
        l1d_write_hits: f[4],
        l1d_write_misses: f[5],
        l2_read_hits: f[6],
        l2_read_misses: f[7],
        l2_write_hits: f[8],
        l2_write_misses: f[9],
        dram_reads: f[10],
        dram_writes: f[11],
        writebacks: f[12],
        mshr_merges: f[13],
    }
}

/// Serialize a materialized trace to the versioned binary format (the
/// slice twin of [`SpillWriter`] — byte-identical output).
pub fn encode(t: &Trace) -> Vec<u8> {
    let mut w = Writer { buf: Vec::with_capacity(64 + t.ciq.len() * 96) };
    w.u32(MAGIC);
    w.u32(VERSION);
    let mut body = Writer { buf: Vec::new() };
    for chunk in t.ciq.chunks(CHUNK_RECORDS as usize) {
        body.buf.clear();
        for is in chunk {
            body.istate(is);
        }
        w.u32(chunk.len() as u32);
        w.u32(body.buf.len() as u32);
        w.buf.extend_from_slice(&body.buf);
    }
    w.u32(0);
    w.summary(&t.summary());
    w.buf
}

/// Parse a trace from the binary format; errors on any inconsistency.
/// Decodes through the same chunk scanner as `replay`, so the fuzz tests
/// exercising this path exercise the hot path.
pub fn decode(bytes: &[u8]) -> Result<Trace, String> {
    let mut sink = CollectSink::default();
    let (summary, _chunks) = decode_stream_zero_copy(bytes, &mut sink)?;
    Ok(Trace::from_parts(summary, sink.ciq))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::sim::{simulate, Limits};
    use crate::workloads;

    fn sample_trace() -> Trace {
        let prog = workloads::build("lcs", 2, 3).unwrap();
        let cfg = SystemConfig::preset("c1").unwrap();
        simulate(&prog, &cfg, Limits::default()).unwrap()
    }

    fn assert_traces_equal(a: &Trace, b: &Trace) {
        assert_eq!(a.program, b.program);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.committed, b.committed);
        assert_eq!(a.stop, b.stop);
        assert_eq!(pipe_fields(&a.pipe), pipe_fields(&b.pipe));
        assert_eq!(a.pipe.fu_counts, b.pipe.fu_counts);
        assert_eq!(mem_fields(&a.mem), mem_fields(&b.mem));
        assert_eq!(a.ciq.len(), b.ciq.len());
        for (x, y) in a.ciq.iter().zip(&b.ciq) {
            assert_eq!(x.seq, y.seq);
            assert_eq!(x.instr, y.instr);
            assert_eq!(x.fu, y.fu);
            assert_eq!(x.tick_commit, y.tick_commit);
            assert_eq!(x.mem.is_some(), y.mem.is_some());
            if let (Some(m), Some(n)) = (&x.mem, &y.mem) {
                assert_eq!(m.addr, n.addr);
                assert_eq!(m.level, n.level);
                assert_eq!(m.latency, n.latency);
            }
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let t = sample_trace();
        let decoded = decode(&encode(&t)).unwrap();
        assert_traces_equal(&t, &decoded);
    }

    #[test]
    fn decode_rejects_corruption() {
        let t = sample_trace();
        let mut bytes = encode(&t);
        assert!(decode(&bytes[..bytes.len() - 1]).is_err());
        bytes[0] ^= 0xff;
        assert!(decode(&bytes).is_err());
        assert!(decode(b"").is_err());
    }

    #[test]
    fn reference_decoder_matches_zero_copy() {
        let t = sample_trace();
        let bytes = encode(&t);
        let mut sink = CollectSink::default();
        let summary =
            decode_stream_reference(&mut bytes.as_slice(), &mut sink).unwrap();
        assert_traces_equal(&t, &Trace::from_parts(summary, sink.ciq));
    }

    #[test]
    fn store_roundtrip_via_disk() {
        let dir = std::env::temp_dir().join(format!(
            "eva-cim-trace-store-test-{}",
            std::process::id()
        ));
        let store = TraceStore::open(&dir).unwrap();
        let t = sample_trace();
        assert!(!store.contains("k1"));
        assert!(store.load("k1").is_none());
        store.store("k1", &t).unwrap();
        assert!(store.contains("k1"));
        let back = store.load("k1").unwrap();
        assert_traces_equal(&t, &back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn streaming_writer_matches_encode_and_replays_in_chunks() {
        let dir = std::env::temp_dir().join(format!(
            "eva-cim-trace-stream-test-{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        let store = TraceStore::open(&dir).unwrap();
        let t = sample_trace();

        // streaming spill, record by record
        let mut w = store.writer("k2").unwrap();
        for is in &t.ciq {
            w.on_commit(is.clone());
        }
        w.finish(&t.summary()).unwrap();

        // on disk: byte-identical to the slice encoder
        let disk = std::fs::read(dir.join("trace-k2.bin")).unwrap();
        assert_eq!(disk, encode(&t));

        // replay streams the same records and trailer
        let mut sink = CollectSink::default();
        let summary = store.replay("k2", &mut sink).unwrap();
        assert_traces_equal(&t, &Trace::from_parts(summary, sink.ciq));

        // multi-lane replay reassembles the identical stream, and the
        // reference decoder agrees
        for lanes in [2usize, 8] {
            let mut sink = CollectSink::default();
            let (summary, chunks) =
                store.replay_with("k2", &mut sink, lanes).unwrap();
            assert!(chunks >= 1);
            assert_traces_equal(&t, &Trace::from_parts(summary, sink.ciq));
        }
        let mut sink = CollectSink::default();
        let summary = store.replay_reference("k2", &mut sink).unwrap();
        assert_traces_equal(&t, &Trace::from_parts(summary, sink.ciq));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unfinished_writer_leaves_no_published_trace() {
        let dir = std::env::temp_dir().join(format!(
            "eva-cim-trace-drop-test-{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        let store = TraceStore::open(&dir).unwrap();
        let t = sample_trace();
        {
            let mut w = store.writer("k3").unwrap();
            for is in t.ciq.iter().take(5) {
                w.on_commit(is.clone());
            }
            // dropped without finish: simulated crash mid-spill
        }
        assert!(store.load("k3").is_none());
        // the temp file was cleaned up too
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains("tmp"))
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
