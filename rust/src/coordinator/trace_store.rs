//! On-disk spill store for simulation traces.
//!
//! The simulator is the most expensive stage of a sweep, and its output
//! depends only on (workload, core, cache geometry) — not on technology or
//! CiM placement.  Spilling each trace to `traces/trace-<key>.bin` lets
//! the same trace serve every tech/placement variant *across processes*,
//! not just within one coordinator's in-memory memo.
//!
//! Format (version 2, chunked): a versioned little-endian binary stream
//! (no third-party serialization crates exist in this environment):
//!
//! ```text
//! magic  version
//! (count>0, count × I-state record)*      — committed instructions, chunked
//! 0u32                                    — chunk terminator
//! program cycles committed stop pipe fu mem   — the TraceSummary trailer
//! ```
//!
//! The chunked layout serves the streaming pipeline on both sides: a
//! [`SpillWriter`] is a [`TraceSink`] that writes records as the simulator
//! commits them (the summary trailer lands in `finish`), and
//! [`TraceStore::replay`] feeds a sink chunk-by-chunk without ever
//! materializing the trace — both O(chunk) memory.  Loads are
//! best-effort: any corruption (or a version-1 file from an older build)
//! is treated as a cache miss and the trace is re-simulated and
//! re-written.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::isa::{FuncUnit, Instruction};
use crate::probes::{
    CollectSink, IState, MemAccessInfo, MemLevel, MemStats, PipeStats,
    StopReason, Trace, TraceSink, TraceSummary,
};

const MAGIC: u32 = 0x4543_5452; // "ECTR"
const VERSION: u32 = 2;

/// Records per chunk: bounds both writer batching and replay memory.
const CHUNK_RECORDS: u32 = 4096;

/// Upper bound accepted for on-disk chunk counts and string lengths —
/// anything larger is corruption, not data.
const SANITY_LIMIT: u32 = 1 << 24;

/// A directory of spilled traces, addressed by content-hash key.
pub struct TraceStore {
    dir: PathBuf,
}

impl TraceStore {
    /// Open (creating if needed) the spill directory.
    pub fn open(dir: &Path) -> Result<Self> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating trace store {dir:?}"))?;
        Ok(Self { dir: dir.to_path_buf() })
    }

    fn path_for(&self, key: &str) -> PathBuf {
        self.dir.join(format!("trace-{key}.bin"))
    }

    /// Stream a spilled trace into `sink` chunk-by-chunk; returns the
    /// summary trailer on success.  Any missing/corrupt/old-version file
    /// is a miss (`None`) — note the sink may already have consumed
    /// records by then, so treat its state as tainted on a miss.
    pub fn replay(&self, key: &str, sink: &mut dyn TraceSink) -> Option<TraceSummary> {
        let f = std::fs::File::open(self.path_for(key)).ok()?;
        let mut src = FileSource { r: BufReader::new(f) };
        decode_stream(&mut src, sink).ok()
    }

    /// Load a spilled trace, materialized; any missing/corrupt file is a
    /// miss.
    pub fn load(&self, key: &str) -> Option<Trace> {
        let mut sink = CollectSink::default();
        let summary = self.replay(key, &mut sink)?;
        Some(Trace::from_parts(summary, sink.ciq))
    }

    /// Open a streaming spill for `key`.  Feed it as a [`TraceSink`], then
    /// call [`SpillWriter::finish`] with the simulation summary; the trace
    /// is written to a temp file and renamed, so concurrent processes
    /// never observe a half-written trace.  Dropping without `finish`
    /// discards the temp file.
    ///
    /// The temp name carries a per-writer token on top of the pid: two
    /// sweep workers cold-spilling the same trace key concurrently (same
    /// geometry, different tech variants) must not truncate each other's
    /// in-progress file — last rename wins, both files stay intact.
    pub fn writer(&self, key: &str) -> Result<SpillWriter> {
        use std::sync::atomic::{AtomicU64, Ordering};
        static WRITER_TOKEN: AtomicU64 = AtomicU64::new(0);
        let token = WRITER_TOKEN.fetch_add(1, Ordering::Relaxed);
        let tmp = self
            .dir
            .join(format!("trace-{key}.tmp.{}.{token}", std::process::id()));
        let file = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {tmp:?}"))?;
        let mut w = SpillWriter {
            tmp,
            final_path: self.path_for(key),
            file: Some(BufWriter::new(file)),
            chunk: Vec::new(),
            pending: 0,
            error: None,
            finished: false,
        };
        let mut header = Writer { buf: Vec::with_capacity(8) };
        header.u32(MAGIC);
        header.u32(VERSION);
        w.write_bytes(&header.buf);
        Ok(w)
    }

    /// Spill a materialized trace (adapter over [`TraceStore::writer`]).
    pub fn store(&self, key: &str, trace: &Trace) -> Result<()> {
        let mut w = self.writer(key)?;
        for is in &trace.ciq {
            w.on_commit(is.clone());
        }
        w.finish(&trace.summary())
    }
}

/// Streaming trace spill: a [`TraceSink`] writing chunk-framed records.
/// IO errors are held internally (a full disk must not fail the sweep,
/// only future reuse) and surfaced by [`SpillWriter::finish`].
pub struct SpillWriter {
    tmp: PathBuf,
    final_path: PathBuf,
    file: Option<BufWriter<std::fs::File>>,
    chunk: Vec<u8>,
    pending: u32,
    error: Option<String>,
    finished: bool,
}

impl SpillWriter {
    fn write_bytes(&mut self, bytes: &[u8]) {
        if self.error.is_some() {
            return;
        }
        let Some(f) = self.file.as_mut() else { return };
        if let Err(e) = f.write_all(bytes) {
            self.error = Some(e.to_string());
            self.file = None;
        }
    }

    fn flush_chunk(&mut self) {
        if self.pending == 0 {
            return;
        }
        let count = self.pending.to_le_bytes();
        let mut chunk = std::mem::take(&mut self.chunk);
        self.pending = 0;
        self.write_bytes(&count);
        self.write_bytes(&chunk);
        chunk.clear();
        self.chunk = chunk; // reuse the allocation
    }

    /// Seal the spill with the summary trailer and publish it atomically.
    pub fn finish(mut self, summary: &TraceSummary) -> Result<()> {
        self.flush_chunk();
        let mut tail = Writer { buf: Vec::with_capacity(256) };
        tail.u32(0); // chunk terminator
        tail.summary(summary);
        self.write_bytes(&tail.buf);
        if self.error.is_none() {
            if let Some(f) = self.file.as_mut() {
                if let Err(e) = f.flush() {
                    self.error = Some(e.to_string());
                }
            }
        }
        self.file = None; // close before rename
        if let Some(e) = self.error.take() {
            // Drop removes the temp file
            return Err(anyhow!("writing trace spill: {e}"));
        }
        let res = std::fs::rename(&self.tmp, &self.final_path)
            .with_context(|| format!("publishing trace {:?}", self.final_path));
        if res.is_ok() {
            self.finished = true;
        }
        res
    }
}

impl TraceSink for SpillWriter {
    fn on_commit(&mut self, is: IState) {
        if self.error.is_some() {
            return;
        }
        let mut w = Writer { buf: std::mem::take(&mut self.chunk) };
        w.istate(&is);
        self.chunk = w.buf;
        self.pending += 1;
        if self.pending >= CHUNK_RECORDS {
            self.flush_chunk();
        }
    }
}

impl Drop for SpillWriter {
    fn drop(&mut self) {
        if !self.finished {
            self.file = None;
            let _ = std::fs::remove_file(&self.tmp);
        }
    }
}

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn u8(&mut self, x: u8) {
        self.buf.push(x);
    }

    fn u32(&mut self, x: u32) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    fn u64(&mut self, x: u64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    fn istate(&mut self, is: &IState) {
        self.u64(is.seq);
        self.u32(is.pc);
        self.u64(is.instr.encode());
        self.u8(is.fu as u8);
        self.u64(is.tick_fetch);
        self.u64(is.tick_decode);
        self.u64(is.tick_rename);
        self.u64(is.tick_dispatch);
        self.u64(is.tick_issue);
        self.u64(is.tick_complete);
        self.u64(is.tick_commit);
        match &is.mem {
            None => self.u8(0),
            Some(m) => {
                self.u8(1);
                self.u32(m.addr);
                self.u8(m.size);
                self.u8(m.is_store as u8);
                self.u8(level_to_u8(m.level));
                self.u32(m.bank);
                self.u8(m.l1_hit as u8);
                self.u8(m.l2_hit as u8);
                self.u8(m.mshr_merged as u8);
                self.u64(m.latency);
                self.u64(m.issue_tick);
            }
        }
    }

    fn summary(&mut self, s: &TraceSummary) {
        self.str(&s.program);
        self.u64(s.cycles);
        self.u64(s.committed);
        self.u8(stop_to_u8(s.stop));
        for x in pipe_fields(&s.pipe) {
            self.u64(x);
        }
        for x in s.pipe.fu_counts {
            self.u64(x);
        }
        for x in mem_fields(&s.mem) {
            self.u64(x);
        }
    }
}

/// Byte source abstraction so the same decoder serves in-memory slices
/// (tests, `decode`) and buffered files (`replay`) without materializing.
trait ByteSource {
    fn fill(&mut self, buf: &mut [u8]) -> Result<(), String>;
    /// True when the source is exhausted (trailing bytes are corruption).
    fn at_end(&mut self) -> Result<bool, String>;
}

struct SliceSource<'a> {
    b: &'a [u8],
    i: usize,
}

impl ByteSource for SliceSource<'_> {
    fn fill(&mut self, buf: &mut [u8]) -> Result<(), String> {
        let end = self
            .i
            .checked_add(buf.len())
            .filter(|&e| e <= self.b.len())
            .ok_or_else(|| format!("truncated trace at byte {}", self.i))?;
        buf.copy_from_slice(&self.b[self.i..end]);
        self.i = end;
        Ok(())
    }

    fn at_end(&mut self) -> Result<bool, String> {
        Ok(self.i == self.b.len())
    }
}

struct FileSource {
    r: BufReader<std::fs::File>,
}

impl ByteSource for FileSource {
    fn fill(&mut self, buf: &mut [u8]) -> Result<(), String> {
        self.r.read_exact(buf).map_err(|e| format!("reading trace: {e}"))
    }

    fn at_end(&mut self) -> Result<bool, String> {
        let mut probe = [0u8; 1];
        match self.r.read(&mut probe) {
            Ok(0) => Ok(true),
            Ok(_) => Ok(false),
            Err(e) => Err(format!("reading trace: {e}")),
        }
    }
}

fn r_u8<S: ByteSource>(s: &mut S) -> Result<u8, String> {
    let mut b = [0u8; 1];
    s.fill(&mut b)?;
    Ok(b[0])
}

fn r_u32<S: ByteSource>(s: &mut S) -> Result<u32, String> {
    let mut b = [0u8; 4];
    s.fill(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn r_u64<S: ByteSource>(s: &mut S) -> Result<u64, String> {
    let mut b = [0u8; 8];
    s.fill(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn r_str<S: ByteSource>(s: &mut S) -> Result<String, String> {
    let n = r_u32(s)?;
    if n > SANITY_LIMIT {
        return Err(format!("implausible string length {n}"));
    }
    let mut buf = vec![0u8; n as usize];
    s.fill(&mut buf)?;
    String::from_utf8(buf).map_err(|_| "bad utf8".to_string())
}

fn r_istate<S: ByteSource>(s: &mut S) -> Result<IState, String> {
    let seq = r_u64(s)?;
    let pc = r_u32(s)?;
    let instr = Instruction::decode(r_u64(s)?).ok_or("bad instruction word")?;
    let fu_idx = r_u8(s)? as usize;
    let fu = *FuncUnit::all()
        .get(fu_idx)
        .ok_or_else(|| format!("bad func unit {fu_idx}"))?;
    let tick_fetch = r_u64(s)?;
    let tick_decode = r_u64(s)?;
    let tick_rename = r_u64(s)?;
    let tick_dispatch = r_u64(s)?;
    let tick_issue = r_u64(s)?;
    let tick_complete = r_u64(s)?;
    let tick_commit = r_u64(s)?;
    let mem = match r_u8(s)? {
        0 => None,
        1 => Some(MemAccessInfo {
            addr: r_u32(s)?,
            size: r_u8(s)?,
            is_store: r_u8(s)? != 0,
            level: level_from_u8(r_u8(s)?)?,
            bank: r_u32(s)?,
            l1_hit: r_u8(s)? != 0,
            l2_hit: r_u8(s)? != 0,
            mshr_merged: r_u8(s)? != 0,
            latency: r_u64(s)?,
            issue_tick: r_u64(s)?,
        }),
        x => return Err(format!("bad mem flag {x}")),
    };
    Ok(IState {
        seq,
        pc,
        instr,
        fu,
        tick_fetch,
        tick_decode,
        tick_rename,
        tick_dispatch,
        tick_issue,
        tick_complete,
        tick_commit,
        mem,
    })
}

/// Decode a v2 stream, feeding records into `sink`; returns the trailer.
fn decode_stream<S: ByteSource>(
    src: &mut S,
    sink: &mut dyn TraceSink,
) -> Result<TraceSummary, String> {
    if r_u32(src)? != MAGIC {
        return Err("bad magic".into());
    }
    if r_u32(src)? != VERSION {
        return Err("unsupported trace version".into());
    }
    let mut records: u64 = 0;
    loop {
        let n = r_u32(src)?;
        if n == 0 {
            break;
        }
        if n > SANITY_LIMIT {
            return Err(format!("implausible chunk size {n}"));
        }
        for _ in 0..n {
            sink.on_commit(r_istate(src)?);
            records += 1;
        }
    }
    let program = r_str(src)?;
    let cycles = r_u64(src)?;
    let committed = r_u64(src)?;
    let stop = stop_from_u8(r_u8(src)?)?;
    let mut pf = [0u64; 16];
    for x in pf.iter_mut() {
        *x = r_u64(src)?;
    }
    let mut fu_counts = [0u64; crate::isa::func_unit::NUM_FUNC_UNITS];
    for x in fu_counts.iter_mut() {
        *x = r_u64(src)?;
    }
    let pipe = pipe_from_fields(pf, fu_counts);
    let mut mf = [0u64; 14];
    for x in mf.iter_mut() {
        *x = r_u64(src)?;
    }
    let mem = mem_from_fields(mf);
    if !src.at_end()? {
        return Err("trailing bytes after trailer".into());
    }
    if records != committed {
        return Err(format!(
            "record count {records} disagrees with trailer committed {committed}"
        ));
    }
    Ok(TraceSummary { program: program.into(), pipe, mem, cycles, committed, stop })
}

fn level_to_u8(l: MemLevel) -> u8 {
    match l {
        MemLevel::L1 => 0,
        MemLevel::L2 => 1,
        MemLevel::Dram => 2,
    }
}

fn level_from_u8(x: u8) -> Result<MemLevel, String> {
    match x {
        0 => Ok(MemLevel::L1),
        1 => Ok(MemLevel::L2),
        2 => Ok(MemLevel::Dram),
        _ => Err(format!("bad mem level {x}")),
    }
}

pub(crate) fn stop_to_u8(s: StopReason) -> u8 {
    match s {
        StopReason::Halt => 0,
        StopReason::MaxInstructions => 1,
        StopReason::RanOffEnd => 2,
    }
}

pub(crate) fn stop_from_u8(x: u8) -> Result<StopReason, String> {
    match x {
        0 => Ok(StopReason::Halt),
        1 => Ok(StopReason::MaxInstructions),
        2 => Ok(StopReason::RanOffEnd),
        _ => Err(format!("bad stop reason {x}")),
    }
}

pub(crate) fn pipe_fields(p: &PipeStats) -> [u64; 16] {
    [
        p.fetched,
        p.decoded,
        p.renamed,
        p.iq_reads,
        p.iq_writes,
        p.rob_reads,
        p.rob_writes,
        p.int_rf_reads,
        p.int_rf_writes,
        p.fp_rf_reads,
        p.fp_rf_writes,
        p.bpred_lookups,
        p.bpred_mispredicts,
        p.lsq_reads,
        p.lsq_writes,
        0, // reserved
    ]
}

pub(crate) fn pipe_from_fields(
    f: [u64; 16],
    fu_counts: [u64; crate::isa::func_unit::NUM_FUNC_UNITS],
) -> PipeStats {
    PipeStats {
        fetched: f[0],
        decoded: f[1],
        renamed: f[2],
        iq_reads: f[3],
        iq_writes: f[4],
        rob_reads: f[5],
        rob_writes: f[6],
        int_rf_reads: f[7],
        int_rf_writes: f[8],
        fp_rf_reads: f[9],
        fp_rf_writes: f[10],
        fu_counts,
        bpred_lookups: f[11],
        bpred_mispredicts: f[12],
        lsq_reads: f[13],
        lsq_writes: f[14],
    }
}

pub(crate) fn mem_fields(m: &MemStats) -> [u64; 14] {
    [
        m.l1i_hits,
        m.l1i_misses,
        m.l1d_read_hits,
        m.l1d_read_misses,
        m.l1d_write_hits,
        m.l1d_write_misses,
        m.l2_read_hits,
        m.l2_read_misses,
        m.l2_write_hits,
        m.l2_write_misses,
        m.dram_reads,
        m.dram_writes,
        m.writebacks,
        m.mshr_merges,
    ]
}

pub(crate) fn mem_from_fields(f: [u64; 14]) -> MemStats {
    MemStats {
        l1i_hits: f[0],
        l1i_misses: f[1],
        l1d_read_hits: f[2],
        l1d_read_misses: f[3],
        l1d_write_hits: f[4],
        l1d_write_misses: f[5],
        l2_read_hits: f[6],
        l2_read_misses: f[7],
        l2_write_hits: f[8],
        l2_write_misses: f[9],
        dram_reads: f[10],
        dram_writes: f[11],
        writebacks: f[12],
        mshr_merges: f[13],
    }
}

/// Serialize a materialized trace to the versioned binary format (the
/// slice twin of [`SpillWriter`] — byte-identical output).
pub fn encode(t: &Trace) -> Vec<u8> {
    let mut w = Writer { buf: Vec::with_capacity(64 + t.ciq.len() * 96) };
    w.u32(MAGIC);
    w.u32(VERSION);
    for chunk in t.ciq.chunks(CHUNK_RECORDS as usize) {
        w.u32(chunk.len() as u32);
        for is in chunk {
            w.istate(is);
        }
    }
    w.u32(0);
    w.summary(&t.summary());
    w.buf
}

/// Parse a trace from the binary format; errors on any inconsistency.
pub fn decode(bytes: &[u8]) -> Result<Trace, String> {
    let mut src = SliceSource { b: bytes, i: 0 };
    let mut sink = CollectSink::default();
    let summary = decode_stream(&mut src, &mut sink)?;
    Ok(Trace::from_parts(summary, sink.ciq))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::sim::{simulate, Limits};
    use crate::workloads;

    fn sample_trace() -> Trace {
        let prog = workloads::build("lcs", 2, 3).unwrap();
        let cfg = SystemConfig::preset("c1").unwrap();
        simulate(&prog, &cfg, Limits::default()).unwrap()
    }

    fn assert_traces_equal(a: &Trace, b: &Trace) {
        assert_eq!(a.program, b.program);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.committed, b.committed);
        assert_eq!(a.stop, b.stop);
        assert_eq!(pipe_fields(&a.pipe), pipe_fields(&b.pipe));
        assert_eq!(a.pipe.fu_counts, b.pipe.fu_counts);
        assert_eq!(mem_fields(&a.mem), mem_fields(&b.mem));
        assert_eq!(a.ciq.len(), b.ciq.len());
        for (x, y) in a.ciq.iter().zip(&b.ciq) {
            assert_eq!(x.seq, y.seq);
            assert_eq!(x.instr, y.instr);
            assert_eq!(x.fu, y.fu);
            assert_eq!(x.tick_commit, y.tick_commit);
            assert_eq!(x.mem.is_some(), y.mem.is_some());
            if let (Some(m), Some(n)) = (&x.mem, &y.mem) {
                assert_eq!(m.addr, n.addr);
                assert_eq!(m.level, n.level);
                assert_eq!(m.latency, n.latency);
            }
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let t = sample_trace();
        let decoded = decode(&encode(&t)).unwrap();
        assert_traces_equal(&t, &decoded);
    }

    #[test]
    fn decode_rejects_corruption() {
        let t = sample_trace();
        let mut bytes = encode(&t);
        assert!(decode(&bytes[..bytes.len() - 1]).is_err());
        bytes[0] ^= 0xff;
        assert!(decode(&bytes).is_err());
        assert!(decode(b"").is_err());
    }

    #[test]
    fn store_roundtrip_via_disk() {
        let dir = std::env::temp_dir().join(format!(
            "eva-cim-trace-store-test-{}",
            std::process::id()
        ));
        let store = TraceStore::open(&dir).unwrap();
        let t = sample_trace();
        assert!(store.load("k1").is_none());
        store.store("k1", &t).unwrap();
        let back = store.load("k1").unwrap();
        assert_traces_equal(&t, &back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn streaming_writer_matches_encode_and_replays_in_chunks() {
        let dir = std::env::temp_dir().join(format!(
            "eva-cim-trace-stream-test-{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        let store = TraceStore::open(&dir).unwrap();
        let t = sample_trace();

        // streaming spill, record by record
        let mut w = store.writer("k2").unwrap();
        for is in &t.ciq {
            w.on_commit(is.clone());
        }
        w.finish(&t.summary()).unwrap();

        // on disk: byte-identical to the slice encoder
        let disk = std::fs::read(dir.join("trace-k2.bin")).unwrap();
        assert_eq!(disk, encode(&t));

        // replay streams the same records and trailer
        let mut sink = CollectSink::default();
        let summary = store.replay("k2", &mut sink).unwrap();
        assert_traces_equal(&t, &Trace::from_parts(summary, sink.ciq));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unfinished_writer_leaves_no_published_trace() {
        let dir = std::env::temp_dir().join(format!(
            "eva-cim-trace-drop-test-{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        let store = TraceStore::open(&dir).unwrap();
        let t = sample_trace();
        {
            let mut w = store.writer("k3").unwrap();
            for is in t.ciq.iter().take(5) {
                w.on_commit(is.clone());
            }
            // dropped without finish: simulated crash mid-spill
        }
        assert!(store.load("k3").is_none());
        // the temp file was cleaned up too
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains("tmp"))
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
