//! Stable (de)serialization of [`SweepRow`] for the on-disk result cache.
//!
//! The serialization must be *canonical*: object keys come from a
//! `BTreeMap` (sorted), and `util::json` prints `f64`s with Rust's
//! shortest-roundtrip formatter, so `parse(dump(x)) == x` bit-for-bit.
//! That property is what lets a resumed sweep return byte-identical rows
//! to a cold sweep — `tests/sweep_cache.rs` asserts it.

use crate::analyzer::Macr;
use crate::config::{CimLevels, Technology};
use crate::energy::calib::{NCOMP, NOPS};
use crate::profiler::ProfileResult;
use crate::util::json::Json;

use super::SweepRow;

pub(crate) fn arr(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

pub(crate) fn get_f64(o: &Json, key: &str) -> Result<f64, String> {
    o.req(key)?
        .as_f64()
        .ok_or_else(|| format!("key '{key}' is not a number"))
}

pub(crate) fn get_u64(o: &Json, key: &str) -> Result<u64, String> {
    Ok(get_f64(o, key)? as u64)
}

pub(crate) fn get_str<'a>(o: &'a Json, key: &str) -> Result<&'a str, String> {
    o.req(key)?
        .as_str()
        .ok_or_else(|| format!("key '{key}' is not a string"))
}

pub(crate) fn get_f64_array<const N: usize>(o: &Json, key: &str) -> Result<[f64; N], String> {
    let xs = o
        .req(key)?
        .as_arr()
        .ok_or_else(|| format!("key '{key}' is not an array"))?;
    if xs.len() != N {
        return Err(format!("key '{key}': expected {N} elements, got {}", xs.len()));
    }
    let mut out = [0.0; N];
    for (i, x) in xs.iter().enumerate() {
        out[i] = x
            .as_f64()
            .ok_or_else(|| format!("key '{key}'[{i}] is not a number"))?;
    }
    Ok(out)
}

fn macr_to_json(m: &Macr) -> Json {
    Json::obj(vec![
        ("total_accesses", m.total_accesses.into()),
        ("convertible", m.convertible.into()),
        ("convertible_l1", m.convertible_l1.into()),
        ("convertible_other", m.convertible_other.into()),
        ("cim_ops", m.cim_ops.into()),
    ])
}

fn macr_from_json(o: &Json) -> Result<Macr, String> {
    Ok(Macr {
        total_accesses: get_u64(o, "total_accesses")?,
        convertible: get_u64(o, "convertible")?,
        convertible_l1: get_u64(o, "convertible_l1")?,
        convertible_other: get_u64(o, "convertible_other")?,
        cim_ops: get_u64(o, "cim_ops")?,
    })
}

fn result_to_json(r: &ProfileResult) -> Json {
    Json::obj(vec![
        ("comps_base", arr(&r.comps_base)),
        ("comps_cim", arr(&r.comps_cim)),
        ("total_base", r.total_base.into()),
        ("total_cim", r.total_cim.into()),
        ("improvement", r.improvement.into()),
        ("speedup", r.speedup.into()),
        ("ratio_proc", r.ratio_proc.into()),
        ("ratio_cache", r.ratio_cache.into()),
        ("e_l1", arr(&r.e_l1)),
        ("lat_l1", arr(&r.lat_l1)),
        ("e_l2", arr(&r.e_l2)),
        ("lat_l2", arr(&r.lat_l2)),
    ])
}

fn result_from_json(o: &Json) -> Result<ProfileResult, String> {
    Ok(ProfileResult {
        comps_base: get_f64_array::<NCOMP>(o, "comps_base")?,
        comps_cim: get_f64_array::<NCOMP>(o, "comps_cim")?,
        total_base: get_f64(o, "total_base")?,
        total_cim: get_f64(o, "total_cim")?,
        improvement: get_f64(o, "improvement")?,
        speedup: get_f64(o, "speedup")?,
        ratio_proc: get_f64(o, "ratio_proc")?,
        ratio_cache: get_f64(o, "ratio_cache")?,
        e_l1: get_f64_array::<NOPS>(o, "e_l1")?,
        lat_l1: get_f64_array::<NOPS>(o, "lat_l1")?,
        e_l2: get_f64_array::<NOPS>(o, "e_l2")?,
        lat_l2: get_f64_array::<NOPS>(o, "lat_l2")?,
    })
}

/// Canonical JSON form of a sweep row.
pub fn row_to_json(row: &SweepRow) -> Json {
    Json::obj(vec![
        ("bench", row.bench.as_str().into()),
        ("config_name", row.config_name.as_str().into()),
        ("tech", row.tech.name().into()),
        ("cim_levels", row.cim_levels.name().into()),
        ("macr", macr_to_json(&row.macr)),
        ("committed", row.committed.into()),
        ("cycles", row.cycles.into()),
        ("removed", row.removed.into()),
        ("cim_ops", row.cim_ops.into()),
        ("result", result_to_json(&row.result)),
    ])
}

/// Parse a sweep row back from its canonical JSON form.
pub fn row_from_json(o: &Json) -> Result<SweepRow, String> {
    let tech_name = get_str(o, "tech")?;
    let tech = Technology::from_name(tech_name).ok_or_else(|| {
        format!(
            "unknown tech '{tech_name}' (custom technologies must be \
             registered — e.g. via --tech-file — before their cached rows \
             can be read back)"
        )
    })?;
    let cim_name = get_str(o, "cim_levels")?;
    let cim_levels = CimLevels::from_name(cim_name)
        .ok_or_else(|| format!("unknown cim levels '{cim_name}'"))?;
    Ok(SweepRow {
        bench: get_str(o, "bench")?.to_string(),
        config_name: get_str(o, "config_name")?.to_string(),
        tech,
        cim_levels,
        macr: macr_from_json(o.req("macr")?)?,
        committed: get_u64(o, "committed")?,
        cycles: get_u64(o, "cycles")?,
        removed: get_u64(o, "removed")?,
        cim_ops: get_u64(o, "cim_ops")?,
        result: result_from_json(o.req("result")?)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_row() -> SweepRow {
        let mut result = ProfileResult {
            total_base: 1.25e7,
            total_cim: 9.5e6,
            improvement: 1.3157894736842106,
            speedup: 1.08,
            ratio_proc: 0.4,
            ratio_cache: 0.6,
            ..Default::default()
        };
        result.comps_base[0] = 123.456;
        result.e_l1[1] = 61.0;
        SweepRow {
            bench: "lcs".into(),
            config_name: "c1-sram".into(),
            tech: Technology::SRAM,
            cim_levels: CimLevels::Both,
            macr: Macr {
                total_accesses: 1000,
                convertible: 400,
                convertible_l1: 300,
                convertible_other: 100,
                cim_ops: 150,
            },
            committed: 123_456,
            cycles: 222_222,
            removed: 900,
            cim_ops: 150,
            result,
        }
    }

    #[test]
    fn row_roundtrips_byte_identically() {
        let row = sample_row();
        let dumped = row_to_json(&row).dump();
        let parsed = crate::util::json::parse(&dumped).unwrap();
        let row2 = row_from_json(&parsed).unwrap();
        assert_eq!(row_to_json(&row2).dump(), dumped);
    }

    #[test]
    fn row_from_json_rejects_malformed() {
        let mut o = row_to_json(&sample_row());
        if let Json::Obj(m) = &mut o {
            m.remove("cycles");
        }
        assert!(row_from_json(&o).is_err());
        assert!(row_from_json(&Json::Null).is_err());
    }
}
