//! Append-only on-disk result cache for sweep design points.
//!
//! Layout under the cache directory:
//!
//! ```text
//! <cache-dir>/
//!   cache-meta.json    {"schema": 1}  — version gate
//!   results.jsonl      one design point per line:
//!                      {"key":"<16-hex fnv1a>","row":{...canonical row...}}
//!   traces/            spilled simulation traces (trace_store.rs)
//!   analysis/          stage-2 analysis artifacts (analysis_store.rs):
//!     analysis-meta.json   {"schema": <analyzer schema>} — version stamp
//!     artifacts.jsonl      {"art":{...},"key":"<16-hex fnv1a>"} per line
//! ```
//!
//! Appends are the only mutation, so concurrent sweeps sharing a cache
//! directory can only ever duplicate work, never corrupt results (the
//! loader takes the last line per key).  A truncated or garbage line —
//! e.g. from a killed process — is quarantined to
//! `<cache-dir>/quarantine/` with a reason file (see
//! [`crate::util::faultio`]) rather than failing the whole sweep; every
//! filesystem call goes through the injectable [`faultio::StoreIo`]
//! layer with transient-fault retries.

use std::collections::HashMap;
use std::fs::File;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::util::faultio::{self, StoreIo as _};
use crate::util::json::{self, Json};
use crate::util::lock_unpoisoned;

use super::persist;
use super::SweepRow;

const RESULTS_FILE: &str = "results.jsonl";
const META_FILE: &str = "cache-meta.json";
const SCHEMA: u64 = 1;

/// An open result cache rooted at a directory.
pub struct ResultCache {
    dir: PathBuf,
    writer: Mutex<File>,
    /// `fsync` after every append (the crash-consistency policy knob —
    /// default off: a lost tail line only costs a recompute)
    fsync: bool,
}

impl ResultCache {
    /// Open (creating if needed) the cache at `dir`, verifying the schema.
    pub fn open(dir: &Path) -> Result<Self> {
        Self::open_with(dir, false)
    }

    /// [`ResultCache::open`] with an explicit fsync-on-append policy.
    pub fn open_with(dir: &Path, fsync: bool) -> Result<Self> {
        let io = faultio::fs();
        faultio::with_retries("creating cache dir", || io.create_dir_all(dir))
            .with_context(|| format!("creating cache dir {dir:?}"))?;
        let meta_path = dir.join(META_FILE);
        let stamp_meta = || -> Result<()> {
            let meta = Json::obj(vec![("schema", SCHEMA.into())]).dump();
            faultio::with_retries("writing cache meta", || {
                io.write(&meta_path, meta.as_bytes())
            })
            .with_context(|| format!("writing {meta_path:?}"))
        };
        match io.read_to_string(&meta_path) {
            Ok(text) => match json::parse(&text) {
                Ok(meta) => {
                    let schema = meta.get("schema").and_then(|v| v.as_u64());
                    if schema != Some(SCHEMA) {
                        bail!(
                            "cache {dir:?} has schema {schema:?}, this build \
                             expects {SCHEMA}; delete the directory to \
                             rebuild it"
                        );
                    }
                }
                Err(e) => {
                    // a torn meta stamp (crash or short write mid-open) is
                    // not a *mismatching* schema: quarantine the fragment
                    // and restamp, exactly as if the store were fresh
                    faultio::quarantine_bytes(
                        &dir.join(super::QUARANTINE_DIR),
                        &format!(
                            "cache-meta-{}.json",
                            faultio::content_tag(text.as_bytes())
                        ),
                        text.as_bytes(),
                        &format!("undecodable {META_FILE}: {e}"),
                    );
                    stamp_meta()?;
                }
            },
            Err(_) => stamp_meta()?,
        }
        let results = dir.join(RESULTS_FILE);
        let writer =
            faultio::with_retries("opening result cache", || io.open_append(&results))
                .with_context(|| format!("opening {RESULTS_FILE} in {dir:?}"))?;
        Ok(Self { dir: dir.to_path_buf(), writer: Mutex::new(writer), fsync })
    }

    /// Root directory this cache was opened at.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Read every cached row (last write per key wins).  A line that
    /// fails decode — truncated append, garbage, hand-edit — is
    /// quarantined to `<cache-dir>/quarantine/` with a reason file (and
    /// counted in the sweep ledger), never served and never fatal.
    pub fn load(&self) -> Result<HashMap<String, SweepRow>> {
        let path = self.dir.join(RESULTS_FILE);
        let text = match faultio::with_retries("reading result cache", || {
            faultio::fs().read_to_string(&path)
        }) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(HashMap::new())
            }
            Err(e) => {
                return Err(e).with_context(|| format!("reading {path:?}"))
            }
        };
        let mut rows = HashMap::new();
        let mut skipped = 0usize;
        let qdir = self.dir.join(super::QUARANTINE_DIR);
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            match parse_line(line) {
                Ok((key, row)) => {
                    rows.insert(key, row);
                }
                Err(e) => {
                    skipped += 1;
                    let name = format!(
                        "results-{}.line",
                        faultio::content_tag(line.as_bytes())
                    );
                    faultio::quarantine_bytes(
                        &qdir,
                        &name,
                        line.as_bytes(),
                        &format!("undecodable line in {RESULTS_FILE}: {e}"),
                    );
                }
            }
        }
        if skipped > 0 {
            eprintln!(
                "warning: skipped {skipped} malformed line(s) in {path:?} \
                 (quarantined under {qdir:?})"
            );
        }
        Ok(rows)
    }

    /// Append one computed row. Flushed immediately so a crash loses at
    /// most the in-flight line; transient write faults are retried with
    /// backoff, and a torn write is self-healed with a newline so the
    /// *next* append starts on a fresh line (the torn one quarantines on
    /// the next load).
    ///
    /// The writer lock is poison-tolerant: a worker that panicked while
    /// appending leaves at most one truncated line, which `load` already
    /// quarantines — the surviving workers must keep appending rather
    /// than cascade the panic across the sweep pool.
    pub fn append(&self, key: &str, row: &SweepRow) -> Result<()> {
        let line = Json::obj(vec![
            ("key", key.into()),
            ("row", persist::row_to_json(row)),
        ])
        .dump();
        let payload = format!("{line}\n");
        let path = self.dir.join(RESULTS_FILE);
        let io = faultio::fs();
        let mut f = lock_unpoisoned(&self.writer);
        if let Err(e) = faultio::with_retries("appending to result cache", || {
            io.write_all(&path, &mut f, payload.as_bytes())
        }) {
            // terminate any torn tail so later appends stay decodable
            use std::io::Write as _;
            let _ = f.write_all(b"\n");
            return Err(e).context("appending to result cache");
        }
        if self.fsync {
            faultio::with_retries("fsyncing result cache", || io.fsync(&path, &f))
                .context("fsyncing result cache")?;
        }
        Ok(())
    }
}

fn parse_line(line: &str) -> Result<(String, SweepRow), String> {
    let v = json::parse(line)?;
    let key = v
        .req("key")?
        .as_str()
        .ok_or_else(|| "key is not a string".to_string())?
        .to_string();
    let row = persist::row_from_json(v.req("row")?)?;
    Ok((key, row))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyzer::Macr;
    use crate::config::{CimLevels, Technology};
    use crate::profiler::ProfileResult;

    fn tmp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("eva-cim-cache-{tag}-{}", std::process::id()))
    }

    fn row(bench: &str) -> SweepRow {
        SweepRow {
            bench: bench.into(),
            config_name: "c1".into(),
            tech: Technology::SRAM,
            cim_levels: CimLevels::Both,
            macr: Macr {
                total_accesses: 10,
                convertible: 5,
                convertible_l1: 4,
                convertible_other: 1,
                cim_ops: 2,
            },
            committed: 100,
            cycles: 150,
            removed: 9,
            cim_ops: 2,
            result: ProfileResult { total_base: 1.5, ..Default::default() },
        }
    }

    #[test]
    fn append_then_load_roundtrips() {
        let dir = tmp_dir("roundtrip");
        std::fs::remove_dir_all(&dir).ok();
        let cache = ResultCache::open(&dir).unwrap();
        assert!(cache.load().unwrap().is_empty());
        cache.append("k1", &row("lcs")).unwrap();
        cache.append("k2", &row("km")).unwrap();
        // reopen to prove persistence across instances
        let cache2 = ResultCache::open(&dir).unwrap();
        let rows = cache2.load().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows["k1"].bench, "lcs");
        assert_eq!(rows["k2"].bench, "km");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn truncated_line_is_skipped_not_fatal() {
        let dir = tmp_dir("truncated");
        std::fs::remove_dir_all(&dir).ok();
        let cache = ResultCache::open(&dir).unwrap();
        cache.append("k1", &row("lcs")).unwrap();
        // simulate a crash mid-append
        let path = dir.join(RESULTS_FILE);
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str("{\"key\":\"k2\",\"row\":{\"bench\"");
        std::fs::write(&path, text).unwrap();
        let rows = cache.load().unwrap();
        assert_eq!(rows.len(), 1);
        assert!(rows.contains_key("k1"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn append_survives_a_poisoned_writer_lock() {
        let dir = tmp_dir("poison");
        std::fs::remove_dir_all(&dir).ok();
        let cache = std::sync::Arc::new(ResultCache::open(&dir).unwrap());
        let c2 = std::sync::Arc::clone(&cache);
        let _ = std::thread::spawn(move || {
            let _guard = c2.writer.lock().unwrap();
            panic!("worker dies while holding the writer lock");
        })
        .join();
        assert!(cache.writer.lock().is_err(), "lock should be poisoned");
        cache.append("k1", &row("lcs")).unwrap();
        let rows = cache.load().unwrap();
        assert_eq!(rows.len(), 1);
        assert!(rows.contains_key("k1"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn schema_mismatch_is_an_error() {
        let dir = tmp_dir("schema");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join(META_FILE), "{\"schema\": 999}").unwrap();
        assert!(ResultCache::open(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
