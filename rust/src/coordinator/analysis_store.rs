//! Persistable analysis artifacts — the middle stage of the
//! stage-factored sweep.
//!
//! The per-point pipeline factors into three independently keyed stages
//! (paper Fig 2: trace capture → dependency/selection analysis → energy
//! folding):
//!
//! 1. **simulate** — keyed by [`super::key::trace_key`], spilled to
//!    `traces/` ([`super::trace_store`]);
//! 2. **analyze** — keyed by [`super::key::analysis_key`] (trace key ×
//!    CiM placement × locality rule × [`ANALYZER_SCHEMA`]), persisted
//!    here;
//! 3. **energy fold** — per technology, microseconds, never cached.
//!
//! An [`AnalysisArtifact`] is everything the energy fold needs: the
//! simulation summary, the [`StreamOutcome`] aggregates and the finished
//! reshape [`DeltaSink`].  Technology enters only in stage 3, so one
//! artifact serves *every* technology variant of a design point — a
//! T-tech sweep performs P analyses, not T·P.
//!
//! Layout under `<cache-dir>/analysis/`:
//!
//! ```text
//! analysis-meta.json   {"schema": <ANALYZER_SCHEMA>} — version stamp; a
//!                      mismatch rotates artifacts.jsonl aside (miss,
//!                      never an error — see [`AnalysisStore::open`])
//! artifacts.jsonl      one artifact per line:
//!                      {"art":{...canonical json...},"key":"<16-hex fnv1a>"}
//! ```
//!
//! Same append-only discipline as the point cache ([`super::cache`]):
//! concurrent sweeps can only duplicate work, never corrupt artifacts;
//! the loader takes the last line per key and quarantines undecodable
//! lines to `<cache-dir>/quarantine/` (see [`crate::util::faultio`]).
//! Serialization is canonical (sorted keys, shortest-roundtrip `f64`s),
//! so a reloaded artifact folds into byte-identical sweep rows.

use std::collections::HashMap;
use std::fs::File;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::analyzer::{Macr, StreamOutcome};
use crate::probes::TraceSummary;
use crate::reshape::{DeltaSink, NC};
use crate::util::faultio::{self, StoreIo as _};
use crate::util::json::{self, Json};
use crate::util::lock_unpoisoned;

use super::persist::{arr, get_f64_array, get_str, get_u64};
use super::trace_store::{
    mem_fields, mem_from_fields, pipe_fields, pipe_from_fields, stop_from_u8,
    stop_to_u8,
};

/// Version of the online analyzer + reshape-delta contract.  Part of
/// every [`super::key::analysis_key`] *and* the store's schema gate: any
/// change to what the analyzer computes (selection order, rejection
/// accounting, delta layout) must bump it so stale artifacts are
/// unreachable by construction.
pub const ANALYZER_SCHEMA: u64 = 1;

const ARTIFACTS_FILE: &str = "artifacts.jsonl";
const META_FILE: &str = "analysis-meta.json";

/// The serializable product of one analysis pass: everything downstream
/// of the analyzer and upstream of the (per-technology) energy fold.
#[derive(Clone)]
pub struct AnalysisArtifact {
    /// simulation summary of the analyzed trace
    pub summary: TraceSummary,
    /// analyzer aggregates (MACR, IDG statistics, rejections, window)
    pub outcome: StreamOutcome,
    /// finished reshape deltas (signed counter deltas + removal totals)
    pub deltas: DeltaSink,
}

impl AnalysisArtifact {
    /// Assemble an artifact from one finished analysis lane (the shape
    /// `pipeline::AnalyzerFanout::finish` hands back per lane).
    pub fn new(
        summary: TraceSummary,
        outcome: StreamOutcome,
        deltas: DeltaSink,
    ) -> Self {
        Self { summary, outcome, deltas }
    }
}

const NUM_FU: usize = crate::isa::func_unit::NUM_FUNC_UNITS;

fn u64_arr(xs: &[u64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
}

fn get_u64_array<const N: usize>(o: &Json, key: &str) -> Result<[u64; N], String> {
    Ok(get_f64_array::<N>(o, key)?.map(|x| x as u64))
}

/// Canonical JSON form of an artifact.
pub fn artifact_to_json(a: &AnalysisArtifact) -> Json {
    Json::obj(vec![
        ("program", (&*a.summary.program).into()),
        ("cycles", a.summary.cycles.into()),
        ("committed", a.summary.committed.into()),
        ("stop", (stop_to_u8(a.summary.stop) as u64).into()),
        ("pipe", u64_arr(&pipe_fields(&a.summary.pipe))),
        ("fu", u64_arr(&a.summary.pipe.fu_counts)),
        ("mem", u64_arr(&mem_fields(&a.summary.mem))),
        (
            "macr",
            Json::obj(vec![
                ("total_accesses", a.outcome.macr.total_accesses.into()),
                ("convertible", a.outcome.macr.convertible.into()),
                ("convertible_l1", a.outcome.macr.convertible_l1.into()),
                ("convertible_other", a.outcome.macr.convertible_other.into()),
                ("cim_ops", a.outcome.macr.cim_ops.into()),
            ]),
        ),
        ("idg_total", a.outcome.idg_nodes.0.into()),
        ("idg_eligible", a.outcome.idg_nodes.1.into()),
        ("candidates", a.outcome.candidates.into()),
        ("rejected_locality", a.outcome.rejected_locality.into()),
        ("rejected_no_loads", a.outcome.rejected_no_loads.into()),
        ("rejected_dram", a.outcome.rejected_dram.into()),
        ("peak_window", (a.outcome.peak_window as u64).into()),
        ("delta", arr(&a.deltas.delta.0)),
        ("removed", a.deltas.removed.into()),
        ("cim_add", u64_arr(&a.deltas.cim_add)),
        ("cim_op_count", a.deltas.cim_op_count.into()),
    ])
}

/// Parse an artifact back from its canonical JSON form.
pub fn artifact_from_json(o: &Json) -> Result<AnalysisArtifact, String> {
    let macr_o = o.req("macr")?;
    let macr = Macr {
        total_accesses: get_u64(macr_o, "total_accesses")?,
        convertible: get_u64(macr_o, "convertible")?,
        convertible_l1: get_u64(macr_o, "convertible_l1")?,
        convertible_other: get_u64(macr_o, "convertible_other")?,
        cim_ops: get_u64(macr_o, "cim_ops")?,
    };
    let summary = TraceSummary {
        program: get_str(o, "program")?.into(),
        pipe: pipe_from_fields(
            get_u64_array::<16>(o, "pipe")?,
            get_u64_array::<NUM_FU>(o, "fu")?,
        ),
        mem: mem_from_fields(get_u64_array::<14>(o, "mem")?),
        cycles: get_u64(o, "cycles")?,
        committed: get_u64(o, "committed")?,
        stop: stop_from_u8(get_u64(o, "stop")? as u8)?,
    };
    let outcome = StreamOutcome {
        macr,
        idg_nodes: (get_u64(o, "idg_total")?, get_u64(o, "idg_eligible")?),
        candidates: get_u64(o, "candidates")?,
        rejected_locality: get_u64(o, "rejected_locality")?,
        rejected_no_loads: get_u64(o, "rejected_no_loads")?,
        rejected_dram: get_u64(o, "rejected_dram")?,
        peak_window: get_u64(o, "peak_window")? as usize,
    };
    let deltas = DeltaSink {
        delta: crate::reshape::DeltaCounters(get_f64_array::<NC>(o, "delta")?),
        removed: get_u64(o, "removed")?,
        cim_add: get_u64_array::<2>(o, "cim_add")?,
        cim_op_count: get_u64(o, "cim_op_count")?,
    };
    Ok(AnalysisArtifact { summary, outcome, deltas })
}

/// An open artifact store rooted at `<cache-dir>/analysis/`.
pub struct AnalysisStore {
    dir: PathBuf,
    writer: Mutex<File>,
    /// `fsync` after every append (crash-consistency policy knob)
    fsync: bool,
}

impl AnalysisStore {
    /// Open (creating if needed) the store at `dir`.
    ///
    /// A schema mismatch is *not* an error: stale artifacts are already
    /// unreachable (the analyzer schema is part of every analysis key),
    /// so the old `artifacts.jsonl` is rotated aside and a fresh store
    /// starts — an upgraded build must recompute, never fail the sweep.
    /// This mirrors the trace store's miss-don't-fail discipline; the
    /// *point* cache keeps its hard gate because its keys don't embed
    /// its schema.
    pub fn open(dir: &Path) -> Result<Self> {
        Self::open_with(dir, false)
    }

    /// [`AnalysisStore::open`] with an explicit fsync-on-append policy.
    pub fn open_with(dir: &Path, fsync: bool) -> Result<Self> {
        let io = faultio::fs();
        faultio::with_retries("creating analysis store", || io.create_dir_all(dir))
            .with_context(|| format!("creating analysis store {dir:?}"))?;
        let meta_path = dir.join(META_FILE);
        let stamp_meta = || -> Result<()> {
            let meta = Json::obj(vec![("schema", ANALYZER_SCHEMA.into())]).dump();
            faultio::with_retries("writing analysis meta", || {
                io.write(&meta_path, meta.as_bytes())
            })
            .with_context(|| format!("writing {meta_path:?}"))
        };
        match io.read_to_string(&meta_path) {
            Ok(text) => {
                let schema = json::parse(&text)
                    .ok()
                    .and_then(|m| m.get("schema").and_then(|v| v.as_u64()));
                if schema != Some(ANALYZER_SCHEMA) {
                    eprintln!(
                        "warning: analysis store {dir:?} has schema \
                         {schema:?}, this build expects {ANALYZER_SCHEMA}; \
                         rotating the old artifacts aside"
                    );
                    let tag = schema
                        .map(|s| s.to_string())
                        .unwrap_or_else(|| "unknown".into());
                    let _ = io.rename(
                        &dir.join(ARTIFACTS_FILE),
                        &dir.join(format!("{ARTIFACTS_FILE}.schema-{tag}")),
                    );
                    stamp_meta()?;
                }
            }
            Err(_) => stamp_meta()?,
        }
        let artifacts = dir.join(ARTIFACTS_FILE);
        let writer = faultio::with_retries("opening analysis store", || {
            io.open_append(&artifacts)
        })
        .with_context(|| format!("opening {ARTIFACTS_FILE} in {dir:?}"))?;
        Ok(Self { dir: dir.to_path_buf(), writer: Mutex::new(writer), fsync })
    }

    /// Quarantine directory shared with the sibling stores: the
    /// analysis store lives at `<cache-dir>/analysis/`, so bad entries
    /// land beside the point cache's under `<cache-dir>/quarantine/`.
    fn quarantine_dir(&self) -> PathBuf {
        self.dir
            .parent()
            .unwrap_or(&self.dir)
            .join(super::QUARANTINE_DIR)
    }

    /// Read every stored artifact (last write per key wins).
    /// Undecodable lines are quarantined, like the point cache's loader.
    pub fn load(&self) -> Result<HashMap<String, AnalysisArtifact>> {
        self.load_filtered(None)
    }

    /// [`AnalysisStore::load`] restricted to the given keys: lines whose
    /// trailing key is not wanted are skipped *without* parsing their
    /// artifact payload, so a sweep pays O(wanted) deserialization even
    /// when the store has accumulated O(history) artifacts.
    pub fn load_wanted(
        &self,
        wanted: &std::collections::HashSet<String>,
    ) -> Result<HashMap<String, AnalysisArtifact>> {
        self.load_filtered(Some(wanted))
    }

    fn load_filtered(
        &self,
        wanted: Option<&std::collections::HashSet<String>>,
    ) -> Result<HashMap<String, AnalysisArtifact>> {
        use std::io::BufRead as _;

        let path = self.dir.join(ARTIFACTS_FILE);
        let file = match faultio::with_retries("opening analysis artifacts", || {
            faultio::fs().open_read(&path)
        }) {
            Ok(f) => f,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(HashMap::new())
            }
            Err(e) => {
                return Err(e).with_context(|| format!("opening {path:?}"))
            }
        };
        let mut arts = HashMap::new();
        let mut skipped = 0usize;
        let qdir = self.quarantine_dir();
        // streamed line-by-line: peak memory is O(kept artifacts + one
        // line), not O(file) — the store accumulates history
        for line in std::io::BufReader::new(file).lines() {
            let line = match line {
                Ok(l) => l,
                Err(_) => {
                    // unreadable tail (io error / bad utf8): best-effort,
                    // like a truncated line
                    skipped += 1;
                    break;
                }
            };
            if line.trim().is_empty() {
                continue;
            }
            if let (Some(w), Some(k)) = (wanted, line_key(&line)) {
                if !w.contains(k) {
                    continue; // cheap reject: payload never parsed
                }
            }
            match parse_line(&line) {
                Ok((key, art)) => {
                    arts.insert(key, art);
                }
                Err(e) => {
                    skipped += 1;
                    let name = format!(
                        "artifacts-{}.line",
                        faultio::content_tag(line.as_bytes())
                    );
                    faultio::quarantine_bytes(
                        &qdir,
                        &name,
                        line.as_bytes(),
                        &format!("undecodable line in {ARTIFACTS_FILE}: {e}"),
                    );
                }
            }
        }
        if skipped > 0 {
            eprintln!(
                "warning: skipped {skipped} malformed line(s) in {path:?} \
                 (quarantined under {qdir:?})"
            );
        }
        Ok(arts)
    }

    /// Append one artifact.  Flushed immediately; transient faults are
    /// retried, torn tails are newline-healed, and the writer lock is
    /// poison-tolerant for the same reason as the point cache's.
    pub fn append(&self, key: &str, art: &AnalysisArtifact) -> Result<()> {
        let line = Json::obj(vec![
            ("key", key.into()),
            ("art", artifact_to_json(art)),
        ])
        .dump();
        let payload = format!("{line}\n");
        let path = self.dir.join(ARTIFACTS_FILE);
        let io = faultio::fs();
        let mut f = lock_unpoisoned(&self.writer);
        if let Err(e) = faultio::with_retries("appending to analysis store", || {
            io.write_all(&path, &mut f, payload.as_bytes())
        }) {
            // terminate any torn tail so later appends stay decodable
            use std::io::Write as _;
            let _ = f.write_all(b"\n");
            return Err(e).context("appending to analysis store");
        }
        if self.fsync {
            faultio::with_retries("fsyncing analysis store", || io.fsync(&path, &f))
                .context("fsyncing analysis store")?;
        }
        Ok(())
    }
}

/// Extract a line's key without parsing its artifact payload.  The
/// canonical serialization sorts object keys, so `"key"` is the final
/// member: `{"art":{...},"key":"<16-hex>"}`.  Lines that don't match the
/// shape (hand-edited, corrupt) return `None` and fall through to the
/// full parser, which decides between keep and skip.
fn line_key(line: &str) -> Option<&str> {
    let start = line.rfind("\"key\":\"")? + "\"key\":\"".len();
    let rest = &line[start..];
    let end = rest.find('"')?;
    Some(&rest[..end])
}

fn parse_line(line: &str) -> Result<(String, AnalysisArtifact), String> {
    let v = json::parse(line)?;
    let key = v
        .req("key")?
        .as_str()
        .ok_or_else(|| "key is not a string".to_string())?
        .to_string();
    let art = artifact_from_json(v.req("art")?)?;
    Ok((key, art))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyzer::LocalityRule;
    use crate::config::SystemConfig;
    use crate::pipeline::run_pipelined;
    use crate::sim::Limits;
    use crate::workloads;

    fn tmp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir()
            .join(format!("eva-cim-analysis-{tag}-{}", std::process::id()))
    }

    fn sample_artifact() -> AnalysisArtifact {
        let prog = workloads::build("lcs", 2, 3).unwrap();
        let cfg = SystemConfig::preset("c1").unwrap();
        let (summary, outcome, deltas) = run_pipelined(
            &prog,
            &cfg,
            Limits::default(),
            LocalityRule::AnyCache,
            DeltaSink::default(),
            None,
        )
        .unwrap();
        AnalysisArtifact { summary, outcome, deltas }
    }

    #[test]
    fn artifact_roundtrips_byte_identically() {
        let art = sample_artifact();
        let dumped = artifact_to_json(&art).dump();
        let parsed = json::parse(&dumped).unwrap();
        let art2 = artifact_from_json(&parsed).unwrap();
        assert_eq!(artifact_to_json(&art2).dump(), dumped);
        // and the parts that drive the energy fold survive exactly
        assert_eq!(art2.summary.committed, art.summary.committed);
        assert_eq!(art2.outcome.macr, art.outcome.macr);
        assert_eq!(art2.deltas.delta.0, art.deltas.delta.0);
        assert_eq!(art2.deltas.removed, art.deltas.removed);
    }

    #[test]
    fn store_roundtrips_and_skips_truncation() {
        let dir = tmp_dir("roundtrip");
        std::fs::remove_dir_all(&dir).ok();
        let store = AnalysisStore::open(&dir).unwrap();
        assert!(store.load().unwrap().is_empty());
        let art = sample_artifact();
        store.append("k1", &art).unwrap();
        // reopen as a new process would
        let store2 = AnalysisStore::open(&dir).unwrap();
        let arts = store2.load().unwrap();
        assert_eq!(arts.len(), 1);
        assert_eq!(
            artifact_to_json(&arts["k1"]).dump(),
            artifact_to_json(&art).dump()
        );
        // a crash mid-append must not poison future loads
        let path = dir.join(ARTIFACTS_FILE);
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str("{\"key\":\"k2\",\"art\"");
        std::fs::write(&path, text).unwrap();
        let arts = store2.load().unwrap();
        assert_eq!(arts.len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_wanted_filters_by_trailing_key() {
        let dir = tmp_dir("wanted");
        std::fs::remove_dir_all(&dir).ok();
        let store = AnalysisStore::open(&dir).unwrap();
        let art = sample_artifact();
        store.append("k1", &art).unwrap();
        store.append("k2", &art).unwrap();
        let line = Json::obj(vec![
            ("key", "k1".into()),
            ("art", artifact_to_json(&art)),
        ])
        .dump();
        assert_eq!(line_key(&line), Some("k1"));
        let wanted: std::collections::HashSet<String> =
            ["k2".to_string()].into_iter().collect();
        let arts = store.load_wanted(&wanted).unwrap();
        assert_eq!(arts.len(), 1);
        assert!(arts.contains_key("k2"));
        // unfiltered load still sees both
        assert_eq!(store.load().unwrap().len(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn schema_mismatch_rotates_the_store_instead_of_failing() {
        let dir = tmp_dir("schema");
        std::fs::remove_dir_all(&dir).ok();
        let store = AnalysisStore::open(&dir).unwrap();
        store.append("k1", &sample_artifact()).unwrap();
        drop(store);
        // an older/newer build stamped a different analyzer schema
        std::fs::write(dir.join(META_FILE), "{\"schema\": 999}").unwrap();
        let store = AnalysisStore::open(&dir).unwrap();
        // the incompatible artifacts were rotated aside, not served
        assert!(store.load().unwrap().is_empty());
        assert!(dir.join(format!("{ARTIFACTS_FILE}.schema-999")).exists());
        // and the store is fully usable again under the current schema
        store.append("k2", &sample_artifact()).unwrap();
        assert_eq!(store.load().unwrap().len(), 1);
        let meta = std::fs::read_to_string(dir.join(META_FILE)).unwrap();
        assert!(meta.contains(&format!("{ANALYZER_SCHEMA}")));
        std::fs::remove_dir_all(&dir).ok();
    }
}
