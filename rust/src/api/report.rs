//! Structured evaluation reports — the single source of truth for every
//! output format.
//!
//! A [`Report`] is a tree of [`Section`]s; each section is a titled grid of
//! *typed* [`Cell`]s (numbers keep their full `f64` value and only carry a
//! display precision).  The three renderers all read the same value:
//!
//! * [`Report::render_table`] — aligned monospace text (via
//!   [`crate::util::TextTable`], which is now just a renderer),
//! * [`Report::render_csv`] — RFC-4180-ish CSV with the same formatted
//!   cells as the table,
//! * [`Report::render_json`] — canonical JSON with *raw* numeric values
//!   (full precision, fractions instead of percent strings), suitable for
//!   machine consumption.
//!
//! Canonical means byte-stable: object keys are sorted
//! ([`crate::util::json`] uses a `BTreeMap`) and floats print with Rust's
//! shortest-roundtrip formatter, so the same `Report` value always dumps
//! to the same bytes — `rust/tests/report_golden.rs` asserts this across
//! cold and cache-warm runs.
//!
//! Sweep-ledger data ([`SweepStats`] + elapsed time) rides on the report
//! for the CLI's stderr diagnostics but is deliberately *excluded* from
//! all three renderers: it differs between cold and cached runs and would
//! break byte-stability.

use crate::coordinator::{SweepRow, SweepStats};
use crate::util::json::Json;
use crate::util::table::{f as fnum, TextTable};
use crate::workloads;

/// One typed report cell.
///
/// Numeric variants keep the raw `f64`/`u64` and a display precision:
/// the table/CSV renderers format, the JSON renderer emits the raw value.
#[derive(Clone, Debug, PartialEq)]
pub enum Cell {
    /// absent value (renders as an empty cell, JSON `null`)
    Empty,
    /// free-form text
    Str(String),
    /// exact integer count
    Int(u64),
    /// fixed-point number shown with `prec` decimals
    Num {
        /// raw value
        v: f64,
        /// decimals in table/CSV form
        prec: usize,
    },
    /// fraction in `[0, 1]` shown as a percentage with `prec` decimals;
    /// JSON emits the *fraction*
    Pct {
        /// raw fraction
        v: f64,
        /// decimals in table/CSV form
        prec: usize,
    },
    /// number shown in signed scientific notation with `prec` decimals
    Sci {
        /// raw value
        v: f64,
        /// decimals in table/CSV form
        prec: usize,
    },
    /// boolean marker shown as `*` / empty (Pareto-frontier flags)
    Mark(bool),
}

impl Cell {
    /// Text cell.
    pub fn str(s: impl Into<String>) -> Cell {
        Cell::Str(s.into())
    }

    /// Integer cell.
    pub fn int(v: u64) -> Cell {
        Cell::Int(v)
    }

    /// Fixed-point cell with `prec` decimals.
    pub fn num(v: f64, prec: usize) -> Cell {
        Cell::Num { v, prec }
    }

    /// Percentage cell: `v` is the fraction (0.5 renders as `50.0%`).
    pub fn pct(v: f64, prec: usize) -> Cell {
        Cell::Pct { v, prec }
    }

    /// Scientific-notation cell.
    pub fn sci(v: f64, prec: usize) -> Cell {
        Cell::Sci { v, prec }
    }

    /// Formatted text form — shared by the table and CSV renderers.
    pub fn text(&self) -> String {
        match self {
            Cell::Empty => String::new(),
            Cell::Str(s) => s.clone(),
            Cell::Int(v) => format!("{v}"),
            Cell::Num { v, prec } => fnum(*v, *prec),
            Cell::Pct { v, prec } => format!("{:.*}%", *prec, *v * 100.0),
            Cell::Sci { v, prec } => format!("{:+.*e}", *prec, *v),
            Cell::Mark(m) => if *m { "*".into() } else { String::new() },
        }
    }

    /// Raw machine-readable form for the JSON renderer.  Non-finite
    /// numbers (NaN, ±∞ — e.g. a relative deviation against a zero
    /// reference) map to `null`: JSON has no literal for them, and one
    /// degenerate value must not make the whole document unparseable.
    pub fn to_json(&self) -> Json {
        match self {
            Cell::Empty => Json::Null,
            Cell::Str(s) => Json::Str(s.clone()),
            Cell::Int(v) => Json::Num(*v as f64),
            Cell::Num { v, .. } | Cell::Pct { v, .. } | Cell::Sci { v, .. } => {
                if v.is_finite() {
                    Json::Num(*v)
                } else {
                    Json::Null
                }
            }
            Cell::Mark(m) => Json::Bool(*m),
        }
    }
}

/// A titled grid of typed cells — one table/figure of a report.
pub struct Section {
    /// section heading (printed above the table, `title` key in JSON)
    pub title: String,
    /// column names; unique within the section (they key the JSON rows)
    pub columns: Vec<String>,
    /// row-major cell grid; every row has `columns.len()` cells
    pub rows: Vec<Vec<Cell>>,
}

impl Section {
    /// New empty section.  Column names must be unique — they become the
    /// per-row JSON object keys.
    pub fn new(title: &str, columns: &[&str]) -> Self {
        for (i, c) in columns.iter().enumerate() {
            assert!(
                !columns[..i].contains(c),
                "duplicate report column '{c}' in section '{title}'"
            );
        }
        Self {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (width-checked against the columns).
    pub fn row(&mut self, cells: Vec<Cell>) -> &mut Self {
        assert_eq!(cells.len(), self.columns.len(), "report row width mismatch");
        self.rows.push(cells);
        self
    }

    /// Number of data rows.
    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    /// Cell at `row` in the column named `col`, if both exist.
    pub fn cell(&self, row: usize, col: &str) -> Option<&Cell> {
        let ci = self.columns.iter().position(|c| c == col)?;
        self.rows.get(row)?.get(ci)
    }

    /// Render through the legacy [`TextTable`] (now just a view).
    pub fn to_table(&self) -> TextTable {
        let headers: Vec<&str> = self.columns.iter().map(|s| s.as_str()).collect();
        let mut t = TextTable::new(&self.title, &headers);
        for r in &self.rows {
            t.row(r.iter().map(Cell::text).collect());
        }
        t
    }

    /// CSV form: header line + one line per row, formatted cells.
    pub fn to_csv(&self) -> String {
        self.to_table().to_csv()
    }

    /// Canonical JSON form: `{title, columns, rows: [{col: value, ...}]}`.
    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|r| {
                Json::Obj(
                    self.columns
                        .iter()
                        .cloned()
                        .zip(r.iter().map(Cell::to_json))
                        .collect(),
                )
            })
            .collect();
        Json::obj(vec![
            ("title", self.title.as_str().into()),
            (
                "columns",
                Json::Arr(self.columns.iter().map(|c| c.as_str().into()).collect()),
            ),
            ("rows", Json::Arr(rows)),
        ])
    }
}

/// Output format selector shared by every CLI subcommand (`--format`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Format {
    /// aligned monospace tables (the default)
    Table,
    /// canonical machine-readable JSON
    Json,
    /// CSV (one block per section)
    Csv,
}

impl Format {
    /// Parse a `--format` value.
    pub fn from_name(s: &str) -> Option<Format> {
        match s.to_ascii_lowercase().as_str() {
            "table" | "text" => Some(Format::Table),
            "json" => Some(Format::Json),
            "csv" => Some(Format::Csv),
            _ => None,
        }
    }

    /// Canonical name.
    pub fn name(&self) -> &'static str {
        match self {
            Format::Table => "table",
            Format::Json => "json",
            Format::Csv => "csv",
        }
    }
}

/// A structured evaluation result: titled sections plus the (non-rendered)
/// sweep ledger.  Every experiment, the sweep engine and the single-run
/// profiler all produce this one type; the CLI formats it with
/// [`Report::render_as`].
pub struct Report {
    /// report name (`title` key in JSON; not printed in table form —
    /// sections carry their own headings)
    pub title: String,
    /// the section tree
    pub sections: Vec<Section>,
    /// sweep cache/scale ledger when a coordinator sweep ran (stderr
    /// diagnostics only — never rendered, see module docs)
    pub stats: Option<SweepStats>,
    /// wall-clock seconds of the sweep behind `stats` (0 when none ran)
    pub elapsed_secs: f64,
    /// name of the backend that actually evaluated the sweep (`"native"`
    /// vs `"pjrt"` matters: the auto policy may silently fall back)
    pub backend: Option<&'static str>,
}

impl Report {
    /// New empty report.
    pub fn new(title: &str) -> Self {
        Self {
            title: title.to_string(),
            sections: Vec::new(),
            stats: None,
            elapsed_secs: 0.0,
            backend: None,
        }
    }

    /// Builder-style section append.
    pub fn with_section(mut self, s: Section) -> Self {
        self.sections.push(s);
        self
    }

    /// Attach the sweep ledger (builder-style).
    pub fn with_ledger(
        mut self,
        stats: SweepStats,
        elapsed_secs: f64,
        backend: &'static str,
    ) -> Self {
        self.stats = Some(stats);
        self.elapsed_secs = elapsed_secs;
        self.backend = Some(backend);
        self
    }

    /// Append another report's sections (ledger: last one wins).
    pub fn merged(mut self, other: Report) -> Self {
        self.sections.extend(other.sections);
        if other.stats.is_some() {
            self.stats = other.stats;
            self.elapsed_secs = other.elapsed_secs;
            self.backend = other.backend;
        }
        self
    }

    /// Total data rows across all sections.
    pub fn num_rows(&self) -> usize {
        self.sections.iter().map(Section::num_rows).sum()
    }

    /// Alias for [`Report::render_table`] (drop-in for the old
    /// `TextTable::render` call sites).
    pub fn render(&self) -> String {
        self.render_table()
    }

    /// All sections as aligned monospace tables, blank-line separated.
    pub fn render_table(&self) -> String {
        let blocks: Vec<String> =
            self.sections.iter().map(|s| s.to_table().render()).collect();
        blocks.join("\n")
    }

    /// CSV: a single section renders as plain `header\nrows...` (pipeable
    /// into any CSV reader); multiple sections are blank-line separated
    /// blocks, each preceded by a `# <title>` comment line.
    pub fn render_csv(&self) -> String {
        if self.sections.len() == 1 {
            return self.sections[0].to_csv();
        }
        let blocks: Vec<String> = self
            .sections
            .iter()
            .map(|s| {
                if s.title.is_empty() {
                    s.to_csv()
                } else {
                    format!("# {}\n{}", s.title, s.to_csv())
                }
            })
            .collect();
        blocks.join("\n")
    }

    /// Canonical JSON document (newline-terminated).
    pub fn render_json(&self) -> String {
        let mut s = self.to_json().dump();
        s.push('\n');
        s
    }

    /// The canonical JSON value: `{schema, title, sections}`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", 1u64.into()),
            ("title", self.title.as_str().into()),
            (
                "sections",
                Json::Arr(self.sections.iter().map(Section::to_json).collect()),
            ),
        ])
    }

    /// Render in the requested format.
    pub fn render_as(&self, format: Format) -> String {
        match format {
            Format::Table => self.render_table(),
            Format::Json => self.render_json(),
            Format::Csv => self.render_csv(),
        }
    }
}

/// Pivot sweep rows into a bench × config grid: one row per entry of
/// `benches`, one column per `(header, config_name)` pair, cell values
/// drawn by `value` from the matching row ([`Cell::Empty`] when a point is
/// missing).  This is the shape of the paper's Figs 14/15 tables.
pub fn pivot(
    title: &str,
    benches: &[&str],
    rows: &[SweepRow],
    cols: &[(&str, &str)],
    value: impl Fn(&SweepRow) -> Cell,
) -> Section {
    let mut headers = vec!["bench"];
    headers.extend(cols.iter().map(|(h, _)| *h));
    let mut s = Section::new(title, &headers);
    for b in benches {
        let mut cells = vec![Cell::str(workloads::display_name(b))];
        for (_, cfg_name) in cols {
            cells.push(
                rows.iter()
                    .find(|r| r.bench == *b && r.config_name == *cfg_name)
                    .map(&value)
                    .unwrap_or(Cell::Empty),
            );
        }
        s.row(cells);
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn sample() -> Report {
        let mut s = Section::new("t1", &["name", "x", "share", "front"]);
        s.row(vec![
            Cell::str("a"),
            Cell::num(1.25, 2),
            Cell::pct(0.5, 1),
            Cell::Mark(true),
        ]);
        s.row(vec![Cell::str("b,c"), Cell::int(7), Cell::Empty, Cell::Mark(false)]);
        Report::new("sample").with_section(s)
    }

    #[test]
    fn all_three_formats_render_from_one_value() {
        let r = sample();
        let table = r.render_table();
        assert!(table.contains("t1") && table.contains("1.25") && table.contains("50.0%"));
        let csv = r.render_csv();
        assert_eq!(csv.lines().next().unwrap(), "name,x,share,front");
        assert!(csv.contains("\"b,c\",7,,"));
        let j = json::parse(&r.render_json()).unwrap();
        assert_eq!(j.get("schema").unwrap().as_u64(), Some(1));
        let row0 = j.get("sections").unwrap().idx(0).unwrap().get("rows").unwrap().idx(0).unwrap();
        // JSON carries raw values: the fraction, not the percent string
        assert_eq!(row0.get("share").unwrap().as_f64(), Some(0.5));
        assert_eq!(row0.get("front").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn json_is_canonical_and_roundtrips() {
        let r = sample();
        let dumped = r.to_json().dump();
        let parsed = json::parse(&dumped).unwrap();
        assert_eq!(parsed.dump(), dumped);
        assert_eq!(r.render_json(), r.render_json());
    }

    #[test]
    fn multi_section_csv_marks_sections() {
        let r = sample().merged(sample());
        let csv = r.render_csv();
        assert_eq!(csv.matches("# t1").count(), 2);
    }

    #[test]
    fn cell_text_forms() {
        assert_eq!(Cell::pct(0.123, 1).text(), "12.3%");
        assert_eq!(Cell::num(2.0, 2).text(), "2.00");
        assert_eq!(Cell::int(42).text(), "42");
        assert_eq!(Cell::sci(-1234.5, 2).text(), "-1.23e3");
        assert_eq!(Cell::Mark(true).text(), "*");
        assert_eq!(Cell::Empty.text(), "");
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        let mut s = Section::new("nf", &["x", "y", "z"]);
        s.row(vec![
            Cell::num(f64::INFINITY, 2),
            Cell::pct(f64::NAN, 1),
            Cell::num(1.5, 1),
        ]);
        let r = Report::new("nf").with_section(s);
        let doc = r.render_json();
        let parsed = json::parse(&doc).unwrap();
        let row = parsed.get("sections").unwrap().idx(0).unwrap()
            .get("rows").unwrap().idx(0).unwrap();
        assert_eq!(row.get("x"), Some(&json::Json::Null));
        assert_eq!(row.get("y"), Some(&json::Json::Null));
        assert_eq!(row.get("z").unwrap().as_f64(), Some(1.5));
    }

    #[test]
    fn format_names() {
        assert_eq!(Format::from_name("JSON"), Some(Format::Json));
        assert_eq!(Format::from_name("table"), Some(Format::Table));
        assert_eq!(Format::from_name("csv").unwrap().name(), "csv");
        assert!(Format::from_name("yaml").is_none());
    }

    #[test]
    #[should_panic]
    fn duplicate_columns_rejected() {
        Section::new("bad", &["a", "a"]);
    }
}
