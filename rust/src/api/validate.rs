//! Model-validation primitives (paper §VI-A/§VI-B): the two comparisons
//! the paper uses to establish trust in the framework, packaged as
//! structured [`Report`]s so `eva-cim validate`, `eva-cim table
//! table5|fig12` and the bench targets all share one implementation.

use anyhow::Result;

use crate::analyzer::{self, baseline, LocalityRule};
use crate::config::SystemConfig;
use crate::energy::{self, calib::*};
use crate::profiler::ProfileInputs;
use crate::reshape;
use crate::runtime::Backend;
use crate::sim::{simulate, Limits};
use crate::util::stats;
use crate::workloads;

use super::{Cell, Report, Section};

/// Table V: Eva-CiM vs array-level-only (DESTINY) energy on an LCS trace.
///
/// The paper reports ≈24% deviation for both CiM and non-CiM instructions:
/// Eva-CiM adds the multi-level-hierarchy effects (misses, refills, core
/// interactions) that the array-only estimate omits.
pub fn destiny_comparison(backend: &mut dyn Backend, scale: usize) -> Result<Report> {
    let cfg = SystemConfig::preset("c1").unwrap();
    let prog = workloads::build("lcs", scale, 42).unwrap();
    let trace = simulate(&prog, &cfg, Limits::default())?;
    let analysis = analyzer::analyze(&trace, &cfg, LocalityRule::AnyCache);
    let reshaped = reshape::reshape(&trace, &analysis.selection, &cfg);
    let inputs = ProfileInputs::new(&cfg, &reshaped);
    let res = backend.evaluate_batch(&[inputs.clone()])?.remove(0);

    // Eva-CiM's memory-side energy split into CiM vs non-CiM portions.
    // The CiM share includes the hierarchy's data-locality management:
    // cross-level operand moves and result readbacks (§IV-C) — exactly the
    // effects the array-only estimate cannot see.
    let (e1, _) = energy::energy_latency(&inputs.cfg_l1);
    let (e2, _) = energy::energy_latency(&inputs.cfg_l2);
    let mut overhead = 0.0;
    for c in &analysis.selection.candidates {
        let (rd_src, wr_dst, rd_back) = match c.level {
            crate::probes::MemLevel::L2 => (e1[OP_READ], e2[OP_WRITE], e2[OP_READ]),
            _ => (e2[OP_READ], e1[OP_WRITE], e1[OP_READ]),
        };
        overhead += c.moves as f64 * (rd_src + wr_dst);
        overhead += c.readbacks as f64 * rd_back;
        // rereads of operands shared with earlier candidates
        overhead += c.shared_loads.len() as f64 * rd_back;
    }
    let eva_cim = (res.comps_cim[COMP_CIM_L1] + res.comps_cim[COMP_CIM_L2]
        + overhead) / 1000.0;
    // compare at *array* level (÷ XBUS_FACTOR): DESTINY models the array
    // only, so the H-tree/bus transport must be excluded on both sides —
    // the remaining deviation is the hierarchy-event accounting (misses,
    // refills, I-fetch traffic) that Eva-CiM adds on top of DESTINY.
    let eva_non = (res.comps_cim[COMP_L1I] + res.comps_cim[COMP_L1D]
        + res.comps_cim[COMP_L2]) / XBUS_FACTOR / 1000.0;
    // array-only (DESTINY-style) estimate of the same reshaped activity
    let (d_cim, d_non) = energy::destiny_only_estimate(
        &inputs.counters_cim, &inputs.cfg_l1, &inputs.cfg_l2);
    let (d_cim, d_non) = (d_cim / 1000.0, d_non / 1000.0);

    let mut s = Section::new(
        "Table V — energy (nJ) comparison: array-only (DESTINY) vs Eva-CiM (LCS trace)",
        &["model", "CiM", "non-CiM"],
    );
    s.row(vec![Cell::str("DESTINY (array-only)"), Cell::num(d_cim, 2), Cell::num(d_non, 2)]);
    s.row(vec![Cell::str("Eva-CiM"), Cell::num(eva_cim, 2), Cell::num(eva_non, 2)]);
    s.row(vec![
        Cell::str("Deviation"),
        Cell::pct(stats::rel_dev(eva_cim, d_cim), 1),
        Cell::pct(stats::rel_dev(eva_non, d_non), 1),
    ]);
    Ok(Report::new("table5").with_section(s))
}

/// Fig 12: CiM-supported memory-access fraction, Eva-CiM vs Jain [23],
/// LCS over `runs` random inputs on the 1 MB SPM-like config.
pub fn macr_comparison(runs: usize, scale: usize) -> Result<Report> {
    let cfg = SystemConfig::preset("spm1mb").unwrap();
    let mut eva = Vec::new();
    let mut jain = Vec::new();
    for r in 0..runs {
        let prog = workloads::build("lcs", scale, 1000 + r as u64).unwrap();
        let trace = simulate(&prog, &cfg, Limits::default())?;
        let analysis = analyzer::analyze(&trace, &cfg, LocalityRule::AnyCache);
        eva.push(analysis.macr.ratio());
        jain.push(baseline::classify(&trace.ciq).cim_fraction());
    }
    let mut s = Section::new(
        &format!("Fig 12 — CiM-supported memory accesses on LCS ({runs} runs, 1MB config)"),
        &["method", "mean", "min", "max"],
    );
    for (name, xs) in [("Eva-CiM (IDG)", &eva), ("Jain et al. [23]", &jain)] {
        s.row(vec![
            Cell::str(name),
            Cell::pct(stats::mean(xs), 1),
            Cell::pct(stats::percentile(xs, 0.0), 1),
            Cell::pct(stats::percentile(xs, 100.0), 1),
        ]);
    }
    Ok(Report::new("fig12").with_section(s))
}

/// Per-technology, per-level device-model row at the paper's anchor
/// geometries — the data behind Table III (energies) and Fig 11
/// (latencies).
pub struct DeviceRow {
    /// technology handle
    pub tech: crate::config::Technology,
    /// `"L1"` or `"L2"`
    pub level: &'static str,
    /// geometry summary, e.g. `"4-way/64kB"`
    pub geometry: String,
    /// per-op energies (pJ), indexed by `OP_*`
    pub e: [f64; NOPS],
    /// per-op latencies (cycles), indexed by `OP_*`
    pub lat: [f64; NOPS],
}

/// Evaluate every given technology at the Table III anchor geometries
/// (L1 = 64 kB/4-way, L2 = 256 kB/8-way) through the device registry.
pub fn device_grid(techs: &[crate::config::Technology]) -> Vec<DeviceRow> {
    let mut out = Vec::new();
    for &tech in techs {
        for (level, cap_kb, assoc, lv) in
            [("L1", 64.0, 4.0, 1.0), ("L2", 256.0, 8.0, 2.0)]
        {
            let row = [cap_kb * 1024.0, assoc, 64.0, 4.0, tech.index() as f64, lv];
            let (e, lat) = energy::energy_latency(&row);
            out.push(DeviceRow {
                tech,
                level,
                geometry: format!("{}-way/{}kB", assoc as u32, cap_kb as u32),
                e,
                lat,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Technology;
    use crate::runtime::NativeBackend;

    #[test]
    fn destiny_comparison_has_the_three_model_rows() {
        let r = destiny_comparison(&mut NativeBackend, 2).unwrap();
        let s = &r.sections[0];
        assert_eq!(s.num_rows(), 3);
        assert!(matches!(s.cell(2, "model"), Some(Cell::Str(m)) if m.as_str() == "Deviation"));
    }

    #[test]
    fn device_grid_covers_levels_per_tech() {
        let g = device_grid(&[Technology::SRAM, Technology::FEFET]);
        assert_eq!(g.len(), 4);
        assert_eq!(g[0].e[OP_READ].round(), 61.0); // Table III anchor
        assert!(g.iter().all(|r| r.lat[OP_ADD] >= r.lat[OP_READ]));
    }
}
