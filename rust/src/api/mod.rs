//! The unified evaluation facade — the crate's public front door.
//!
//! Eva-CiM's promise is "give it a program, an architecture and a CiM
//! spec — get a system-level energy estimate" (paper §I).  [`Evaluation`]
//! is that promise as one typed builder: pick benchmarks, configurations,
//! technologies and sizing knobs, then ask for a structured [`Report`]:
//!
//! ```
//! use eva_cim::api::Evaluation;
//!
//! let report = Evaluation::new()
//!     .bench("lcs")
//!     .preset("c1")
//!     .scale(2)
//!     .run()
//!     .unwrap();
//! assert_eq!(report.sections[0].num_rows(), 1);
//! println!("{}", report.render_table()); // or render_json() / render_csv()
//! ```
//!
//! Everything downstream — the `eva-cim` CLI, the paper experiments in
//! [`crate::experiments`], the examples — is a thin composition over this
//! module.  The coordinator's shard/cache/backend wiring
//! ([`crate::coordinator::SweepOptions`], backend selection, the worker
//! pool) is absorbed behind the builder: callers state *what* to evaluate,
//! not how to stage it.

pub mod report;
pub mod validate;

pub use report::{Cell, Format, Report, Section};

use std::path::PathBuf;

use anyhow::{anyhow, bail, Result};

use crate::analyzer::{LocalityRule, StreamOutcome};
use crate::asm::Program;
use crate::config::{CimLevels, SystemConfig, Technology};
use crate::coordinator::{
    cross, Coordinator, SweepOptions, SweepPoint, SweepRow, SweepStats,
};
use crate::energy::{calib, device};
use crate::pipeline::run_pipelined;
use crate::planner::{PlanKnobs, PlanPolicy};
use crate::probes::TraceSummary;
use crate::profiler::ProfileInputs;
use crate::reshape::{reshape_from_deltas, DeltaSink, Reshaped};
use crate::runtime::{best_backend, Backend, NativeBackend, PjrtRuntime};
use crate::sim::Limits;
use crate::util::stats;
use crate::workloads;

/// Profiler-backend selection policy.
///
/// The AOT'd PJRT artifacts are lowered against the frozen two-row
/// SRAM/FeFET tech table, so `Auto` resolves to the native mirror whenever
/// a registry technology (RRAM, STT-MRAM, TOML customs) is in play, and an
/// explicit `Pjrt` fails up front instead of after the simulation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendSel {
    /// PJRT when its artifacts load *and* every technology is in the AOT
    /// table; native mirror otherwise (the default)
    Auto,
    /// always the native f64 mirror
    Native,
    /// the PJRT runtime, or an error when unavailable/uncovered
    Pjrt,
}

impl BackendSel {
    /// Parse a `--backend` value.
    pub fn from_name(s: &str) -> Option<BackendSel> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Some(BackendSel::Auto),
            "native" => Some(BackendSel::Native),
            "pjrt" => Some(BackendSel::Pjrt),
            _ => None,
        }
    }

    /// Canonical name.
    pub fn name(&self) -> &'static str {
        match self {
            BackendSel::Auto => "auto",
            BackendSel::Native => "native",
            BackendSel::Pjrt => "pjrt",
        }
    }

    /// Resolve to a concrete backend for a set of technologies about to be
    /// evaluated (see the type docs for the AOT-coverage rule).
    pub fn resolve(&self, techs: &[Technology]) -> Result<Box<dyn Backend>> {
        let outside_table =
            techs.iter().find(|t| t.index() >= calib::NTECH).copied();
        match self {
            BackendSel::Native => Ok(Box::new(NativeBackend)),
            BackendSel::Pjrt => {
                if let Some(t) = outside_table {
                    bail!(
                        "the pjrt backend only covers the {}-row AOT tech table \
                         (sram/fefet); technology '{}' needs the native backend",
                        calib::NTECH,
                        t.name()
                    );
                }
                PjrtRuntime::load(&PjrtRuntime::default_dir())
                    .map(|rt| Box::new(rt) as Box<dyn Backend>)
            }
            BackendSel::Auto => {
                if outside_table.is_some() {
                    Ok(Box::new(NativeBackend))
                } else {
                    Ok(best_backend(&PjrtRuntime::default_dir()))
                }
            }
        }
    }
}

/// Raw output of an [`Evaluation`] sweep: the structured rows plus the
/// cache/scale ledger.  Most callers want [`Evaluation::run`] (a rendered
/// [`Report`]); this is the escape hatch for custom post-processing.
pub struct Sweep {
    /// one row per design point, in point order
    pub rows: Vec<SweepRow>,
    /// what the sweep actually did (cache hits, simulator runs, windows)
    pub stats: SweepStats,
    /// wall-clock seconds
    pub elapsed_secs: f64,
    /// name of the backend that evaluated the points
    pub backend: &'static str,
}

/// The typed evaluation builder — see the [module docs](self) for the
/// one-paragraph tour and `README.md` § "Library usage" for a worked
/// example.
///
/// Empty selections fall back to sensible defaults: all 17 paper
/// benchmarks, the `c1` configuration, each configuration's own
/// technology, [`BackendSel::Auto`].
#[derive(Clone)]
pub struct Evaluation {
    benches: Vec<String>,
    presets: Vec<String>,
    explicit: Vec<SystemConfig>,
    techs: Vec<Technology>,
    cim_override: Option<CimLevels>,
    cim_variants: Vec<CimLevels>,
    rule: LocalityRule,
    backend: BackendSel,
    opts: SweepOptions,
    /// explicit simulator budget; `None` = each path's own default
    /// ([`SweepOptions`] for sweeps, [`Limits`] for single runs)
    max_instr: Option<u64>,
    /// offload-decision policy for [`Evaluation::plan`]
    policy: PlanPolicy,
    /// explicit planner-knob overrides; unset fields keep the policy's
    /// [`PlanPolicy::default_knobs`]
    min_ops: Option<u64>,
    min_net_pj: Option<f64>,
    plan_level: Option<CimLevels>,
}

impl Evaluation {
    /// A builder with the defaults described on the type.
    pub fn new() -> Self {
        Self {
            benches: Vec::new(),
            presets: Vec::new(),
            explicit: Vec::new(),
            techs: Vec::new(),
            cim_override: None,
            cim_variants: Vec::new(),
            rule: LocalityRule::AnyCache,
            backend: BackendSel::Auto,
            opts: SweepOptions::default(),
            max_instr: None,
            policy: PlanPolicy::AcceptAll,
            min_ops: None,
            min_net_pj: None,
            plan_level: None,
        }
    }

    /// Add one benchmark by name (see [`workloads::NAMES`]).
    pub fn bench(mut self, name: &str) -> Self {
        self.benches.push(name.to_string());
        self
    }

    /// Add several benchmarks by name.
    pub fn benches(mut self, names: &[&str]) -> Self {
        self.benches.extend(names.iter().map(|s| s.to_string()));
        self
    }

    /// Add a base configuration by preset name (see
    /// [`SystemConfig::preset`]).
    pub fn preset(mut self, name: &str) -> Self {
        self.presets.push(name.to_string());
        self
    }

    /// Add several presets.
    pub fn presets(mut self, names: &[&str]) -> Self {
        self.presets.extend(names.iter().map(|s| s.to_string()));
        self
    }

    /// Add an explicit base configuration (used verbatim, keeping its
    /// name — the way to evaluate custom geometries).
    pub fn config(mut self, cfg: SystemConfig) -> Self {
        self.explicit.push(cfg);
        self
    }

    /// Add several explicit base configurations.
    pub fn configs(mut self, cfgs: &[SystemConfig]) -> Self {
        self.explicit.extend(cfgs.iter().cloned());
        self
    }

    /// Cross every base configuration with this technology (the variant is
    /// named `{base}-{tech}`).  Repeatable.
    pub fn tech(mut self, tech: Technology) -> Self {
        self.techs.push(tech);
        self
    }

    /// Cross every base configuration with these technologies.
    pub fn techs(mut self, techs: &[Technology]) -> Self {
        self.techs.extend(techs.iter().copied());
        self
    }

    /// Force one CiM placement on every evaluated configuration (names
    /// unchanged).
    pub fn cim(mut self, cim: CimLevels) -> Self {
        self.cim_override = Some(cim);
        self
    }

    /// Cross every configuration with these CiM placements (the variant is
    /// named `{base}-{placement}` — the Fig 15 axis).
    pub fn cim_variants(mut self, cims: &[CimLevels]) -> Self {
        self.cim_variants.extend(cims.iter().copied());
        self
    }

    /// Candidate-selection locality rule (default
    /// [`LocalityRule::AnyCache`]).
    pub fn rule(mut self, rule: LocalityRule) -> Self {
        self.rule = rule;
        self
    }

    /// Backend selection policy (default [`BackendSel::Auto`]).
    pub fn backend(mut self, sel: BackendSel) -> Self {
        self.backend = sel;
        self
    }

    /// Absorb a whole [`SweepOptions`] (sizing, worker pool, cache).
    pub fn sweep(mut self, opts: SweepOptions) -> Self {
        self.opts = opts;
        self
    }

    /// Workload problem-size hint (0 = each workload's default).
    pub fn scale(mut self, scale: usize) -> Self {
        self.opts.scale = scale;
        self
    }

    /// Workload input RNG seed.
    pub fn seed(mut self, seed: u64) -> Self {
        self.opts.seed = seed;
        self
    }

    /// Worker-pool size for staging.
    pub fn jobs(mut self, jobs: usize) -> Self {
        self.opts.workers = jobs;
        self
    }

    /// Points per work-stealing chunk (0 = auto).
    pub fn chunk(mut self, chunk: usize) -> Self {
        self.opts.chunk = chunk;
        self
    }

    /// Decode-lane count for warm-trace replay (0 = auto, 1 =
    /// sequential).  A tuning knob only: every setting produces
    /// byte-identical rows and reports.
    pub fn replay_threads(mut self, n: usize) -> Self {
        self.opts.replay_threads = n;
        self
    }

    /// Root of the on-disk design-point + trace cache.
    pub fn cache_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.opts.cache_dir = Some(dir.into());
        self
    }

    /// Serve previously cached rows instead of recomputing them.
    pub fn resume(mut self, resume: bool) -> Self {
        self.opts.resume = resume;
        self
    }

    /// `fsync` the result/artifact stores after every append — the
    /// crash-consistency policy knob (default off: losing an unsynced
    /// tail line only costs a recompute).  A durability knob only: it
    /// never changes any cache key or any output byte.
    pub fn fsync(mut self, fsync: bool) -> Self {
        self.opts.fsync = fsync;
        self
    }

    /// Simulator instruction budget per design point.  Unset, each path
    /// keeps its own default: sweeps use the [`SweepOptions`] budget
    /// (part of the cache key), single runs the larger [`Limits`] default.
    pub fn max_instructions(mut self, n: u64) -> Self {
        self.max_instr = Some(n);
        self
    }

    /// Offload-decision policy for [`Evaluation::plan`] (default
    /// [`PlanPolicy::AcceptAll`]).
    pub fn policy(mut self, policy: PlanPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Planner knob: reject groups with fewer CiM ops than this.
    pub fn min_ops(mut self, n: u64) -> Self {
        self.min_ops = Some(n);
        self
    }

    /// Planner knob: reject groups whose net saving (pJ) falls below this.
    pub fn min_net_pj(mut self, pj: f64) -> Self {
        self.min_net_pj = Some(pj);
        self
    }

    /// Planner knob: placement filter applied at plan time.
    pub fn plan_level(mut self, level: CimLevels) -> Self {
        self.plan_level = Some(level);
        self
    }

    /// The effective planner knobs: the policy's
    /// [`PlanPolicy::default_knobs`] with this builder's explicit
    /// overrides applied.
    pub fn plan_knobs(&self) -> PlanKnobs {
        let mut knobs = self.policy.default_knobs();
        if let Some(n) = self.min_ops {
            knobs.min_ops = n;
        }
        if let Some(pj) = self.min_net_pj {
            knobs.min_net_pj = pj;
        }
        if let Some(level) = self.plan_level {
            knobs.level = level;
        }
        knobs
    }

    /// The coordinator options this evaluation will sweep with (explicit
    /// [`Evaluation::max_instructions`] applied) — for handing to the
    /// [`crate::experiments`] adapters.
    pub fn sweep_options(&self) -> SweepOptions {
        let mut opts = self.opts.clone();
        if let Some(n) = self.max_instr {
            opts.max_instructions = n;
        }
        opts
    }

    /// The benchmark list this evaluation will run (defaults applied).
    pub fn bench_list(&self) -> Vec<String> {
        if self.benches.is_empty() {
            workloads::NAMES.iter().map(|s| s.to_string()).collect()
        } else {
            self.benches.clone()
        }
    }

    /// Expand presets/explicit configs × technologies × CiM variants into
    /// the concrete configuration list (defaults applied).
    pub fn config_list(&self) -> Result<Vec<SystemConfig>> {
        if self.cim_override.is_some() && !self.cim_variants.is_empty() {
            // the override would silently stomp the placement the variant
            // names advertise
            bail!("set either .cim(..) or .cim_variants(..), not both");
        }
        let mut bases = Vec::new();
        for p in &self.presets {
            bases.push(
                SystemConfig::preset(p)
                    .ok_or_else(|| anyhow!("unknown preset '{p}'"))?,
            );
        }
        bases.extend(self.explicit.iter().cloned());
        if bases.is_empty() {
            bases.push(SystemConfig::preset("c1").expect("builtin preset"));
        }
        let mut out = bases;
        if !self.techs.is_empty() {
            out = out
                .iter()
                .flat_map(|base| {
                    self.techs.iter().map(|&tech| {
                        let mut c = base.clone().with_tech(tech);
                        c.name = format!("{}-{}", base.name, tech.name());
                        c
                    })
                })
                .collect();
        }
        if !self.cim_variants.is_empty() {
            out = out
                .iter()
                .flat_map(|base| {
                    self.cim_variants.iter().map(|&cim| {
                        let mut c = base.clone().with_cim(cim);
                        c.name = format!("{}-{}", base.name, cim.name());
                        c
                    })
                })
                .collect();
        }
        if let Some(cim) = self.cim_override {
            for c in &mut out {
                c.cim_levels = cim;
            }
        }
        Ok(out)
    }

    /// Resolve the backend policy against the technologies this evaluation
    /// will touch.
    pub fn resolve_backend(&self) -> Result<Box<dyn Backend>> {
        self.backend_for(&self.config_list()?)
    }

    /// [`Evaluation::resolve_backend`] for an already-expanded config list.
    fn backend_for(&self, configs: &[SystemConfig]) -> Result<Box<dyn Backend>> {
        let techs: Vec<Technology> = configs.iter().map(|c| c.tech).collect();
        self.backend.resolve(&techs)
    }

    /// Run the sweep and return the raw rows + ledger.
    pub fn rows(&self) -> Result<Sweep> {
        let configs = self.config_list()?;
        let mut backend = self.backend_for(&configs)?;
        self.rows_for(&configs, backend.as_mut())
    }

    /// [`Evaluation::rows`] on a caller-provided backend.
    pub fn rows_with(&self, backend: &mut dyn Backend) -> Result<Sweep> {
        self.rows_for(&self.config_list()?, backend)
    }

    /// The sweep core, for an already-expanded config list.
    fn rows_for(
        &self,
        configs: &[SystemConfig],
        backend: &mut dyn Backend,
    ) -> Result<Sweep> {
        self.rows_for_on(&Coordinator::new(self.sweep_options()), configs, backend)
    }

    /// The sweep core on a caller-provided coordinator: the driver's
    /// in-process analysis memo outlives this call, so repeated
    /// evaluations on one coordinator dedupe the analysis stage even
    /// without a cache dir.  The evaluation's own options (not the
    /// coordinator's) size the sweep.
    fn rows_for_on(
        &self,
        coord: &Coordinator,
        configs: &[SystemConfig],
        backend: &mut dyn Backend,
    ) -> Result<Sweep> {
        let benches = self.bench_list();
        let bench_refs: Vec<&str> = benches.iter().map(|s| s.as_str()).collect();
        let points = cross(&bench_refs, configs, self.rule);
        let t0 = std::time::Instant::now();
        let (rows, stats) =
            coord.run_sweep_with_stats_using(&points, &self.sweep_options(), backend)?;
        Ok(Sweep {
            rows,
            stats,
            elapsed_secs: t0.elapsed().as_secs_f64(),
            backend: backend.name(),
        })
    }

    /// [`Evaluation::rows`] on a caller-provided warm [`Coordinator`] —
    /// the serving entry point (`eva-cim serve` keeps one coordinator for
    /// the process lifetime and routes every request through here).
    pub fn rows_on(&self, coord: &Coordinator) -> Result<Sweep> {
        let configs = self.config_list()?;
        let mut backend = self.backend_for(&configs)?;
        self.rows_for_on(coord, &configs, backend.as_mut())
    }

    /// [`Evaluation::run`] on a caller-provided warm [`Coordinator`].
    pub fn run_on(&self, coord: &Coordinator) -> Result<Report> {
        Ok(Self::sweep_report(self.rows_on(coord)?))
    }

    /// [`Evaluation::explore`] on a caller-provided warm [`Coordinator`].
    pub fn explore_on(&self, coord: &Coordinator) -> Result<Report> {
        self.explore_report(self.rows_on(coord)?)
    }

    /// Run the sweep and report every design point (bench × config grid
    /// with MACR/speedup/energy columns).
    pub fn run(&self) -> Result<Report> {
        Ok(Self::sweep_report(self.rows()?))
    }

    /// [`Evaluation::run`] on a caller-provided backend.
    pub fn run_with(&self, backend: &mut dyn Backend) -> Result<Report> {
        Ok(Self::sweep_report(self.rows_with(backend)?))
    }

    /// The generic per-design-point report over a finished sweep.
    fn sweep_report(sweep: Sweep) -> Report {
        Report::new("sweep results")
            .with_section(sweep_section(&sweep.rows))
            .with_ledger(sweep.stats, sweep.elapsed_secs, sweep.backend)
    }

    /// Cross-technology design-space exploration: evaluate the configured
    /// grid and rank each benchmark's points by Pareto dominance on
    /// (energy improvement, speedup).  The report carries the full grid
    /// (frontier rows marked) and a frontier-only section.
    pub fn explore(&self) -> Result<Report> {
        self.explore_report(self.rows()?)
    }

    /// [`Evaluation::explore`] on a caller-provided backend.
    pub fn explore_with(&self, backend: &mut dyn Backend) -> Result<Report> {
        self.explore_report(self.rows_with(backend)?)
    }

    /// The Pareto grid/frontier report over a finished sweep.
    fn explore_report(&self, sweep: Sweep) -> Result<Report> {
        let mut grid = Section::new(
            "explore — tech × config Pareto grid (* = frontier)",
            &["bench", "tech", "config", "MACR", "E-impr", "speedup", "Pareto"],
        );
        let mut frontier = Section::new(
            "explore — Pareto frontier (non-dominated on E-impr × speedup)",
            &["bench", "tech", "config", "E-impr", "speedup"],
        );
        for b in self.bench_list() {
            let bench_rows: Vec<&SweepRow> =
                sweep.rows.iter().filter(|r| r.bench == b).collect();
            let scores: Vec<(f64, f64)> = bench_rows
                .iter()
                .map(|r| (r.result.improvement, r.result.speedup))
                .collect();
            for (r, &front) in bench_rows.iter().zip(&stats::pareto_front(&scores)) {
                let config = config_label(r);
                grid.row(vec![
                    Cell::str(workloads::display_name(&r.bench)),
                    Cell::str(r.tech.name()),
                    Cell::str(config.as_str()),
                    Cell::pct(r.macr.ratio(), 1),
                    Cell::num(r.result.improvement, 2),
                    Cell::num(r.result.speedup, 2),
                    Cell::Mark(front),
                ]);
                if front {
                    frontier.row(vec![
                        Cell::str(workloads::display_name(&r.bench)),
                        Cell::str(r.tech.name()),
                        Cell::str(config),
                        Cell::num(r.result.improvement, 2),
                        Cell::num(r.result.speedup, 2),
                    ]);
                }
            }
        }
        Ok(Report::new("explore")
            .with_section(grid)
            .with_section(frontier)
            .with_ledger(sweep.stats, sweep.elapsed_secs, sweep.backend))
    }

    /// Evaluate exactly one benchmark on exactly one configuration through
    /// the streaming pipeline and report the full profile (run summary,
    /// energy/speedup, per-component breakdown).
    pub fn single(&self) -> Result<Report> {
        let configs = self.config_list()?;
        let mut backend = self.backend_for(&configs)?;
        self.single_for(&configs, backend.as_mut())
    }

    /// [`Evaluation::single`] on a caller-provided backend.
    pub fn single_with(&self, backend: &mut dyn Backend) -> Result<Report> {
        self.single_for(&self.config_list()?, backend)
    }

    /// The single-run core, for an already-expanded config list.
    fn single_for(
        &self,
        configs: &[SystemConfig],
        backend: &mut dyn Backend,
    ) -> Result<Report> {
        let benches = self.bench_list();
        if benches.len() != 1 || configs.len() != 1 {
            bail!(
                "single() needs exactly one benchmark and one configuration \
                 (got {} × {})",
                benches.len(),
                configs.len()
            );
        }
        let prog = workloads::build(&benches[0], self.opts.scale, self.opts.seed)
            .ok_or_else(|| {
                anyhow!(
                    "unknown benchmark '{}' (see `eva-cim list` / \
                     workloads::NAMES)",
                    benches[0]
                )
            })?;
        profile_program(&prog, &configs[0], self.rule, self.limits(), backend)
    }

    /// Simulator limits for the single-run paths: an explicit
    /// [`Evaluation::max_instructions`] wins, otherwise the simulator's
    /// own (larger) default budget — sweeps' tighter per-point budget
    /// must not silently truncate single runs.
    fn limits(&self) -> Limits {
        match self.max_instr {
            Some(n) => Limits { max_instructions: n },
            None => Limits::default(),
        }
    }

    /// Profile a caller-assembled [`Program`] (the `eva-cim asm` path) on
    /// this evaluation's single configuration.
    pub fn single_program(&self, prog: &Program) -> Result<Report> {
        let configs = self.config_list()?;
        if configs.len() != 1 {
            bail!("single_program() needs exactly one configuration");
        }
        let mut backend = self.backend_for(&configs)?;
        profile_program(prog, &configs[0], self.rule, self.limits(), backend.as_mut())
    }

    /// Run the offload planner on exactly one benchmark × configuration
    /// and report every group's priced decision — the `eva-cim plan`
    /// core.  The accepted groups are folded through the reshape/energy
    /// stage, so the summary's improvement/speedup reflect *the plan*,
    /// not the raw candidate stream.
    pub fn plan(&self) -> Result<Report> {
        self.plan_on(&Coordinator::new(self.sweep_options()))
    }

    /// [`Evaluation::plan`] on a caller-provided warm [`Coordinator`] —
    /// the `POST /plan` entry point (plans share the service's trace
    /// store and are memoized by plan key for the process lifetime).
    pub fn plan_on(&self, coord: &Coordinator) -> Result<Report> {
        let configs = self.config_list()?;
        let benches = self.bench_list();
        if benches.len() != 1 || configs.len() != 1 {
            bail!(
                "plan() needs exactly one benchmark and one configuration \
                 (got {} × {})",
                benches.len(),
                configs.len()
            );
        }
        let mut backend = self.backend_for(&configs)?;
        let point = SweepPoint {
            bench: benches[0].clone(),
            config: configs[0].clone(),
            rule: self.rule,
        };
        let knobs = self.plan_knobs();
        let t0 = std::time::Instant::now();
        let (art, stats) =
            coord.run_plan(&point, self.policy, &knobs, &self.sweep_options())?;

        // stage 4 on the plan's output: fold ONLY the accepted groups'
        // deltas through reshape + the profiler backend
        let reshaped = reshape_from_deltas(&art.summary, &art.deltas, &point.config);
        let inputs = ProfileInputs::new(&point.config, &reshaped);
        let res = backend.evaluate_batch(&[inputs])?.remove(0);

        let plan = &art.plan;
        let mut summary = Section::new("plan summary", &["metric", "value"]);
        let rows: Vec<(&str, Cell)> = vec![
            ("bench", Cell::str(workloads::display_name(&point.bench))),
            ("config", Cell::str(point.config.name.as_str())),
            ("tech", Cell::str(point.config.tech.name())),
            ("cim", Cell::str(point.config.cim_levels.name())),
            ("rule", Cell::str(self.rule.name())),
            ("policy", Cell::str(plan.policy.name())),
            ("min ops", Cell::int(plan.knobs.min_ops)),
            ("min net (pJ)", Cell::num(plan.knobs.min_net_pj, 2)),
            ("plan level", Cell::str(plan.knobs.level.name())),
            ("groups seen", Cell::int(plan.decisions.len() as u64)),
            ("groups accepted", Cell::int(plan.groups_accepted())),
            ("groups rejected", Cell::int(plan.groups_rejected())),
            ("accepted CiM ops", Cell::int(plan.accepted_ops())),
            ("offloaded instrs", Cell::int(reshaped.removed)),
            ("accepted net saving (pJ)", Cell::num(plan.accepted_net_pj(), 1)),
            ("rejected energy (pJ)", Cell::num(plan.rejected_energy_pj(), 1)),
            ("E-impr", Cell::num(res.improvement, 2)),
            ("speedup", Cell::num(res.speedup, 2)),
            ("backend", Cell::str(backend.name())),
        ];
        for (metric, value) in rows {
            summary.row(vec![Cell::str(metric), value]);
        }

        let mut decisions = Section::new(
            "offload decisions (identical groups aggregated)",
            &["groups", "level", "ops", "removed", "moves", "readbacks",
              "cim pJ", "marshal pJ", "readback pJ", "saved pJ", "net pJ",
              "decision", "reason"],
        );
        for row in plan.rows() {
            let d = &row.decision;
            decisions.row(vec![
                Cell::int(row.count),
                Cell::str(d.level.name()),
                Cell::int(d.ops),
                Cell::int(d.removed),
                Cell::int(d.moves as u64),
                Cell::int(d.readbacks as u64),
                Cell::num(d.ledger.cim_op_pj, 3),
                Cell::num(d.ledger.marshal_pj, 3),
                Cell::num(d.ledger.readback_pj, 3),
                Cell::num(d.ledger.saved_pj(), 3),
                Cell::num(d.ledger.net_pj(), 3),
                Cell::str(if d.accepted() { "offload" } else { "reject" }),
                Cell::str(match d.rejected {
                    Some(r) => r.name(),
                    None => "-",
                }),
            ]);
        }

        Ok(Report::new(&format!("offload plan: {}", point.bench))
            .with_section(summary)
            .with_section(decisions)
            .with_ledger(stats, t0.elapsed().as_secs_f64(), backend.name()))
    }
}

/// The per-design-point grid section every sweep renders (bench × config
/// with MACR/speedup/energy columns) — the single source of truth for
/// [`Evaluation::run`]'s output, public so equivalence suites can render
/// independently produced [`SweepRow`]s through the identical formatter
/// and compare bytes.
pub fn sweep_section(rows: &[SweepRow]) -> Section {
    let mut s = Section::new(
        "sweep results",
        &["bench", "config", "tech", "cim", "MACR", "speedup", "E-impr",
          "proc", "caches"],
    );
    for r in rows {
        s.row(vec![
            Cell::str(workloads::display_name(&r.bench)),
            Cell::str(r.config_name.as_str()),
            Cell::str(r.tech.name()),
            Cell::str(r.cim_levels.name()),
            Cell::pct(r.macr.ratio(), 1),
            Cell::num(r.result.speedup, 2),
            Cell::num(r.result.improvement, 2),
            Cell::num(r.result.ratio_proc, 2),
            Cell::num(r.result.ratio_cache, 2),
        ]);
    }
    s
}

/// The `eva-cim list` catalog — benchmarks (Table IV), config presets,
/// registered technologies and CiM levels — as a structured [`Report`].
///
/// Shared verbatim by the CLI `list` command and the service's
/// `GET /list`, so both render byte-identical output.
pub fn list_report() -> Report {
    let mut benches = Section::new("benchmarks (Table IV)", &["key", "name"]);
    for n in workloads::NAMES {
        benches.row(vec![Cell::str(n), Cell::str(workloads::display_name(n))]);
    }
    let mut presets = Section::new("config presets", &["preset", "L1", "L2"]);
    for p in SystemConfig::preset_names() {
        let c = SystemConfig::preset(p).unwrap();
        presets.row(vec![
            Cell::str(*p),
            Cell::str(c.l1d.pretty()),
            Cell::str(c.l2.pretty()),
        ]);
    }
    let mut techs = Section::new(
        "technologies (--tech; extend via --tech-file or [tech.<name>])",
        &["tech", "kind", "aliases"],
    );
    for tech in Technology::all() {
        let m = device::model_of(tech);
        techs.row(vec![
            Cell::str(tech.name()),
            Cell::str(if device::is_builtin(tech) { "built-in" } else { "custom" }),
            Cell::str(m.aliases.join(", ")),
        ]);
    }
    let mut cims = Section::new("cim levels (--cim)", &["name"]);
    for c in [CimLevels::None, CimLevels::L1Only, CimLevels::L2Only, CimLevels::Both] {
        cims.row(vec![Cell::str(c.name())]);
    }
    let mut policies = Section::new(
        "planner policies (--policy)",
        &["policy", "description", "aliases"],
    );
    for p in PlanPolicy::all() {
        policies.row(vec![
            Cell::str(p.name()),
            Cell::str(p.describe()),
            Cell::str(p.aliases()),
        ]);
    }
    Report::new("list")
        .with_section(benches)
        .with_section(presets)
        .with_section(techs)
        .with_section(cims)
        .with_section(policies)
}

/// The `config` column of the explore grid: the row's configuration name
/// with its `-{tech}` segment removed (the grid has a dedicated tech
/// column).  `"c1-sram"` → `"c1"`, `"c1-sram-l1"` → `"c1-l1"`; names
/// without a tech segment — explicit configs — pass through verbatim, so
/// distinct design points always get distinct labels.
fn config_label(r: &SweepRow) -> String {
    let seg = format!("-{}", r.tech.name());
    if let Some(base) = r.config_name.strip_suffix(&seg) {
        return base.to_string();
    }
    let infix = format!("{seg}-");
    match r.config_name.find(&infix) {
        Some(i) => format!(
            "{}{}",
            &r.config_name[..i],
            &r.config_name[i + seg.len()..]
        ),
        None => r.config_name.clone(),
    }
}

/// Run one program through the pipelined sim ∥ analyze ∥ reshape stack and
/// profile it — the shared core of [`Evaluation::single`] and the CLI's
/// `run`/`asm` commands.
pub fn profile_program(
    prog: &Program,
    cfg: &SystemConfig,
    rule: LocalityRule,
    limits: Limits,
    backend: &mut dyn Backend,
) -> Result<Report> {
    let (summary, outcome, deltas) =
        run_pipelined(prog, cfg, limits, rule, DeltaSink::default(), None)?;
    let reshaped = reshape_from_deltas(&summary, &deltas, cfg);
    let inputs = ProfileInputs::new(cfg, &reshaped);
    let res = backend.evaluate_batch(&[inputs])?.remove(0);

    let summary_section = run_summary(&summary, &outcome, &reshaped, backend.name());

    let mut profile = Section::new("profile", &["metric", "baseline", "CiM", "ratio"]);
    profile.row(vec![
        Cell::str("energy (uJ)"),
        Cell::num(res.total_base / 1e6, 2),
        Cell::num(res.total_cim / 1e6, 2),
        Cell::num(res.improvement, 2),
    ]);
    profile.row(vec![
        Cell::str("speedup"),
        Cell::num(1.0, 2),
        Cell::num(res.speedup, 2),
        Cell::num(res.speedup, 2),
    ]);

    let mut comps =
        Section::new("energy breakdown (uJ)", &["component", "baseline", "CiM"]);
    for i in 0..calib::NCOMP {
        comps.row(vec![
            Cell::str(calib::COMP_NAMES[i]),
            Cell::num(res.comps_base[i] / 1e6, 3),
            Cell::num(res.comps_cim[i] / 1e6, 3),
        ]);
    }

    let mut split =
        Section::new("improvement breakdown", &["component", "share"]);
    split.row(vec![Cell::str("processor"), Cell::num(res.ratio_proc, 2)]);
    split.row(vec![Cell::str("caches"), Cell::num(res.ratio_cache, 2)]);

    Ok(Report::new(&format!("profile: {}", summary.program))
        .with_section(summary_section)
        .with_section(profile)
        .with_section(comps)
        .with_section(split))
}

/// The run-summary section (program identity, pipeline statistics, MACR).
fn run_summary(
    summary: &TraceSummary,
    outcome: &StreamOutcome,
    reshaped: &Reshaped,
    backend: &str,
) -> Section {
    let mut s = Section::new("run summary", &["metric", "value"]);
    let rows: Vec<(&str, Cell)> = vec![
        ("program", Cell::str(&*summary.program)),
        ("committed instrs", Cell::int(summary.committed)),
        ("cycles", Cell::int(summary.cycles)),
        ("CPI", Cell::num(summary.cpi(), 2)),
        ("IDG nodes", Cell::int(outcome.idg_nodes.0)),
        ("IDG eligible", Cell::int(outcome.idg_nodes.1)),
        ("candidates", Cell::int(outcome.candidates)),
        ("peak analysis window", Cell::int(outcome.peak_window as u64)),
        ("MACR", Cell::pct(outcome.macr.ratio(), 1)),
        ("MACR L1 share", Cell::pct(outcome.macr.l1_share(), 1)),
        ("offloaded instrs", Cell::int(reshaped.removed)),
        ("CiM ops", Cell::int(reshaped.cim_op_count)),
        ("backend", Cell::str(backend)),
    ];
    for (metric, value) in rows {
        s.row(vec![Cell::str(metric), value]);
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast(ev: Evaluation) -> Evaluation {
        ev.scale(2).jobs(2).backend(BackendSel::Native)
    }

    #[test]
    fn defaults_cover_all_benches_on_c1() {
        let ev = Evaluation::new();
        assert_eq!(ev.bench_list().len(), 17);
        let cfgs = ev.config_list().unwrap();
        assert_eq!(cfgs.len(), 1);
        assert_eq!(cfgs[0].name, "c1");
    }

    #[test]
    fn tech_and_cim_crossings_name_variants() {
        let ev = Evaluation::new()
            .presets(&["c1", "c3"])
            .techs(&[Technology::SRAM, Technology::FEFET])
            .cim_variants(&[CimLevels::L1Only, CimLevels::Both]);
        let names: Vec<String> =
            ev.config_list().unwrap().into_iter().map(|c| c.name).collect();
        assert_eq!(names.len(), 8);
        assert!(names.contains(&"c1-sram-l1".to_string()));
        assert!(names.contains(&"c3-fefet-l1+l2".to_string()));
    }

    #[test]
    fn cim_override_keeps_names() {
        let ev = Evaluation::new().preset("c2").cim(CimLevels::L2Only);
        let cfgs = ev.config_list().unwrap();
        assert_eq!(cfgs[0].name, "c2");
        assert_eq!(cfgs[0].cim_levels, CimLevels::L2Only);
    }

    #[test]
    fn unknown_preset_is_an_error() {
        assert!(Evaluation::new().preset("nope").config_list().is_err());
    }

    #[test]
    fn cim_override_conflicts_with_cim_variants() {
        let ev = Evaluation::new()
            .preset("c1")
            .cim_variants(&[CimLevels::L1Only])
            .cim(CimLevels::L2Only);
        assert!(ev.config_list().is_err());
    }

    #[test]
    fn explore_config_labels_drop_only_the_tech_segment() {
        let mk = |name: &str, tech: Technology| {
            let mut cfg = SystemConfig::preset("c1").unwrap().with_tech(tech);
            cfg.name = name.to_string();
            crate::coordinator::SweepRow {
                bench: "lcs".into(),
                config_name: cfg.name.clone(),
                tech: cfg.tech,
                cim_levels: cfg.cim_levels,
                macr: Default::default(),
                committed: 0,
                cycles: 0,
                removed: 0,
                cim_ops: 0,
                result: Default::default(),
            }
        };
        assert_eq!(config_label(&mk("c1-sram", Technology::SRAM)), "c1");
        assert_eq!(config_label(&mk("c1-sram-l1", Technology::SRAM)), "c1-l1");
        assert_eq!(config_label(&mk("big-l2", Technology::FEFET)), "big-l2");
    }

    #[test]
    fn run_reports_every_design_point() {
        let report = fast(Evaluation::new().benches(&["lcs", "km"]).preset("c1"))
            .run()
            .unwrap();
        let s = &report.sections[0];
        assert_eq!(s.num_rows(), 2);
        assert_eq!(s.columns[0], "bench");
        assert!(report.stats.is_some());
        // machine-readable and text forms come from the same value
        assert!(report.render_json().contains("\"bench\":\"LCS\""));
        assert!(report.render_table().contains("LCS"));
    }

    #[test]
    fn single_reports_the_full_profile() {
        let report =
            fast(Evaluation::new().bench("lcs").preset("c1")).single().unwrap();
        let titles: Vec<&str> =
            report.sections.iter().map(|s| s.title.as_str()).collect();
        assert_eq!(
            titles,
            ["run summary", "profile", "energy breakdown (uJ)",
             "improvement breakdown"]
        );
        assert!(matches!(
            report.sections[0].cell(0, "value"),
            Some(Cell::Str(p)) if p.as_str() == "lcs"
        ));
    }

    #[test]
    fn single_rejects_grids() {
        let ev = fast(Evaluation::new().benches(&["lcs", "km"]).preset("c1"));
        assert!(ev.single().is_err());
    }

    #[test]
    fn plan_reports_summary_and_decisions() {
        let report = fast(Evaluation::new().bench("lcs").preset("c1"))
            .plan()
            .unwrap();
        let titles: Vec<&str> =
            report.sections.iter().map(|s| s.title.as_str()).collect();
        assert_eq!(
            titles,
            ["plan summary",
             "offload decisions (identical groups aggregated)"]
        );
        // default accept-all: nothing rejected, ledger counters agree
        assert!(matches!(
            report.sections[0].cell(11, "value"),
            Some(Cell::Int(0))
        ));
        let stats = report.stats.expect("plan carries the sweep ledger");
        assert_eq!(stats.groups_rejected, 0);
        assert!(stats.groups_accepted > 0);
        assert!(report.render_json().contains("\"metric\":\"groups accepted\""));
    }

    #[test]
    fn plan_rejects_grids() {
        let ev = fast(Evaluation::new().benches(&["lcs", "km"]).preset("c1"));
        assert!(ev.plan().is_err());
    }

    #[test]
    fn plan_knobs_start_from_the_policy_defaults() {
        let ev = Evaluation::new().policy(PlanPolicy::Profitability);
        assert_eq!(ev.plan_knobs().min_ops, 2);
        let ev = ev.min_ops(5).min_net_pj(1.5).plan_level(CimLevels::L1Only);
        let knobs = ev.plan_knobs();
        assert_eq!(knobs.min_ops, 5);
        assert_eq!(knobs.min_net_pj, 1.5);
        assert_eq!(knobs.level, CimLevels::L1Only);
    }

    #[test]
    fn list_report_enumerates_planner_policies() {
        let report = list_report();
        let s = report
            .sections
            .iter()
            .find(|s| s.title == "planner policies (--policy)")
            .expect("policies section");
        assert_eq!(s.num_rows(), PlanPolicy::all().len());
        assert!(matches!(
            s.cell(0, "policy"),
            Some(Cell::Str(p)) if p.as_str() == "accept-all"
        ));
    }

    #[test]
    fn backend_policy_respects_the_aot_table() {
        // registry technologies force the native mirror under Auto...
        let b = BackendSel::Auto.resolve(&[Technology::RRAM]).unwrap();
        assert_eq!(b.name(), "native");
        // ...and are rejected outright under explicit Pjrt
        assert!(BackendSel::Pjrt.resolve(&[Technology::RRAM]).is_err());
        assert_eq!(BackendSel::from_name("NATIVE"), Some(BackendSel::Native));
        assert!(BackendSel::from_name("cuda").is_none());
    }
}
