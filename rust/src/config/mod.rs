//! System configuration: core, memory hierarchy, CiM placement, technology.
//!
//! Mirrors the paper's experimental setup (§VI): ARM Cortex-A9-class
//! out-of-order core at 1 GHz, 512 MB main memory, and the three cache
//! configurations of Fig 14.  Presets are in [`SystemConfig::preset`];
//! everything can be overridden via the TOML-subset files in `parse`,
//! including user-defined device technologies (`[tech.<name>]` sections —
//! see [`crate::energy::device`]).

pub mod parse;

/// Memory technology of the cache arrays (and their CiM peripherals).
///
/// A `Technology` is an interned handle (id + name) into the process-wide
/// device registry ([`crate::energy::device`]).  The four built-ins are
/// available as associated constants; anything registered at runtime —
/// from a `[tech.<name>]` TOML section or [`crate::energy::device::register`]
/// — resolves through [`Technology::from_name`] exactly like a built-in.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct Technology(u16);

impl Technology {
    /// CMOS SRAM (Table III / Fig 11 anchors). Alias: `cmos`.
    pub const SRAM: Technology = Technology(0);
    /// FeFET-RAM (Table III / Fig 11 anchors). Alias: `fefet-ram`.
    pub const FEFET: Technology = Technology(1);
    /// ReRAM preset (representative published numbers). Alias: `reram`.
    pub const RRAM: Technology = Technology(2);
    /// STT-MRAM preset (representative published numbers).
    /// Aliases: `sttram`, `stt`, `mram`.
    pub const STT_MRAM: Technology = Technology(3);

    /// Construct from a raw registry id (crate-internal: ids are only
    /// minted by the device registry).
    pub(crate) fn from_id(id: u16) -> Technology {
        Technology(id)
    }

    /// Registry index of this technology (row in the device table).
    pub fn index(&self) -> usize {
        self.0 as usize
    }

    /// Registered (interned) name, e.g. `"sram"` or `"stt-mram"`.
    pub fn name(&self) -> &'static str {
        crate::energy::device::name_of(*self)
    }

    /// Resolve a registered name or alias, case-insensitively.
    pub fn from_name(s: &str) -> Option<Self> {
        crate::energy::device::lookup(s)
    }

    /// Every registered technology (built-ins first, then customs), in
    /// registration order.
    pub fn all() -> Vec<Technology> {
        crate::energy::device::all()
    }
}

impl std::fmt::Debug for Technology {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Technology({})", self.name())
    }
}

/// Which cache levels have CiM-capable arrays (Fig 15 sweep).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CimLevels {
    /// no CiM arrays — the pure baseline system
    None,
    /// CiM peripherals in the L1 data cache only
    L1Only,
    /// CiM peripherals in the L2 cache only
    L2Only,
    /// CiM peripherals in both cache levels
    Both,
}

impl CimLevels {
    /// True when the L1 data cache is CiM-capable.
    pub fn l1(&self) -> bool {
        matches!(self, CimLevels::L1Only | CimLevels::Both)
    }

    /// True when the L2 cache is CiM-capable.
    pub fn l2(&self) -> bool {
        matches!(self, CimLevels::L2Only | CimLevels::Both)
    }

    /// Canonical CLI/TOML name (`none`, `l1`, `l2`, `l1+l2`).
    pub fn name(&self) -> &'static str {
        match self {
            CimLevels::None => "none",
            CimLevels::L1Only => "l1",
            CimLevels::L2Only => "l2",
            CimLevels::Both => "l1+l2",
        }
    }

    /// Parse a CLI/TOML name (accepts `both` for `l1+l2`).
    pub fn from_name(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "none" => Some(CimLevels::None),
            "l1" => Some(CimLevels::L1Only),
            "l2" => Some(CimLevels::L2Only),
            "both" | "l1+l2" => Some(CimLevels::Both),
            _ => None,
        }
    }
}

/// Out-of-order core parameters (Cortex-A9-class defaults).
#[derive(Clone, Debug, PartialEq)]
pub struct CoreConfig {
    /// instructions fetched/decoded/committed per cycle
    pub width: usize,
    /// reorder-buffer entries
    pub rob_entries: usize,
    /// issue-queue entries
    pub iq_entries: usize,
    /// load/store-queue entries
    pub lsq_entries: usize,
    /// branch mispredict pipeline refill penalty (cycles)
    pub mispredict_penalty: u64,
    /// number of parallel integer ALUs
    pub int_alu_units: usize,
    /// number of integer multiply/divide units
    pub int_mul_units: usize,
    /// number of floating-point units
    pub fp_units: usize,
    /// memory ports between the LSQ and the L1 data cache
    pub mem_ports: usize,
}

impl Default for CoreConfig {
    fn default() -> Self {
        Self {
            width: 2,
            rob_entries: 40,
            iq_entries: 24,
            lsq_entries: 16,
            mispredict_penalty: 12,
            int_alu_units: 2,
            int_mul_units: 1,
            fp_units: 1,
            mem_ports: 1,
        }
    }
}

/// One cache level.
#[derive(Clone, Debug, PartialEq)]
pub struct CacheConfig {
    /// total capacity in bytes (power of two)
    pub capacity: u32,
    /// set associativity (ways)
    pub assoc: u32,
    /// line size in bytes
    pub line: u32,
    /// number of independently accessible banks
    pub banks: u32,
    /// hit latency (cycles)
    pub latency: u64,
    /// miss-status-holding registers (outstanding misses)
    pub mshr_entries: usize,
}

impl CacheConfig {
    /// A cache level with the default 64 B line, 4 banks and 8 MSHRs.
    pub fn new(capacity: u32, assoc: u32, latency: u64) -> Self {
        Self { capacity, assoc, line: 64, banks: 4, latency, mshr_entries: 8 }
    }

    /// Number of sets implied by capacity/associativity/line size.
    pub fn sets(&self) -> u32 {
        self.capacity / (self.assoc * self.line)
    }

    /// Pretty string like "64kB/4-way".
    pub fn pretty(&self) -> String {
        let cap = self.capacity;
        let s = if cap >= 1024 * 1024 {
            format!("{}MB", cap / (1024 * 1024))
        } else {
            format!("{}kB", cap / 1024)
        };
        format!("{s}/{}-way", self.assoc)
    }
}

/// Main-memory model.
#[derive(Clone, Debug, PartialEq)]
pub struct DramConfig {
    /// main-memory size in bytes
    pub size: u64,
    /// access latency (cycles)
    pub latency: u64,
}

impl Default for DramConfig {
    fn default() -> Self {
        Self { size: 512 * 1024 * 1024, latency: 100 }
    }
}

/// Full system configuration: the design point of a sweep.
#[derive(Clone, Debug, PartialEq)]
pub struct SystemConfig {
    /// display name of the design point (cosmetic; part of the cache key)
    pub name: String,
    /// out-of-order core parameters
    pub core: CoreConfig,
    /// L1 instruction cache
    pub l1i: CacheConfig,
    /// L1 data cache
    pub l1d: CacheConfig,
    /// unified L2 cache
    pub l2: CacheConfig,
    /// main-memory model
    pub dram: DramConfig,
    /// device technology of the cache arrays
    pub tech: Technology,
    /// which levels carry CiM-capable arrays
    pub cim_levels: CimLevels,
    /// core clock in GHz
    pub clock_ghz: f64,
}

impl SystemConfig {
    /// Named presets matching the paper:
    /// * `c1` — 32 kB/4-way L1, 256 kB/8-way L2 (validation + Table VI)
    /// * `c2` — 64 kB/4-way L1, 256 kB/8-way L2 (Table III anchor, Fig 14)
    /// * `c3` — 64 kB/4-way L1, 2 MB/8-way L2 (Fig 14)
    /// * `spm1mb` — 1 MB single-level config approximating [23]'s SPM (Fig 12)
    pub fn preset(name: &str) -> Option<SystemConfig> {
        let mut cfg = SystemConfig {
            name: name.to_string(),
            core: CoreConfig::default(),
            l1i: CacheConfig::new(32 * 1024, 4, 3),
            l1d: CacheConfig::new(32 * 1024, 4, 3),
            l2: CacheConfig::new(256 * 1024, 8, 10),
            dram: DramConfig::default(),
            tech: Technology::SRAM,
            cim_levels: CimLevels::Both,
            clock_ghz: 1.0,
        };
        match name {
            "c1" => {}
            "c2" => {
                cfg.l1d.capacity = 64 * 1024;
                cfg.l1i.capacity = 64 * 1024;
            }
            "c3" => {
                cfg.l1d.capacity = 64 * 1024;
                cfg.l1i.capacity = 64 * 1024;
                cfg.l2.capacity = 2 * 1024 * 1024;
                cfg.l2.latency = 14;
            }
            "spm1mb" => {
                // one big low-latency level: L1 = 1 MB, L2 pass-through-sized
                cfg.l1d = CacheConfig::new(1024 * 1024, 8, 3);
                cfg.l1i = CacheConfig::new(64 * 1024, 4, 3);
                cfg.l2 = CacheConfig::new(2 * 1024 * 1024, 8, 10);
            }
            _ => return None,
        }
        Some(cfg)
    }

    /// All preset names.
    pub fn preset_names() -> &'static [&'static str] {
        &["c1", "c2", "c3", "spm1mb"]
    }

    /// Builder-style technology override.
    pub fn with_tech(mut self, tech: Technology) -> Self {
        self.tech = tech;
        self
    }

    /// Builder-style CiM-placement override.
    pub fn with_cim(mut self, cim: CimLevels) -> Self {
        self.cim_levels = cim;
        self
    }

    /// Validate invariants; returns a list of problems (empty = ok).
    pub fn validate(&self) -> Vec<String> {
        let mut problems = Vec::new();
        for (name, c) in [("l1i", &self.l1i), ("l1d", &self.l1d), ("l2", &self.l2)]
        {
            if !c.capacity.is_power_of_two() {
                problems.push(format!("{name}: capacity must be a power of two"));
            }
            if !c.line.is_power_of_two() || c.line < 4 {
                problems.push(format!("{name}: bad line size {}", c.line));
            }
            if c.assoc == 0 || c.capacity % (c.assoc * c.line) != 0 {
                problems.push(format!("{name}: capacity not divisible by assoc*line"));
            }
            if !c.banks.is_power_of_two() {
                problems.push(format!("{name}: banks must be a power of two"));
            }
        }
        if self.l2.capacity < self.l1d.capacity {
            problems.push("l2 smaller than l1d (non-inclusive hierarchies unsupported)".into());
        }
        if self.core.width == 0 || self.core.rob_entries < self.core.width {
            problems.push("core: width/rob mismatch".into());
        }
        if self.clock_ghz <= 0.0 {
            problems.push("clock must be positive".into());
        }
        problems
    }
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig::preset("c1").unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_valid() {
        for name in SystemConfig::preset_names() {
            let cfg = SystemConfig::preset(name).unwrap();
            assert!(cfg.validate().is_empty(), "{name}: {:?}", cfg.validate());
        }
        assert!(SystemConfig::preset("nope").is_none());
    }

    #[test]
    fn paper_configs() {
        let c1 = SystemConfig::preset("c1").unwrap();
        assert_eq!(c1.l1d.capacity, 32 * 1024);
        assert_eq!(c1.l2.capacity, 256 * 1024);
        let c3 = SystemConfig::preset("c3").unwrap();
        assert_eq!(c3.l2.capacity, 2 * 1024 * 1024);
    }

    #[test]
    fn sets_computed() {
        let c = CacheConfig::new(32 * 1024, 4, 2);
        assert_eq!(c.sets(), 128);
        assert_eq!(c.pretty(), "32kB/4-way");
    }

    #[test]
    fn validation_catches_bad_configs() {
        let mut cfg = SystemConfig::default();
        cfg.l1d.capacity = 3000;
        assert!(!cfg.validate().is_empty());
        let mut cfg2 = SystemConfig::default();
        cfg2.l2.capacity = 16 * 1024;
        assert!(!cfg2.validate().is_empty());
    }

    #[test]
    fn cim_levels_flags() {
        assert!(CimLevels::Both.l1() && CimLevels::Both.l2());
        assert!(CimLevels::L1Only.l1() && !CimLevels::L1Only.l2());
        assert!(!CimLevels::None.l1() && !CimLevels::None.l2());
    }

    #[test]
    fn technology_handles_resolve_through_the_registry() {
        assert_eq!(Technology::from_name("sram"), Some(Technology::SRAM));
        assert_eq!(Technology::from_name("CMOS"), Some(Technology::SRAM));
        assert_eq!(Technology::from_name("fefet-ram"), Some(Technology::FEFET));
        assert_eq!(Technology::from_name("rram"), Some(Technology::RRAM));
        assert_eq!(Technology::from_name("stt-mram"), Some(Technology::STT_MRAM));
        assert!(Technology::from_name("bogus").is_none());
        assert_eq!(format!("{:?}", Technology::FEFET), "Technology(fefet)");
        let all = Technology::all();
        assert!(all.len() >= 4);
        assert_eq!(all[0], Technology::SRAM);
    }
}
