//! TOML-subset config file parser (serde/toml are unavailable offline).
//!
//! Supported grammar: `[section]` headers, `key = value` lines, `#` comments.
//! Values: integers (decimal, `0x`, size suffixes `k`/`m`), floats, strings.
//!
//! ```toml
//! # example
//! preset = "c1"
//! tech = "fefet"
//! cim = "l1+l2"
//!
//! [l1d]
//! capacity = 64k
//! assoc = 4
//!
//! [core]
//! rob_entries = 64
//! ```
//!
//! # Device-technology sections
//!
//! A `[tech.<name>]` section registers `<name>` in the process-wide device
//! registry ([`crate::energy::device`]) before the rest of the file is
//! interpreted, so a top-level `tech = "<name>"` may appear before or
//! after its definition.  Coefficients default to the `base` technology
//! (itself defaulting to `sram`); only the overridden keys need listing:
//!
//! ```
//! use eva_cim::config::parse;
//!
//! let cfg = parse::parse(
//!     r#"
//!     tech = "doc-pcm"            # defined below — order doesn't matter
//!
//!     [tech.doc-pcm]
//!     base = "rram"               # start from the RRAM preset
//!     alias = "doc-pcram"
//!     e_l1_write = 150.0          # pJ, L1 anchor geometry
//!     lat_l2_add = 15.0           # cycles, L2 anchor geometry
//!     "#,
//! )
//! .unwrap();
//! assert_eq!(cfg.tech.name(), "doc-pcm");
//! let model = eva_cim::energy::device::model_of(cfg.tech);
//! assert_eq!(model.e_l1[eva_cim::energy::calib::OP_WRITE], 150.0);
//! ```
//!
//! Recognized tech keys: `base`, `alias` (comma-separated),
//! `e_{l1,l2}_{read,write,or,and,xor,add}` (pJ),
//! `lat_{l1,l2}_{read,write,or,and,xor,add}` (cycles),
//! `anchor_{l1,l2}_cap`, `anchor_{l1,l2}_assoc`, `anchor_banks`,
//! `assoc_exp` (the [`crate::energy::device::ScalingRule`] fields).

use crate::energy::calib::NOPS;
use crate::energy::device::{self, DeviceModel};

use super::{CimLevels, SystemConfig, Technology};

/// Parse failure: line number + message, `Display`-ready.
#[derive(Debug)]
pub struct ConfigError(
    /// human-readable description of what went wrong
    pub String,
);

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config error: {}", self.0)
    }
}

impl std::error::Error for ConfigError {}

fn parse_num(v: &str) -> Option<f64> {
    let v = v.trim().to_ascii_lowercase();
    let (body, mult) = if let Some(b) = v.strip_suffix('k') {
        (b.to_string(), 1024.0)
    } else if let Some(b) = v.strip_suffix('m') {
        (b.to_string(), 1024.0 * 1024.0)
    } else {
        (v.clone(), 1.0)
    };
    if let Some(hex) = body.strip_prefix("0x") {
        return u64::from_str_radix(hex, 16).ok().map(|x| x as f64 * mult);
    }
    body.parse::<f64>().ok().map(|x| x * mult)
}

fn unquote(v: &str) -> String {
    let v = v.trim();
    if v.len() >= 2 && (v.starts_with('"') && v.ends_with('"')) {
        v[1..v.len() - 1].to_string()
    } else {
        v.to_string()
    }
}

/// One comment-stripped, non-empty line: `(line_number, text)`.
fn logical_lines(text: &str) -> impl Iterator<Item = (usize, &str)> {
    text.lines().enumerate().filter_map(|(i, raw)| {
        let src = match raw.find('#') {
            Some(p) => &raw[..p],
            None => raw,
        };
        let src = src.trim();
        if src.is_empty() {
            None
        } else {
            Some((i + 1, src))
        }
    })
}

/// TOML op-key suffixes, in `calib` column order (read..add).
const TECH_OPS: [&str; NOPS] = ["read", "write", "or", "and", "xor", "add"];

/// A collected `[tech.<name>]` section, pre-registration.
struct TechSection {
    header_line: usize,
    name: String,
    keys: Vec<(usize, String, String)>,
}

/// Collect every `[tech.<name>]` section of `text` in file order.
fn collect_tech_sections(text: &str) -> Result<Vec<TechSection>, ConfigError> {
    let mut sections: Vec<TechSection> = Vec::new();
    let mut in_tech = false;
    for (line, src) in logical_lines(text) {
        if src.starts_with('[') {
            if !src.ends_with(']') {
                return Err(ConfigError(format!("line {line}: bad section header")));
            }
            let section = src[1..src.len() - 1].trim();
            if section == "tech" {
                return Err(ConfigError(format!(
                    "line {line}: [tech] needs a name — use [tech.<name>]"
                )));
            }
            if let Some(name) = section.strip_prefix("tech.") {
                // lowercase here because registration lowercases too —
                // [tech.PCM] and [tech.pcm] are the same table
                let name = name.trim().to_ascii_lowercase();
                // real TOML rejects duplicate tables; a silently-last-wins
                // merge would drop the first section's overrides
                if sections.iter().any(|s| s.name == name) {
                    return Err(ConfigError(format!(
                        "line {line}: duplicate section [tech.{name}]"
                    )));
                }
                sections.push(TechSection { header_line: line, name, keys: Vec::new() });
                in_tech = true;
            } else {
                in_tech = false;
            }
            continue;
        }
        if !in_tech {
            continue;
        }
        let eq = src
            .find('=')
            .ok_or_else(|| ConfigError(format!("line {line}: expected key = value")))?;
        let section = sections.last_mut().expect("in_tech implies a section");
        section.keys.push((
            line,
            src[..eq].trim().to_string(),
            src[eq + 1..].trim().to_string(),
        ));
    }
    Ok(sections)
}

/// Build and register one `[tech.<name>]` section.
fn register_tech_section(sec: &TechSection) -> Result<Technology, ConfigError> {
    // `base` wins regardless of key order within the section
    let mut base = Technology::SRAM;
    for (line, key, value) in &sec.keys {
        if key == "base" {
            let b = unquote(value);
            base = Technology::from_name(&b).ok_or_else(|| {
                ConfigError(format!("line {line}: {}", device::unknown_tech_message(&b)))
            })?;
        }
    }
    let mut model = DeviceModel::based_on(base, &sec.name)
        .map_err(|e| ConfigError(format!("line {}: {e}", sec.header_line)))?;
    for (line, key, value) in &sec.keys {
        let line = *line;
        if key == "base" {
            continue;
        }
        if key == "alias" || key == "aliases" {
            model.aliases.extend(
                unquote(value)
                    .split(',')
                    .map(|a| a.trim().to_ascii_lowercase())
                    .filter(|a| !a.is_empty()),
            );
            continue;
        }
        let num = parse_num(value).ok_or_else(|| {
            ConfigError(format!("line {line}: '{key}' needs a number"))
        })?;
        if let Some(slot) = tech_op_slot(&mut model, key) {
            *slot = num;
            continue;
        }
        match key.as_str() {
            "anchor_l1_cap" => model.scaling.anchor_l1_cap = num,
            "anchor_l2_cap" => model.scaling.anchor_l2_cap = num,
            "anchor_l1_assoc" => model.scaling.anchor_l1_assoc = num,
            "anchor_l2_assoc" => model.scaling.anchor_l2_assoc = num,
            "anchor_banks" => model.scaling.anchor_banks = num,
            "assoc_exp" => model.scaling.assoc_exp = num,
            _ => {
                return Err(ConfigError(format!(
                    "line {line}: unknown key 'tech.{}.{key}'",
                    sec.name
                )))
            }
        }
    }
    device::register(model)
        .map_err(|e| ConfigError(format!("line {}: {e}", sec.header_line)))
}

/// Resolve an `e_*`/`lat_*` op key to its coefficient slot.
fn tech_op_slot<'a>(model: &'a mut DeviceModel, key: &str) -> Option<&'a mut f64> {
    let (kind, rest) = if let Some(r) = key.strip_prefix("e_") {
        ("e", r)
    } else if let Some(r) = key.strip_prefix("lat_") {
        ("lat", r)
    } else {
        return None;
    };
    let (level, op) = rest.split_once('_')?;
    let j = TECH_OPS.iter().position(|&o| o == op)?;
    let arr = match (kind, level) {
        ("e", "l1") => &mut model.e_l1,
        ("e", "l2") => &mut model.e_l2,
        ("lat", "l1") => &mut model.lat_l1,
        ("lat", "l2") => &mut model.lat_l2,
        _ => return None,
    };
    Some(&mut arr[j])
}

/// Register every `[tech.<name>]` section of `text`, returning the handles
/// in file order.  Lines outside tech sections are ignored — use this for
/// standalone technology files (CLI `--tech-file`).
pub fn register_technologies(text: &str) -> Result<Vec<Technology>, ConfigError> {
    collect_tech_sections(text)?
        .iter()
        .map(register_tech_section)
        .collect()
}

/// Parse `text` on top of the given base configuration.
///
/// `[tech.<name>]` sections are registered first (whole-file pass), so a
/// `tech = "<name>"` reference may precede its definition.
pub fn parse_into(text: &str, mut cfg: SystemConfig) -> Result<SystemConfig, ConfigError> {
    register_technologies(text)?;
    let mut section = String::new();
    for (line, src) in logical_lines(text) {
        if src.starts_with('[') {
            if !src.ends_with(']') {
                return Err(ConfigError(format!("line {line}: bad section header")));
            }
            section = src[1..src.len() - 1].trim().to_string();
            continue;
        }
        if section.starts_with("tech.") {
            continue; // handled by register_technologies
        }
        let eq = src
            .find('=')
            .ok_or_else(|| ConfigError(format!("line {line}: expected key = value")))?;
        let key = src[..eq].trim();
        let value = src[eq + 1..].trim();
        let num = parse_num(value);
        let need_num = || {
            num.ok_or_else(|| ConfigError(format!("line {line}: '{key}' needs a number")))
        };

        match (section.as_str(), key) {
            ("", "preset") => {
                let p = unquote(value);
                cfg = SystemConfig::preset(&p).ok_or_else(|| {
                    ConfigError(format!("line {line}: unknown preset '{p}'"))
                })?;
            }
            ("", "name") => cfg.name = unquote(value),
            ("", "tech") => {
                let t = unquote(value);
                cfg.tech = Technology::from_name(&t).ok_or_else(|| {
                    ConfigError(format!("line {line}: {}", device::unknown_tech_message(&t)))
                })?;
            }
            ("", "cim") => {
                let c = unquote(value);
                cfg.cim_levels = CimLevels::from_name(&c).ok_or_else(|| {
                    ConfigError(format!("line {line}: unknown cim levels '{c}'"))
                })?;
            }
            ("", "clock_ghz") => cfg.clock_ghz = need_num()?,
            ("core", "width") => cfg.core.width = need_num()? as usize,
            ("core", "rob_entries") => cfg.core.rob_entries = need_num()? as usize,
            ("core", "iq_entries") => cfg.core.iq_entries = need_num()? as usize,
            ("core", "lsq_entries") => cfg.core.lsq_entries = need_num()? as usize,
            ("core", "mispredict_penalty") => {
                cfg.core.mispredict_penalty = need_num()? as u64
            }
            ("core", "int_alu_units") => cfg.core.int_alu_units = need_num()? as usize,
            ("core", "int_mul_units") => cfg.core.int_mul_units = need_num()? as usize,
            ("core", "fp_units") => cfg.core.fp_units = need_num()? as usize,
            ("core", "mem_ports") => cfg.core.mem_ports = need_num()? as usize,
            ("dram", "latency") => cfg.dram.latency = need_num()? as u64,
            ("dram", "size") => cfg.dram.size = need_num()? as u64,
            (lvl @ ("l1i" | "l1d" | "l2"), k) => {
                let c = match lvl {
                    "l1i" => &mut cfg.l1i,
                    "l1d" => &mut cfg.l1d,
                    _ => &mut cfg.l2,
                };
                match k {
                    "capacity" => c.capacity = need_num()? as u32,
                    "assoc" => c.assoc = need_num()? as u32,
                    "line" => c.line = need_num()? as u32,
                    "banks" => c.banks = need_num()? as u32,
                    "latency" => c.latency = need_num()? as u64,
                    "mshr_entries" => c.mshr_entries = need_num()? as usize,
                    _ => {
                        return Err(ConfigError(format!(
                            "line {line}: unknown key '{lvl}.{k}'"
                        )))
                    }
                }
            }
            (s, k) => {
                return Err(ConfigError(format!(
                    "line {line}: unknown key '{}{}{k}'",
                    s,
                    if s.is_empty() { "" } else { "." },
                )))
            }
        }
    }
    let problems = cfg.validate();
    if !problems.is_empty() {
        return Err(ConfigError(format!("invalid config: {}", problems.join("; "))));
    }
    Ok(cfg)
}

/// Parse from scratch (defaults = preset c1).
pub fn parse(text: &str) -> Result<SystemConfig, ConfigError> {
    parse_into(text, SystemConfig::default())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let cfg = parse(
            r#"
            preset = "c1"
            tech = "fefet"       # switch technology
            cim = "l1"
            clock_ghz = 2.0

            [l1d]
            capacity = 64k
            assoc = 8

            [core]
            rob_entries = 64
            "#,
        )
        .unwrap();
        assert_eq!(cfg.tech, Technology::FEFET);
        assert_eq!(cfg.cim_levels, CimLevels::L1Only);
        assert_eq!(cfg.l1d.capacity, 64 * 1024);
        assert_eq!(cfg.l1d.assoc, 8);
        assert_eq!(cfg.core.rob_entries, 64);
        assert_eq!(cfg.clock_ghz, 2.0);
    }

    #[test]
    fn size_suffixes() {
        let cfg = parse("[l2]\ncapacity = 2m\n").unwrap();
        assert_eq!(cfg.l2.capacity, 2 * 1024 * 1024);
    }

    #[test]
    fn rejects_unknown_keys_and_invalid_result() {
        assert!(parse("bogus = 1").is_err());
        assert!(parse("[l1d]\nwhat = 3").is_err());
        // capacity not a power of two -> validation error
        assert!(parse("[l1d]\ncapacity = 3000").is_err());
    }

    #[test]
    fn preset_then_overrides() {
        let cfg = parse("preset = \"c3\"\n[l2]\nlatency = 20").unwrap();
        assert_eq!(cfg.l2.capacity, 2 * 1024 * 1024);
        assert_eq!(cfg.l2.latency, 20);
    }

    #[test]
    fn tech_section_registers_and_resolves_before_definition() {
        let cfg = parse(
            r#"
            tech = "parse-test-pcm"     # forward reference

            [tech.parse-test-pcm]
            base = "stt-mram"
            alias = "parse-test-pcram, parse-test-pcm2"
            e_l1_read = 41.0
            lat_l1_add = 9.0
            anchor_banks = 8
            "#,
        )
        .unwrap();
        assert_eq!(cfg.tech.name(), "parse-test-pcm");
        let m = crate::energy::device::model_of(cfg.tech);
        assert_eq!(m.e_l1[crate::energy::calib::OP_READ], 41.0);
        assert_eq!(m.lat_l1[crate::energy::calib::OP_ADD], 9.0);
        assert_eq!(m.scaling.anchor_banks, 8.0);
        // non-overridden coefficients inherit the base preset
        let base = crate::energy::device::model_of(Technology::STT_MRAM);
        assert_eq!(m.e_l2, base.e_l2);
        assert_eq!(Technology::from_name("parse-test-pcram"), Some(cfg.tech));
    }

    #[test]
    fn tech_section_errors_are_actionable() {
        // unnamed section
        assert!(parse("[tech]\ne_l1_read = 1").is_err());
        // unknown base, with the registry's did-you-mean message
        let e = parse("[tech.x]\nbase = \"sramm\"").unwrap_err();
        assert!(e.0.contains("did you mean"), "{e}");
        // unknown key inside a tech section
        assert!(parse("[tech.x]\nbogus = 1").is_err());
        // non-positive coefficient rejected by model validation
        assert!(parse("[tech.x]\ne_l1_read = 0").is_err());
        // redefining a built-in rejected
        assert!(parse("[tech.sram]\ne_l1_read = 9").is_err());
        // duplicate tables rejected (silent last-wins would drop overrides),
        // case-insensitively — registration lowercases names
        let e = parse("[tech.dup]\ne_l1_read = 2\n\n[tech.dup]\ne_l1_write = 3")
            .unwrap_err();
        assert!(e.0.contains("duplicate section"), "{e}");
        assert!(
            parse("[tech.DUP2]\ne_l1_read = 2\n\n[tech.dup2]\ne_l1_write = 3")
                .is_err()
        );
    }

    #[test]
    fn register_technologies_ignores_non_tech_lines() {
        let techs = register_technologies(
            "# tech library\n[tech.parse-test-lib]\nbase = \"rram\"\n",
        )
        .unwrap();
        assert_eq!(techs.len(), 1);
        assert_eq!(techs[0].name(), "parse-test-lib");
    }
}
