//! TOML-subset config file parser (serde/toml are unavailable offline).
//!
//! Supported grammar: `[section]` headers, `key = value` lines, `#` comments.
//! Values: integers (decimal, `0x`, size suffixes `k`/`m`), floats, strings.
//!
//! ```toml
//! # example
//! preset = "c1"
//! tech = "fefet"
//! cim = "l1+l2"
//!
//! [l1d]
//! capacity = 64k
//! assoc = 4
//!
//! [core]
//! rob_entries = 64
//! ```

use super::{CimLevels, SystemConfig, Technology};

#[derive(Debug)]
pub struct ConfigError(pub String);

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config error: {}", self.0)
    }
}

impl std::error::Error for ConfigError {}

fn parse_num(v: &str) -> Option<f64> {
    let v = v.trim().to_ascii_lowercase();
    let (body, mult) = if let Some(b) = v.strip_suffix('k') {
        (b.to_string(), 1024.0)
    } else if let Some(b) = v.strip_suffix('m') {
        (b.to_string(), 1024.0 * 1024.0)
    } else {
        (v.clone(), 1.0)
    };
    if let Some(hex) = body.strip_prefix("0x") {
        return u64::from_str_radix(hex, 16).ok().map(|x| x as f64 * mult);
    }
    body.parse::<f64>().ok().map(|x| x * mult)
}

fn unquote(v: &str) -> String {
    let v = v.trim();
    if v.len() >= 2 && (v.starts_with('"') && v.ends_with('"')) {
        v[1..v.len() - 1].to_string()
    } else {
        v.to_string()
    }
}

/// Parse `text` on top of the given base configuration.
pub fn parse_into(text: &str, mut cfg: SystemConfig) -> Result<SystemConfig, ConfigError> {
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = lineno + 1;
        let mut src = raw;
        if let Some(p) = src.find('#') {
            src = &src[..p];
        }
        let src = src.trim();
        if src.is_empty() {
            continue;
        }
        if src.starts_with('[') {
            if !src.ends_with(']') {
                return Err(ConfigError(format!("line {line}: bad section header")));
            }
            section = src[1..src.len() - 1].trim().to_string();
            continue;
        }
        let eq = src
            .find('=')
            .ok_or_else(|| ConfigError(format!("line {line}: expected key = value")))?;
        let key = src[..eq].trim();
        let value = src[eq + 1..].trim();
        let num = parse_num(value);
        let need_num = || {
            num.ok_or_else(|| ConfigError(format!("line {line}: '{key}' needs a number")))
        };

        match (section.as_str(), key) {
            ("", "preset") => {
                let p = unquote(value);
                cfg = SystemConfig::preset(&p).ok_or_else(|| {
                    ConfigError(format!("line {line}: unknown preset '{p}'"))
                })?;
            }
            ("", "name") => cfg.name = unquote(value),
            ("", "tech") => {
                let t = unquote(value);
                cfg.tech = Technology::from_name(&t).ok_or_else(|| {
                    ConfigError(format!("line {line}: unknown tech '{t}'"))
                })?;
            }
            ("", "cim") => {
                let c = unquote(value);
                cfg.cim_levels = CimLevels::from_name(&c).ok_or_else(|| {
                    ConfigError(format!("line {line}: unknown cim levels '{c}'"))
                })?;
            }
            ("", "clock_ghz") => cfg.clock_ghz = need_num()?,
            ("core", "width") => cfg.core.width = need_num()? as usize,
            ("core", "rob_entries") => cfg.core.rob_entries = need_num()? as usize,
            ("core", "iq_entries") => cfg.core.iq_entries = need_num()? as usize,
            ("core", "lsq_entries") => cfg.core.lsq_entries = need_num()? as usize,
            ("core", "mispredict_penalty") => {
                cfg.core.mispredict_penalty = need_num()? as u64
            }
            ("core", "int_alu_units") => cfg.core.int_alu_units = need_num()? as usize,
            ("core", "int_mul_units") => cfg.core.int_mul_units = need_num()? as usize,
            ("core", "fp_units") => cfg.core.fp_units = need_num()? as usize,
            ("core", "mem_ports") => cfg.core.mem_ports = need_num()? as usize,
            ("dram", "latency") => cfg.dram.latency = need_num()? as u64,
            ("dram", "size") => cfg.dram.size = need_num()? as u64,
            (lvl @ ("l1i" | "l1d" | "l2"), k) => {
                let c = match lvl {
                    "l1i" => &mut cfg.l1i,
                    "l1d" => &mut cfg.l1d,
                    _ => &mut cfg.l2,
                };
                match k {
                    "capacity" => c.capacity = need_num()? as u32,
                    "assoc" => c.assoc = need_num()? as u32,
                    "line" => c.line = need_num()? as u32,
                    "banks" => c.banks = need_num()? as u32,
                    "latency" => c.latency = need_num()? as u64,
                    "mshr_entries" => c.mshr_entries = need_num()? as usize,
                    _ => {
                        return Err(ConfigError(format!(
                            "line {line}: unknown key '{lvl}.{k}'"
                        )))
                    }
                }
            }
            (s, k) => {
                return Err(ConfigError(format!(
                    "line {line}: unknown key '{}{}{k}'",
                    s,
                    if s.is_empty() { "" } else { "." },
                )))
            }
        }
    }
    let problems = cfg.validate();
    if !problems.is_empty() {
        return Err(ConfigError(format!("invalid config: {}", problems.join("; "))));
    }
    Ok(cfg)
}

/// Parse from scratch (defaults = preset c1).
pub fn parse(text: &str) -> Result<SystemConfig, ConfigError> {
    parse_into(text, SystemConfig::default())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let cfg = parse(
            r#"
            preset = "c1"
            tech = "fefet"       # switch technology
            cim = "l1"
            clock_ghz = 2.0

            [l1d]
            capacity = 64k
            assoc = 8

            [core]
            rob_entries = 64
            "#,
        )
        .unwrap();
        assert_eq!(cfg.tech, Technology::Fefet);
        assert_eq!(cfg.cim_levels, CimLevels::L1Only);
        assert_eq!(cfg.l1d.capacity, 64 * 1024);
        assert_eq!(cfg.l1d.assoc, 8);
        assert_eq!(cfg.core.rob_entries, 64);
        assert_eq!(cfg.clock_ghz, 2.0);
    }

    #[test]
    fn size_suffixes() {
        let cfg = parse("[l2]\ncapacity = 2m\n").unwrap();
        assert_eq!(cfg.l2.capacity, 2 * 1024 * 1024);
    }

    #[test]
    fn rejects_unknown_keys_and_invalid_result() {
        assert!(parse("bogus = 1").is_err());
        assert!(parse("[l1d]\nwhat = 3").is_err());
        // capacity not a power of two -> validation error
        assert!(parse("[l1d]\ncapacity = 3000").is_err());
    }

    #[test]
    fn preset_then_overrides() {
        let cfg = parse("preset = \"c3\"\n[l2]\nlatency = 20").unwrap();
        assert_eq!(cfg.l2.capacity, 2 * 1024 * 1024);
        assert_eq!(cfg.l2.latency, 20);
    }
}
