//! Performance-counter schema — the McPAT-facing interface.
//!
//! MUST stay in sync with `python/compile/kernels/constants.py`
//! (`COUNTER_NAMES`): the AOT'd profiler graph consumes counters in exactly
//! this order.  `runtime_artifacts.rs` cross-checks the manifest.

use crate::isa::FuncUnit;
use crate::probes::{MemStats, PipeStats, Trace, TraceSummary};

/// Number of performance counters (the profiler input width).
pub const NC: usize = 43;

// core events [0, 22)
/// Counter slot: instructions fetched.
pub const C_FETCH: usize = 0;
/// Counter slot: instructions decoded.
pub const C_DECODE: usize = 1;
/// Counter slot: register-rename operations.
pub const C_RENAME: usize = 2;
/// Counter slot: issue-queue read ports exercised.
pub const C_IQ_READS: usize = 3;
/// Counter slot: issue-queue write ports exercised.
pub const C_IQ_WRITES: usize = 4;
/// Counter slot: reorder-buffer reads.
pub const C_ROB_READS: usize = 5;
/// Counter slot: reorder-buffer writes.
pub const C_ROB_WRITES: usize = 6;
/// Counter slot: integer register-file reads.
pub const C_INT_RF_READS: usize = 7;
/// Counter slot: integer register-file writes.
pub const C_INT_RF_WRITES: usize = 8;
/// Counter slot: floating-point register-file reads.
pub const C_FP_RF_READS: usize = 9;
/// Counter slot: floating-point register-file writes.
pub const C_FP_RF_WRITES: usize = 10;
/// Counter slot: integer-ALU executions.
pub const C_INT_ALU: usize = 11;
/// Counter slot: integer-multiplier executions.
pub const C_INT_MUL: usize = 12;
/// Counter slot: integer-divider executions.
pub const C_INT_DIV: usize = 13;
/// Counter slot: FP-ALU executions.
pub const C_FP_ALU: usize = 14;
/// Counter slot: FP-multiplier executions.
pub const C_FP_MUL: usize = 15;
/// Counter slot: FP-divider executions.
pub const C_FP_DIV: usize = 16;
/// Counter slot: branch-unit executions.
pub const C_BRANCH: usize = 17;
/// Counter slot: branch-predictor lookups.
pub const C_BPRED_LOOKUPS: usize = 18;
/// Counter slot: branch mispredictions.
pub const C_BPRED_MISPREDICTS: usize = 19;
/// Counter slot: load/store-queue reads.
pub const C_LSQ_READS: usize = 20;
/// Counter slot: load/store-queue writes.
pub const C_LSQ_WRITES: usize = 21;
// cache events [22, 34)
/// Counter slot: L1I fetch hits.
pub const C_L1I_HITS: usize = 22;
/// Counter slot: L1I fetch misses.
pub const C_L1I_MISSES: usize = 23;
/// Counter slot: L1D load hits.
pub const C_L1D_READ_HITS: usize = 24;
/// Counter slot: L1D load misses.
pub const C_L1D_READ_MISSES: usize = 25;
/// Counter slot: L1D store hits.
pub const C_L1D_WRITE_HITS: usize = 26;
/// Counter slot: L1D store misses.
pub const C_L1D_WRITE_MISSES: usize = 27;
/// Counter slot: L2 read hits.
pub const C_L2_READ_HITS: usize = 28;
/// Counter slot: L2 read misses.
pub const C_L2_READ_MISSES: usize = 29;
/// Counter slot: L2 write hits.
pub const C_L2_WRITE_HITS: usize = 30;
/// Counter slot: L2 write misses.
pub const C_L2_WRITE_MISSES: usize = 31;
/// Counter slot: main-memory reads.
pub const C_DRAM_READS: usize = 32;
/// Counter slot: main-memory writes.
pub const C_DRAM_WRITES: usize = 33;
// CiM events [34, 42)
/// Counter slot: CiM OR operations in the L1 array.
pub const C_CIM_L1_OR: usize = 34;
/// Counter slot: CiM AND operations in the L1 array.
pub const C_CIM_L1_AND: usize = 35;
/// Counter slot: CiM XOR operations in the L1 array.
pub const C_CIM_L1_XOR: usize = 36;
/// Counter slot: CiM ADD operations in the L1 array.
pub const C_CIM_L1_ADD: usize = 37;
/// Counter slot: CiM OR operations in the L2 array.
pub const C_CIM_L2_OR: usize = 38;
/// Counter slot: CiM AND operations in the L2 array.
pub const C_CIM_L2_AND: usize = 39;
/// Counter slot: CiM XOR operations in the L2 array.
pub const C_CIM_L2_XOR: usize = 40;
/// Counter slot: CiM ADD operations in the L2 array.
pub const C_CIM_L2_ADD: usize = 41;
/// Counter slot: total simulated cycles.
pub const C_CYCLES: usize = 42;

/// Counter names, slot-aligned with the `C_*` constants and the Python
/// AOT schema (`COUNTER_NAMES` in `constants.py`).
pub const COUNTER_NAMES: [&str; NC] = [
    "fetch_insts", "decode_insts", "rename_ops",
    "iq_reads", "iq_writes", "rob_reads", "rob_writes",
    "int_rf_reads", "int_rf_writes", "fp_rf_reads", "fp_rf_writes",
    "int_alu_ops", "int_mul_ops", "int_div_ops",
    "fp_alu_ops", "fp_mul_ops", "fp_div_ops",
    "branch_ops", "bpred_lookups", "bpred_mispredicts",
    "lsq_reads", "lsq_writes",
    "l1i_hits", "l1i_misses",
    "l1d_read_hits", "l1d_read_misses",
    "l1d_write_hits", "l1d_write_misses",
    "l2_read_hits", "l2_read_misses",
    "l2_write_hits", "l2_write_misses",
    "dram_reads", "dram_writes",
    "cim_l1_or", "cim_l1_and", "cim_l1_xor", "cim_l1_add",
    "cim_l2_or", "cim_l2_and", "cim_l2_xor", "cim_l2_add",
    "cycles",
];

/// One row of the profiler input matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct CounterSet(pub [f64; NC]);

impl Default for CounterSet {
    fn default() -> Self {
        Self([0.0; NC])
    }
}

impl std::ops::Index<usize> for CounterSet {
    type Output = f64;
    fn index(&self, i: usize) -> &f64 {
        &self.0[i]
    }
}

impl std::ops::IndexMut<usize> for CounterSet {
    fn index_mut(&mut self, i: usize) -> &mut f64 {
        &mut self.0[i]
    }
}

impl CounterSet {
    /// Extract the baseline (non-CiM) counter vector from a trace.
    pub fn from_trace(t: &Trace) -> Self {
        Self::from_stats(&t.pipe, &t.mem, t.cycles)
    }

    /// Same, from the streaming summary (no materialized CIQ needed).
    pub fn from_summary(s: &TraceSummary) -> Self {
        Self::from_stats(&s.pipe, &s.mem, s.cycles)
    }

    fn from_stats(p: &PipeStats, m: &MemStats, cycles: u64) -> Self {
        let mut c = CounterSet::default();
        c[C_FETCH] = p.fetched as f64;
        c[C_DECODE] = p.decoded as f64;
        c[C_RENAME] = p.renamed as f64;
        c[C_IQ_READS] = p.iq_reads as f64;
        c[C_IQ_WRITES] = p.iq_writes as f64;
        c[C_ROB_READS] = p.rob_reads as f64;
        c[C_ROB_WRITES] = p.rob_writes as f64;
        c[C_INT_RF_READS] = p.int_rf_reads as f64;
        c[C_INT_RF_WRITES] = p.int_rf_writes as f64;
        c[C_FP_RF_READS] = p.fp_rf_reads as f64;
        c[C_FP_RF_WRITES] = p.fp_rf_writes as f64;
        c[C_INT_ALU] = p.fu_counts[FuncUnit::IntAlu.index()] as f64;
        c[C_INT_MUL] = p.fu_counts[FuncUnit::IntMul.index()] as f64;
        c[C_INT_DIV] = p.fu_counts[FuncUnit::IntDiv.index()] as f64;
        c[C_FP_ALU] = p.fu_counts[FuncUnit::FpAlu.index()] as f64;
        c[C_FP_MUL] = p.fu_counts[FuncUnit::FpMul.index()] as f64;
        c[C_FP_DIV] = p.fu_counts[FuncUnit::FpDiv.index()] as f64;
        c[C_BRANCH] = p.fu_counts[FuncUnit::Branch.index()] as f64;
        c[C_BPRED_LOOKUPS] = p.bpred_lookups as f64;
        c[C_BPRED_MISPREDICTS] = p.bpred_mispredicts as f64;
        c[C_LSQ_READS] = p.lsq_reads as f64;
        c[C_LSQ_WRITES] = p.lsq_writes as f64;
        c[C_L1I_HITS] = m.l1i_hits as f64;
        c[C_L1I_MISSES] = m.l1i_misses as f64;
        c[C_L1D_READ_HITS] = m.l1d_read_hits as f64;
        c[C_L1D_READ_MISSES] = m.l1d_read_misses as f64;
        c[C_L1D_WRITE_HITS] = m.l1d_write_hits as f64;
        c[C_L1D_WRITE_MISSES] = m.l1d_write_misses as f64;
        c[C_L2_READ_HITS] = m.l2_read_hits as f64;
        c[C_L2_READ_MISSES] = m.l2_read_misses as f64;
        c[C_L2_WRITE_HITS] = m.l2_write_hits as f64;
        c[C_L2_WRITE_MISSES] = m.l2_write_misses as f64;
        c[C_DRAM_READS] = m.dram_reads as f64;
        c[C_DRAM_WRITES] = m.dram_writes as f64;
        c[C_CYCLES] = cycles as f64;
        c
    }

    /// Subtract `amount` from counter `i`, clamping at zero.
    pub fn dec(&mut self, i: usize, amount: f64) {
        self.0[i] = (self.0[i] - amount).max(0.0);
    }

    /// The counter vector narrowed to f32 (the PJRT artifact's dtype).
    pub fn as_f32(&self) -> [f32; NC] {
        let mut out = [0f32; NC];
        for (o, v) in out.iter_mut().zip(self.0.iter()) {
            *o = *v as f32;
        }
        out
    }

    /// Sum of every CiM-op counter (all levels, all op kinds).
    pub fn total_cim_ops(&self) -> f64 {
        self.0[C_CIM_L1_OR..=C_CIM_L2_ADD].iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::Asm;
    use crate::config::SystemConfig;
    use crate::sim::{simulate, Limits};

    #[test]
    fn names_match_python_schema_shape() {
        assert_eq!(COUNTER_NAMES.len(), NC);
        assert_eq!(COUNTER_NAMES[C_CYCLES], "cycles");
        assert_eq!(COUNTER_NAMES[C_CIM_L1_ADD], "cim_l1_add");
        assert_eq!(COUNTER_NAMES[C_DRAM_WRITES], "dram_writes");
    }

    #[test]
    fn from_trace_populates_core_and_mem() {
        let mut a = Asm::new("t");
        let buf = a.data.alloc_i32("buf", &[1, 2]);
        a.li(1, buf as i32);
        a.lw(2, 1, 0);
        a.lw(3, 1, 4);
        a.add(4, 2, 3);
        a.sw(4, 1, 0);
        a.halt();
        let t = simulate(&a.assemble(), &SystemConfig::default(), Limits::default()).unwrap();
        let c = CounterSet::from_trace(&t);
        assert_eq!(c[C_FETCH], t.committed as f64);
        assert_eq!(c[C_LSQ_READS], 2.0);
        assert_eq!(c[C_LSQ_WRITES], 1.0);
        assert!(c[C_CYCLES] > 0.0);
        assert_eq!(c.total_cim_ops(), 0.0);
    }

    #[test]
    fn dec_clamps_at_zero() {
        let mut c = CounterSet::default();
        c[C_FETCH] = 2.0;
        c.dec(C_FETCH, 5.0);
        assert_eq!(c[C_FETCH], 0.0);
    }
}
