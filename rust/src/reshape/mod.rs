//! Trace reshaping for system profiling — paper §IV-C.
//!
//! Given the offloading candidates, reshaping produces the CiM view of the
//! execution: offloaded instructions leave the CPU pipeline (their fetch/
//! decode/rename/issue/commit and functional-unit events disappear, their
//! memory accesses disappear), CiM operations appear at the cache level that
//! owns the data, operand moves and result readbacks add compensating
//! accesses, and the speedup-model perf vector is assembled (§V-C2).
//!
//! Candidates extracted from the same IDG tree were already merged by the
//! selection pass (post-order claim), matching the paper's combine step.
//!
//! Two entry points share one per-candidate application
//! ([`apply_candidate`]):
//!
//! * [`reshape`] — the batch view: mutate a copy of the trace's counters.
//! * [`DeltaSink`] + [`reshape_from_deltas`] — the streaming view: fold
//!   each candidate into a *signed delta* vector as the online analyzer
//!   emits it, then combine with the baseline counters once the
//!   simulation summary exists.  Every counter mutation is a ±1.0 step on
//!   integer-valued f64s, so the two orders produce bit-identical
//!   results.

pub mod counters;

pub use counters::{CounterSet, NC};

use crate::analyzer::{CandidateRecord, CandidateSink, CimOp, Selection};
use crate::isa::FuncUnit;
use crate::probes::{InstrInfo, MemLevel, Trace, TraceSummary};

use counters::*;

/// Perf-vector length (mirrors `constants.py` PERF_*).
pub const NPERF: usize = 6;
/// Perf-vector slot: simulated baseline cycles.
pub const P_CYCLES: usize = 0;
/// Perf-vector slot: committed instructions.
pub const P_COMMITTED: usize = 1;
/// Perf-vector slot: instructions removed from the CPU stream.
pub const P_REMOVED: usize = 2;
/// Perf-vector slot: CiM-ADD operations executing in the L1 array.
pub const P_CIM_ADD_L1: usize = 3;
/// Perf-vector slot: CiM-ADD operations executing in the L2 array.
pub const P_CIM_ADD_L2: usize = 4;
/// Perf-vector slot: core clock in GHz.
pub const P_CLOCK_GHZ: usize = 5;

/// The reshaped execution: both counter vectors plus the perf vector.
#[derive(Clone, Debug)]
pub struct Reshaped {
    /// baseline (non-CiM) performance counters
    pub base: CounterSet,
    /// CiM-view counters after candidate application
    pub cim: CounterSet,
    /// speedup-model inputs (see the `P_*` slot constants)
    pub perf: [f64; NPERF],
    /// instructions removed from the CPU stream
    pub removed: u64,
    /// CiM ops added, by (level, op)
    pub cim_op_count: u64,
}

/// Counter mutation target: the batch path mutates a [`CounterSet`]
/// (decrements clamp at zero), the streaming path accumulates a signed
/// delta.  All mutations are unit steps.
trait EventAcc {
    fn dec(&mut self, i: usize);
    fn inc(&mut self, i: usize);
}

impl EventAcc for CounterSet {
    fn dec(&mut self, i: usize) {
        CounterSet::dec(self, i, 1.0);
    }

    fn inc(&mut self, i: usize) {
        self[i] += 1.0;
    }
}

/// Signed per-counter delta (no clamping — applied to the baseline later).
#[derive(Clone, Debug)]
pub struct DeltaCounters(pub [f64; NC]);

impl Default for DeltaCounters {
    fn default() -> Self {
        Self([0.0; NC])
    }
}

impl EventAcc for DeltaCounters {
    fn dec(&mut self, i: usize) {
        self.0[i] -= 1.0;
    }

    fn inc(&mut self, i: usize) {
        self.0[i] += 1.0;
    }
}

fn remove_core_events<A: EventAcc>(c: &mut A, is: &InstrInfo) {
    c.dec(C_FETCH);
    c.dec(C_DECODE);
    c.dec(C_RENAME);
    c.dec(C_IQ_READS);
    c.dec(C_IQ_WRITES);
    c.dec(C_ROB_READS);
    c.dec(C_ROB_WRITES);
    for s in is.instr.sources().into_iter().flatten() {
        if s < crate::isa::NUM_INT_REGS {
            c.dec(C_INT_RF_READS);
        } else {
            c.dec(C_FP_RF_READS);
        }
    }
    if let Some(rd) = is.instr.dest() {
        if rd < crate::isa::NUM_INT_REGS {
            c.dec(C_INT_RF_WRITES);
        } else {
            c.dec(C_FP_RF_WRITES);
        }
    }
    let fu_counter = match is.fu {
        FuncUnit::IntAlu => C_INT_ALU,
        FuncUnit::IntMul => C_INT_MUL,
        FuncUnit::IntDiv => C_INT_DIV,
        FuncUnit::FpAlu => C_FP_ALU,
        FuncUnit::FpMul => C_FP_MUL,
        FuncUnit::FpDiv => C_FP_DIV,
        FuncUnit::Branch => C_BRANCH,
        FuncUnit::MemRead => {
            c.dec(C_LSQ_READS);
            C_INT_ALU // address generation ALU op folded into mem path
        }
        FuncUnit::MemWrite => {
            c.dec(C_LSQ_WRITES);
            C_INT_ALU
        }
    };
    if !is.instr.op.is_mem() {
        c.dec(fu_counter);
    }
}

fn remove_cache_events<A: EventAcc>(c: &mut A, is: &InstrInfo) {
    let Some(m) = is.mem else { return };
    if m.is_store {
        if m.l1_hit {
            c.dec(C_L1D_WRITE_HITS);
        } else {
            c.dec(C_L1D_WRITE_MISSES);
            if m.l2_hit {
                c.dec(C_L2_READ_HITS);
            } else {
                c.dec(C_L2_READ_MISSES);
                c.dec(C_DRAM_READS);
            }
        }
    } else if m.l1_hit {
        c.dec(C_L1D_READ_HITS);
    } else {
        c.dec(C_L1D_READ_MISSES);
        if m.l2_hit {
            c.dec(C_L2_READ_HITS);
        } else {
            c.dec(C_L2_READ_MISSES);
            c.dec(C_DRAM_READS);
        }
    }
}

fn cim_counter(level: MemLevel, op: CimOp) -> usize {
    match (level, op) {
        (MemLevel::L1, CimOp::Or) => C_CIM_L1_OR,
        (MemLevel::L1, CimOp::And) => C_CIM_L1_AND,
        (MemLevel::L1, CimOp::Xor) => C_CIM_L1_XOR,
        (MemLevel::L1, CimOp::Add) => C_CIM_L1_ADD,
        (MemLevel::L2, CimOp::Or) => C_CIM_L2_OR,
        (MemLevel::L2, CimOp::And) => C_CIM_L2_AND,
        (MemLevel::L2, CimOp::Xor) => C_CIM_L2_XOR,
        (MemLevel::L2, CimOp::Add) => C_CIM_L2_ADD,
        (MemLevel::Dram, _) => unreachable!("CiM ops never execute in DRAM"),
    }
}

/// Fold one candidate's effect into `acc`: removals for its offloaded
/// instructions, CiM-op appearances at its level, compensating accesses
/// for operand moves and readbacks.
#[allow(clippy::too_many_arguments)]
fn apply_candidate<A: EventAcc>(
    acc: &mut A,
    level: MemLevel,
    ops: &[CimOp],
    member_infos: &[InstrInfo],
    load_infos: &[InstrInfo],
    absorbed: Option<&InstrInfo>,
    moves: u32,
    readbacks: u32,
    cim_add: &mut [u64; 2],
    cim_op_count: &mut u64,
) {
    // offloaded CiM-op instructions leave the pipeline
    for is in member_infos {
        remove_core_events(acc, is);
    }
    // claimed loads disappear (instruction + cache traffic)
    for is in load_infos {
        remove_core_events(acc, is);
        remove_cache_events(acc, is);
    }
    // absorbed store disappears
    if let Some(is) = absorbed {
        remove_core_events(acc, is);
        remove_cache_events(acc, is);
    }
    // CiM operations appear at the candidate's level
    for &op in ops {
        acc.inc(cim_counter(level, op));
        *cim_op_count += 1;
        if op == CimOp::Add {
            cim_add[(level == MemLevel::L2) as usize] += 1;
        }
    }
    // operand moves: read at the source level + write at the exec level
    for _ in 0..moves {
        match level {
            MemLevel::L2 => {
                acc.inc(C_L1D_READ_HITS);
                acc.inc(C_L2_WRITE_HITS);
            }
            _ => {
                acc.inc(C_L2_READ_HITS);
                acc.inc(C_L1D_WRITE_HITS);
            }
        }
    }
    // readbacks: the CPU still needs the result in a register
    for _ in 0..readbacks {
        match level {
            MemLevel::L2 => acc.inc(C_L2_READ_HITS),
            _ => acc.inc(C_L1D_READ_HITS),
        }
        acc.inc(C_LSQ_READS);
    }
}

/// Streaming accumulator: fold candidates into deltas as the online
/// analyzer emits them.  O(1) state — nothing per-candidate is retained,
/// which is also what makes the finished sink a cheap, serializable
/// analysis artifact (see `coordinator::analysis_store`).
#[derive(Clone, Default)]
pub struct DeltaSink {
    /// signed counter deltas accumulated over every candidate so far
    pub delta: DeltaCounters,
    /// instructions removed from the CPU stream so far
    pub removed: u64,
    /// CiM-ADD counts per level (L1, L2) for the speedup model
    pub cim_add: [u64; 2],
    /// CiM operations added so far (all levels, all ops)
    pub cim_op_count: u64,
}

impl DeltaSink {
    /// Fold one candidate's effect into the running deltas.  This is the
    /// whole sink logic, exposed by reference so tee sinks can share a
    /// record with another consumer without cloning it.
    pub fn fold(&mut self, rec: &CandidateRecord) {
        let c = &rec.candidate;
        apply_candidate(
            &mut self.delta,
            c.level,
            &c.ops,
            &rec.member_infos,
            &rec.load_infos,
            rec.absorbed.as_ref(),
            c.moves,
            c.readbacks,
            &mut self.cim_add,
            &mut self.cim_op_count,
        );
        // readbacks keep one CPU-side consumer access alive; per-candidate
        // readbacks never exceed removed_count, so folding the subtraction
        // per candidate matches the batch running total exactly
        self.removed += c.removed_count();
        self.removed = self.removed.saturating_sub(c.readbacks as u64);
    }
}

impl CandidateSink for DeltaSink {
    fn on_candidate(&mut self, rec: CandidateRecord) {
        self.fold(&rec);
    }
}

/// Extra cycles a CiM-ADD pays over a plain read at each level, from the
/// array latency model (Fig 11) — used to scale the CiM system's cycle
/// count so leakage tracks execution time.
fn add_latency_extra(cfg: &crate::config::SystemConfig) -> (f64, f64) {
    let (r1, r2) = crate::energy::cfg_rows(cfg);
    let (_, l1) = crate::energy::energy_latency(&r1);
    let (_, l2) = crate::energy::energy_latency(&r2);
    use crate::energy::calib::{OP_ADD, OP_READ};
    (
        (l1[OP_ADD] - l1[OP_READ]).max(0.0),
        (l2[OP_ADD] - l2[OP_READ]).max(0.0),
    )
}

/// Shared tail: assemble the perf vector and the CiM cycle estimate.
fn finish_reshape(
    base: CounterSet,
    mut cim: CounterSet,
    cycles: u64,
    committed: u64,
    removed: u64,
    cim_add: [u64; 2],
    cim_op_count: u64,
    cfg: &crate::config::SystemConfig,
) -> Reshaped {
    let perf = [
        cycles as f64,
        committed as f64,
        removed as f64,
        cim_add[0] as f64,
        cim_add[1] as f64,
        cfg.clock_ghz,
    ];
    // leakage tracks execution time: the CiM system's cycle counter uses
    // the same constant-CPI estimate the speedup model applies (§V-C2)
    let (extra_l1, extra_l2) = add_latency_extra(cfg);
    let cpi = if committed > 0 {
        cycles as f64 / committed as f64
    } else {
        1.0
    };
    let cycles_cim = (cycles as f64 - removed as f64 * cpi
        + cim_add[0] as f64 * extra_l1
        + cim_add[1] as f64 * extra_l2)
        .max(1.0);
    cim[counters::C_CYCLES] = cycles_cim;
    Reshaped { base, cim, perf, removed, cim_op_count }
}

/// Reshape `trace` according to `sel`, producing profiler inputs (the
/// batch view over a materialized trace).
pub fn reshape(trace: &Trace, sel: &Selection, cfg: &crate::config::SystemConfig) -> Reshaped {
    let base = CounterSet::from_trace(trace);
    let mut cim = base.clone();
    let mut removed = 0u64;
    let mut cim_op_count = 0u64;
    let mut cim_add = [0u64; 2]; // L1, L2

    for cand in &sel.candidates {
        let member_infos: Vec<InstrInfo> = cand
            .members
            .iter()
            .map(|&m| InstrInfo::of(&trace.ciq[m as usize]))
            .collect();
        let load_infos: Vec<InstrInfo> = cand
            .loads
            .iter()
            .map(|&l| InstrInfo::of(&trace.ciq[l as usize]))
            .collect();
        let absorbed = cand
            .absorbed_store
            .map(|s| InstrInfo::of(&trace.ciq[s as usize]));
        apply_candidate(
            &mut cim,
            cand.level,
            &cand.ops,
            &member_infos,
            &load_infos,
            absorbed.as_ref(),
            cand.moves,
            cand.readbacks,
            &mut cim_add,
            &mut cim_op_count,
        );
        removed += cand.removed_count();
        // readbacks keep one CPU-side consumer access alive
        removed = removed.saturating_sub(cand.readbacks as u64);
    }

    finish_reshape(
        base,
        cim,
        trace.cycles,
        trace.committed,
        removed,
        cim_add,
        cim_op_count,
        cfg,
    )
}

/// Streaming counterpart of [`reshape`]: combine the baseline counters
/// (available once the simulation summary exists) with the deltas a
/// [`DeltaSink`] folded while candidates streamed past.  Produces results
/// bit-identical to the batch path because every delta is an exact
/// integer step.
pub fn reshape_from_deltas(
    summary: &TraceSummary,
    d: &DeltaSink,
    cfg: &crate::config::SystemConfig,
) -> Reshaped {
    let base = CounterSet::from_summary(summary);
    let mut cim = base.clone();
    for i in 0..NC {
        // counts are exact integers in f64, so (base + Σ±1) equals the
        // batch path's sequential updates; the clamp mirrors
        // `CounterSet::dec` and never fires for a consistent trace
        cim.0[i] = (cim.0[i] + d.delta.0[i]).max(0.0);
    }
    finish_reshape(
        base,
        cim,
        summary.cycles,
        summary.committed,
        d.removed,
        d.cim_add,
        d.cim_op_count,
        cfg,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyzer::{analyze, LocalityRule};
    use crate::asm::Asm;
    use crate::config::SystemConfig;
    use crate::sim::{simulate, Limits};

    fn pattern_program(reps: usize) -> Asm {
        let mut a = Asm::new("t");
        let buf = a.data.alloc_i32("buf", &[1, 2, 3, 4, 5, 6, 7, 8]);
        a.li(1, buf as i32);
        a.lw(9, 1, 0);
        for _ in 0..reps {
            a.lw(2, 1, 0);
            a.lw(3, 1, 4);
            a.add(4, 2, 3);
            a.sw(4, 1, 8);
        }
        a.halt();
        a
    }

    fn reshaped(reps: usize) -> (Trace, Reshaped) {
        let cfg = SystemConfig::default();
        let t = simulate(&pattern_program(reps).assemble(), &cfg, Limits::default()).unwrap();
        let an = analyze(&t, &cfg, LocalityRule::AnyCache);
        let r = reshape(&t, &an.selection, &cfg);
        (t, r)
    }

    #[test]
    fn conservation_of_instructions() {
        let (t, r) = reshaped(5);
        // removed + remaining fetches == original fetches
        assert_eq!(r.base[C_FETCH], t.committed as f64);
        assert!((r.cim[C_FETCH] + r.removed as f64 - r.base[C_FETCH]).abs() < 1e-9);
    }

    #[test]
    fn cim_ops_appear_and_memory_traffic_drops() {
        let (_, r) = reshaped(5);
        assert!(r.cim_op_count >= 5);
        assert!(r.cim.total_cim_ops() >= 5.0);
        let base_mem: f64 = r.base.0[C_L1D_READ_HITS..=C_DRAM_WRITES].iter().sum();
        let cim_mem: f64 = r.cim.0[C_L1D_READ_HITS..=C_DRAM_WRITES].iter().sum();
        assert!(cim_mem < base_mem, "cim {cim_mem} !< base {base_mem}");
    }

    #[test]
    fn counters_never_negative() {
        let (_, r) = reshaped(8);
        for (i, v) in r.cim.0.iter().enumerate() {
            assert!(*v >= 0.0, "counter {i} negative: {v}");
        }
    }

    #[test]
    fn perf_vector_consistent() {
        let (t, r) = reshaped(4);
        assert_eq!(r.perf[P_CYCLES], t.cycles as f64);
        assert_eq!(r.perf[P_COMMITTED], t.committed as f64);
        assert_eq!(r.perf[P_REMOVED], r.removed as f64);
        assert_eq!(r.perf[P_CIM_ADD_L1] + r.perf[P_CIM_ADD_L2], r.cim_op_count as f64);
        assert_eq!(r.perf[P_CLOCK_GHZ], 1.0);
    }

    #[test]
    fn no_candidates_means_identity() {
        let mut a = Asm::new("t");
        a.li(1, 3);
        a.mul(2, 1, 1);
        a.halt();
        let cfg = SystemConfig::default();
        let t = simulate(&a.assemble(), &cfg, Limits::default()).unwrap();
        let an = analyze(&t, &cfg, LocalityRule::AnyCache);
        let r = reshape(&t, &an.selection, &cfg);
        assert_eq!(r.base, r.cim);
        assert_eq!(r.removed, 0);
    }

    #[test]
    fn delta_path_matches_batch_path() {
        let cfg = SystemConfig::default();
        let t = simulate(&pattern_program(6).assemble(), &cfg, Limits::default()).unwrap();
        let an = analyze(&t, &cfg, LocalityRule::AnyCache);
        let batch = reshape(&t, &an.selection, &cfg);

        let mut oa = crate::analyzer::OnlineAnalyzer::new(
            cfg.cim_levels,
            LocalityRule::AnyCache,
            super::DeltaSink::default(),
        );
        for is in &t.ciq {
            oa.push(is);
        }
        let (_, deltas) = oa.finish();
        let streamed = reshape_from_deltas(&t.summary(), &deltas, &cfg);
        assert_eq!(batch.base, streamed.base);
        assert_eq!(batch.cim, streamed.cim);
        assert_eq!(batch.perf, streamed.perf);
        assert_eq!(batch.removed, streamed.removed);
        assert_eq!(batch.cim_op_count, streamed.cim_op_count);
    }
}
